//! NUMA invariants of the socket-aware shared-memory model:
//!
//! * **1 socket == flat, bit for bit** — with the default single-socket
//!   config every registry dataset's multi-core run carries structurally
//!   zero `numa` stats, and cranking the remote-cost knobs changes nothing
//!   (the distances are all zero, so the knobs can never leak in);
//! * **count additivity** — `ws-numa` keeps the exact per-core event-count
//!   additivity contract vs the serial loop (same group-aligned dyn block
//!   geometry as ws-dyn/ws-bw);
//! * **2-socket behaviour** — real runs report remote traffic, stay
//!   bit-reproducible, never get *faster* than the flat model under the
//!   same plan, and the critical path is monotone in the remote-distance
//!   cost (the all-remote-vs-local replay-level pin lives in
//!   `mem::shared`'s unit tests);
//! * **pilot arbitration** — `ws-numa` beats or ties `ws-bw` on most of
//!   the registry at 2 sockets (it falls back to ws-bw's plan whenever its
//!   socket-aware pilot predicts no win).

use anyhow::Result;
use sparsezipper::config::SharedMemConfig;
use sparsezipper::matrix::registry;
use sparsezipper::sim::machine::OpCounters;
use sparsezipper::spgemm::parallel::{self, ParallelConfig, Scheduler};
use sparsezipper::spgemm::{ImplId, SpGemm};
use sparsezipper::{Machine, SystemConfig};

const SCALE: f64 = 0.003;

fn native(id: ImplId) -> impl Fn() -> Result<Box<dyn SpGemm>> + Sync {
    move || id.instantiate(sparsezipper::Engine::Native, std::path::Path::new("."))
}

fn two_socket_sys() -> SystemConfig {
    let base = SystemConfig::default();
    SystemConfig {
        shared: SharedMemConfig { sockets: 2, ..base.shared },
        ..base
    }
}

#[test]
fn one_socket_is_bit_identical_to_the_flat_model_on_every_registry_dataset() {
    let flat = SystemConfig::default();
    // Same single-socket topology, but with the remote-cost knobs cranked
    // three orders of magnitude: if any NUMA charge leaked in at 1 socket,
    // this run would diverge. Bit-identical per-core cycles and shared
    // stats pin "sockets=1 reproduces the flat model bit for bit".
    let cranked = SystemConfig {
        shared: SharedMemConfig {
            remote_transfer_cycles: 10_000.0,
            remote_coherence_cycles: 10_000.0,
            ..flat.shared
        },
        ..flat
    };
    for d in registry::DATASETS {
        let a = d.build(SCALE);
        let cfg = ParallelConfig::new(4);
        let base = parallel::row_blocked(&flat, native(ImplId::Spz), &a, &a, &cfg).unwrap();
        let loud = parallel::row_blocked(&cranked, native(ImplId::Spz), &a, &a, &cfg).unwrap();
        for (c, (mb, ml)) in base
            .metrics
            .per_core
            .iter()
            .zip(&loud.metrics.per_core)
            .enumerate()
        {
            let sh = &mb.shared;
            assert_eq!(sh.remote_fills, 0, "{}: core {c} remote fills at 1 socket", d.name);
            assert_eq!(sh.remote_forwards, 0, "{}: core {c}", d.name);
            assert_eq!(sh.remote_extra_cycles, 0.0, "{}: core {c}", d.name);
            assert_eq!(mb.cycles, ml.cycles, "{}: core {c} cycles drifted", d.name);
            assert_eq!(mb.shared, ml.shared, "{}: core {c} shared stats drifted", d.name);
        }
        assert_eq!(
            base.metrics.channel_busy_cycles, loud.metrics.channel_busy_cycles,
            "{}: channel occupancy must ignore remote knobs at 1 socket",
            d.name
        );
    }
}

#[test]
fn ws_numa_keeps_exact_count_additivity_vs_serial() {
    let sys = two_socket_sys();
    for d in registry::DATASETS.iter().take(6) {
        let a = d.build(SCALE);
        for id in [ImplId::SclHash, ImplId::Spz] {
            let serial_counts = {
                let mut m = Machine::new(SystemConfig::default());
                let mut im = native(id)().unwrap();
                im.multiply(&mut m, &a, &a).unwrap();
                m.metrics().ops
            };
            let cfg = ParallelConfig {
                scheduler: Scheduler::WorkStealingNuma,
                ..ParallelConfig::new(4)
            };
            let run = parallel::row_blocked(&sys, native(id), &a, &a, &cfg).unwrap();
            let mut sum = OpCounters::default();
            for core in &run.metrics.per_core {
                sum.add(&core.ops);
            }
            assert_eq!(
                sum, serial_counts,
                "{} on {}: ws-numa per-core counts must sum to the serial loop's",
                id.name(),
                d.name
            );
        }
    }
}

#[test]
fn two_socket_runs_report_remote_traffic_and_stay_deterministic() {
    let sys = two_socket_sys();
    let d = registry::find("p2p").unwrap();
    let a = d.build(0.01);
    let cfg = ParallelConfig::new(4);
    let r1 = parallel::row_blocked(&sys, native(ImplId::Spz), &a, &a, &cfg).unwrap();
    let r2 = parallel::row_blocked(&sys, native(ImplId::Spz), &a, &a, &cfg).unwrap();
    let tot = &r1.metrics.total.shared;
    // Four cores over two sockets streaming one B: half the channel groups
    // are remote to each core, so remote fills are the norm.
    assert!(tot.remote_fills > 0, "no remote fills at 2 sockets: {tot:?}");
    assert!(tot.remote_extra_cycles > 0.0);
    // Bit-reproducible across host thread schedules.
    assert_eq!(
        r1.metrics.per_core.iter().map(|m| m.shared).collect::<Vec<_>>(),
        r2.metrics.per_core.iter().map(|m| m.shared).collect::<Vec<_>>()
    );
    let c1: Vec<f64> = r1.metrics.per_core.iter().map(|m| m.cycles).collect();
    let c2: Vec<f64> = r2.metrics.per_core.iter().map(|m| m.cycles).collect();
    assert_eq!(c1, c2);
    // NUMA only ever adds: under the same (socket-blind work-stealing)
    // plan *and the same line-to-channel mapping*, the flat run
    // lower-bounds the 2-socket critical path. The mapping-preserving
    // policy is the blind interleave — first-touch re-homes pages into
    // per-socket channel groups, which legitimately reshuffles queueing
    // and bank patterns, so the structural inequality is interleave's.
    let il = SystemConfig {
        shared: SharedMemConfig {
            page_placement: sparsezipper::config::PagePlacement::Interleave,
            ..sys.shared
        },
        ..sys
    };
    let r_il = parallel::row_blocked(&il, native(ImplId::Spz), &a, &a, &cfg).unwrap();
    let flat = parallel::row_blocked(
        &SystemConfig::default(),
        native(ImplId::Spz),
        &a,
        &a,
        &cfg,
    )
    .unwrap();
    assert!(
        r_il.metrics.critical_path_cycles >= flat.metrics.critical_path_cycles,
        "2-socket {} < flat {}: remote pricing cannot speed a run up",
        r_il.metrics.critical_path_cycles,
        flat.metrics.critical_path_cycles
    );
}

#[test]
fn two_socket_critical_path_is_monotone_in_remote_distance_cost() {
    // Under a plan that ignores the NUMA knobs (ws-dyn's geometry and claim
    // depend only on the work estimates), pricier distances can only slow
    // the run: near costs <= the same run with every remote hop 4x as
    // expensive. This is the driver-level face of the replay-level
    // "local placement beats all-remote placement" pin.
    let near = two_socket_sys();
    let far = SystemConfig {
        shared: SharedMemConfig {
            remote_transfer_cycles: near.shared.remote_transfer_cycles * 4.0,
            remote_coherence_cycles: near.shared.remote_coherence_cycles * 4.0,
            ..near.shared
        },
        ..near
    };
    let cfg = ParallelConfig {
        scheduler: Scheduler::WorkStealingDyn,
        ..ParallelConfig::new(4)
    };
    for d in registry::DATASETS.iter().take(4) {
        let a = d.build(SCALE);
        let n = parallel::row_blocked(&near, native(ImplId::Spz), &a, &a, &cfg).unwrap();
        let f = parallel::row_blocked(&far, native(ImplId::Spz), &a, &a, &cfg).unwrap();
        assert!(
            n.metrics.critical_path_cycles <= f.metrics.critical_path_cycles,
            "{}: near {} > far {}",
            d.name,
            n.metrics.critical_path_cycles,
            f.metrics.critical_path_cycles
        );
        assert!(
            f.metrics.total.shared.remote_extra_cycles
                > n.metrics.total.shared.remote_extra_cycles,
            "{}: pricier hops must charge more",
            d.name
        );
    }
}

#[test]
fn ws_numa_does_not_lose_to_ws_bw_on_most_of_the_registry_at_two_sockets() {
    let sys = two_socket_sys();
    let mut wins_or_ties = 0usize;
    let sample: Vec<_> = registry::DATASETS.iter().take(8).collect();
    for d in &sample {
        let a = d.build(SCALE);
        let bw = parallel::row_blocked(
            &sys,
            native(ImplId::Spz),
            &a,
            &a,
            &ParallelConfig { scheduler: Scheduler::WorkStealingBw, ..ParallelConfig::new(4) },
        )
        .unwrap();
        let nu = parallel::row_blocked(
            &sys,
            native(ImplId::Spz),
            &a,
            &a,
            &ParallelConfig { scheduler: Scheduler::WorkStealingNuma, ..ParallelConfig::new(4) },
        )
        .unwrap();
        if nu.metrics.critical_path_cycles <= bw.metrics.critical_path_cycles * (1.0 + 1e-9) {
            wins_or_ties += 1;
        }
    }
    assert!(
        wins_or_ties * 2 >= sample.len(),
        "ws-numa beat/tied ws-bw on only {wins_or_ties}/{} datasets",
        sample.len()
    );
}
