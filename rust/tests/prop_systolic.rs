//! Property tests over the systolic functional model — the invariants the
//! SpGEMM software and the micro-architecture both rely on. (Hand-rolled
//! generators: proptest is not in the offline vendor set.)

use sparsezipper::systolic::functional::{sort_chunk, sort_step, zip_step};
use sparsezipper::util::Pcg32;

fn sorted_unique(rng: &mut Pcg32, max_len: usize, range: u32) -> (Vec<u32>, Vec<f32>) {
    let mut k: Vec<u32> = (0..rng.gen_usize(max_len + 1)).map(|_| rng.gen_range(range)).collect();
    k.sort_unstable();
    k.dedup();
    let v: Vec<f32> = k.iter().map(|_| rng.gen_f32_range(0.5, 1.5)).collect();
    (k, v)
}

/// sort_chunk output is sorted, unique, value-mass-preserving.
#[test]
fn prop_sort_chunk_invariants() {
    let mut rng = Pcg32::new(1);
    for _ in 0..2000 {
        let len = rng.gen_usize(33);
        let k: Vec<u32> = (0..len).map(|_| rng.gen_range(20)).collect();
        let v: Vec<f32> = (0..len).map(|_| 1.0).collect();
        let (ok, ov) = sort_chunk(&k, &v);
        assert!(ok.windows(2).all(|w| w[0] < w[1]), "sorted unique");
        let mass: f32 = ov.iter().sum();
        assert!((mass - len as f32).abs() < 1e-3, "value mass");
        let mut uniq = k.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(ok, uniq, "key set preserved");
    }
}

/// zip_step invariants: sorted-unique output, prefix consumption, the
/// emitted<unconsumed ordering the software merge loop needs, and exact
/// value conservation over consumed elements.
#[test]
fn prop_zip_step_invariants() {
    let mut rng = Pcg32::new(2);
    for trial in 0..3000 {
        let n = [4usize, 8, 16][trial % 3];
        let (a, av) = sorted_unique(&mut rng, n, 50);
        let (b, bv) = sorted_unique(&mut rng, n, 50);
        let out = zip_step(n, &a, &av, &b, &bv);

        // 1. consumption counts are prefixes within bounds
        assert!(out.consumed_a <= a.len() && out.consumed_b <= b.len());
        // 2. outputs sorted-unique and east < south
        let all: Vec<u32> = out.east_keys.iter().chain(&out.south_keys).copied().collect();
        assert!(all.windows(2).all(|w| w[0] < w[1]), "merged sorted unique");
        assert!(out.east_keys.len() <= n);
        // 3. emitted keys < all unconsumed keys
        if let Some(&emax) = all.last() {
            assert!(a[out.consumed_a..].iter().all(|&k| k > emax));
            assert!(b[out.consumed_b..].iter().all(|&k| k > emax));
        }
        // 4. value conservation over consumed prefixes
        let consumed_mass: f32 = av[..out.consumed_a].iter().chain(&bv[..out.consumed_b]).sum();
        let out_mass: f32 = out.east_vals.iter().chain(&out.south_vals).sum();
        assert!(
            (consumed_mass - out_mass).abs() < 1e-3,
            "mass {consumed_mass} vs {out_mass}"
        );
        // 5. progress whenever both sides non-empty
        if !a.is_empty() && !b.is_empty() {
            assert!(out.consumed_a + out.consumed_b >= 1);
        }
        // 6. merged key set == union of consumed prefixes
        let mut expect: Vec<u32> = a[..out.consumed_a]
            .iter()
            .chain(&b[..out.consumed_b])
            .copied()
            .collect();
        expect.sort_unstable();
        expect.dedup();
        assert_eq!(all, expect);
    }
}

/// Iterated zip (the software merge loop) fully merges two partitions for
/// any input — termination + completeness, the Figure 2 algorithm.
#[test]
fn prop_zip_loop_merges_fully() {
    let mut rng = Pcg32::new(3);
    for trial in 0..300 {
        let n = 8;
        let (a, av) = sorted_unique(&mut rng, 40, 100);
        let (b, bv) = sorted_unique(&mut rng, 40, 100);
        let (mut ia, mut ib) = (0usize, 0usize);
        let mut out_k: Vec<u32> = Vec::new();
        let mut out_v: Vec<f32> = Vec::new();
        let mut steps = 0;
        while ia < a.len() && ib < b.len() {
            steps += 1;
            assert!(steps < 200, "merge loop did not terminate (trial {trial})");
            let ea = (ia + n).min(a.len());
            let eb = (ib + n).min(b.len());
            let st = zip_step(n, &a[ia..ea], &av[ia..ea], &b[ib..eb], &bv[ib..eb]);
            out_k.extend(&st.east_keys);
            out_k.extend(&st.south_keys);
            out_v.extend(&st.east_vals);
            out_v.extend(&st.south_vals);
            ia += st.consumed_a;
            ib += st.consumed_b;
        }
        // tail copy
        for (k, v) in a[ia..].iter().zip(&av[ia..]) {
            out_k.push(*k);
            out_v.push(*v);
        }
        for (k, v) in b[ib..].iter().zip(&bv[ib..]) {
            out_k.push(*k);
            out_v.push(*v);
        }
        // reference merge
        let mut expect: std::collections::BTreeMap<u32, f32> = std::collections::BTreeMap::new();
        for (k, v) in a.iter().zip(&av).chain(b.iter().zip(&bv)) {
            *expect.entry(*k).or_insert(0.0) += v;
        }
        let ek: Vec<u32> = expect.keys().copied().collect();
        assert_eq!(out_k, ek, "trial {trial}");
        for (got, want) in out_v.iter().zip(expect.values()) {
            assert!((got - want).abs() < 1e-3);
        }
    }
}

/// sort_step never mixes the two chunks.
#[test]
fn prop_sort_step_partition_isolation() {
    let mut rng = Pcg32::new(4);
    for _ in 0..1000 {
        let la = rng.gen_usize(17);
        let lb = rng.gen_usize(17);
        let a: Vec<u32> = (0..la).map(|_| rng.gen_range(100)).collect();
        let b: Vec<u32> = (0..lb).map(|_| rng.gen_range(100)).collect();
        let av = vec![1.0f32; a.len()];
        let bv = vec![1.0f32; b.len()];
        let out = sort_step(&a, &av, &b, &bv);
        let mut ua = a.clone();
        ua.sort_unstable();
        ua.dedup();
        let mut ub = b.clone();
        ub.sort_unstable();
        ub.dedup();
        assert_eq!(out.a_keys, ua);
        assert_eq!(out.b_keys, ub);
    }
}
