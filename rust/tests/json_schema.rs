//! Golden-file pin of the stable `--json` suite-export schema: the exact
//! sequence of object keys (field names, nesting order, phase names, and the
//! multi-core `per_core` section) must match `tests/golden/suite_json_schema.txt`.
//!
//! The contract from PR 1 is that this schema only ever *grows* — fields are
//! appended, never renamed or reordered. If you intentionally extend the
//! export, append the new keys to the golden file in emission order (the
//! test's failure output prints the observed sequence).

use sparsezipper::api::{DatasetSource, Session, SuiteSpec};
use sparsezipper::matrix::gen;
use sparsezipper::ImplId;
use std::sync::Arc;

const GOLDEN: &str = include_str!("golden/suite_json_schema.txt");

/// Object keys in order of appearance: every `"name"` immediately followed
/// (modulo whitespace) by a `:`. String *values* are never followed by a
/// colon in this grammar, so they are not captured.
fn keys(json: &str) -> Vec<String> {
    let b: Vec<char> = json.chars().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < b.len() {
        if b[i] == '"' {
            let start = i + 1;
            let mut j = start;
            while j < b.len() && b[j] != '"' {
                if b[j] == '\\' {
                    j += 1;
                }
                j += 1;
            }
            let mut k = j + 1;
            while k < b.len() && (b[k] == ' ' || b[k] == '\n' || b[k] == '\t') {
                k += 1;
            }
            if k < b.len() && b[k] == ':' {
                out.push(b[start..j].iter().collect());
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    out
}

#[test]
fn key_extractor_handles_nesting_and_string_values() {
    let ks = keys("{\"a\":1,\"b\":{\"c\":\"not:a:key\"},\"d\":[{\"e\":null}]}");
    assert_eq!(ks, vec!["a", "b", "c", "d", "e"]);
}

#[test]
fn suite_json_schema_matches_golden() {
    let session = Session::new();
    let spec = SuiteSpec {
        datasets: vec![DatasetSource::in_memory(
            "golden",
            Arc::new(gen::erdos_renyi(64, 64, 300, 7)),
        )],
        impls: vec![ImplId::SclHash, ImplId::Spz],
        scale: 1.0,
        threads: 1,
        verify: false,
        cores: 2,
        ..SuiteSpec::default()
    };
    let suite = session.run_suite(&spec).expect("suite");
    let observed = keys(&suite.to_json());
    let expected: Vec<String> = GOLDEN
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(str::to_string)
        .collect();
    assert_eq!(
        observed,
        expected,
        "--json schema drifted from tests/golden/suite_json_schema.txt.\n\
         The export schema is a stable contract: fields may only be appended.\n\
         Observed key sequence:\n{}",
        observed.join("\n")
    );
}

#[test]
fn suite_json_service_block_reports_the_pool() {
    let session = Session::new();
    let spec = SuiteSpec {
        datasets: vec![DatasetSource::in_memory(
            "svc",
            Arc::new(gen::erdos_renyi(48, 48, 200, 11)),
        )],
        impls: vec![ImplId::SclHash, ImplId::Spz],
        scale: 1.0,
        threads: 1,
        verify: false,
        ..SuiteSpec::default()
    };
    let suite = session.run_suite(&spec).expect("suite");
    let j = suite.to_json();
    // The deterministic counters of the pool run_suite ran on: 1 worker
    // (threads=1), both grid jobs admitted and completed under the internal
    // "suite" tenant. High-water marks depend on host timing and are only
    // bounded, not pinned.
    assert!(
        j.contains("\"service\": {\"workers\":1,\"admitted\":2,\"rejected\":0,\"completed\":2,\"failed\":0"),
        "{j}"
    );
    assert!(j.contains("\"tenants\":[{\"tenant\":\"suite\",\"weight\":1,\"served\":2}]"), "{j}");
    assert_eq!(suite.service.admitted, 2);
    assert_eq!(suite.service.completed, 2);
    assert!(suite.service.queue_depth_high_water <= 2);
    assert!(suite.service.slots_high_water <= 1, "1-worker pool can never run 2 slots");
}

#[test]
fn single_core_job_schema_has_null_multicore_tail() {
    let session = Session::new();
    let src = DatasetSource::in_memory("solo", Arc::new(gen::erdos_renyi(40, 40, 160, 9)));
    let res = session
        .run(&sparsezipper::JobSpec::new(ImplId::SclHash, src))
        .expect("job");
    let j = res.to_json();
    // The multi-core fields exist at every core count (null when serial), so
    // parsers see one shape.
    assert!(j.contains("\"cores\":1"), "{j}");
    assert!(
        j.ends_with("\"sched\":null,\"multicore\":null,\"sched_decisions\":null}"),
        "{j}"
    );
}
