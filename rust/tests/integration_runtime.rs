//! Runtime integration: load the AOT artifacts through PJRT and cross-check
//! the XLA engine against the native engine (and therefore against the
//! normative functional model and the PE-level array simulation).
//!
//! Requires `make artifacts`; tests skip (with a notice) when the artifacts
//! are missing so `cargo test` works in a fresh checkout. The whole suite is
//! compiled only with the `xla` cargo feature (the PJRT client lives behind
//! it).

#![cfg(feature = "xla")]

use sparsezipper::runtime::client::{artifact_dir, artifacts_available};
use sparsezipper::runtime::{NativeEngine, XlaEngine, ZipUnit};
use sparsezipper::util::Pcg32;

fn engines() -> Option<(NativeEngine, XlaEngine)> {
    let dir = artifact_dir();
    if !artifacts_available(&dir) {
        eprintln!("[skip] artifacts not found in {} — run `make artifacts`", dir.display());
        return None;
    }
    let xla = XlaEngine::load(&dir, 16, 16).expect("load artifacts");
    Some((NativeEngine::new(16), xla))
}

fn random_chunk(rng: &mut Pcg32, max_len: usize, key_range: u32) -> (Vec<u32>, Vec<f32>) {
    let len = rng.gen_usize(max_len + 1);
    let ks: Vec<u32> = (0..len).map(|_| rng.gen_range(key_range)).collect();
    let vs: Vec<f32> = ks.iter().map(|_| rng.gen_f32_range(0.5, 1.5)).collect();
    (ks, vs)
}

fn sorted_unique_chunk(rng: &mut Pcg32, max_len: usize, key_range: u32) -> (Vec<u32>, Vec<f32>) {
    let (mut ks, _) = random_chunk(rng, max_len, key_range);
    ks.sort_unstable();
    ks.dedup();
    let vs: Vec<f32> = ks.iter().map(|_| rng.gen_f32_range(0.5, 1.5)).collect();
    (ks, vs)
}

fn assert_steps_match(
    native: &sparsezipper::runtime::StepOut,
    xla: &sparsezipper::runtime::StepOut,
    ctx: &str,
) {
    assert_eq!(native.k0, xla.k0, "{ctx}: k0");
    assert_eq!(native.k1, xla.k1, "{ctx}: k1");
    assert_eq!(native.ic0, xla.ic0, "{ctx}: ic0");
    assert_eq!(native.ic1, xla.ic1, "{ctx}: ic1");
    assert_eq!(native.oc0, xla.oc0, "{ctx}: oc0");
    assert_eq!(native.oc1, xla.oc1, "{ctx}: oc1");
    for (a, b) in [(&native.v0, &xla.v0), (&native.v1, &xla.v1)] {
        assert_eq!(a.len(), b.len(), "{ctx}: value group size");
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.len(), y.len(), "{ctx}: value row len");
            for (p, q) in x.iter().zip(y) {
                assert!((p - q).abs() < 1e-4, "{ctx}: value {p} vs {q}");
            }
        }
    }
}

#[test]
fn xla_sort_step_matches_native_random() {
    let Some((mut native, mut xla)) = engines() else { return };
    let mut rng = Pcg32::new(2024);
    for trial in 0..25 {
        let group = 1 + rng.gen_usize(16);
        let mut k0 = Vec::new();
        let mut v0 = Vec::new();
        let mut k1 = Vec::new();
        let mut v1 = Vec::new();
        for _ in 0..group {
            let (k, v) = random_chunk(&mut rng, 16, 50);
            k0.push(k);
            v0.push(v);
            let (k, v) = random_chunk(&mut rng, 16, 50);
            k1.push(k);
            v1.push(v);
        }
        let a = native.sort_step(&k0, &v0, &k1, &v1).unwrap();
        let b = xla.sort_step(&k0, &v0, &k1, &v1).unwrap();
        assert_steps_match(&a, &b, &format!("sort trial {trial}"));
    }
}

#[test]
fn xla_zip_step_matches_native_random() {
    let Some((mut native, mut xla)) = engines() else { return };
    let mut rng = Pcg32::new(777);
    for trial in 0..25 {
        let group = 1 + rng.gen_usize(16);
        let mut k0 = Vec::new();
        let mut v0 = Vec::new();
        let mut k1 = Vec::new();
        let mut v1 = Vec::new();
        for _ in 0..group {
            let (k, v) = sorted_unique_chunk(&mut rng, 16, 60);
            k0.push(k);
            v0.push(v);
            let (k, v) = sorted_unique_chunk(&mut rng, 16, 60);
            k1.push(k);
            v1.push(v);
        }
        let a = native.zip_step(&k0, &v0, &k1, &v1).unwrap();
        let b = xla.zip_step(&k0, &v0, &k1, &v1).unwrap();
        assert_steps_match(&a, &b, &format!("zip trial {trial}"));
    }
}

#[test]
fn xla_fig5b_golden() {
    let Some((_, mut xla)) = engines() else { return };
    let out = xla
        .zip_step(
            &[vec![2, 5, 9]],
            &[vec![1.0, 2.0, 3.0]],
            &[vec![3, 8]],
            &[vec![4.0, 5.0]],
        )
        .unwrap();
    // N=16 here, so the whole merged stream {2,3,5,8} lands east; 9 excluded.
    assert_eq!(out.k0[0], vec![2, 3, 5, 8]);
    assert_eq!(out.ic0[0], 2);
    assert_eq!(out.ic1[0], 2);
}

#[test]
fn spz_end_to_end_with_xla_engine_matches_native() {
    let Some(_) = engines() else { return };
    use sparsezipper::config::SystemConfig;
    use sparsezipper::matrix::gen;
    use sparsezipper::sim::Machine;
    use sparsezipper::spgemm::{reference, same_product, spz::Spz, SpGemm};

    let a = gen::rmat(80, 80, 700, 0.58, 0.2, 0.14, 99);
    let r = reference(&a, &a);

    let mut m1 = Machine::new(SystemConfig::default());
    let c_native = Spz::native().multiply(&mut m1, &a, &a).unwrap();
    assert!(same_product(&c_native, &r, 1e-3));

    let mut m2 = Machine::new(SystemConfig::default());
    let mut spz_xla = Spz::xla(&artifact_dir()).unwrap();
    let c_xla = spz_xla.multiply(&mut m2, &a, &a).unwrap();
    assert!(same_product(&c_xla, &r, 1e-3), "XLA-engine product wrong");

    // Engine choice must not change simulated timing/counters.
    assert_eq!(m1.metrics().ops.mszipk, m2.metrics().ops.mszipk);
    assert_eq!(m1.metrics().ops.mssortk, m2.metrics().ops.mssortk);
    assert!((m1.metrics().cycles - m2.metrics().cycles).abs() < 1e-6);
}

#[test]
fn runner_reports_platform() {
    let mut r = sparsezipper::runtime::XlaRunner::new().unwrap();
    assert!(!r.platform().is_empty());
    let dir = artifact_dir();
    if artifacts_available(&dir) {
        r.load_hlo_text("sort_step", &dir.join("sort_step.hlo.txt")).unwrap();
        assert!(r.loaded().contains(&"sort_step"));
    }
}

#[test]
fn missing_artifact_is_an_error() {
    let mut r = sparsezipper::runtime::XlaRunner::new().unwrap();
    assert!(r
        .load_hlo_text("nope", std::path::Path::new("/nonexistent/nope.hlo.txt"))
        .is_err());
    assert!(r.run("nope", &[]).is_err());
}
