//! Sharded deterministic replay: `replay_shards` is a *throughput* knob,
//! never a *results* knob. These tests pin that contract end to end:
//!
//! * byte identity — the full stable job JSON (every metric the simulator
//!   reports) is byte-for-byte identical at 1, 2, and 8 shards on every
//!   Table III registry dataset;
//! * pilot schedulers — `ws-bw` and `ws-numa` route their arbitration
//!   through the same sharded engine (the pilot replay shares the config),
//!   and two independent sessions still produce identical bytes;
//! * exact counts — aggregate [`SharedStats`] counters and f64 stall
//!   charges match the serial engine exactly (no tolerance), and per-core
//!   counts still sum to the reported total at every shard count.
//!
//! [`SharedStats`]: sparsezipper::mem::SharedStats

use anyhow::Result;
use sparsezipper::api::{DatasetSource, JobSpec, Session, SessionConfig};
use sparsezipper::config::SharedMemConfig;
use sparsezipper::matrix::registry;
use sparsezipper::spgemm::parallel::{self, ParallelConfig, Scheduler};
use sparsezipper::spgemm::{ImplId, SpGemm};
use sparsezipper::SystemConfig;

const SCALE: f64 = 0.003;

fn native(id: ImplId) -> impl Fn() -> Result<Box<dyn SpGemm>> + Sync {
    move || id.instantiate(sparsezipper::Engine::Native, std::path::Path::new("."))
}

/// A fresh session whose replay engine runs `shards` worker shards on top
/// of `sys` (everything else default).
fn session_with_shards(sys: &SystemConfig, shards: usize) -> Session {
    Session::with_config(SessionConfig {
        sys: SystemConfig {
            shared: SharedMemConfig { replay_shards: shards, ..sys.shared },
            ..*sys
        },
        ..SessionConfig::default()
    })
}

fn stable_json(sess: &Session, spec: &JobSpec) -> String {
    sess.run(spec).expect("job runs").to_json_stable()
}

#[test]
fn sharded_replay_json_is_byte_identical_on_every_registry_dataset() {
    let sys = SystemConfig::default();
    for d in registry::DATASETS {
        let spec = JobSpec::new(ImplId::Spz, DatasetSource::registry(d.name).unwrap())
            .with_scale(SCALE)
            .with_cores(4);
        let serial = stable_json(&session_with_shards(&sys, 1), &spec);
        for shards in [2usize, 8] {
            let sharded = stable_json(&session_with_shards(&sys, shards), &spec);
            assert_eq!(
                sharded, serial,
                "{}: {shards}-shard replay diverged from serial",
                d.name
            );
        }
    }
}

#[test]
fn pilot_schedulers_stay_deterministic_with_shards() {
    // Two sockets so ws-numa actually exercises its socket-stamped pilot
    // (at one socket it degenerates to ws-bw's plan by construction).
    let base = SystemConfig::default();
    let sys = SystemConfig {
        shared: SharedMemConfig { sockets: 2, ..base.shared },
        ..base
    };
    for sched in [Scheduler::WorkStealingBw, Scheduler::WorkStealingNuma] {
        let spec = JobSpec::new(ImplId::Spz, DatasetSource::registry("p2p").unwrap())
            .with_scale(SCALE)
            .with_cores(4)
            .with_scheduler(sched);
        // Shard invariance through the pilot + final replay...
        let serial = stable_json(&session_with_shards(&sys, 1), &spec);
        let sharded = stable_json(&session_with_shards(&sys, 4), &spec);
        assert_eq!(sharded, serial, "{}: sharded pilot diverged", sched.name());
        // ...and run-to-run determinism of the sharded path itself: a
        // completely fresh session (new caches, new threads) byte-matches.
        let rerun = stable_json(&session_with_shards(&sys, 4), &spec);
        assert_eq!(rerun, sharded, "{}: sharded replay is nondeterministic", sched.name());
    }
}

#[test]
fn sharded_counts_and_stalls_match_serial_exactly() {
    let sys = SystemConfig::default();
    let sharded_sys = SystemConfig {
        shared: SharedMemConfig { replay_shards: 8, ..sys.shared },
        ..sys
    };
    let cfg = ParallelConfig::new(4);
    for name in ["p2p", "wiki", "soc"] {
        let d = registry::DATASETS.iter().find(|d| d.name == name).unwrap();
        let a = d.build(SCALE);
        let serial = parallel::row_blocked(&sys, native(ImplId::Spz), &a, &a, &cfg).unwrap();
        let sharded =
            parallel::row_blocked(&sharded_sys, native(ImplId::Spz), &a, &a, &cfg).unwrap();

        // Exact equality — counters *and* the f64 stall charges. The merge
        // phase performs every float add in canonical order, so there is no
        // tolerance to grant.
        let (s, t) = (&serial.metrics.total.shared, &sharded.metrics.total.shared);
        assert_eq!(t.llc_accesses, s.llc_accesses, "{name}");
        assert_eq!(t.llc_hits, s.llc_hits, "{name}");
        assert_eq!(t.demotions, s.demotions, "{name}");
        assert_eq!(t.upgrades, s.upgrades, "{name}");
        assert_eq!(t.invalidations_sent, s.invalidations_sent, "{name}");
        assert_eq!(t.dirty_forwards, s.dirty_forwards, "{name}");
        assert_eq!(t.row_conflicts, s.row_conflicts, "{name}");
        assert_eq!(t.replay_iters, s.replay_iters, "{name}");
        assert!(t.stall_cycles() == s.stall_cycles(), "{name}: stall cycles drifted");
        assert!(
            sharded.metrics.critical_path_cycles == serial.metrics.critical_path_cycles,
            "{name}: critical path drifted"
        );

        // Per-core additivity survives sharding: the reported total is the
        // element-wise sum of the per-core stats on the sharded run too.
        let mut acc = sparsezipper::mem::SharedStats::default();
        for core in &sharded.metrics.per_core {
            acc.add(&core.shared);
        }
        assert_eq!(acc.llc_accesses, t.llc_accesses, "{name}: per-core sum");
        assert_eq!(acc.demotions, t.demotions, "{name}: per-core sum");
        assert!(acc.stall_cycles() == t.stall_cycles(), "{name}: per-core stall sum");
    }
}
