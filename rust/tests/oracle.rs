//! The compulsory-DRAM-traffic oracle's honesty contract:
//!
//! * **closed forms** — a dense block, an identity B, and a cache larger
//!   than the whole footprint all come out exactly as the formulas in
//!   `mem::oracle`'s docs predict;
//! * **registry-wide soundness** — on every registry dataset, for every
//!   scheduler and for both kernel families, the replay's achieved DRAM
//!   line count is at least the oracle bound (`oracle_ratio >= 1.0`): the
//!   memory model never reports less traffic than any execution must move;
//! * **monotonicity** — the bound never increases with the cache budget,
//!   so a bigger simulated cache can only certify, never condemn.

use anyhow::Result;
use sparsezipper::config::SharedMemConfig;
use sparsezipper::matrix::{registry, Csr};
use sparsezipper::mem::oracle::{budget_lines, OracleBound};
use sparsezipper::mem::SharedStats;
use sparsezipper::spgemm::parallel::{self, ParallelConfig, Scheduler};
use sparsezipper::spgemm::{ImplId, SpGemm};
use sparsezipper::SystemConfig;

const SCALE: f64 = 0.003;

fn native(id: ImplId) -> impl Fn() -> Result<Box<dyn SpGemm>> + Sync {
    move || id.instantiate(sparsezipper::Engine::Native, std::path::Path::new("."))
}

fn totals(run: &sparsezipper::MulticoreMetrics) -> SharedStats {
    let mut tot = SharedStats::default();
    for core in &run.per_core {
        tot.add(&core.shared);
    }
    tot
}

fn dense(n: usize) -> Csr {
    let rows: Vec<(Vec<u32>, Vec<f32>)> = (0..n)
        .map(|_| ((0..n as u32).collect(), vec![1.0; n]))
        .collect();
    Csr::from_rows(n, n, rows)
}

#[test]
fn dense_block_matches_the_closed_form() {
    // A 64x64 dense block: every region's footprint is a whole multiple of
    // lines, so the oracle is pure arithmetic. lines(b) = ceil(b/64).
    let n = 64u64;
    let a = dense(n as usize);
    let o = OracleBound::new(&a, &a, n * n);
    let elem = (n * n * 4).div_ceil(64); // one element region of B (or A, or C)
    let ptr = ((n + 1) * 8).div_ceil(64);
    assert_eq!(o.cold_a_lines, ptr + 2 * elem);
    assert_eq!(o.cold_b_lines, 2 * elem + ptr);
    assert_eq!(o.cold_c_lines, (n * 8).div_ceil(64) + 2 * elem);
    // Each of the n output rows re-touches all of B: at budget 0 the raw
    // reuse pressure is n * 2*elem lines.
    assert_eq!(o.reuse_b_lines(0), n * 2 * elem);
    // A budget covering one row's whole working set kills the reuse term.
    assert_eq!(o.dram_lines(2 * elem, 1), o.cold_lines());
}

#[test]
fn identity_b_and_oversized_cache_are_cold_only() {
    let d = registry::find("p2p").expect("registry dataset");
    let a = d.build(SCALE);
    let b = Csr::identity(a.ncols);
    let o = OracleBound::new(&a, &b, a.nnz() as u64);
    // B = I: row i's working set is one 4-byte element per column of A's
    // row i, so a budget covering the heaviest row's footprint (index +
    // data regions) leaves compulsory traffic only.
    let max_deg = (0..a.nrows)
        .map(|i| (a.indptr[i + 1] - a.indptr[i]) as u64)
        .max()
        .unwrap_or(0);
    let budget = 2 * (max_deg * 4).div_ceil(64);
    assert_eq!(o.dram_lines(budget, 1), o.cold_lines());
    // A cache bigger than the whole footprint leaves compulsory traffic
    // only, on a real pattern too.
    let o2 = OracleBound::new(&a, &a, 4 * a.nnz() as u64);
    assert_eq!(o2.dram_lines(u64::MAX, 4), o2.cold_lines());
}

#[test]
fn bound_is_monotone_non_increasing_in_the_budget() {
    for d in registry::DATASETS.iter().take(5) {
        let a = d.build(SCALE);
        let o = OracleBound::new(&a, &a, 4 * a.nnz() as u64);
        let mut prev = u64::MAX;
        for budget in [0u64, 32, 128, 512, 2048, 8192, 1 << 22] {
            let v = o.dram_lines(budget, 4);
            assert!(
                v <= prev,
                "{}: bound rose from {prev} to {v} at budget {budget}",
                d.name
            );
            assert!(v >= o.cold_lines(), "{}: bound under cold floor", d.name);
            prev = v;
        }
        assert_eq!(o.dram_lines(u64::MAX, 4), o.cold_lines(), "{}", d.name);
    }
}

#[test]
fn achieved_traffic_never_undercuts_the_oracle_on_any_registry_dataset() {
    // The headline honesty gate, mirrored in CI on the rendered fig12 TSV:
    // on every registry dataset the replay's total LLC-miss count (the
    // achieved DRAM line traffic) is at least the compulsory bound.
    let sys = SystemConfig::default();
    for d in registry::DATASETS {
        let a = d.build(SCALE);
        let cfg = ParallelConfig {
            scheduler: Scheduler::WorkStealingDyn,
            ..ParallelConfig::new(4)
        };
        let run = parallel::row_blocked(&sys, native(ImplId::Spz), &a, &a, &cfg).unwrap();
        let tot = totals(&run.metrics);
        assert!(tot.oracle_dram_lines > 0, "{}: oracle not stamped", d.name);
        assert_eq!(
            tot.achieved_dram_lines, tot.llc_misses,
            "{}: achieved must be the LLC demand-miss count",
            d.name
        );
        assert!(
            tot.achieved_dram_lines >= tot.oracle_dram_lines,
            "{}: achieved {} lines under oracle bound {}",
            d.name,
            tot.achieved_dram_lines,
            tot.oracle_dram_lines
        );
        assert!(tot.oracle_ratio() >= 1.0, "{}: ratio {}", d.name, tot.oracle_ratio());
        // The stamped oracle is exactly what the standalone construction
        // computes for this (matrix, budget, cores) triple.
        let c_nnz = run.csr.nnz() as u64;
        let expect = OracleBound::new(&a, &a, c_nnz).dram_lines(budget_lines(&sys, 4), 4);
        assert_eq!(tot.oracle_dram_lines, expect, "{}: stamp drifted", d.name);
    }
}

#[test]
fn every_scheduler_and_kernel_family_respects_the_bound() {
    // Schedulers move work, not arithmetic: whatever plan runs, the model
    // cannot report less DRAM traffic than compulsory. Two sockets so the
    // NUMA-aware paths (first-touch homes, remote fills) are exercised too.
    let base = SystemConfig::default();
    let sys = SystemConfig {
        shared: SharedMemConfig { sockets: 2, ..base.shared },
        ..base
    };
    for d in registry::DATASETS.iter().take(3) {
        let a = d.build(SCALE);
        for id in [ImplId::SclHash, ImplId::Spz] {
            for sched in Scheduler::ALL {
                let cfg = ParallelConfig {
                    scheduler: sched,
                    ..ParallelConfig::new(4)
                };
                let run = parallel::row_blocked(&sys, native(id), &a, &a, &cfg).unwrap();
                let tot = totals(&run.metrics);
                assert!(
                    tot.achieved_dram_lines >= tot.oracle_dram_lines,
                    "{} {} {}: achieved {} < oracle {}",
                    d.name,
                    id.name(),
                    sched.name(),
                    tot.achieved_dram_lines,
                    tot.oracle_dram_lines
                );
                assert!(
                    tot.oracle_ratio() >= 1.0,
                    "{} {} {}: ratio {}",
                    d.name,
                    id.name(),
                    sched.name(),
                    tot.oracle_ratio()
                );
            }
        }
    }
}
