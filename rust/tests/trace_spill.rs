//! Streaming trace pipeline: `trace_ring_chunks` is a *footprint* knob,
//! never a *results* knob. Workers publish sealed 64KB trace chunks into a
//! bounded per-core ring consumed concurrently by the replay engine; when
//! the ring fills, the oldest chunks spill to a temp file and are demand
//! loaded back in merge order. These tests pin the contract end to end:
//!
//! * byte identity — the full stable job JSON is byte-for-byte identical
//!   between an unbounded (in-memory) run and a spill-forced
//!   `trace_ring_chunks = 2` run on every Table III registry dataset;
//! * scheduler coverage — every scheduler in [`Scheduler::ALL`] (including
//!   the pilot-replay-driven ones) is ring-invariant;
//! * bounded residency — on a workload whose per-core trace exceeds the
//!   ring, `trace_peak_resident_chunks` respects the budget on every core
//!   while `spilled_chunks` proves the overflow went through the disk path.
//!
//! The unit-level half of the contract (streamed replay vs the
//! materialize-then-replay `TraceBuf` path, event-for-event) lives in
//! `sparsezipper::mem::shared`'s tests.

use sparsezipper::api::{DatasetSource, JobSpec, Session, SessionConfig};
use sparsezipper::config::SharedMemConfig;
use sparsezipper::matrix::{gen, registry};
use sparsezipper::mem::TRACE_CHUNK;
use sparsezipper::spgemm::parallel::Scheduler;
use sparsezipper::spgemm::ImplId;
use sparsezipper::SystemConfig;
use std::sync::Arc;

const SCALE: f64 = 0.003;

/// A fresh session whose workers stream through a `ring`-chunk trace ring
/// (`0` = unbounded; everything else default).
fn session_with_ring(ring: usize) -> Session {
    let sys = SystemConfig::default();
    Session::with_config(SessionConfig {
        sys: SystemConfig {
            shared: SharedMemConfig { trace_ring_chunks: ring, ..sys.shared },
            ..sys
        },
        ..SessionConfig::default()
    })
}

fn stable_json(sess: &Session, spec: &JobSpec) -> String {
    sess.run(spec).expect("job runs").to_json_stable()
}

#[test]
fn spill_forced_json_is_byte_identical_on_every_registry_dataset() {
    for d in registry::DATASETS {
        let spec = JobSpec::new(ImplId::Spz, DatasetSource::registry(d.name).unwrap())
            .with_scale(SCALE)
            .with_cores(4);
        let unbounded = stable_json(&session_with_ring(0), &spec);
        let spilled = stable_json(&session_with_ring(2), &spec);
        assert_eq!(
            spilled, unbounded,
            "{}: 2-chunk spill-forced ring diverged from the unbounded run",
            d.name
        );
    }
}

#[test]
fn every_scheduler_is_ring_invariant() {
    for sched in Scheduler::ALL {
        let spec = JobSpec::new(ImplId::Spz, DatasetSource::registry("p2p").unwrap())
            .with_scale(SCALE)
            .with_cores(4)
            .with_scheduler(sched);
        let unbounded = stable_json(&session_with_ring(0), &spec);
        let spilled = stable_json(&session_with_ring(2), &spec);
        assert_eq!(
            spilled,
            unbounded,
            "{}: spill-forced run diverged from the unbounded run",
            sched.name()
        );
    }
}

#[test]
fn peak_residency_respects_the_ring_and_overflow_spills() {
    const RING: u64 = 2;
    // Big enough that every core records well over RING chunks of trace
    // (the test asserts that premise rather than silently passing on a
    // fixture that never overflows).
    let src = DatasetSource::in_memory(
        "spill-heavy",
        Arc::new(gen::erdos_renyi(4096, 4096, 65536, 42)),
    );
    let spec = JobSpec::new(ImplId::Spz, src).with_cores(4);
    let res = session_with_ring(RING as usize).run(&spec).expect("job runs");
    let mc = res.multicore.as_ref().expect("4-core job has multicore metrics");
    let mut spilled_total = 0;
    for (c, m) in mc.per_core.iter().enumerate() {
        let s = &m.shared;
        let chunks = (s.trace_bytes_total / 16).div_ceil(TRACE_CHUNK as u64);
        assert!(
            chunks > RING,
            "core {c}: fixture too small ({chunks} trace chunks; need > {RING} to force a spill)"
        );
        assert!(
            s.trace_peak_resident_chunks <= RING,
            "core {c}: {} resident chunks exceeded the {RING}-chunk ring",
            s.trace_peak_resident_chunks
        );
        assert!(
            s.spilled_chunks > 0,
            "core {c}: {chunks} chunks through a {RING}-chunk ring must spill"
        );
        spilled_total += s.spilled_chunks;
    }
    assert_eq!(
        mc.total.shared.spilled_chunks, spilled_total,
        "the aggregate spill counter is the per-core sum"
    );
    // The recorded volume itself is ring-independent and survives into the
    // stable JSON; only the ring-shaped counters are zeroed there.
    assert!(mc.total.shared.trace_bytes_total > 0);
    let j = res.to_json_stable();
    assert!(j.contains("\"trace_peak_resident_chunks\":0"), "{j}");
    assert!(j.contains("\"spilled_chunks\":0"), "{j}");
    assert!(!j.contains("\"trace_bytes_total\":0,"), "{j}");
}
