//! Differential sweep: all five implementations x every registry dataset at
//! small scale against the `spgemm::reference` oracle — serial and through
//! the row-blocked multi-core driver at 1, 2, and 7 cores (non-power-of-two
//! on purpose). Pins the two multi-core contracts:
//!
//! * the parallel product is bit-identical in structure (and within
//!   `same_product` tolerance in values) to the serial run, at every core
//!   count and scheduler;
//! * per-core event counts sum *exactly* to the 1-core run's totals (under
//!   the same block policy — uniform or ws-dyn) — and, for the strictly
//!   row/group-local implementations (scl-array, scl-hash, spz), exactly to
//!   the plain serial loop's counts;
//! * at 1 core the shared-memory replay is an exact no-op: every queueing /
//!   coherence / sharing correction is 0.0, so the new shared model
//!   reproduces the seed cycle model cycle-for-cycle.

use sparsezipper::matrix::{registry, Csr};
use sparsezipper::sim::machine::OpCounters;
use sparsezipper::sim::{Machine, RunMetrics};
use sparsezipper::spgemm::parallel::{self, ParallelConfig, Scheduler};
use sparsezipper::spgemm::{self, ImplId, SpGemm};
use sparsezipper::SystemConfig;
use anyhow::Result;

const SCALE: f64 = 0.003;

fn native(id: ImplId) -> impl Fn() -> Result<Box<dyn SpGemm>> + Sync {
    move || id.instantiate(sparsezipper::Engine::Native, std::path::Path::new("."))
}

fn serial(id: ImplId, a: &Csr) -> (Csr, RunMetrics) {
    let mut m = Machine::new(SystemConfig::default());
    let mut im = native(id)().unwrap();
    let c = im.multiply(&mut m, a, a).unwrap();
    (c, m.metrics())
}

#[test]
fn differential_every_impl_every_registry_dataset_serial_and_parallel() {
    let sys = SystemConfig::default();
    for d in registry::DATASETS {
        let a = d.build(SCALE);
        let oracle = spgemm::reference(&a, &a);
        for id in ImplId::ALL {
            let ctx = |extra: &str| format!("{} on {} {extra}", id.name(), d.name);

            // Serial loop vs the independent oracle.
            let (cs, sm) = serial(id, &a);
            assert!(spgemm::same_product(&cs, &oracle, 1e-2), "{}", ctx("serial vs oracle"));

            // Driver at 1 core: same block list as every other core count.
            let one = parallel::row_blocked(&sys, native(id), &a, &a, &ParallelConfig::new(1))
                .unwrap_or_else(|e| panic!("{}: {e:#}", ctx("x1")));
            assert_eq!(one.csr.indptr, cs.indptr, "{}", ctx("x1 structure"));
            assert_eq!(one.csr.indices, cs.indices, "{}", ctx("x1 structure"));
            assert!(spgemm::same_product(&one.csr, &cs, 1e-4), "{}", ctx("x1 values"));

            // The row/group-local impls match the serial loop *exactly*.
            if matches!(id, ImplId::SclArray | ImplId::SclHash | ImplId::Spz) {
                assert_eq!(one.metrics.total.ops, sm.ops, "{}", ctx("x1 counts vs serial"));
            }

            // Acceptance pin: at 1 core the shared-memory model reproduces
            // the seed cycle model exactly — the replay's queueing,
            // coherence, and sharing corrections are all *exactly* zero
            // (phase-1 charging is the uncontended seed model, so zero
            // extras means identical cycles).
            let s1 = &one.metrics.per_core[0].shared;
            assert_eq!(s1.stall_cycles(), 0.0, "{}", ctx("x1 replay stalls"));
            assert_eq!(s1.llc_queue_cycles, 0.0, "{}", ctx("x1 llc queue"));
            assert_eq!(s1.dram_queue_cycles, 0.0, "{}", ctx("x1 dram queue"));
            assert_eq!(s1.coherence_cycles, 0.0, "{}", ctx("x1 coherence"));
            assert_eq!(
                s1.shared_fills + s1.demotions,
                0,
                "{}",
                ctx("x1 shadow/shared divergence")
            );
            assert_eq!(s1.coherence_events(), 0, "{}", ctx("x1 coherence events"));
            assert_eq!(
                s1.llc_accesses + s1.writeback_installs,
                one.metrics.per_core[0].mem.llc_accesses,
                "{}",
                ctx("x1 trace accounting")
            );

            for cores in [2usize, 7] {
                for sched in [Scheduler::Static, Scheduler::WorkStealing] {
                    let cfg = ParallelConfig { scheduler: sched, ..ParallelConfig::new(cores) };
                    let many = parallel::row_blocked(&sys, native(id), &a, &a, &cfg)
                        .unwrap_or_else(|e| panic!("{}: {e:#}", ctx("xN")));
                    // Deterministic product: bitwise equal across core counts
                    // and schedulers.
                    assert_eq!(many.csr, one.csr, "{}", ctx(&format!("x{cores} {sched}")));
                    // Per-core event counts sum exactly to the 1-core totals.
                    let mut sum = OpCounters::default();
                    for core in &many.metrics.per_core {
                        sum.add(&core.ops);
                    }
                    assert_eq!(
                        sum,
                        one.metrics.total.ops,
                        "{}",
                        ctx(&format!("x{cores} {sched} count additivity"))
                    );
                    assert_eq!(many.metrics.cores(), cores);
                }
            }

            // ws-dyn uses its own (work-proportional, core-count-independent)
            // block list: the product stays bit-identical, and the 2-core
            // counts sum exactly to the 1-core ws-dyn run's totals.
            let dyn1 = ParallelConfig {
                scheduler: Scheduler::WorkStealingDyn,
                ..ParallelConfig::new(1)
            };
            let dyn2 = ParallelConfig {
                scheduler: Scheduler::WorkStealingDyn,
                ..ParallelConfig::new(2)
            };
            let done = parallel::row_blocked(&sys, native(id), &a, &a, &dyn1)
                .unwrap_or_else(|e| panic!("{}: {e:#}", ctx("ws-dyn x1")));
            let dtwo = parallel::row_blocked(&sys, native(id), &a, &a, &dyn2)
                .unwrap_or_else(|e| panic!("{}: {e:#}", ctx("ws-dyn x2")));
            assert_eq!(done.csr, one.csr, "{}", ctx("ws-dyn x1 product"));
            assert_eq!(dtwo.csr, one.csr, "{}", ctx("ws-dyn x2 product"));
            let mut sum = OpCounters::default();
            for core in &dtwo.metrics.per_core {
                sum.add(&core.ops);
            }
            assert_eq!(
                sum,
                done.metrics.total.ops,
                "{}",
                ctx("ws-dyn count additivity")
            );
            // Group-aligned dyn blocks keep the row/group-local impls'
            // counts exactly equal to the uniform-block (and serial) runs.
            if matches!(id, ImplId::SclArray | ImplId::SclHash | ImplId::Spz) {
                assert_eq!(done.metrics.total.ops, sm.ops, "{}", ctx("ws-dyn vs serial"));
            }
        }

        // Multi-core spz must never be slower than its 1-core run once there
        // are enough blocks to spread (the fig12/acceptance property; tiny
        // 4-block datasets can degenerate to one hot block, so gate on size).
        if a.nrows >= 128 {
            let one =
                parallel::row_blocked(&sys, native(ImplId::Spz), &a, &a, &ParallelConfig::new(1))
                    .unwrap();
            let eight =
                parallel::row_blocked(&sys, native(ImplId::Spz), &a, &a, &ParallelConfig::new(8))
                    .unwrap();
            assert!(
                eight.metrics.critical_path_cycles <= one.metrics.critical_path_cycles,
                "{}: x8 critical path {} > x1 {}",
                d.name,
                eight.metrics.critical_path_cycles,
                one.metrics.critical_path_cycles
            );
        }
    }
}
