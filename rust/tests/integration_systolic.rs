//! Systolic micro-architecture integration: the PE-level cycle simulation,
//! the functional ISA model, and the timing model agree with each other and
//! with the paper's Figure 5/6 descriptions.

use sparsezipper::config::{MatrixUnitConfig, SystemConfig};
use sparsezipper::systolic::array::{self, run_sort, run_zip};
use sparsezipper::systolic::functional;
use sparsezipper::systolic::SystolicTiming;
use sparsezipper::util::Pcg32;

#[test]
fn fig5_and_fig6_cycle_counts() {
    // One micro-op = two passes of 2N+1 plus the turn-around (Fig. 5/6).
    for n in [3usize, 8, 16] {
        let out = run_sort(n, &[(1, 1.0)], &[(2, 1.0)]);
        assert_eq!(out.cycles as usize, 2 * (2 * n + 1) + 1);
    }
    // Fig. 6 scale: 3x3 array, 3 streams back-to-back.
    let t = SystolicTiming::new(MatrixUnitConfig {
        n: 3,
        num_regs: 16,
        mac_latency: 4,
        issue_overhead: 0,
        pass_stalls: 2,
    });
    assert_eq!(t.k_instr_cycles(3), 18);
}

#[test]
fn array_vs_functional_exhaustive_small() {
    // Exhaustive over all sorted-unique chunk pairs from a small key
    // universe at n=3 — stronger than random sampling.
    let universe = [0u32, 1, 2, 3];
    let mut subsets: Vec<Vec<u32>> = Vec::new();
    for mask in 0u32..16 {
        let mut s = Vec::new();
        for (bit, &k) in universe.iter().enumerate() {
            if mask & (1 << bit) != 0 {
                s.push(k);
            }
        }
        if s.len() <= 3 {
            subsets.push(s);
        }
    }
    for a in &subsets {
        for b in &subsets {
            let ap: Vec<(u32, f32)> = a.iter().map(|&k| (k, 1.0 + k as f32)).collect();
            let bp: Vec<(u32, f32)> = b.iter().map(|&k| (k, 2.0 + k as f32)).collect();
            array::crosscheck_zip(3, &ap, &bp)
                .unwrap_or_else(|e| panic!("a={a:?} b={b:?}: {e}"));
        }
    }
}

#[test]
fn array_handles_full_16x16_chunks() {
    let a: Vec<(u32, f32)> = (0..16).map(|i| (2 * i, 1.0)).collect();
    let b: Vec<(u32, f32)> = (0..16).map(|i| (2 * i + 1, 1.0)).collect();
    let out = run_zip(16, &a, &b);
    // b's 31 > max(a) = 30 is unmergeable; the other 31 elements merge.
    assert_eq!(out.east.len(), 16);
    assert_eq!(out.south.len(), 15);
    assert_eq!(out.excluded_west, 0);
    assert_eq!(out.excluded_north, 1);
}

#[test]
fn sort_stress_random_shapes() {
    let mut rng = Pcg32::new(5150);
    for _ in 0..100 {
        let n = 16;
        let la = rng.gen_usize(n + 1);
        let lb = rng.gen_usize(n + 1);
        let a: Vec<(u32, f32)> = (0..la).map(|_| (rng.gen_range(64), 1.0)).collect();
        let b: Vec<(u32, f32)> = (0..lb).map(|_| (rng.gen_range(64), 1.0)).collect();
        let arr = array::sort_as_functional(n, &a, &b);
        let f = functional::sort_step(
            &a.iter().map(|p| p.0).collect::<Vec<_>>(),
            &a.iter().map(|p| p.1).collect::<Vec<_>>(),
            &b.iter().map(|p| p.0).collect::<Vec<_>>(),
            &b.iter().map(|p| p.1).collect::<Vec<_>>(),
        );
        assert_eq!(arr.a_keys, f.a_keys);
        assert_eq!(arr.b_keys, f.b_keys);
    }
}

#[test]
fn timing_model_scales_with_array_size() {
    let cfg = SystemConfig::default().unit;
    let t16 = SystolicTiming::new(cfg);
    let t32 = SystolicTiming::new(MatrixUnitConfig { n: 32, ..cfg });
    assert!(t32.pair_cycles(16) > t16.pair_cycles(16));
    assert_eq!(t16.pass_latency(), 33);
    assert_eq!(t32.pass_latency(), 65);
}

#[test]
fn counters_match_consumption_invariants() {
    // IC0+IC1 >= 1 whenever both chunks are non-empty (progress guarantee
    // the software merge loop depends on), and OC0+OC1 counts merged
    // uniques exactly.
    let mut rng = Pcg32::new(99);
    for _ in 0..500 {
        let n = 8;
        let mk = |rng: &mut Pcg32| {
            let mut k: Vec<u32> = (0..1 + rng.gen_usize(n)).map(|_| rng.gen_range(40)).collect();
            k.sort_unstable();
            k.dedup();
            k
        };
        let a = mk(&mut rng);
        let b = mk(&mut rng);
        let av = vec![1.0f32; a.len()];
        let bv = vec![1.0f32; b.len()];
        let out = functional::zip_step(n, &a, &av, &b, &bv);
        assert!(
            out.consumed_a + out.consumed_b >= 1,
            "no progress on a={a:?} b={b:?}"
        );
        let mut merged: Vec<u32> = a[..out.consumed_a]
            .iter()
            .chain(&b[..out.consumed_b])
            .copied()
            .collect();
        merged.sort_unstable();
        merged.dedup();
        assert_eq!(
            merged.len(),
            out.east_keys.len() + out.south_keys.len(),
            "unique count mismatch"
        );
    }
}
