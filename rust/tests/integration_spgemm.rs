//! Cross-implementation integration: all five SpGEMM implementations must
//! produce the same product on every dataset family, under one simulated
//! machine each, including non-square and rectangular chains.

use sparsezipper::config::SystemConfig;
use sparsezipper::matrix::{gen, Csr};
use sparsezipper::runtime::Engine;
use sparsezipper::sim::Machine;
use sparsezipper::spgemm::{self, SpGemm};

fn all_impls() -> Vec<Box<dyn SpGemm>> {
    spgemm::ImplId::ALL
        .iter()
        .map(|id| {
            id.instantiate(Engine::Native, std::path::Path::new("artifacts")).unwrap()
        })
        .collect()
}

fn check_all(a: &Csr, ctx: &str) {
    let r = spgemm::reference(a, a);
    for mut im in all_impls() {
        let mut m = Machine::new(SystemConfig::default());
        let c = im.multiply(&mut m, a, a).unwrap();
        assert!(
            spgemm::same_product(&c, &r, 1e-2),
            "{} wrong on {ctx}: {} vs {} nnz",
            im.name(),
            c.nnz(),
            r.nnz()
        );
        assert!(m.metrics().cycles > 0.0, "{} charged no cycles", im.name());
    }
}

#[test]
fn all_impls_agree_on_every_family() {
    check_all(&gen::powerlaw_clustered(300, 2400, 1.1, 0.5, 1), "powerlaw");
    check_all(&gen::kregular(256, 4, 2), "kregular");
    check_all(&gen::grid2d(18, 18, 3), "grid2d");
    check_all(&gen::banded(200, 16, 10, 4), "banded");
    check_all(&gen::block_banded(240, 24, 10, 6, 0.3, 5), "block_banded");
    check_all(&gen::road(18, 18, 0.64, 6), "road");
    check_all(&gen::circuit(300, 5.0, 0.1, 7), "circuit");
}

#[test]
fn all_impls_agree_on_degenerate_inputs() {
    check_all(&Csr::identity(33), "identity");
    check_all(&Csr::empty(40, 40), "empty");
    // Single non-empty row.
    let mut rows = vec![(Vec::new(), Vec::new()); 20];
    rows[7] = ((0..20u32).step_by(2).collect(), vec![1.0; 10]);
    check_all(&Csr::from_rows(20, 20, rows), "single-row");
    // Fully dense tiny matrix (max duplicate pressure).
    let dense = Csr::from_rows(
        9,
        9,
        (0..9)
            .map(|_| ((0..9u32).collect::<Vec<_>>(), vec![0.7f32; 9]))
            .collect(),
    );
    check_all(&dense, "dense9");
}

#[test]
fn rectangular_products() {
    // (30x50) * (50x20) through spz vs reference.
    let a = gen::erdos_renyi(30, 50, 200, 11);
    let b = gen::erdos_renyi(50, 20, 180, 12);
    let mut m = Machine::new(SystemConfig::default());
    let c = spgemm::spz::Spz::native().multiply(&mut m, &a, &b).unwrap();
    let r = spgemm::reference(&a, &b);
    assert!(spgemm::same_product(&c, &r, 1e-3));
    assert_eq!(c.nrows, 30);
    assert_eq!(c.ncols, 20);
}

#[test]
fn power_iteration_chain() {
    // A^4 via repeated simulated SpGEMM stays correct (error accumulation
    // across chained products).
    let a = gen::kregular(128, 3, 13);
    let mut m = Machine::new(SystemConfig::default());
    let mut spz = spgemm::spz::Spz::native();
    let a2 = spz.multiply(&mut m, &a, &a).unwrap();
    let a4 = spz.multiply(&mut m, &a2, &a2).unwrap();
    let r2 = spgemm::reference(&a, &a);
    let r4 = spgemm::reference(&r2, &r2);
    assert!(spgemm::same_product(&a4, &r4, 1e-2));
}

#[test]
fn metrics_are_sane_across_impls() {
    let a = gen::powerlaw_clustered(400, 3000, 1.0, 0.4, 21);
    for mut im in all_impls() {
        let mut m = Machine::new(SystemConfig::default());
        im.multiply(&mut m, &a, &a).unwrap();
        let r = m.metrics();
        // phases sum to total
        let phase_sum: f64 = r.phase_cycles.iter().sum();
        assert!(
            (phase_sum - r.cycles).abs() < 1e-6 * r.cycles.max(1.0),
            "{}: phase sum mismatch",
            im.name()
        );
        // L1 accesses >= L2 accesses >= LLC accesses
        assert!(r.mem.l1d_accesses >= r.mem.l2_accesses);
        assert!(r.mem.l2_accesses >= r.mem.llc_accesses);
        // matrix unit used iff spz variant
        let uses_unit = r.ops.mssortk + r.ops.mszipk > 0;
        assert_eq!(uses_unit, im.name().starts_with("spz"), "{}", im.name());
    }
}

#[test]
fn vec_radix_block_size_does_not_change_result() {
    let a = gen::powerlaw_clustered(300, 2000, 1.0, 0.4, 31);
    let r = spgemm::reference(&a, &a);
    for be in [128usize, 1024, 1 << 20] {
        let mut m = Machine::new(SystemConfig::default());
        let c = spgemm::vec_radix::VecRadix { block_elems: be }
            .multiply(&mut m, &a, &a)
            .unwrap();
        assert!(spgemm::same_product(&c, &r, 1e-2), "block {be}");
    }
}
