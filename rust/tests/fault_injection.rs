//! Failure injection: corrupt the functional datapath deliberately and
//! verify that the harness's product verification catches it — evidence the
//! oracle checks are load-bearing, not vacuous.

use sparsezipper::config::SystemConfig;
use sparsezipper::matrix::gen;
use sparsezipper::runtime::{NativeEngine, StepOut, ZipUnit};
use sparsezipper::sim::Machine;
use sparsezipper::spgemm::{self, SpGemm};
use anyhow::Result;

/// Wraps the native engine and injects one kind of fault.
struct FaultyEngine {
    inner: NativeEngine,
    mode: Fault,
    armed: std::cell::Cell<u32>,
}

#[derive(Clone, Copy, PartialEq)]
enum Fault {
    /// Flip one merged value (bad mszipv accumulate).
    ValueCorruption,
    /// Drop one key from an east chunk (bad compress pass).
    KeyDrop,
    /// Over-report IC0 by one (bad popcount logic).
    CounterSkew,
}

impl ZipUnit for FaultyEngine {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn sort_step(
        &mut self,
        k0: &[Vec<u32>],
        v0: &[Vec<f32>],
        k1: &[Vec<u32>],
        v1: &[Vec<f32>],
    ) -> Result<StepOut> {
        self.inner.sort_step(k0, v0, k1, v1)
    }

    fn zip_step(
        &mut self,
        k0: &[Vec<u32>],
        v0: &[Vec<f32>],
        k1: &[Vec<u32>],
        v1: &[Vec<f32>],
    ) -> Result<StepOut> {
        let mut out = self.inner.zip_step(k0, v0, k1, v1)?;
        // Fire the fault on the 3rd zip step to hit a mid-stream merge.
        let shots = self.armed.get();
        self.armed.set(shots + 1);
        if shots == 3 {
            match self.mode {
                Fault::ValueCorruption => {
                    if let Some(v) = out.v0.iter_mut().flat_map(|r| r.iter_mut()).next() {
                        *v += 1000.0;
                    }
                }
                Fault::KeyDrop => {
                    for (ks, (vs, oc)) in out.k0.iter_mut().zip(out.v0.iter_mut().zip(out.oc0.iter_mut())) {
                        if !ks.is_empty() {
                            ks.pop();
                            vs.pop();
                            *oc -= 1;
                            break;
                        }
                    }
                }
                Fault::CounterSkew => {
                    for (ic, k) in out.ic0.iter_mut().zip(k0) {
                        if *ic < k.len() {
                            *ic += 1;
                            break;
                        }
                    }
                }
            }
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "faulty"
    }
}

fn run_with_fault(mode: Fault) -> bool {
    // A matrix big enough that zip steps definitely fire.
    let a = gen::powerlaw_clustered(400, 4000, 1.1, 0.4, 321);
    let reference = spgemm::reference(&a, &a);
    let engine = FaultyEngine {
        inner: NativeEngine::new(16),
        mode,
        armed: std::cell::Cell::new(0),
    };
    let mut m = Machine::new(SystemConfig::default());
    let mut im = spgemm::spz::Spz::with_engine(Box::new(engine));
    match im.multiply(&mut m, &a, &a) {
        Ok(c) => spgemm::same_product(&c, &reference, 1e-2),
        Err(_) => false, // detected as a hard failure: also fine
    }
}

#[test]
fn value_corruption_is_detected() {
    assert!(!run_with_fault(Fault::ValueCorruption), "corrupted value slipped through");
}

#[test]
fn key_drop_is_detected() {
    assert!(!run_with_fault(Fault::KeyDrop), "dropped key slipped through");
}

#[test]
fn counter_skew_is_detected() {
    assert!(!run_with_fault(Fault::CounterSkew), "skewed IC counter slipped through");
}

#[test]
fn unfaulted_wrapper_passes() {
    // Control: the same wrapper without firing (armed past the run) passes.
    let a = gen::powerlaw_clustered(200, 1600, 1.0, 0.4, 322);
    let reference = spgemm::reference(&a, &a);
    let engine = FaultyEngine {
        inner: NativeEngine::new(16),
        mode: Fault::ValueCorruption,
        armed: std::cell::Cell::new(1_000_000),
    };
    let mut m = Machine::new(SystemConfig::default());
    let mut im = spgemm::spz::Spz::with_engine(Box::new(engine));
    let c = im.multiply(&mut m, &a, &a).unwrap();
    assert!(spgemm::same_product(&c, &reference, 1e-3));
}
