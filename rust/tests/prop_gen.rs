//! Property tests for the synthetic generators (`matrix::gen`): seed
//! determinism, structural invariants (bandwidth, stencil degrees), and
//! realized-nnz fidelity — the contracts the Table III registry stand-ins
//! and every simulation test build on.

use sparsezipper::matrix::{gen, Csr};

/// Every generator family, invoked at a fixed small size from one seed.
fn all_generators(seed: u64) -> Vec<(&'static str, Csr)> {
    vec![
        ("erdos_renyi", gen::erdos_renyi(300, 300, 2000, seed)),
        ("rmat", gen::rmat(256, 256, 4096, 0.57, 0.19, 0.19, seed)),
        ("powerlaw", gen::powerlaw(2000, 16000, 0.8, seed)),
        ("powerlaw_clustered", gen::powerlaw_clustered(1500, 9000, 1.0, 0.5, seed)),
        ("grid2d", gen::grid2d(20, 20, seed)),
        ("grid3d_27pt", gen::grid3d_27pt(5, seed)),
        ("road", gen::road(40, 40, 0.64, seed)),
        ("banded", gen::banded(600, 24, 12, seed)),
        ("block_banded", gen::block_banded(2000, 100, 16, 8, 0.2, seed)),
        ("kregular", gen::kregular(500, 4, seed)),
        ("uniform_degree", gen::uniform_degree(1000, 10, 14, seed)),
        ("circuit", gen::circuit(2000, 6.0, 0.1, seed)),
    ]
}

#[test]
fn every_generator_validates() {
    for (name, m) in all_generators(11) {
        assert!(m.validate().is_ok(), "{name}: {:?}", m.validate());
        assert!(m.nnz() > 0, "{name} empty");
    }
}

#[test]
fn same_seed_is_bit_identical() {
    for ((name, a), (_, b)) in all_generators(42).into_iter().zip(all_generators(42)) {
        assert_eq!(a, b, "{name} not deterministic");
    }
}

#[test]
fn different_seed_changes_the_matrix() {
    for ((name, a), (_, b)) in all_generators(1).into_iter().zip(all_generators(2)) {
        // Random generators move the sparsity pattern; the fixed-structure
        // stencils (grid2d/grid3d) at least draw different values.
        let pattern_differs = a.indptr != b.indptr || a.indices != b.indices;
        let values_differ = a.data != b.data;
        assert!(
            pattern_differs || values_differ,
            "{name}: seeds 1 and 2 give identical matrices"
        );
        match name {
            "grid2d" | "grid3d_27pt" => {
                assert_eq!(a.indices, b.indices, "{name} structure should be seed-free");
                assert!(values_differ, "{name} values should move with the seed");
            }
            _ => assert!(pattern_differs, "{name} pattern should move with the seed"),
        }
    }
}

#[test]
fn banded_respects_bandwidth() {
    for (n, half_band, per_row, seed) in
        [(200usize, 8usize, 6usize, 3u64), (600, 24, 12, 4), (1000, 50, 20, 5)]
    {
        let m = gen::banded(n, half_band, per_row, seed);
        assert!(m.validate().is_ok());
        for r in 0..m.nrows {
            let (k, _) = m.row(r);
            assert!(!k.is_empty(), "row {r} lost its diagonal");
            for &c in k {
                assert!(
                    (c as i64 - r as i64).unsigned_abs() <= half_band as u64,
                    "banded({n},{half_band},{per_row}) row {r} column {c} outside band"
                );
            }
        }
    }
}

#[test]
fn stencil_row_degrees_bounded() {
    let g2 = gen::grid2d(17, 9, 7);
    for r in 0..g2.nrows {
        let d = g2.row_len(r);
        assert!((3..=5).contains(&d), "grid2d row {r} degree {d}");
    }
    let g3 = gen::grid3d_27pt(5, 8);
    for r in 0..g3.nrows {
        let d = g3.row_len(r);
        assert!((8..=27).contains(&d), "grid3d row {r} degree {d}");
    }
    // Total nnz follows from the degree bounds.
    assert!(g2.nnz() <= 5 * g2.nrows && g2.nnz() >= 3 * g2.nrows);
    assert!(g3.nnz() <= 27 * g3.nrows && g3.nnz() >= 8 * g3.nrows);
}

/// Realized nnz stays within tolerance of the request for every generator
/// that takes an nnz/degree target (duplicates collapse, so the realized
/// count is at most the request and loses only a modest fraction).
#[test]
fn realized_nnz_tracks_request() {
    let within = |name: &str, got: usize, want: f64, lo: f64, hi: f64| {
        let ratio = got as f64 / want;
        assert!(
            ratio >= lo && ratio <= hi,
            "{name}: realized {got} vs requested {want} (ratio {ratio:.3} outside [{lo},{hi}])"
        );
    };

    let er = gen::erdos_renyi(300, 300, 2000, 21);
    within("erdos_renyi", er.nnz(), 2000.0, 0.85, 1.0);

    let rm = gen::rmat(256, 256, 4096, 0.57, 0.19, 0.19, 22);
    within("rmat", rm.nnz(), 4096.0, 0.55, 1.0);

    let pl = gen::powerlaw(2000, 16000, 0.8, 23);
    within("powerlaw", pl.nnz(), 16000.0, 0.6, 1.2);

    let plc = gen::powerlaw_clustered(1500, 9000, 1.0, 0.5, 24);
    within("powerlaw_clustered", plc.nnz(), 9000.0, 0.5, 1.25);

    let ud = gen::uniform_degree(1000, 10, 14, 25);
    within("uniform_degree", ud.nnz(), 12000.0, 0.8, 1.2);

    let ci = gen::circuit(2000, 6.0, 0.1, 26);
    within("circuit", ci.nnz(), 2000.0 * 6.0, 0.7, 1.15);

    let bb = gen::block_banded(2000, 100, 16, 8, 0.2, 27);
    within("block_banded", bb.nnz(), 2000.0 * 16.0, 0.5, 1.8);

    let rd = gen::road(40, 40, 0.64, 28);
    // Two undirected edge families at p_edge each: ~4*p_edge entries/vertex.
    within("road", rd.nnz(), 1600.0 * 4.0 * 0.64, 0.6, 1.2);

    // Exact-count generators: no tolerance needed.
    assert_eq!(gen::kregular(500, 4, 29).nnz(), 500 * 4);
    let g = gen::grid2d(20, 20, 30);
    assert_eq!(g.nnz(), 5 * 400 - 2 * 20 - 2 * 20);
}

#[test]
fn kregular_rows_and_columns_are_k_regular() {
    let m = gen::kregular(300, 4, 31);
    for r in 0..m.nrows {
        assert_eq!(m.row_len(r), 4, "row {r}");
    }
    let t = m.transpose();
    let col_degs: Vec<usize> = (0..t.nrows).map(|r| t.row_len(r)).collect();
    // Columns are k-regular up to the rare linear-probe collision.
    let exact = col_degs.iter().filter(|&&d| d == 4).count();
    assert!(exact >= 290, "only {exact}/300 columns have degree 4");
}

#[test]
fn values_stay_in_generator_range() {
    for (name, m) in all_generators(33) {
        match name {
            // Stencils/regular matrices carry structured diagonals/signs.
            "grid2d" | "grid3d_27pt" | "kregular" | "banded" | "block_banded" => continue,
            _ => {}
        }
        assert!(
            m.data.iter().all(|&v| (0.5..1.5).contains(&v)),
            "{name} values escaped [0.5, 1.5)"
        );
    }
}
