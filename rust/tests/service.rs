//! Integration tests for the multi-tenant service subsystem
//! (`sparsezipper::service`): the determinism contract (results through the
//! shared pool are byte-identical to direct `Session::run`), bounded-pool
//! admission control, DRR fairness, and the runtime-free `Future` handle.

use sparsezipper::api::{DatasetSource, JobSpec, Session, SuiteSpec};
use sparsezipper::matrix::{gen, DATASETS};
use sparsezipper::service::{Backpressure, QueueFull, SimService, SimServiceConfig};
use sparsezipper::ImplId;
use std::sync::Arc;

fn tiny(name: &str, seed: u64) -> DatasetSource {
    DatasetSource::in_memory(name, Arc::new(gen::erdos_renyi(40, 40, 160, seed)))
}

/// The headline contract: for **every** registry dataset, a job routed
/// through a saturated multi-tenant queue (28 jobs, depth 4, 3 workers,
/// interleaved tenants) produces a result byte-identical (stable JSON,
/// wall-clock stripped) to a fresh `Session::run` of the same spec.
#[test]
fn every_registry_dataset_is_bit_identical_through_a_saturated_service() {
    const SCALE: f64 = 0.008;
    let svc = SimService::start(
        Session::new(),
        SimServiceConfig {
            workers: 3,
            queue_depth: 4,
            backpressure: Backpressure::Block,
            ..SimServiceConfig::default()
        },
    )
    .unwrap();

    let mut handles = Vec::new();
    for (i, d) in DATASETS.iter().enumerate() {
        for id in [ImplId::SclHash, ImplId::Spz] {
            let spec = JobSpec::new(id, DatasetSource::registry(d.name).unwrap()).with_scale(SCALE);
            handles.push((d.name, svc.submit(&format!("t{}", i % 3), spec).unwrap()));
        }
    }
    let through_service: Vec<(&str, String)> = handles
        .into_iter()
        .map(|(name, h)| (name, h.wait().unwrap().to_json_stable()))
        .collect();

    // Ground truth from a session the service never touched.
    let direct = Session::new();
    let mut idx = 0;
    for d in DATASETS.iter() {
        for id in [ImplId::SclHash, ImplId::Spz] {
            let spec = JobSpec::new(id, DatasetSource::registry(d.name).unwrap()).with_scale(SCALE);
            let expected = direct.run(&spec).unwrap().to_json_stable();
            let (name, got) = &through_service[idx];
            assert_eq!(*got, expected, "{name}/{} diverged through the service", id.name());
            idx += 1;
        }
    }

    let stats = svc.stats();
    assert_eq!(stats.admitted, 28);
    assert_eq!(stats.completed, 28);
    assert_eq!(stats.rejected, 0);
    assert_eq!(stats.failed, 0);
    assert!(stats.queue_depth_high_water <= 4, "depth bound violated: {stats:?}");
    assert!(stats.slots_high_water <= 3, "pool budget violated: {stats:?}");
}

/// ~2k 1-core jobs from 8 concurrent tenants on a 4-slot pool with a bounded
/// blocking queue: everything completes, every result is bit-identical to a
/// direct run, and the pool's own high-water counters prove neither the
/// worker budget nor the queue bound was ever exceeded.
#[test]
fn two_thousand_jobs_from_eight_tenants_stay_on_the_bounded_pool() {
    const TENANTS: usize = 8;
    const JOBS: usize = 250;
    const WORKERS: usize = 4;
    const DEPTH: usize = 32;

    let sources: Vec<DatasetSource> =
        (0..TENANTS).map(|i| tiny(&format!("stress{i}"), 100 + i as u64)).collect();
    // Ground truth per dataset, from an independent session.
    let direct = Session::new();
    let expected: Vec<String> = sources
        .iter()
        .map(|src| {
            direct.run(&JobSpec::new(ImplId::SclHash, src.clone())).unwrap().to_json_stable()
        })
        .collect();

    let svc = SimService::start(
        Session::new(),
        SimServiceConfig {
            workers: WORKERS,
            queue_depth: DEPTH,
            backpressure: Backpressure::Block,
            ..SimServiceConfig::default()
        },
    )
    .unwrap();

    std::thread::scope(|scope| {
        for (i, src) in sources.iter().enumerate() {
            let svc = &svc;
            let expected = expected[i].as_str();
            scope.spawn(move || {
                let tenant = format!("t{i}");
                let handles: Vec<_> = (0..JOBS)
                    .map(|_| svc.submit(&tenant, JobSpec::new(ImplId::SclHash, src.clone())).unwrap())
                    .collect();
                for h in handles {
                    assert_eq!(h.wait().unwrap().to_json_stable(), expected, "tenant {i}");
                }
            });
        }
    });

    let stats = svc.stats();
    assert_eq!(stats.admitted, (TENANTS * JOBS) as u64);
    assert_eq!(stats.completed, (TENANTS * JOBS) as u64);
    assert_eq!(stats.rejected, 0);
    assert_eq!(stats.failed, 0);
    assert!(
        stats.slots_high_water <= WORKERS as u64,
        "core-slot budget exceeded: {} > {WORKERS}",
        stats.slots_high_water
    );
    assert!(
        stats.queue_depth_high_water <= DEPTH as u64,
        "queue bound exceeded: {} > {DEPTH}",
        stats.queue_depth_high_water
    );
    assert_eq!(stats.tenants.len(), TENANTS);
    for t in &stats.tenants {
        assert_eq!(t.served, JOBS as u64, "tenant {} served count", t.tenant);
    }
}

/// `Backpressure::Reject` fires at exactly the configured depth, with the
/// typed `QueueFull` error, and the service still drains the admitted jobs.
#[test]
fn reject_fires_at_exactly_the_configured_depth() {
    const DEPTH: usize = 5;
    let svc = SimService::start(
        Session::new(),
        SimServiceConfig {
            workers: 1,
            queue_depth: DEPTH,
            backpressure: Backpressure::Reject,
            ..SimServiceConfig::default()
        },
    )
    .unwrap();
    // Paused pool: nothing dispatches, so the pending depth is exact.
    svc.pause();

    let src = tiny("reject", 7);
    let handles: Vec<_> = (0..DEPTH)
        .map(|_| svc.submit("t0", JobSpec::new(ImplId::SclHash, src.clone())).unwrap())
        .collect();

    let err = svc.submit("t0", JobSpec::new(ImplId::SclHash, src.clone())).unwrap_err();
    let qf = err.downcast_ref::<QueueFull>().expect("typed QueueFull error");
    assert_eq!(*qf, QueueFull { depth: DEPTH });
    assert!(err.to_string().contains("job queue full (5 pending jobs)"), "{err}");

    let stats = svc.stats();
    assert_eq!(stats.admitted, DEPTH as u64);
    assert_eq!(stats.rejected, 1);

    svc.resume();
    for h in handles {
        h.wait().unwrap();
    }
    assert_eq!(svc.stats().completed, DEPTH as u64);
}

/// DRR fairness, pinned exactly: on a 1-worker pool (completion order ==
/// dispatch order) with quantum == job cost, tenants weighted 1/2/4 are
/// served 1/2/4 jobs per round — every 7-dispatch window of the backlogged
/// phase splits exactly along the weights.
#[test]
fn drr_serves_backlogged_tenants_in_weight_ratio() {
    const JOBS: usize = 20;
    let svc = SimService::start(
        Session::new(),
        SimServiceConfig {
            workers: 1,
            queue_depth: 3 * JOBS,
            backpressure: Backpressure::Block,
            quantum: 1024,
            default_cost: 1024, // every job costs exactly one quantum
            tenant_weights: vec![
                ("a".to_string(), 1),
                ("b".to_string(), 2),
                ("c".to_string(), 4),
            ],
            ..SimServiceConfig::default()
        },
    )
    .unwrap();
    svc.pause();

    let src = tiny("drr", 21);
    let mut handles = Vec::new();
    for tenant in ["a", "b", "c"] {
        for _ in 0..JOBS {
            handles
                .push((tenant, svc.submit(tenant, JobSpec::new(ImplId::SclHash, src.clone())).unwrap()));
        }
    }
    svc.resume();

    // `wait()` consumes a handle, but the seq must be read from it — so
    // join on the pool counter and then read every seq by reference.
    loop {
        let s = svc.stats();
        if s.completed + s.failed == (3 * JOBS) as u64 {
            assert_eq!(s.failed, 0, "no job may fail: {s:?}");
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    let mut seqs: Vec<(u64, &str)> = handles
        .iter()
        .map(|(tenant, h)| (h.completion_seq().expect("finished job has a seq"), *tenant))
        .collect();
    seqs.sort_unstable();
    assert_eq!(seqs.len(), 3 * JOBS);
    assert_eq!(seqs.last().unwrap().0, (3 * JOBS - 1) as u64, "seqs are dense 0..N");

    // All three tenants stay backlogged through 5 full rounds (c, weight 4,
    // drains fastest: 20 jobs / 4 per round). Each round serves a:1 b:2 c:4.
    for round in 1..=5usize {
        let window = &seqs[..7 * round];
        let count = |t: &str| window.iter().filter(|(_, tn)| *tn == t).count();
        assert_eq!(count("a"), round, "tenant a after {round} rounds: {seqs:?}");
        assert_eq!(count("b"), 2 * round, "tenant b after {round} rounds");
        assert_eq!(count("c"), 4 * round, "tenant c after {round} rounds");
    }

    let stats = svc.stats();
    let by_name: Vec<(String, u32, u64)> =
        stats.tenants.iter().map(|t| (t.tenant.clone(), t.weight, t.served)).collect();
    assert_eq!(
        by_name,
        vec![
            ("a".to_string(), 1, JOBS as u64),
            ("b".to_string(), 2, JOBS as u64),
            ("c".to_string(), 4, JOBS as u64),
        ]
    );
}

/// Minimal hand-rolled executor machinery for the `Future` tests.
mod exec {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::task::{Wake, Waker};
    use std::thread::Thread;

    pub struct ThreadWaker {
        thread: Thread,
        pub wakes: AtomicUsize,
    }

    impl ThreadWaker {
        pub fn pair() -> (Arc<ThreadWaker>, Waker) {
            let tw = Arc::new(ThreadWaker {
                thread: std::thread::current(),
                wakes: AtomicUsize::new(0),
            });
            (tw.clone(), Waker::from(tw))
        }
    }

    impl Wake for ThreadWaker {
        fn wake(self: Arc<Self>) {
            self.wakes.fetch_add(1, Ordering::SeqCst);
            self.thread.unpark();
        }
    }
}

/// `JobHandle` is a real `Future`: pollable with a bare `Waker`, no async
/// runtime anywhere. Pending while queued, woken on completion, Ready with
/// the result — and a post-poll `wait()` reports the result as consumed.
#[test]
fn handles_can_be_awaited_without_a_runtime() {
    use std::future::Future;
    use std::pin::Pin;
    use std::sync::atomic::Ordering;
    use std::task::{Context, Poll};

    let svc = SimService::start(
        Session::new(),
        SimServiceConfig { workers: 1, ..SimServiceConfig::default() },
    )
    .unwrap();
    svc.pause();
    let mut h = svc.submit("t0", JobSpec::new(ImplId::SclHash, tiny("await", 31))).unwrap();

    let (tw, waker) = exec::ThreadWaker::pair();
    let mut cx = Context::from_waker(&waker);
    assert!(Pin::new(&mut h).poll(&mut cx).is_pending(), "job cannot finish on a paused pool");
    assert_eq!(tw.wakes.load(Ordering::SeqCst), 0);

    svc.resume();
    // Park until the service's completion path calls our waker.
    while tw.wakes.load(Ordering::SeqCst) == 0 {
        std::thread::park_timeout(std::time::Duration::from_millis(50));
    }
    match Pin::new(&mut h).poll(&mut cx) {
        Poll::Ready(r) => assert!(r.is_ok(), "{r:?}"),
        Poll::Pending => panic!("woken future must be ready"),
    }
    // The poll consumed the one-shot result; the blocking join says so.
    let err = h.wait().unwrap_err();
    assert!(err.to_string().contains("already taken"), "{err}");
}

/// `submit_suite` streams every cell as it lands, and `collect_ordered`
/// reassembles the exact `Session::run_suite` output (same results, same
/// spec order) — one scheduler, two consumption styles.
#[test]
fn suite_streams_and_collects_in_spec_order() {
    let spec = SuiteSpec {
        datasets: vec![tiny("s0", 41), tiny("s1", 42)],
        impls: vec![ImplId::SclHash, ImplId::Spz],
        scale: 1.0,
        threads: 2,
        verify: true,
        ..SuiteSpec::default()
    };

    let svc = SimService::start(
        Session::new(),
        SimServiceConfig { workers: 2, ..SimServiceConfig::default() },
    )
    .unwrap();

    // Streaming: exactly total() items, indices covering the grid, all Ok.
    let sweep = svc.submit_suite("tenant-a", &spec).unwrap();
    assert_eq!(sweep.total(), 4);
    let mut seen: Vec<usize> = sweep
        .results()
        .map(|(idx, r)| {
            r.unwrap();
            idx
        })
        .collect();
    seen.sort_unstable();
    assert_eq!(seen, vec![0, 1, 2, 3]);

    // Ordered collection == the classic API, byte for byte.
    let via_service = svc.submit_suite("tenant-a", &spec).unwrap().collect_ordered().unwrap();
    let classic = Session::new().run_suite(&spec).unwrap();
    assert_eq!(via_service.results.len(), classic.results.len());
    for (a, b) in via_service.results.iter().zip(&classic.results) {
        assert_eq!(a.to_json_stable(), b.to_json_stable());
        assert_eq!(a.dataset, b.dataset);
        assert_eq!(a.impl_id, b.impl_id);
    }
    assert_eq!(via_service.dataset_stats.len(), 2);
}

/// `SuiteSpec.threads == 0` is a hard error now, not a silent clamp.
#[test]
fn zero_threads_suite_is_an_error_not_a_clamp() {
    let spec = SuiteSpec {
        datasets: vec![tiny("z", 5)],
        impls: vec![ImplId::SclHash],
        scale: 1.0,
        threads: 0,
        verify: false,
        ..SuiteSpec::default()
    };
    let err = Session::new().run_suite(&spec).unwrap_err();
    assert!(err.to_string().contains("SuiteSpec.threads must be at least 1"), "{err}");
}

/// Dropping the service fails still-queued handles deterministically instead
/// of hanging their waiters; in-flight work is never aborted mid-simulation.
#[test]
fn dropping_the_service_fails_still_queued_jobs() {
    let svc = SimService::start(
        Session::new(),
        SimServiceConfig { workers: 1, ..SimServiceConfig::default() },
    )
    .unwrap();
    svc.pause();
    let src = tiny("drop", 55);
    let handles: Vec<_> = (0..3)
        .map(|_| svc.submit("t0", JobSpec::new(ImplId::SclHash, src.clone())).unwrap())
        .collect();
    drop(svc);
    for h in handles {
        let err = h.wait().unwrap_err();
        assert!(err.to_string().contains("service shut down before the job ran"), "{err}");
    }
}

/// Submitting a 0-core job is a submit-time error (admission validates the
/// spec like `Session::run` does), and the string `Backpressure` parser used
/// by the CLI round-trips both modes.
#[test]
fn admission_validates_specs_and_backpressure_parses() {
    let svc = SimService::start(Session::new(), SimServiceConfig::default()).unwrap();
    let mut bad = JobSpec::new(ImplId::SclHash, tiny("bad", 3));
    bad.cores = 0;
    let err = svc.submit("t0", bad).unwrap_err();
    assert!(err.to_string().contains("cores must be at least 1"), "{err}");

    assert_eq!("reject".parse::<Backpressure>().unwrap(), Backpressure::Reject);
    assert_eq!("block".parse::<Backpressure>().unwrap(), Backpressure::Block);
    assert!("drop".parse::<Backpressure>().is_err());
}

/// The service's per-job trace-ring budget is a pure footprint knob: a pool
/// forcing every job onto a 2-chunk ring (spilling overflow to disk) returns
/// results byte-identical (stable JSON) to an unconstrained direct run. A
/// 1-chunk ring is rejected at service start, mirroring
/// `SharedMemConfig::validate`.
#[test]
fn service_trace_ring_budget_is_bit_identical_and_validated() {
    let err = SimService::start(
        Session::new(),
        SimServiceConfig { trace_ring_chunks: 1, ..SimServiceConfig::default() },
    )
    .unwrap_err();
    assert!(err.to_string().contains("trace_ring_chunks"), "{err}");

    let svc = SimService::start(
        Session::new(),
        SimServiceConfig {
            workers: 2,
            trace_ring_chunks: 2,
            ..SimServiceConfig::default()
        },
    )
    .unwrap();
    let spec = JobSpec::new(ImplId::Spz, tiny("ring", 7)).with_cores(4);
    let got = svc.submit("t0", spec.clone()).unwrap().wait().unwrap().to_json_stable();
    let expected = Session::new().run(&spec).unwrap().to_json_stable();
    assert_eq!(got, expected, "ring-budgeted service run diverged from direct run");
}
