//! Session API integration: dataset-cache memoization across jobs, typed
//! `ImplId`/`DatasetSource` round-trips, actionable error paths, `.mtx`
//! sources end-to-end, and the stable JSON export.

use sparsezipper::api::{DatasetSource, JobSpec, Session, SessionConfig, SuiteSpec};
use sparsezipper::matrix::{gen, mm};
use sparsezipper::ImplId;
use std::sync::Arc;

/// Two jobs on the same `(source, scale)` through one session must build
/// the dataset (and the reference oracle) exactly once.
#[test]
fn second_job_on_same_dataset_does_not_rebuild() {
    let session = Session::new();
    let src = DatasetSource::registry("p2p").unwrap();
    let first = session
        .run(&JobSpec::new(ImplId::SclHash, src.clone()).with_scale(0.01).with_verify(true))
        .unwrap();
    assert_eq!(session.dataset_builds(), 1);
    assert_eq!(session.reference_builds(), 1);

    let second = session
        .run(&JobSpec::new(ImplId::Spz, src.clone()).with_scale(0.01).with_verify(true))
        .unwrap();
    assert_eq!(session.dataset_builds(), 1, "dataset was rebuilt");
    assert_eq!(session.reference_builds(), 1, "oracle was rebuilt");
    assert!(first.verified && second.verified);
    assert_eq!(first.out_nnz, second.out_nnz);

    // A different scale is a different cache entry.
    session
        .run(&JobSpec::new(ImplId::SclHash, src).with_scale(0.02))
        .unwrap();
    assert_eq!(session.dataset_builds(), 2);
}

/// A suite after a job reuses the session cache for overlapping datasets.
#[test]
fn suite_reuses_job_cache() {
    let session = Session::new();
    let p2p = DatasetSource::registry("p2p").unwrap();
    session
        .run(&JobSpec::new(ImplId::SclHash, p2p.clone()).with_scale(0.01))
        .unwrap();
    assert_eq!(session.dataset_builds(), 1);

    let spec = SuiteSpec {
        datasets: vec![p2p, DatasetSource::registry("m133-b3").unwrap()],
        impls: vec![ImplId::SclHash, ImplId::Spz],
        scale: 0.01,
        threads: 2,
        verify: false,
        ..SuiteSpec::default()
    };
    let r = session.run_suite(&spec).unwrap();
    assert_eq!(r.results.len(), 4);
    // Only m133-b3 was new; p2p came from the cache.
    assert_eq!(session.dataset_builds(), 2);
}

#[test]
fn impl_and_dataset_round_trip_parsing() {
    for id in ImplId::ALL {
        assert_eq!(id.name().parse::<ImplId>().unwrap(), id);
        assert_eq!(format!("{id}"), id.name());
    }
    for name in ["p2p", "wiki", "m133-b3"] {
        let src: DatasetSource = name.parse().unwrap();
        assert_eq!(src.name(), name);
    }
}

#[test]
fn unknown_names_produce_actionable_messages() {
    let impl_err = "warp-drive".parse::<ImplId>().unwrap_err();
    assert!(impl_err.contains("unknown implementation 'warp-drive'"), "{impl_err}");
    assert!(impl_err.contains("scl-array") && impl_err.contains("spz-rsort"), "{impl_err}");

    let ds_err = format!("{:#}", "atlantis".parse::<DatasetSource>().unwrap_err());
    assert!(ds_err.contains("unknown dataset 'atlantis'"), "{ds_err}");
    assert!(ds_err.contains("p2p") && ds_err.contains(".mtx"), "{ds_err}");

    // A missing .mtx file fails at build time with the path in the message.
    let session = Session::new();
    let missing = DatasetSource::mtx("/definitely/not/here.mtx");
    let e = format!("{:#}", session.run(&JobSpec::new(ImplId::Spz, missing)).unwrap_err());
    assert!(e.contains("here"), "{e}");
    // A failed build must not leave a dead placeholder in the cache.
    assert_eq!(session.cached_datasets(), 0);
}

#[test]
fn mtx_source_runs_end_to_end() {
    let dir = std::env::temp_dir().join(format!("spz_api_mtx_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tiny.mtx");
    let a = gen::erdos_renyi(50, 50, 250, 7);
    mm::write_mtx(&path, &a).unwrap();

    let session = Session::new();
    // Resolved two ways: via --mtx-dir style lookup, and as an explicit path.
    let by_dir = DatasetSource::parse("tiny", Some(&dir)).unwrap();
    let by_path = DatasetSource::parse(path.to_str().unwrap(), None).unwrap();
    assert_eq!(by_dir.name(), "tiny");
    assert_eq!(by_path.name(), "tiny");
    // A spec already carrying .mtx still resolves inside --mtx-dir.
    let by_dir_ext = DatasetSource::parse("tiny.mtx", Some(&dir)).unwrap();
    assert_eq!(by_dir_ext.cache_key(1.0), by_dir.cache_key(1.0));

    let res = session
        .run(&JobSpec::new(ImplId::Spz, by_dir).with_verify(true))
        .unwrap();
    assert!(res.verified);
    assert_eq!(res.dataset, "tiny");
    // Same underlying file, same cache entry.
    session
        .run(&JobSpec::new(ImplId::SclHash, by_path.clone()).with_verify(true))
        .unwrap();
    assert_eq!(session.dataset_builds(), 1);
    assert_eq!(session.reference_builds(), 1);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn rectangular_inputs_error_instead_of_panicking() {
    let session = Session::new();
    let rect = DatasetSource::in_memory("rect", Arc::new(gen::erdos_renyi(30, 50, 100, 1)));
    let e = format!(
        "{:#}",
        session
            .run(&JobSpec::new(ImplId::SclHash, rect.clone()).with_verify(true))
            .unwrap_err()
    );
    assert!(e.contains("A*A"), "{e}");
    let e = format!("{:#}", session.run(&JobSpec::new(ImplId::SclHash, rect)).unwrap_err());
    assert!(e.contains("A*A"), "{e}");
    // spgemm validates inner dimensions for general products.
    let a = gen::erdos_renyi(30, 50, 100, 2);
    let e = format!("{:#}", session.spgemm(ImplId::Spz, &a, &a).unwrap_err());
    assert!(e.contains("dimension mismatch"), "{e}");
}

#[test]
fn in_memory_source_shares_one_build() {
    let session = Session::new();
    let src = DatasetSource::in_memory("mine", Arc::new(gen::erdos_renyi(40, 40, 160, 3)));
    for id in [ImplId::SclArray, ImplId::SclHash, ImplId::VecRadix] {
        let r = session.run(&JobSpec::new(id, src.clone()).with_verify(true)).unwrap();
        assert!(r.verified, "{}", id.name());
        assert_eq!(r.dataset, "mine");
    }
    assert_eq!(session.dataset_builds(), 1);
    assert_eq!(session.reference_builds(), 1);
}

#[test]
fn evict_and_clear_release_cache_entries() {
    let session = Session::new();
    let src = DatasetSource::registry("p2p").unwrap();
    session.run(&JobSpec::new(ImplId::SclHash, src.clone()).with_scale(0.01)).unwrap();
    assert_eq!(session.cached_datasets(), 1);
    assert!(session.evict(&src, 0.01));
    assert!(!session.evict(&src, 0.01));
    assert_eq!(session.cached_datasets(), 0);
    // Next job rebuilds (counters keep counting across eviction).
    session.run(&JobSpec::new(ImplId::SclHash, src.clone()).with_scale(0.01)).unwrap();
    assert_eq!(session.dataset_builds(), 2);
    session.clear_cache();
    assert_eq!(session.cached_datasets(), 0);
}

#[test]
fn duplicate_dataset_names_rejected() {
    let session = Session::new();
    let spec = SuiteSpec {
        datasets: vec![
            DatasetSource::in_memory("same", Arc::new(gen::erdos_renyi(30, 30, 90, 1))),
            DatasetSource::in_memory("same", Arc::new(gen::erdos_renyi(30, 30, 90, 2))),
        ],
        impls: vec![ImplId::SclHash],
        scale: 1.0,
        threads: 1,
        verify: false,
        ..SuiteSpec::default()
    };
    let e = format!("{:#}", session.run_suite(&spec).unwrap_err());
    assert!(e.contains("duplicate dataset name 'same'"), "{e}");
}

#[test]
fn non_registry_datasets_appear_in_figures() {
    use sparsezipper::coordinator::figures;
    let session = Session::new();
    let spec = SuiteSpec {
        datasets: vec![DatasetSource::in_memory(
            "mygraph",
            Arc::new(gen::erdos_renyi(60, 60, 300, 9)),
        )],
        impls: vec![ImplId::SclHash, ImplId::VecRadix, ImplId::Spz],
        scale: 1.0,
        threads: 1,
        verify: false,
        ..SuiteSpec::default()
    };
    let suite = session.run_suite(&spec).unwrap();
    assert!(figures::fig8(&suite).contains("mygraph"));
    assert!(figures::fig10(&suite).contains("mygraph"));
    for (_, tsv) in figures::tsv_exports(&suite) {
        assert!(tsv.contains("mygraph"), "{tsv}");
    }
    // table3 compares against paper rows, which only registry datasets have.
    assert!(!figures::table3(&suite).contains("mygraph"));
}

#[test]
fn bounded_session_cache_and_ws_dyn_jobs_work_end_to_end() {
    use sparsezipper::api::Scheduler;
    let session = Session::with_config(SessionConfig {
        max_cached_datasets: Some(1),
        ..SessionConfig::default()
    });
    // A ws-dyn multi-core job through the public API, with the bounded
    // cache evicting as new datasets stream through.
    let a = session
        .run(
            &JobSpec::new(ImplId::Spz, DatasetSource::registry("p2p").unwrap())
                .with_scale(0.01)
                .with_verify(true)
                .with_cores(4)
                .with_scheduler(Scheduler::WorkStealingDyn),
        )
        .unwrap();
    assert!(a.verified);
    assert_eq!(a.sched, Some(Scheduler::WorkStealingDyn));
    let mc = a.multicore.as_ref().expect("multicore metrics");
    assert_eq!(mc.cores(), 4);
    assert!(!mc.channel_busy_cycles.is_empty(), "replay reports channel occupancy");
    let wiki = DatasetSource::registry("wiki").unwrap();
    session
        .run(&JobSpec::new(ImplId::SclHash, wiki).with_scale(0.01))
        .unwrap();
    assert_eq!(session.cached_datasets(), 1, "cap 1 keeps only the latest dataset");
    assert!(session.cache_evictions() >= 1);
}

#[test]
fn json_export_is_stable_and_parseable_ish() {
    let session = Session::with_config(SessionConfig::default());
    let src = DatasetSource::in_memory("jay", Arc::new(gen::erdos_renyi(40, 40, 160, 5)));
    let res = session.run(&JobSpec::new(ImplId::SclHash, src.clone()).with_verify(true)).unwrap();
    let j = res.to_json();
    for key in [
        "\"impl\":\"scl-hash\"",
        "\"dataset\":\"jay\"",
        "\"verified\":true",
        "\"cycles\":",
        "\"l1d_accesses\":",
        "\"mssortk\":",
        "\"block_elems\":null",
        "\"cores\":1",
        "\"sched\":null",
        "\"multicore\":null",
    ] {
        assert!(j.contains(key), "missing {key} in {j}");
    }

    // A multi-core job exports the per-core section.
    let par = session
        .run(&JobSpec::new(ImplId::SclHash, src.clone()).with_cores(2))
        .unwrap();
    let pj = par.to_json();
    for key in [
        "\"cores\":2",
        "\"sched\":\"work-stealing\"",
        "\"multicore\":{\"critical_path_cycles\":",
        "\"critical_path\":{\"preprocess\":",
        "\"per_core\":[",
        "\"shared\":{\"llc_accesses\":",
        "\"writeback_installs\":",
        "\"stall_cycles\":",
        "\"channel_busy_cycles\":[",
    ] {
        assert!(pj.contains(key), "missing {key} in {pj}");
    }
    // The serial job carries the same shape with an all-zero shared block.
    assert!(j.contains("\"shared\":{\"llc_accesses\":"), "{j}");
    assert!(j.contains("\"coherence_cycles\":0"), "{j}");

    let spec = SuiteSpec {
        datasets: vec![src],
        impls: vec![ImplId::SclHash, ImplId::Spz],
        scale: 1.0,
        threads: 1,
        verify: false,
        ..SuiteSpec::default()
    };
    let suite = session.run_suite(&spec).unwrap();
    let sj = suite.to_json();
    assert!(sj.contains("\"datasets\""), "{sj}");
    assert!(sj.contains("\"results\""), "{sj}");
    assert!(sj.contains("\"impl\":\"spz\""), "{sj}");
    assert!(sj.contains("\"work_var\":"), "{sj}");
    // Balanced braces/brackets (cheap well-formedness check, no serde here).
    assert_eq!(sj.matches('{').count(), sj.matches('}').count(), "{sj}");
    assert_eq!(sj.matches('[').count(), sj.matches(']').count(), "{sj}");
}
