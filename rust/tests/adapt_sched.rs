//! Contract tests for `ws-adapt`, the per-block adaptive dataflow scheduler:
//!
//! * **wins-or-ties** — on every registry dataset at the same (cores,
//!   sockets), ws-adapt's critical path does not lose to the best of the
//!   four fixed schedulers (static / ws-dyn / ws-bw / ws-numa) beyond a
//!   small tie band that covers probe/pilot prediction noise, and on at
//!   least half the registry the result is an exact win-or-tie (the
//!   fallback path executes a fixed plan bit-identically, so ties are
//!   byte-ties whenever the pilot ranks the fixed plans correctly);
//! * **strict win on skew** — on a hub-skewed matrix with the job pinned to
//!   scl-hash, ws-adapt swaps the heavy blocks onto spz and strictly beats
//!   every fixed scheduler on critical-path cycles;
//! * **count additivity per chosen impl** — summing, over
//!   [`ParallelRun::block_plan`], a *serial* run of each block's slab on the
//!   kernel ws-adapt chose reproduces the parallel per-core event counts
//!   exactly, even when blocks were swapped and split;
//! * **degenerate fallback** — at 1 core ws-adapt is bit-identical to
//!   ws-dyn (no probes, no decisions);
//! * **determinism** — two runs of the same spec at 2 sockets with 4 replay
//!   shards compare byte-equal through `to_json_stable()`.

use anyhow::Result;
use sparsezipper::api::{DatasetSource, JobSpec, Session, SessionConfig};
use sparsezipper::config::SharedMemConfig;
use sparsezipper::matrix::registry;
use sparsezipper::sim::machine::OpCounters;
use sparsezipper::spgemm::parallel::{self, ParallelConfig, Scheduler};
use sparsezipper::spgemm::{ImplId, SpGemm};
use sparsezipper::{Csr, Machine, SystemConfig};

const SCALE: f64 = 0.003;

/// Tie band for the registry sweep. The fallback path replays a fixed plan
/// bit-identically, so a "tie" is exact whenever the pilot ranks the fixed
/// plans the way the replay does; the band only absorbs the cases where two
/// near-equal fixed plans swap order between prediction and reality.
const TIE: f64 = 1.05;

fn native(id: ImplId) -> impl Fn() -> Result<Box<dyn SpGemm>> + Sync {
    move || id.instantiate(sparsezipper::Engine::Native, std::path::Path::new("."))
}

fn two_socket_sys() -> SystemConfig {
    let base = SystemConfig::default();
    SystemConfig {
        shared: SharedMemConfig { sockets: 2, ..base.shared },
        ..base
    }
}

fn fixed_cfg(s: Scheduler) -> ParallelConfig {
    ParallelConfig { scheduler: s, ..ParallelConfig::new(4) }
}

fn adapt_cfg(id: ImplId) -> ParallelConfig {
    ParallelConfig {
        scheduler: Scheduler::WorkStealingAdapt,
        impl_id: Some(id),
        ..ParallelConfig::new(4)
    }
}

/// Rows `[lo, hi)` as a standalone CSR (mirror of the driver's slab cut).
fn slab(a: &Csr, lo: usize, hi: usize) -> Csr {
    let base = a.indptr[lo];
    Csr {
        nrows: hi - lo,
        ncols: a.ncols,
        indptr: a.indptr[lo..=hi].iter().map(|&p| p - base).collect(),
        indices: a.indices[a.indptr[lo]..a.indptr[hi]].to_vec(),
        data: a.data[a.indptr[lo]..a.indptr[hi]].to_vec(),
    }
}

/// A deterministic hub-skewed matrix: the first `heavy` rows carry
/// `heavy_nnz` entries each, the rest two — so a few row blocks concentrate
/// almost all the Gustavson work (the shape `ws-adapt`'s kernel swap and
/// block split are for).
fn skewed(nrows: usize, heavy: usize, heavy_nnz: usize) -> Csr {
    let mut indptr = vec![0usize];
    let mut indices: Vec<u32> = Vec::new();
    let mut data: Vec<f32> = Vec::new();
    let mut x = 0x9e3779b97f4a7c15u64;
    for r in 0..nrows {
        let n = if r < heavy { heavy_nnz } else { 2 };
        let mut cols: Vec<u32> = (0..n)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((x >> 33) as usize % nrows) as u32
            })
            .collect();
        cols.sort_unstable();
        cols.dedup();
        for c in cols {
            indices.push(c);
            data.push(1.0);
        }
        indptr.push(indices.len());
    }
    Csr { nrows, ncols: nrows, indptr, indices, data }
}

const FIXED: [Scheduler; 4] = [
    Scheduler::Static,
    Scheduler::WorkStealingDyn,
    Scheduler::WorkStealingBw,
    Scheduler::WorkStealingNuma,
];

#[test]
fn ws_adapt_wins_or_ties_the_best_fixed_scheduler_on_every_registry_dataset() {
    let sys = two_socket_sys();
    let mut exact = 0usize;
    for d in registry::DATASETS {
        let a = d.build(SCALE);
        let best = FIXED
            .iter()
            .map(|&s| {
                parallel::row_blocked(&sys, native(ImplId::Spz), &a, &a, &fixed_cfg(s))
                    .unwrap()
                    .metrics
                    .critical_path_cycles
            })
            .fold(f64::INFINITY, f64::min);
        let adapt =
            parallel::row_blocked(&sys, native(ImplId::Spz), &a, &a, &adapt_cfg(ImplId::Spz))
                .unwrap()
                .metrics
                .critical_path_cycles;
        assert!(
            adapt <= best * TIE,
            "{}: ws-adapt {adapt:.0} lost to the best fixed scheduler {best:.0} \
             beyond the tie band",
            d.name
        );
        if adapt <= best * (1.0 + 1e-9) {
            exact += 1;
        }
    }
    // The tie band should be the exception, not the rule: on at least half
    // the registry the pilot ranks the plans correctly and the result is an
    // exact win-or-tie.
    assert!(
        exact * 2 >= registry::DATASETS.len(),
        "exact wins-or-ties on only {exact}/{} datasets",
        registry::DATASETS.len()
    );
}

#[test]
fn ws_adapt_strictly_beats_every_fixed_scheduler_on_a_skewed_matrix() {
    // Job kernel scl-hash on a hub-skewed matrix: the heavy blocks carry
    // ~50x the average row work, so probing finds spz far cheaper there and
    // the swap pays on the real critical path — something no fixed
    // scheduler can do at any placement, since they run scl-hash everywhere.
    let sys = two_socket_sys();
    let a = skewed(512, 64, 48);
    let run =
        parallel::row_blocked(&sys, native(ImplId::SclHash), &a, &a, &adapt_cfg(ImplId::SclHash))
            .unwrap();
    let d = run.decisions.expect("ws-adapt at 4 cores must report decisions");
    assert!(d.swapped_blocks > 0, "no kernel swaps on a hub-skewed matrix: {d:?}");
    for s in FIXED {
        let fixed = parallel::row_blocked(&sys, native(ImplId::SclHash), &a, &a, &fixed_cfg(s))
            .unwrap()
            .metrics
            .critical_path_cycles;
        assert!(
            run.metrics.critical_path_cycles < fixed,
            "{}: ws-adapt {:.0} did not strictly beat {fixed:.0}",
            s.name(),
            run.metrics.critical_path_cycles
        );
    }
}

#[test]
fn ws_adapt_counts_are_exactly_additive_per_chosen_impl() {
    // Reconstruct the run from its own block plan: one *serial* machine per
    // block, running the slab on the kernel ws-adapt chose. The event
    // counts must sum to the parallel per-core totals exactly — swaps and
    // splits included (cuts are group-aligned, so no group changes
    // composition).
    let a = skewed(512, 64, 48);
    let sys = SystemConfig::default();
    for job in [ImplId::SclHash, ImplId::Spz] {
        let run = parallel::row_blocked(&sys, native(job), &a, &a, &adapt_cfg(job)).unwrap();
        assert_eq!(
            run.block_plan.len(),
            run.decisions.map(|d| d.total_blocks).unwrap_or(0),
            "block plan and decision summary disagree on the executed geometry"
        );
        let mut rebuilt = OpCounters::default();
        for &(lo, hi, imp) in &run.block_plan {
            let mut m = Machine::new(SystemConfig::default());
            let mut im = native(imp.unwrap_or(job))().unwrap();
            im.multiply(&mut m, &slab(&a, lo, hi), &a).unwrap();
            rebuilt.add(&m.metrics().ops);
        }
        let mut parallel_sum = OpCounters::default();
        for core in &run.metrics.per_core {
            parallel_sum.add(&core.ops);
        }
        assert_eq!(
            parallel_sum, rebuilt,
            "{}: per-core counts must sum to the per-block serial counts of \
             each chosen impl",
            job.name()
        );
    }
}

#[test]
fn ws_adapt_at_one_core_is_bit_identical_to_ws_dyn() {
    let sys = SystemConfig::default();
    let d = registry::find("p2p").unwrap();
    let a = d.build(0.01);
    let adapt = parallel::row_blocked(
        &sys,
        native(ImplId::Spz),
        &a,
        &a,
        &ParallelConfig {
            scheduler: Scheduler::WorkStealingAdapt,
            impl_id: Some(ImplId::Spz),
            ..ParallelConfig::new(1)
        },
    )
    .unwrap();
    let dynr = parallel::row_blocked(
        &sys,
        native(ImplId::Spz),
        &a,
        &a,
        &ParallelConfig { scheduler: Scheduler::WorkStealingDyn, ..ParallelConfig::new(1) },
    )
    .unwrap();
    assert!(adapt.decisions.is_none(), "1-core ws-adapt must not probe or decide");
    assert!(adapt.block_plan.iter().all(|&(_, _, imp)| imp.is_none()));
    assert_eq!(adapt.csr, dynr.csr);
    for (ma, md) in adapt.metrics.per_core.iter().zip(&dynr.metrics.per_core) {
        assert_eq!(ma.cycles, md.cycles);
        assert_eq!(ma.ops, md.ops);
        assert_eq!(ma.shared, md.shared);
    }
}

#[test]
fn double_run_stable_json_is_byte_identical_at_two_sockets_and_four_shards() {
    let sys = SystemConfig {
        shared: SharedMemConfig {
            sockets: 2,
            replay_shards: 4,
            ..SystemConfig::default().shared
        },
        ..SystemConfig::default()
    };
    let spec = JobSpec::new(ImplId::SclHash, DatasetSource::registry("wiki").unwrap())
        .with_scale(0.01)
        .with_cores(4)
        .with_scheduler(Scheduler::WorkStealingAdapt);
    let run = |cfg: SessionConfig| {
        Session::with_config(cfg).run(&spec).expect("job").to_json_stable()
    };
    let j1 = run(SessionConfig { sys, ..SessionConfig::default() });
    let j2 = run(SessionConfig { sys, ..SessionConfig::default() });
    assert_eq!(j1, j2, "ws-adapt double run drifted through to_json_stable()");
    assert!(
        j1.contains("\"sched_decisions\":{\"total_blocks\":"),
        "multi-core ws-adapt runs must export their decision summary: {j1}"
    );
}
