//! Randomized differential testing of the five SpGEMM implementations
//! against the reference oracle (hand-rolled property testing; proptest is
//! not in the offline vendor set). Every trial uses a fresh random matrix
//! family, shape, and density.

use sparsezipper::config::SystemConfig;
use sparsezipper::matrix::{gen, Csr};
use sparsezipper::runtime::Engine;
use sparsezipper::sim::Machine;
use sparsezipper::spgemm::{self, SpGemm};
use sparsezipper::util::Pcg32;

fn random_matrix(rng: &mut Pcg32, trial: usize) -> (Csr, String) {
    match trial % 6 {
        0 => {
            let n = 32 + rng.gen_usize(200);
            let nnz = n * (1 + rng.gen_usize(8));
            (gen::erdos_renyi(n, n, nnz, rng.next_u64()), format!("er({n},{nnz})"))
        }
        1 => {
            let n = 64 + rng.gen_usize(300);
            let nnz = n * (1 + rng.gen_usize(6));
            let sigma = rng.gen_f64() * 1.4;
            (
                gen::powerlaw_clustered(n, nnz, sigma, rng.gen_f64() * 0.7, rng.next_u64()),
                format!("powerlaw({n},{nnz},{sigma:.2})"),
            )
        }
        2 => {
            let n = 64 + rng.gen_usize(200);
            let k = 1 + rng.gen_usize(6);
            (gen::kregular(n, k, rng.next_u64()), format!("kregular({n},{k})"))
        }
        3 => {
            let s = 5 + rng.gen_usize(12);
            (gen::grid2d(s, s, rng.next_u64()), format!("grid2d({s})"))
        }
        4 => {
            let n = 64 + rng.gen_usize(200);
            (
                gen::banded(n, 4 + rng.gen_usize(20), 3 + rng.gen_usize(10), rng.next_u64()),
                format!("banded({n})"),
            )
        }
        _ => {
            let n = 50 + rng.gen_usize(150);
            (
                gen::circuit(n, 2.0 + rng.gen_f64() * 5.0, 0.1, rng.next_u64()),
                format!("circuit({n})"),
            )
        }
    }
}

#[test]
fn prop_differential_all_impls() {
    let mut rng = Pcg32::new(0xD1FF);
    for trial in 0..30 {
        let (a, desc) = random_matrix(&mut rng, trial);
        let r = spgemm::reference(&a, &a);
        for id in spgemm::ImplId::ALL {
            let name = id.name();
            let mut im = id.instantiate(Engine::Native, std::path::Path::new("artifacts")).unwrap();
            let mut m = Machine::new(SystemConfig::default());
            let c = im.multiply(&mut m, &a, &a).unwrap();
            assert!(
                spgemm::same_product(&c, &r, 1e-2),
                "trial {trial} {desc}: {name} diverges ({} vs {} nnz)",
                c.nnz(),
                r.nnz()
            );
            assert!(c.validate().is_ok(), "trial {trial} {desc}: {name} invalid CSR");
        }
    }
}

#[test]
fn prop_output_structure_only_depends_on_structure() {
    // Same pattern, different values: output pattern identical.
    let mut rng = Pcg32::new(77);
    let a1 = gen::powerlaw_clustered(200, 1500, 1.0, 0.3, 5);
    let mut a2 = a1.clone();
    for v in &mut a2.data {
        *v = rng.gen_f32_range(0.5, 1.5);
    }
    let mut m1 = Machine::new(SystemConfig::default());
    let mut m2 = Machine::new(SystemConfig::default());
    let c1 = spgemm::spz::Spz::native().multiply(&mut m1, &a1, &a1).unwrap();
    let c2 = spgemm::spz::Spz::native().multiply(&mut m2, &a2, &a2).unwrap();
    assert_eq!(c1.indptr, c2.indptr);
    assert_eq!(c1.indices, c2.indices);
    // ... and so do the simulated metrics (timing is value-independent).
    assert_eq!(m1.metrics().ops.mszipk, m2.metrics().ops.mszipk);
    assert!((m1.metrics().cycles - m2.metrics().cycles).abs() < 1e-9);
}

#[test]
fn prop_determinism() {
    // Same seed -> bit-identical run (metrics and product).
    let a = gen::powerlaw_clustered(300, 2400, 1.1, 0.4, 123);
    let run = || {
        let mut m = Machine::new(SystemConfig::default());
        let c = spgemm::spz_rsort::SpzRsort::native()
            .multiply(&mut m, &a, &a)
            .unwrap();
        (c, m.metrics().cycles, m.metrics().mem.l1d_accesses)
    };
    let (c1, cy1, l1a) = run();
    let (c2, cy2, l1b) = run();
    assert_eq!(c1, c2);
    assert_eq!(cy1, cy2);
    assert_eq!(l1a, l1b);
}

#[test]
fn prop_scaled_datasets_all_verify() {
    // Every registry dataset at small scale, spz vs oracle.
    for d in sparsezipper::matrix::registry::DATASETS {
        let a = d.build(0.008);
        let r = spgemm::reference(&a, &a);
        let mut m = Machine::new(SystemConfig::default());
        let c = spgemm::spz::Spz::native().multiply(&mut m, &a, &a).unwrap();
        assert!(
            spgemm::same_product(&c, &r, 1e-2),
            "{} at scale 0.008",
            d.name
        );
    }
}
