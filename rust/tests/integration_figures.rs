//! Figure/table harness integration: a small-scale sweep must reproduce the
//! paper's qualitative shape (who wins, where, why) and render every
//! report. This is the fast CI version of examples/paper_pipeline.rs.

use sparsezipper::api::{DatasetSource, Session, SuiteRun, SuiteSpec};
use sparsezipper::area::AreaModel;
use sparsezipper::coordinator::figures;
use sparsezipper::ImplId;

fn small_suite() -> SuiteRun {
    let session = Session::new();
    let spec = SuiteSpec {
        datasets: ["p2p", "wiki", "usroads", "m133-b3", "bcsstk17"]
            .iter()
            .map(|n| DatasetSource::registry(n).unwrap())
            .collect(),
        scale: 0.05,
        verify: true,
        threads: 1,
        ..Default::default()
    };
    session.run_suite(&spec).expect("suite")
}

#[test]
fn suite_verifies_and_renders_everything() {
    let suite = small_suite();
    assert_eq!(suite.results.len(), 25);
    assert!(suite.results.iter().all(|r| r.verified));

    for (name, content) in [
        ("table3", figures::table3(&suite)),
        ("fig8", figures::fig8(&suite)),
        ("fig9", figures::fig9(&suite)),
        ("fig10", figures::fig10(&suite)),
        ("fig11", figures::fig11(&suite)),
        ("table4", AreaModel::paper().table4()),
    ] {
        assert!(!content.is_empty(), "{name} empty");
        assert!(content.lines().count() > 3, "{name} too short");
    }
    let tsv = figures::tsv_exports(&suite);
    assert_eq!(tsv.len(), 4);
    for (name, content) in &tsv {
        assert!(content.lines().count() > 5, "{name} too short");
    }
    // The structured export covers every job and dataset.
    let json = suite.to_json();
    assert!(json.contains("\"results\""), "json missing results");
    for r in &suite.results {
        assert!(json.contains(&format!("\"impl\":\"{}\"", r.impl_id)), "{}", r.impl_id);
    }
}

#[test]
fn qualitative_shape_small_scale() {
    let suite = small_suite();
    // Matrix-unit implementations beat the vector baseline even at small
    // scale (cache effects shrink, but the sort-phase advantage remains).
    for d in ["p2p", "wiki", "m133-b3"] {
        let sp = suite.speedup(ImplId::Spz, ImplId::VecRadix, d).unwrap();
        assert!(sp > 1.0, "spz !> vec-radix on {d} ({sp:.2}x)");
    }
    // vec-radix always touches L1D more than spz (Figure 10's claim).
    for r in &suite.results {
        if r.impl_id == ImplId::VecRadix {
            let z = suite.get(ImplId::Spz, &r.dataset).unwrap();
            assert!(
                r.metrics.mem.l1d_accesses > z.metrics.mem.l1d_accesses,
                "fig10 shape broken on {}",
                r.dataset
            );
        }
    }
}

#[test]
fn fig12_scaling_sweep_renders_and_scales() {
    let session = Session::new();
    let datasets: Vec<DatasetSource> = ["p2p", "m133-b3"]
        .iter()
        .map(|n| DatasetSource::registry(n).unwrap())
        .collect();
    let points = figures::scaling_sweep(
        &session,
        &datasets,
        ImplId::Spz,
        0.02,
        &[1, 4],
        &sparsezipper::spgemm::parallel::Scheduler::ALL,
    )
    .expect("sweep");
    // 1 serial baseline + every scheduler at 4 cores, per dataset.
    assert_eq!(
        points.len(),
        2 * (1 + sparsezipper::spgemm::parallel::Scheduler::ALL.len())
    );
    for p in &points {
        assert!(p.cycles > 0.0, "{}: zero cycles", p.dataset);
        if p.cores > 1 {
            assert!(
                p.speedup > 1.0,
                "{} x{} {:?}: no speedup ({:.2}x)",
                p.dataset,
                p.cores,
                p.scheduler,
                p.speedup
            );
            assert!(p.imbalance >= 1.0);
            // The shared-memory replay ran: the hit rate is a rate and the
            // queueing totals are non-negative.
            assert!((0.0..=1.0).contains(&p.llc_hit_rate), "{}", p.dataset);
            assert!(p.dram_queue_cycles >= 0.0);
        }
    }
    let txt = figures::fig12(&points);
    assert!(txt.contains("p2p") && txt.contains("work-stealing"), "{txt}");
    let tsv = figures::fig12_tsv(&points);
    assert_eq!(tsv.lines().count(), 1 + points.len());
    assert!(tsv.starts_with("matrix\timpl\tsched\tcores\t"), "{tsv}");
}

#[test]
fn area_model_reproduces_table4() {
    let m = AreaModel::paper();
    assert!((m.overhead_pct() - 12.72).abs() < 1.0);
}

#[test]
fn vec_radix_block_sweep_recorded() {
    let suite = small_suite();
    for r in &suite.results {
        if r.impl_id == ImplId::VecRadix {
            assert!(r.block_elems.is_some(), "block sweep missing on {}", r.dataset);
        }
    }
}
