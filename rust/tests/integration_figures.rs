//! Figure/table harness integration: a small-scale sweep must reproduce the
//! paper's qualitative shape (who wins, where, why) and render every
//! report. This is the fast CI version of examples/paper_pipeline.rs.

use sparsezipper::area::AreaModel;
use sparsezipper::coordinator::{figures, run_suite, SuiteConfig};

fn small_suite() -> sparsezipper::coordinator::SuiteResult {
    let cfg = SuiteConfig {
        datasets: vec![
            "p2p".into(),
            "wiki".into(),
            "usroads".into(),
            "m133-b3".into(),
            "bcsstk17".into(),
        ],
        scale: 0.05,
        verify: true,
        threads: 1,
        ..Default::default()
    };
    run_suite(&cfg).expect("suite")
}

#[test]
fn suite_verifies_and_renders_everything() {
    let suite = small_suite();
    assert_eq!(suite.results.len(), 25);
    assert!(suite.results.iter().all(|r| r.verified));

    for (name, content) in [
        ("table3", figures::table3(&suite)),
        ("fig8", figures::fig8(&suite)),
        ("fig9", figures::fig9(&suite)),
        ("fig10", figures::fig10(&suite)),
        ("fig11", figures::fig11(&suite)),
        ("table4", AreaModel::paper().table4()),
    ] {
        assert!(!content.is_empty(), "{name} empty");
        assert!(content.lines().count() > 3, "{name} too short");
    }
    let tsv = figures::tsv_exports(&suite);
    assert_eq!(tsv.len(), 4);
    for (name, content) in &tsv {
        assert!(content.lines().count() > 5, "{name} too short");
    }
}

#[test]
fn qualitative_shape_small_scale() {
    let suite = small_suite();
    // Matrix-unit implementations beat the vector baseline even at small
    // scale (cache effects shrink, but the sort-phase advantage remains).
    for d in ["p2p", "wiki", "m133-b3"] {
        let sp = suite.speedup("spz", "vec-radix", d).unwrap();
        assert!(sp > 1.0, "spz !> vec-radix on {d} ({sp:.2}x)");
    }
    // vec-radix always touches L1D more than spz (Figure 10's claim).
    for r in &suite.results {
        if r.impl_name == "vec-radix" {
            let z = suite.get("spz", &r.dataset).unwrap();
            assert!(
                r.metrics.mem.l1d_accesses > z.metrics.mem.l1d_accesses,
                "fig10 shape broken on {}",
                r.dataset
            );
        }
    }
}

#[test]
fn area_model_reproduces_table4() {
    let m = AreaModel::paper();
    assert!((m.overhead_pct() - 12.72).abs() < 1.0);
}

#[test]
fn vec_radix_block_sweep_recorded() {
    let suite = small_suite();
    for r in &suite.results {
        if r.impl_name == "vec-radix" {
            assert!(r.block_elems.is_some(), "block sweep missing on {}", r.dataset);
        }
    }
}
