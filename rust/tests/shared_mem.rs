//! Properties of the shared memory system (trace-and-replay): replay
//! determinism, exact per-core trace accounting, 1-core == seed behaviour,
//! and contention behaviour under real multi-core runs.
//!
//! The 1-core differential over *all five implementations x all 14 registry
//! datasets* lives in `tests/parallel_diff.rs` (it rides the existing
//! sweep); this file pins the deeper shared-model properties on targeted
//! inputs.

use anyhow::Result;
use sparsezipper::matrix::gen;
use sparsezipper::mem::{replay, SharedStats, TraceBuf, TraceEvent, TraceKind};
use sparsezipper::spgemm::parallel::{self, ParallelConfig, Scheduler};
use sparsezipper::spgemm::{ImplId, SpGemm};
use sparsezipper::SystemConfig;

fn native(id: ImplId) -> impl Fn() -> Result<Box<dyn SpGemm>> + Sync {
    move || id.instantiate(sparsezipper::Engine::Native, std::path::Path::new("."))
}

fn sys() -> SystemConfig {
    SystemConfig::default()
}

#[test]
fn per_core_trace_accounting_is_exact_at_every_core_count() {
    // Every LLC-level access of every core's shadow shows up in the replay
    // exactly once: demand lookups + writeback installs == shadow accesses,
    // and hits + misses == demand lookups.
    let a = gen::rmat(256, 256, 2600, 0.62, 0.18, 0.14, 61);
    for cores in [1usize, 2, 7] {
        let run = parallel::row_blocked(&sys(), native(ImplId::Spz), &a, &a,
            &ParallelConfig::new(cores))
        .unwrap();
        for (c, m) in run.metrics.per_core.iter().enumerate() {
            let sh = &m.shared;
            assert_eq!(
                sh.llc_accesses + sh.writeback_installs,
                m.mem.llc_accesses,
                "core {c} of {cores}: replay must see every shadow LLC access"
            );
            assert_eq!(sh.llc_hits + sh.llc_misses, sh.llc_accesses, "core {c} of {cores}");
        }
        // Totals are exact sums of the per-core counters.
        let mut sum = SharedStats::default();
        for m in &run.metrics.per_core {
            sum.add(&m.shared);
        }
        assert_eq!(sum, run.metrics.total.shared, "x{cores}");
    }
}

#[test]
fn one_core_stalls_are_exactly_zero_for_every_scheduler() {
    let a = gen::rmat(160, 160, 1400, 0.58, 0.2, 0.14, 62);
    for sched in Scheduler::ALL {
        let cfg = ParallelConfig { scheduler: sched, ..ParallelConfig::new(1) };
        let run = parallel::row_blocked(&sys(), native(ImplId::SclHash), &a, &a, &cfg).unwrap();
        let s = &run.metrics.per_core[0].shared;
        assert_eq!(s.stall_cycles(), 0.0, "{sched}");
        assert_eq!(s.shared_fills + s.demotions, 0, "{sched}: shadow == shared at 1 core");
        assert_eq!(s.coherence_events(), 0, "{sched}");
        assert_eq!(s.invalidations_received, 0, "{sched}");
    }
}

#[test]
fn multicore_results_are_bit_reproducible_per_scheduler() {
    let a = gen::rmat(256, 256, 2600, 0.62, 0.18, 0.14, 63);
    for sched in Scheduler::ALL {
        let cfg = ParallelConfig { scheduler: sched, ..ParallelConfig::new(7) };
        let r1 = parallel::row_blocked(&sys(), native(ImplId::Spz), &a, &a, &cfg).unwrap();
        let r2 = parallel::row_blocked(&sys(), native(ImplId::Spz), &a, &a, &cfg).unwrap();
        for c in 0..7 {
            let (m1, m2) = (&r1.metrics.per_core[c], &r2.metrics.per_core[c]);
            assert_eq!(m1.cycles, m2.cycles, "{sched} core {c}");
            assert_eq!(m1.phase_cycles, m2.phase_cycles, "{sched} core {c}");
            assert_eq!(m1.shared, m2.shared, "{sched} core {c}");
        }
        assert_eq!(
            r1.metrics.channel_busy_cycles, r2.metrics.channel_busy_cycles,
            "{sched}"
        );
    }
}

#[test]
fn shared_llc_sees_constructive_sharing_of_b_rows() {
    // Every core multiplies its row slab of A against the *same* B, so B's
    // rows are pulled in once and shared: some shadow-predicted misses must
    // turn into shared-LLC hits (the effect the analytic model couldn't
    // see). A dense-ish B at 4 cores makes this reliable.
    let a = gen::erdos_renyi(512, 512, 8000, 64);
    let run =
        parallel::row_blocked(&sys(), native(ImplId::SclHash), &a, &a, &ParallelConfig::new(4))
            .unwrap();
    let tot = &run.metrics.total.shared;
    assert!(
        tot.shared_fills > 0,
        "cores streaming one B must constructively share ({tot:?})"
    );
    assert!(tot.sharing_saved_cycles > 0.0);
}

#[test]
fn dram_channel_occupancy_matches_misses() {
    // Total channel busy cycles == shared-LLC misses x transfer occupancy
    // (every miss occupies exactly one channel once).
    let a = gen::erdos_renyi(512, 512, 6000, 65);
    let cfgsys = sys();
    let run = parallel::row_blocked(&cfgsys, native(ImplId::Spz), &a, &a, &ParallelConfig::new(4))
        .unwrap();
    let misses = run.metrics.total.shared.llc_misses;
    let busy: f64 = run.metrics.channel_busy_cycles.iter().sum();
    assert_eq!(
        busy,
        misses as f64 * cfgsys.shared.dram_transfer_cycles,
        "channel occupancy must account for every miss exactly once"
    );
    assert_eq!(run.metrics.channel_busy_cycles.len(), cfgsys.shared.dram_channels);
}

#[test]
fn hand_built_disjoint_traces_are_coherence_free_and_order_deterministic() {
    let c = sys();
    let mk = |base: u64, n: u64, t0: f64| -> TraceBuf {
        TraceBuf::from_events((0..n).map(|i| {
            (
                t0 + i as f64,
                TraceEvent::new(base + i, TraceKind::Demand, i % 3 == 0, false, true, 1),
            )
        }))
    };
    // Disjoint line ranges per core.
    let traces = vec![mk(0, 200, 0.0), mk(10_000, 200, 0.0), mk(20_000, 200, 0.0)];
    let out1 = replay(&c.mem, &c.shared, &traces);
    let out2 = replay(&c.mem, &c.shared, &traces);
    assert_eq!(out1, out2, "replay is a pure function of the traces");
    for s in &out1.per_core {
        assert_eq!(s.coherence_events(), 0);
        assert_eq!(s.invalidations_sent + s.invalidations_received, 0);
        assert_eq!(s.coherence_cycles, 0.0);
    }
    // Queueing exists (overlapping times, shared pipeline) but coherence
    // cannot: the address sets never intersect.
    let queued: f64 = out1.per_core.iter().map(|s| s.llc_queue_cycles).sum();
    assert!(queued > 0.0, "overlapping traffic must queue at the shared LLC");
}
