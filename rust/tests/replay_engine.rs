//! Properties of the iterative shared-memory ReplayEngine and the
//! write-shared destination region across the *entire* Table III registry:
//!
//! * convergence — a second iteration never increases the total corrected
//!   stalls, and every dataset reaches the fixed point within
//!   `max_replay_iters` (the reported residual is ≤ epsilon);
//! * write sharing — with the stitched product mapped into the shared
//!   destination region, real multi-core runs report nonzero coherence
//!   traffic on the output (before this, per-block outputs were
//!   core-private and upgrades on real workloads were ~zero);
//! * `ws-bw` — the bandwidth-aware scheduler preserves exact per-core
//!   event-count additivity vs the serial loop and never loses to `ws-dyn`
//!   on at least half the registry in simulated wall-clock.

use anyhow::Result;
use sparsezipper::config::SharedMemConfig;
use sparsezipper::matrix::registry;
use sparsezipper::sim::machine::OpCounters;
use sparsezipper::spgemm::parallel::{self, ParallelConfig, Scheduler};
use sparsezipper::spgemm::{ImplId, SpGemm};
use sparsezipper::SystemConfig;

const SCALE: f64 = 0.003;

fn native(id: ImplId) -> impl Fn() -> Result<Box<dyn SpGemm>> + Sync {
    move || id.instantiate(sparsezipper::Engine::Native, std::path::Path::new("."))
}

#[test]
fn replay_engine_converges_on_every_registry_dataset() {
    let sys = SystemConfig::default();
    let one_shot = SystemConfig {
        shared: SharedMemConfig { max_replay_iters: 1, ..sys.shared },
        ..sys
    };
    for d in registry::DATASETS {
        let a = d.build(SCALE);
        let cfg = ParallelConfig::new(4);
        let full = parallel::row_blocked(&sys, native(ImplId::Spz), &a, &a, &cfg).unwrap();
        let capped = parallel::row_blocked(&one_shot, native(ImplId::Spz), &a, &a, &cfg).unwrap();

        let tot = &full.metrics.total.shared;
        // Fixed point within the iteration budget, and the residual says so.
        assert!(tot.replay_iters >= 1 && tot.replay_iters <= sys.shared.max_replay_iters,
            "{}: {} iters", d.name, tot.replay_iters);
        assert!(
            tot.replay_residual <= sys.shared.replay_epsilon,
            "{}: fixed point not reached (residual {})",
            d.name,
            tot.replay_residual
        );

        // Iteration never increases the corrected stalls: the engine only
        // ever downgrades repeat demotions. (Counters are pass-invariant.)
        let one = &capped.metrics.total.shared;
        assert!(
            tot.demotion_cycles <= one.demotion_cycles + 1e-9,
            "{}: iterated demotion cycles {} > one-shot {}",
            d.name,
            tot.demotion_cycles,
            one.demotion_cycles
        );
        assert!(
            tot.stall_cycles() <= one.stall_cycles() + 1e-9,
            "{}: iterated stalls {} > one-shot {}",
            d.name,
            tot.stall_cycles(),
            one.stall_cycles()
        );
        assert_eq!(tot.demotions, one.demotions, "{}: counters are pass-invariant", d.name);
        assert_eq!(tot.llc_accesses, one.llc_accesses, "{}", d.name);
        // The one-shot residual is exactly the correction iteration applies.
        assert!(
            (one.replay_residual - (one.demotion_cycles - tot.demotion_cycles)).abs() <= 1e-6,
            "{}: residual {} vs applied correction {}",
            d.name,
            one.replay_residual,
            one.demotion_cycles - tot.demotion_cycles
        );
    }
}

#[test]
fn shared_output_region_sees_write_sharing_on_real_datasets() {
    let sys = SystemConfig::default();
    let mut with_upgrades = 0usize;
    let mut total_upgrades = 0u64;
    for d in registry::DATASETS {
        let a = d.build(SCALE);
        let run =
            parallel::row_blocked(&sys, native(ImplId::SclHash), &a, &a, &ParallelConfig::new(4))
                .unwrap();
        let tot = &run.metrics.total.shared;
        total_upgrades += tot.upgrades;
        if tot.upgrades > 0 {
            with_upgrades += 1;
        }
        // Larger datasets have many block boundaries on distinct cores:
        // the write-shared output path must fire.
        if a.nrows >= 256 {
            assert!(
                tot.upgrades >= 1,
                "{}: no coherence upgrades on the shared output region ({tot:?})",
                d.name
            );
        }
    }
    assert!(total_upgrades > 0, "no dataset produced write-shared traffic");
    assert!(
        with_upgrades * 2 >= registry::DATASETS.len(),
        "write sharing must be the norm, not the exception ({with_upgrades}/{})",
        registry::DATASETS.len()
    );
}

#[test]
fn ws_bw_keeps_exact_count_additivity_vs_serial() {
    let sys = SystemConfig::default();
    for d in registry::DATASETS.iter().take(6) {
        let a = d.build(SCALE);
        for id in [ImplId::SclHash, ImplId::Spz] {
            let mut m = sparsezipper::Machine::new(sys);
            let serial_counts = {
                let mut im = native(id)().unwrap();
                im.multiply(&mut m, &a, &a).unwrap();
                m.metrics().ops
            };
            let cfg = ParallelConfig {
                scheduler: Scheduler::WorkStealingBw,
                ..ParallelConfig::new(4)
            };
            let run = parallel::row_blocked(&sys, native(id), &a, &a, &cfg).unwrap();
            let mut sum = OpCounters::default();
            for core in &run.metrics.per_core {
                sum.add(&core.ops);
            }
            assert_eq!(
                sum, serial_counts,
                "{} on {}: ws-bw per-core counts must sum to the serial loop's",
                id.name(),
                d.name
            );
        }
    }
}

#[test]
fn ws_bw_critical_path_does_not_lose_to_ws_dyn_on_most_of_the_registry() {
    let sys = SystemConfig::default();
    let mut wins_or_ties = 0usize;
    for d in registry::DATASETS {
        let a = d.build(SCALE);
        let dy = parallel::row_blocked(
            &sys,
            native(ImplId::Spz),
            &a,
            &a,
            &ParallelConfig { scheduler: Scheduler::WorkStealingDyn, ..ParallelConfig::new(4) },
        )
        .unwrap();
        let bw = parallel::row_blocked(
            &sys,
            native(ImplId::Spz),
            &a,
            &a,
            &ParallelConfig { scheduler: Scheduler::WorkStealingBw, ..ParallelConfig::new(4) },
        )
        .unwrap();
        if bw.metrics.critical_path_cycles <= dy.metrics.critical_path_cycles * (1.0 + 1e-9) {
            wins_or_ties += 1;
        }
    }
    assert!(
        wins_or_ties * 2 >= registry::DATASETS.len(),
        "ws-bw beat/tied ws-dyn on only {wins_or_ties}/{} registry datasets",
        registry::DATASETS.len()
    );
}
