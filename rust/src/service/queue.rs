//! Deficit-round-robin (DRR) fair queueing over per-tenant FIFOs.
//!
//! Each tenant owns a FIFO of admitted jobs and a *deficit* counter in work
//! units — the same Gustavson multiply estimates the `ws-*` row-block
//! schedulers are driven by. Backlogged tenants sit on a round-robin ring;
//! every time a tenant reaches the front and cannot afford its head job, its
//! deficit grows by `quantum * weight` and it rotates to the back. A tenant
//! whose deficit covers its head job serves jobs (front position retained)
//! until the deficit runs dry, so over any window in which a set of tenants
//! stays backlogged, the *work* served per tenant tracks the weight ratios
//! to within one quantum — a 10k-job burst from one tenant cannot starve the
//! others. Draining a tenant resets its deficit (no hoarding while idle).
//!
//! The queue is plain data behind the service's one mutex: `next()` is a
//! pure function of the queue state, so the dispatch *order* is independent
//! of which worker thread happens to ask — that, plus the simulator's own
//! determinism, is why co-tenants can never perturb each other's results.

use super::handle::JobState;
use super::service::SuiteSink;
use crate::api::JobSpec;
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

/// One admitted, not-yet-running job.
pub(crate) struct QueuedJob {
    pub spec: JobSpec,
    pub st: Arc<JobState>,
    pub tenant: String,
    /// DRR cost in Gustavson multiply units (>= 1).
    pub cost: u64,
    /// Pool slots the job occupies while running (`cores` clamped to the
    /// pool budget).
    pub slots: usize,
    /// Streaming destination for suite jobs: `(sink, spec index)`.
    pub sink: Option<(Arc<SuiteSink>, usize)>,
}

struct TenantState {
    queue: VecDeque<QueuedJob>,
    weight: u32,
    deficit: u64,
    served: u64,
    in_ring: bool,
}

/// What the dispatcher should do next.
pub(crate) enum Dispatch {
    /// Run this job (already charged against the tenant's deficit).
    Job(QueuedJob),
    /// The DRR-selected head job needs more pool slots than are free. The
    /// dispatcher must wait — narrower jobs queued behind it do *not* jump
    /// ahead, so fairness order is preserved at the cost of momentarily
    /// idle slots.
    WaitForSlots,
    /// No jobs queued.
    Empty,
}

pub(crate) struct DrrQueue {
    tenants: HashMap<String, TenantState>,
    ring: VecDeque<String>,
    quantum: u64,
    /// Jobs admitted but not yet dispatched (the bounded-depth quantity).
    pub queued: usize,
}

impl DrrQueue {
    pub fn new(quantum: u64) -> DrrQueue {
        // `SimServiceConfig::validate` guarantees this at the construction
        // boundary; a zero quantum would deadlock `next()` (deficits never
        // grow), so fail loudly here rather than clamp silently.
        assert!(quantum >= 1, "DRR quantum must be at least 1 (got {quantum})");
        DrrQueue { tenants: HashMap::new(), ring: VecDeque::new(), quantum, queued: 0 }
    }

    /// Enqueue a job under its tenant (creating the tenant with `weight` on
    /// first contact; the weight is fixed thereafter).
    pub fn push(&mut self, job: QueuedJob, weight: u32) {
        // Weights are validated with the service config (a zero weight
        // would starve the tenant forever); not clamped here.
        debug_assert!(weight >= 1, "tenant weight must be at least 1 (got {weight})");
        let t = self.tenants.entry(job.tenant.clone()).or_insert_with(|| TenantState {
            queue: VecDeque::new(),
            weight,
            deficit: 0,
            served: 0,
            in_ring: false,
        });
        if !t.in_ring {
            t.in_ring = true;
            self.ring.push_back(job.tenant.clone());
        }
        t.queue.push_back(job);
        self.queued += 1;
    }

    /// The next job in DRR order, given `free_slots` of pool budget.
    ///
    /// Terminates: every full pass over the ring grows each backlogged
    /// tenant's deficit by `quantum * weight`, and a pass that leaves every
    /// head unaffordable fast-forwards the remaining idle passes in one
    /// arithmetic step — so the loop visits each tenant O(1) times per
    /// dispatch even when job costs dwarf the quantum.
    pub fn next(&mut self, free_slots: usize) -> Dispatch {
        let mut rotations = 0usize;
        loop {
            let Some(front) = self.ring.front().cloned() else {
                return Dispatch::Empty;
            };
            let t = self.tenants.get_mut(&front).expect("ring tenant exists");
            if t.queue.is_empty() {
                t.deficit = 0;
                t.in_ring = false;
                self.ring.pop_front();
                continue;
            }
            let head = t.queue.front().expect("non-empty queue");
            if t.deficit >= head.cost {
                if head.slots > free_slots {
                    return Dispatch::WaitForSlots;
                }
                t.deficit -= head.cost;
                let job = t.queue.pop_front().expect("non-empty queue");
                self.queued -= 1;
                if t.queue.is_empty() {
                    t.deficit = 0;
                    t.in_ring = false;
                    self.ring.pop_front();
                }
                return Dispatch::Job(job);
            }
            t.deficit += self.quantum * u64::from(t.weight);
            self.ring.rotate_left(1);
            rotations += 1;
            if rotations >= self.ring.len() {
                // A whole pass credited one quantum each and nothing became
                // affordable: skip the remaining idle passes at once. Every
                // tenant receives the same k quanta (scaled by weight), so
                // the fairness accounting is exactly as if we had rotated.
                let k = self
                    .ring
                    .iter()
                    .map(|name| {
                        let t = &self.tenants[name];
                        let cost = t.queue.front().expect("backlogged").cost;
                        let per_pass = self.quantum * u64::from(t.weight);
                        cost.saturating_sub(t.deficit).div_ceil(per_pass)
                    })
                    .min()
                    .unwrap_or(0);
                if k > 0 {
                    for name in self.ring.iter() {
                        let t = self.tenants.get_mut(name).expect("ring tenant exists");
                        t.deficit += k * self.quantum * u64::from(t.weight);
                    }
                }
                rotations = 0;
            }
        }
    }

    /// Record a completion for the per-tenant served counter.
    pub fn record_served(&mut self, tenant: &str) {
        if let Some(t) = self.tenants.get_mut(tenant) {
            t.served += 1;
        }
    }

    /// Remove and return every still-queued job (service shutdown).
    pub fn drain(&mut self) -> Vec<QueuedJob> {
        let mut out = Vec::with_capacity(self.queued);
        for t in self.tenants.values_mut() {
            out.extend(t.queue.drain(..));
            t.deficit = 0;
            t.in_ring = false;
        }
        self.ring.clear();
        self.queued = 0;
        // Deterministic abort order (tenant map iteration is not).
        out.sort_by(|a, b| a.tenant.cmp(&b.tenant));
        out
    }

    /// `(tenant, weight, served)` rows, sorted by tenant name.
    pub fn tenant_rows(&self) -> Vec<(String, u32, u64)> {
        let mut rows: Vec<(String, u32, u64)> = self
            .tenants
            .iter()
            .map(|(n, t)| (n.clone(), t.weight, t.served))
            .collect();
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::DatasetSource;
    use crate::spgemm::ImplId;

    fn job(tenant: &str, cost: u64, slots: usize) -> QueuedJob {
        QueuedJob {
            spec: JobSpec::new(ImplId::SclHash, DatasetSource::registry("p2p").unwrap()),
            st: JobState::new(),
            tenant: tenant.to_string(),
            cost,
            slots,
            sink: None,
        }
    }

    fn drain_order(q: &mut DrrQueue, slots: usize) -> Vec<String> {
        let mut out = Vec::new();
        loop {
            match q.next(slots) {
                Dispatch::Job(j) => out.push(j.tenant),
                Dispatch::Empty => return out,
                Dispatch::WaitForSlots => panic!("unexpected slot wait"),
            }
        }
    }

    #[test]
    fn equal_cost_jobs_serve_weight_per_round() {
        let mut q = DrrQueue::new(10);
        for _ in 0..6 {
            q.push(job("a", 10, 1), 1);
            q.push(job("b", 10, 1), 2);
        }
        // Round pattern: a once, b twice — exactly the weights — until b
        // drains after round 3 and a finishes its backlog alone.
        let order = drain_order(&mut q, 1);
        assert_eq!(
            order,
            vec!["a", "b", "b", "a", "b", "b", "a", "b", "b", "a", "a", "a"]
        );
    }

    #[test]
    fn expensive_jobs_wait_for_deficit() {
        let mut q = DrrQueue::new(10);
        q.push(job("big", 40, 1), 1); // needs 4 rounds of deficit
        for _ in 0..4 {
            q.push(job("small", 10, 1), 1);
        }
        let order = drain_order(&mut q, 1);
        // `big` affords its job only after accumulating 4 quanta; `small`
        // serves one unit-cost job per round meanwhile.
        assert_eq!(order, vec!["small", "small", "small", "big", "small"]);
    }

    #[test]
    fn wide_job_blocks_rather_than_being_bypassed() {
        let mut q = DrrQueue::new(10);
        q.push(job("a", 10, 4), 1);
        q.push(job("a", 10, 1), 1);
        assert!(matches!(q.next(2), Dispatch::WaitForSlots));
        // Slots free up: the wide job goes first, order preserved.
        match q.next(4) {
            Dispatch::Job(j) => assert_eq!(j.slots, 4),
            _ => panic!("expected the wide job"),
        }
    }

    #[test]
    #[should_panic(expected = "quantum must be at least 1")]
    fn zero_quantum_is_a_construction_error() {
        // Used to clamp to 1 silently; the config boundary validates it, so
        // a zero reaching here is a bug and must fail loudly.
        let _ = DrrQueue::new(0);
    }

    #[test]
    fn draining_resets_deficit() {
        let mut q = DrrQueue::new(10);
        q.push(job("a", 10, 1), 1);
        let _ = drain_order(&mut q, 1);
        // An idle round later, the tenant starts from zero deficit again.
        q.push(job("a", 10, 1), 1);
        q.push(job("b", 10, 1), 1);
        assert_eq!(drain_order(&mut q, 1), vec!["a", "b"]);
        assert_eq!(q.tenant_rows().len(), 2);
    }
}
