//! [`SimService`]: admission control, the fixed worker pool, and the
//! streaming suite API. See the module docs ([`crate::service`]) for the
//! architecture and the determinism contract.

use super::handle::{JobHandle, JobState};
use super::queue::{Dispatch, DrrQueue, QueuedJob};
use crate::api::{JobResult, JobSpec, Session, SuiteRun, SuiteSpec};
use crate::spgemm::ImplId;
use anyhow::{bail, ensure, Context, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// What [`SimService::submit`] does when the pending queue is at
/// [`SimServiceConfig::queue_depth`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backpressure {
    /// Fail the submission with the typed [`QueueFull`] error.
    Reject,
    /// Park the submitting thread until a slot frees (dispatch makes room).
    Block,
}

impl std::str::FromStr for Backpressure {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "reject" => Ok(Backpressure::Reject),
            "block" => Ok(Backpressure::Block),
            _ => bail!("unknown backpressure mode '{s}' (expected 'reject' or 'block')"),
        }
    }
}

/// Typed admission failure: the bounded queue was full under
/// [`Backpressure::Reject`]. Travels as the source of the `anyhow` error
/// returned by [`SimService::submit`], so callers can
/// `err.downcast_ref::<QueueFull>()` to distinguish flow control from real
/// failures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QueueFull {
    /// The configured depth the queue was at.
    pub depth: usize,
}

impl std::fmt::Display for QueueFull {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job queue full ({} pending jobs); retry later or use Backpressure::Block", self.depth)
    }
}

impl std::error::Error for QueueFull {}

/// Service configuration. The defaults suit an interactive host: one worker
/// per hardware thread, a deep blocking queue, equal tenant weights.
#[derive(Clone, Debug)]
pub struct SimServiceConfig {
    /// Worker pool budget in core-slots (and the number of pool threads).
    /// A job occupies `spec.cores.min(workers)` slots while running, so
    /// many 1-core jobs pack onto the pool while a wide job occupies it —
    /// the host never runs more than ~`workers` simulated cores at once.
    pub workers: usize,
    /// Bound on *pending* (admitted, not yet dispatched) jobs.
    pub queue_depth: usize,
    /// Behaviour when the queue is at `queue_depth`.
    pub backpressure: Backpressure,
    /// DRR quantum in Gustavson work units added per ring visit (scaled by
    /// the tenant weight). Smaller = fairer interleaving, larger = longer
    /// per-tenant bursts.
    pub quantum: u64,
    /// Weight for tenants not listed in `tenant_weights`.
    pub default_weight: u32,
    /// Per-tenant weight overrides (first match wins); a weight-2 tenant is
    /// served twice the work of a weight-1 tenant over any backlogged window.
    pub tenant_weights: Vec<(String, u32)>,
    /// DRR cost assumed for jobs whose dataset has no cached
    /// characterization yet (see [`Session::cached_stats`]).
    pub default_cost: u64,
    /// Per-job trace-ring budget, in 64KB chunks per simulated core.
    /// `0` (the default) inherits the session's configured
    /// [`crate::config::SharedMemConfig::trace_ring_chunks`]; a nonzero
    /// value overrides it for every job this service runs, so a saturated
    /// pool's aggregate resident trace memory is bounded by roughly
    /// `workers * trace_ring_chunks * 64KB` (each job holds at most
    /// `cores * ring` chunks, and jobs occupy `cores` slots). Must be 0 or
    /// at least 2. Purely a footprint knob: results are bit-identical at
    /// every ring size (overflow spills to disk).
    pub trace_ring_chunks: usize,
}

impl Default for SimServiceConfig {
    fn default() -> Self {
        SimServiceConfig {
            workers: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4),
            queue_depth: 1024,
            backpressure: Backpressure::Block,
            quantum: 1024,
            default_weight: 1,
            tenant_weights: Vec::new(),
            default_cost: 1024,
            trace_ring_chunks: 0,
        }
    }
}

impl SimServiceConfig {
    /// Weight for `tenant` (override list, else the default; floored at 1).
    pub fn weight_for(&self, tenant: &str) -> u32 {
        self.tenant_weights
            .iter()
            .find(|(t, _)| t == tenant)
            .map(|(_, w)| *w)
            .unwrap_or(self.default_weight)
            .max(1)
    }

    fn validate(&self) -> Result<()> {
        ensure!(self.workers >= 1, "SimServiceConfig.workers must be at least 1 (got {})", self.workers);
        ensure!(
            self.queue_depth >= 1,
            "SimServiceConfig.queue_depth must be at least 1 (got {})",
            self.queue_depth
        );
        ensure!(self.quantum >= 1, "SimServiceConfig.quantum must be at least 1 (got 0)");
        ensure!(self.default_weight >= 1, "SimServiceConfig.default_weight must be at least 1 (got 0)");
        for (t, w) in &self.tenant_weights {
            ensure!(*w >= 1, "tenant '{t}' weight must be at least 1 (got 0)");
        }
        ensure!(self.default_cost >= 1, "SimServiceConfig.default_cost must be at least 1 (got 0)");
        ensure!(
            self.trace_ring_chunks != 1,
            "SimServiceConfig.trace_ring_chunks must be 0 (inherit) or at least 2 (got 1)"
        );
        Ok(())
    }
}

/// Per-tenant service counters (one row of [`ServiceStats::tenants`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TenantStats {
    pub tenant: String,
    pub weight: u32,
    /// Jobs dispatched and finished (successfully or not) for this tenant.
    pub served: u64,
}

/// Snapshot of the service counters, exported through the stable JSON layer
/// (the `service` block of a suite export).
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    /// Configured pool budget in core-slots.
    pub workers: u64,
    /// Jobs accepted past admission control.
    pub admitted: u64,
    /// Submissions refused with [`QueueFull`].
    pub rejected: u64,
    /// Jobs that ran to a successful [`JobResult`].
    pub completed: u64,
    /// Jobs that ran and returned an error (or were abandoned at shutdown).
    pub failed: u64,
    /// Most pending jobs ever queued at once.
    pub queue_depth_high_water: u64,
    /// Most core-slots ever occupied at once (never exceeds `workers`: the
    /// no-thread-explosion witness).
    pub slots_high_water: u64,
    /// Per-tenant rows, sorted by tenant name.
    pub tenants: Vec<TenantStats>,
}

/// Everything behind the service's one mutex.
struct PoolState {
    q: DrrQueue,
    /// Unoccupied core-slots out of `cfg.workers`.
    free_slots: usize,
    paused: bool,
    shutdown: bool,
    admitted: u64,
    rejected: u64,
    completed: u64,
    failed: u64,
    queue_hw: usize,
    slots_hw: usize,
    /// Global completion sequence (stamped into each [`JobHandle`]).
    next_seq: u64,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Workers wait here for jobs / slots / resume; completions notify it.
    work: Condvar,
    /// Blocked submitters wait here for queue space; dispatch notifies it.
    space: Condvar,
    session: Session,
    cfg: SimServiceConfig,
}

impl Shared {
    fn snapshot(&self) -> ServiceStats {
        let s = self.state.lock().unwrap();
        ServiceStats {
            workers: self.cfg.workers as u64,
            admitted: s.admitted,
            rejected: s.rejected,
            completed: s.completed,
            failed: s.failed,
            queue_depth_high_water: s.queue_hw as u64,
            slots_high_water: s.slots_hw as u64,
            tenants: s
                .q
                .tenant_rows()
                .into_iter()
                .map(|(tenant, weight, served)| TenantStats { tenant, weight, served })
                .collect(),
        }
    }
}

/// The multi-tenant simulation service. See [`crate::service`].
///
/// Dropping the service shuts it down: in-flight jobs finish, still-queued
/// jobs complete their handles with a shutdown error, workers are joined.
pub struct SimService {
    sh: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl SimService {
    /// Spawn the worker pool over a shared session handle (sessions are
    /// cheap `Arc` clones; all clones share one dataset/oracle cache).
    pub fn start(session: Session, cfg: SimServiceConfig) -> Result<SimService> {
        cfg.validate()?;
        let sh = Arc::new(Shared {
            state: Mutex::new(PoolState {
                q: DrrQueue::new(cfg.quantum),
                free_slots: cfg.workers,
                paused: false,
                shutdown: false,
                admitted: 0,
                rejected: 0,
                completed: 0,
                failed: 0,
                queue_hw: 0,
                slots_hw: 0,
                next_seq: 0,
            }),
            work: Condvar::new(),
            space: Condvar::new(),
            session,
            cfg,
        });
        let mut workers = Vec::with_capacity(sh.cfg.workers);
        for i in 0..sh.cfg.workers {
            let sh = sh.clone();
            let t = std::thread::Builder::new()
                .name(format!("spz-svc-{i}"))
                .spawn(move || worker_loop(&sh))
                .context("spawn service worker")?;
            workers.push(t);
        }
        Ok(SimService { sh, workers })
    }

    /// The shared session (e.g. to pre-characterize datasets so DRR costs
    /// use real work estimates instead of [`SimServiceConfig::default_cost`]).
    pub fn session(&self) -> &Session {
        &self.sh.session
    }

    /// Submit one job under `tenant`. Applies admission control, then
    /// enqueues into the tenant's DRR FIFO. The returned [`JobHandle`] can
    /// be `wait()`ed or `.await`ed.
    pub fn submit(&self, tenant: &str, spec: JobSpec) -> Result<JobHandle> {
        self.submit_inner(tenant, spec, None)
    }

    fn submit_inner(
        &self,
        tenant: &str,
        spec: JobSpec,
        sink: Option<(Arc<SuiteSink>, usize)>,
    ) -> Result<JobHandle> {
        // Validate at admission, matching Session::run, so a bad spec is a
        // submit-time error rather than a deferred handle error.
        ensure!(spec.cores >= 1, "JobSpec.cores must be at least 1 (got {})", spec.cores);
        let cost = self
            .sh
            .session
            .cached_stats(&spec.dataset, spec.scale)
            .map(|st| (st.avg_work_per_row * st.nrows as f64) as u64)
            .unwrap_or(self.sh.cfg.default_cost)
            .max(1);
        let slots = spec.cores.min(self.sh.cfg.workers).max(1);
        let weight = self.sh.cfg.weight_for(tenant);
        let st = JobState::new();
        let mut s = self.sh.state.lock().unwrap();
        loop {
            if s.shutdown {
                bail!("service is shutting down; job not admitted");
            }
            if s.q.queued < self.sh.cfg.queue_depth {
                break;
            }
            match self.sh.cfg.backpressure {
                Backpressure::Reject => {
                    s.rejected += 1;
                    return Err(QueueFull { depth: self.sh.cfg.queue_depth }.into());
                }
                Backpressure::Block => s = self.sh.space.wait(s).unwrap(),
            }
        }
        s.admitted += 1;
        s.q.push(
            QueuedJob { spec, st: st.clone(), tenant: tenant.to_string(), cost, slots, sink },
            weight,
        );
        s.queue_hw = s.queue_hw.max(s.q.queued);
        drop(s);
        self.sh.work.notify_all();
        Ok(JobHandle { st, tenant: tenant.to_string() })
    }

    /// Submit a whole (datasets x implementations) sweep under `tenant`,
    /// one job per grid cell in dataset-major order. Results stream through
    /// the returned [`SuiteHandle`] as they land; `spec.threads` is ignored
    /// here (the pool's `workers` budget governs concurrency).
    pub fn submit_suite(&self, tenant: &str, spec: &SuiteSpec) -> Result<SuiteHandle> {
        ensure!(spec.cores >= 1, "SuiteSpec.cores must be at least 1 (got {})", spec.cores);
        let mut seen = std::collections::HashSet::new();
        for src in &spec.datasets {
            ensure!(
                seen.insert(src.name()),
                "duplicate dataset name '{}' in suite (dataset names must be unique)",
                src.name()
            );
        }
        let stream = SuiteSink::new();
        let mut jobs = Vec::with_capacity(spec.datasets.len() * spec.impls.len());
        for src in &spec.datasets {
            for &id in &spec.impls {
                let job = JobSpec {
                    impl_id: id,
                    dataset: src.clone(),
                    scale: spec.scale,
                    verify: spec.verify,
                    cores: spec.cores,
                    sched: spec.sched,
                };
                let idx = jobs.len();
                let h = self.submit_inner(tenant, job, Some((stream.clone(), idx)))?;
                jobs.push((id, src.name(), h));
            }
        }
        Ok(SuiteHandle {
            jobs,
            stream,
            datasets: spec.datasets.clone(),
            scale: spec.scale,
            session: self.sh.session.clone(),
            sh: self.sh.clone(),
        })
    }

    /// Stop dispatching (in-flight jobs finish; admission stays open). With
    /// the pool paused, queue state is fully deterministic — tests use this
    /// to fill the queue to an exact depth or pin the DRR order.
    pub fn pause(&self) {
        self.sh.state.lock().unwrap().paused = true;
    }

    /// Resume dispatching after [`SimService::pause`].
    pub fn resume(&self) {
        self.sh.state.lock().unwrap().paused = false;
        self.sh.work.notify_all();
    }

    /// Snapshot of the service counters.
    pub fn stats(&self) -> ServiceStats {
        self.sh.snapshot()
    }
}

impl Drop for SimService {
    fn drop(&mut self) {
        self.sh.state.lock().unwrap().shutdown = true;
        self.sh.work.notify_all();
        self.sh.space.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Workers are gone; fail the handles of jobs that never ran so no
        // waiter hangs (deterministic tenant order from drain()).
        let (abandoned, seq0) = {
            let mut s = self.sh.state.lock().unwrap();
            let jobs = s.q.drain();
            let seq0 = s.next_seq;
            s.next_seq += jobs.len() as u64;
            s.failed += jobs.len() as u64;
            (jobs, seq0)
        };
        for (i, job) in abandoned.into_iter().enumerate() {
            if let Some((sink, idx)) = &job.sink {
                sink.push(*idx, Err("service shut down before the job ran".to_string()));
            }
            job.st
                .complete(seq0 + i as u64, Err(anyhow::anyhow!("service shut down before the job ran")));
        }
    }
}

fn worker_loop(sh: &Shared) {
    let budget = sh.cfg.workers;
    let mut s = sh.state.lock().unwrap();
    loop {
        if s.shutdown {
            return;
        }
        if s.paused {
            s = sh.work.wait(s).unwrap();
            continue;
        }
        match s.q.next(s.free_slots) {
            Dispatch::Job(job) => {
                s.free_slots -= job.slots;
                s.slots_hw = s.slots_hw.max(budget - s.free_slots);
                drop(s);
                // Dispatch frees queue space: wake blocked submitters.
                sh.space.notify_all();
                let outcome = sh.session.run_with_trace_ring(&job.spec, sh.cfg.trace_ring_chunks);
                let mut s2 = sh.state.lock().unwrap();
                s2.free_slots += job.slots;
                let seq = s2.next_seq;
                s2.next_seq += 1;
                match &outcome {
                    Ok(_) => s2.completed += 1,
                    Err(_) => s2.failed += 1,
                }
                s2.q.record_served(&job.tenant);
                drop(s2);
                if let Some((sink, idx)) = &job.sink {
                    sink.push(
                        *idx,
                        match &outcome {
                            Ok(r) => Ok(r.clone()),
                            Err(e) => Err(format!("{e:#}")),
                        },
                    );
                }
                job.st.complete(seq, outcome);
                // Freed slots may unblock a WaitForSlots dispatcher.
                sh.work.notify_all();
                s = sh.state.lock().unwrap();
            }
            Dispatch::WaitForSlots | Dispatch::Empty => s = sh.work.wait(s).unwrap(),
        }
    }
}

/// Completion funnel for a streamed suite: workers push `(index, result)`
/// pairs as jobs land; the consumer pops them in completion order.
pub(crate) struct SuiteSink {
    ready: Mutex<VecDeque<(usize, Result<JobResult, String>)>>,
    cv: Condvar,
}

impl SuiteSink {
    fn new() -> Arc<SuiteSink> {
        Arc::new(SuiteSink { ready: Mutex::new(VecDeque::new()), cv: Condvar::new() })
    }

    pub(crate) fn push(&self, idx: usize, r: Result<JobResult, String>) {
        self.ready.lock().unwrap().push_back((idx, r));
        self.cv.notify_all();
    }

    fn next_blocking(&self) -> (usize, Result<JobResult, String>) {
        let mut q = self.ready.lock().unwrap();
        loop {
            if let Some(item) = q.pop_front() {
                return item;
            }
            q = self.cv.wait(q).unwrap();
        }
    }
}

/// A streamed sweep from [`SimService::submit_suite`].
///
/// Two consumption styles: [`SuiteHandle::results`] yields each job as it
/// completes (out of order, for progress bars and incremental writers), and
/// [`SuiteHandle::collect_ordered`] blocks for everything and returns the
/// classic spec-ordered [`SuiteRun`]. Both observe the same underlying
/// completions; `collect_ordered` joins the per-job handles, so it works
/// whether or not the stream was drained first.
pub struct SuiteHandle {
    /// `(impl, dataset name, handle)` in dataset-major spec order.
    jobs: Vec<(ImplId, String, JobHandle)>,
    stream: Arc<SuiteSink>,
    datasets: Vec<crate::api::DatasetSource>,
    scale: f64,
    session: Session,
    sh: Arc<Shared>,
}

impl SuiteHandle {
    /// Number of jobs in the sweep.
    pub fn total(&self) -> usize {
        self.jobs.len()
    }

    /// Stream `(spec_index, result)` pairs in completion order, blocking
    /// for each; yields exactly [`SuiteHandle::total`] items. Errors arrive
    /// as items (the iterator keeps going), so one failed cell does not
    /// hide the rest of the sweep.
    pub fn results(&self) -> impl Iterator<Item = (usize, Result<JobResult>)> + '_ {
        let total = self.jobs.len();
        let mut yielded = 0;
        std::iter::from_fn(move || {
            if yielded >= total {
                return None;
            }
            yielded += 1;
            let (idx, r) = self.stream.next_blocking();
            Some((idx, r.map_err(anyhow::Error::msg)))
        })
    }

    /// Block until every job finishes and assemble the spec-ordered
    /// [`SuiteRun`] (dataset-major results, per-dataset characterization,
    /// service counters), with `Session::run_suite`'s error aggregation.
    pub fn collect_ordered(self) -> Result<SuiteRun> {
        let mut results = Vec::with_capacity(self.jobs.len());
        let mut errv = Vec::new();
        for (id, name, h) in self.jobs {
            match h.wait() {
                Ok(r) => results.push(r),
                Err(e) => errv.push(format!("{}/{name}: {e:#}", id.name())),
            }
        }
        ensure!(errv.is_empty(), "experiment failures: {errv:?}");
        let mut dataset_stats = HashMap::new();
        for src in &self.datasets {
            dataset_stats.insert(src.name(), self.session.dataset_stats(src, self.scale)?);
        }
        Ok(SuiteRun { results, dataset_stats, service: self.sh.snapshot() })
    }

    /// Service counters (live snapshot; the final numbers also ride on the
    /// [`SuiteRun`] from [`SuiteHandle::collect_ordered`]).
    pub fn stats(&self) -> ServiceStats {
        self.sh.snapshot()
    }
}
