//! Simulation as a service: a multi-tenant job queue over one [`Session`].
//!
//! The simulator core ([`crate::api`]) is deterministic and synchronous: one
//! [`Session::run`] call simulates one job, and [`Session::run_suite`] blocks
//! on scoped threads until a whole sweep finishes. This module adds the layer
//! a production deployment needs on top of that fixed substrate — admission,
//! queueing, tenancy, and flow control — without touching the simulator:
//!
//! * [`SimService`] wraps a shared [`Session`] behind a **fixed worker pool**
//!   ([`SimServiceConfig::workers`] host threads, spawned once). A job's
//!   simulated [`cores`](crate::api::JobSpec::cores) count against the pool
//!   budget, generalizing `run_suite`'s grid-worker cap: many small jobs pack
//!   onto the pool, one wide job occupies it, and the host never sees a
//!   thread explosion (the pool's slot high-water mark is exported).
//! * [`SimService::submit`] applies **admission control**: a bounded queue of
//!   [`SimServiceConfig::queue_depth`] pending jobs, with
//!   [`Backpressure::Reject`] returning the typed [`QueueFull`] error and
//!   [`Backpressure::Block`] parking the submitter until space frees.
//! * Admitted jobs are dispatched by **deficit round robin** over per-tenant
//!   FIFOs: each tenant has a weight, each job a cost in Gustavson multiply
//!   units (the same per-row work estimates the `ws-*` schedulers use,
//!   [`Session::cached_stats`]; jobs on uncharacterized datasets fall back to
//!   [`SimServiceConfig::default_cost`]). A tenant's 10k-job burst cannot
//!   starve the others: over any backlogged window, served work per tenant
//!   tracks the weight ratios to within one quantum.
//! * [`SimService::submit`] returns a [`JobHandle`] that is both
//!   blocking-joinable ([`JobHandle::wait`]) and pollable (`JobHandle`
//!   implements [`std::future::Future`]) with **no async runtime** — a
//!   hand-rolled Condvar + waker one-shot, std-only.
//! * [`SimService::submit_suite`] streams a whole sweep: a [`SuiteHandle`]
//!   yields `JobResult`s as they land ([`SuiteHandle::results`]) or collects
//!   them spec-ordered into a [`crate::api::SuiteRun`]
//!   ([`SuiteHandle::collect_ordered`]). `Session::run_suite` itself runs on
//!   this pool, so there is one grid scheduler, not two.
//!
//! Concurrent tenants share the session's `(source, scale)` dataset/oracle
//! cache — the per-key entry locks make phase-1 builds dedupe across
//! submitters. The core contract: every [`crate::api::JobResult`] produced
//! through the service is **byte-identical** (stable JSON, `wall_secs`
//! stripped) to [`Session::run`] of the same spec, regardless of queue
//! interleaving, pool size, or co-tenants — the queue owns *when* a job
//! runs, never *what* it computes.
//!
//! ```no_run
//! # fn main() -> anyhow::Result<()> {
//! use sparsezipper::api::{DatasetSource, ImplId, JobSpec, Session};
//! use sparsezipper::service::{SimService, SimServiceConfig};
//!
//! let svc = SimService::start(Session::new(), SimServiceConfig::default())?;
//! let job = JobSpec::new(ImplId::Spz, DatasetSource::registry("p2p")?).with_scale(0.05);
//! let handle = svc.submit("tenant-a", job)?;
//! let result = handle.wait()?; // or `handle.await` from any executor
//! println!("{:.0} cycles", result.time_cycles());
//! # Ok(())
//! # }
//! ```

mod handle;
mod queue;
#[allow(clippy::module_inception)]
mod service;

pub use handle::JobHandle;
pub use service::{
    Backpressure, QueueFull, ServiceStats, SimService, SimServiceConfig, SuiteHandle, TenantStats,
};

#[cfg(doc)]
use crate::api::Session;
