//! The one-shot job completion cell behind [`JobHandle`]: blocking-joinable
//! *and* pollable with no async-runtime dependency. A `Condvar` serves
//! `wait()`; a stored-waker list serves `Future::poll` — both observe the
//! same `Mutex`-guarded slot, so whichever consumer arrives first takes the
//! result.

use crate::api::JobResult;
use anyhow::{bail, Result};
use std::future::Future;
use std::pin::Pin;
use std::sync::{Arc, Condvar, Mutex};
use std::task::{Context, Poll, Waker};

#[derive(Default)]
struct Slot {
    /// Filled exactly once by the worker that ran the job.
    outcome: Option<Result<JobResult>>,
    /// Whether the (single) consumer already took the outcome.
    taken: bool,
    /// Global completion sequence number, stamped when the outcome lands
    /// (the dispatch order the DRR scheduler chose, observable for tests
    /// and fairness reports).
    seq: Option<u64>,
    /// Wakers registered by `Future::poll` before completion.
    wakers: Vec<Waker>,
}

/// Shared completion state: the worker side of a [`JobHandle`].
pub(crate) struct JobState {
    slot: Mutex<Slot>,
    cv: Condvar,
}

impl JobState {
    pub(crate) fn new() -> Arc<JobState> {
        Arc::new(JobState { slot: Mutex::new(Slot::default()), cv: Condvar::new() })
    }

    /// Publish the job's outcome (exactly once): wakes blocking waiters and
    /// every registered async waker.
    pub(crate) fn complete(&self, seq: u64, outcome: Result<JobResult>) {
        let wakers = {
            let mut s = self.slot.lock().unwrap();
            debug_assert!(s.outcome.is_none() && !s.taken, "job completed twice");
            s.outcome = Some(outcome);
            s.seq = Some(seq);
            std::mem::take(&mut s.wakers)
        };
        self.cv.notify_all();
        for w in wakers {
            w.wake();
        }
    }
}

/// A submitted job: join it with [`JobHandle::wait`] (blocking) or `.await`
/// it (it implements [`Future`] via a hand-rolled waker state machine —
/// std-only, usable from any executor). The handle is the single consumer of
/// the result; dropping it abandons the result but never cancels the job.
pub struct JobHandle {
    pub(crate) st: Arc<JobState>,
    pub(crate) tenant: String,
}

impl JobHandle {
    /// The tenant this job was submitted under.
    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    /// Whether the job has finished (its outcome landed or was consumed).
    pub fn is_finished(&self) -> bool {
        let s = self.st.slot.lock().unwrap();
        s.outcome.is_some() || s.taken
    }

    /// The global completion sequence number, once finished: the order in
    /// which the service completed jobs (deterministic on a 1-worker pool,
    /// where it equals the DRR dispatch order).
    pub fn completion_seq(&self) -> Option<u64> {
        self.st.slot.lock().unwrap().seq
    }

    /// Block until the job finishes and take its result. Errors if the
    /// result was already consumed through `poll`.
    pub fn wait(self) -> Result<JobResult> {
        let mut s = self.st.slot.lock().unwrap();
        loop {
            if let Some(out) = s.outcome.take() {
                s.taken = true;
                return out;
            }
            if s.taken {
                bail!("job result already taken (the handle was polled to completion)");
            }
            s = self.st.cv.wait(s).unwrap();
        }
    }
}

impl Future for JobHandle {
    type Output = Result<JobResult>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        let mut s = self.st.slot.lock().unwrap();
        if let Some(out) = s.outcome.take() {
            s.taken = true;
            return Poll::Ready(out);
        }
        if s.taken {
            // Futures contract: a future must not be polled after Ready.
            panic!("JobHandle polled after completion");
        }
        if !s.wakers.iter().any(|w| w.will_wake(cx.waker())) {
            s.wakers.push(cx.waker().clone());
        }
        Poll::Pending
    }
}

impl std::fmt::Debug for JobHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("JobHandle")
            .field("tenant", &self.tenant)
            .field("finished", &self.is_finished())
            .finish()
    }
}
