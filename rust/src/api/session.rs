//! The long-lived [`Session`]: owns the engine selection, the XLA artifact
//! location, the [`SystemConfig`], and a dataset cache keyed by
//! `(source, scale)` that memoizes built matrices, their Table III
//! characterization, and reference products across jobs.

use crate::api::spec::{DatasetKey, DatasetSource, JobSpec, SuiteSpec};
use crate::config::SystemConfig;
use crate::matrix::{stats, Csr, MatrixStats};
use crate::runtime::{client, Engine};
use crate::service::{Backpressure, ServiceStats, SimService, SimServiceConfig};
use crate::sim::{Machine, MulticoreMetrics, RunMetrics};
use crate::spgemm::parallel::{self, Scheduler};
use crate::spgemm::{self, ImplId, SpGemm};
use anyhow::{ensure, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Session-level configuration (what used to be scattered over
/// `SuiteConfig` and free-function arguments).
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// Functional datapath for the spz variants.
    pub engine: Engine,
    /// Directory holding the AOT HLO artifacts (xla engine only).
    pub artifact_dir: PathBuf,
    /// Simulated system (Table II).
    pub sys: SystemConfig,
    /// Upper bound on cached `(source, scale)` entries. `None` (the
    /// default, and the pre-existing behaviour) keeps the cache unbounded;
    /// `Some(cap)` evicts the least-recently-used entries once the cache
    /// would exceed `cap`, so long-lived services streaming many distinct
    /// datasets stop growing without manual `evict`/`clear_cache` calls.
    /// The entry being accessed is never the victim (an effective floor of
    /// one).
    pub max_cached_datasets: Option<usize>,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            engine: Engine::Native,
            artifact_dir: client::artifact_dir(),
            sys: SystemConfig::default(),
            max_cached_datasets: None,
        }
    }
}

#[derive(Default)]
struct CacheEntry {
    csr: Option<Arc<Csr>>,
    stats: Option<MatrixStats>,
    reference: Option<Arc<Csr>>,
}

/// One lock per cache key: the outer map lock is held only long enough to
/// fetch the entry handle, and the expensive build/characterize/reference
/// work happens under the entry lock — concurrent callers on the *same*
/// `(source, scale)` serialize (second one finds the cached value) while
/// different datasets proceed in parallel.
type SharedEntry = Arc<Mutex<CacheEntry>>;

/// A long-lived SpGEMM-simulation service handle.
///
/// All experiment entry points hang off a `Session`:
/// [`Session::run`] for one [`JobSpec`], [`Session::run_suite`] for a
/// [`SuiteSpec`] sweep, and [`Session::spgemm`] for a general A*B product on
/// caller-owned matrices. Datasets, their characterization, and reference
/// products are built at most once per `(source, scale)` and shared across
/// jobs; `&Session` is `Sync`, so one session can serve concurrent callers.
///
/// A `Session` is a cheap shared handle (`Clone` bumps an `Arc`): every
/// clone sees the same caches and counters. That is what lets the
/// [`crate::service::SimService`] worker pool, `run_suite`, and an
/// embedding application all drive one session concurrently.
#[derive(Clone)]
pub struct Session {
    inner: Arc<SessionInner>,
}

struct SessionInner {
    cfg: SessionConfig,
    /// Entry handle plus its last-use tick (for LRU eviction when
    /// [`SessionConfig::max_cached_datasets`] caps the cache).
    cache: Mutex<HashMap<DatasetKey, (SharedEntry, u64)>>,
    cache_tick: AtomicU64,
    cache_evictions: AtomicU64,
    dataset_builds: AtomicU64,
    reference_builds: AtomicU64,
}

/// ESC block sizes the per-matrix vec-radix sweep tries (§V-B), shared by
/// the serial and multi-core execution paths so they can never drift.
const VEC_RADIX_BLOCK_SWEEP: [usize; 3] = [4 * 1024, 16 * 1024, 64 * 1024];

/// A general product from [`Session::spgemm`].
#[derive(Clone, Debug)]
pub struct Product {
    pub csr: Csr,
    pub metrics: RunMetrics,
}

/// Result of one simulated job.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub impl_id: ImplId,
    pub dataset: String,
    /// Single-core metrics, or the element-wise totals over cores for
    /// multi-core jobs (counts stay exact and additive; cycles become
    /// aggregate core-cycles — see [`JobResult::time_cycles`]).
    pub metrics: RunMetrics,
    pub out_nnz: usize,
    pub verified: bool,
    /// Host wall-clock seconds for the simulation itself (§Perf data).
    pub wall_secs: f64,
    /// Block size chosen for vec-radix (after the sweep), if applicable.
    pub block_elems: Option<usize>,
    /// Simulated cores the job ran on (1 = serial loop).
    pub cores: usize,
    /// Row-block scheduler (multi-core jobs only).
    pub sched: Option<Scheduler>,
    /// Per-core breakdown + critical path (multi-core jobs only).
    pub multicore: Option<MulticoreMetrics>,
    /// `ws-adapt`'s per-block decision summary (kernel swap / split counts
    /// and predicted-vs-achieved stalls); `None` under fixed schedulers.
    pub sched_decisions: Option<parallel::SchedDecisions>,
}

impl JobResult {
    /// Simulated wall-clock cycles: the multi-core critical path when
    /// present, the single core's cycles otherwise. This is the number to
    /// compare across core counts (fig12); `metrics.cycles` sums over cores.
    pub fn time_cycles(&self) -> f64 {
        self.multicore
            .as_ref()
            .map(|m| m.critical_path_cycles)
            .unwrap_or(self.metrics.cycles)
    }
}

/// All results of a sweep, with the per-dataset Table III characterization.
#[derive(Debug, Default)]
pub struct SuiteRun {
    /// Dataset-major, implementation-minor, in the spec's order.
    pub results: Vec<JobResult>,
    pub dataset_stats: HashMap<String, MatrixStats>,
    /// Counters of the service pool the sweep ran on (admission, fairness,
    /// and the slots high-water witness that the host was never
    /// oversubscribed). Exported as the `service` block of the stable JSON.
    pub service: ServiceStats,
}

impl SuiteRun {
    pub fn get(&self, id: ImplId, dataset: &str) -> Option<&JobResult> {
        self.results
            .iter()
            .find(|r| r.impl_id == id && r.dataset == dataset)
    }

    /// Speedup of `num` over `den` on `dataset`: ratio of simulated
    /// wall-clock cycles ([`JobResult::time_cycles`] — the multi-core
    /// critical path when jobs ran on several cores, plain cycles otherwise).
    pub fn speedup(&self, num: ImplId, den: ImplId, dataset: &str) -> Option<f64> {
        let n = self.get(num, dataset)?;
        let d = self.get(den, dataset)?;
        Some(d.time_cycles() / n.time_cycles())
    }
}

impl Session {
    /// A session with the default configuration (native engine).
    pub fn new() -> Self {
        Session::with_config(SessionConfig::default())
    }

    pub fn with_config(cfg: SessionConfig) -> Self {
        Session {
            inner: Arc::new(SessionInner {
                cfg,
                cache: Mutex::new(HashMap::new()),
                cache_tick: AtomicU64::new(0),
                cache_evictions: AtomicU64::new(0),
                dataset_builds: AtomicU64::new(0),
                reference_builds: AtomicU64::new(0),
            }),
        }
    }

    pub fn engine(&self) -> Engine {
        self.inner.cfg.engine
    }

    pub fn system(&self) -> &SystemConfig {
        &self.inner.cfg.sys
    }

    /// How many datasets were materialized (cache misses) so far.
    pub fn dataset_builds(&self) -> u64 {
        self.inner.dataset_builds.load(Ordering::Relaxed)
    }

    /// How many reference products were computed (cache misses) so far.
    pub fn reference_builds(&self) -> u64 {
        self.inner.reference_builds.load(Ordering::Relaxed)
    }

    /// Number of cached `(source, scale)` entries currently held.
    pub fn cached_datasets(&self) -> usize {
        self.inner.cache.lock().unwrap().len()
    }

    /// How many entries the LRU cap has evicted so far (0 when unbounded).
    pub fn cache_evictions(&self) -> u64 {
        self.inner.cache_evictions.load(Ordering::Relaxed)
    }

    /// Evict one `(source, scale)` entry, dropping its matrix, stats, and
    /// reference product (and releasing any in-memory `Arc` it pinned).
    /// Returns whether an entry existed. In-flight builds on the entry
    /// finish on their own handle and are simply not cached.
    pub fn evict(&self, src: &DatasetSource, scale: f64) -> bool {
        self.inner.cache.lock().unwrap().remove(&src.cache_key(scale)).is_some()
    }

    /// Drop every cached entry. By default the cache is unbounded (suites
    /// revisit datasets); set [`SessionConfig::max_cached_datasets`] to make
    /// the session evict least-recently-used entries automatically instead.
    /// Build counters are not reset.
    pub fn clear_cache(&self) {
        self.inner.cache.lock().unwrap().clear();
    }

    /// The per-key entry handle (creating it if absent), bumping its LRU
    /// tick and applying the cache cap; the map lock is released before any
    /// expensive work starts. Evicting an entry another thread is still
    /// building is safe: the builder keeps its own `Arc` handle and simply
    /// is no longer cached.
    fn entry(&self, key: DatasetKey) -> SharedEntry {
        let mut map = self.inner.cache.lock().unwrap();
        let tick = self.inner.cache_tick.fetch_add(1, Ordering::Relaxed) + 1;
        let handle = {
            let slot = map.entry(key.clone()).or_default();
            slot.1 = tick;
            slot.0.clone()
        };
        if let Some(cap) = self.inner.cfg.max_cached_datasets {
            while map.len() > cap.max(1) {
                // LRU victim, never the entry this caller just touched.
                let mut victim: Option<(DatasetKey, u64)> = None;
                for (k, v) in map.iter() {
                    if *k == key {
                        continue;
                    }
                    if victim.as_ref().map(|(_, t)| v.1 < *t).unwrap_or(true) {
                        victim = Some((k.clone(), v.1));
                    }
                }
                match victim {
                    Some((v, _)) => {
                        map.remove(&v);
                        self.inner.cache_evictions.fetch_add(1, Ordering::Relaxed);
                    }
                    None => break,
                }
            }
        }
        handle
    }

    /// Build-or-fetch the matrix with the entry lock held, so racing
    /// callers on one key cannot both build.
    fn csr_locked(
        &self,
        src: &DatasetSource,
        scale: f64,
        e: &mut CacheEntry,
    ) -> Result<Arc<Csr>> {
        if let Some(c) = &e.csr {
            return Ok(c.clone());
        }
        let built = src
            .build(scale)
            .with_context(|| format!("build dataset '{}'", src.name()))?;
        self.inner.dataset_builds.fetch_add(1, Ordering::Relaxed);
        e.csr = Some(built.clone());
        Ok(built)
    }

    /// Drop the map entry again if a failed build left it empty, so retries
    /// against bad sources don't accumulate dead placeholders. Removes only
    /// the exact entry this caller holds (a racing retry may already have
    /// replaced the slot with a successfully-populated one). (Safe lock
    /// order: nothing takes an entry lock while holding the map lock.)
    fn forget_if_empty(&self, key: &DatasetKey, entry: &SharedEntry, e: &CacheEntry) {
        if e.csr.is_none() && e.stats.is_none() && e.reference.is_none() {
            let mut map = self.inner.cache.lock().unwrap();
            if map.get(key).is_some_and(|(cur, _)| Arc::ptr_eq(cur, entry)) {
                map.remove(key);
            }
        }
    }

    /// The matrix for `(source, scale)`, built at most once per session —
    /// including under concurrent callers (they serialize on this key).
    pub fn dataset(&self, src: &DatasetSource, scale: f64) -> Result<Arc<Csr>> {
        let key = src.cache_key(scale);
        let entry = self.entry(key.clone());
        let mut e = entry.lock().unwrap();
        match self.csr_locked(src, scale, &mut e) {
            Ok(c) => Ok(c),
            Err(err) => {
                self.forget_if_empty(&key, &entry, &e);
                Err(err)
            }
        }
    }

    /// Table III characterization for `(source, scale)`, memoized.
    pub fn dataset_stats(&self, src: &DatasetSource, scale: f64) -> Result<MatrixStats> {
        let key = src.cache_key(scale);
        let entry = self.entry(key.clone());
        let mut e = entry.lock().unwrap();
        if let Some(st) = &e.stats {
            return Ok(st.clone());
        }
        let a = match self.csr_locked(src, scale, &mut e) {
            Ok(a) => a,
            Err(err) => {
                self.forget_if_empty(&key, &entry, &e);
                return Err(err);
            }
        };
        let st = stats::characterize(&a, 16);
        e.stats = Some(st.clone());
        Ok(st)
    }

    /// Non-blocking peek at an already-cached characterization for
    /// `(source, scale)`: `None` if the entry is absent, not yet
    /// characterized, or momentarily locked by a builder. Never builds
    /// anything and never bumps the LRU tick — the admission path of
    /// [`crate::service::SimService`] uses this to price jobs without
    /// stalling `submit` behind a dataset build.
    pub fn cached_stats(&self, src: &DatasetSource, scale: f64) -> Option<MatrixStats> {
        let entry = {
            let map = self.inner.cache.lock().unwrap();
            map.get(&src.cache_key(scale))?.0.clone()
        };
        let e = entry.try_lock().ok()?;
        e.stats.clone()
    }

    /// The reference product A*A for `(source, scale)`, memoized (the
    /// oracle all verified jobs on this dataset share), computed at most
    /// once even under concurrent callers.
    pub fn reference_product(&self, src: &DatasetSource, scale: f64) -> Result<Arc<Csr>> {
        let key = src.cache_key(scale);
        let entry = self.entry(key.clone());
        let mut e = entry.lock().unwrap();
        if let Some(r) = &e.reference {
            return Ok(r.clone());
        }
        let a = match self.csr_locked(src, scale, &mut e) {
            Ok(a) => a,
            Err(err) => {
                self.forget_if_empty(&key, &entry, &e);
                return Err(err);
            }
        };
        ensure!(
            a.nrows == a.ncols,
            "dataset '{}' is {}x{}, but the reference oracle computes A*A; use \
             Session::spgemm for rectangular products",
            src.name(),
            a.nrows,
            a.ncols
        );
        let reference = Arc::new(spgemm::reference(&a, &a));
        self.inner.reference_builds.fetch_add(1, Ordering::Relaxed);
        e.reference = Some(reference.clone());
        Ok(reference)
    }

    /// General SpGEMM on caller-owned matrices: C = A*B under the cycle
    /// model, with this session's engine and system configuration.
    ///
    /// Unlike [`Session::run`], `ImplId::VecRadix` uses its default ESC
    /// block size here — the paper's per-matrix block-size sweep is an
    /// evaluation-pipeline concern and only happens for A*A jobs.
    ///
    /// The job owns the core count in this API: serial entry points always
    /// price as a single active core (`sys.cores` is normalized to 1 here
    /// and to [`crate::api::JobSpec::cores`] in `run`/`run_suite`), so a
    /// `SessionConfig` carrying `sys.cores > 1` never charges idle-core
    /// contention to a serial run.
    pub fn spgemm(&self, id: ImplId, a: &Csr, b: &Csr) -> Result<Product> {
        ensure!(
            a.ncols == b.nrows,
            "dimension mismatch: ({}x{}) * ({}x{})",
            a.nrows,
            a.ncols,
            b.nrows,
            b.ncols
        );
        let mut sys = self.inner.cfg.sys;
        sys.cores = 1;
        let mut machine = Machine::new(sys);
        let mut im = id.instantiate(self.inner.cfg.engine, &self.inner.cfg.artifact_dir)?;
        let csr = im
            .multiply(&mut machine, a, b)
            .with_context(|| format!("{} product", id.name()))?;
        Ok(Product { csr, metrics: machine.metrics() })
    }

    /// Run one job (A*A on the job's dataset), reusing the session caches.
    /// `job.cores >= 2` runs the row-blocked multi-core driver
    /// ([`crate::spgemm::parallel`]) and fills [`JobResult::multicore`].
    pub fn run(&self, job: &JobSpec) -> Result<JobResult> {
        self.run_with_trace_ring(job, 0)
    }

    /// [`Session::run`] with a per-job trace-ring budget. `ring == 0`
    /// inherits the session's configured
    /// [`crate::config::SharedMemConfig::trace_ring_chunks`]; a nonzero
    /// `ring` replaces it for this job only, so a service hosting many
    /// concurrent jobs can bound each job's resident trace footprint to
    /// `cores * ring * 64KB` regardless of what the session was built with.
    /// The override is a pure memory knob: results are bit-identical at
    /// every ring size (overflow chunks spill to disk and the stable JSON
    /// zeroes the ring-dependent counters).
    pub fn run_with_trace_ring(&self, job: &JobSpec, ring: usize) -> Result<JobResult> {
        ensure!(
            job.cores >= 1,
            "JobSpec.cores must be at least 1 (got {})",
            job.cores
        );
        ensure!(
            ring != 1,
            "trace-ring override must be 0 (inherit) or at least 2 (got 1)"
        );
        let a = self.dataset(&job.dataset, job.scale)?;
        let reference = if job.verify {
            Some(self.reference_product(&job.dataset, job.scale)?)
        } else {
            None
        };
        let mut sys = self.inner.cfg.sys;
        if ring != 0 {
            sys.shared.trace_ring_chunks = ring;
        }
        self.execute(
            &sys,
            job.impl_id,
            &job.dataset.name(),
            &a,
            reference.as_deref(),
            job.cores,
            job.sched,
        )
    }

    /// Run a (datasets x implementations) sweep on a service worker pool.
    ///
    /// Phase 1 builds datasets (plus stats and, when verifying, reference
    /// products) through the cache with a work-stealing index loop — one
    /// slow dataset never idles the pool. Phase 2 submits the grid to a
    /// private [`crate::service::SimService`] pool of `threads` core-slots
    /// and collects spec-ordered — the same scheduler multi-tenant callers
    /// get, so there is exactly one grid scheduler in the crate. Simulations
    /// are independent (one `Machine` each), so the parallelism does not
    /// perturb the simulated metrics.
    pub fn run_suite(&self, spec: &SuiteSpec) -> Result<SuiteRun> {
        anyhow::ensure!(
            spec.cores >= 1,
            "SuiteSpec.cores must be at least 1 (got {})",
            spec.cores
        );
        anyhow::ensure!(
            spec.threads >= 1,
            "SuiteSpec.threads must be at least 1 (got {})",
            spec.threads
        );
        let threads = spec.threads;

        // Results and stats are keyed by display name; two different
        // sources with one name would silently collide in `SuiteRun`.
        let mut seen = std::collections::HashSet::new();
        for src in &spec.datasets {
            anyhow::ensure!(
                seen.insert(src.name()),
                "duplicate dataset name '{}' in suite (dataset names must be unique)",
                src.name()
            );
        }

        // Reference oracles are only worth building if jobs will verify
        // against them (table3 runs with no implementations at all).
        let want_reference = spec.verify && !spec.impls.is_empty();

        // Phase 1: materialize datasets (work-stealing across datasets).
        let errs: Mutex<Vec<String>> = Mutex::new(Vec::new());
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..threads.min(spec.datasets.len()) {
                let errs = &errs;
                let next = &next;
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= spec.datasets.len() {
                        break;
                    }
                    let src = &spec.datasets[i];
                    let prepared = self
                        .dataset_stats(src, spec.scale)
                        .map(|_| ())
                        .and_then(|()| {
                            if want_reference {
                                self.reference_product(src, spec.scale).map(|_| ())
                            } else {
                                Ok(())
                            }
                        });
                    if let Err(e) = prepared {
                        errs.lock().unwrap().push(format!("{}: {e:#}", src.name()));
                    }
                });
            }
        });
        let errv = errs.into_inner().unwrap();
        anyhow::ensure!(errv.is_empty(), "dataset build failures: {errv:?}");

        // Phase 2: submit the grid (dataset-major job order) to a private
        // pool of `threads` core-slots. A job's simulated `cores` count
        // against the budget, so the host sees ~`threads` busy threads
        // total — the service generalization of the old grid-worker cap.
        // Every dataset was characterized in phase 1, so DRR prices each
        // job with its real Gustavson work estimate.
        let njobs = spec.datasets.len() * spec.impls.len();
        let svc = SimService::start(
            self.clone(),
            SimServiceConfig {
                workers: threads,
                queue_depth: njobs.max(1),
                backpressure: Backpressure::Block,
                ..SimServiceConfig::default()
            },
        )?;
        svc.submit_suite("suite", spec)?.collect_ordered()
    }

    /// One simulated run of `id` on `a * a`, verifying against `verify`
    /// when given. vec-radix sweeps the ESC block size per matrix and keeps
    /// the best configuration, as in the paper (§V-B). The implementation
    /// (and, under `Engine::Xla`, its compiled artifacts) is instantiated
    /// per job: `ZipUnit` is `&mut`-stateful, so jobs running on parallel
    /// workers cannot share one engine.
    ///
    /// `cores >= 2` runs the row-blocked multi-core driver instead of the
    /// serial loop; the vec-radix block sweep then picks the configuration
    /// with the shortest *critical path*. Every scheduler (including the
    /// pilot-replay-driven `ws-bw`) is a pure function of the inputs, so
    /// repeated jobs on one session are bit-reproducible even though the
    /// grid itself runs on work-stealing host threads.
    #[allow(clippy::too_many_arguments)]
    fn execute(
        &self,
        sys: &SystemConfig,
        id: ImplId,
        dataset: &str,
        a: &Csr,
        verify: Option<&Csr>,
        cores: usize,
        sched: Scheduler,
    ) -> Result<JobResult> {
        let t0 = Instant::now();
        let mut block = None;
        ensure!(
            a.nrows == a.ncols,
            "dataset '{dataset}' is {}x{}, but jobs compute A*A; use Session::spgemm for \
             rectangular products",
            a.nrows,
            a.ncols
        );

        let (metrics, multicore, product, sched_decisions) = if cores > 1 {
            let pcfg = parallel::ParallelConfig {
                cores,
                scheduler: sched,
                block_rows: None,
                impl_id: Some(id),
            };
            let run = if id == ImplId::VecRadix {
                let mut best: Option<(parallel::ParallelRun, usize)> = None;
                for be in VEC_RADIX_BLOCK_SWEEP {
                    let r = parallel::row_blocked(
                        sys,
                        move || {
                            Ok(Box::new(spgemm::vec_radix::VecRadix { block_elems: be })
                                as Box<dyn SpGemm>)
                        },
                        a,
                        a,
                        &pcfg,
                    )
                    .with_context(|| format!("vec-radix block={be}"))?;
                    let better = best
                        .as_ref()
                        .map(|(b, _)| {
                            r.metrics.critical_path_cycles < b.metrics.critical_path_cycles
                        })
                        .unwrap_or(true);
                    if better {
                        best = Some((r, be));
                    }
                }
                let (r, be) = best.unwrap();
                block = Some(be);
                r
            } else {
                parallel::row_blocked(
                    sys,
                    || id.instantiate(self.inner.cfg.engine, &self.inner.cfg.artifact_dir),
                    a,
                    a,
                    &pcfg,
                )
                .with_context(|| format!("{} on {dataset} ({cores} cores)", id.name()))?
            };
            let parallel::ParallelRun { csr, metrics: mc, decisions, .. } = run;
            (mc.total.clone(), Some(mc), csr, decisions)
        } else if id == ImplId::VecRadix {
            let mut best: Option<(RunMetrics, Csr, usize)> = None;
            let mut serial_sys = *sys;
            serial_sys.cores = 1;
            for be in VEC_RADIX_BLOCK_SWEEP {
                let mut m = Machine::new(serial_sys);
                let mut im = spgemm::vec_radix::VecRadix { block_elems: be };
                let c = im
                    .multiply(&mut m, a, a)
                    .with_context(|| format!("vec-radix block={be}"))?;
                let met = m.metrics();
                if best.as_ref().map(|(b, _, _)| met.cycles < b.cycles).unwrap_or(true) {
                    best = Some((met, c, be));
                }
            }
            let (met, c, be) = best.unwrap();
            block = Some(be);
            (met, None, c, None)
        } else {
            let p = self
                .spgemm(id, a, a)
                .with_context(|| format!("{} on {dataset}", id.name()))?;
            (p.metrics, None, p.csr, None)
        };

        let verified = match verify {
            Some(r) => {
                ensure!(
                    spgemm::same_product(&product, r, 1e-2),
                    "{} on {dataset}: product mismatch ({} vs {} nnz)",
                    id.name(),
                    product.nnz(),
                    r.nnz()
                );
                true
            }
            None => false,
        };

        Ok(JobResult {
            impl_id: id,
            dataset: dataset.to_string(),
            out_nnz: product.nnz(),
            metrics,
            verified,
            wall_secs: t0.elapsed().as_secs_f64(),
            block_elems: block,
            cores: cores.max(1),
            sched: if cores > 1 { Some(sched) } else { None },
            multicore,
            sched_decisions,
        })
    }
}

impl Default for Session {
    fn default() -> Self {
        Session::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;

    #[test]
    fn small_suite_runs_and_verifies() {
        let session = Session::new();
        let spec = SuiteSpec {
            datasets: vec![
                DatasetSource::registry("p2p").unwrap(),
                DatasetSource::registry("m133-b3").unwrap(),
            ],
            impls: vec![ImplId::SclHash, ImplId::Spz],
            scale: 0.01,
            threads: 2,
            verify: true,
            ..SuiteSpec::default()
        };
        let r = session.run_suite(&spec).unwrap();
        assert_eq!(r.results.len(), 4);
        assert!(r.results.iter().all(|x| x.verified));
        assert!(r.results.iter().all(|x| x.cores == 1 && x.multicore.is_none()));
        assert!(r.speedup(ImplId::Spz, ImplId::SclHash, "p2p").unwrap() > 0.0);
        assert!(r.dataset_stats.contains_key("m133-b3"));
        // Everything went through the cache exactly once per dataset.
        assert_eq!(session.dataset_builds(), 2);
        assert_eq!(session.reference_builds(), 2);
    }

    #[test]
    fn suite_results_are_in_spec_order() {
        let session = Session::new();
        let spec = SuiteSpec {
            datasets: vec![
                DatasetSource::registry("m133-b3").unwrap(),
                DatasetSource::registry("p2p").unwrap(),
            ],
            impls: vec![ImplId::Spz, ImplId::SclHash],
            scale: 0.01,
            threads: 4,
            verify: false,
            ..SuiteSpec::default()
        };
        let r = session.run_suite(&spec).unwrap();
        let order: Vec<(String, ImplId)> = r
            .results
            .iter()
            .map(|x| (x.dataset.clone(), x.impl_id))
            .collect();
        assert_eq!(
            order,
            vec![
                ("m133-b3".to_string(), ImplId::Spz),
                ("m133-b3".to_string(), ImplId::SclHash),
                ("p2p".to_string(), ImplId::Spz),
                ("p2p".to_string(), ImplId::SclHash),
            ]
        );
    }

    #[test]
    fn concurrent_jobs_on_one_key_build_once() {
        let session = Session::new();
        let src = DatasetSource::registry("p2p").unwrap();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let session = &session;
                let src = src.clone();
                s.spawn(move || {
                    session
                        .run(&JobSpec::new(ImplId::SclHash, src).with_scale(0.01).with_verify(true))
                        .unwrap();
                });
            }
        });
        assert_eq!(session.dataset_builds(), 1);
        assert_eq!(session.reference_builds(), 1);
    }

    #[test]
    fn run_verifies_every_impl() {
        let a = Arc::new(gen::erdos_renyi(60, 60, 300, 81));
        let session = Session::new();
        let src = DatasetSource::in_memory("er60", a);
        let oracle = session.reference_product(&src, 1.0).unwrap();
        for id in ImplId::ALL {
            let res = session
                .run(&JobSpec::new(id, src.clone()).with_verify(true))
                .unwrap();
            assert!(res.verified, "{}", id.name());
            assert!(res.metrics.cycles > 0.0, "{}", id.name());
            assert_eq!(res.out_nnz, oracle.nnz(), "{}", id.name());
        }
        // One dataset materialization, one oracle, five verified jobs.
        assert_eq!(session.dataset_builds(), 1);
        assert_eq!(session.reference_builds(), 1);
    }

    #[test]
    fn vec_radix_reports_block() {
        let a = Arc::new(gen::erdos_renyi(60, 60, 300, 82));
        let session = Session::new();
        let res = session
            .run(&JobSpec::new(
                ImplId::VecRadix,
                DatasetSource::in_memory("er60b", a),
            ))
            .unwrap();
        assert!(res.block_elems.is_some());
    }

    #[test]
    fn multicore_job_verifies_and_reports_per_core_metrics() {
        let a = Arc::new(gen::rmat(128, 128, 1000, 0.6, 0.18, 0.14, 83));
        let session = Session::new();
        let src = DatasetSource::in_memory("er-mc", a);
        let serial = session
            .run(&JobSpec::new(ImplId::Spz, src.clone()).with_verify(true))
            .unwrap();
        let par = session
            .run(&JobSpec::new(ImplId::Spz, src).with_verify(true).with_cores(4))
            .unwrap();
        assert!(par.verified);
        assert_eq!(par.cores, 4);
        assert_eq!(par.sched, Some(Scheduler::WorkStealing));
        let mc = par.multicore.as_ref().expect("multicore metrics");
        assert_eq!(mc.cores(), 4);
        // Exact event-count additivity vs the serial run (16-aligned blocks).
        assert_eq!(mc.total.ops, serial.metrics.ops);
        // The critical path is the effective time and beats the serial run.
        assert!(par.time_cycles() <= serial.time_cycles());
        assert_eq!(par.out_nnz, serial.out_nnz);
    }

    #[test]
    fn bounded_cache_evicts_least_recently_used() {
        let session = Session::with_config(SessionConfig {
            max_cached_datasets: Some(2),
            ..SessionConfig::default()
        });
        let a = DatasetSource::registry("p2p").unwrap();
        let b = DatasetSource::registry("m133-b3").unwrap();
        let c = DatasetSource::registry("wiki").unwrap();
        session.dataset(&a, 0.005).unwrap();
        session.dataset(&b, 0.005).unwrap();
        assert_eq!(session.cached_datasets(), 2);
        assert_eq!(session.cache_evictions(), 0);
        // Touch `a` so `b` becomes the LRU victim when `c` arrives.
        session.dataset(&a, 0.005).unwrap();
        session.dataset(&c, 0.005).unwrap();
        assert_eq!(session.cached_datasets(), 2);
        assert_eq!(session.cache_evictions(), 1);
        assert_eq!(session.dataset_builds(), 3);
        // `a` survived (no rebuild); `b` was evicted (rebuilds).
        session.dataset(&a, 0.005).unwrap();
        assert_eq!(session.dataset_builds(), 3, "recently-used entry must survive");
        session.dataset(&b, 0.005).unwrap();
        assert_eq!(session.dataset_builds(), 4, "LRU entry must have been evicted");
    }

    #[test]
    fn unbounded_cache_is_backwards_compatible() {
        let session = Session::new();
        for name in ["p2p", "m133-b3", "wiki"] {
            let src = DatasetSource::registry(name).unwrap();
            session.dataset(&src, 0.005).unwrap();
        }
        assert_eq!(session.cached_datasets(), 3);
        assert_eq!(session.cache_evictions(), 0);
    }

    #[test]
    fn cache_cap_never_evicts_the_active_entry() {
        let session = Session::with_config(SessionConfig {
            max_cached_datasets: Some(0),
            ..SessionConfig::default()
        });
        let a = DatasetSource::registry("p2p").unwrap();
        session.dataset(&a, 0.005).unwrap();
        // Cap 0 behaves as cap 1: the entry being touched stays cached.
        assert_eq!(session.cached_datasets(), 1);
        session.dataset(&a, 0.005).unwrap();
        assert_eq!(session.dataset_builds(), 1);
    }

    #[test]
    fn spgemm_matches_reference_on_rectangular_product() {
        let a = gen::erdos_renyi(30, 50, 200, 11);
        let b = gen::erdos_renyi(50, 20, 180, 12);
        let session = Session::new();
        let p = session.spgemm(ImplId::Spz, &a, &b).unwrap();
        assert!(spgemm::same_product(&p.csr, &spgemm::reference(&a, &b), 1e-3));
        assert!(p.metrics.cycles > 0.0);
    }
}
