//! Stable JSON export for the typed results (the offline vendor set has no
//! serde, so this is a small purpose-built emitter). The schema is part of
//! the public API: downstream tooling parses these objects, so field names
//! only ever grow — they do not change meaning.

use crate::api::session::{JobResult, SuiteRun};
use crate::matrix::MatrixStats;
use crate::mem::SharedStats;
use crate::service::ServiceStats;
use crate::sim::machine::{NUM_PHASES, PHASE_NAMES};
use crate::sim::{MulticoreMetrics, RunMetrics};
use std::fmt::Write as _;

/// Escape a string for a JSON string literal (without the quotes).
pub(crate) fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// JSON has no NaN/inf; map them to null.
fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

fn phases_json(phase_cycles: &[f64; NUM_PHASES]) -> String {
    let mut phases = String::from("{");
    for p in 0..NUM_PHASES {
        if p > 0 {
            phases.push(',');
        }
        let _ = write!(phases, "\"{}\":{}", PHASE_NAMES[p], num(phase_cycles[p]));
    }
    phases.push('}');
    phases
}

fn metrics_json(m: &RunMetrics) -> String {
    let phases = phases_json(&m.phase_cycles);
    let o = &m.ops;
    let ops = format!(
        "{{\"scalar_ops\":{},\"branches\":{},\"vector_ops\":{},\"scalar_loads\":{},\
         \"scalar_stores\":{},\"vector_loads\":{},\"vector_stores\":{},\"gather_elems\":{},\
         \"scatter_elems\":{},\"mssortk\":{},\"mszipk\":{},\"mlxe\":{},\"msxe\":{},\
         \"mmv\":{},\"mmul\":{},\"matrix_busy_cycles\":{}}}",
        o.scalar_ops,
        o.branches,
        o.vector_ops,
        o.scalar_loads,
        o.scalar_stores,
        o.vector_loads,
        o.vector_stores,
        o.gather_elems,
        o.scatter_elems,
        o.mssortk,
        o.mszipk,
        o.mlxe,
        o.msxe,
        o.mmv,
        o.mmul,
        o.matrix_busy_cycles
    );
    let mem = format!(
        "{{\"l1d_accesses\":{},\"l1d_hits\":{},\"l1d_hit_rate\":{},\"l2_accesses\":{},\
         \"l2_hits\":{},\"llc_accesses\":{},\"llc_hits\":{},\"dram_accesses\":{},\
         \"writebacks\":{}}}",
        m.mem.l1d_accesses,
        m.mem.l1d_hits,
        num(m.mem.l1d_hit_rate()),
        m.mem.l2_accesses,
        m.mem.l2_hits,
        m.mem.llc_accesses,
        m.mem.llc_hits,
        m.mem.dram_accesses,
        m.mem.writebacks
    );
    format!(
        "{{\"cycles\":{},\"phase_cycles\":{phases},\"total_matrix_kv_pairs\":{},\
         \"ops\":{ops},\"mem\":{mem},\"sim_footprint_bytes\":{},\"shared\":{}}}",
        num(m.cycles),
        m.total_matrix_kv_pairs(),
        m.sim_footprint_bytes,
        shared_json(&m.shared)
    )
}

/// Shared-memory replay results (all-zero for serial runs, so parsers see
/// one shape at every core count). Append-only: the iterative-engine and
/// row-buffer fields (`replay_iters` .. `row_extra_cycles`) extend the
/// PR 3 schema after `stall_cycles`, the NUMA `numa` block (remote
/// fills / forwards / hop-priced extra cycles — structurally zero at one
/// socket) extends it again after `row_extra_cycles`, the streaming
/// trace counters (`trace_bytes_total` .. `spilled_chunks`) extend it once
/// more after `numa`, and the compulsory-traffic oracle triple
/// (`achieved_dram_lines` / `oracle_dram_lines` / `oracle_ratio`) extends
/// it again after the trace counters.
fn shared_json(s: &SharedStats) -> String {
    format!(
        "{{\"llc_accesses\":{},\"llc_hits\":{},\"llc_misses\":{},\"writeback_installs\":{},\
         \"llc_hit_rate\":{},\"shared_fills\":{},\"demotions\":{},\"upgrades\":{},\
         \"invalidations_sent\":{},\"invalidations_received\":{},\"dirty_forwards\":{},\
         \"llc_queue_cycles\":{},\"dram_queue_cycles\":{},\"coherence_cycles\":{},\
         \"demotion_cycles\":{},\"sharing_saved_cycles\":{},\"stall_cycles\":{},\
         \"replay_iters\":{},\"replay_residual\":{},\"row_hits\":{},\"row_misses\":{},\
         \"row_conflicts\":{},\"row_extra_cycles\":{},\
         \"numa\":{{\"remote_fills\":{},\"remote_forwards\":{},\"remote_extra_cycles\":{}}},\
         \"trace_bytes_total\":{},\"trace_peak_resident_chunks\":{},\"spilled_chunks\":{},\
         \"achieved_dram_lines\":{},\"oracle_dram_lines\":{},\"oracle_ratio\":{}}}",
        s.llc_accesses,
        s.llc_hits,
        s.llc_misses,
        s.writeback_installs,
        num(s.llc_hit_rate()),
        s.shared_fills,
        s.demotions,
        s.upgrades,
        s.invalidations_sent,
        s.invalidations_received,
        s.dirty_forwards,
        num(s.llc_queue_cycles),
        num(s.dram_queue_cycles),
        num(s.coherence_cycles),
        num(s.demotion_cycles),
        num(s.sharing_saved_cycles),
        num(s.stall_cycles()),
        s.replay_iters,
        num(s.replay_residual),
        s.row_hits,
        s.row_misses,
        s.row_conflicts,
        num(s.row_extra_cycles),
        s.remote_fills,
        s.remote_forwards,
        num(s.remote_extra_cycles),
        s.trace_bytes_total,
        s.trace_peak_resident_chunks,
        s.spilled_chunks,
        s.achieved_dram_lines,
        s.oracle_dram_lines,
        num(s.oracle_ratio())
    )
}

fn stats_json(st: &MatrixStats) -> String {
    format!(
        "{{\"nrows\":{},\"nnz\":{},\"density\":{},\"avg_work_per_row\":{},\
         \"avg_out_nnz_per_row\":{},\"avg_work_per_group\":{},\"work_var\":{}}}",
        st.nrows,
        st.nnz,
        num(st.density),
        num(st.avg_work_per_row),
        num(st.avg_out_nnz_per_row),
        num(st.avg_work_per_group),
        num(st.work_var)
    )
}

fn multicore_json(mc: &MulticoreMetrics) -> String {
    let mut per_core = String::from("[");
    for (c, m) in mc.per_core.iter().enumerate() {
        if c > 0 {
            per_core.push(',');
        }
        per_core.push_str(&metrics_json(m));
    }
    per_core.push(']');
    let mut channels = String::from("[");
    for (i, b) in mc.channel_busy_cycles.iter().enumerate() {
        if i > 0 {
            channels.push(',');
        }
        channels.push_str(&num(*b));
    }
    channels.push(']');
    format!(
        "{{\"critical_path_cycles\":{},\"critical_path\":{},\"per_core\":{per_core},\
         \"channel_busy_cycles\":{channels}}}",
        num(mc.critical_path_cycles),
        phases_json(&mc.critical_path)
    )
}

/// Service counters (see [`ServiceStats`]). Tenants are an *array* of
/// fixed-key objects sorted by name, so the key sequence is schema-stable
/// no matter what tenants call themselves.
fn service_json(sv: &ServiceStats) -> String {
    let mut tenants = String::from("[");
    for (i, t) in sv.tenants.iter().enumerate() {
        if i > 0 {
            tenants.push(',');
        }
        let _ = write!(
            tenants,
            "{{\"tenant\":\"{}\",\"weight\":{},\"served\":{}}}",
            escape(&t.tenant),
            t.weight,
            t.served
        );
    }
    tenants.push(']');
    format!(
        "{{\"workers\":{},\"admitted\":{},\"rejected\":{},\"completed\":{},\"failed\":{},\
         \"queue_depth_high_water\":{},\"slots_high_water\":{},\"tenants\":{tenants}}}",
        sv.workers,
        sv.admitted,
        sv.rejected,
        sv.completed,
        sv.failed,
        sv.queue_depth_high_water,
        sv.slots_high_water
    )
}

/// `ws-adapt`'s decision summary as a JSON object.
fn sched_decisions_json(d: &crate::spgemm::parallel::SchedDecisions) -> String {
    format!(
        "{{\"total_blocks\":{},\"blocks_scl_array\":{},\"blocks_scl_hash\":{},\
         \"blocks_spz\":{},\"blocks_other\":{},\"swapped_blocks\":{},\
         \"split_blocks\":{},\"predicted_stall_cycles\":{},\
         \"achieved_stall_cycles\":{}}}",
        d.total_blocks,
        d.blocks_scl_array,
        d.blocks_scl_hash,
        d.blocks_spz,
        d.blocks_other,
        d.swapped_blocks,
        d.split_blocks,
        num(d.predicted_stall_cycles),
        num(d.achieved_stall_cycles),
    )
}

impl JobResult {
    /// One job as a single-line JSON object. New fields only ever get
    /// appended (`cores`/`sched`/`multicore` landed after `metrics`;
    /// `sched_decisions` after `multicore`).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"impl\":\"{}\",\"dataset\":\"{}\",\"out_nnz\":{},\"verified\":{},\
             \"wall_secs\":{},\"block_elems\":{},\"metrics\":{},\"cores\":{},\
             \"sched\":{},\"multicore\":{},\"sched_decisions\":{}}}",
            self.impl_id.name(),
            escape(&self.dataset),
            self.out_nnz,
            self.verified,
            num(self.wall_secs),
            self.block_elems
                .map(|b| b.to_string())
                .unwrap_or_else(|| "null".to_string()),
            metrics_json(&self.metrics),
            self.cores,
            self.sched
                .map(|s| format!("\"{}\"", s.name()))
                .unwrap_or_else(|| "null".to_string()),
            self.multicore
                .as_ref()
                .map(multicore_json)
                .unwrap_or_else(|| "null".to_string()),
            self.sched_decisions
                .as_ref()
                .map(sched_decisions_json)
                .unwrap_or_else(|| "null".to_string()),
        )
    }

    /// [`JobResult::to_json`] with the nondeterministic/configuration-shaped
    /// fields zeroed: `wall_secs` (host wall-clock) and the two ring-shaped
    /// trace counters (`trace_peak_resident_chunks`, `spilled_chunks`, which
    /// depend on `trace_ring_chunks` but never on the simulated result —
    /// `trace_bytes_total` is ring-independent and stays). Two runs of the
    /// same spec on any pool/queue/tenancy/ring configuration compare
    /// byte-equal through this form — the service and streaming-replay
    /// determinism contracts are stated (and tested) in terms of it.
    pub fn to_json_stable(&self) -> String {
        let mut r = self.clone();
        r.wall_secs = 0.0;
        r.metrics.shared.trace_peak_resident_chunks = 0;
        r.metrics.shared.spilled_chunks = 0;
        if let Some(mc) = r.multicore.as_mut() {
            mc.total.shared.trace_peak_resident_chunks = 0;
            mc.total.shared.spilled_chunks = 0;
            for m in &mut mc.per_core {
                m.shared.trace_peak_resident_chunks = 0;
                m.shared.spilled_chunks = 0;
            }
        }
        r.to_json()
    }
}

impl SuiteRun {
    /// The whole sweep as a JSON document: dataset characterization (sorted
    /// by name for determinism) plus one object per job in suite order.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n  \"datasets\": {\n");
        let mut names: Vec<&String> = self.dataset_stats.keys().collect();
        names.sort();
        for (i, name) in names.iter().enumerate() {
            let _ = writeln!(
                s,
                "    \"{}\": {}{}",
                escape(name),
                stats_json(&self.dataset_stats[*name]),
                if i + 1 < names.len() { "," } else { "" }
            );
        }
        s.push_str("  },\n  \"results\": [\n");
        for (i, r) in self.results.iter().enumerate() {
            let _ = writeln!(
                s,
                "    {}{}",
                r.to_json(),
                if i + 1 < self.results.len() { "," } else { "" }
            );
        }
        s.push_str("  ],\n  \"service\": ");
        s.push_str(&service_json(&self.service));
        s.push_str("\n}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escape_handles_specials() {
        assert_eq!(escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn nonfinite_numbers_become_null() {
        assert_eq!(num(f64::NAN), "null");
        assert_eq!(num(1.5), "1.5");
    }
}
