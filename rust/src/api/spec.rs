//! Typed job/suite specifications: what to run, on which data.
//!
//! [`DatasetSource`] replaces stringly-typed dataset names end-to-end: a
//! dataset is either a calibrated synthetic from the Table III registry, a
//! user-provided MatrixMarket file, or an in-memory [`Csr`] handed in by an
//! embedding application. String parsing happens exactly once, at the argv
//! boundary ([`DatasetSource::parse`]).

use crate::matrix::{mm, registry, Csr};
use crate::spgemm::parallel::Scheduler;
use crate::spgemm::ImplId;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Where a dataset comes from.
#[derive(Clone, Debug)]
pub enum DatasetSource {
    /// A calibrated synthetic stand-in from the Table III registry.
    Registry(&'static registry::Dataset),
    /// A MatrixMarket file on disk (scale is ignored; the file is read as-is).
    Mtx(PathBuf),
    /// A matrix the embedding application already built (scale is ignored).
    InMemory { name: String, csr: Arc<Csr> },
}

/// Cache key for a `(source, scale)` pair — see [`crate::api::Session`].
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum DatasetKey {
    Registry { name: &'static str, scale_bits: u64 },
    Mtx(PathBuf),
    /// In-memory matrices are keyed by `Arc` identity.
    InMemory(usize),
}

impl DatasetSource {
    /// Look a registry dataset up by its Table III name.
    pub fn registry(name: &str) -> Result<Self> {
        registry::find(name).map(DatasetSource::Registry).with_context(|| {
            let known: Vec<&str> = registry::DATASETS.iter().map(|d| d.name).collect();
            format!(
                "unknown dataset '{name}' (known datasets: {}; or provide a .mtx file instead)",
                known.join(", ")
            )
        })
    }

    /// A MatrixMarket file.
    pub fn mtx(path: impl Into<PathBuf>) -> Self {
        DatasetSource::Mtx(path.into())
    }

    /// An already-built matrix owned by the embedding application.
    pub fn in_memory(name: impl Into<String>, csr: Arc<Csr>) -> Self {
        DatasetSource::InMemory { name: name.into(), csr }
    }

    /// Resolve a CLI dataset spec: a `<name>.mtx` under `mtx_dir` overrides
    /// the synthetic registry (as `spz --mtx-dir` always did), an explicit
    /// `*.mtx` path is read from disk, anything else is a registry name.
    pub fn parse(spec: &str, mtx_dir: Option<&Path>) -> Result<Self> {
        if let Some(dir) = mtx_dir {
            let p = if spec.ends_with(".mtx") {
                dir.join(spec)
            } else {
                dir.join(format!("{spec}.mtx"))
            };
            if p.exists() {
                return Ok(DatasetSource::Mtx(p));
            }
        }
        if spec.ends_with(".mtx") {
            return Ok(DatasetSource::Mtx(PathBuf::from(spec)));
        }
        Self::registry(spec)
    }

    /// Display/report name ("p2p", the file stem of an `.mtx`, or the name
    /// given to an in-memory matrix).
    pub fn name(&self) -> String {
        match self {
            DatasetSource::Registry(d) => d.name.to_string(),
            DatasetSource::Mtx(p) => p
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| p.display().to_string()),
            DatasetSource::InMemory { name, .. } => name.clone(),
        }
    }

    /// Cache key for this source at `scale`. Registry scales are normalized
    /// with the same clamp [`registry::Dataset::build`] applies, so
    /// equivalent scales (e.g. 1.0 and 2.0) share one cache entry; file and
    /// in-memory sources ignore scale entirely.
    pub fn cache_key(&self, scale: f64) -> DatasetKey {
        match self {
            DatasetSource::Registry(d) => DatasetKey::Registry {
                name: d.name,
                scale_bits: registry::normalize_scale(scale).to_bits(),
            },
            DatasetSource::Mtx(p) => DatasetKey::Mtx(p.clone()),
            DatasetSource::InMemory { csr, .. } => DatasetKey::InMemory(Arc::as_ptr(csr) as usize),
        }
    }

    /// Materialize the matrix (uncached; [`crate::api::Session::dataset`]
    /// memoizes this per `(source, scale)`).
    pub fn build(&self, scale: f64) -> Result<Arc<Csr>> {
        match self {
            DatasetSource::Registry(d) => Ok(Arc::new(d.build(scale))),
            DatasetSource::Mtx(p) => Ok(Arc::new(mm::read_mtx(p)?)),
            DatasetSource::InMemory { csr, .. } => Ok(csr.clone()),
        }
    }
}

impl std::str::FromStr for DatasetSource {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        DatasetSource::parse(s, None)
    }
}

impl std::fmt::Display for DatasetSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(&self.name())
    }
}

/// One job: one implementation on one dataset (C = A*A, as in the paper's
/// evaluation; use [`crate::api::Session::spgemm`] for general A*B).
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub impl_id: ImplId,
    pub dataset: DatasetSource,
    /// Dataset scale in (0, 1] (registry synthetics only).
    pub scale: f64,
    /// Verify the product against the memoized reference oracle.
    pub verify: bool,
    /// Simulated cores. 1 = the classic serial loop; >= 2 runs the
    /// row-blocked multi-core driver ([`crate::spgemm::parallel`]) and fills
    /// [`crate::api::JobResult::multicore`].
    pub cores: usize,
    /// Row-block scheduler for multi-core runs (ignored at 1 core). The
    /// full set lives in [`Scheduler::ALL`], and string forms parse through
    /// the one `Scheduler::from_str` the CLI uses — so `"ws-bw"` works
    /// identically here, in `spz run/suite/fig12/mem`, and in every sweep.
    pub sched: Scheduler,
}

impl JobSpec {
    pub fn new(impl_id: ImplId, dataset: DatasetSource) -> Self {
        JobSpec {
            impl_id,
            dataset,
            scale: 1.0,
            verify: false,
            cores: 1,
            sched: Scheduler::WorkStealing,
        }
    }

    pub fn with_scale(mut self, scale: f64) -> Self {
        self.scale = scale;
        self
    }

    pub fn with_verify(mut self, verify: bool) -> Self {
        self.verify = verify;
        self
    }

    pub fn with_cores(mut self, cores: usize) -> Self {
        self.cores = cores.max(1);
        self
    }

    pub fn with_scheduler(mut self, sched: Scheduler) -> Self {
        self.sched = sched;
        self
    }
}

/// A (datasets x implementations) sweep.
#[derive(Clone, Debug)]
pub struct SuiteSpec {
    /// Datasets (default: all 14 of Table III).
    pub datasets: Vec<DatasetSource>,
    /// Implementations (default: the five of Figure 8).
    pub impls: Vec<ImplId>,
    /// Dataset scale in (0, 1].
    pub scale: f64,
    /// Worker threads.
    pub threads: usize,
    /// Verify every product against the reference oracle.
    pub verify: bool,
    /// Simulated cores per job (see [`JobSpec::cores`]). At >= 2 every
    /// job's `metrics` are aggregate core-cycles; use
    /// [`crate::api::JobResult::multicore`] (or `time_cycles()`) for the
    /// critical-path view.
    pub cores: usize,
    /// Row-block scheduler for multi-core jobs.
    pub sched: Scheduler,
}

impl Default for SuiteSpec {
    fn default() -> Self {
        SuiteSpec {
            datasets: registry::DATASETS.iter().map(DatasetSource::Registry).collect(),
            impls: ImplId::ALL.to_vec(),
            scale: 1.0,
            threads: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4),
            verify: false,
            cores: 1,
            sched: Scheduler::WorkStealing,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_source_round_trips() {
        let s = DatasetSource::parse("p2p", None).unwrap();
        assert_eq!(s.name(), "p2p");
        assert!(matches!(s, DatasetSource::Registry(_)));
        let again: DatasetSource = "p2p".parse().unwrap();
        assert_eq!(again.cache_key(0.5), s.cache_key(0.5));
    }

    #[test]
    fn unknown_dataset_is_actionable() {
        let e = DatasetSource::parse("nope", None).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("unknown dataset 'nope'"), "{msg}");
        assert!(msg.contains("p2p") && msg.contains(".mtx"), "{msg}");
    }

    #[test]
    fn mtx_path_spec_parses() {
        let s = DatasetSource::parse("some/dir/web.mtx", None).unwrap();
        assert!(matches!(&s, DatasetSource::Mtx(p) if p.ends_with("web.mtx")));
        assert_eq!(s.name(), "web");
    }

    #[test]
    fn cache_keys_distinguish_scales_and_sources() {
        let s = DatasetSource::registry("wiki").unwrap();
        assert_ne!(s.cache_key(1.0), s.cache_key(0.5));
        // Scales beyond the clamp range alias to the same built matrix.
        assert_eq!(s.cache_key(1.0), s.cache_key(2.0));
        assert_eq!(s.cache_key(1e-3), s.cache_key(1e-4));
        let a = DatasetSource::in_memory("m", Arc::new(Csr::identity(4)));
        let b = DatasetSource::in_memory("m", Arc::new(Csr::identity(4)));
        assert_ne!(a.cache_key(1.0), b.cache_key(1.0));
        assert_eq!(a.cache_key(1.0), a.clone().cache_key(0.25));
    }

    #[test]
    fn default_suite_matches_paper() {
        let s = SuiteSpec::default();
        assert_eq!(s.datasets.len(), 14);
        assert_eq!(s.impls, ImplId::ALL.to_vec());
    }
}
