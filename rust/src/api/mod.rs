//! The embeddable SpGEMM-simulation service API.
//!
//! A long-lived [`Session`] owns the functional engine, the XLA artifact
//! location, and the simulated [`crate::SystemConfig`], plus a dataset cache
//! keyed by `(source, scale)` that memoizes built matrices, their Table III
//! characterization, and reference products across jobs. Experiments are
//! typed values — [`JobSpec`] / [`SuiteSpec`] in, [`JobResult`] /
//! [`SuiteRun`] out — with [`ImplId`] and [`DatasetSource`] replacing string
//! names end-to-end; the `spz` CLI is a thin argv adapter over this module.
//!
//! ```no_run
//! # fn main() -> anyhow::Result<()> {
//! use sparsezipper::api::{DatasetSource, ImplId, JobSpec, Session};
//!
//! let session = Session::new();
//! let dataset = DatasetSource::registry("p2p")?;
//! let spz = session.run(&JobSpec::new(ImplId::Spz, dataset.clone()).with_verify(true))?;
//! let hash = session.run(&JobSpec::new(ImplId::SclHash, dataset).with_verify(true))?;
//! // The dataset and its reference product were each built exactly once.
//! println!("speedup {:.2}x", hash.metrics.cycles / spz.metrics.cycles);
//! println!("{}", spz.to_json());
//! # Ok(())
//! # }
//! ```

mod json;
mod session;
mod spec;

pub use crate::mem::SharedStats;
pub use crate::sim::MulticoreMetrics;
pub use crate::spgemm::parallel::Scheduler;
pub use crate::spgemm::ImplId;
pub use session::{JobResult, Product, Session, SessionConfig, SuiteRun};
pub use spec::{DatasetKey, DatasetSource, JobSpec, SuiteSpec};
