//! PCG32 pseudo-random number generator (O'Neill, 2014) seeded through
//! SplitMix64. Deterministic across platforms; used for all synthetic
//! dataset generation and property tests so every experiment is replayable
//! from a seed recorded in EXPERIMENTS.md.

/// PCG-XSH-RR 64/32.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

#[inline]
fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Pcg32 {
    /// Create a generator from a single seed (stream derived from the seed).
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let state = splitmix64(&mut s);
        let inc = splitmix64(&mut s) | 1;
        let mut rng = Pcg32 { state: 0, inc };
        rng.state = state.wrapping_add(inc);
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, bound) without modulo bias (Lemire).
    #[inline]
    pub fn gen_range(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    #[inline]
    pub fn gen_usize(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0 && bound <= u32::MAX as usize);
        self.gen_range(bound as u32) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn gen_f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.gen_f64() as f32) * (hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        if n < 2 {
            return;
        }
        for i in (1..n).rev() {
            let j = self.gen_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }

    /// Approximate Poisson sample via inversion (small means) / normal
    /// approximation (large means). Good enough for degree synthesis.
    pub fn gen_poisson(&mut self, mean: f64) -> u32 {
        if mean <= 0.0 {
            return 0;
        }
        if mean < 30.0 {
            let l = (-mean).exp();
            let mut k = 0u32;
            let mut p = 1.0;
            loop {
                p *= self.gen_f64();
                if p <= l {
                    return k;
                }
                k += 1;
                if k > 10_000 {
                    return k; // numeric guard
                }
            }
        } else {
            // normal approximation
            let z = self.gen_normal();
            let v = mean + z * mean.sqrt();
            if v < 0.0 {
                0
            } else {
                v.round() as u32
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-12);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Pcg32::new(7);
        for _ in 0..10_000 {
            assert!(r.gen_range(17) < 17);
        }
    }

    #[test]
    fn gen_range_covers_all_values() {
        let mut r = Pcg32::new(9);
        let mut seen = [false; 8];
        for _ in 0..10_000 {
            seen[r.gen_range(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Pcg32::new(3);
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut ys = xs.clone();
        ys.sort_unstable();
        assert_eq!(ys, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn poisson_mean_roughly_right() {
        let mut r = Pcg32::new(11);
        for &mean in &[2.0f64, 8.0, 50.0] {
            let n = 20_000;
            let s: u64 = (0..n).map(|_| r.gen_poisson(mean) as u64).sum();
            let m = s as f64 / n as f64;
            assert!(
                (m - mean).abs() < mean * 0.1 + 0.2,
                "poisson mean {m} vs {mean}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let m = xs.iter().sum::<f64>() / n as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n as f64;
        assert!(m.abs() < 0.05, "mean {m}");
        assert!((v - 1.0).abs() < 0.1, "var {v}");
    }
}
