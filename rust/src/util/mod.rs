//! Small self-contained utilities (the offline vendor set has no `rand`,
//! `serde`, or `itertools`, so we carry our own PRNG and helpers).

pub mod prng;
pub mod stats;

pub use prng::Pcg32;
pub use stats::{geomean, mean, stddev};

/// Round `x` up to the next multiple of `m` (m > 0).
#[inline]
pub fn round_up(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// Next power of two >= x (x >= 1).
#[inline]
pub fn next_pow2(x: usize) -> usize {
    x.next_power_of_two()
}

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// log2 of a power of two.
#[inline]
pub fn log2_pow2(x: usize) -> u32 {
    debug_assert!(x.is_power_of_two());
    x.trailing_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basic() {
        assert_eq!(round_up(0, 16), 0);
        assert_eq!(round_up(1, 16), 16);
        assert_eq!(round_up(16, 16), 16);
        assert_eq!(round_up(17, 16), 32);
    }

    #[test]
    fn next_pow2_basic() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(16), 16);
        assert_eq!(next_pow2(17), 32);
    }

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(8, 4), 2);
        assert_eq!(ceil_div(9, 4), 3);
    }

    #[test]
    fn log2_basic() {
        assert_eq!(log2_pow2(1), 0);
        assert_eq!(log2_pow2(16), 4);
        assert_eq!(log2_pow2(1024), 10);
    }
}
