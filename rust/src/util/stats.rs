//! Summary statistics used by dataset characterization (Table III) and the
//! benchmark harnesses.

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation (stddev / mean); 0 when the mean is 0.
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        stddev(xs) / m
    }
}

/// Geometric mean of strictly positive inputs (0 for empty input).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Median (on a copy; fine for reporting paths).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cv_zero_mean() {
        assert_eq!(cv(&[0.0, 0.0]), 0.0);
    }

    #[test]
    fn geomean_basic() {
        let xs = [1.0, 10.0, 100.0];
        assert!((geomean(&xs) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }
}
