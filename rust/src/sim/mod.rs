//! First-order cycle simulation substrate.
//!
//! SpGEMM implementations execute *functionally* in ordinary Rust while
//! charging every architectural event (scalar/vector ops, memory accesses
//! through the cache hierarchy, matrix-unit instruction pairs) to a
//! [`Machine`]. This replaces gem5's detailed OoO model with an
//! instrumented-execution model (DESIGN.md "Substitutions"): event *counts*
//! are exact; cycles are first-order effective costs from [`cost`].

pub mod cost;
pub mod machine;

pub use cost::CostModel;
pub use machine::{Machine, MulticoreMetrics, Phase, RunMetrics};
