//! Effective-cost model: converts architectural events into cycles with
//! first-order out-of-order overlap (issue-bandwidth + MLP-divided miss
//! latency). All constants live in [`crate::config::CoreConfig`]; this
//! module only encodes *how* they combine.
//!
//! This model prices everything a core does against its *own* resources
//! (pipeline, private caches, and the uncontended bandwidth floor of one
//! DRAM line transfer). Shared-resource costs — queueing at the shared LLC,
//! DRAM channel conflicts, coherence — are **not** analytic constants here
//! any more: they are derived by replaying the per-core access traces
//! through the shared-memory model ([`crate::mem::shared::replay`]), which
//! charges exactly zero when one core runs alone. (The retired
//! `DRAM_BW_CONTENTION_PER_CORE` / `LLC_QUEUE_CYCLES_PER_CORE` knobs
//! inflated every access by a flat per-core factor regardless of what the
//! other cores actually touched.)

use crate::config::{CoreConfig, MemConfig};

pub use crate::config::DRAM_BW_CYCLES;

/// Computes effective (overlap-adjusted) cycle costs for the machine model.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    pub core: CoreConfig,
    /// L1 hit latency, subtracted from raw latencies (hits are pipelined).
    l1_hit: f64,
    /// Raw latency at or above which an access reached DRAM.
    dram_threshold: f64,
}

impl CostModel {
    /// Cost model for one core (Table II machine). Identical at every core
    /// count: multi-core contention is priced by the shared-memory replay,
    /// not by inflating per-access costs.
    pub fn new(core: CoreConfig, mem: &MemConfig) -> Self {
        CostModel {
            core,
            l1_hit: mem.l1d.hit_latency as f64,
            dram_threshold: (mem.l1d.hit_latency + mem.l2.hit_latency + mem.llc.hit_latency) as f64
                + 1.0,
        }
    }

    /// Uncontended DRAM-bandwidth floor of an access whose raw hierarchy
    /// latency was `raw`: [`DRAM_BW_CYCLES`] for any access that reached
    /// DRAM, zero otherwise. This is what makes one-useful-element-per-line
    /// access patterns (scl-array's scattered accumulator) pay for the full
    /// line. Contended shared costs come from the trace replay.
    #[inline]
    pub fn dram_bw(&self, raw: u32) -> f64 {
        if (raw as f64) >= self.dram_threshold {
            DRAM_BW_CYCLES
        } else {
            0.0
        }
    }

    /// Cycles for `n` dependent-ish scalar ALU ops.
    #[inline]
    pub fn scalar_ops(&self, n: u64) -> f64 {
        n as f64 / self.core.scalar_ipc
    }

    /// Cycles for `n` taken-or-not branches.
    #[inline]
    pub fn branches(&self, n: u64) -> f64 {
        n as f64 * self.core.branch_cost
    }

    /// Cycles for `n` 512-bit vector ALU ops.
    #[inline]
    pub fn vector_ops(&self, n: u64) -> f64 {
        n as f64 / self.core.vector_ipc
    }

    /// Issue cost of one load/store micro-op.
    #[inline]
    pub fn mem_issue(&self, uops: u64) -> f64 {
        uops as f64 / self.core.mem_issue_per_cycle
    }

    /// Exposed stall cycles for a scalar access whose raw hierarchy latency
    /// was `raw` (L1-hit portion is hidden by the pipeline; misses overlap
    /// by the scalar MLP factor).
    #[inline]
    pub fn scalar_miss(&self, raw: u32) -> f64 {
        ((raw as f64) - self.l1_hit).max(0.0) / self.core.mlp_scalar
    }

    /// A *dependent* load (pointer chase / hash probe / accumulator
    /// read-modify-write): the L1 hit latency sits on the critical path, on
    /// top of the overlapped miss component.
    #[inline]
    pub fn dep_load(&self, raw: u32) -> f64 {
        // Load-to-use on the critical path is ~2x the pipelined hit latency
        // (address generation + forwarding), and dependent misses barely
        // overlap (serial RMW chains defeat the LQ's MLP).
        2.0 * self.l1_hit + ((raw as f64) - self.l1_hit).max(0.0) / (self.core.mlp_scalar / 4.0).max(1.0)
    }

    /// Data-dependent compare-and-branch (sorting, probe loops): ~50%
    /// mispredicted at a ~14-cycle flush, partially overlapped.
    #[inline]
    pub fn branch_unpredictable(&self, n: u64) -> f64 {
        n as f64 * 3.5
    }

    /// Exposed stall for a unit-stride vector access (`raw` = slowest line).
    #[inline]
    pub fn vector_miss(&self, raw: u32) -> f64 {
        ((raw as f64) - self.l1_hit).max(0.0) / self.core.mlp_vector
    }

    /// Exposed stall for one lane of a gather/scatter.
    #[inline]
    pub fn gather_miss(&self, raw: u32) -> f64 {
        ((raw as f64) - self.l1_hit).max(0.0) / self.core.mlp_gather
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn cm() -> CostModel {
        let c = SystemConfig::default();
        CostModel::new(c.core, &c.mem)
    }

    #[test]
    fn scalar_throughput() {
        assert!((cm().scalar_ops(8) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn l1_hit_has_no_miss_cost() {
        assert_eq!(cm().scalar_miss(2), 0.0);
    }

    #[test]
    fn dram_miss_divided_by_mlp() {
        let m = cm();
        let raw = 2 + 8 + 8 + 160;
        assert!((m.scalar_miss(raw) - 176.0 / 4.0).abs() < 1e-9);
        assert!(m.gather_miss(raw) > m.vector_miss(raw));
    }

    #[test]
    fn vector_cheaper_than_gather() {
        let m = cm();
        assert!(m.vector_miss(100) < m.gather_miss(100));
    }

    #[test]
    fn uncontended_costs_match_seed_model() {
        // The per-access shared cost is the seed model's single-core cost at
        // every core count: no bandwidth-factor inflation, no flat LLC
        // queueing. Contention now comes exclusively from the trace replay.
        let m = cm();
        let dram_raw = 2 + 8 + 8 + 160;
        assert_eq!(m.dram_bw(2), 0.0); // L1 hit
        assert_eq!(m.dram_bw(2 + 8), 0.0); // L2 hit
        assert_eq!(m.dram_bw(2 + 8 + 8), 0.0); // LLC hit
        assert_eq!(m.dram_bw(dram_raw), DRAM_BW_CYCLES);
    }
}
