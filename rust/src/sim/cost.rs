//! Effective-cost model: converts architectural events into cycles with
//! first-order out-of-order overlap (issue-bandwidth + MLP-divided miss
//! latency). All constants live in [`crate::config::CoreConfig`]; this
//! module only encodes *how* they combine.

use crate::config::{CoreConfig, MemConfig};

/// Computes effective (overlap-adjusted) cycle costs for the machine model.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    pub core: CoreConfig,
    /// L1 hit latency, subtracted from raw latencies (hits are pipelined).
    l1_hit: f64,
    /// Raw latency at or above which an access left the private caches
    /// (i.e. at least an LLC lookup happened).
    llc_threshold: f64,
    /// Raw latency at or above which an access reached DRAM.
    dram_threshold: f64,
    /// DRAM-bandwidth inflation from cores sharing the bus (1.0 = alone).
    bw_factor: f64,
    /// Extra queueing cycles at the shared LLC per contended access.
    llc_queue: f64,
}

/// Cycles of DRAM *bandwidth* occupancy per line transfer — a floor that
/// memory-level parallelism cannot hide (64B line at ~20GB/s on a ~3GHz
/// core). Charged on every DRAM-reaching access; this is what makes
/// one-useful-element-per-line access patterns (scl-array's scattered
/// accumulator) pay for the full line.
pub const DRAM_BW_CYCLES: f64 = 6.0;

/// First-order multi-core contention knobs: with `cores` active cores the
/// shared DRAM bus sustains proportionally less bandwidth per core
/// (`1 + 0.5*(cores-1)` occupancy inflation — half of the extra demand is
/// absorbed by bank parallelism) and the shared LLC adds a small queueing
/// delay per contended lookup. Calibration-knob constants in the spirit of
/// DESIGN.md: relative multi-core behaviour (bandwidth-bound kernels stop
/// scaling, cache-resident ones keep scaling) is what matters.
pub const DRAM_BW_CONTENTION_PER_CORE: f64 = 0.5;
pub const LLC_QUEUE_CYCLES_PER_CORE: f64 = 1.0;

impl CostModel {
    /// Cost model for one core of a `cores`-core system (Table II machine
    /// when `cores == 1`; contended shared-resource costs otherwise).
    pub fn new(core: CoreConfig, mem: &MemConfig, cores: usize) -> Self {
        let extra = (cores.max(1) - 1) as f64;
        CostModel {
            core,
            l1_hit: mem.l1d.hit_latency as f64,
            llc_threshold: (mem.l1d.hit_latency + mem.l2.hit_latency) as f64 + 1.0,
            dram_threshold: (mem.l1d.hit_latency + mem.l2.hit_latency + mem.llc.hit_latency) as f64
                + 1.0,
            bw_factor: 1.0 + DRAM_BW_CONTENTION_PER_CORE * extra,
            llc_queue: LLC_QUEUE_CYCLES_PER_CORE * extra,
        }
    }

    /// Shared-resource cost of an access whose raw hierarchy latency was
    /// `raw`: the DRAM bandwidth floor (inflated under multi-core bus
    /// contention) plus LLC queueing for any access that left the private
    /// caches. Zero for L1/L2 hits; identical to the seed model at 1 core.
    #[inline]
    pub fn dram_bw(&self, raw: u32) -> f64 {
        let mut c = 0.0;
        if (raw as f64) >= self.llc_threshold {
            c += self.llc_queue;
        }
        if (raw as f64) >= self.dram_threshold {
            c += DRAM_BW_CYCLES * self.bw_factor;
        }
        c
    }

    /// Cycles for `n` dependent-ish scalar ALU ops.
    #[inline]
    pub fn scalar_ops(&self, n: u64) -> f64 {
        n as f64 / self.core.scalar_ipc
    }

    /// Cycles for `n` taken-or-not branches.
    #[inline]
    pub fn branches(&self, n: u64) -> f64 {
        n as f64 * self.core.branch_cost
    }

    /// Cycles for `n` 512-bit vector ALU ops.
    #[inline]
    pub fn vector_ops(&self, n: u64) -> f64 {
        n as f64 / self.core.vector_ipc
    }

    /// Issue cost of one load/store micro-op.
    #[inline]
    pub fn mem_issue(&self, uops: u64) -> f64 {
        uops as f64 / self.core.mem_issue_per_cycle
    }

    /// Exposed stall cycles for a scalar access whose raw hierarchy latency
    /// was `raw` (L1-hit portion is hidden by the pipeline; misses overlap
    /// by the scalar MLP factor).
    #[inline]
    pub fn scalar_miss(&self, raw: u32) -> f64 {
        ((raw as f64) - self.l1_hit).max(0.0) / self.core.mlp_scalar
    }

    /// A *dependent* load (pointer chase / hash probe / accumulator
    /// read-modify-write): the L1 hit latency sits on the critical path, on
    /// top of the overlapped miss component.
    #[inline]
    pub fn dep_load(&self, raw: u32) -> f64 {
        // Load-to-use on the critical path is ~2x the pipelined hit latency
        // (address generation + forwarding), and dependent misses barely
        // overlap (serial RMW chains defeat the LQ's MLP).
        2.0 * self.l1_hit + ((raw as f64) - self.l1_hit).max(0.0) / (self.core.mlp_scalar / 4.0).max(1.0)
    }

    /// Data-dependent compare-and-branch (sorting, probe loops): ~50%
    /// mispredicted at a ~14-cycle flush, partially overlapped.
    #[inline]
    pub fn branch_unpredictable(&self, n: u64) -> f64 {
        n as f64 * 3.5
    }

    /// Exposed stall for a unit-stride vector access (`raw` = slowest line).
    #[inline]
    pub fn vector_miss(&self, raw: u32) -> f64 {
        ((raw as f64) - self.l1_hit).max(0.0) / self.core.mlp_vector
    }

    /// Exposed stall for one lane of a gather/scatter.
    #[inline]
    pub fn gather_miss(&self, raw: u32) -> f64 {
        ((raw as f64) - self.l1_hit).max(0.0) / self.core.mlp_gather
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn cm() -> CostModel {
        let c = SystemConfig::default();
        CostModel::new(c.core, &c.mem, 1)
    }

    #[test]
    fn scalar_throughput() {
        assert!((cm().scalar_ops(8) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn l1_hit_has_no_miss_cost() {
        assert_eq!(cm().scalar_miss(2), 0.0);
    }

    #[test]
    fn dram_miss_divided_by_mlp() {
        let m = cm();
        let raw = 2 + 8 + 8 + 160;
        assert!((m.scalar_miss(raw) - 176.0 / 4.0).abs() < 1e-9);
        assert!(m.gather_miss(raw) > m.vector_miss(raw));
    }

    #[test]
    fn vector_cheaper_than_gather() {
        let m = cm();
        assert!(m.vector_miss(100) < m.gather_miss(100));
    }

    #[test]
    fn single_core_shared_costs_match_seed_model() {
        let m = cm();
        let dram_raw = 2 + 8 + 8 + 160;
        assert_eq!(m.dram_bw(2), 0.0); // L1 hit
        assert_eq!(m.dram_bw(2 + 8), 0.0); // L2 hit
        assert_eq!(m.dram_bw(2 + 8 + 8), 0.0); // LLC hit, no queueing alone
        assert_eq!(m.dram_bw(dram_raw), DRAM_BW_CYCLES);
    }

    #[test]
    fn contention_inflates_shared_costs_only() {
        let c = SystemConfig::default();
        let alone = CostModel::new(c.core, &c.mem, 1);
        let crowd = CostModel::new(c.core, &c.mem, 8);
        let dram_raw = 2 + 8 + 8 + 160;
        let llc_raw = 2 + 8 + 8;
        // DRAM bus occupancy scales with active cores; LLC lookups queue.
        assert!(crowd.dram_bw(dram_raw) > alone.dram_bw(dram_raw));
        assert!(crowd.dram_bw(llc_raw) > 0.0);
        assert_eq!(crowd.dram_bw(2 + 8), 0.0, "private-cache hits are free of contention");
        // Core-private costs are untouched.
        assert_eq!(crowd.scalar_ops(8), alone.scalar_ops(8));
        assert_eq!(crowd.scalar_miss(dram_raw), alone.scalar_miss(dram_raw));
    }
}
