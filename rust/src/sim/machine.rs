//! The instrumented machine: functional execution + architectural event
//! accounting. Every SpGEMM implementation takes `&mut Machine` and charges
//! its scalar/vector/matrix/memory activity here; the coordinator snapshots
//! [`RunMetrics`] afterwards to build Figures 8–11.

use crate::config::SystemConfig;
use crate::mem::alloc::{CORE_ADDR_SPAN, SHARED_ADDR_BASE};
use crate::mem::{AccessKind, Hierarchy, MemStats, SharedStats, SimAlloc, TraceBuf};
use crate::sim::cost::CostModel;
use crate::systolic::SystolicTiming;

/// Execution-time breakdown phases (Figure 9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Per-row work estimation, block sizing, temp allocation.
    Preprocess = 0,
    /// All multiplications; intermediate (key, value) generation.
    Expand = 1,
    /// Stream sorting/merging (incl. radix sort in vec-radix).
    Sort = 2,
    /// Final output row generation / compression.
    Output = 3,
    /// Row-index sorting + output shuffling (spz-rsort only).
    RowSort = 4,
}

pub const NUM_PHASES: usize = 5;
pub const PHASE_NAMES: [&str; NUM_PHASES] =
    ["preprocess", "expand", "sort", "output", "rowsort"];

// Trace events bucket replay stalls per phase in MAX_PHASES-sized arrays.
const _: () = assert!(NUM_PHASES <= crate::mem::MAX_PHASES);

/// Dynamic instruction / event counters (Figure 10 & 11 inputs).
///
/// Counts are *exact* (instrumented execution, not sampling), so they are
/// additive across cores: a multi-core run's per-core counters sum to the
/// matching single-core totals — the invariant the parallel-driver tests pin.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCounters {
    pub scalar_ops: u64,
    pub branches: u64,
    pub vector_ops: u64,
    pub scalar_loads: u64,
    pub scalar_stores: u64,
    pub vector_loads: u64,
    pub vector_stores: u64,
    pub gather_elems: u64,
    pub scatter_elems: u64,
    pub mssortk: u64,
    pub mszipk: u64,
    pub mlxe: u64,
    pub msxe: u64,
    pub mmv: u64,
    pub mmul: u64,
    pub matrix_busy_cycles: u64,
}

/// Snapshot of one run, consumed by the coordinator.
#[derive(Clone, Debug)]
pub struct RunMetrics {
    pub cycles: f64,
    pub phase_cycles: [f64; NUM_PHASES],
    pub ops: OpCounters,
    /// Private-hierarchy statistics (L1/L2 plus the core's shadow LLC).
    pub mem: MemStats,
    /// Shared-memory replay results (queueing, coherence, sharing
    /// corrections). All-zero for serial runs — the parallel driver fills
    /// this after phase-2 replay, and the replay stalls are already folded
    /// into `cycles` / `phase_cycles`.
    pub shared: SharedStats,
    pub sim_footprint_bytes: u64,
}

impl OpCounters {
    /// Element-wise accumulate (multi-core aggregation).
    pub fn add(&mut self, o: &OpCounters) {
        self.scalar_ops += o.scalar_ops;
        self.branches += o.branches;
        self.vector_ops += o.vector_ops;
        self.scalar_loads += o.scalar_loads;
        self.scalar_stores += o.scalar_stores;
        self.vector_loads += o.vector_loads;
        self.vector_stores += o.vector_stores;
        self.gather_elems += o.gather_elems;
        self.scatter_elems += o.scatter_elems;
        self.mssortk += o.mssortk;
        self.mszipk += o.mszipk;
        self.mlxe += o.mlxe;
        self.msxe += o.msxe;
        self.mmv += o.mmv;
        self.mmul += o.mmul;
        self.matrix_busy_cycles += o.matrix_busy_cycles;
    }
}

impl RunMetrics {
    pub fn total_matrix_kv_pairs(&self) -> u64 {
        self.ops.mssortk + self.ops.mszipk
    }

    /// All-zero metrics (identity of [`RunMetrics::merge`]).
    pub fn zero() -> RunMetrics {
        RunMetrics {
            cycles: 0.0,
            phase_cycles: [0.0; NUM_PHASES],
            ops: OpCounters::default(),
            mem: MemStats::default(),
            shared: SharedStats::default(),
            sim_footprint_bytes: 0,
        }
    }

    /// Accumulate another run's metrics into this one (sums everywhere:
    /// cycles become *aggregate core-cycles*, not wall time — see
    /// [`MulticoreMetrics`] for the critical-path view).
    pub fn merge(&mut self, o: &RunMetrics) {
        self.cycles += o.cycles;
        for p in 0..NUM_PHASES {
            self.phase_cycles[p] += o.phase_cycles[p];
        }
        self.ops.add(&o.ops);
        self.mem.add(&o.mem);
        self.shared.add(&o.shared);
        self.sim_footprint_bytes += o.sim_footprint_bytes;
    }
}

/// Aggregate view of one multi-core run: the per-core breakdown, element-wise
/// totals, and the critical path under a barrier-per-phase execution model
/// (each phase ends when its slowest core finishes, so the per-phase critical
/// path is the max over cores and the run's critical path is their sum).
#[derive(Clone, Debug)]
pub struct MulticoreMetrics {
    /// One [`RunMetrics`] per core, indexed by core id.
    pub per_core: Vec<RunMetrics>,
    /// Element-wise sums over cores (aggregate core-cycles, exact counts).
    pub total: RunMetrics,
    /// Per-phase critical path: max over cores of that phase's cycles.
    pub critical_path: [f64; NUM_PHASES],
    /// Simulated wall-clock cycles: sum of the per-phase maxima.
    pub critical_path_cycles: f64,
    /// Total transfer occupancy per DRAM channel from the shared-memory
    /// replay (empty when no replay ran).
    pub channel_busy_cycles: Vec<f64>,
}

impl MulticoreMetrics {
    /// Aggregate per-core snapshots (index = core id).
    pub fn from_cores(per_core: Vec<RunMetrics>) -> MulticoreMetrics {
        let mut total = RunMetrics::zero();
        let mut critical_path = [0.0; NUM_PHASES];
        for m in &per_core {
            total.merge(m);
            for p in 0..NUM_PHASES {
                critical_path[p] = critical_path[p].max(m.phase_cycles[p]);
            }
        }
        MulticoreMetrics {
            critical_path_cycles: critical_path.iter().sum(),
            per_core,
            total,
            critical_path,
            channel_busy_cycles: Vec::new(),
        }
    }

    pub fn cores(&self) -> usize {
        self.per_core.len()
    }

    /// Aggregate core-cycles over critical-path cycles: the effective
    /// parallel speedup *within this run* (upper-bounded by `cores()`).
    pub fn parallel_efficiency(&self) -> f64 {
        if self.critical_path_cycles > 0.0 {
            self.total.cycles / self.critical_path_cycles
        } else {
            1.0
        }
    }

    /// Load imbalance: busiest core's cycles over the per-core mean
    /// (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        let max = self.per_core.iter().map(|m| m.cycles).fold(0.0, f64::max);
        let mean = self.total.cycles / self.per_core.len().max(1) as f64;
        if mean > 0.0 {
            max / mean
        } else {
            1.0
        }
    }
}

/// Shared-operand table entries: `(identity key, (indptr, indices, data))`.
type SharedObjTable = Vec<(usize, (u64, u64, u64))>;

/// Canonical addresses of the modeled *shared destination region* for the
/// stitched product: one indptr array covering every row of C plus packed
/// indices/data arrays sized by the Gustavson work estimate. Mapped once on
/// the base machine (before forking), so every core sees the same addresses
/// — phase-3 output writes from different cores land in one region and the
/// block-boundary lines generate real upgrade/invalidation traffic through
/// the replay's directory.
#[derive(Clone, Copy, Debug)]
struct SharedOutRegion {
    indptr: u64,
    indices: u64,
    data: u64,
}

/// One row block's window into the shared destination region, bound by the
/// parallel driver before each block's multiply: rows `[row_lo, ...)` of the
/// global indptr and `elem_cap` elements of the packed indices/data arrays
/// starting at `elem_off`.
#[derive(Clone, Copy, Debug)]
struct OutWindow {
    row_lo: usize,
    elem_off: u64,
    elem_cap: u64,
}

/// The simulated machine (one core plus its private caches and matrix unit).
pub struct Machine {
    pub cfg: SystemConfig,
    pub cost: CostModel,
    pub mem: Hierarchy,
    pub alloc: SimAlloc,
    pub unit: SystolicTiming,
    pub ops: OpCounters,
    core_id: usize,
    /// NUMA socket this core sits on (0 for serial machines and
    /// single-socket configs; assigned by [`Machine::fork_core`] from
    /// [`crate::config::SharedMemConfig::socket_of_core`]).
    socket_id: usize,
    cycles: f64,
    phase_cycles: [f64; NUM_PHASES],
    phase: Phase,
    /// Canonical allocator for operands shared read-only by all cores;
    /// every fork starts from the same state, so the same registration
    /// sequence yields the same addresses on every core.
    shared_alloc: SimAlloc,
    /// Shared-operand table; `None` on serial machines (plain per-machine
    /// allocation applies).
    shared_objs: Option<SharedObjTable>,
    /// Canonical shared destination region for the stitched output; `None`
    /// on serial machines (outputs stay in the core's private region).
    shared_out: Option<SharedOutRegion>,
    /// The current row block's window into the shared destination region
    /// (set by the parallel driver before each block's multiply).
    out_window: Option<OutWindow>,
}

impl Machine {
    pub fn new(cfg: SystemConfig) -> Self {
        Machine {
            cost: CostModel::new(cfg.core, &cfg.mem),
            mem: Hierarchy::new(cfg.mem),
            alloc: SimAlloc::new(),
            unit: SystolicTiming::new(cfg.unit),
            ops: OpCounters::default(),
            core_id: 0,
            socket_id: 0,
            cycles: 0.0,
            phase_cycles: [0.0; NUM_PHASES],
            phase: Phase::Preprocess,
            shared_alloc: SimAlloc::with_base(SHARED_ADDR_BASE),
            shared_objs: None,
            shared_out: None,
            out_window: None,
            cfg,
        }
    }

    /// Shard off a per-core machine for multi-core simulation: shares this
    /// machine's [`SystemConfig`] with fresh private caches, counters, and
    /// simulated address space. Each worker thread of the parallel SpGEMM
    /// driver charges its own fork and records its shared-memory trace for
    /// the phase-2 replay; see [`crate::spgemm::parallel`].
    pub fn fork_core(&self, core_id: usize) -> Machine {
        let mut m = Machine::new(self.cfg);
        m.core_id = core_id;
        // NUMA placement: contiguous core blocks per socket, stamped onto
        // the hierarchy so every trace event carries its requester's socket
        // (the replay prices LLC fills / forwards / DRAM transfers by the
        // distance from this socket to the line's home channel group).
        m.socket_id = self.cfg.shared.socket_of_core(core_id, self.cfg.cores.max(1));
        m.mem.set_socket(m.socket_id as u8);
        // Each core owns a disjoint private address region (the power-of-two
        // stride keeps every cache-index bit identical to a base-region run,
        // so per-core cache behaviour is unchanged), and inherits the
        // parent's shared-operand table so shared objects resolve to the
        // same canonical addresses on every core — cross-core line identity
        // in the replay means real sharing, never allocator aliasing.
        m.alloc = SimAlloc::with_base(crate::mem::alloc::START + core_id as u64 * CORE_ADDR_SPAN);
        // Inherit the allocator *cursor* too: a fork registering a new
        // shared operand must not reuse addresses the parent already handed
        // out (that would alias two distinct operands).
        m.shared_alloc = self.shared_alloc.clone();
        m.shared_objs = self.shared_objs.clone();
        // The shared destination region is common to all forks; the
        // per-block window is the worker's to bind.
        m.shared_out = self.shared_out;
        m
    }

    /// Turn on the shared-operand table (the parallel driver calls this on
    /// the base machine before forking, so every fork inherits it).
    pub fn enable_shared_operands(&mut self) {
        if self.shared_objs.is_none() {
            self.shared_objs = Some(Vec::new());
        }
    }

    /// Canonical addresses for an operand shared read-only by every core
    /// (the B matrix of a parallel run): the same `key` resolves to the same
    /// three block addresses on every fork. Returns `None` on machines
    /// without a shared-operand table (serial runs keep the seed's plain
    /// per-machine allocation).
    pub fn shared_csr(
        &mut self,
        key: usize,
        sizes: (usize, usize, usize),
    ) -> Option<(u64, u64, u64)> {
        let table = self.shared_objs.as_mut()?;
        if let Some(&(_, addrs)) = table.iter().find(|&&(k, _)| k == key) {
            return Some(addrs);
        }
        let addrs = (
            self.shared_alloc.alloc(sizes.0),
            self.shared_alloc.alloc(sizes.1),
            self.shared_alloc.alloc(sizes.2),
        );
        table.push((key, addrs));
        Some(addrs)
    }

    /// Map the canonical shared destination region for an `nrows`-row
    /// stitched product whose packed indices/data arrays hold up to
    /// `est_elems` elements (the Gustavson work upper bound). The parallel
    /// driver calls this on the base machine before forking, so every core
    /// resolves the same addresses; serial machines never map one and keep
    /// the seed's private output allocation.
    pub fn map_shared_output(&mut self, nrows: usize, est_elems: usize) {
        self.shared_out = Some(SharedOutRegion {
            indptr: self.shared_alloc.alloc((nrows + 1) * 8),
            indices: self.shared_alloc.alloc(est_elems.max(1) * 4),
            data: self.shared_alloc.alloc(est_elems.max(1) * 4),
        });
    }

    /// Canonical base addresses of the shared destination region
    /// (`(indptr, indices, data)`), if one is mapped. The `ws-bw` pilot uses
    /// this to price output traffic on the same lines the replay will see.
    pub fn shared_output(&self) -> Option<(u64, u64, u64)> {
        self.shared_out.map(|r| (r.indptr, r.indices, r.data))
    }

    /// Bind the current row block's window into the shared destination
    /// region: global output rows start at `row_lo`, and the block owns
    /// `elem_cap` packed elements starting at element `elem_off`. Called by
    /// the parallel driver before each block's multiply; a no-op influence
    /// on machines without a mapped region.
    pub fn bind_output_block(&mut self, row_lo: usize, elem_off: u64, elem_cap: u64) {
        self.out_window = Some(OutWindow { row_lo, elem_off, elem_cap });
    }

    /// Simulated addresses for an implementation's output CSR arrays
    /// (`(indices, data, indptr)` bases): `rows` output rows and up to
    /// `est_elems` packed elements. With a shared destination region and a
    /// bound block window that fits, the returned addresses are canonical —
    /// `indptr` is offset so slab row `r` maps to global row `row_lo + r`,
    /// and the packed arrays sit at the block's element offset, so adjacent
    /// blocks on different cores write-share boundary lines. Otherwise this
    /// allocates privately, in exactly the order and sizes the seed
    /// implementations always used (indices, data, indptr).
    pub fn out_csr_addrs(&mut self, rows: usize, est_elems: usize) -> (u64, u64, u64) {
        if let (Some(region), Some(w)) = (self.shared_out, self.out_window) {
            if est_elems as u64 <= w.elem_cap {
                return (
                    region.indices + w.elem_off * 4,
                    region.data + w.elem_off * 4,
                    region.indptr + w.row_lo as u64 * 8,
                );
            }
        }
        (
            self.alloc.alloc(est_elems.max(1) * 4),
            self.alloc.alloc(est_elems.max(1) * 4),
            self.alloc.alloc((rows + 1) * 8),
        )
    }

    /// Start recording this machine's shared-memory (LLC-level) access
    /// trace for the deterministic replay ([`crate::mem::shared::replay`]).
    pub fn enable_trace(&mut self) {
        self.mem.enable_trace();
    }

    /// Take the recorded trace (empty if tracing was never enabled).
    pub fn take_trace(&mut self) -> TraceBuf {
        self.mem.take_trace()
    }

    /// Stream this machine's shared-memory trace into `w` instead of
    /// materializing it: sealed chunks are consumed concurrently by the
    /// replay engine, bounded by the ring budget the writer was created
    /// with ([`crate::mem::TraceStream::channel`]).
    pub fn attach_trace_writer(&mut self, w: crate::mem::TraceWriter) {
        self.mem.attach_trace_writer(w);
    }

    /// Finish and detach the streaming trace sink (marks the stream
    /// complete; the replay merge can then drain past this core).
    pub fn finish_trace(&mut self) {
        self.mem.finish_trace();
    }

    /// Which core of the simulated system this machine models (0 for
    /// single-core runs).
    pub fn core_id(&self) -> usize {
        self.core_id
    }

    /// Which NUMA socket this core sits on (0 for serial machines and
    /// single-socket configs).
    pub fn socket_id(&self) -> usize {
        self.socket_id
    }

    #[inline]
    fn charge(&mut self, c: f64) {
        self.cycles += c;
        self.phase_cycles[self.phase as usize] += c;
    }

    /// Switch the current Figure 9 breakdown phase.
    pub fn phase(&mut self, p: Phase) {
        self.phase = p;
        self.mem.set_phase(p as u8);
    }

    pub fn cycles(&self) -> f64 {
        self.cycles
    }

    /// Allocate simulated address space.
    pub fn salloc(&mut self, bytes: usize) -> u64 {
        self.alloc.alloc(bytes)
    }

    // ---- scalar / vector compute ------------------------------------------

    pub fn scalar_ops(&mut self, n: u64) {
        self.ops.scalar_ops += n;
        let c = self.cost.scalar_ops(n);
        self.charge(c);
    }

    pub fn branches(&mut self, n: u64) {
        self.ops.branches += n;
        let c = self.cost.branches(n);
        self.charge(c);
    }

    pub fn vector_ops(&mut self, n: u64) {
        self.ops.vector_ops += n;
        let c = self.cost.vector_ops(n);
        self.charge(c);
    }

    // ---- scalar memory -----------------------------------------------------

    pub fn load(&mut self, addr: u64, bytes: usize) {
        self.ops.scalar_loads += 1;
        self.mem.set_now(self.cycles);
        let (raw, _) = self.mem.access(addr, bytes, AccessKind::Read);
        let c = self.cost.mem_issue(1) + self.cost.scalar_miss(raw) + self.cost.dram_bw(raw);
        self.charge(c);
    }

    /// Dependent scalar load (hash probe, accumulator RMW, pointer chase):
    /// the hit latency is on the critical path.
    pub fn load_dep(&mut self, addr: u64, bytes: usize) {
        self.ops.scalar_loads += 1;
        self.mem.set_now(self.cycles);
        let (raw, _) = self.mem.access(addr, bytes, AccessKind::Read);
        let c = self.cost.mem_issue(1) + self.cost.dep_load(raw) + self.cost.dram_bw(raw);
        self.charge(c);
    }

    /// Data-dependent compare-and-branch (sorting networks, probe loops).
    pub fn branches_unpredictable(&mut self, n: u64) {
        self.ops.branches += n;
        let c = self.cost.branch_unpredictable(n);
        self.charge(c);
    }

    pub fn store(&mut self, addr: u64, bytes: usize) {
        self.ops.scalar_stores += 1;
        self.mem.set_now(self.cycles);
        let (raw, _) = self.mem.access(addr, bytes, AccessKind::Write);
        // Stores retire through the store buffer; expose only a fraction.
        let c = self.cost.mem_issue(1) + 0.25 * self.cost.scalar_miss(raw) + self.cost.dram_bw(raw);
        self.charge(c);
    }

    // ---- vector memory -----------------------------------------------------

    /// Unit-stride vector load of `bytes` starting at `addr`.
    pub fn vload(&mut self, addr: u64, bytes: usize) {
        self.ops.vector_loads += 1;
        self.mem.set_now(self.cycles);
        let (raw, lines) = self.mem.access(addr, bytes, AccessKind::Read);
        let c = self.cost.mem_issue(lines as u64) + self.cost.vector_miss(raw) + self.cost.dram_bw(raw);
        self.charge(c);
    }

    /// Unit-stride vector store.
    pub fn vstore(&mut self, addr: u64, bytes: usize) {
        self.ops.vector_stores += 1;
        self.mem.set_now(self.cycles);
        let (raw, lines) = self.mem.access(addr, bytes, AccessKind::Write);
        let c = self.cost.mem_issue(lines as u64) + 0.25 * self.cost.vector_miss(raw) + self.cost.dram_bw(raw);
        self.charge(c);
    }

    /// Indexed vector load (gather): one lane per address.
    pub fn vgather<I: IntoIterator<Item = u64>>(&mut self, addrs: I, elem_bytes: usize) {
        self.ops.vector_loads += 1;
        let mut c = 0.0;
        for a in addrs {
            self.ops.gather_elems += 1;
            // Lanes issue as the gather progresses: stamp each lane with the
            // cycle it would leave the core, so replay interleaves fairly.
            self.mem.set_now(self.cycles + c);
            let (raw, _) = self.mem.access(a, elem_bytes, AccessKind::Read);
            // Gathers sustain ~1 lane/cycle on wide SIMD machines.
            c += self.cost.mem_issue(2) + self.cost.gather_miss(raw) + self.cost.dram_bw(raw);
        }
        self.charge(c);
    }

    /// Indexed vector store (scatter).
    pub fn vscatter<I: IntoIterator<Item = u64>>(&mut self, addrs: I, elem_bytes: usize) {
        self.ops.vector_stores += 1;
        let mut c = 0.0;
        for a in addrs {
            self.ops.scatter_elems += 1;
            self.mem.set_now(self.cycles + c);
            let (raw, _) = self.mem.access(a, elem_bytes, AccessKind::Write);
            c += self.cost.mem_issue(2) + 0.25 * self.cost.gather_miss(raw) + self.cost.dram_bw(raw);
        }
        self.charge(c);
    }

    // ---- matrix unit -------------------------------------------------------

    /// `mlxe.t`: R row-wise unit-stride load micro-ops
    /// (`rows` = (sim_addr, elems) per active stream).
    pub fn mlxe<'a, I: IntoIterator<Item = &'a (u64, usize)>>(&mut self, rows: I) {
        self.ops.mlxe += 1;
        let mut c = 0.0;
        for &(addr, elems) in rows {
            if elems == 0 {
                continue;
            }
            self.mem.set_now(self.cycles + c);
            let (raw, lines) = self.mem.access(addr, elems * 4, AccessKind::Read);
            c += self.cost.mem_issue(lines as u64) + self.cost.vector_miss(raw) + self.cost.dram_bw(raw);
        }
        self.charge(c);
    }

    /// `msxe.t`: row-wise unit-stride store micro-ops.
    pub fn msxe<'a, I: IntoIterator<Item = &'a (u64, usize)>>(&mut self, rows: I) {
        self.ops.msxe += 1;
        let mut c = 0.0;
        for &(addr, elems) in rows {
            if elems == 0 {
                continue;
            }
            self.mem.set_now(self.cycles + c);
            let (raw, lines) = self.mem.access(addr, elems * 4, AccessKind::Write);
            c += self.cost.mem_issue(lines as u64) + 0.25 * self.cost.vector_miss(raw) + self.cost.dram_bw(raw);
        }
        self.charge(c);
    }

    /// One `mssortk`+`mssortv` pair over `rows` active streams.
    pub fn sort_pair(&mut self, rows: usize) {
        self.ops.mssortk += 1;
        let c = self.unit.pair_cycles(rows);
        self.ops.matrix_busy_cycles += c;
        self.charge(c as f64);
    }

    /// One `mszipk`+`mszipv` pair over `rows` active streams.
    pub fn zip_pair(&mut self, rows: usize) {
        self.ops.mszipk += 1;
        let c = self.unit.pair_cycles(rows);
        self.ops.matrix_busy_cycles += c;
        self.charge(c as f64);
    }

    /// Baseline dense-GEMM tile multiply (`mmul`-style instruction).
    pub fn mmul_tile(&mut self) {
        self.ops.mmul += 1;
        let c = self.unit.dense_gemm_cycles();
        self.ops.matrix_busy_cycles += c;
        self.charge(c as f64);
    }

    /// `mmv.vi`/`mmv.vo` counter moves (cheap vector move).
    pub fn mmv(&mut self, n: u64) {
        self.ops.mmv += n;
        let c = self.cost.vector_ops(n);
        self.charge(c);
    }

    /// Final metrics snapshot. `shared` stays zero here: the parallel
    /// driver fills it (and folds the stall cycles in) after replay.
    pub fn metrics(&self) -> RunMetrics {
        RunMetrics {
            cycles: self.cycles,
            phase_cycles: self.phase_cycles,
            ops: self.ops,
            mem: self.mem.stats(),
            shared: SharedStats::default(),
            sim_footprint_bytes: self.alloc.footprint() + self.shared_alloc.footprint(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> Machine {
        Machine::new(SystemConfig::default())
    }

    #[test]
    fn phases_accumulate_separately() {
        let mut mc = m();
        mc.phase(Phase::Expand);
        mc.scalar_ops(400);
        mc.phase(Phase::Sort);
        mc.zip_pair(16);
        let r = mc.metrics();
        assert!(r.phase_cycles[Phase::Expand as usize] > 0.0);
        assert!(r.phase_cycles[Phase::Sort as usize] > 0.0);
        assert_eq!(r.phase_cycles[Phase::Output as usize], 0.0);
        let total: f64 = r.phase_cycles.iter().sum();
        assert!((total - r.cycles).abs() < 1e-9);
    }

    #[test]
    fn cached_loads_cheaper_than_cold() {
        let mut mc = m();
        let a = mc.salloc(4096);
        mc.load(a, 4);
        let cold = mc.cycles();
        mc.load(a, 4);
        let warm = mc.cycles() - cold;
        assert!(warm < cold, "warm {warm} cold {cold}");
    }

    #[test]
    fn zip_pair_counts_and_busy() {
        let mut mc = m();
        mc.zip_pair(16);
        mc.sort_pair(8);
        let r = mc.metrics();
        assert_eq!(r.ops.mszipk, 1);
        assert_eq!(r.ops.mssortk, 1);
        assert_eq!(r.total_matrix_kv_pairs(), 2);
        assert!(r.ops.matrix_busy_cycles > 0);
    }

    #[test]
    fn mlxe_unit_stride_is_few_lines() {
        let mut mc = m();
        let a = mc.salloc(4096);
        let rows: Vec<(u64, usize)> = (0..16).map(|i| (a + i * 64, 16)).collect();
        mc.mlxe(rows.iter());
        let s = mc.metrics().mem;
        // 16 rows x 64B aligned = exactly 16 line accesses.
        assert_eq!(s.l1d_accesses, 16);
    }

    #[test]
    fn gather_touches_more_lines_than_unit_stride() {
        let mut mc = m();
        let a = mc.salloc(1 << 20);
        let addrs: Vec<u64> = (0..16u64).map(|i| a + i * 4096).collect();
        mc.vgather(addrs.iter().copied(), 4);
        let scattered = mc.metrics().mem.l1d_accesses;
        let mut mc2 = m();
        let b = mc2.salloc(1 << 20);
        mc2.vload(b, 64);
        let unit = mc2.metrics().mem.l1d_accesses;
        assert!(scattered > unit * 8);
    }

    #[test]
    fn footprint_tracked() {
        let mut mc = m();
        mc.salloc(1000);
        assert_eq!(mc.metrics().sim_footprint_bytes, 1000);
    }

    #[test]
    fn fork_core_shares_config_with_fresh_state() {
        let mut base = Machine::new(SystemConfig { cores: 4, ..SystemConfig::default() });
        base.scalar_ops(100);
        let fork = base.fork_core(3);
        assert_eq!(fork.core_id(), 3);
        assert_eq!(fork.cfg.cores, 4);
        assert_eq!(fork.cycles(), 0.0, "forked core starts with fresh counters");
        assert_eq!(fork.ops, OpCounters::default());
        assert_eq!(base.core_id(), 0);
    }

    #[test]
    fn fork_core_assigns_contiguous_sockets_and_stamps_traces() {
        let mut cfg = SystemConfig { cores: 4, ..SystemConfig::default() };
        cfg.shared.sockets = 2;
        let base = Machine::new(cfg);
        assert_eq!(base.socket_id(), 0, "the base machine sits on socket 0");
        let socks: Vec<usize> = (0..4).map(|c| base.fork_core(c).socket_id()).collect();
        assert_eq!(socks, vec![0, 0, 1, 1], "contiguous core blocks per socket");
        // The fork's trace events carry its socket.
        let mut f3 = base.fork_core(3);
        f3.enable_trace();
        let a = f3.salloc(4096);
        f3.load(a, 4);
        let t = f3.take_trace();
        assert!(!t.is_empty());
        assert_eq!(t.get(0).socket(), 1);
        // Single-socket forks stay socket 0 everywhere.
        let flat = Machine::new(SystemConfig { cores: 4, ..SystemConfig::default() });
        assert!((0..4).all(|c| flat.fork_core(c).socket_id() == 0));
    }

    #[test]
    fn forked_cores_have_disjoint_private_regions_and_shared_operands() {
        let mut base = Machine::new(SystemConfig { cores: 2, ..SystemConfig::default() });
        base.enable_shared_operands();
        let mut f0 = base.fork_core(0);
        let mut f1 = base.fork_core(1);
        // Private allocations can never alias across cores...
        let p0 = f0.salloc(64);
        let p1 = f1.salloc(64);
        assert_ne!(p0 >> 40, p1 >> 40, "private regions must be disjoint");
        // ...while a shared operand resolves to identical addresses on
        // every fork (and is stable across repeated registrations).
        let s0 = f0.shared_csr(42, (64, 64, 64)).unwrap();
        let s1 = f1.shared_csr(42, (64, 64, 64)).unwrap();
        assert_eq!(s0, s1, "shared operand must map identically on every core");
        assert_eq!(f0.shared_csr(42, (64, 64, 64)).unwrap(), s0);
        assert_ne!(s0.0, s0.1);
        // Shared addresses live outside every private region.
        assert!(s0.0 > p0 && s0.0 > p1);
        // Serial machines have no shared-operand table.
        let mut serial = Machine::new(SystemConfig::default());
        assert!(serial.shared_csr(42, (64, 64, 64)).is_none());
    }

    #[test]
    fn shared_output_region_maps_canonically_and_falls_back() {
        let mut base = Machine::new(SystemConfig { cores: 2, ..SystemConfig::default() });
        base.enable_shared_operands();
        base.map_shared_output(100, 1000);
        let (ip, ix, dv) = base.shared_output().unwrap();
        assert!(ip >= crate::mem::alloc::SHARED_ADDR_BASE);
        let mut f0 = base.fork_core(0);
        let mut f1 = base.fork_core(1);
        // Block [0, 16) on core 0, block [16, 32) on core 1: canonical,
        // adjacent, and derived from the same global arrays.
        f0.bind_output_block(0, 0, 300);
        f1.bind_output_block(16, 300, 700);
        let (i0, d0, p0) = f0.out_csr_addrs(16, 300);
        let (i1, d1, p1) = f1.out_csr_addrs(16, 700);
        assert_eq!(p0, ip);
        assert_eq!(p1, ip + 16 * 8, "indptr windows tile the global array");
        assert_eq!(i0, ix);
        assert_eq!(i1, ix + 300 * 4, "packed element windows tile too");
        assert_eq!(d1, dv + 300 * 4);
        assert_ne!(d0, i0);
        // A request larger than the bound window falls back to the private
        // region (never aliasing another block's canonical window).
        let (priv_i, _, priv_p) = f1.out_csr_addrs(16, 10_000);
        assert!(priv_i < crate::mem::alloc::SHARED_ADDR_BASE);
        assert!(priv_p < crate::mem::alloc::SHARED_ADDR_BASE);
        // Serial machines allocate privately (the seed path).
        let mut serial = Machine::new(SystemConfig::default());
        let (si, sd, sp) = serial.out_csr_addrs(10, 50);
        assert!(si < crate::mem::alloc::SHARED_ADDR_BASE);
        assert!(si < sd && sd < sp, "seed allocation order: indices, data, indptr");
    }

    #[test]
    fn core_count_never_changes_phase1_charging() {
        // Per-access costs are the uncontended Table II machine at every
        // core count: contention is the replay's business, not phase 1's.
        let run = |cores: usize| {
            let mut mc = Machine::new(SystemConfig { cores, ..SystemConfig::default() });
            let a = mc.salloc(1 << 22);
            for i in 0..1024u64 {
                mc.load(a + i * 4096, 4);
            }
            mc.metrics()
        };
        let alone = run(1);
        let crowd = run(8);
        assert_eq!(crowd.cycles, alone.cycles, "phase 1 is core-count independent");
        assert_eq!(crowd.ops, alone.ops);
        assert_eq!(crowd.mem.dram_accesses, alone.mem.dram_accesses);
    }

    #[test]
    fn machine_trace_stamps_phase_and_monotone_time() {
        let mut mc = m();
        mc.enable_trace();
        let a = mc.salloc(1 << 20);
        mc.phase(Phase::Expand);
        mc.load(a, 4); // cold -> demand event in Expand
        mc.phase(Phase::Sort);
        mc.load(a + 4096, 4); // cold -> demand event in Sort
        mc.load(a + 4096, 4); // warm L1 hit -> no event
        let t = mc.take_trace();
        assert_eq!(t.len(), 2);
        let timed: Vec<(f64, crate::mem::TraceEvent)> = t.iter_timed().collect();
        assert_eq!(timed[0].1.phase(), Phase::Expand as u8);
        assert_eq!(timed[1].1.phase(), Phase::Sort as u8);
        assert_eq!(timed[0].0, 0.0, "first access issues at cycle zero");
        assert!(timed[1].0 > timed[0].0, "local timestamps are monotone");
        assert!(!timed[0].1.write());
        // An untraced machine records nothing.
        let mut quiet = m();
        let b = quiet.salloc(4096);
        quiet.load(b, 4);
        assert!(quiet.take_trace().is_empty());
    }

    #[test]
    fn merge_sums_everything() {
        let mut a = m();
        a.phase(Phase::Expand);
        a.scalar_ops(10);
        a.zip_pair(4);
        let mut b = m();
        b.phase(Phase::Sort);
        b.vector_ops(6);
        b.salloc(128);
        let (ra, rb) = (a.metrics(), b.metrics());
        let mut tot = RunMetrics::zero();
        tot.merge(&ra);
        tot.merge(&rb);
        assert!((tot.cycles - (ra.cycles + rb.cycles)).abs() < 1e-9);
        assert_eq!(tot.ops.scalar_ops, 10);
        assert_eq!(tot.ops.vector_ops, 6);
        assert_eq!(tot.ops.mszipk, 1);
        assert_eq!(tot.sim_footprint_bytes, 128);
        let ps: f64 = tot.phase_cycles.iter().sum();
        assert!((ps - tot.cycles).abs() < 1e-9);
    }

    #[test]
    fn multicore_critical_path_is_per_phase_max() {
        let mk = |expand: f64, sort: f64| {
            let mut r = RunMetrics::zero();
            r.phase_cycles[Phase::Expand as usize] = expand;
            r.phase_cycles[Phase::Sort as usize] = sort;
            r.cycles = expand + sort;
            r
        };
        let mc = MulticoreMetrics::from_cores(vec![mk(100.0, 10.0), mk(40.0, 50.0)]);
        assert_eq!(mc.cores(), 2);
        assert_eq!(mc.critical_path[Phase::Expand as usize], 100.0);
        assert_eq!(mc.critical_path[Phase::Sort as usize], 50.0);
        assert_eq!(mc.critical_path_cycles, 150.0);
        assert_eq!(mc.total.cycles, 200.0);
        assert!((mc.parallel_efficiency() - 200.0 / 150.0).abs() < 1e-12);
        assert!((mc.imbalance() - 110.0 / 100.0).abs() < 1e-12);
        // A single core's critical path is just its own cycles.
        let solo = MulticoreMetrics::from_cores(vec![mk(100.0, 10.0)]);
        assert_eq!(solo.critical_path_cycles, solo.total.cycles);
    }
}
