//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `make artifacts` from the L2 JAX model + L1 Pallas kernels) and
//! executes them from the Rust request path through the `xla` crate's CPU
//! client. Python is never on the request path.

pub mod client;
pub mod engine;

pub use client::XlaRunner;
pub use engine::{Engine, NativeEngine, StepOut, XlaEngine, ZipUnit};
