//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `make artifacts` from the L2 JAX model + L1 Pallas kernels) and
//! executes them from the Rust request path through the `xla` crate's CPU
//! client. Python is never on the request path.
//!
//! The `xla` crate (and its native xla_extension library) is behind the
//! off-by-default `xla` cargo feature; without it the [`Engine::Xla`]
//! variant still parses but fails with an actionable error when a session
//! tries to instantiate it, and everything else runs on [`NativeEngine`].

pub mod client;
pub mod engine;

#[cfg(feature = "xla")]
pub use client::XlaRunner;
pub use engine::{Engine, NativeEngine, StepOut, ZipUnit};
#[cfg(feature = "xla")]
pub use engine::XlaEngine;
