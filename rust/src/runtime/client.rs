//! Thin wrapper over the `xla` crate's PJRT CPU client: load HLO *text*
//! artifacts, compile once, execute many times.
//!
//! HLO text (not a serialized `HloModuleProto`) is the interchange format:
//! jax >= 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids and round-trips cleanly
//! (see /opt/xla-example/README.md and python/compile/aot.py).

#[cfg(feature = "xla")]
use anyhow::{Context, Result};
#[cfg(feature = "xla")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A PJRT CPU client plus a set of named compiled executables.
#[cfg(feature = "xla")]
pub struct XlaRunner {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "xla")]
impl XlaRunner {
    /// Create the CPU client.
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(XlaRunner {
            client,
            exes: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO-text artifact under `name`.
    pub fn load_hlo_text(&mut self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute a loaded artifact. The L2 model lowers with
    /// `return_tuple=True`, so the single output literal is a tuple that is
    /// decomposed into its elements here.
    pub fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .exes
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))?;
        let result = exe.execute::<xla::Literal>(inputs).context("execute")?;
        let lit = result[0][0].to_literal_sync().context("fetch result")?;
        lit.to_tuple().context("decompose result tuple")
    }

    pub fn loaded(&self) -> Vec<&str> {
        self.exes.keys().map(|s| s.as_str()).collect()
    }
}

/// Default artifact directory: `$SPZ_ARTIFACTS` or `<repo>/artifacts`.
pub fn artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("SPZ_ARTIFACTS") {
        return PathBuf::from(d);
    }
    // Relative to the crate root when run via cargo, else cwd.
    let cargo = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
    PathBuf::from(cargo).join("artifacts")
}

/// True if both AOT artifacts exist (tests skip gracefully otherwise).
pub fn artifacts_available(dir: &Path) -> bool {
    dir.join("sort_step.hlo.txt").exists() && dir.join("zip_step.hlo.txt").exists()
}
