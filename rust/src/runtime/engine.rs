//! The matrix unit's *functional datapath* behind a trait, with two
//! interchangeable engines:
//!
//! * [`NativeEngine`] — bit-equivalent Rust implementation of the normative
//!   semantics (`systolic::functional`); default for large sweeps.
//! * [`XlaEngine`] — executes the AOT-compiled L2 JAX model (which wraps the
//!   L1 Pallas kernels) through PJRT; proves the three layers compose and is
//!   cross-checked against the native engine in the integration tests.
//!
//! Timing is engine-independent: the `Machine` charges the systolic
//! occupancy model either way; the engine only produces the data.

#[cfg(feature = "xla")]
use crate::runtime::client::XlaRunner;
use crate::systolic::functional;
#[cfg(feature = "xla")]
use anyhow::{ensure, Context};
use anyhow::Result;
#[cfg(feature = "xla")]
use std::path::Path;

/// Key sentinel for padded lanes (i32::MAX on the XLA side).
pub const KEY_PAD: u32 = i32::MAX as u32;

/// Output of one sort/zip step over a group of S streams.
#[derive(Clone, Debug, Default)]
pub struct StepOut {
    /// Per-stream primary output chunk (sort: sorted A; zip: east part).
    pub k0: Vec<Vec<u32>>,
    pub v0: Vec<Vec<f32>>,
    /// Per-stream secondary output chunk (sort: sorted B; zip: south part).
    pub k1: Vec<Vec<u32>>,
    pub v1: Vec<Vec<f32>>,
    /// IC0/IC1: consumed-per-input-chunk counters (zip); echo of input
    /// lengths for sort.
    pub ic0: Vec<usize>,
    pub ic1: Vec<usize>,
    /// OC0/OC1: output chunk lengths.
    pub oc0: Vec<usize>,
    pub oc1: Vec<usize>,
}

/// A group-level functional unit for `mssort`/`mszip` pairs.
pub trait ZipUnit {
    /// Hardware chunk size N (= matrix register row length).
    fn n(&self) -> usize;

    /// `mssortk`+`mssortv` over a group of streams; chunk `i` of stream `s`
    /// is `keys_i[s]` / `vals_i[s]` (len <= N each).
    fn sort_step(
        &mut self,
        keys0: &[Vec<u32>],
        vals0: &[Vec<f32>],
        keys1: &[Vec<u32>],
        vals1: &[Vec<f32>],
    ) -> Result<StepOut>;

    /// `mszipk`+`mszipv` over a group of streams (inputs sorted-unique).
    fn zip_step(
        &mut self,
        keys0: &[Vec<u32>],
        vals0: &[Vec<f32>],
        keys1: &[Vec<u32>],
        vals1: &[Vec<f32>],
    ) -> Result<StepOut>;

    fn name(&self) -> &'static str;
}

/// Engine selection for CLI / examples.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    Native,
    Xla,
}

impl std::str::FromStr for Engine {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "native" => Ok(Engine::Native),
            "xla" => Ok(Engine::Xla),
            other => Err(format!("unknown engine '{other}' (native|xla)")),
        }
    }
}

// ---------------------------------------------------------------------------
// Native engine
// ---------------------------------------------------------------------------

/// Pure-Rust normative semantics.
pub struct NativeEngine {
    n: usize,
}

impl NativeEngine {
    pub fn new(n: usize) -> Self {
        NativeEngine { n }
    }
}

impl ZipUnit for NativeEngine {
    fn n(&self) -> usize {
        self.n
    }

    fn sort_step(
        &mut self,
        keys0: &[Vec<u32>],
        vals0: &[Vec<f32>],
        keys1: &[Vec<u32>],
        vals1: &[Vec<f32>],
    ) -> Result<StepOut> {
        let s = keys0.len();
        let mut out = StepOut::default();
        for i in 0..s {
            let r = functional::sort_step(&keys0[i], &vals0[i], &keys1[i], &vals1[i]);
            out.ic0.push(keys0[i].len());
            out.ic1.push(keys1[i].len());
            out.oc0.push(r.a_keys.len());
            out.oc1.push(r.b_keys.len());
            out.k0.push(r.a_keys);
            out.v0.push(r.a_vals);
            out.k1.push(r.b_keys);
            out.v1.push(r.b_vals);
        }
        Ok(out)
    }

    fn zip_step(
        &mut self,
        keys0: &[Vec<u32>],
        vals0: &[Vec<f32>],
        keys1: &[Vec<u32>],
        vals1: &[Vec<f32>],
    ) -> Result<StepOut> {
        let s = keys0.len();
        let mut out = StepOut::default();
        for i in 0..s {
            let r = functional::zip_step(self.n, &keys0[i], &vals0[i], &keys1[i], &vals1[i]);
            out.ic0.push(r.consumed_a);
            out.ic1.push(r.consumed_b);
            out.oc0.push(r.east_keys.len());
            out.oc1.push(r.south_keys.len());
            out.k0.push(r.east_keys);
            out.v0.push(r.east_vals);
            out.k1.push(r.south_keys);
            out.v1.push(r.south_vals);
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

// ---------------------------------------------------------------------------
// XLA engine
// ---------------------------------------------------------------------------

/// Executes the AOT artifacts (L2 JAX model wrapping the L1 Pallas kernels)
/// through the PJRT CPU client. Fixed group shape [S, N] per compilation
/// (S = N = 16 by default, matching the matrix registers).
#[cfg(feature = "xla")]
pub struct XlaEngine {
    runner: XlaRunner,
    n: usize,
    s: usize,
}

#[cfg(feature = "xla")]
impl XlaEngine {
    /// Load `sort_step.hlo.txt` and `zip_step.hlo.txt` from `dir`.
    pub fn load(dir: &Path, s: usize, n: usize) -> Result<Self> {
        let mut runner = XlaRunner::new()?;
        runner
            .load_hlo_text("sort_step", &dir.join("sort_step.hlo.txt"))
            .context("load sort_step artifact")?;
        runner
            .load_hlo_text("zip_step", &dir.join("zip_step.hlo.txt"))
            .context("load zip_step artifact")?;
        Ok(XlaEngine { runner, n, s })
    }

    /// Pack a ragged group into padded [S, N] literals (keys i32 with
    /// KEY_PAD sentinel, values f32 zero-padded) plus an i32[S] length vec.
    fn pack(
        &self,
        keys: &[Vec<u32>],
        vals: &[Vec<f32>],
    ) -> Result<(xla::Literal, xla::Literal, xla::Literal)> {
        ensure!(keys.len() <= self.s, "group larger than engine S");
        let (s, n) = (self.s, self.n);
        let mut k = vec![KEY_PAD as i32; s * n];
        let mut v = vec![0f32; s * n];
        let mut lens = vec![0i32; s];
        for (i, (ks, vs)) in keys.iter().zip(vals).enumerate() {
            ensure!(ks.len() <= n, "chunk longer than N");
            for (j, (&kk, &vv)) in ks.iter().zip(vs).enumerate() {
                k[i * n + j] = kk as i32;
                v[i * n + j] = vv;
            }
            lens[i] = ks.len() as i32;
        }
        let kl = xla::Literal::vec1(&k).reshape(&[s as i64, n as i64])?;
        let vl = xla::Literal::vec1(&v).reshape(&[s as i64, n as i64])?;
        let ll = xla::Literal::vec1(&lens);
        Ok((kl, vl, ll))
    }

    /// Unpack padded [S, N] outputs back into ragged vectors using `lens`.
    fn unpack(
        group: usize,
        n: usize,
        k: &xla::Literal,
        v: &xla::Literal,
        lens: &[i32],
    ) -> Result<(Vec<Vec<u32>>, Vec<Vec<f32>>)> {
        let kd = k.to_vec::<i32>()?;
        let vd = v.to_vec::<f32>()?;
        let mut ks = Vec::with_capacity(group);
        let mut vs = Vec::with_capacity(group);
        for i in 0..group {
            let l = lens[i] as usize;
            ks.push(kd[i * n..i * n + l].iter().map(|&x| x as u32).collect());
            vs.push(vd[i * n..i * n + l].to_vec());
        }
        Ok((ks, vs))
    }

    fn run_step(
        &mut self,
        which: &str,
        keys0: &[Vec<u32>],
        vals0: &[Vec<f32>],
        keys1: &[Vec<u32>],
        vals1: &[Vec<f32>],
    ) -> Result<StepOut> {
        let group = keys0.len();
        let (k0, v0, l0) = self.pack(keys0, vals0)?;
        let (k1, v1, l1) = self.pack(keys1, vals1)?;
        let outs = self.runner.run(which, &[k0, v0, k1, v1, l0, l1])?;
        ensure!(outs.len() == 8, "expected 8 outputs, got {}", outs.len());
        let ic0: Vec<i32> = outs[4].to_vec()?;
        let ic1: Vec<i32> = outs[5].to_vec()?;
        let oc0: Vec<i32> = outs[6].to_vec()?;
        let oc1: Vec<i32> = outs[7].to_vec()?;
        let (k0o, v0o) = Self::unpack(group, self.n, &outs[0], &outs[1], &oc0)?;
        let (k1o, v1o) = Self::unpack(group, self.n, &outs[2], &outs[3], &oc1)?;
        Ok(StepOut {
            k0: k0o,
            v0: v0o,
            k1: k1o,
            v1: v1o,
            ic0: ic0[..group].iter().map(|&x| x as usize).collect(),
            ic1: ic1[..group].iter().map(|&x| x as usize).collect(),
            oc0: oc0[..group].iter().map(|&x| x as usize).collect(),
            oc1: oc1[..group].iter().map(|&x| x as usize).collect(),
        })
    }
}

#[cfg(feature = "xla")]
impl ZipUnit for XlaEngine {
    fn n(&self) -> usize {
        self.n
    }

    fn sort_step(
        &mut self,
        keys0: &[Vec<u32>],
        vals0: &[Vec<f32>],
        keys1: &[Vec<u32>],
        vals1: &[Vec<f32>],
    ) -> Result<StepOut> {
        self.run_step("sort_step", keys0, vals0, keys1, vals1)
    }

    fn zip_step(
        &mut self,
        keys0: &[Vec<u32>],
        vals0: &[Vec<f32>],
        keys1: &[Vec<u32>],
        vals1: &[Vec<f32>],
    ) -> Result<StepOut> {
        self.run_step("zip_step", keys0, vals0, keys1, vals1)
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_sort_step_group() {
        let mut e = NativeEngine::new(4);
        let out = e
            .sort_step(
                &[vec![5, 8, 5], vec![]],
                &[vec![1.0, 3.0, 7.0], vec![]],
                &[vec![2, 1], vec![9]],
                &[vec![1.0, 1.0], vec![2.0]],
            )
            .unwrap();
        assert_eq!(out.k0[0], vec![5, 8]);
        assert_eq!(out.v0[0], vec![8.0, 3.0]);
        assert_eq!(out.k1[0], vec![1, 2]);
        assert_eq!(out.oc0, vec![2, 0]);
        assert_eq!(out.k1[1], vec![9]);
    }

    #[test]
    fn native_zip_step_group() {
        let mut e = NativeEngine::new(3);
        let out = e
            .zip_step(
                &[vec![2, 5, 9]],
                &[vec![1.0, 2.0, 3.0]],
                &[vec![3, 8]],
                &[vec![4.0, 5.0]],
            )
            .unwrap();
        assert_eq!(out.k0[0], vec![2, 3, 5]);
        assert_eq!(out.k1[0], vec![8]);
        assert_eq!(out.ic0, vec![2]);
        assert_eq!(out.ic1, vec![2]);
    }

    #[test]
    fn engine_parse() {
        assert_eq!("native".parse::<Engine>().unwrap(), Engine::Native);
        assert_eq!("xla".parse::<Engine>().unwrap(), Engine::Xla);
        assert!("tpu".parse::<Engine>().is_err());
    }
}
