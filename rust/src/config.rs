//! System configuration mirroring Table II of the paper plus the first-order
//! timing-model constants (documented calibration knobs; see DESIGN.md §6).
//!
//! The simulated machine is an aggressive 8-way out-of-order core with two
//! 512-bit SIMD units and a 16x16 systolic matrix unit, fronted by a
//! 32KB L1D / 256KB L2 / 512KB LLC hierarchy over DDR4-2400.

/// Cycles of DRAM *bandwidth* occupancy per line transfer — a floor that
/// memory-level parallelism cannot hide (64B line at ~20GB/s on a ~3GHz
/// core). Charged on every DRAM-reaching access by [`crate::sim::CostModel`]
/// and used as the per-channel transfer occupancy by the shared-memory
/// replay ([`crate::mem::shared`]).
pub const DRAM_BW_CYCLES: f64 = 6.0;

/// One cache level's geometry and hit latency (Table II).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheConfig {
    pub size_bytes: usize,
    pub ways: usize,
    pub line_bytes: usize,
    pub hit_latency: u32,
}

impl CacheConfig {
    pub fn sets(&self) -> usize {
        self.size_bytes / (self.ways * self.line_bytes)
    }
}

/// Full memory-hierarchy configuration.
#[derive(Clone, Copy, Debug)]
pub struct MemConfig {
    pub l1d: CacheConfig,
    pub l2: CacheConfig,
    pub llc: CacheConfig,
    /// DRAM access latency in CPU cycles (DDR4-2400 at ~3 GHz core clock).
    pub dram_latency: u32,
}

/// The shared end of the memory system: one LLC shared by all active cores
/// plus a multi-channel DRAM back end with per-channel bank/row-buffer
/// state, modeled by the iterative deterministic trace-and-replay engine
/// (see [`crate::mem::trace`] and [`crate::mem::shared::ReplayEngine`]).
/// All cost fields are calibration knobs in the DESIGN.md spirit: relative
/// multi-core behaviour is what matters, and every one of them contributes
/// *zero* cycles when a single core runs alone.
#[derive(Clone, Copy, Debug)]
pub struct SharedMemConfig {
    /// Independent DRAM channels; lines are channel-interleaved by address
    /// (`line % dram_channels`), so streaming traffic spreads while pathological
    /// same-channel conflicts stay representable.
    pub dram_channels: usize,
    /// DRAM banks per channel. Within a channel, consecutive lines fill one
    /// bank's row buffer for [`SharedMemConfig::row_buffer_lines`] lines
    /// before rotating to the next bank, so streams keep rows open while
    /// interleaved streams from other cores close them.
    pub dram_banks: usize,
    /// Cache lines per DRAM row buffer (row size / line size; 8KB rows of
    /// 64B lines = 128).
    pub row_buffer_lines: usize,
    /// Service cost of a row-buffer *hit* (the open-row fast path), used as
    /// the baseline the miss/conflict costs are priced against. The replay
    /// charges only the *difference* between the shared bank outcome and the
    /// core's private shadow bank outcome, so single-stream row behaviour
    /// stays phase 1's business and everything is exactly zero at 1 core.
    pub row_hit_cycles: f64,
    /// Service cost of a row-buffer miss (precharge + activate) caused by
    /// the core's own stream turning the row.
    pub row_miss_cycles: f64,
    /// Service cost of a row-buffer *conflict*: the row this core's stream
    /// had open was closed by another core's interleaved traffic.
    pub row_conflict_cycles: f64,
    /// Upper bound on replay iterations of the
    /// [`crate::mem::shared::ReplayEngine`]: iteration k+1 re-replays with
    /// the shadow-LLC lines that iteration k demoted treated as invalidated
    /// (so repeat demotions stop paying the exposed-latency penalty).
    /// Set to 1 to select the one-shot (PR 3) model. The current
    /// invalidation feedback provably reaches its fixed point in <= 2
    /// passes (demotion triggers are pass-invariant), so the default budget
    /// of 2 is exact; the knob stays a budget so richer cross-pass feedback
    /// (e.g. timing shifts) can land without an interface change.
    pub max_replay_iters: u32,
    /// Convergence threshold: the engine stops iterating once the pending
    /// stall correction (the cycles the next iteration would reclassify)
    /// falls to or below this many cycles.
    pub replay_epsilon: f64,
    /// Shared LLC capacity policy: `true` models a sliced LLC whose
    /// capacity scales with the active core count — each core brings its
    /// Table II slice, added as extra sets (power-of-two slicings; odd core
    /// counts round up via a second way bank) — while `false` keeps one
    /// fixed slice that all cores contend for. Either way the geometry at
    /// 1 core is exactly the Table II LLC, which the 1-core == seed
    /// differential tests pin.
    pub llc_sliced: bool,
    /// Cycles one lookup (or writeback install) occupies the shared LLC tag
    /// pipeline; queueing behind *other* cores' lookups is charged to the
    /// waiting core.
    pub llc_service_cycles: f64,
    /// Cycles a line transfer occupies its DRAM channel. Defaults to
    /// [`DRAM_BW_CYCLES`] so channel occupancy and the per-access bandwidth
    /// floor describe the same bus.
    pub dram_transfer_cycles: f64,
    /// Writer stall for invalidating remote sharers on a write to a
    /// write-shared line (MESI upgrade round-trip).
    pub upgrade_cycles: f64,
    /// Reader stall for a line whose last writer was another core (dirty
    /// data forwarded through the shared LLC).
    pub dirty_forward_cycles: f64,
    /// Extra exposed latency when a phase-1 shadow-LLC hit turns into a
    /// shared-LLC miss under real sharing pressure (capacity interference;
    /// charged on top of the unpaid bandwidth floor).
    pub demotion_cycles: f64,
}

impl Default for SharedMemConfig {
    fn default() -> Self {
        SharedMemConfig {
            dram_channels: 4,
            dram_banks: 4,
            row_buffer_lines: 128,
            row_hit_cycles: 0.0,
            row_miss_cycles: 18.0,
            row_conflict_cycles: 50.0,
            max_replay_iters: 2,
            replay_epsilon: 1e-6,
            llc_sliced: true,
            llc_service_cycles: 2.0,
            dram_transfer_cycles: DRAM_BW_CYCLES,
            upgrade_cycles: 24.0,
            dirty_forward_cycles: 24.0,
            demotion_cycles: 40.0,
        }
    }
}

/// Matrix-unit (systolic array) configuration.
#[derive(Clone, Copy, Debug)]
pub struct MatrixUnitConfig {
    /// PEs per row/column; also elements per matrix-register row (R = N = 16).
    pub n: usize,
    /// Number of physical matrix registers.
    pub num_regs: usize,
    /// MAC latency in CPU cycles (dense GEMM path; unused by sort/zip).
    pub mac_latency: u32,
    /// Fixed overhead for non-speculative issue of a sort/zip *pair* at the
    /// head of the ROB (drain + dispatch), in cycles.
    pub issue_overhead: u32,
    /// Pass turn-around stalls per micro-op batch (east/south -> west/north
    /// loop-back registers), in cycles.
    pub pass_stalls: u32,
}

/// Out-of-order core model constants (Table II) and first-order overlap
/// factors used by `sim::cost`. These are the *calibration knobs*: absolute
/// cycles are not gem5's, but relative behaviour tracks operation mix, cache
/// behaviour and matrix-unit occupancy (DESIGN.md "Substitutions").
#[derive(Clone, Copy, Debug)]
pub struct CoreConfig {
    /// Maximum scalar ops committed per cycle (8-way issue, dependency-limited).
    pub scalar_ipc: f64,
    /// 512-bit vector ops per cycle (two SIMD units).
    pub vector_ipc: f64,
    /// Loads/stores issued per cycle (two AGUs).
    pub mem_issue_per_cycle: f64,
    /// Memory-level parallelism divisor for scalar-miss latency overlap.
    pub mlp_scalar: f64,
    /// MLP divisor for vector unit-stride accesses.
    pub mlp_vector: f64,
    /// MLP divisor for vector gather/scatter accesses.
    pub mlp_gather: f64,
    /// Branch cost in cycles (amortized, incl. occasional mispredictions).
    pub branch_cost: f64,
}

/// Whole simulated system (Table II).
#[derive(Clone, Copy, Debug)]
pub struct SystemConfig {
    pub core: CoreConfig,
    pub mem: MemConfig,
    pub unit: MatrixUnitConfig,
    /// The shared memory system behind the private L1/L2s: one shared LLC
    /// with MESI-lite coherence bookkeeping and a multi-channel DRAM back
    /// end, priced by deterministic trace-and-replay.
    pub shared: SharedMemConfig,
    /// Elements per 512-bit vector register (ELEN=32 -> 16).
    pub vlen_elems: usize,
    /// Active cores sharing the LLC and DRAM channels. Each core has its own
    /// pipeline, private caches, and matrix unit (a [`crate::sim::Machine`]
    /// each, see [`crate::sim::Machine::fork_core`]); with `cores > 1` the
    /// parallel driver replays the per-core access traces through the shared
    /// LLC + DRAM model ([`crate::mem::shared::replay`]) to derive queueing,
    /// coherence, and sharing costs. Event *counts* are never affected.
    pub cores: usize,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            core: CoreConfig {
                scalar_ipc: 4.0,
                vector_ipc: 2.0,
                mem_issue_per_cycle: 2.0,
                mlp_scalar: 4.0,
                mlp_vector: 6.0,
                mlp_gather: 4.0,
                branch_cost: 0.75,
            },
            mem: MemConfig {
                l1d: CacheConfig {
                    size_bytes: 32 * 1024,
                    ways: 8,
                    line_bytes: 64,
                    hit_latency: 2,
                },
                l2: CacheConfig {
                    size_bytes: 256 * 1024,
                    ways: 4,
                    line_bytes: 64,
                    hit_latency: 8,
                },
                llc: CacheConfig {
                    size_bytes: 512 * 1024,
                    ways: 8,
                    line_bytes: 64,
                    hit_latency: 8,
                },
                dram_latency: 160,
            },
            unit: MatrixUnitConfig {
                n: 16,
                num_regs: 16,
                mac_latency: 4,
                issue_overhead: 4,
                pass_stalls: 2,
            },
            shared: SharedMemConfig::default(),
            vlen_elems: 16,
            cores: 1,
        }
    }
}

impl SystemConfig {
    /// Pretty-print the configuration (reproduces Table II).
    pub fn table2(&self) -> String {
        let m = &self.mem;
        let u = &self.unit;
        format!(
            "Table II. Baseline System Configuration (simulated)\n\
             CPU        | 8-way out-of-order issue (first-order model: {:.1} scalar IPC,\n\
             \x20          | {:.1} 512b vector IPC, {:.1} mem ops/cycle)\n\
             Matrix Unit| {}x{} PE systolic array, {} physical matrix registers,\n\
             \x20          | {}-cycle MAC, non-speculative sort/zip issue (+{} cycles)\n\
             L1D        | {}-way, {}KB, {}-cycle hit\n\
             L2         | {}-way, {}KB, {}-cycle hit\n\
             LLC        | {}-way, {}KB, {}-cycle hit (shared, {})\n\
             Memory     | DDR4-2400 ({} CPU cycles), {} channels\n",
            self.core.scalar_ipc,
            self.core.vector_ipc,
            self.core.mem_issue_per_cycle,
            u.n,
            u.n,
            u.num_regs,
            u.mac_latency,
            u.issue_overhead,
            m.l1d.ways,
            m.l1d.size_bytes / 1024,
            m.l1d.hit_latency,
            m.l2.ways,
            m.l2.size_bytes / 1024,
            m.l2.hit_latency,
            m.llc.ways,
            m.llc.size_bytes / 1024,
            m.llc.hit_latency,
            if self.shared.llc_sliced {
                "sliced per core"
            } else {
                "one fixed slice"
            },
            m.dram_latency,
            self.shared.dram_channels,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_table2() {
        let c = SystemConfig::default();
        assert_eq!(c.mem.l1d.size_bytes, 32 * 1024);
        assert_eq!(c.mem.l1d.ways, 8);
        assert_eq!(c.mem.l2.size_bytes, 256 * 1024);
        assert_eq!(c.mem.l2.ways, 4);
        assert_eq!(c.mem.llc.size_bytes, 512 * 1024);
        assert_eq!(c.unit.n, 16);
        assert_eq!(c.unit.num_regs, 16);
        assert_eq!(c.vlen_elems, 16);
        assert_eq!(c.cores, 1);
    }

    #[test]
    fn cache_sets() {
        let c = SystemConfig::default();
        assert_eq!(c.mem.l1d.sets(), 64);
        assert_eq!(c.mem.l2.sets(), 1024);
    }

    #[test]
    fn table2_renders() {
        let s = SystemConfig::default().table2();
        assert!(s.contains("16x16"));
        assert!(s.contains("32KB"));
        assert!(s.contains("4 channels"));
    }

    #[test]
    fn shared_mem_defaults_are_inert_at_one_core() {
        let c = SystemConfig::default();
        assert_eq!(c.shared.dram_channels, 4);
        assert!(c.shared.llc_sliced);
        assert_eq!(c.shared.dram_transfer_cycles, DRAM_BW_CYCLES);
        assert_eq!(c.shared.dram_banks, 4);
        assert_eq!(c.shared.row_buffer_lines, 128);
        assert!(c.shared.max_replay_iters >= 2, "fixed point needs >= 2 passes");
        assert!(c.shared.replay_epsilon >= 0.0);
        assert!(c.shared.row_conflict_cycles >= c.shared.row_miss_cycles);
    }
}
