//! System configuration mirroring Table II of the paper plus the first-order
//! timing-model constants (documented calibration knobs; see DESIGN.md §6).
//!
//! The simulated machine is an aggressive 8-way out-of-order core with two
//! 512-bit SIMD units and a 16x16 systolic matrix unit, fronted by a
//! 32KB L1D / 256KB L2 / 512KB LLC hierarchy over DDR4-2400.

/// Cycles of DRAM *bandwidth* occupancy per line transfer — a floor that
/// memory-level parallelism cannot hide (64B line at ~20GB/s on a ~3GHz
/// core). Charged on every DRAM-reaching access by [`crate::sim::CostModel`]
/// and used as the per-channel transfer occupancy by the shared-memory
/// replay ([`crate::mem::shared`]).
pub const DRAM_BW_CYCLES: f64 = 6.0;

/// Upper bound on [`SharedMemConfig::sockets`]: trace events carry the
/// requesting core's socket id in 4 packed bits (see [`crate::mem::trace`]).
pub const MAX_SOCKETS: usize = 16;

/// DRAM page-placement policy: which socket's channel group a line's page
/// is served from (`spz ... --page-placement`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PagePlacement {
    /// The historical blind interleave: line `l` goes to channel
    /// `l % dram_channels` regardless of who touches it, so at more than
    /// one socket a page's lines are striped across *all* sockets and
    /// every core pays remote hops for most of its traffic — the model
    /// `ws-numa` had to fight rather than cooperate with.
    Interleave,
    /// First-touch (the OS default on real NUMA parts): a 4KB page's home
    /// is the socket of the core that first demands any of its lines (in
    /// deterministic canonical merge order), and the page's lines
    /// interleave over that socket's channel group only. At one socket
    /// this degenerates to exactly the blind interleave bit for bit.
    FirstTouch,
}

impl PagePlacement {
    /// CLI/debug name (`interleave` / `first-touch`).
    pub fn name(&self) -> &'static str {
        match self {
            PagePlacement::Interleave => "interleave",
            PagePlacement::FirstTouch => "first-touch",
        }
    }

    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Option<PagePlacement> {
        match s {
            "interleave" => Some(PagePlacement::Interleave),
            "first-touch" | "firsttouch" | "first_touch" => Some(PagePlacement::FirstTouch),
            _ => None,
        }
    }
}

/// One cache level's geometry and hit latency (Table II).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheConfig {
    pub size_bytes: usize,
    pub ways: usize,
    pub line_bytes: usize,
    pub hit_latency: u32,
}

impl CacheConfig {
    pub fn sets(&self) -> usize {
        self.size_bytes / (self.ways * self.line_bytes)
    }
}

/// Full memory-hierarchy configuration.
#[derive(Clone, Copy, Debug)]
pub struct MemConfig {
    pub l1d: CacheConfig,
    pub l2: CacheConfig,
    pub llc: CacheConfig,
    /// DRAM access latency in CPU cycles (DDR4-2400 at ~3 GHz core clock).
    pub dram_latency: u32,
}

/// The shared end of the memory system: one LLC shared by all active cores
/// plus a multi-channel DRAM back end with per-channel bank/row-buffer
/// state, modeled by the iterative deterministic trace-and-replay engine
/// (see [`crate::mem::trace`] and [`crate::mem::shared::ReplayEngine`]).
/// All cost fields are calibration knobs in the DESIGN.md spirit: relative
/// multi-core behaviour is what matters, and every one of them contributes
/// *zero* cycles when a single core runs alone.
#[derive(Clone, Copy, Debug)]
pub struct SharedMemConfig {
    /// Independent DRAM channels; lines are channel-interleaved by address
    /// (`line % dram_channels`), so streaming traffic spreads while pathological
    /// same-channel conflicts stay representable.
    pub dram_channels: usize,
    /// DRAM banks per channel. Within a channel, consecutive lines fill one
    /// bank's row buffer for [`SharedMemConfig::row_buffer_lines`] lines
    /// before rotating to the next bank, so streams keep rows open while
    /// interleaved streams from other cores close them.
    pub dram_banks: usize,
    /// Cache lines per DRAM row buffer (row size / line size; 8KB rows of
    /// 64B lines = 128).
    pub row_buffer_lines: usize,
    /// Service cost of a row-buffer *hit* (the open-row fast path), used as
    /// the baseline the miss/conflict costs are priced against. The replay
    /// charges only the *difference* between the shared bank outcome and the
    /// core's private shadow bank outcome, so single-stream row behaviour
    /// stays phase 1's business and everything is exactly zero at 1 core.
    pub row_hit_cycles: f64,
    /// Service cost of a row-buffer miss (precharge + activate) caused by
    /// the core's own stream turning the row.
    pub row_miss_cycles: f64,
    /// Service cost of a row-buffer *conflict*: the row this core's stream
    /// had open was closed by another core's interleaved traffic.
    pub row_conflict_cycles: f64,
    /// Upper bound on replay iterations of the
    /// [`crate::mem::shared::ReplayEngine`]: iteration k+1 re-replays with
    /// the shadow-LLC lines that iteration k demoted treated as invalidated
    /// (so repeat demotions stop paying the exposed-latency penalty).
    /// Set to 1 to select the one-shot (PR 3) model. The current
    /// invalidation feedback provably reaches its fixed point in <= 2
    /// passes (demotion triggers are pass-invariant), so the default budget
    /// of 2 is exact; the knob stays a budget so richer cross-pass feedback
    /// (e.g. timing shifts) can land without an interface change.
    pub max_replay_iters: u32,
    /// Convergence threshold: the engine stops iterating once the pending
    /// stall correction (the cycles the next iteration would reclassify)
    /// falls to or below this many cycles.
    pub replay_epsilon: f64,
    /// Worker shards the replay engine spreads each pass across (`spz ...
    /// --replay-shards N`). Lines partition by `line % replay_shards`, a
    /// power of two that divides the LLC set count, so every shard owns a
    /// disjoint slice of LLC sets, directory lines, and demotion triggers;
    /// the order-coupled accounting (queue tails, DRAM banks, every float
    /// accumulation) stays in a serial canonical-order merge pass consuming
    /// the shards' discrete outcomes. The result is **bit-identical at
    /// every shard count** — sharding is purely a wall-clock knob, which is
    /// why it never appears in the JSON exports. Must be a power of two in
    /// `1..=64` ([`SharedMemConfig::validate`] rejects anything else; the
    /// engine never clamps).
    pub replay_shards: usize,
    /// Per-core trace ring budget for the streaming pipeline (`spz ...
    /// --trace-ring-chunks N`): the maximum sealed 64KB trace chunks a
    /// core's stream keeps resident before the oldest chunks spill to a
    /// temp file (demand-loaded back in merge order), bounding peak trace
    /// memory at `cores * N` chunks for >RAM jobs. `0` (the default) means
    /// unbounded — everything stays resident and nothing spills. Spilling
    /// never changes results (the stream replays the identical event
    /// sequence), so like `replay_shards` this is a pure footprint knob;
    /// unlike it, the two ring-dependent footprint *counters* do surface in
    /// the JSON and are zeroed in its stable form. Must be `0` or at least
    /// `2` ([`SharedMemConfig::validate`] rejects `1`; the writer always
    /// needs one chunk open plus one sealed to make progress without
    /// thrashing the spill file).
    pub trace_ring_chunks: usize,
    /// Shared LLC capacity policy: `true` models a sliced LLC whose
    /// capacity scales with the active core count — each core brings its
    /// Table II slice, added as extra sets (power-of-two slicings; odd core
    /// counts round up via a second way bank) — while `false` keeps one
    /// fixed slice that all cores contend for. Either way the geometry at
    /// 1 core is exactly the Table II LLC, which the 1-core == seed
    /// differential tests pin.
    pub llc_sliced: bool,
    /// Cycles one lookup (or writeback install) occupies the shared LLC tag
    /// pipeline; queueing behind *other* cores' lookups is charged to the
    /// waiting core.
    pub llc_service_cycles: f64,
    /// Cycles a line transfer occupies its DRAM channel. Defaults to
    /// [`DRAM_BW_CYCLES`] so channel occupancy and the per-access bandwidth
    /// floor describe the same bus.
    pub dram_transfer_cycles: f64,
    /// Writer stall for invalidating remote sharers on a write to a
    /// write-shared line (MESI upgrade round-trip).
    pub upgrade_cycles: f64,
    /// Reader stall for a line whose last writer was another core (dirty
    /// data forwarded through the shared LLC).
    pub dirty_forward_cycles: f64,
    /// Extra exposed latency when a phase-1 shadow-LLC hit turns into a
    /// shared-LLC miss under real sharing pressure (capacity interference;
    /// charged on top of the unpaid bandwidth floor).
    pub demotion_cycles: f64,
    /// CPU sockets (NUMA nodes). The DRAM channels are split into
    /// `sockets` contiguous *channel groups* (channel `c` belongs to socket
    /// `c * sockets / dram_channels`; [`SharedMemConfig::validate`] requires
    /// `dram_channels % sockets == 0` so the groups are equal), and cores
    /// are assigned to sockets in contiguous blocks by
    /// [`SharedMemConfig::socket_of_core`]. Every LLC fill, dirty forward,
    /// and DRAM transfer is then priced by the requesting core's
    /// [`SharedMemConfig::socket_distance`] to the line's home socket —
    /// all distances are zero at `sockets == 1`, so the default is exactly
    /// the flat (PR 4) model bit for bit.
    pub sockets: usize,
    /// Extra cycles per interconnect *hop* a DRAM line transfer pays when
    /// the requesting core's socket is not the channel's home socket
    /// (remote memory access: the QPI/UPI traversal both lengthens the
    /// exposed latency and occupies the channel end-to-end for longer).
    /// Multiplied by the hop distance; zero-hop (local) transfers pay
    /// nothing extra.
    pub remote_transfer_cycles: f64,
    /// Extra cycles per interconnect hop for cross-socket *coherence*
    /// traffic: a dirty forward from a core on another socket, an upgrade
    /// whose invalidations cross the interconnect, or a shared-LLC hit
    /// served by a remote socket's slice. Multiplied by the hop distance.
    pub remote_coherence_cycles: f64,
    /// How DRAM pages map to socket channel groups (see
    /// [`PagePlacement`]). Defaults to first-touch, which is structurally
    /// identical to the blind interleave at one socket, so every 1-socket
    /// result is unchanged bit for bit.
    pub page_placement: PagePlacement,
}

impl Default for SharedMemConfig {
    fn default() -> Self {
        SharedMemConfig {
            dram_channels: 4,
            dram_banks: 4,
            row_buffer_lines: 128,
            row_hit_cycles: 0.0,
            row_miss_cycles: 18.0,
            row_conflict_cycles: 50.0,
            max_replay_iters: 2,
            replay_epsilon: 1e-6,
            replay_shards: 1,
            trace_ring_chunks: 0,
            llc_sliced: true,
            llc_service_cycles: 2.0,
            dram_transfer_cycles: DRAM_BW_CYCLES,
            upgrade_cycles: 24.0,
            dirty_forward_cycles: 24.0,
            demotion_cycles: 40.0,
            sockets: 1,
            remote_transfer_cycles: 12.0,
            remote_coherence_cycles: 24.0,
            page_placement: PagePlacement::FirstTouch,
        }
    }
}

impl SharedMemConfig {
    /// Validate the knob ranges once, at the API/CLI boundary (like the
    /// 64-core check): every count must be at least 1 — the replay divides
    /// by them and sizes its per-channel vectors from them — and the
    /// socket topology must tile the channels evenly. The replay engine
    /// asserts the same invariants instead of silently clamping.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.dram_channels >= 1,
            "SharedMemConfig.dram_channels must be at least 1 (got {})",
            self.dram_channels
        );
        anyhow::ensure!(
            self.dram_banks >= 1,
            "SharedMemConfig.dram_banks must be at least 1 (got {})",
            self.dram_banks
        );
        anyhow::ensure!(
            self.row_buffer_lines >= 1,
            "SharedMemConfig.row_buffer_lines must be at least 1 (got {})",
            self.row_buffer_lines
        );
        anyhow::ensure!(
            self.max_replay_iters >= 1,
            "SharedMemConfig.max_replay_iters must be at least 1 (got {}); use 1 to \
             select the one-shot model",
            self.max_replay_iters
        );
        anyhow::ensure!(
            self.replay_epsilon >= 0.0 && self.replay_epsilon.is_finite(),
            "SharedMemConfig.replay_epsilon must be finite and non-negative (got {})",
            self.replay_epsilon
        );
        anyhow::ensure!(
            (1..=64).contains(&self.replay_shards) && self.replay_shards.is_power_of_two(),
            "SharedMemConfig.replay_shards must be a power of two between 1 and 64 \
             (got {}): the line partition must tile the power-of-two LLC set index",
            self.replay_shards
        );
        anyhow::ensure!(
            self.trace_ring_chunks != 1,
            "SharedMemConfig.trace_ring_chunks must be 0 (unbounded) or at least 2 \
             (got 1): a ring of one chunk would spill every seal"
        );
        anyhow::ensure!(
            (1..=MAX_SOCKETS).contains(&self.sockets),
            "SharedMemConfig.sockets must be between 1 and {MAX_SOCKETS} (trace events \
             carry the socket id in 4 bits), got {}",
            self.sockets
        );
        anyhow::ensure!(
            self.dram_channels % self.sockets == 0,
            "SharedMemConfig.dram_channels ({}) must be a multiple of sockets ({}) so \
             each socket owns an equal channel group",
            self.dram_channels,
            self.sockets
        );
        anyhow::ensure!(
            self.remote_transfer_cycles >= 0.0 && self.remote_transfer_cycles.is_finite(),
            "SharedMemConfig.remote_transfer_cycles must be finite and non-negative"
        );
        anyhow::ensure!(
            self.remote_coherence_cycles >= 0.0 && self.remote_coherence_cycles.is_finite(),
            "SharedMemConfig.remote_coherence_cycles must be finite and non-negative"
        );
        Ok(())
    }

    /// Socket a simulated core belongs to: contiguous blocks (cores
    /// `[0, cores/sockets)` on socket 0, the next block on socket 1, ...)
    /// the way real parts number them. Always 0 at one socket.
    pub fn socket_of_core(&self, core: usize, cores: usize) -> usize {
        let sockets = self.sockets.max(1);
        (core * sockets / cores.max(1)).min(sockets - 1)
    }

    /// Home socket of a DRAM channel: contiguous channel groups (channels
    /// `[0, dram_channels/sockets)` belong to socket 0, ...).
    pub fn socket_of_channel(&self, channel: usize) -> usize {
        let sockets = self.sockets.max(1);
        (channel * sockets / self.dram_channels.max(1)).min(sockets - 1)
    }

    /// Interconnect hop distance between two sockets — the distance matrix
    /// the NUMA charges scale with. Modeled as a ring (the common 2/4-socket
    /// topology): 0 intra-socket, and `min(|a-b|, sockets-|a-b|)` hops
    /// otherwise, so at 2 sockets every remote access is exactly one hop.
    pub fn socket_distance(&self, a: usize, b: usize) -> usize {
        let sockets = self.sockets.max(1);
        let d = a.abs_diff(b);
        d.min(sockets - d)
    }
}

/// Matrix-unit (systolic array) configuration.
#[derive(Clone, Copy, Debug)]
pub struct MatrixUnitConfig {
    /// PEs per row/column; also elements per matrix-register row (R = N = 16).
    pub n: usize,
    /// Number of physical matrix registers.
    pub num_regs: usize,
    /// MAC latency in CPU cycles (dense GEMM path; unused by sort/zip).
    pub mac_latency: u32,
    /// Fixed overhead for non-speculative issue of a sort/zip *pair* at the
    /// head of the ROB (drain + dispatch), in cycles.
    pub issue_overhead: u32,
    /// Pass turn-around stalls per micro-op batch (east/south -> west/north
    /// loop-back registers), in cycles.
    pub pass_stalls: u32,
}

/// Out-of-order core model constants (Table II) and first-order overlap
/// factors used by `sim::cost`. These are the *calibration knobs*: absolute
/// cycles are not gem5's, but relative behaviour tracks operation mix, cache
/// behaviour and matrix-unit occupancy (DESIGN.md "Substitutions").
#[derive(Clone, Copy, Debug)]
pub struct CoreConfig {
    /// Maximum scalar ops committed per cycle (8-way issue, dependency-limited).
    pub scalar_ipc: f64,
    /// 512-bit vector ops per cycle (two SIMD units).
    pub vector_ipc: f64,
    /// Loads/stores issued per cycle (two AGUs).
    pub mem_issue_per_cycle: f64,
    /// Memory-level parallelism divisor for scalar-miss latency overlap.
    pub mlp_scalar: f64,
    /// MLP divisor for vector unit-stride accesses.
    pub mlp_vector: f64,
    /// MLP divisor for vector gather/scatter accesses.
    pub mlp_gather: f64,
    /// Branch cost in cycles (amortized, incl. occasional mispredictions).
    pub branch_cost: f64,
}

/// Whole simulated system (Table II).
#[derive(Clone, Copy, Debug)]
pub struct SystemConfig {
    pub core: CoreConfig,
    pub mem: MemConfig,
    pub unit: MatrixUnitConfig,
    /// The shared memory system behind the private L1/L2s: one shared LLC
    /// with MESI-lite coherence bookkeeping and a multi-channel DRAM back
    /// end, priced by deterministic trace-and-replay.
    pub shared: SharedMemConfig,
    /// Elements per 512-bit vector register (ELEN=32 -> 16).
    pub vlen_elems: usize,
    /// Active cores sharing the LLC and DRAM channels. Each core has its own
    /// pipeline, private caches, and matrix unit (a [`crate::sim::Machine`]
    /// each, see [`crate::sim::Machine::fork_core`]); with `cores > 1` the
    /// parallel driver replays the per-core access traces through the shared
    /// LLC + DRAM model ([`crate::mem::shared::replay`]) to derive queueing,
    /// coherence, and sharing costs. Event *counts* are never affected.
    pub cores: usize,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            core: CoreConfig {
                scalar_ipc: 4.0,
                vector_ipc: 2.0,
                mem_issue_per_cycle: 2.0,
                mlp_scalar: 4.0,
                mlp_vector: 6.0,
                mlp_gather: 4.0,
                branch_cost: 0.75,
            },
            mem: MemConfig {
                l1d: CacheConfig {
                    size_bytes: 32 * 1024,
                    ways: 8,
                    line_bytes: 64,
                    hit_latency: 2,
                },
                l2: CacheConfig {
                    size_bytes: 256 * 1024,
                    ways: 4,
                    line_bytes: 64,
                    hit_latency: 8,
                },
                llc: CacheConfig {
                    size_bytes: 512 * 1024,
                    ways: 8,
                    line_bytes: 64,
                    hit_latency: 8,
                },
                dram_latency: 160,
            },
            unit: MatrixUnitConfig {
                n: 16,
                num_regs: 16,
                mac_latency: 4,
                issue_overhead: 4,
                pass_stalls: 2,
            },
            shared: SharedMemConfig::default(),
            vlen_elems: 16,
            cores: 1,
        }
    }
}

impl SystemConfig {
    /// Pretty-print the configuration (reproduces Table II).
    pub fn table2(&self) -> String {
        let m = &self.mem;
        let u = &self.unit;
        format!(
            "Table II. Baseline System Configuration (simulated)\n\
             CPU        | 8-way out-of-order issue (first-order model: {:.1} scalar IPC,\n\
             \x20          | {:.1} 512b vector IPC, {:.1} mem ops/cycle)\n\
             Matrix Unit| {}x{} PE systolic array, {} physical matrix registers,\n\
             \x20          | {}-cycle MAC, non-speculative sort/zip issue (+{} cycles)\n\
             L1D        | {}-way, {}KB, {}-cycle hit\n\
             L2         | {}-way, {}KB, {}-cycle hit\n\
             LLC        | {}-way, {}KB, {}-cycle hit (shared, {})\n\
             Memory     | DDR4-2400 ({} CPU cycles), {} channels across {} socket(s)\n",
            self.core.scalar_ipc,
            self.core.vector_ipc,
            self.core.mem_issue_per_cycle,
            u.n,
            u.n,
            u.num_regs,
            u.mac_latency,
            u.issue_overhead,
            m.l1d.ways,
            m.l1d.size_bytes / 1024,
            m.l1d.hit_latency,
            m.l2.ways,
            m.l2.size_bytes / 1024,
            m.l2.hit_latency,
            m.llc.ways,
            m.llc.size_bytes / 1024,
            m.llc.hit_latency,
            if self.shared.llc_sliced {
                "sliced per core"
            } else {
                "one fixed slice"
            },
            m.dram_latency,
            self.shared.dram_channels,
            self.shared.sockets,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_table2() {
        let c = SystemConfig::default();
        assert_eq!(c.mem.l1d.size_bytes, 32 * 1024);
        assert_eq!(c.mem.l1d.ways, 8);
        assert_eq!(c.mem.l2.size_bytes, 256 * 1024);
        assert_eq!(c.mem.l2.ways, 4);
        assert_eq!(c.mem.llc.size_bytes, 512 * 1024);
        assert_eq!(c.unit.n, 16);
        assert_eq!(c.unit.num_regs, 16);
        assert_eq!(c.vlen_elems, 16);
        assert_eq!(c.cores, 1);
    }

    #[test]
    fn cache_sets() {
        let c = SystemConfig::default();
        assert_eq!(c.mem.l1d.sets(), 64);
        assert_eq!(c.mem.l2.sets(), 1024);
    }

    #[test]
    fn table2_renders() {
        let s = SystemConfig::default().table2();
        assert!(s.contains("16x16"));
        assert!(s.contains("32KB"));
        assert!(s.contains("4 channels"));
    }

    #[test]
    fn shared_mem_defaults_are_inert_at_one_core() {
        let c = SystemConfig::default();
        assert_eq!(c.shared.dram_channels, 4);
        assert!(c.shared.llc_sliced);
        assert_eq!(c.shared.dram_transfer_cycles, DRAM_BW_CYCLES);
        assert_eq!(c.shared.dram_banks, 4);
        assert_eq!(c.shared.row_buffer_lines, 128);
        assert!(c.shared.max_replay_iters >= 2, "fixed point needs >= 2 passes");
        assert!(c.shared.replay_epsilon >= 0.0);
        assert!(c.shared.row_conflict_cycles >= c.shared.row_miss_cycles);
        // The default is a single socket: every NUMA distance is zero, so
        // the flat (PR 4) model is reproduced bit for bit.
        assert_eq!(c.shared.sockets, 1);
        assert!(c.shared.validate().is_ok());
        for core in 0..8 {
            assert_eq!(c.shared.socket_of_core(core, 8), 0);
        }
        for ch in 0..c.shared.dram_channels {
            assert_eq!(c.shared.socket_of_channel(ch), 0);
        }
        assert_eq!(c.shared.socket_distance(0, 0), 0);
    }

    #[test]
    fn socket_maps_are_contiguous_and_distances_ring() {
        let s = SharedMemConfig {
            sockets: 2,
            ..SharedMemConfig::default()
        };
        assert!(s.validate().is_ok());
        // 8 cores over 2 sockets: contiguous halves.
        let socks: Vec<usize> = (0..8).map(|c| s.socket_of_core(c, 8)).collect();
        assert_eq!(socks, vec![0, 0, 0, 0, 1, 1, 1, 1]);
        // 4 channels over 2 sockets: contiguous channel groups.
        let chans: Vec<usize> = (0..4).map(|c| s.socket_of_channel(c)).collect();
        assert_eq!(chans, vec![0, 0, 1, 1]);
        assert_eq!(s.socket_distance(0, 1), 1);
        assert_eq!(s.socket_distance(1, 0), 1);
        assert_eq!(s.socket_distance(1, 1), 0);
        // Fewer cores than sockets still maps into range.
        assert!(s.socket_of_core(0, 1) < 2);
        // Ring distance at 4 sockets: opposite corners are 2 hops, neighbours
        // (including the wrap-around pair) are 1.
        let q = SharedMemConfig { sockets: 4, ..SharedMemConfig::default() };
        assert_eq!(q.socket_distance(0, 2), 2);
        assert_eq!(q.socket_distance(0, 3), 1);
        assert_eq!(q.socket_distance(1, 2), 1);
    }

    #[test]
    fn shared_mem_validation_rejects_bad_knobs() {
        let base = SharedMemConfig::default();
        assert!(SharedMemConfig { dram_channels: 0, ..base }.validate().is_err());
        assert!(SharedMemConfig { dram_banks: 0, ..base }.validate().is_err());
        assert!(SharedMemConfig { row_buffer_lines: 0, ..base }.validate().is_err());
        assert!(SharedMemConfig { sockets: 0, ..base }.validate().is_err());
        assert!(SharedMemConfig { sockets: MAX_SOCKETS + 1, ..base }.validate().is_err());
        // 4 channels cannot split into 3 equal groups.
        assert!(SharedMemConfig { sockets: 3, ..base }.validate().is_err());
        assert!(SharedMemConfig { sockets: 4, ..base }.validate().is_ok());
        assert!(
            SharedMemConfig { remote_transfer_cycles: f64::NAN, ..base }
                .validate()
                .is_err()
        );
        assert!(
            SharedMemConfig { remote_coherence_cycles: -1.0, ..base }
                .validate()
                .is_err()
        );
        // The iteration budget and epsilon are validated, never clamped.
        assert!(SharedMemConfig { max_replay_iters: 0, ..base }.validate().is_err());
        assert!(SharedMemConfig { replay_epsilon: -1.0, ..base }.validate().is_err());
        assert!(SharedMemConfig { replay_epsilon: f64::NAN, ..base }.validate().is_err());
        // Shard counts: powers of two in 1..=64 only.
        assert!(SharedMemConfig { replay_shards: 0, ..base }.validate().is_err());
        assert!(SharedMemConfig { replay_shards: 3, ..base }.validate().is_err());
        assert!(SharedMemConfig { replay_shards: 128, ..base }.validate().is_err());
        for s in [1usize, 2, 4, 8, 16, 32, 64] {
            assert!(SharedMemConfig { replay_shards: s, ..base }.validate().is_ok(), "{s}");
        }
        // Trace ring budgets: 0 = unbounded, otherwise at least 2.
        assert!(SharedMemConfig { trace_ring_chunks: 1, ..base }.validate().is_err());
        for r in [0usize, 2, 3, 16, 1024] {
            assert!(SharedMemConfig { trace_ring_chunks: r, ..base }.validate().is_ok(), "{r}");
        }
    }
}
