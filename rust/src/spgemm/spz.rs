//! `spz`: merge-based row-wise SpGEMM using the SparseZipper ISA (§III-D).
//!
//! Sixteen output rows (one per matrix-register row) are processed as a
//! lockstep *group* of key-value streams:
//!
//! 1. **Expansion** (RISC-V vector): partial products appended per stream
//!    with unit-stride vector stores.
//! 2. **Chunk sort** (`mlxe` + `mssortk/mssortv` + `mmv` + `msxe`): every
//!    16-element chunk becomes a sorted-unique partition.
//! 3. **Merge rounds** (`mlxe` + `mszipk/mszipv` + `mmv` + `msxe`):
//!    adjacent partitions merge chunk-at-a-time (Figure 2) until one
//!    sorted-unique partition per stream remains. Consumed counts come from
//!    IC0/IC1; east+south output chunks are streamed out per OC0/OC1.
//! 4. **Output generation**: the final partition is copied into the output
//!    CSR with unit-stride vector ops.
//!
//! The functional datapath runs through a [`ZipUnit`] engine — native Rust
//! or the AOT-compiled XLA artifacts — while the `Machine` charges identical
//! timing either way.

use crate::matrix::Csr;
#[cfg(feature = "xla")]
use crate::runtime::XlaEngine;
use crate::runtime::{NativeEngine, StepOut, ZipUnit};
use crate::sim::{Machine, Phase};
use crate::spgemm::{CsrAddrs, SpGemm};
use crate::util::ceil_div;
use anyhow::Result;
#[cfg(feature = "xla")]
use std::path::Path;

/// One sorted-unique partition of a stream (functional mirror + its
/// simulated element offset within the current arena).
#[derive(Clone, Debug, Default)]
struct Part {
    keys: Vec<u32>,
    vals: Vec<f32>,
    sim_off: u64,
}

pub struct Spz {
    engine: Box<dyn ZipUnit>,
}

impl Spz {
    pub fn native() -> Self {
        Spz {
            engine: Box::new(NativeEngine::new(16)),
        }
    }

    #[cfg(feature = "xla")]
    pub fn xla(artifact_dir: &Path) -> Result<Self> {
        Ok(Spz {
            engine: Box::new(XlaEngine::load(artifact_dir, 16, 16)?),
        })
    }

    pub fn with_engine(engine: Box<dyn ZipUnit>) -> Self {
        Spz { engine }
    }

    /// Core row-wise merge SpGEMM over groups of N streams. `order` remaps
    /// the processing order of rows (spz-rsort); output stays in row order.
    pub(crate) fn run(
        &mut self,
        m: &mut Machine,
        a: &Csr,
        b: &Csr,
        order: Option<&[u32]>,
    ) -> Result<Csr> {
        let n = self.engine.n(); // chunk size = matrix register rows
        let vl = m.cfg.vlen_elems;
        let aa = CsrAddrs::register(m, a);
        let ba = CsrAddrs::register_shared(m, b);

        // --- Preprocess: work + padded temp offsets (§V-B). ---------------
        let work = crate::spgemm::prep::row_work(m, a, b, &aa, &ba);
        let padded: Vec<u64> = work.iter().map(|&w| w.div_ceil(n as u64) * n as u64).collect();
        let total_work: u64 = work.iter().sum();

        // Max group footprint so the ping-pong arenas are allocated once.
        let row_at = |g: usize, s: usize| -> Option<usize> {
            let i = g * n + s;
            if i >= a.nrows {
                return None;
            }
            Some(match order {
                Some(o) => o[i] as usize,
                None => i,
            })
        };
        let ngroups = ceil_div(a.nrows, n);
        let mut max_group_work = 0u64;
        for g in 0..ngroups {
            let w: u64 = (0..n).filter_map(|s| row_at(g, s)).map(|r| padded[r]).sum();
            max_group_work = max_group_work.max(w);
        }
        m.phase(Phase::Preprocess);
        let arena_k = [
            m.salloc((max_group_work.max(1) as usize) * 4),
            m.salloc((max_group_work.max(1) as usize) * 4),
        ];
        let arena_v = [
            m.salloc((max_group_work.max(1) as usize) * 4),
            m.salloc((max_group_work.max(1) as usize) * 4),
        ];
        let out = CsrAddrs::register_output(m, a.nrows, total_work.max(1) as usize);
        let (out_idx_addr, out_val_addr, out_ptr_addr) = (out.indices, out.data, out.indptr);

        let mut rows_out: Vec<(Vec<u32>, Vec<f32>)> = vec![(Vec::new(), Vec::new()); a.nrows];
        let mut out_cursor = 0u64;

        for g in 0..ngroups {
            let streams: Vec<usize> = (0..n).filter_map(|s| row_at(g, s)).collect();
            let gsize = streams.len();
            // Per-stream element offsets in the arenas.
            let mut offs = Vec::with_capacity(gsize);
            {
                let mut acc = 0u64;
                for &r in &streams {
                    offs.push(acc);
                    acc += padded[r];
                }
            }

            // --- 1. Expansion (vectorized, unit-stride stores). ------------
            m.phase(Phase::Expand);
            let mut exp_k: Vec<Vec<u32>> = vec![Vec::new(); gsize];
            let mut exp_v: Vec<Vec<f32>> = vec![Vec::new(); gsize];
            for (s, &r) in streams.iter().enumerate() {
                let (ak, av) = a.row(r);
                m.load(aa.indptr_at(r + 1), 8);
                // A-side streamed with vector loads; B row extents gathered
                // (vectorized RVV expansion, paper SS V-B).
                for (ci, chunk) in ak.chunks(vl).enumerate() {
                    m.vload(aa.idx_at(a.indptr[r] + ci * vl), chunk.len() * 4);
                    m.vload(aa.val_at(a.indptr[r] + ci * vl), chunk.len() * 4);
                    m.vgather(chunk.iter().map(|&j| ba.indptr_at(j as usize)), 8);
                    m.vector_ops(2);
                }
                for (&j, &aval) in ak.iter().zip(av) {
                    let (bk, bv) = b.row(j as usize);
                    let b_base = b.indptr[j as usize];
                    let mut bi = 0;
                    while bi < bk.len() {
                        let c = (bk.len() - bi).min(vl);
                        m.vload(ba.idx_at(b_base + bi), c * 4);
                        m.vload(ba.val_at(b_base + bi), c * 4);
                        m.vector_ops(1); // broadcast-multiply
                        let pos = offs[s] + exp_k[s].len() as u64;
                        m.vstore(arena_k[0] + pos * 4, c * 4);
                        m.vstore(arena_v[0] + pos * 4, c * 4);
                        for t in 0..c {
                            exp_k[s].push(bk[bi + t]);
                            exp_v[s].push(aval * bv[bi + t]);
                        }
                        bi += c;
                    }
                    m.scalar_ops(1);
                }
            }

            // --- 2. Chunk sort: every chunk -> sorted-unique partition. ----
            m.phase(Phase::Sort);
            let mut parts: Vec<Vec<Part>> = vec![Vec::new(); gsize];
            let max_chunks = streams
                .iter()
                .enumerate()
                .map(|(s, _)| ceil_div(exp_k[s].len(), n))
                .max()
                .unwrap_or(0);
            let mut c0 = 0usize;
            while c0 < max_chunks {
                // Gather chunk c0 (-> td0/td1) and c0+1 (-> td2/td3) per stream.
                let mut k0 = Vec::with_capacity(gsize);
                let mut v0 = Vec::with_capacity(gsize);
                let mut k1 = Vec::with_capacity(gsize);
                let mut v1 = Vec::with_capacity(gsize);
                let mut rows0: Vec<(u64, usize)> = Vec::with_capacity(gsize);
                let mut rows1: Vec<(u64, usize)> = Vec::with_capacity(gsize);
                for s in 0..gsize {
                    let len = exp_k[s].len();
                    let chunk = |c: usize| -> (usize, usize) {
                        let lo = (c * n).min(len);
                        let hi = ((c + 1) * n).min(len);
                        (lo, hi)
                    };
                    let (lo0, hi0) = chunk(c0);
                    let (lo1, hi1) = chunk(c0 + 1);
                    k0.push(exp_k[s][lo0..hi0].to_vec());
                    v0.push(exp_v[s][lo0..hi0].to_vec());
                    k1.push(exp_k[s][lo1..hi1].to_vec());
                    v1.push(exp_v[s][lo1..hi1].to_vec());
                    rows0.push((arena_k[0] + (offs[s] + lo0 as u64) * 4, hi0 - lo0));
                    rows1.push((arena_k[0] + (offs[s] + lo1 as u64) * 4, hi1 - lo1));
                }
                let active = rows0.iter().chain(&rows1).filter(|r| r.1 > 0).count();
                if active == 0 {
                    break;
                }
                // mlxe x4 (keys+vals for both chunk sets).
                m.mlxe(rows0.iter());
                m.mlxe(rows0.iter()); // values (same addresses in arena_v)
                m.mlxe(rows1.iter());
                m.mlxe(rows1.iter());
                m.sort_pair(gsize);
                m.mmv(2); // OC0, OC1
                m.vector_ops(2); // length bookkeeping
                let step = self.engine.sort_step(&k0, &v0, &k1, &v1)?;
                // msxe x4: sorted chunks written back in place.
                let st0: Vec<(u64, usize)> = (0..gsize)
                    .map(|s| (rows0[s].0, step.oc0[s]))
                    .collect();
                let st1: Vec<(u64, usize)> = (0..gsize)
                    .map(|s| (rows1[s].0, step.oc1[s]))
                    .collect();
                m.msxe(st0.iter());
                m.msxe(st0.iter());
                m.msxe(st1.iter());
                m.msxe(st1.iter());
                for s in 0..gsize {
                    if !step.k0[s].is_empty() || rows0[s].1 > 0 {
                        parts[s].push(Part {
                            keys: step.k0[s].clone(),
                            vals: step.v0[s].clone(),
                            sim_off: offs[s] + (c0 * n) as u64,
                        });
                    }
                    if !step.k1[s].is_empty() || rows1[s].1 > 0 {
                        parts[s].push(Part {
                            keys: step.k1[s].clone(),
                            vals: step.v1[s].clone(),
                            sim_off: offs[s] + ((c0 + 1) * n) as u64,
                        });
                    }
                }
                c0 += 2;
            }

            // --- 3. Merge rounds: pairwise zip until one partition. --------
            let mut src_arena = 0usize;
            loop {
                let max_parts = parts.iter().map(|p| p.len()).max().unwrap_or(0);
                if max_parts <= 1 {
                    break;
                }
                let dst_arena = 1 - src_arena;
                let pairs = ceil_div(max_parts, 2);
                let mut new_parts: Vec<Vec<Part>> = vec![Vec::new(); gsize];
                // Running output offset per stream in the destination arena.
                let mut dst_off: Vec<u64> = offs.clone();
                for q in 0..pairs {
                    // Per-stream merge state for partition pair (2q, 2q+1).
                    struct St {
                        ia: usize,
                        ib: usize,
                        out: Part,
                    }
                    let mut sts: Vec<Option<St>> = Vec::with_capacity(gsize);
                    for s in 0..gsize {
                        let pa = parts[s].get(2 * q);
                        let pb = parts[s].get(2 * q + 1);
                        match (pa, pb) {
                            (None, None) => sts.push(None),
                            (Some(_), None) => {
                                // Odd partition passes through (no merge work).
                                let p = parts[s][2 * q].clone();
                                // Copy to dest arena (vector memcpy).
                                let moved = copy_part(
                                    m,
                                    &p,
                                    arena_k[src_arena],
                                    arena_v[src_arena],
                                    arena_k[dst_arena],
                                    arena_v[dst_arena],
                                    dst_off[s],
                                    vl,
                                );
                                dst_off[s] += moved.keys.len().max(1) as u64;
                                new_parts[s].push(moved);
                                sts.push(None);
                            }
                            (Some(_), Some(_)) => {
                                sts.push(Some(St {
                                    ia: 0,
                                    ib: 0,
                                    out: Part {
                                        keys: Vec::new(),
                                        vals: Vec::new(),
                                        sim_off: dst_off[s],
                                    },
                                }));
                            }
                            (None, Some(_)) => unreachable!("parts are packed"),
                        }
                    }
                    // Lockstep chunk-at-a-time zip loop (Figure 2 / Fig 4b).
                    loop {
                        let mut k0 = Vec::with_capacity(gsize);
                        let mut v0 = Vec::with_capacity(gsize);
                        let mut k1 = Vec::with_capacity(gsize);
                        let mut v1 = Vec::with_capacity(gsize);
                        let mut rows0: Vec<(u64, usize)> = Vec::with_capacity(gsize);
                        let mut rows1: Vec<(u64, usize)> = Vec::with_capacity(gsize);
                        let mut active = 0usize;
                        for s in 0..gsize {
                            let (ca, va2, cb, vb2, ra, rb) = match &sts[s] {
                                Some(st) => {
                                    let pa = &parts[s][2 * q];
                                    let pb = &parts[s][2 * q + 1];
                                    let ra = pa.keys.len() - st.ia;
                                    let rb = pb.keys.len() - st.ib;
                                    if ra > 0 && rb > 0 {
                                        active += 1;
                                        let ea = (st.ia + n.min(ra)).min(pa.keys.len());
                                        let eb = (st.ib + n.min(rb)).min(pb.keys.len());
                                        (
                                            pa.keys[st.ia..ea].to_vec(),
                                            pa.vals[st.ia..ea].to_vec(),
                                            pb.keys[st.ib..eb].to_vec(),
                                            pb.vals[st.ib..eb].to_vec(),
                                            (arena_k[src_arena] + (pa.sim_off + st.ia as u64) * 4, ea - st.ia),
                                            (arena_k[src_arena] + (pb.sim_off + st.ib as u64) * 4, eb - st.ib),
                                        )
                                    } else {
                                        (Vec::new(), Vec::new(), Vec::new(), Vec::new(), (0, 0), (0, 0))
                                    }
                                }
                                None => (Vec::new(), Vec::new(), Vec::new(), Vec::new(), (0, 0), (0, 0)),
                            };
                            k0.push(ca);
                            v0.push(va2);
                            k1.push(cb);
                            v1.push(vb2);
                            rows0.push(ra);
                            rows1.push(rb);
                        }
                        if active == 0 {
                            break;
                        }
                        m.mlxe(rows0.iter());
                        m.mlxe(rows0.iter());
                        m.mlxe(rows1.iter());
                        m.mlxe(rows1.iter());
                        m.zip_pair(active);
                        m.mmv(4); // IC0, IC1, OC0, OC1
                        m.vector_ops(4); // pointer/length updates
                        m.branches(2);
                        let step: StepOut = self.engine.zip_step(&k0, &v0, &k1, &v1)?;
                        // Store east (+ south when present) chunks.
                        let east_rows: Vec<(u64, usize)> = (0..gsize)
                            .map(|s| match &sts[s] {
                                Some(st) if rows0[s].1 > 0 || rows1[s].1 > 0 => (
                                    arena_k[dst_arena]
                                        + (st.out.sim_off + st.out.keys.len() as u64) * 4,
                                    step.oc0[s],
                                ),
                                _ => (0, 0),
                            })
                            .collect();
                        m.msxe(east_rows.iter());
                        m.msxe(east_rows.iter());
                        let any_south = step.oc1.iter().any(|&x| x > 0);
                        if any_south {
                            let south_rows: Vec<(u64, usize)> = (0..gsize)
                                .map(|s| match &sts[s] {
                                    Some(st) if step.oc1[s] > 0 => (
                                        arena_k[dst_arena]
                                            + (st.out.sim_off
                                                + (st.out.keys.len() + step.oc0[s]) as u64)
                                                * 4,
                                        step.oc1[s],
                                    ),
                                    _ => (0, 0),
                                })
                                .collect();
                            m.msxe(south_rows.iter());
                            m.msxe(south_rows.iter());
                        }
                        for s in 0..gsize {
                            if let Some(st) = &mut sts[s] {
                                if rows0[s].1 == 0 && rows1[s].1 == 0 {
                                    continue;
                                }
                                st.ia += step.ic0[s];
                                st.ib += step.ic1[s];
                                st.out.keys.extend_from_slice(&step.k0[s]);
                                st.out.vals.extend_from_slice(&step.v0[s]);
                                st.out.keys.extend_from_slice(&step.k1[s]);
                                st.out.vals.extend_from_slice(&step.v1[s]);
                            }
                        }
                    }
                    // Tail copy: one side exhausted -> vector memcpy of the rest.
                    for s in 0..gsize {
                        if let Some(st) = sts[s].take() {
                            let mut out = st.out;
                            let pa = &parts[s][2 * q];
                            let pb = &parts[s][2 * q + 1];
                            for (part, i0) in [(pa, st.ia), (pb, st.ib)] {
                                let rem = part.keys.len() - i0;
                                if rem > 0 {
                                    let mut i = i0;
                                    while i < part.keys.len() {
                                        let c = (part.keys.len() - i).min(vl);
                                        m.vload(arena_k[src_arena] + (part.sim_off + i as u64) * 4, c * 4);
                                        m.vload(arena_v[src_arena] + (part.sim_off + i as u64) * 4, c * 4);
                                        m.vstore(
                                            arena_k[dst_arena]
                                                + (out.sim_off + out.keys.len() as u64) * 4,
                                            c * 4,
                                        );
                                        m.vstore(
                                            arena_v[dst_arena]
                                                + (out.sim_off + out.keys.len() as u64) * 4,
                                            c * 4,
                                        );
                                        out.keys.extend_from_slice(&part.keys[i..i + c]);
                                        out.vals.extend_from_slice(&part.vals[i..i + c]);
                                        i += c;
                                    }
                                }
                            }
                            dst_off[s] += out.keys.len().max(1) as u64;
                            new_parts[s].push(out);
                        }
                    }
                }
                parts = new_parts;
                src_arena = dst_arena;
            }

            // --- 4. Output generation: final partition -> output CSR. ------
            m.phase(Phase::Output);
            for (s, &r) in streams.iter().enumerate() {
                let part = parts[s].first().cloned().unwrap_or_default();
                let len = part.keys.len();
                let mut i = 0usize;
                while i < len {
                    let c = (len - i).min(vl);
                    m.vload(arena_k[src_arena] + (part.sim_off + i as u64) * 4, c * 4);
                    m.vload(arena_v[src_arena] + (part.sim_off + i as u64) * 4, c * 4);
                    m.vstore(out_idx_addr + (out_cursor + i as u64) * 4, c * 4);
                    m.vstore(out_val_addr + (out_cursor + i as u64) * 4, c * 4);
                    i += c;
                }
                out_cursor += len as u64;
                m.store(out_ptr_addr + (r as u64 + 1) * 8, 8);
                m.scalar_ops(2);
                rows_out[r] = (part.keys, part.vals);
            }
        }

        Ok(Csr::from_rows(a.nrows, b.ncols, rows_out))
    }
}

/// Vector memcpy of a pass-through partition into the destination arena.
#[allow(clippy::too_many_arguments)]
fn copy_part(
    m: &mut Machine,
    p: &Part,
    src_k: u64,
    src_v: u64,
    dst_k: u64,
    dst_v: u64,
    dst_off: u64,
    vl: usize,
) -> Part {
    let len = p.keys.len();
    let mut i = 0usize;
    while i < len {
        let c = (len - i).min(vl);
        m.vload(src_k + (p.sim_off + i as u64) * 4, c * 4);
        m.vstore(dst_k + (dst_off + i as u64) * 4, c * 4);
        m.vload(src_v + (p.sim_off + i as u64) * 4, c * 4);
        m.vstore(dst_v + (dst_off + i as u64) * 4, c * 4);
        i += c;
    }
    Part {
        keys: p.keys.clone(),
        vals: p.vals.clone(),
        sim_off: dst_off,
    }
}

impl SpGemm for Spz {
    fn name(&self) -> &'static str {
        "spz"
    }

    fn multiply(&mut self, m: &mut Machine, a: &Csr, b: &Csr) -> Result<Csr> {
        self.run(m, a, b, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::matrix::gen;
    use crate::spgemm::{reference, same_product};

    fn check(a: &Csr) {
        let mut m = Machine::new(SystemConfig::default());
        let c = Spz::native().multiply(&mut m, a, a).unwrap();
        let r = reference(a, a);
        assert!(
            same_product(&c, &r, 1e-3),
            "mismatch: got {} nnz, want {} nnz",
            c.nnz(),
            r.nnz()
        );
    }

    #[test]
    fn correct_on_random() {
        check(&gen::erdos_renyi(100, 100, 600, 61));
    }

    #[test]
    fn correct_on_skewed() {
        check(&gen::rmat(128, 128, 1200, 0.6, 0.18, 0.14, 62));
    }

    #[test]
    fn correct_on_regular() {
        check(&gen::kregular(96, 4, 63));
    }

    #[test]
    fn correct_on_banded() {
        check(&gen::banded(120, 12, 8, 64));
    }

    #[test]
    fn correct_on_identity() {
        check(&Csr::identity(40));
    }

    #[test]
    fn correct_on_empty() {
        check(&Csr::empty(20, 20));
    }

    #[test]
    fn correct_single_dense_row_matrix() {
        // One hub row -> long stream exercising many merge rounds.
        let mut rows = vec![(Vec::new(), Vec::new()); 17];
        rows[0] = ((0..17u32).collect(), vec![1.0; 17]);
        for r in 1..17 {
            rows[r] = (vec![(r as u32 * 7) % 17], vec![1.0]);
        }
        check(&Csr::from_rows(17, 17, rows));
    }

    #[test]
    fn uses_matrix_unit() {
        let a = gen::erdos_renyi(64, 64, 400, 65);
        let mut m = Machine::new(SystemConfig::default());
        Spz::native().multiply(&mut m, &a, &a).unwrap();
        let r = m.metrics();
        assert!(r.ops.mssortk > 0, "must execute mssortk");
        assert!(r.ops.mszipk > 0, "must execute mszipk");
        assert!(r.ops.mlxe > 0 && r.ops.msxe > 0);
    }

    #[test]
    fn processing_order_does_not_change_result() {
        let a = gen::rmat(80, 80, 700, 0.58, 0.2, 0.14, 66);
        let mut m1 = Machine::new(SystemConfig::default());
        let c1 = Spz::native().run(&mut m1, &a, &a, None).unwrap();
        let order: Vec<u32> = (0..80u32).rev().collect();
        let mut m2 = Machine::new(SystemConfig::default());
        let c2 = Spz::native().run(&mut m2, &a, &a, Some(&order)).unwrap();
        assert!(same_product(&c1, &c2, 1e-3));
    }
}
