//! `spz-rsort`: spz with work-sorted row scheduling (§V-B, §VI-A).
//!
//! The preprocessing work estimates are sorted (serial quicksort, as in the
//! paper — a noted overhead) so that rows with similar work land in the same
//! 16-stream group, cutting the lockstep imbalance that inflates the
//! mssortk/mszipk iteration count on high-work-variance matrices
//! (Figure 11). Only row *indices* are sorted; after compute, output rows
//! are shuffled back into row order (the second noted overhead).

use crate::matrix::Csr;
use crate::runtime::ZipUnit;
use crate::sim::{Machine, Phase};
use crate::spgemm::spz::Spz;
use crate::spgemm::SpGemm;
use anyhow::Result;
#[cfg(feature = "xla")]
use std::path::Path;

pub struct SpzRsort {
    inner: Spz,
}

impl SpzRsort {
    pub fn native() -> Self {
        SpzRsort { inner: Spz::native() }
    }

    #[cfg(feature = "xla")]
    pub fn xla(artifact_dir: &Path) -> Result<Self> {
        Ok(SpzRsort {
            inner: Spz::xla(artifact_dir)?,
        })
    }

    pub fn with_engine(engine: Box<dyn ZipUnit>) -> Self {
        SpzRsort {
            inner: Spz::with_engine(engine),
        }
    }
}

impl SpGemm for SpzRsort {
    fn name(&self) -> &'static str {
        "spz-rsort"
    }

    fn multiply(&mut self, m: &mut Machine, a: &Csr, b: &Csr) -> Result<Csr> {
        // Work estimation happens inside Spz::run too; the row sort needs it
        // up front. The paper's implementation reuses one preprocessing pass;
        // we charge the sort itself (the dominant overhead) to RowSort.
        let work = crate::matrix::stats::row_work(a, b);

        m.phase(Phase::RowSort);
        let nrows = a.nrows as u64;
        let order_addr = m.salloc(a.nrows * 4 + 8);
        let mut order: Vec<u32> = (0..a.nrows as u32).collect();
        // Serial quicksort over (work, row) — n log n compares, each with a
        // load of the work key and occasional swap stores.
        if nrows > 1 {
            let logn = (64 - nrows.leading_zeros() as u64).max(1);
            let cmps = nrows * logn;
            m.scalar_ops(4 * cmps);
            m.branches_unpredictable(cmps);
            for i in 0..cmps {
                m.load(order_addr + (i % nrows) * 4, 4);
            }
            let swaps = cmps / 2;
            for i in 0..swaps {
                m.store(order_addr + (i % nrows) * 4, 4);
            }
        }
        order.sort_by_key(|&r| work[r as usize]);

        // Compute with the sorted schedule.
        let c = self.inner.run(m, a, b, Some(&order))?;

        // Output shuffle: computed rows are re-emitted in row-index order
        // (vector copy per row; poor locality is captured by the scattered
        // source addresses).
        m.phase(Phase::RowSort);
        let vl = m.cfg.vlen_elems;
        let shuf_src = m.salloc(c.nnz().max(1) * 8);
        let shuf_dst = m.salloc(c.nnz().max(1) * 8);
        let mut src_pos: u64 = 0;
        for &r in &order {
            let len = c.row_len(r as usize);
            let mut i = 0usize;
            while i < len {
                let chunk = (len - i).min(vl);
                m.vload(shuf_src + (src_pos + i as u64) * 8, chunk * 8);
                m.vstore(shuf_dst + (c.indptr[r as usize] + i) as u64 * 8, chunk * 8);
                i += chunk;
            }
            src_pos += len as u64;
            m.scalar_ops(3);
        }

        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::matrix::gen;
    use crate::spgemm::{reference, same_product};

    #[test]
    fn correct_on_random() {
        let a = gen::erdos_renyi(100, 100, 700, 71);
        let mut m = Machine::new(SystemConfig::default());
        let c = SpzRsort::native().multiply(&mut m, &a, &a).unwrap();
        assert!(same_product(&c, &reference(&a, &a), 1e-3));
    }

    #[test]
    fn correct_on_skewed() {
        let a = gen::rmat(160, 160, 1600, 0.62, 0.18, 0.14, 72);
        let mut m = Machine::new(SystemConfig::default());
        let c = SpzRsort::native().multiply(&mut m, &a, &a).unwrap();
        assert!(same_product(&c, &reference(&a, &a), 1e-3));
    }

    #[test]
    fn charges_rowsort_phase() {
        let a = gen::rmat(96, 96, 800, 0.6, 0.19, 0.15, 73);
        let mut m = Machine::new(SystemConfig::default());
        SpzRsort::native().multiply(&mut m, &a, &a).unwrap();
        assert!(m.metrics().phase_cycles[Phase::RowSort as usize] > 0.0);
    }

    #[test]
    fn fewer_zip_iterations_on_skewed_input() {
        // Figure 11: work-sorted scheduling cuts dynamic mssortk/mszipk
        // counts on high-variance matrices.
        let a = gen::rmat(512, 512, 6000, 0.62, 0.18, 0.14, 74);
        let mut m1 = Machine::new(SystemConfig::default());
        crate::spgemm::spz::Spz::native().multiply(&mut m1, &a, &a).unwrap();
        let mut m2 = Machine::new(SystemConfig::default());
        SpzRsort::native().multiply(&mut m2, &a, &a).unwrap();
        let i1 = m1.metrics().total_matrix_kv_pairs();
        let i2 = m2.metrics().total_matrix_kv_pairs();
        assert!(i2 < i1, "rsort {i2} !< spz {i1}");
    }
}
