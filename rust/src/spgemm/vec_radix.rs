//! `vec-radix`: vectorized Expand-Sort-Compress SpGEMM [16] — the paper's
//! state-of-the-art vector baseline (§V-B).
//!
//! Multiple output rows are processed per block. Expansion produces
//! (row, col, value) triples; an LSD radix sort (8-bit digits, vectorized
//! per Zagha–Blelloch [56]) sorts triples by (row, col); a vectorized
//! compress pass combines duplicate keys and emits the block's rows.
//!
//! The cache behaviour the paper highlights (Figure 10): the radix
//! histogram/scatter passes perform long-stride and indexed accesses that
//! touch a different cache line per element, so vec-radix's L1 access count
//! dwarfs spz's unit-stride matrix loads. Block size is swept externally
//! (the coordinator picks the best-performing configuration per matrix,
//! exactly as the paper does).

use crate::matrix::Csr;
use crate::sim::{Machine, Phase};
use crate::spgemm::{CsrAddrs, SpGemm};
use anyhow::Result;

pub struct VecRadix {
    /// Target intermediate-triple count per row block.
    pub block_elems: usize,
}

impl Default for VecRadix {
    fn default() -> Self {
        // Default chosen by the calibration sweep (see EXPERIMENTS.md);
        // the coordinator still sweeps per matrix for Figure 8.
        VecRadix { block_elems: 16 * 1024 }
    }
}

impl SpGemm for VecRadix {
    fn name(&self) -> &'static str {
        "vec-radix"
    }

    fn multiply(&mut self, m: &mut Machine, a: &Csr, b: &Csr) -> Result<Csr> {
        let vl = m.cfg.vlen_elems;
        let aa = CsrAddrs::register(m, a);
        let ba = CsrAddrs::register_shared(m, b);

        // --- Preprocess: per-row work, block partitioning, allocation. ----
        let work = crate::spgemm::prep::row_work(m, a, b, &aa, &ba);
        let total_work: u64 = work.iter().sum();
        let mut blocks: Vec<(usize, usize, u64)> = Vec::new(); // (row_lo, row_hi, work)
        {
            let mut lo = 0usize;
            while lo < a.nrows {
                let mut hi = lo;
                let mut w = 0u64;
                while hi < a.nrows && (w == 0 || w + work[hi] <= self.block_elems as u64) {
                    w += work[hi];
                    hi += 1;
                }
                blocks.push((lo, hi, w));
                lo = hi;
            }
            m.scalar_ops(2 * a.nrows as u64); // block partition scan
        }
        let max_block: u64 = blocks.iter().map(|b| b.2).max().unwrap_or(0);

        // Ping-pong triple buffers (key: u64 = row<<32|col, val: f32).
        let kbuf = [m.salloc((max_block.max(1) as usize) * 8), m.salloc((max_block.max(1) as usize) * 8)];
        let vbuf = [m.salloc((max_block.max(1) as usize) * 4), m.salloc((max_block.max(1) as usize) * 4)];
        // Per-lane histogram counters: vl lanes x 256 buckets x 4B.
        let hist_addr = m.salloc(vl * 256 * 4);
        let out = CsrAddrs::register_output(m, a.nrows, total_work.max(1) as usize);
        let (out_idx_addr, out_val_addr, out_ptr_addr) = (out.indices, out.data, out.indptr);

        let col_bits = (64 - (b.ncols.max(2) as u64 - 1).leading_zeros()) as usize;

        let mut rows_out: Vec<(Vec<u32>, Vec<f32>)> = Vec::with_capacity(a.nrows);
        let mut out_cursor = 0u64;

        for &(lo, hi, bwork) in &blocks {
            // --- Expand (vectorized): emit (row<<32|col, val) triples. -----
            m.phase(Phase::Expand);
            let mut keys: Vec<u64> = Vec::with_capacity(bwork as usize);
            let mut vals: Vec<f32> = Vec::with_capacity(bwork as usize);
            for r in lo..hi {
                let (ak, av) = a.row(r);
                m.load(aa.indptr_at(r + 1), 8);
                // Vectorized A-side streaming, as in the spz expansion.
                for (ci, chunk) in ak.chunks(vl).enumerate() {
                    m.vload(aa.idx_at(a.indptr[r] + ci * vl), chunk.len() * 4);
                    m.vload(aa.val_at(a.indptr[r] + ci * vl), chunk.len() * 4);
                    m.vgather(chunk.iter().map(|&j| ba.indptr_at(j as usize)), 8);
                    m.vector_ops(2);
                }
                for (&j, &aval) in ak.iter().zip(av) {
                    let (bk, bv) = b.row(j as usize);
                    let b_base = b.indptr[j as usize];
                    let lr = (r - lo) as u64;
                    let mut bi = 0;
                    while bi < bk.len() {
                        let c = (bk.len() - bi).min(vl);
                        m.vload(ba.idx_at(b_base + bi), c * 4);
                        m.vload(ba.val_at(b_base + bi), c * 4);
                        m.vector_ops(3); // widen+pack key, multiply
                        m.vstore(kbuf[0] + keys.len() as u64 * 8, c * 8);
                        m.vstore(vbuf[0] + vals.len() as u64 * 4, c * 4);
                        for t in 0..c {
                            keys.push((lr << 32) | bk[bi + t] as u64);
                            vals.push(aval * bv[bi + t]);
                        }
                        bi += c;
                    }
                    m.scalar_ops(1);
                }
            }

            // --- Sort: LSD radix over (row, col) bits. ---------------------
            m.phase(Phase::Sort);
            let row_bits = (64 - ((hi - lo).max(2) as u64 - 1).leading_zeros()) as usize;
            let bits = col_bits + row_bits;
            let passes = bits.div_ceil(8);
            let n_elems = keys.len();
            let mut src_k = keys;
            let mut src_v = vals;
            let mut cur = 0usize;
            for p in 0..passes {
                let shift = p * 8;
                // Histogram pass: sequential key reads + per-lane counter
                // increments (gather/scatter into the 16x256 table).
                let mut hist = [0u32; 256];
                let mut i = 0;
                while i < n_elems {
                    let c = (n_elems - i).min(vl);
                    m.vload(kbuf[cur] + i as u64 * 8, c * 8);
                    m.vector_ops(2); // shift + mask digit extract
                    m.vgather(
                        (0..c).map(|t| {
                            let d = ((src_k[i + t] >> shift) & 0xFF) as u64;
                            hist_addr + (t as u64 * 256 + d) * 4
                        }),
                        4,
                    );
                    m.vscatter(
                        (0..c).map(|t| {
                            let d = ((src_k[i + t] >> shift) & 0xFF) as u64;
                            hist_addr + (t as u64 * 256 + d) * 4
                        }),
                        4,
                    );
                    for t in 0..c {
                        hist[((src_k[i + t] >> shift) & 0xFF) as usize] += 1;
                    }
                    i += c;
                }
                // Prefix sum across lanes and buckets.
                m.vector_ops(256);
                m.scalar_ops(256);
                let mut offs = [0u32; 256];
                let mut accum = 0u32;
                for d in 0..256 {
                    offs[d] = accum;
                    accum += hist[d];
                }
                // Scatter pass: read sequential, write scattered.
                let dst = 1 - cur;
                let mut dst_k = vec![0u64; n_elems];
                let mut dst_v = vec![0f32; n_elems];
                let mut i = 0;
                while i < n_elems {
                    let c = (n_elems - i).min(vl);
                    m.vload(kbuf[cur] + i as u64 * 8, c * 8);
                    m.vload(vbuf[cur] + i as u64 * 4, c * 4);
                    m.vector_ops(3);
                    // Destination offsets via the counter table again.
                    m.vgather(
                        (0..c).map(|t| {
                            let d = ((src_k[i + t] >> shift) & 0xFF) as u64;
                            hist_addr + (t as u64 * 256 + d) * 4
                        }),
                        4,
                    );
                    let mut kaddrs = Vec::with_capacity(c);
                    let mut vaddrs = Vec::with_capacity(c);
                    for t in 0..c {
                        let d = ((src_k[i + t] >> shift) & 0xFF) as usize;
                        let pos = offs[d] as usize;
                        offs[d] += 1;
                        dst_k[pos] = src_k[i + t];
                        dst_v[pos] = src_v[i + t];
                        kaddrs.push(kbuf[dst] + pos as u64 * 8);
                        vaddrs.push(vbuf[dst] + pos as u64 * 4);
                    }
                    m.vscatter(kaddrs, 8);
                    m.vscatter(vaddrs, 4);
                    i += c;
                }
                src_k = dst_k;
                src_v = dst_v;
                cur = dst;
            }

            // --- Compress + output generation. -----------------------------
            m.phase(Phase::Output);
            let mut i = 0usize;
            let mut block_rows: Vec<(Vec<u32>, Vec<f32>)> =
                (lo..hi).map(|_| (Vec::new(), Vec::new())).collect();
            while i < n_elems {
                let c = (n_elems - i).min(vl);
                m.vload(kbuf[cur] + i as u64 * 8, c * 8);
                m.vload(vbuf[cur] + i as u64 * 4, c * 4);
                m.vector_ops(4); // shifted compare, segment mask, segment sum
                i += c;
            }
            let mut i = 0usize;
            let mut uniques_in_block = 0u64;
            while i < n_elems {
                let key = src_k[i];
                let mut v = src_v[i];
                let mut j = i + 1;
                while j < n_elems && src_k[j] == key {
                    v += src_v[j];
                    j += 1;
                }
                let lr = (key >> 32) as usize;
                let col = (key & 0xFFFF_FFFF) as u32;
                block_rows[lr].0.push(col);
                block_rows[lr].1.push(v);
                uniques_in_block += 1;
                i = j;
            }
            // Compact unique entries to the output arrays (unit-stride).
            let mut written = 0u64;
            while written < uniques_in_block {
                let c = ((uniques_in_block - written) as usize).min(vl);
                m.vstore(out_idx_addr + (out_cursor + written) * 4, c * 4);
                m.vstore(out_val_addr + (out_cursor + written) * 4, c * 4);
                written += c as u64;
            }
            out_cursor += uniques_in_block;
            for (r, row) in block_rows.into_iter().enumerate() {
                m.store(out_ptr_addr + (lo + r + 1) as u64 * 8, 8);
                rows_out.push(row);
            }
        }

        Ok(Csr::from_rows(a.nrows, b.ncols, rows_out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::matrix::gen;
    use crate::spgemm::{reference, same_product};

    #[test]
    fn correct_on_random() {
        let a = gen::erdos_renyi(100, 100, 600, 51);
        let mut m = Machine::new(SystemConfig::default());
        let c = VecRadix::default().multiply(&mut m, &a, &a).unwrap();
        assert!(same_product(&c, &reference(&a, &a), 1e-3));
    }

    #[test]
    fn correct_with_tiny_blocks() {
        let a = gen::rmat(64, 64, 400, 0.55, 0.2, 0.15, 52);
        let mut m = Machine::new(SystemConfig::default());
        let c = VecRadix { block_elems: 64 }.multiply(&mut m, &a, &a).unwrap();
        assert!(same_product(&c, &reference(&a, &a), 1e-3));
    }

    #[test]
    fn correct_single_giant_block() {
        let a = gen::erdos_renyi(50, 50, 300, 53);
        let mut m = Machine::new(SystemConfig::default());
        let c = VecRadix { block_elems: usize::MAX }.multiply(&mut m, &a, &a).unwrap();
        assert!(same_product(&c, &reference(&a, &a), 1e-3));
    }

    #[test]
    fn sort_phase_dominates() {
        // Paper Figure 9: stream sorting dominates vec-radix.
        let a = gen::rmat(512, 512, 4096, 0.57, 0.19, 0.19, 54);
        let mut m = Machine::new(SystemConfig::default());
        VecRadix::default().multiply(&mut m, &a, &a).unwrap();
        let r = m.metrics();
        let sort = r.phase_cycles[Phase::Sort as usize];
        let expand = r.phase_cycles[Phase::Expand as usize];
        assert!(sort > expand, "sort {sort} <= expand {expand}");
    }

    #[test]
    fn empty_matrix_ok() {
        let a = Csr::empty(10, 10);
        let mut m = Machine::new(SystemConfig::default());
        let c = VecRadix::default().multiply(&mut m, &a, &a).unwrap();
        assert_eq!(c.nnz(), 0);
    }
}
