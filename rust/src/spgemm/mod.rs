//! The five SpGEMM implementations of the paper's evaluation (§V-B):
//!
//! | name        | module        | paper description                         |
//! |-------------|---------------|-------------------------------------------|
//! | `scl-array` | [`scl_array`] | scalar row-wise, dense accumulator [19]   |
//! | `scl-hash`  | [`scl_hash`]  | scalar row-wise, hash accumulator [1,15]  |
//! | `vec-radix` | [`vec_radix`] | vectorized Expand-Sort-Compress [16]      |
//! | `spz`       | [`spz`]       | SparseZipper merge-based row-wise         |
//! | `spz-rsort` | [`spz_rsort`] | spz + work-sorted row scheduling          |
//!
//! Every implementation computes the *real* product (verified against
//! [`reference`]) while charging its architectural events to a
//! [`crate::sim::Machine`]. The [`parallel`] module runs any of them over
//! row blocks of A on multiple simulated cores (one forked machine each).

pub mod parallel;
pub mod prep;
pub mod scl_array;
pub mod scl_hash;
pub mod spz;
pub mod spz_rsort;
pub mod vec_radix;

use crate::matrix::Csr;
use crate::sim::Machine;
use anyhow::Result;
use std::collections::BTreeMap;

/// A simulated SpGEMM implementation.
pub trait SpGemm {
    fn name(&self) -> &'static str;
    /// Compute C = A * B, charging events to `m`.
    fn multiply(&mut self, m: &mut Machine, a: &Csr, b: &Csr) -> Result<Csr>;
}

/// Independent correctness oracle (BTreeMap accumulation; no shared code
/// with any simulated implementation).
pub fn reference(a: &Csr, b: &Csr) -> Csr {
    assert_eq!(a.ncols, b.nrows);
    let mut rows = Vec::with_capacity(a.nrows);
    for r in 0..a.nrows {
        let mut acc: BTreeMap<u32, f32> = BTreeMap::new();
        let (ak, av) = a.row(r);
        for (&j, &aval) in ak.iter().zip(av) {
            let (bk, bv) = b.row(j as usize);
            for (&k, &bval) in bk.iter().zip(bv) {
                *acc.entry(k).or_insert(0.0) += aval * bval;
            }
        }
        let keys: Vec<u32> = acc.keys().copied().collect();
        let vals: Vec<f32> = acc.values().copied().collect();
        rows.push((keys, vals));
    }
    Csr::from_rows(a.nrows, b.ncols, rows)
}

/// Structural equality + relative numeric tolerance (accumulation order
/// differs between implementations; f32 is not associative).
pub fn same_product(x: &Csr, y: &Csr, rel: f32) -> bool {
    x.approx_eq(y, rel)
}

/// Simulated addresses of a CSR's three arrays.
#[derive(Clone, Copy, Debug)]
pub struct CsrAddrs {
    pub indptr: u64,
    pub indices: u64,
    pub data: u64,
}

/// Identity key for a shared operand: the `&Csr`'s address. One shared
/// reference across the parallel workers (and the driver) means one key,
/// so every party resolves the same canonical simulated addresses.
pub fn csr_shared_key(m: &Csr) -> usize {
    m as *const Csr as usize
}

impl CsrAddrs {
    /// Byte sizes of a CSR's three arrays (indptr, indices, data) — the one
    /// definition [`CsrAddrs::register_shared`] and the parallel driver's
    /// pre-registration both use.
    pub fn csr_sizes(m: &Csr) -> (usize, usize, usize) {
        ((m.nrows + 1) * 8, m.nnz().max(1) * 4, m.nnz().max(1) * 4)
    }

    /// Register `m`'s arrays in the simulated address space.
    pub fn register(mach: &mut Machine, m: &Csr) -> CsrAddrs {
        CsrAddrs {
            indptr: mach.salloc((m.nrows + 1) * 8),
            indices: mach.salloc(m.nnz().max(1) * 4),
            data: mach.salloc(m.nnz().max(1) * 4),
        }
    }

    /// Register the *shared* operand (the B matrix every core streams):
    /// under the parallel driver each core maps the same matrix at the same
    /// canonical simulated addresses (keyed by the `&Csr`'s identity, which
    /// is one shared reference across the workers), so cross-core line
    /// identity in the shared-memory replay is real sharing of B — not
    /// per-core allocator aliasing. On serial machines, where no
    /// shared-operand table exists, this is exactly [`CsrAddrs::register`].
    pub fn register_shared(mach: &mut Machine, m: &Csr) -> CsrAddrs {
        match mach.shared_csr(csr_shared_key(m), CsrAddrs::csr_sizes(m)) {
            Some((indptr, indices, data)) => CsrAddrs { indptr, indices, data },
            None => CsrAddrs::register(mach, m),
        }
    }

    /// Addresses for an implementation's output CSR (`rows` output rows,
    /// at most `est_elems` packed elements — the Gustavson work bound every
    /// implementation sizes its output by). Under the parallel driver the
    /// output lands in the block's window of the modeled *shared
    /// destination region* (see [`crate::sim::Machine::map_shared_output`]),
    /// so phase-3 writes from different cores share boundary lines and the
    /// replay sees real write-shared traffic; serial machines allocate
    /// privately exactly as the seed always did.
    pub fn register_output(mach: &mut Machine, rows: usize, est_elems: usize) -> CsrAddrs {
        let (indices, data, indptr) = mach.out_csr_addrs(rows, est_elems);
        CsrAddrs { indptr, indices, data }
    }

    #[inline]
    pub fn indptr_at(&self, r: usize) -> u64 {
        self.indptr + (r as u64) * 8
    }

    #[inline]
    pub fn idx_at(&self, i: usize) -> u64 {
        self.indices + (i as u64) * 4
    }

    #[inline]
    pub fn val_at(&self, i: usize) -> u64 {
        self.data + (i as u64) * 4
    }
}

/// Typed identifier for the five SpGEMM implementations of the paper's
/// evaluation, in Figure 8 order. This is the API-level handle: parsing from
/// a string happens once at the argv boundary (or via [`str::parse`]), and
/// everything downstream — [`crate::api::JobSpec`], suite sweeps, figure
/// emitters — carries the enum.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ImplId {
    SclArray,
    SclHash,
    VecRadix,
    Spz,
    SpzRsort,
}

impl ImplId {
    /// All implementations in the paper's Figure 8 order.
    pub const ALL: [ImplId; 5] = [
        ImplId::SclArray,
        ImplId::SclHash,
        ImplId::VecRadix,
        ImplId::Spz,
        ImplId::SpzRsort,
    ];

    /// The canonical CLI/report name.
    pub const fn name(self) -> &'static str {
        match self {
            ImplId::SclArray => "scl-array",
            ImplId::SclHash => "scl-hash",
            ImplId::VecRadix => "vec-radix",
            ImplId::Spz => "spz",
            ImplId::SpzRsort => "spz-rsort",
        }
    }

    /// Construct the implementation (engine applies to the spz variants; the
    /// scalar/vector baselines ignore it, as before).
    pub fn instantiate(
        self,
        engine: crate::runtime::Engine,
        artifact_dir: &std::path::Path,
    ) -> Result<Box<dyn SpGemm>> {
        use crate::runtime::Engine;
        #[cfg(not(feature = "xla"))]
        let _ = artifact_dir; // only consumed by the xla-gated arms
        Ok(match self {
            ImplId::SclArray => Box::new(scl_array::SclArray),
            ImplId::SclHash => Box::new(scl_hash::SclHash),
            ImplId::VecRadix => Box::new(vec_radix::VecRadix::default()),
            ImplId::Spz => match engine {
                Engine::Native => Box::new(spz::Spz::native()),
                #[cfg(feature = "xla")]
                Engine::Xla => Box::new(spz::Spz::xla(artifact_dir)?),
                #[cfg(not(feature = "xla"))]
                Engine::Xla => return Err(xla_unavailable()),
            },
            ImplId::SpzRsort => match engine {
                Engine::Native => Box::new(spz_rsort::SpzRsort::native()),
                #[cfg(feature = "xla")]
                Engine::Xla => Box::new(spz_rsort::SpzRsort::xla(artifact_dir)?),
                #[cfg(not(feature = "xla"))]
                Engine::Xla => return Err(xla_unavailable()),
            },
        })
    }
}

#[cfg(not(feature = "xla"))]
fn xla_unavailable() -> anyhow::Error {
    anyhow::anyhow!(
        "engine 'xla' is unavailable in this build: it needs the `xla` cargo feature AND the \
         vendored `xla` crate added as a dependency first — see the note in rust/Cargo.toml"
    )
}

impl std::str::FromStr for ImplId {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        ImplId::ALL
            .iter()
            .find(|i| i.name() == s)
            .copied()
            .ok_or_else(|| {
                let known: Vec<&str> = ImplId::ALL.iter().map(|i| i.name()).collect();
                format!(
                    "unknown implementation '{s}' (expected one of: {})",
                    known.join(", ")
                )
            })
    }
}

impl std::fmt::Display for ImplId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(self.name())
    }
}

/// All implementation names in the paper's Figure 8 order (derived from
/// [`ImplId`] so the two lists cannot drift).
pub const IMPL_NAMES: [&str; 5] = [
    ImplId::ALL[0].name(),
    ImplId::ALL[1].name(),
    ImplId::ALL[2].name(),
    ImplId::ALL[3].name(),
    ImplId::ALL[4].name(),
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;

    #[test]
    fn reference_identity() {
        let i = Csr::identity(6);
        let c = reference(&i, &i);
        assert_eq!(c, i);
    }

    #[test]
    fn reference_matches_dense() {
        let a = gen::erdos_renyi(20, 20, 60, 3);
        let b = gen::erdos_renyi(20, 20, 60, 4);
        let c = reference(&a, &b);
        let (da, db, dc) = (a.to_dense(), b.to_dense(), c.to_dense());
        for r in 0..20 {
            for k in 0..20 {
                let mut s = 0f32;
                for j in 0..20 {
                    s += da[r][j] * db[j][k];
                }
                assert!((s - dc[r][k]).abs() < 1e-4, "({r},{k}): {s} vs {}", dc[r][k]);
            }
        }
    }

    #[test]
    fn impl_id_names_round_trip() {
        for id in ImplId::ALL {
            assert_eq!(id.name().parse::<ImplId>().unwrap(), id);
        }
        let names: Vec<&str> = ImplId::ALL.iter().map(|i| i.name()).collect();
        assert_eq!(names, IMPL_NAMES);
        let err = "nope".parse::<ImplId>().unwrap_err();
        assert!(err.contains("scl-array") && err.contains("nope"), "{err}");
    }

    #[test]
    fn reference_empty_rows() {
        let mut a = Csr::identity(4);
        a.indptr = vec![0, 0, 1, 2, 3];
        a.indices = vec![1, 2, 3];
        a.data = vec![1.0; 3];
        let c = reference(&a, &Csr::identity(4));
        assert_eq!(c.row_len(0), 0);
        assert_eq!(c.row_len(1), 1);
    }
}
