//! Shared preprocessing: per-row work estimation (the "amount of work"
//! pre-pass every implementation in §V-B performs) with its simulation
//! accounting.

use crate::matrix::Csr;
use crate::sim::{Machine, Phase};
use crate::spgemm::CsrAddrs;

/// Compute per-row multiplication counts for C = A*B, charging the scan to
/// the `Preprocess` phase: sequential reads of A.indptr/A.indices plus a
/// gather of B.indptr[j] per nonzero.
pub fn row_work(
    m: &mut Machine,
    a: &Csr,
    b: &Csr,
    aa: &CsrAddrs,
    ba: &CsrAddrs,
) -> Vec<u64> {
    m.phase(Phase::Preprocess);
    let mut work = Vec::with_capacity(a.nrows);
    let vl = m.cfg.vlen_elems;
    for r in 0..a.nrows {
        m.load(aa.indptr_at(r + 1), 8);
        let (ak, _) = a.row(r);
        let mut w = 0u64;
        // Vectorized gather of B.indptr[j] for the row's column indices.
        for chunk in ak.chunks(vl) {
            m.vload(aa.idx_at(a.indptr[r]), chunk.len() * 4);
            m.vgather(
                chunk.iter().map(|&j| ba.indptr_at(j as usize)),
                8,
            );
            m.vector_ops(2); // length diff + horizontal add
            for &j in chunk {
                w += b.row_len(j as usize) as u64;
            }
        }
        m.scalar_ops(2);
        work.push(w);
    }
    work
}

/// Exclusive prefix sum (charged as a vector pass) used for temp-buffer
/// offsets; returns offsets and the total.
pub fn prefix_sum(m: &mut Machine, xs: &[u64]) -> (Vec<u64>, u64) {
    let vl = m.cfg.vlen_elems as u64;
    m.vector_ops(xs.len() as u64 / vl + 1);
    m.scalar_ops(xs.len() as u64 / 4 + 1);
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = 0u64;
    for &x in xs {
        out.push(acc);
        acc += x;
    }
    (out, acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::matrix::gen;

    #[test]
    fn work_matches_stats_module() {
        let a = gen::erdos_renyi(50, 50, 200, 17);
        let mut m = Machine::new(SystemConfig::default());
        let aa = CsrAddrs::register(&mut m, &a);
        let ba = CsrAddrs::register(&mut m, &a);
        let w = row_work(&mut m, &a, &a, &aa, &ba);
        let expect = crate::matrix::stats::row_work(&a, &a);
        assert_eq!(w, expect);
        assert!(m.metrics().phase_cycles[Phase::Preprocess as usize] > 0.0);
    }

    #[test]
    fn prefix_sum_correct() {
        let mut m = Machine::new(SystemConfig::default());
        let (offs, total) = prefix_sum(&mut m, &[3, 0, 5, 2]);
        assert_eq!(offs, vec![0, 3, 3, 8]);
        assert_eq!(total, 10);
    }
}
