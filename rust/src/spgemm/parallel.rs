//! Multi-core SpGEMM driver: run any [`SpGemm`] implementation over row
//! blocks of A on real worker threads, one forked [`Machine`] per simulated
//! core (the paper's evaluation distributes rows of A to per-core matrix
//! units the same way; SpArch and the SSR multi-core clusters are the
//! related-work analogues).
//!
//! Row-wise SpGEMM makes this exact: rows `[lo, hi)` of `C = A*B` depend
//! only on rows `[lo, hi)` of A (and all of B), so a block is simulated by
//! multiplying the corresponding row *slab* of A against B and the per-block
//! outputs stitch back into one [`Csr`] in block order — bit-identical in
//! structure to the serial product, independent of core count and scheduler.
//!
//! Two invariants the tests pin:
//!
//! * **Blocks are core-count independent**: both the uniform splitter
//!   ([`block_rows_for`]) and the work-proportional one ([`row_blocks_dyn`])
//!   depend only on the matrices and the matrix-unit group size, so the
//!   per-core event counts of an N-core run always sum exactly to the
//!   1-core run's totals under the same block policy.
//! * **Blocks are aligned to the matrix-unit group size** (16 rows): the spz
//!   variants process rows in lockstep groups of `unit.n` streams, so
//!   group-aligned blocks leave every group's composition — and therefore
//!   every dynamic event count of `spz`, `scl-array`, and `scl-hash` —
//!   exactly equal to the serial run's. (`vec-radix` re-partitions its ESC
//!   batches per block and `spz-rsort` work-sorts within a block, so their
//!   counts match the 1-core *driver* run, not the serial loop.)
//!
//! The **shared-memory replay** ([`crate::mem::shared`]) runs *concurrently*
//! with the workers: each core publishes its LLC-level access trace into a
//! bounded per-core chunk ring ([`crate::mem::TraceStream`]) as it executes,
//! and the deterministic replay engine consumes the streams in canonical
//! merge order on its own scoped thread, pricing the shared LLC (queueing +
//! MESI-lite coherence) and the multi-channel DRAM back end before folding
//! per-core stall cycles into the per-phase metrics. Peak trace memory is
//! bounded by the ring budget
//! ([`crate::config::SharedMemConfig::trace_ring_chunks`]; overflow spills
//! to disk), production and replay overlap in wall-clock time, and the
//! result is bit-identical to materialize-then-replay — everything stays
//! bit-reproducible across host thread schedules, and at 1 core the replay
//! is an exact no-op on the cycle counts.

use crate::config::SystemConfig;
use crate::matrix::Csr;
use crate::mem::{shared, TraceBuf, TraceEvent, TraceKind, TraceStream};
use crate::sim::machine::NUM_PHASES;
use crate::sim::{Machine, MulticoreMetrics};
use crate::spgemm::{CsrAddrs, SpGemm};
use crate::util::round_up;
use anyhow::{ensure, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Mutex;

/// How row blocks are assigned to cores.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheduler {
    /// Contiguous static partition of the block list (each core gets
    /// `nblocks/cores` consecutive blocks up front). Cheap, but exposed to
    /// load imbalance when heavy rows cluster — the effect `spz-rsort`'s
    /// row sorting (and Figure 11's work-variance column) makes measurable.
    Static,
    /// Dynamic self-scheduling off a shared queue: blocks are claimed in
    /// order by whichever core becomes idle first, so one heavy block never
    /// idles the pool. The claim sequence is simulated *deterministically*
    /// from the per-row work estimates (the same Gustavson work counts every
    /// implementation's Preprocess pass computes) rather than from host
    /// thread timing — per-core metrics, critical path, and fig12 are
    /// bit-reproducible run to run.
    WorkStealing,
    /// Work-stealing claims over *work-proportional* blocks: instead of a
    /// uniform row count per block, block boundaries are cut where the
    /// accumulated Gustavson work estimate crosses an equal share (see
    /// [`row_blocks_dyn`]), so heavy hub rows stop producing one outsized
    /// block. Boundaries stay group-aligned and depend only on the matrices
    /// — never the core count — preserving exact count additivity.
    WorkStealingDyn,
    /// Bandwidth-aware work stealing: the same work-proportional block
    /// geometry as [`Scheduler::WorkStealingDyn`] (so event-count additivity
    /// is untouched), but the block-to-core assignment is refined by a cheap
    /// *pilot replay* built from the Gustavson estimates and the canonical
    /// shared addresses: the pilot prices each core's DRAM-channel and
    /// shared-LLC pressure under the plain greedy plan, and blocks are then
    /// rebalanced away from cores whose channels saturated. Falls back to
    /// the plain plan whenever the pilot predicts no improvement, so `ws-bw`
    /// never schedules worse than `ws-dyn` by its own estimate. Fully
    /// deterministic (a pure function of the matrices and core count).
    WorkStealingBw,
    /// Socket-aware bandwidth scheduling: [`Scheduler::WorkStealingBw`]'s
    /// pilot replay made NUMA-aware. Block line footprints (B rows + the
    /// block's output window, the very lines the replay prices) are binned
    /// into per-socket channel groups, a candidate plan claims blocks onto
    /// cores whose socket keeps the footprint local (remote lines inflate a
    /// block's effective cost by the hop-priced transfer ratio), and the
    /// socket-stamped pilot replay then arbitrates between that candidate
    /// and `ws-bw`'s plan — falling back to `ws-bw` whenever the pilot
    /// predicts no win. At one socket every distance is zero and the plan
    /// is exactly `ws-bw`'s. Same dyn block geometry, so event-count
    /// additivity is untouched; fully deterministic.
    WorkStealingNuma,
    /// Adaptive dataflow scheduling: pick the *kernel* and the *geometry*
    /// per block, not just the core. Deterministic serial probe passes
    /// (simulated cycles on a scratch fork at the canonical shared
    /// addresses — never host timing) measure each candidate kernel's
    /// per-block, per-phase cost; Table III-style gates (row-work
    /// histogram, within-block work variance, accumulator footprint) bound
    /// which kernels are probed where. Heavy or channel-concentrated
    /// blocks are split at group-aligned cuts, and a barrier-aware claim
    /// places the resulting heterogeneous blocks so no single phase's
    /// critical path inflates. The pilot replay arbitrates the adaptive
    /// plan against the exact plans of the fixed schedulers and falls back
    /// to the best of them — bit-identically — whenever it predicts no
    /// win. Group alignment keeps exact per-core count additivity against
    /// the serial loop *of each chosen impl*; at 1 core the plan degrades
    /// to exactly `ws-dyn`.
    WorkStealingAdapt,
}

impl Scheduler {
    /// Every scheduler, in presentation order — the single source of truth
    /// the CLI help, `fig12` sweeps, and the parse error all derive from,
    /// so a new scheduler lands everywhere at once.
    pub const ALL: [Scheduler; 6] = [
        Scheduler::Static,
        Scheduler::WorkStealing,
        Scheduler::WorkStealingDyn,
        Scheduler::WorkStealingBw,
        Scheduler::WorkStealingNuma,
        Scheduler::WorkStealingAdapt,
    ];

    pub const fn name(self) -> &'static str {
        match self {
            Scheduler::Static => "static",
            Scheduler::WorkStealing => "work-stealing",
            Scheduler::WorkStealingDyn => "ws-dyn",
            Scheduler::WorkStealingBw => "ws-bw",
            Scheduler::WorkStealingNuma => "ws-numa",
            Scheduler::WorkStealingAdapt => "ws-adapt",
        }
    }
}

impl std::str::FromStr for Scheduler {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        // Canonical names come from the one table; the historical aliases
        // stay accepted.
        if let Some(&s) = Scheduler::ALL.iter().find(|sch| sch.name() == s) {
            return Ok(s);
        }
        match s {
            "ws" => Ok(Scheduler::WorkStealing),
            "work-stealing-dyn" => Ok(Scheduler::WorkStealingDyn),
            "work-stealing-bw" => Ok(Scheduler::WorkStealingBw),
            "work-stealing-numa" => Ok(Scheduler::WorkStealingNuma),
            "work-stealing-adapt" => Ok(Scheduler::WorkStealingAdapt),
            other => {
                let known: Vec<&str> = Scheduler::ALL.iter().map(|s| s.name()).collect();
                Err(format!(
                    "unknown scheduler '{other}' (expected one of: {})",
                    known.join(", ")
                ))
            }
        }
    }
}

impl std::fmt::Display for Scheduler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(self.name())
    }
}

/// Parallel-execution parameters.
#[derive(Clone, Copy, Debug)]
pub struct ParallelConfig {
    /// Simulated cores (= real worker threads). Clamped to at least 1.
    pub cores: usize,
    pub scheduler: Scheduler,
    /// Rows of A per block (rounded up to the matrix-unit group size);
    /// `None` picks [`block_rows_for`]'s core-count-independent default.
    pub block_rows: Option<usize>,
    /// Which implementation `make_impl` constructs, when the caller knows.
    /// Only `ws-adapt` consults it: per-block kernel swaps are enabled only
    /// when the job's own kernel is one of the paper trio (scl-array,
    /// scl-hash, spz), whose group-aligned counts are exactly serial — so a
    /// heterogeneous run stays count-additive per chosen impl. `None`
    /// disables swapping (geometry + placement adaptation still apply).
    pub impl_id: Option<crate::spgemm::ImplId>,
}

impl ParallelConfig {
    pub fn new(cores: usize) -> Self {
        ParallelConfig {
            cores,
            scheduler: Scheduler::WorkStealing,
            block_rows: None,
            impl_id: None,
        }
    }
}

/// What `ws-adapt` decided for one job (`None` on every fixed scheduler):
/// how many blocks ran on each paper kernel, how many were swapped off the
/// job's own kernel or split for bandwidth, and the pilot's predicted stall
/// cycles next to the replay's achieved ones — the honesty signal: a large
/// gap means the pilot is misleading the planner.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SchedDecisions {
    /// Blocks actually executed (after any splits).
    pub total_blocks: usize,
    pub blocks_scl_array: usize,
    pub blocks_scl_hash: usize,
    pub blocks_spz: usize,
    /// Blocks on a job kernel outside the paper trio (vec-radix, spz-rsort,
    /// or an unknown `impl_id`) — never swapped.
    pub blocks_other: usize,
    /// Blocks whose kernel differs from the job's own implementation.
    pub swapped_blocks: usize,
    /// Heavy / channel-concentrated blocks split in two at a group-aligned
    /// cut (each contributes one extra entry to `total_blocks`).
    pub split_blocks: usize,
    /// Sum over cores of the pilot's stall score for the chosen plan.
    pub predicted_stall_cycles: f64,
    /// Sum over cores of the replay's folded per-phase stalls.
    pub achieved_stall_cycles: f64,
}

/// Result of a parallel run: the stitched product, the per-core metrics
/// aggregate, and how many blocks each core executed (the scheduler's
/// footprint, useful for imbalance reporting).
#[derive(Clone, Debug)]
pub struct ParallelRun {
    pub csr: Csr,
    pub metrics: MulticoreMetrics,
    pub blocks_per_core: Vec<usize>,
    /// `ws-adapt`'s decision summary; `None` under every fixed scheduler
    /// (and on `ws-adapt`'s degenerate 1-core / empty-matrix paths).
    pub decisions: Option<SchedDecisions>,
    /// The executed block geometry with the kernel that ran each block:
    /// `(lo, hi, impl)`, where `None` means the job's own implementation.
    /// Under fixed schedulers every entry is `None`; `ws-adapt` records its
    /// swaps here so tests can check count additivity *per chosen impl*
    /// exactly (sum of per-impl serial slab counts == parallel totals).
    pub block_plan: Vec<(usize, usize, Option<crate::spgemm::ImplId>)>,
}

/// Target block count for both the uniform and the work-proportional
/// splitters: ~64 blocks means plenty of steals even at 8 cores.
const TARGET_BLOCKS: usize = 64;

/// Default rows per block: targets ~[`TARGET_BLOCKS`] blocks with a
/// one-group floor, rounded up to the group size. Depends only on the
/// matrix and the unit geometry — never on the core count — so per-core
/// event counts sum identically at every core count.
pub fn block_rows_for(nrows: usize, group: usize) -> usize {
    let group = group.max(1);
    round_up(nrows.max(1).div_ceil(TARGET_BLOCKS).max(group), group)
}

/// The row-block list for an `nrows`-row A (block size from
/// [`ParallelConfig::block_rows`] or [`block_rows_for`]).
pub fn row_blocks(nrows: usize, group: usize, cfg: &ParallelConfig) -> Vec<(usize, usize)> {
    let bs = match cfg.block_rows {
        Some(req) => round_up(req.max(1), group.max(1)),
        None => block_rows_for(nrows, group),
    };
    let mut blocks = Vec::with_capacity(nrows.div_ceil(bs.max(1)));
    let mut lo = 0usize;
    while lo < nrows {
        let hi = (lo + bs).min(nrows);
        blocks.push((lo, hi));
        lo = hi;
    }
    blocks
}

/// Work-proportional row blocks (the `ws-dyn` policy): cut a block boundary
/// whenever the accumulated per-row work estimate (Gustavson multiply
/// counts plus a per-row overhead term, the same estimator the
/// work-stealing claim replay uses) crosses 1/[`TARGET_BLOCKS`] of the
/// total. Two invariants are preserved on purpose:
///
/// * boundaries move only at matrix-unit-group granularity, so the spz/scl
///   group compositions — and therefore their dynamic event counts — stay
///   exactly equal to the serial run's;
/// * the split depends only on `(a, b, group)`, never on the core count, so
///   per-core counts still sum identically at every core count.
///
/// An explicit [`ParallelConfig::block_rows`] request overrides the policy
/// and falls back to the uniform splitter.
pub fn row_blocks_dyn(a: &Csr, b: &Csr, group: usize, cfg: &ParallelConfig) -> Vec<(usize, usize)> {
    if cfg.block_rows.is_some() {
        return row_blocks(a.nrows, group, cfg);
    }
    dyn_blocks_from_work(a.nrows, group, &crate::matrix::stats::row_work(a, b))
}

/// [`row_blocks_dyn`]'s core, over a precomputed work estimate (the driver
/// computes `row_work` once and shares it with the scheduler).
fn dyn_blocks_from_work(nrows: usize, group: usize, row_work: &[u64]) -> Vec<(usize, usize)> {
    let group = group.max(1);
    let total: u64 = row_work.iter().sum::<u64>() + nrows as u64;
    let target = total.div_ceil(TARGET_BLOCKS as u64).max(1);
    let mut blocks = Vec::new();
    let mut lo = 0usize;
    let mut acc = 0u64;
    let mut r = 0usize;
    while r < nrows {
        let hi = (r + group).min(nrows);
        acc += row_work[r..hi].iter().sum::<u64>() + (hi - r) as u64;
        r = hi;
        if acc >= target || r == nrows {
            blocks.push((lo, r));
            lo = r;
            acc = 0;
        }
    }
    blocks
}

/// Per-core block assignment, decided up front so it depends only on the
/// inputs (never on host-thread timing):
///
/// * `Static` — contiguous equal-count chunks of the block list.
/// * `WorkStealing` — the deterministic replay of a dynamic self-scheduling
///   queue: walk blocks in order, handing each to the core whose accumulated
///   estimated work (Gustavson multiply counts, + a per-row term for the
///   fixed row overheads) is smallest — i.e. the core that would have gone
///   idle and stolen next. Ties break toward the lowest core id.
fn assign_blocks(
    row_work: &[u64],
    blocks: &[(usize, usize)],
    cores: usize,
    scheduler: Scheduler,
) -> Vec<Vec<usize>> {
    let nblocks = blocks.len();
    match scheduler {
        Scheduler::Static => (0..cores)
            .map(|c| (c * nblocks / cores..(c + 1) * nblocks / cores).collect())
            .collect(),
        // ws-bw, ws-numa, and ws-adapt start from the same greedy claim
        // replay; the driver then refines it with the pilot (see
        // [`assign_blocks_bw`], [`assign_blocks_numa`], [`adapt_plan`]).
        // This is also ws-adapt's degenerate path (1 core / no blocks),
        // which makes it bit-identical to ws-dyn there.
        Scheduler::WorkStealing
        | Scheduler::WorkStealingDyn
        | Scheduler::WorkStealingBw
        | Scheduler::WorkStealingNuma
        | Scheduler::WorkStealingAdapt => greedy_claim(&block_work(row_work, blocks), cores, None),
    }
}

/// The greedy claim replay both the plain work-stealing assignment and the
/// ws-bw rebalance share: walk blocks in order, handing each to the core
/// whose estimated finish time is smallest (ties toward the lowest core
/// id). `slow` scales a core's effective cost — `None` is the plain claim,
/// ws-bw passes its pilot-derived per-core slowdown factors.
fn greedy_claim(work: &[f64], cores: usize, slow: Option<&[f64]>) -> Vec<Vec<usize>> {
    let mut plan: Vec<Vec<usize>> = vec![Vec::new(); cores];
    let mut est = vec![0.0f64; cores];
    for (i, &wb) in work.iter().enumerate() {
        let cost = |c: usize| match slow {
            Some(f) => (est[c] + wb) * f[c],
            None => est[c],
        };
        let mut best = 0usize;
        for c in 1..cores {
            if cost(c) < cost(best) {
                best = c;
            }
        }
        plan[best].push(i);
        est[best] += wb;
    }
    plan
}

/// Per-block estimated work in the claim replay's units (Gustavson multiply
/// counts plus the per-row overhead term) — the one formula both the greedy
/// claim replay and the ws-bw pilot rank blocks by.
fn block_work(row_work: &[u64], blocks: &[(usize, usize)]) -> Vec<f64> {
    blocks
        .iter()
        .map(|&(lo, hi)| (row_work[lo..hi].iter().sum::<u64>() + (hi - lo) as u64) as f64)
        .collect()
}

/// Contiguous simulated line ranges one block will stream through the
/// shared memory system (`(first_line, nlines, write)`), derived from the
/// canonical B addresses and the block's window of the shared destination
/// region — the very lines the real replay will price.
#[allow(clippy::too_many_arguments)]
fn block_line_ranges(
    a: &Csr,
    b: &Csr,
    blocks: &[(usize, usize)],
    line_shift: u32,
    b_addrs: (u64, u64, u64),
    out_addrs: (u64, u64, u64),
    block_est: &[u64],
    block_off: &[u64],
) -> Vec<Vec<(u64, u64, bool)>> {
    let mut ranges: Vec<Vec<(u64, u64, bool)>> = Vec::with_capacity(blocks.len());
    // First-touch stamps so each B row is counted once per block.
    let mut seen = vec![u32::MAX; b.nrows];
    let push = |out: &mut Vec<(u64, u64, bool)>, start: u64, bytes: u64, write: bool| {
        if bytes == 0 {
            return;
        }
        let first = start >> line_shift;
        let last = (start + bytes - 1) >> line_shift;
        out.push((first, last - first + 1, write));
    };
    for (bi, &(lo, hi)) in blocks.iter().enumerate() {
        let mut r = Vec::new();
        for row in lo..hi {
            let (ak, _) = a.row(row);
            for &j in ak {
                let j = j as usize;
                if seen[j] == bi as u32 {
                    continue;
                }
                seen[j] = bi as u32;
                let len = b.row_len(j) as u64;
                push(&mut r, b_addrs.1 + b.indptr[j] as u64 * 4, len * 4, false);
                push(&mut r, b_addrs.2 + b.indptr[j] as u64 * 4, len * 4, false);
            }
        }
        // The block's output window: global indptr rows plus its packed
        // element span.
        push(&mut r, out_addrs.0 + (lo as u64 + 1) * 8, (hi - lo) as u64 * 8, true);
        push(&mut r, out_addrs.1 + block_off[bi] * 4, block_est[bi] * 4, true);
        push(&mut r, out_addrs.2 + block_off[bi] * 4, block_est[bi] * 4, true);
        ranges.push(r);
    }
    ranges
}

/// Synthesize the pilot traces for `plan`: each core walks its blocks in
/// claim order, touching every `stride`-th line of the block's concatenated
/// ranges at a synthetic local time spread across the block's estimated
/// work. The sampling offset carries *across* ranges, so the event count is
/// genuinely ~`total_lines / stride` even when a block has many short
/// ranges. Events carry `shadow_hit = false` and `paid_bw = false`, so the
/// pilot prices pure contention (queueing, row-buffer interference) without
/// sharing refunds muddying the signal — and each core's events are stamped
/// with its socket (`socks`), so the pilot sees the same NUMA distances the
/// real replay will.
fn pilot_traces(
    plan: &[Vec<usize>],
    work: &[f64],
    ranges: &[Vec<(u64, u64, bool)>],
    stride: u64,
    socks: &[u8],
) -> Vec<TraceBuf> {
    plan.iter()
        .enumerate()
        .map(|(core, mine)| {
            let mut buf = TraceBuf::new();
            let mut t = 0.0f64;
            for &bi in mine {
                let block_lines: u64 = ranges[bi].iter().map(|&(_, n, _)| n).sum();
                let total = block_lines.div_ceil(stride).max(1);
                let mut k = 0u64;
                // Offset of the next sample within the concatenated stream.
                let mut next = 0u64;
                for &(first, nlines, write) in &ranges[bi] {
                    while next < nlines {
                        let time = t + work[bi] * k as f64 / total as f64;
                        buf.push(
                            TraceEvent::new(first + next, TraceKind::Demand, write, false, false, 1)
                                .with_socket(socks[core]),
                            time,
                        );
                        k += 1;
                        next += stride;
                    }
                    next -= nlines;
                }
                t += work[bi];
            }
            buf
        })
        .collect()
}

/// Per-block shared-output windows: each block owns the element span its
/// Gustavson estimate bounds (`max(1, work)` so even an empty block gets a
/// window). Returns `(block_est, block_off, total_est)` — used both for the
/// real run's `bind_output_block` and for the pilot's line ranges, so the
/// two always agree on the canonical addresses.
fn block_windows(row_work: &[u64], blocks: &[(usize, usize)]) -> (Vec<u64>, Vec<u64>, u64) {
    let mut block_est: Vec<u64> = Vec::with_capacity(blocks.len());
    let mut block_off: Vec<u64> = Vec::with_capacity(blocks.len());
    let mut total_est = 0u64;
    for &(lo, hi) in blocks {
        let est = row_work[lo..hi].iter().sum::<u64>().max(1);
        block_off.push(total_est);
        block_est.push(est);
        total_est += est;
    }
    (block_est, block_off, total_est)
}

/// One pilot-score memo shared by *every* pilot a single job builds, keyed
/// by `(block geometry, per-block work bits, plan)`. `ws-bw` and `ws-numa`
/// arbitrate overlapping candidate sets over one geometry, and `ws-adapt`
/// additionally scores split geometries and probe-weighted variants of the
/// same plans — a single cache key covering all three means no synthetic
/// trace set is ever replayed twice per job. Scoring stays a pure function
/// of the key; the cache only skips recomputation.
type PilotKey = (Vec<(usize, usize)>, Vec<u64>, Vec<Vec<usize>>);
type PilotMemo = RefCell<HashMap<PilotKey, Vec<f64>>>;

/// Shared machinery of the pilot-guided schedulers (`ws-bw`, `ws-numa`,
/// `ws-adapt`): the per-block work estimates, the canonical line ranges
/// each block will stream, each core's socket, and the one-shot
/// socket-stamped pilot replay that scores a candidate plan. A pure
/// function of the inputs, so every plan it arbitrates is bit-reproducible.
struct Pilot<'a> {
    sys: &'a SystemConfig,
    blocks: Vec<(usize, usize)>,
    work: Vec<f64>,
    ranges: Vec<Vec<(u64, u64, bool)>>,
    stride: u64,
    socks: Vec<u8>,
    cfg: crate::config::SharedMemConfig,
    memo: &'a PilotMemo,
}

impl<'a> Pilot<'a> {
    /// The one entry point every pilot-guided scheduler builds its synthetic
    /// traces through. `work` is the per-block cost the trace events are
    /// time-spread by: the Gustavson estimate (`block_work`) for the fixed
    /// schedulers — whose plans must reproduce bit-for-bit — or `ws-adapt`'s
    /// probed per-block cycles, which time the same line ranges more
    /// honestly for heterogeneous kernels.
    #[allow(clippy::too_many_arguments)]
    fn build(
        sys: &'a SystemConfig,
        a: &Csr,
        b: &Csr,
        work: Vec<f64>,
        blocks: &[(usize, usize)],
        b_addrs: (u64, u64, u64),
        out_addrs: (u64, u64, u64),
        block_est: &[u64],
        block_off: &[u64],
        cores: usize,
        memo: &'a PilotMemo,
    ) -> Pilot<'a> {
        let line_shift = sys.mem.l1d.line_bytes.trailing_zeros();
        let ranges = block_line_ranges(
            a, b, blocks, line_shift, b_addrs, out_addrs, block_est, block_off,
        );
        let total_lines: u64 = ranges.iter().flatten().map(|&(_, n, _)| n).sum();
        // Keep the pilot cheap: sample every stride-th line, aiming for at
        // most ~150k synthetic events regardless of matrix size.
        let stride = (total_lines / 150_000 + 1).max(1);
        let socks: Vec<u8> = (0..cores)
            .map(|c| sys.shared.socket_of_core(c, cores) as u8)
            .collect();
        // One-shot pilot pass (no iteration needed for an estimate).
        let cfg = crate::config::SharedMemConfig {
            max_replay_iters: 1,
            ..sys.shared
        };
        Pilot { sys, blocks: blocks.to_vec(), work, ranges, stride, socks, cfg, memo }
    }

    /// Per-core pilot stall score for `plan`: queueing, row-buffer
    /// interference, and hop-priced NUMA charges (zero at one socket, so
    /// the `ws-bw` arbitration is bit-identical to the flat model there).
    /// Memoized in the job-wide cache — a plan scored once during `ws-bw`'s
    /// arbitration is not re-replayed when `ws-numa` or `ws-adapt`
    /// considers it again under the same geometry and work weights.
    fn stalls(&self, plan: &[Vec<usize>]) -> Vec<f64> {
        let key: PilotKey = (
            self.blocks.clone(),
            self.work.iter().map(|w| w.to_bits()).collect(),
            plan.to_vec(),
        );
        if let Some(scores) = self.memo.borrow().get(&key) {
            return scores.clone();
        }
        let traces = pilot_traces(plan, &self.work, &self.ranges, self.stride, &self.socks);
        let out = shared::replay(&self.sys.mem, &self.cfg, &traces);
        let scores: Vec<f64> = out
            .per_core
            .iter()
            .map(|s| {
                s.llc_queue_cycles
                    + s.dram_queue_cycles
                    + s.row_extra_cycles.max(0.0)
                    + s.remote_extra_cycles
            })
            .collect();
        self.memo.borrow_mut().insert(key, scores.clone());
        scores
    }

    fn core_work(&self, plan: &[Vec<usize>]) -> Vec<f64> {
        plan.iter()
            .map(|mine| mine.iter().map(|&bi| self.work[bi]).sum::<f64>())
            .collect()
    }

    /// Predicted makespan of `plan`: the slowest core's work plus its pilot
    /// stalls.
    fn makespan(&self, plan: &[Vec<usize>], stalls: &[f64]) -> f64 {
        self.core_work(plan)
            .iter()
            .zip(stalls)
            .map(|(&w, &s)| w + s)
            .fold(0.0, f64::max)
    }

    /// Per-block fraction of the block's line footprint homed to each
    /// socket under the blind `line % channels` interleave — what
    /// `ws-numa`'s candidate claim keys block placement on when
    /// [`crate::config::PagePlacement::Interleave`] is active. Under
    /// first-touch the homes are schedule-made, so the claim loop in
    /// [`assign_blocks_numa`] tracks them incrementally instead of using
    /// this static table; `ws-adapt`'s scaled phase claim still uses it as
    /// a cheap shaping heuristic there (the placement-aware pilot replay
    /// arbitrates every candidate either way).
    fn socket_fractions(&self) -> Vec<Vec<f64>> {
        let shared = &self.sys.shared;
        let channels = shared.dram_channels as u64;
        self.ranges
            .iter()
            .map(|r| {
                let mut per = vec![0u64; shared.sockets];
                let mut total = 0u64;
                for &(first, nlines, _) in r {
                    // A contiguous line range visits the channels
                    // cyclically: every channel gets `nlines / channels`,
                    // and the first `nlines % channels` channels starting
                    // at `first % channels` get one more.
                    let base = nlines / channels;
                    let rem = nlines % channels;
                    let start = first % channels;
                    for ch in 0..channels {
                        let pos = (ch + channels - start) % channels;
                        let cnt = base + u64::from(pos < rem);
                        per[shared.socket_of_channel(ch as usize)] += cnt;
                    }
                    total += nlines;
                }
                per.iter()
                    .map(|&n| n as f64 / total.max(1) as f64)
                    .collect()
            })
            .collect()
    }

    /// Mean hop distance of each block's footprint from each socket,
    /// tabulated once so claim loops stay O(blocks x cores). All-zero rows
    /// at one socket.
    fn socket_hops(&self) -> Vec<Vec<f64>> {
        let shared = &self.sys.shared;
        self.socket_fractions()
            .iter()
            .map(|f| {
                (0..shared.sockets)
                    .map(|s| {
                        f.iter()
                            .enumerate()
                            .map(|(s2, &x)| x * shared.socket_distance(s, s2) as f64)
                            .sum()
                    })
                    .collect()
            })
            .collect()
    }

    /// Per-block peak single-channel fraction of the block's line footprint
    /// — the bandwidth-concentration signal `ws-adapt`'s split pass keys
    /// on. A block whose lines pile onto one DRAM channel serializes behind
    /// that channel no matter which core runs it; cutting it in two lets
    /// the halves' windows interleave across channels.
    fn channel_peak(&self) -> Vec<f64> {
        let channels = self.sys.shared.dram_channels as u64;
        self.ranges
            .iter()
            .map(|r| {
                let mut per = vec![0u64; channels as usize];
                let mut total = 0u64;
                for &(first, nlines, _) in r {
                    let base = nlines / channels;
                    let rem = nlines % channels;
                    let start = first % channels;
                    for ch in 0..channels {
                        let pos = (ch + channels - start) % channels;
                        per[ch as usize] += base + u64::from(pos < rem);
                    }
                    total += nlines;
                }
                per.iter().copied().max().unwrap_or(0) as f64 / total.max(1) as f64
            })
            .collect()
    }
}

/// The `ws-bw` assignment: run the plain greedy plan, price it with a
/// single-pass pilot replay (the same deterministic engine the driver runs
/// on the real traces), rebalance blocks away from cores whose channels /
/// LLC slices saturated, and keep whichever plan the pilot scores better —
/// so by its own estimate `ws-bw` never loses to the plain plan. Returns
/// the chosen plan with its pilot stall vector, so `ws-numa` can arbitrate
/// against it without re-scoring the same plan. (The driver only builds a
/// pilot — and so only calls this — with >= 2 cores and a non-empty block
/// list; degenerate cases take the plain `assign_blocks` path there.)
fn assign_blocks_bw(
    pilot: &Pilot,
    row_work: &[u64],
    blocks: &[(usize, usize)],
    cores: usize,
) -> (Vec<Vec<usize>>, Vec<f64>) {
    let plan0 = assign_blocks(row_work, blocks, cores, Scheduler::WorkStealing);
    // Pilot the plain plan and turn each core's observed contention into a
    // slowdown factor; then rebalance with the greedy claim replay where a
    // saturated core's queue looks longer than its raw work.
    let stalls0 = pilot.stalls(&plan0);
    let w0 = pilot.core_work(&plan0);
    let slow: Vec<f64> = stalls0
        .iter()
        .zip(&w0)
        .map(|(&s, &w)| 1.0 + s / w.max(1.0))
        .collect();
    let plan_bw = greedy_claim(&pilot.work, cores, Some(&slow));

    // Keep the plan the pilot scores better (ties keep the plain plan, so
    // ws-bw degrades to exactly ws-dyn when bandwidth is not the problem).
    let stalls_bw = pilot.stalls(&plan_bw);
    if pilot.makespan(&plan_bw, &stalls_bw) < pilot.makespan(&plan0, &stalls0) {
        (plan_bw, stalls_bw)
    } else {
        (plan0, stalls0)
    }
}

/// The `ws-numa` assignment: start from `ws-bw`'s plan, build a candidate
/// that claims each block onto the core whose *socket* keeps the block's
/// line footprint local (remote lines inflate the block's effective cost by
/// the hop-priced transfer ratio), and let the socket-stamped pilot replay
/// arbitrate — keeping `ws-bw`'s plan whenever the pilot predicts no win.
/// At one socket every fraction is local and the candidate is never built,
/// so `ws-numa` degrades to exactly `ws-bw`.
///
/// The candidate's distance signal follows the active page-placement
/// policy. Under the blind interleave a block's footprint is striped over
/// fixed channel groups, so the static per-socket mean hops
/// ([`Pilot::socket_hops`]) are exact. Under first-touch the homes are
/// *made* by the schedule itself, so the claim loop runs the same
/// first-touch rule the replay will: pages nobody claimed yet are free
/// (the claimant homes them locally), pages an earlier claim homed on
/// another socket cost their hop distance — scheduler and allocator
/// cooperating instead of fighting.
fn assign_blocks_numa(
    pilot: &Pilot,
    row_work: &[u64],
    blocks: &[(usize, usize)],
    cores: usize,
) -> Vec<Vec<usize>> {
    let (plan_bw, stalls_bw) = assign_blocks_bw(pilot, row_work, blocks, cores);
    let shared = &pilot.sys.shared;
    if shared.sockets <= 1 {
        return plan_bw;
    }
    let first_touch = shared.page_placement == crate::config::PagePlacement::FirstTouch;
    let static_hops = if first_touch { None } else { Some(pilot.socket_hops()) };
    // Claim-order first-touch approximation: 4KB-page homes (64 lines per
    // page, the same `line >> 6` the replay uses) assigned as blocks claim.
    let mut page_home: HashMap<u64, u8> = HashMap::new();
    // How much a fully-remote footprint inflates a block's effective cost:
    // the hop-priced transfer relative to the local transfer occupancy. The
    // pilot arbitrates below; this only shapes the candidate.
    let beta = shared.remote_transfer_cycles / shared.dram_transfer_cycles.max(1e-9);
    let mut plan: Vec<Vec<usize>> = vec![Vec::new(); cores];
    let mut est = vec![0.0f64; cores];
    for bi in 0..blocks.len() {
        let wb = pilot.work[bi];
        let hops_by_sock: Vec<f64> = match &static_hops {
            Some(h) => h[bi].clone(),
            None => {
                // Mean hop distance of this block's lines from each socket
                // given the homes claimed so far; still-unhomed lines are
                // free for every socket (the winner will home them).
                let mut per = vec![0.0f64; shared.sockets];
                let mut total = 0u64;
                for &(first, nlines, _) in &pilot.ranges[bi] {
                    let mut l = first;
                    let end = first + nlines;
                    while l < end {
                        let page = l >> 6;
                        let span = (((page + 1) << 6).min(end)) - l;
                        if let Some(&h) = page_home.get(&page) {
                            for (s, v) in per.iter_mut().enumerate() {
                                *v += span as f64
                                    * shared.socket_distance(s, h as usize) as f64;
                            }
                        }
                        total += span;
                        l += span;
                    }
                }
                per.iter().map(|&x| x / total.max(1) as f64).collect()
            }
        };
        let mut best = 0usize;
        let mut best_cost = f64::INFINITY;
        for (c, &e) in est.iter().enumerate() {
            let cost = e + wb * (1.0 + beta * hops_by_sock[pilot.socks[c] as usize]);
            if cost < best_cost {
                best_cost = cost;
                best = c;
            }
        }
        plan[best].push(bi);
        est[best] = best_cost;
        if first_touch {
            let home = pilot.socks[best];
            for &(first, nlines, _) in &pilot.ranges[bi] {
                for page in (first >> 6)..=((first + nlines - 1) >> 6) {
                    page_home.entry(page).or_insert(home);
                }
            }
        }
    }
    let stalls_numa = pilot.stalls(&plan);
    if pilot.makespan(&plan, &stalls_numa) < pilot.makespan(&plan_bw, &stalls_bw) {
        plan
    } else {
        plan_bw
    }
}

// ---------------------------------------------------------------------------
// ws-adapt: per-block kernel + geometry adaptation
// ---------------------------------------------------------------------------

/// Which kernel executes one row block under `ws-adapt`. `Job` is the
/// implementation the job asked for; the other three are the paper kernels
/// the planner may swap a block onto (all group-local, so their per-block
/// counts are exactly the serial loop's for the same rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BlockKernel {
    Job,
    SclArray,
    SclHash,
    Spz,
}

/// A swap must beat the job's own kernel on probed cycles by this margin —
/// below it the difference is within the probe's cross-block cache-carryover
/// noise and the planner keeps the homogeneous choice.
const ADAPT_SWAP_MARGIN: f64 = 0.02;

/// The adaptive plan must beat the best fixed candidate's score by this
/// margin, otherwise `ws-adapt` executes that fixed plan bit-identically
/// (the "pilot predicts no win" fallback).
const ADAPT_PLAN_MARGIN: f64 = 0.005;

/// Measure one kernel's per-block, per-phase cycle profile by running it
/// serially over the gated blocks on a scratch fork of the base machine.
/// The fork sees the same canonical B and shared-output addresses as the
/// real workers, so the profile is the kernel's true simulated cost before
/// contention — a probe in the pilot's sense: pure simulation, never host
/// timing, bit-reproducible. Ungated blocks return `None`.
#[allow(clippy::too_many_arguments)]
fn probe_blocks(
    base: &Machine,
    mut im: Box<dyn SpGemm>,
    a: &Csr,
    b: &Csr,
    blocks: &[(usize, usize)],
    block_est: &[u64],
    block_off: &[u64],
    gate: &[bool],
) -> Result<Vec<Option<[f64; NUM_PHASES]>>> {
    let mut m = base.fork_core(0);
    let mut out = Vec::with_capacity(blocks.len());
    let mut prev = m.metrics().phase_cycles;
    for (bi, &(lo, hi)) in blocks.iter().enumerate() {
        if !gate[bi] {
            out.push(None);
            continue;
        }
        m.bind_output_block(lo, block_off[bi], block_est[bi]);
        let slab = row_slab(a, lo, hi);
        im.multiply(&mut m, &slab, b)
            .with_context(|| format!("ws-adapt probe, rows {lo}..{hi}"))?;
        let cur = m.metrics().phase_cycles;
        let mut d = [0.0f64; NUM_PHASES];
        for (p, v) in d.iter_mut().enumerate() {
            *v = cur[p] - prev[p];
        }
        prev = cur;
        out.push(Some(d));
    }
    Ok(out)
}

/// Apportion a block's probed phase profile onto a sub-range of its rows,
/// proportional to the rows' share of the block's work estimate (`+1` per
/// row for the fixed row overheads, mirroring [`block_work`]).
fn apportion(
    phase: &[f64; NUM_PHASES],
    row_work: &[u64],
    block: (usize, usize),
    part: (usize, usize),
) -> [f64; NUM_PHASES] {
    let w = |lo: usize, hi: usize| (row_work[lo..hi].iter().sum::<u64>() + (hi - lo) as u64) as f64;
    let frac = w(part.0, part.1) / w(block.0, block.1).max(1.0);
    let mut out = *phase;
    for v in &mut out {
        *v *= frac;
    }
    out
}

/// Barrier-per-phase makespan of a candidate plan. The multi-core metrics
/// charge each phase's critical path as the max over cores
/// ([`MulticoreMetrics::from_cores`]), so a plan is scored as the sum over
/// phases of the slowest core's phase load — per-core pilot stalls are
/// spread over the core's phases proportionally to its load. This is what
/// makes heterogeneous plans honest: mixing kernels with different phase
/// shapes can inflate a phase barrier even when per-core *totals* balance,
/// and a total-work score would never see it. Public (with
/// [`phase_aware_claims`]) for the scheduler-decision bench.
pub fn phase_makespan(
    phase_cost: &[[f64; NUM_PHASES]],
    plan: &[Vec<usize>],
    stalls: &[f64],
) -> f64 {
    let cores = plan.len();
    let mut load = vec![[0.0f64; NUM_PHASES]; cores];
    for (c, mine) in plan.iter().enumerate() {
        for &bi in mine {
            for (p, l) in load[c].iter_mut().enumerate() {
                *l += phase_cost[bi][p];
            }
        }
    }
    let totals: Vec<f64> = load.iter().map(|l| l.iter().sum()).collect();
    (0..NUM_PHASES)
        .map(|p| {
            (0..cores)
                .map(|c| {
                    let stall = if totals[c] > 0.0 {
                        stalls.get(c).copied().unwrap_or(0.0) * load[c][p] / totals[c]
                    } else {
                        0.0
                    };
                    load[c][p] + stall
                })
                .fold(0.0, f64::max)
        })
        .sum()
}

/// Deterministic barrier-aware greedy claim: walk blocks in order, handing
/// each to the core that minimizes the resulting [`phase_makespan`]
/// objective (no stall term — the pilot arbitrates afterwards). Ties break
/// toward the lowest core id. Public for the scheduler-decision bench.
pub fn phase_aware_claims(phase_cost: &[[f64; NUM_PHASES]], cores: usize) -> Vec<Vec<usize>> {
    phase_claims_scaled(phase_cost, cores, |_, _| 1.0)
}

/// [`phase_aware_claims`] with a per-(block, core) cost inflation — the
/// NUMA candidate passes hop-priced factors, mirroring
/// [`assign_blocks_numa`]'s effective-cost claim.
fn phase_claims_scaled(
    phase_cost: &[[f64; NUM_PHASES]],
    cores: usize,
    scale: impl Fn(usize, usize) -> f64,
) -> Vec<Vec<usize>> {
    let mut plan: Vec<Vec<usize>> = vec![Vec::new(); cores];
    let mut load = vec![[0.0f64; NUM_PHASES]; cores];
    for (bi, pc) in phase_cost.iter().enumerate() {
        let mut best = 0usize;
        let mut best_score = f64::INFINITY;
        for c in 0..cores {
            let f = scale(bi, c);
            let mut score = 0.0;
            for p in 0..NUM_PHASES {
                let mut worst = load[c][p] + pc[p] * f;
                for (c2, l2) in load.iter().enumerate() {
                    if c2 != c {
                        worst = worst.max(l2[p]);
                    }
                }
                score += worst;
            }
            if score < best_score {
                best_score = score;
                best = c;
            }
        }
        let f = scale(bi, best);
        for (p, l) in load[best].iter_mut().enumerate() {
            *l += phase_cost[bi][p] * f;
        }
        plan[best].push(bi);
    }
    plan
}

/// Contiguous equal-work segments of the block list — `ws-adapt`'s
/// surrogate for the `static` scheduler's placement (contiguous row spans
/// per core, so B-row reuse stays core-local), but balanced by probed cost
/// instead of block count, on the same dyn geometry so the shared-output
/// mapping is untouched.
fn contiguous_claims(work: &[f64], cores: usize) -> Vec<Vec<usize>> {
    let mut plan: Vec<Vec<usize>> = vec![Vec::new(); cores];
    let total: f64 = work.iter().sum();
    let mut c = 0usize;
    let mut acc = 0.0f64;
    let mut spent = 0.0f64;
    for (i, &w) in work.iter().enumerate() {
        plan[c].push(i);
        acc += w;
        let target = (total - spent) / (cores - c) as f64;
        if c + 1 < cores && acc >= target {
            spent += acc;
            acc = 0.0;
            c += 1;
        }
    }
    plan
}

/// `ws-adapt`'s chosen execution: possibly-split geometry with its output
/// windows, the block-to-core plan, the per-block kernels, and the decision
/// summary (`achieved_stall_cycles` is filled by the driver after the real
/// replay).
struct AdaptPlan {
    blocks: Vec<(usize, usize)>,
    block_est: Vec<u64>,
    block_off: Vec<u64>,
    plan: Vec<Vec<usize>>,
    kernels: Vec<BlockKernel>,
    decisions: SchedDecisions,
}

/// The `ws-adapt` planner. Deterministic end to end: every input is either
/// matrix structure, the Table III work estimates, a probe (simulated
/// cycles), or the pilot replay — never host timing.
///
/// 1. **Probe** the job's kernel on every block, and each gated alternative
///    kernel (gates from the row-work histogram, within-block density, and
///    the accumulator footprint) on the blocks where it could plausibly
///    win. A swap must beat the job kernel by [`ADAPT_SWAP_MARGIN`].
/// 2. **Split** heavy or channel-concentrated blocks at a group-aligned,
///    work-balanced cut; the children tile the parent's output window, so
///    the shared-output mapping (and count additivity) is untouched.
/// 3. **Place** the heterogeneous blocks with the barrier-aware claim (and
///    a hop-scaled NUMA variant at >1 socket), then let the pilot-backed
///    [`phase_makespan`] arbitrate against the *exact* plans of the fixed
///    schedulers (ws-dyn's greedy claim, ws-bw, ws-numa, and a contiguous
///    static surrogate). Unless the adaptive plan wins by
///    [`ADAPT_PLAN_MARGIN`], the best fixed plan executes bit-identically.
#[allow(clippy::too_many_arguments)]
fn adapt_plan<F>(
    sys: &SystemConfig,
    base: &Machine,
    make_impl: &F,
    a: &Csr,
    b: &Csr,
    row_work: &[u64],
    blocks: &[(usize, usize)],
    block_est: &[u64],
    block_off: &[u64],
    b_addrs: (u64, u64, u64),
    out_addrs: (u64, u64, u64),
    cores: usize,
    impl_id: Option<crate::spgemm::ImplId>,
    memo: &PilotMemo,
) -> Result<AdaptPlan>
where
    F: Fn() -> Result<Box<dyn SpGemm>> + Sync,
{
    use crate::spgemm::ImplId;
    let nblocks = blocks.len();
    let group = sys.unit.n.max(1);

    // --- Table III gating stats, per block --------------------------------
    let bwork = block_work(row_work, blocks);
    let mean_work = bwork.iter().sum::<f64>() / nblocks as f64;
    let avg_wpr: Vec<f64> = blocks
        .iter()
        .map(|&(lo, hi)| row_work[lo..hi].iter().sum::<u64>() as f64 / (hi - lo) as f64)
        .collect();

    // The job's own kernel, when it is one of the paper trio.
    let job_kernel = match impl_id {
        Some(ImplId::SclArray) => Some(BlockKernel::SclArray),
        Some(ImplId::SclHash) => Some(BlockKernel::SclHash),
        Some(ImplId::Spz) => Some(BlockKernel::Spz),
        _ => None,
    };

    // --- Probe passes -----------------------------------------------------
    let job_phase: Vec<[f64; NUM_PHASES]> =
        probe_blocks(base, make_impl()?, a, b, blocks, block_est, block_off, &vec![
            true;
            nblocks
        ])?
        .into_iter()
        .map(|p| p.expect("all blocks gated on for the job probe"))
        .collect();

    let mut cand: Vec<(BlockKernel, Vec<Option<[f64; NUM_PHASES]>>)> = Vec::new();
    if job_kernel.is_some() {
        // scl-array: only when its dense accumulator (acc + stamp + touched,
        // 12 bytes per B column) stays cache-resident; pointless on empty
        // blocks.
        if job_kernel != Some(BlockKernel::SclArray)
            && b.ncols as u64 * 12 <= sys.mem.l2.size_bytes as u64
        {
            let gate: Vec<bool> = bwork
                .iter()
                .zip(blocks)
                .map(|(&w, &(lo, hi))| w > (hi - lo) as f64)
                .collect();
            if gate.iter().any(|&g| g) {
                let probe = probe_blocks(
                    base,
                    Box::new(crate::spgemm::scl_array::SclArray),
                    a,
                    b,
                    blocks,
                    block_est,
                    block_off,
                    &gate,
                )?;
                cand.push((BlockKernel::SclArray, probe));
            }
        }
        // scl-hash: wins on light rows, where its probe table stays small
        // and spz's per-group fixed costs dominate.
        if job_kernel != Some(BlockKernel::SclHash) {
            let gate: Vec<bool> = avg_wpr.iter().map(|&w| w <= (4 * group) as f64).collect();
            if gate.iter().any(|&g| g) {
                let probe = probe_blocks(
                    base,
                    Box::new(crate::spgemm::scl_hash::SclHash),
                    a,
                    b,
                    blocks,
                    block_est,
                    block_off,
                    &gate,
                )?;
                cand.push((BlockKernel::SclHash, probe));
            }
        }
        // spz: vectorized expansion pays off once rows carry real work.
        if job_kernel != Some(BlockKernel::Spz) {
            let gate: Vec<bool> = avg_wpr.iter().map(|&w| w >= group as f64).collect();
            if gate.iter().any(|&g| g) {
                let probe = probe_blocks(
                    base,
                    Box::new(crate::spgemm::spz::Spz::native()),
                    a,
                    b,
                    blocks,
                    block_est,
                    block_off,
                    &gate,
                )?;
                cand.push((BlockKernel::Spz, probe));
            }
        }
    }

    // --- Per-block kernel choice (with margin) ----------------------------
    let mut kernels = vec![BlockKernel::Job; nblocks];
    let mut chosen: Vec<[f64; NUM_PHASES]> = job_phase.clone();
    for bi in 0..nblocks {
        let jt: f64 = job_phase[bi].iter().sum();
        let mut best_t = jt;
        for (k, probe) in &cand {
            if let Some(p) = probe[bi] {
                let t: f64 = p.iter().sum();
                if t < best_t && t < jt * (1.0 - ADAPT_SWAP_MARGIN) {
                    best_t = t;
                    kernels[bi] = *k;
                    chosen[bi] = p;
                }
            }
        }
    }

    // --- Pilot on the dyn geometry (fixed-plan candidates) ----------------
    let pilot = Pilot::build(
        sys,
        a,
        b,
        bwork.clone(),
        blocks,
        b_addrs,
        out_addrs,
        block_est,
        block_off,
        cores,
        memo,
    );

    // --- Split pass: heavy or channel-concentrated blocks -----------------
    let peak = pilot.channel_peak();
    let conc = 1.5 / sys.shared.dram_channels as f64;
    let mut blocks2 = Vec::with_capacity(nblocks);
    let mut est2 = Vec::with_capacity(nblocks);
    let mut off2 = Vec::with_capacity(nblocks);
    let mut kernels2 = Vec::with_capacity(nblocks);
    let mut phase2: Vec<[f64; NUM_PHASES]> = Vec::with_capacity(nblocks);
    let mut split = 0usize;
    for bi in 0..nblocks {
        let (lo, hi) = blocks[bi];
        let want = bwork[bi] >= 2.0 * mean_work || (peak[bi] >= conc && bwork[bi] >= mean_work);
        let mut cut = 0usize;
        if want && hi - lo >= 2 * group {
            // Group-aligned, work-balanced cut.
            let total = bwork[bi];
            let mut acc = 0.0f64;
            let mut r = lo;
            while r + group < hi {
                let g = (r + group).min(hi);
                acc += (row_work[r..g].iter().sum::<u64>() + (g - r) as u64) as f64;
                r = g;
                if acc * 2.0 >= total {
                    break;
                }
            }
            if r > lo && r < hi {
                let w1: u64 = row_work[lo..r].iter().sum();
                let w2: u64 = row_work[r..hi].iter().sum();
                // Both windows must stay non-empty so the children tile the
                // parent's output span exactly.
                if w1 >= 1 && w2 >= 1 {
                    cut = r;
                }
            }
        }
        if cut != 0 {
            let w1: u64 = row_work[lo..cut].iter().sum();
            blocks2.push((lo, cut));
            est2.push(w1);
            off2.push(block_off[bi]);
            kernels2.push(kernels[bi]);
            phase2.push(apportion(&chosen[bi], row_work, (lo, hi), (lo, cut)));
            blocks2.push((cut, hi));
            est2.push(block_est[bi] - w1);
            off2.push(block_off[bi] + w1);
            kernels2.push(kernels[bi]);
            phase2.push(apportion(&chosen[bi], row_work, (lo, hi), (cut, hi)));
            split += 1;
        } else {
            blocks2.push((lo, hi));
            est2.push(block_est[bi]);
            off2.push(block_off[bi]);
            kernels2.push(kernels[bi]);
            phase2.push(chosen[bi]);
        }
    }

    // --- Fixed candidates: the exact plans the fixed schedulers run -------
    let job_only: Vec<[f64; NUM_PHASES]> = job_phase;
    let plan_dyn = assign_blocks(row_work, blocks, cores, Scheduler::WorkStealingDyn);
    let (plan_bw, _) = assign_blocks_bw(&pilot, row_work, blocks, cores);
    let plan_numa = assign_blocks_numa(&pilot, row_work, blocks, cores);
    let job_tot: Vec<f64> = job_only.iter().map(|p| p.iter().sum()).collect();
    let plan_contig = contiguous_claims(&job_tot, cores);
    let fixed = [plan_dyn, plan_bw, plan_numa, plan_contig];
    let mut best_fixed = 0usize;
    let mut best_fixed_score = f64::INFINITY;
    let mut fixed_stalls = 0.0f64;
    for (i, plan) in fixed.iter().enumerate() {
        let stalls = pilot.stalls(plan);
        let score = phase_makespan(&job_only, plan, &stalls);
        if score < best_fixed_score {
            best_fixed_score = score;
            best_fixed = i;
            fixed_stalls = stalls.iter().sum();
        }
    }

    // --- Adaptive candidates on the (possibly split) geometry -------------
    let adapt_work: Vec<f64> = phase2.iter().map(|p| p.iter().sum()).collect();
    let pilot2 = Pilot::build(
        sys,
        a,
        b,
        adapt_work,
        &blocks2,
        b_addrs,
        out_addrs,
        &est2,
        &off2,
        cores,
        memo,
    );
    let mut adapt_cands = vec![phase_aware_claims(&phase2, cores)];
    if sys.shared.sockets > 1 {
        let hops = pilot2.socket_hops();
        let shared = &sys.shared;
        let beta = shared.remote_transfer_cycles / shared.dram_transfer_cycles.max(1e-9);
        let socks = pilot2.socks.clone();
        adapt_cands.push(phase_claims_scaled(&phase2, cores, |bi, c| {
            1.0 + beta * hops[bi][socks[c] as usize]
        }));
    }
    let mut best_adapt = 0usize;
    let mut best_adapt_score = f64::INFINITY;
    let mut adapt_stalls = 0.0f64;
    for (i, plan) in adapt_cands.iter().enumerate() {
        let stalls = pilot2.stalls(plan);
        let score = phase_makespan(&phase2, plan, &stalls);
        if score < best_adapt_score {
            best_adapt_score = score;
            best_adapt = i;
            adapt_stalls = stalls.iter().sum();
        }
    }

    // --- Arbitrate --------------------------------------------------------
    let count = |kernels: &[BlockKernel], d: &mut SchedDecisions| {
        for &k in kernels {
            let eff = if k == BlockKernel::Job { job_kernel } else { Some(k) };
            match eff {
                Some(BlockKernel::SclArray) => d.blocks_scl_array += 1,
                Some(BlockKernel::SclHash) => d.blocks_scl_hash += 1,
                Some(BlockKernel::Spz) => d.blocks_spz += 1,
                _ => d.blocks_other += 1,
            }
            if k != BlockKernel::Job {
                d.swapped_blocks += 1;
            }
        }
    };
    if best_adapt_score < best_fixed_score * (1.0 - ADAPT_PLAN_MARGIN) {
        let mut d = SchedDecisions {
            total_blocks: blocks2.len(),
            split_blocks: split,
            predicted_stall_cycles: adapt_stalls,
            ..SchedDecisions::default()
        };
        count(&kernels2, &mut d);
        Ok(AdaptPlan {
            blocks: blocks2,
            block_est: est2,
            block_off: off2,
            plan: adapt_cands.swap_remove(best_adapt),
            kernels: kernels2,
            decisions: d,
        })
    } else {
        // No predicted win: execute the best fixed plan bit-identically
        // (original geometry, job kernel everywhere).
        let mut d = SchedDecisions {
            total_blocks: nblocks,
            predicted_stall_cycles: fixed_stalls,
            ..SchedDecisions::default()
        };
        count(&vec![BlockKernel::Job; nblocks], &mut d);
        let [p0, p1, p2, p3] = fixed;
        let plan = match best_fixed {
            0 => p0,
            1 => p1,
            2 => p2,
            _ => p3,
        };
        Ok(AdaptPlan {
            blocks: blocks.to_vec(),
            block_est: block_est.to_vec(),
            block_off: block_off.to_vec(),
            plan,
            kernels: vec![BlockKernel::Job; nblocks],
            decisions: d,
        })
    }
}

/// Rows `[lo, hi)` of `a` as a standalone CSR (same column space).
fn row_slab(a: &Csr, lo: usize, hi: usize) -> Csr {
    let base = a.indptr[lo];
    Csr {
        nrows: hi - lo,
        ncols: a.ncols,
        indptr: a.indptr[lo..=hi].iter().map(|&p| p - base).collect(),
        indices: a.indices[a.indptr[lo]..a.indptr[hi]].to_vec(),
        data: a.data[a.indptr[lo]..a.indptr[hi]].to_vec(),
    }
}

/// Concatenate per-block products (in block order) into one CSR.
fn stitch(nrows: usize, ncols: usize, parts: Vec<Option<Csr>>) -> Result<Csr> {
    let nnz: usize = parts.iter().map(|p| p.as_ref().map_or(0, |c| c.nnz())).sum();
    let mut indptr = Vec::with_capacity(nrows + 1);
    indptr.push(0usize);
    let mut indices = Vec::with_capacity(nnz);
    let mut data = Vec::with_capacity(nnz);
    for part in parts {
        let c = part.context("internal: a row block produced no result")?;
        let base = indices.len();
        for &p in &c.indptr[1..] {
            indptr.push(base + p);
        }
        indices.extend_from_slice(&c.indices);
        data.extend_from_slice(&c.data);
    }
    ensure!(indptr.len() == nrows + 1, "internal: stitched row count mismatch");
    Ok(Csr { nrows, ncols, indptr, indices, data })
}

/// Run `C = A*B` over row blocks of A on `cfg.cores` worker threads.
///
/// `make_impl` constructs one implementation instance per worker (the spz
/// engines are `&mut`-stateful, so cores cannot share one). Each worker
/// charges a [`Machine::fork_core`] fork whose `SystemConfig.cores` enables
/// the shared-LLC/DRAM contention adjustment. The block-to-core assignment
/// is decided up front by [`Scheduler`] (host-thread timing never leaks into
/// it), so the product, every event count, *and* the per-core cycle
/// breakdown are bit-reproducible run to run.
pub fn row_blocked<F>(
    sys: &SystemConfig,
    make_impl: F,
    a: &Csr,
    b: &Csr,
    cfg: &ParallelConfig,
) -> Result<ParallelRun>
where
    F: Fn() -> Result<Box<dyn SpGemm>> + Sync,
{
    ensure!(
        a.ncols == b.nrows,
        "dimension mismatch: ({}x{}) * ({}x{})",
        a.nrows,
        a.ncols,
        b.nrows,
        b.ncols
    );
    let cores = cfg.cores.max(1);
    ensure!(
        cores <= 64,
        "at most 64 simulated cores are supported (the shared-memory \
         replay's coherence directory uses 64-bit sharer sets), got {cores}"
    );
    // Validate the shared-memory knobs once at this boundary (like the
    // 64-core check above) instead of clamping deep inside the replay.
    sys.shared.validate()?;
    let mut sys = *sys;
    sys.cores = cores;
    let mut base = Machine::new(sys);
    // Every fork maps the shared operand (B) at the same canonical
    // addresses, and each core's private allocations live in a disjoint
    // region — so line identity across cores in the replay is exactly
    // "the same bytes of B". Registering B on the base machine (with the
    // same identity key the implementations use) pins those addresses
    // before forking and hands them to the ws-bw pilot.
    base.enable_shared_operands();
    let b_addrs = base
        .shared_csr(crate::spgemm::csr_shared_key(b), CsrAddrs::csr_sizes(b))
        .expect("shared-operand table was just enabled");

    // One O(nnz) Gustavson work estimate serves the ws-dyn/ws-bw block
    // cuts, the work-stealing claim replay, and the shared destination
    // region's per-block element windows.
    let row_work = crate::matrix::stats::row_work(a, b);
    let blocks = if matches!(
        cfg.scheduler,
        Scheduler::WorkStealingDyn
            | Scheduler::WorkStealingBw
            | Scheduler::WorkStealingNuma
            | Scheduler::WorkStealingAdapt
    ) && cfg.block_rows.is_none()
    {
        dyn_blocks_from_work(a.nrows, sys.unit.n, &row_work)
    } else {
        row_blocks(a.nrows, sys.unit.n, cfg)
    };

    // The modeled shared destination region: the stitched product's indptr
    // plus packed indices/data arrays at canonical addresses, with each
    // block owning the element window its Gustavson estimate bounds. Blocks
    // on different cores then write-share the boundary lines, so phase-3
    // output traffic exercises the replay's upgrade/invalidation path the
    // way a real parallel SpGEMM stresses its shared C arrays. (When
    // ws-adapt splits a block, the children tile the parent's window, so
    // this mapping — fixed before planning — stays valid for any geometry
    // the planner picks.)
    let (block_est, block_off, total_est) = block_windows(&row_work, &blocks);
    base.map_shared_output(a.nrows, total_est as usize);
    let out_addrs = base.shared_output().expect("shared output was just mapped");

    // One memo shared by every pilot this job builds (ws-bw/ws-numa
    // arbitration and all of ws-adapt's candidate geometries).
    let memo = PilotMemo::default();
    let mut kernels: Vec<BlockKernel> = Vec::new(); // empty = job kernel everywhere
    let mut decisions: Option<SchedDecisions> = None;
    let (plan, blocks, block_est, block_off) = match cfg.scheduler {
        // The pilot-guided schedulers only differ from the plain greedy
        // claim when there is something to arbitrate; at 1 core or with no
        // blocks, skip the (O(nnz) line-range) pilot setup entirely and
        // fall through to the claim they would have returned anyway.
        Scheduler::WorkStealingBw | Scheduler::WorkStealingNuma
            if cores >= 2 && !blocks.is_empty() =>
        {
            let pilot = Pilot::build(
                &sys,
                a,
                b,
                block_work(&row_work, &blocks),
                &blocks,
                b_addrs,
                out_addrs,
                &block_est,
                &block_off,
                cores,
                &memo,
            );
            let plan = if cfg.scheduler == Scheduler::WorkStealingNuma {
                assign_blocks_numa(&pilot, &row_work, &blocks, cores)
            } else {
                assign_blocks_bw(&pilot, &row_work, &blocks, cores).0
            };
            drop(pilot);
            (plan, blocks, block_est, block_off)
        }
        Scheduler::WorkStealingAdapt if cores >= 2 && !blocks.is_empty() => {
            let ap = adapt_plan(
                &sys,
                &base,
                &make_impl,
                a,
                b,
                &row_work,
                &blocks,
                &block_est,
                &block_off,
                b_addrs,
                out_addrs,
                cores,
                cfg.impl_id,
                &memo,
            )?;
            decisions = Some(ap.decisions);
            kernels = ap.kernels;
            (ap.plan, ap.blocks, ap.block_est, ap.block_off)
        }
        _ => (
            assign_blocks(&row_work, &blocks, cores, cfg.scheduler),
            blocks,
            block_est,
            block_off,
        ),
    };
    let blocks_per_core: Vec<usize> = plan.iter().map(|p| p.len()).collect();

    let results: Mutex<Vec<Option<Csr>>> = Mutex::new(vec![None; blocks.len()]);
    let mut per_core = Vec::with_capacity(cores);
    let mut failures: Vec<String> = Vec::new();

    // One bounded chunk ring per core: workers publish sealed trace chunks
    // as they run and the replay engine (phase 2) consumes them
    // *concurrently* on its own scoped thread, so peak trace memory is
    // O(ring) and the replay overlaps kernel execution instead of waiting
    // for the slowest core. A worker that errors or panics still finishes
    // its stream on drop, so the engine always terminates; its outcome is
    // then discarded by the failure check below.
    let ring = sys.shared.trace_ring_chunks;
    let (mut writers, streams): (Vec<_>, Vec<_>) =
        (0..cores).map(|_| TraceStream::channel(ring)).unzip();

    let replayed = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(cores);
        for (core, mine) in plan.iter().enumerate() {
            let machine = base.fork_core(core);
            let writer = writers.remove(0);
            let blocks = &blocks;
            let block_est = &block_est;
            let block_off = &block_off;
            let results = &results;
            let make_impl = &make_impl;
            let kernels = &kernels;
            handles.push(scope.spawn(move || -> Result<crate::sim::RunMetrics> {
                let mut machine = machine;
                machine.attach_trace_writer(writer);
                let mut im = make_impl()?;
                // ws-adapt's swapped kernels, built lazily per worker
                // (the spz engines are `&mut`-stateful, so cores cannot
                // share instances).
                let mut alts: [Option<Box<dyn SpGemm>>; 3] = [None, None, None];
                for &bi in mine {
                    let (lo, hi) = blocks[bi];
                    machine.bind_output_block(lo, block_off[bi], block_est[bi]);
                    let slab = row_slab(a, lo, hi);
                    let run_im = match kernels.get(bi).copied().unwrap_or(BlockKernel::Job) {
                        BlockKernel::Job => &mut im,
                        BlockKernel::SclArray => alts[0].get_or_insert_with(|| {
                            Box::new(crate::spgemm::scl_array::SclArray)
                        }),
                        BlockKernel::SclHash => alts[1].get_or_insert_with(|| {
                            Box::new(crate::spgemm::scl_hash::SclHash)
                        }),
                        BlockKernel::Spz => alts[2].get_or_insert_with(|| {
                            Box::new(crate::spgemm::spz::Spz::native())
                        }),
                    };
                    let c = run_im
                        .multiply(&mut machine, &slab, b)
                        .with_context(|| format!("rows {lo}..{hi} on core {core}"))?;
                    results.lock().unwrap()[bi] = Some(c);
                }
                machine.finish_trace();
                Ok(machine.metrics())
            }));
        }
        // Phase 2, pipelined: the deterministic replay engine drains the
        // live streams in canonical merge order, pricing the shared LLC
        // (queueing + MESI-lite coherence) and the banked DRAM channels and
        // iterating until the demotion-derived corrections reach a fixed
        // point. Bit-identical to replaying materialized traces after the
        // join (the streams carry the same events in the same order), so at
        // 1 core every replay-derived cost is still exactly zero and the
        // differential tests keep pinning the seed model.
        let replay = scope.spawn(|| {
            shared::ReplayEngine::from_source(
                &sys.mem,
                &sys.shared,
                shared::TraceSource::Streams(&streams),
            )
            .run()
        });
        for (core, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(Ok(m)) => per_core.push(m),
                Ok(Err(e)) => failures.push(format!("core {core}: {e:#}")),
                Err(_) => failures.push(format!("core {core}: worker panicked")),
            }
        }
        replay.join()
    });
    ensure!(failures.is_empty(), "parallel SpGEMM failed: {failures:?}");
    let outcome = match replayed {
        Ok(o) => o,
        Err(_) => anyhow::bail!("shared-memory replay engine panicked"),
    };
    // Compulsory-traffic oracle for this run: the achieved side is each
    // core's shared-LLC demand misses (one DRAM line per miss), the bound
    // is computed from the two sparsity patterns, the finished output size,
    // and the run's whole cache budget. The bound is a per-run fact stamped
    // identically on every core (aggregated with `max`, like
    // `replay_iters`).
    let c_nnz: u64 = results
        .lock()
        .unwrap()
        .iter()
        .map(|r| r.as_ref().map_or(0, |c| c.nnz() as u64))
        .sum();
    let oracle = crate::mem::oracle::OracleBound::new(a, b, c_nnz)
        .dram_lines(crate::mem::oracle::budget_lines(&sys, cores), cores);
    for (c, m) in per_core.iter_mut().enumerate() {
        m.shared = outcome.per_core[c];
        m.shared.achieved_dram_lines = m.shared.llc_misses;
        m.shared.oracle_dram_lines = oracle;
        let stalls = &outcome.per_core_phase_stalls[c];
        for (p, &stall) in stalls.iter().enumerate().take(NUM_PHASES) {
            m.phase_cycles[p] += stall;
            m.cycles += stall;
        }
    }
    if let Some(d) = decisions.as_mut() {
        d.achieved_stall_cycles = outcome
            .per_core_phase_stalls
            .iter()
            .flat_map(|s| s.iter().take(NUM_PHASES))
            .sum();
    }
    let mut metrics = MulticoreMetrics::from_cores(per_core);
    metrics.channel_busy_cycles = outcome.channel_busy_cycles;

    let csr = stitch(a.nrows, b.ncols, results.into_inner().unwrap())?;
    let block_plan: Vec<(usize, usize, Option<crate::spgemm::ImplId>)> = blocks
        .iter()
        .enumerate()
        .map(|(bi, &(lo, hi))| {
            let id = match kernels.get(bi).copied().unwrap_or(BlockKernel::Job) {
                BlockKernel::Job => None,
                BlockKernel::SclArray => Some(crate::spgemm::ImplId::SclArray),
                BlockKernel::SclHash => Some(crate::spgemm::ImplId::SclHash),
                BlockKernel::Spz => Some(crate::spgemm::ImplId::Spz),
            };
            (lo, hi, id)
        })
        .collect();
    Ok(ParallelRun {
        csr,
        metrics,
        blocks_per_core,
        decisions,
        block_plan,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::sim::RunMetrics;
    use crate::spgemm::{reference, same_product, ImplId};

    fn sys() -> SystemConfig {
        SystemConfig::default()
    }

    fn native(id: ImplId) -> impl Fn() -> Result<Box<dyn SpGemm>> + Sync {
        move || id.instantiate(crate::runtime::Engine::Native, std::path::Path::new("."))
    }

    fn serial(id: ImplId, a: &Csr) -> (Csr, RunMetrics) {
        let mut m = Machine::new(sys());
        let mut im = native(id)().unwrap();
        let c = im.multiply(&mut m, a, a).unwrap();
        (c, m.metrics())
    }

    #[test]
    fn scheduler_parses_and_prints() {
        assert_eq!("static".parse::<Scheduler>().unwrap(), Scheduler::Static);
        assert_eq!("ws".parse::<Scheduler>().unwrap(), Scheduler::WorkStealing);
        assert_eq!(
            "work-stealing".parse::<Scheduler>().unwrap().to_string(),
            "work-stealing"
        );
        assert_eq!("ws-dyn".parse::<Scheduler>().unwrap(), Scheduler::WorkStealingDyn);
        assert_eq!(Scheduler::WorkStealingDyn.to_string(), "ws-dyn");
        assert_eq!("ws-bw".parse::<Scheduler>().unwrap(), Scheduler::WorkStealingBw);
        assert_eq!(Scheduler::WorkStealingBw.to_string(), "ws-bw");
        assert_eq!("ws-numa".parse::<Scheduler>().unwrap(), Scheduler::WorkStealingNuma);
        assert_eq!(Scheduler::WorkStealingNuma.to_string(), "ws-numa");
        assert_eq!(
            "work-stealing-numa".parse::<Scheduler>().unwrap(),
            Scheduler::WorkStealingNuma
        );
        assert_eq!("ws-adapt".parse::<Scheduler>().unwrap(), Scheduler::WorkStealingAdapt);
        assert_eq!(Scheduler::WorkStealingAdapt.to_string(), "ws-adapt");
        assert_eq!(
            "work-stealing-adapt".parse::<Scheduler>().unwrap(),
            Scheduler::WorkStealingAdapt
        );
        // Every canonical name round-trips through the one parse table.
        for s in Scheduler::ALL {
            assert_eq!(s.name().parse::<Scheduler>().unwrap(), s);
        }
        let e = "greedy".parse::<Scheduler>().unwrap_err();
        assert!(e.contains("static") && e.contains("greedy") && e.contains("ws-dyn"), "{e}");
        assert!(e.contains("ws-bw"), "new schedulers must appear in the error: {e}");
        assert!(e.contains("ws-numa"), "new schedulers must appear in the error: {e}");
        assert!(e.contains("ws-adapt"), "new schedulers must appear in the error: {e}");
    }

    #[test]
    fn block_sizing_is_core_independent_and_group_aligned() {
        assert_eq!(block_rows_for(100, 16), 16);
        assert_eq!(block_rows_for(200_000, 16), 3136);
        assert_eq!(block_rows_for(0, 16), 16);
        assert_eq!(block_rows_for(100, 16) % 16, 0);
        let blocks = row_blocks(100, 16, &ParallelConfig::new(4));
        assert_eq!(blocks.len(), 7);
        assert_eq!(blocks[0], (0, 16));
        assert_eq!(blocks[6], (96, 100));
        // An explicit request is rounded up to group alignment.
        let cfg = ParallelConfig { block_rows: Some(10), ..ParallelConfig::new(2) };
        assert!(row_blocks(100, 16, &cfg).iter().all(|&(lo, _)| lo % 16 == 0));
    }

    #[test]
    fn row_slab_extracts_rows() {
        let a = gen::erdos_renyi(40, 30, 200, 5);
        let s = row_slab(&a, 16, 32);
        assert!(s.validate().is_ok());
        assert_eq!(s.nrows, 16);
        assert_eq!(s.ncols, 30);
        for r in 0..16 {
            assert_eq!(s.row(r), a.row(16 + r), "row {r}");
        }
    }

    #[test]
    fn parallel_matches_serial_for_every_impl() {
        let a = gen::rmat(128, 128, 1100, 0.6, 0.18, 0.14, 91);
        let r = reference(&a, &a);
        for id in ImplId::ALL {
            let (cs, _) = serial(id, &a);
            for cores in [1usize, 3] {
                let run = row_blocked(&sys(), native(id), &a, &a, &ParallelConfig::new(cores))
                    .unwrap();
                assert!(run.csr.validate().is_ok());
                assert_eq!(run.csr.indptr, cs.indptr, "{} x{cores}", id.name());
                assert_eq!(run.csr.indices, cs.indices, "{} x{cores}", id.name());
                assert!(same_product(&run.csr, &cs, 1e-5), "{} x{cores}", id.name());
                assert!(same_product(&run.csr, &r, 1e-3), "{} x{cores}", id.name());
                assert_eq!(run.metrics.cores(), cores);
                assert_eq!(
                    run.blocks_per_core.iter().sum::<usize>(),
                    row_blocks(a.nrows, 16, &ParallelConfig::new(cores)).len()
                );
            }
        }
    }

    #[test]
    fn per_core_counts_sum_to_single_core_totals() {
        let a = gen::rmat(128, 128, 1100, 0.6, 0.18, 0.14, 92);
        for id in ImplId::ALL {
            let one = row_blocked(&sys(), native(id), &a, &a, &ParallelConfig::new(1)).unwrap();
            for cores in [2usize, 7] {
                for sched in [Scheduler::Static, Scheduler::WorkStealing] {
                    let cfg = ParallelConfig { scheduler: sched, ..ParallelConfig::new(cores) };
                    let many = row_blocked(&sys(), native(id), &a, &a, &cfg).unwrap();
                    assert_eq!(
                        many.metrics.total.ops, one.metrics.total.ops,
                        "{} x{cores} {sched}", id.name()
                    );
                }
            }
        }
    }

    #[test]
    fn group_aligned_blocks_keep_spz_counts_exactly_serial() {
        let a = gen::rmat(160, 160, 1400, 0.58, 0.2, 0.14, 93);
        for id in [ImplId::SclArray, ImplId::SclHash, ImplId::Spz] {
            let (_, sm) = serial(id, &a);
            let run = row_blocked(&sys(), native(id), &a, &a, &ParallelConfig::new(4)).unwrap();
            assert_eq!(run.metrics.total.ops, sm.ops, "{}", id.name());
        }
    }

    #[test]
    fn work_stealing_schedule_is_deterministic_and_beats_static_on_skew() {
        let a = gen::rmat(256, 256, 2600, 0.62, 0.18, 0.14, 97);
        let run =
            || row_blocked(&sys(), native(ImplId::Spz), &a, &a, &ParallelConfig::new(4)).unwrap();
        let r1 = run();
        let r2 = run();
        let c1: Vec<f64> = r1.metrics.per_core.iter().map(|m| m.cycles).collect();
        let c2: Vec<f64> = r2.metrics.per_core.iter().map(|m| m.cycles).collect();
        assert_eq!(c1, c2, "per-core schedule must not depend on host timing");
        assert_eq!(r1.blocks_per_core, r2.blocks_per_core);
        // R-MAT hubs cluster in the low rows, so contiguous static chunking
        // overloads one core; estimate-driven dynamic claiming spreads them.
        let st_cfg = ParallelConfig { scheduler: Scheduler::Static, ..ParallelConfig::new(4) };
        let st = row_blocked(&sys(), native(ImplId::Spz), &a, &a, &st_cfg).unwrap();
        assert!(
            r1.metrics.critical_path_cycles <= st.metrics.critical_path_cycles * 1.05,
            "work-stealing {} should not lose to static {}",
            r1.metrics.critical_path_cycles,
            st.metrics.critical_path_cycles
        );
    }

    #[test]
    fn critical_path_shrinks_with_cores() {
        let a = gen::erdos_renyi(512, 512, 6000, 94);
        let one =
            row_blocked(&sys(), native(ImplId::Spz), &a, &a, &ParallelConfig::new(1)).unwrap();
        let eight =
            row_blocked(&sys(), native(ImplId::Spz), &a, &a, &ParallelConfig::new(8)).unwrap();
        assert!(
            eight.metrics.critical_path_cycles < one.metrics.critical_path_cycles,
            "{} !< {}",
            eight.metrics.critical_path_cycles,
            one.metrics.critical_path_cycles
        );
        assert!(eight.metrics.parallel_efficiency() > 1.5);
    }

    #[test]
    fn dyn_blocks_are_aligned_core_independent_and_cover_all_rows() {
        let a = gen::rmat(256, 256, 2600, 0.62, 0.18, 0.14, 98);
        let cfg2 = ParallelConfig {
            scheduler: Scheduler::WorkStealingDyn,
            ..ParallelConfig::new(2)
        };
        let cfg8 = ParallelConfig {
            scheduler: Scheduler::WorkStealingDyn,
            ..ParallelConfig::new(8)
        };
        let b2 = row_blocks_dyn(&a, &a, 16, &cfg2);
        let b8 = row_blocks_dyn(&a, &a, 16, &cfg8);
        assert_eq!(b2, b8, "dyn blocks must not depend on the core count");
        assert!(b2.iter().all(|&(lo, _)| lo % 16 == 0), "group alignment");
        assert_eq!(b2.first().unwrap().0, 0);
        assert_eq!(b2.last().unwrap().1, a.nrows);
        for w in b2.windows(2) {
            assert_eq!(w[0].1, w[1].0, "blocks must tile contiguously");
        }
        // An explicit block size overrides the policy (uniform fallback).
        let forced = ParallelConfig { block_rows: Some(32), ..cfg2 };
        let bf = row_blocks_dyn(&a, &a, 16, &forced);
        assert!(bf.iter().take(bf.len() - 1).all(|&(lo, hi)| hi - lo == 32));
    }

    #[test]
    fn ws_dyn_matches_serial_product_and_counts() {
        let a = gen::rmat(160, 160, 1400, 0.58, 0.2, 0.14, 99);
        for id in [ImplId::SclArray, ImplId::SclHash, ImplId::Spz] {
            let (cs, sm) = serial(id, &a);
            let cfg = ParallelConfig {
                scheduler: Scheduler::WorkStealingDyn,
                ..ParallelConfig::new(4)
            };
            let run = row_blocked(&sys(), native(id), &a, &a, &cfg).unwrap();
            assert_eq!(run.csr.indptr, cs.indptr, "{}", id.name());
            assert_eq!(run.csr.indices, cs.indices, "{}", id.name());
            // Group-aligned dyn blocks keep the row/group-local impls'
            // event counts exactly serial.
            assert_eq!(run.metrics.total.ops, sm.ops, "{}", id.name());
        }
    }

    #[test]
    fn ws_dyn_does_not_lose_to_uniform_work_stealing_on_skew() {
        let a = gen::rmat(256, 256, 2600, 0.62, 0.18, 0.14, 100);
        let ws = row_blocked(&sys(), native(ImplId::Spz), &a, &a, &ParallelConfig::new(4)).unwrap();
        let dyn_cfg = ParallelConfig {
            scheduler: Scheduler::WorkStealingDyn,
            ..ParallelConfig::new(4)
        };
        let dy = row_blocked(&sys(), native(ImplId::Spz), &a, &a, &dyn_cfg).unwrap();
        assert!(
            dy.metrics.critical_path_cycles <= ws.metrics.critical_path_cycles * 1.05,
            "ws-dyn {} should not lose to uniform work-stealing {}",
            dy.metrics.critical_path_cycles,
            ws.metrics.critical_path_cycles
        );
    }

    #[test]
    fn ws_bw_matches_serial_product_counts_and_stays_deterministic() {
        let a = gen::rmat(256, 256, 2600, 0.62, 0.18, 0.14, 103);
        for id in [ImplId::SclHash, ImplId::Spz] {
            let (cs, sm) = serial(id, &a);
            let cfg = ParallelConfig {
                scheduler: Scheduler::WorkStealingBw,
                ..ParallelConfig::new(4)
            };
            let r1 = row_blocked(&sys(), native(id), &a, &a, &cfg).unwrap();
            let r2 = row_blocked(&sys(), native(id), &a, &a, &cfg).unwrap();
            assert_eq!(r1.csr.indptr, cs.indptr, "{}", id.name());
            assert_eq!(r1.csr.indices, cs.indices, "{}", id.name());
            // Same group-aligned dyn block geometry as ws-dyn: event counts
            // stay exactly serial for the row/group-local impls.
            assert_eq!(r1.metrics.total.ops, sm.ops, "{}", id.name());
            // The pilot is a pure function of the inputs: bit-reproducible.
            assert_eq!(r1.blocks_per_core, r2.blocks_per_core, "{}", id.name());
            let c1: Vec<f64> = r1.metrics.per_core.iter().map(|m| m.cycles).collect();
            let c2: Vec<f64> = r2.metrics.per_core.iter().map(|m| m.cycles).collect();
            assert_eq!(c1, c2, "{}", id.name());
        }
    }

    #[test]
    fn ws_bw_uses_the_dyn_block_geometry() {
        let a = gen::rmat(256, 256, 2600, 0.62, 0.18, 0.14, 104);
        let bw2 = ParallelConfig { scheduler: Scheduler::WorkStealingBw, ..ParallelConfig::new(2) };
        let dy8 = ParallelConfig { scheduler: Scheduler::WorkStealingDyn, ..ParallelConfig::new(8) };
        assert_eq!(
            row_blocks_dyn(&a, &a, 16, &bw2),
            row_blocks_dyn(&a, &a, 16, &dy8),
            "ws-bw must not invent its own block geometry"
        );
        let nu2 =
            ParallelConfig { scheduler: Scheduler::WorkStealingNuma, ..ParallelConfig::new(2) };
        assert_eq!(
            row_blocks_dyn(&a, &a, 16, &nu2),
            row_blocks_dyn(&a, &a, 16, &dy8),
            "ws-numa must not invent its own block geometry either"
        );
    }

    #[test]
    fn ws_numa_at_one_socket_is_exactly_ws_bw() {
        // With the default single-socket config, every distance is zero:
        // the NUMA candidate is never built and ws-numa's plan — and every
        // per-core cycle count — is bit-identical to ws-bw's.
        let a = gen::rmat(256, 256, 2600, 0.62, 0.18, 0.14, 106);
        for id in [ImplId::SclHash, ImplId::Spz] {
            let bw = row_blocked(
                &sys(),
                native(id),
                &a,
                &a,
                &ParallelConfig { scheduler: Scheduler::WorkStealingBw, ..ParallelConfig::new(4) },
            )
            .unwrap();
            let nu = row_blocked(
                &sys(),
                native(id),
                &a,
                &a,
                &ParallelConfig {
                    scheduler: Scheduler::WorkStealingNuma,
                    ..ParallelConfig::new(4)
                },
            )
            .unwrap();
            assert_eq!(nu.blocks_per_core, bw.blocks_per_core, "{}", id.name());
            let c_bw: Vec<f64> = bw.metrics.per_core.iter().map(|m| m.cycles).collect();
            let c_nu: Vec<f64> = nu.metrics.per_core.iter().map(|m| m.cycles).collect();
            assert_eq!(c_nu, c_bw, "{}", id.name());
            assert_eq!(nu.csr, bw.csr, "{}", id.name());
        }
    }

    #[test]
    fn ws_numa_two_sockets_is_deterministic_and_keeps_count_additivity() {
        let mut cfgsys = sys();
        cfgsys.shared.sockets = 2;
        let a = gen::rmat(256, 256, 2600, 0.62, 0.18, 0.14, 107);
        for id in [ImplId::SclHash, ImplId::Spz] {
            let (cs, sm) = serial(id, &a);
            let cfg = ParallelConfig {
                scheduler: Scheduler::WorkStealingNuma,
                ..ParallelConfig::new(4)
            };
            let r1 = row_blocked(&cfgsys, native(id), &a, &a, &cfg).unwrap();
            let r2 = row_blocked(&cfgsys, native(id), &a, &a, &cfg).unwrap();
            assert_eq!(r1.csr.indptr, cs.indptr, "{}", id.name());
            assert_eq!(r1.csr.indices, cs.indices, "{}", id.name());
            // Group-aligned dyn blocks: counts stay exactly serial.
            assert_eq!(r1.metrics.total.ops, sm.ops, "{}", id.name());
            // Pure function of the inputs: bit-reproducible.
            assert_eq!(r1.blocks_per_core, r2.blocks_per_core, "{}", id.name());
            let c1: Vec<f64> = r1.metrics.per_core.iter().map(|m| m.cycles).collect();
            let c2: Vec<f64> = r2.metrics.per_core.iter().map(|m| m.cycles).collect();
            assert_eq!(c1, c2, "{}", id.name());
        }
    }

    #[test]
    fn invalid_shared_config_is_a_clean_driver_error() {
        let a = Csr::identity(32);
        let mut bad = sys();
        bad.shared.dram_channels = 0;
        let e = row_blocked(&bad, native(ImplId::SclHash), &a, &a, &ParallelConfig::new(2));
        assert!(e.is_err(), "dram_channels=0 must error, not panic");
        assert!(format!("{:#}", e.unwrap_err()).contains("dram_channels"));
        let mut odd = sys();
        odd.shared.sockets = 3; // 4 channels cannot split into 3 groups
        let e = row_blocked(&odd, native(ImplId::SclHash), &a, &a, &ParallelConfig::new(2));
        assert!(format!("{:#}", e.unwrap_err()).contains("sockets"));
    }

    #[test]
    fn shared_output_region_produces_write_shared_traffic() {
        // The stitched product's boundary lines are written by different
        // cores: a real multi-core run must report coherence upgrades now
        // that outputs share a destination region (before this, per-block
        // outputs were core-private and real workloads saw ~zero).
        let a = gen::erdos_renyi(512, 512, 6000, 105);
        let run =
            row_blocked(&sys(), native(ImplId::SclHash), &a, &a, &ParallelConfig::new(4)).unwrap();
        let tot = &run.metrics.total.shared;
        assert!(tot.upgrades > 0, "no write-shared output traffic: {tot:?}");
        assert!(tot.invalidations_sent > 0);
        assert!(tot.coherence_cycles > 0.0);
    }

    #[test]
    fn one_core_replay_is_an_exact_noop() {
        let a = gen::rmat(128, 128, 1100, 0.6, 0.18, 0.14, 101);
        for id in [ImplId::SclHash, ImplId::Spz] {
            let run = row_blocked(&sys(), native(id), &a, &a, &ParallelConfig::new(1)).unwrap();
            let s = &run.metrics.per_core[0].shared;
            assert!(s.llc_accesses > 0, "{}: trace must have been recorded", id.name());
            assert_eq!(s.stall_cycles(), 0.0, "{}", id.name());
            assert_eq!(s.llc_queue_cycles, 0.0, "{}", id.name());
            assert_eq!(s.dram_queue_cycles, 0.0, "{}", id.name());
            assert_eq!(s.coherence_cycles, 0.0, "{}", id.name());
            assert_eq!(s.shared_fills + s.demotions, 0, "{}: shadow == shared", id.name());
            assert_eq!(s.coherence_events(), 0, "{}", id.name());
            // The shadow and the shared model agree access for access.
            assert_eq!(
                s.llc_accesses + s.writeback_installs,
                run.metrics.per_core[0].mem.llc_accesses,
                "{}",
                id.name()
            );
        }
    }

    #[test]
    fn multicore_replay_reports_contention_and_stays_deterministic() {
        let a = gen::erdos_renyi(512, 512, 6000, 102);
        let run =
            || row_blocked(&sys(), native(ImplId::Spz), &a, &a, &ParallelConfig::new(4)).unwrap();
        let r1 = run();
        let r2 = run();
        // Bit-reproducible across host thread schedules: cycles, stalls,
        // coherence counters, and channel occupancy all match exactly.
        let c1: Vec<f64> = r1.metrics.per_core.iter().map(|m| m.cycles).collect();
        let c2: Vec<f64> = r2.metrics.per_core.iter().map(|m| m.cycles).collect();
        assert_eq!(c1, c2);
        assert_eq!(
            r1.metrics.per_core.iter().map(|m| m.shared).collect::<Vec<_>>(),
            r2.metrics.per_core.iter().map(|m| m.shared).collect::<Vec<_>>()
        );
        assert_eq!(r1.metrics.channel_busy_cycles, r2.metrics.channel_busy_cycles);
        assert_eq!(
            r1.metrics.channel_busy_cycles.len(),
            sys().shared.dram_channels
        );
        // Four cores streaming one B: the shared LLC sees real traffic and
        // the totals add up exactly.
        let tot = &r1.metrics.total.shared;
        assert!(tot.llc_accesses > 0);
        assert_eq!(tot.llc_hits + tot.llc_misses, tot.llc_accesses);
        let sum: u64 = r1.metrics.per_core.iter().map(|m| m.shared.llc_accesses).sum();
        assert_eq!(sum, tot.llc_accesses);
        // Per-phase cycles still sum to the core's total after folding.
        for m in &r1.metrics.per_core {
            let ps: f64 = m.phase_cycles.iter().sum();
            assert!(
                (ps - m.cycles).abs() <= 1e-9 * m.cycles.max(1.0),
                "{ps} vs {}",
                m.cycles
            );
        }
    }

    #[test]
    fn empty_and_tiny_matrices_work() {
        let e = Csr::empty(0, 0);
        let run =
            row_blocked(&sys(), native(ImplId::Spz), &e, &e, &ParallelConfig::new(4)).unwrap();
        assert_eq!(run.csr.nrows, 0);
        assert_eq!(run.csr.nnz(), 0);
        // More cores than blocks: idle cores report zero metrics.
        let tiny = Csr::identity(8);
        let run =
            row_blocked(&sys(), native(ImplId::SclHash), &tiny, &tiny, &ParallelConfig::new(7))
                .unwrap();
        assert_eq!(run.csr, tiny);
        assert_eq!(run.metrics.cores(), 7);
        assert_eq!(run.blocks_per_core.iter().sum::<usize>(), 1);
    }

    #[test]
    fn more_than_64_cores_is_a_clean_error() {
        let a = Csr::identity(8);
        let e = row_blocked(&sys(), native(ImplId::SclHash), &a, &a, &ParallelConfig::new(65));
        assert!(e.is_err(), "65 cores must error, not panic");
        assert!(format!("{:#}", e.unwrap_err()).contains("64"));
        // The boundary itself works.
        assert!(row_blocked(&sys(), native(ImplId::SclHash), &a, &a, &ParallelConfig::new(64))
            .is_ok());
    }

    fn adapt_cfg(cores: usize, id: ImplId) -> ParallelConfig {
        ParallelConfig {
            scheduler: Scheduler::WorkStealingAdapt,
            impl_id: Some(id),
            ..ParallelConfig::new(cores)
        }
    }

    #[test]
    fn ws_adapt_matches_serial_product_and_stays_deterministic() {
        let a = gen::rmat(256, 256, 2600, 0.62, 0.18, 0.14, 108);
        for id in [ImplId::SclArray, ImplId::SclHash, ImplId::Spz] {
            let (cs, _) = serial(id, &a);
            let cfg = adapt_cfg(4, id);
            let r1 = row_blocked(&sys(), native(id), &a, &a, &cfg).unwrap();
            let r2 = row_blocked(&sys(), native(id), &a, &a, &cfg).unwrap();
            assert_eq!(r1.csr.indptr, cs.indptr, "{}", id.name());
            assert_eq!(r1.csr.indices, cs.indices, "{}", id.name());
            assert!(same_product(&r1.csr, &cs, 1e-5), "{}", id.name());
            // Pure function of the inputs: bit-reproducible, decisions too.
            assert_eq!(r1.blocks_per_core, r2.blocks_per_core, "{}", id.name());
            assert_eq!(r1.decisions, r2.decisions, "{}", id.name());
            let c1: Vec<f64> = r1.metrics.per_core.iter().map(|m| m.cycles).collect();
            let c2: Vec<f64> = r2.metrics.per_core.iter().map(|m| m.cycles).collect();
            assert_eq!(c1, c2, "{}", id.name());
            let d = r1.decisions.expect("ws-adapt at 4 cores reports decisions");
            assert_eq!(
                d.blocks_scl_array + d.blocks_scl_hash + d.blocks_spz + d.blocks_other,
                d.total_blocks,
                "{}",
                id.name()
            );
            assert!(d.total_blocks >= r1.blocks_per_core.iter().sum::<usize>().min(1));
        }
    }

    #[test]
    fn ws_adapt_at_one_core_is_exactly_ws_dyn() {
        let a = gen::rmat(160, 160, 1400, 0.58, 0.2, 0.14, 109);
        for id in [ImplId::SclHash, ImplId::Spz] {
            let dy = row_blocked(
                &sys(),
                native(id),
                &a,
                &a,
                &ParallelConfig {
                    scheduler: Scheduler::WorkStealingDyn,
                    ..ParallelConfig::new(1)
                },
            )
            .unwrap();
            let ad = row_blocked(&sys(), native(id), &a, &a, &adapt_cfg(1, id)).unwrap();
            assert_eq!(ad.csr, dy.csr, "{}", id.name());
            assert_eq!(ad.blocks_per_core, dy.blocks_per_core, "{}", id.name());
            let c_dy: Vec<f64> = dy.metrics.per_core.iter().map(|m| m.cycles).collect();
            let c_ad: Vec<f64> = ad.metrics.per_core.iter().map(|m| m.cycles).collect();
            assert_eq!(c_ad, c_dy, "{}: 1-core ws-adapt must be ws-dyn", id.name());
            assert!(ad.decisions.is_none(), "degenerate path reports no decisions");
        }
    }

    #[test]
    fn ws_adapt_without_impl_id_never_swaps_kernels() {
        let a = gen::rmat(256, 256, 2600, 0.62, 0.18, 0.14, 110);
        let cfg = ParallelConfig {
            scheduler: Scheduler::WorkStealingAdapt,
            ..ParallelConfig::new(4)
        };
        let run = row_blocked(&sys(), native(ImplId::Spz), &a, &a, &cfg).unwrap();
        let d = run.decisions.expect("decisions still reported");
        assert_eq!(d.swapped_blocks, 0, "unknown impl_id must disable swapping");
        assert_eq!(d.blocks_other, d.total_blocks);
        // The product is still exactly the serial one.
        let (cs, _) = serial(ImplId::Spz, &a);
        assert_eq!(run.csr.indptr, cs.indptr);
        assert_eq!(run.csr.indices, cs.indices);
    }

    #[test]
    fn ws_adapt_counts_are_additive_per_chosen_impl() {
        // Sum of per-core matrix-unit ops must equal the sum over blocks of
        // the chosen impl's serial ops — group alignment guarantees it for
        // every kernel in the trio, whatever mix the planner picked.
        let a = gen::rmat(256, 256, 2600, 0.62, 0.18, 0.14, 111);
        let id = ImplId::Spz;
        let run = row_blocked(&sys(), native(id), &a, &a, &adapt_cfg(4, id)).unwrap();
        let one = row_blocked(&sys(), native(id), &a, &a, &adapt_cfg(1, id)).unwrap();
        // 1-core ws-adapt degrades to ws-dyn (same geometry, job kernel
        // everywhere), so comparing totals only makes sense when no blocks
        // were swapped; with swaps, additivity is pinned by the dedicated
        // integration test against per-impl serial slabs.
        let d = run.decisions.unwrap();
        if d.swapped_blocks == 0 && d.split_blocks == 0 {
            assert_eq!(run.metrics.total.ops, one.metrics.total.ops);
        }
        // Per-core cycles always sum to phase totals after stall folding.
        for m in &run.metrics.per_core {
            let ps: f64 = m.phase_cycles.iter().sum();
            assert!((ps - m.cycles).abs() <= 1e-9 * m.cycles.max(1.0));
        }
    }

    #[test]
    fn phase_aware_claims_balance_the_barrier_objective() {
        // Two heavy blocks with disjoint phase shapes: a total-work claim
        // would happily co-locate them; the barrier-aware claim must not.
        let mut costs = vec![[0.0; NUM_PHASES]; 4];
        costs[0][1] = 100.0; // expand-heavy
        costs[1][2] = 100.0; // sort-heavy
        costs[2][1] = 100.0;
        costs[3][2] = 100.0;
        let plan = phase_aware_claims(&costs, 2);
        assert_eq!(plan.iter().map(|p| p.len()).sum::<usize>(), 4);
        let score = phase_makespan(&costs, &plan, &[0.0, 0.0]);
        // Optimal: each core gets one expand-heavy and one sort-heavy block
        // (barrier 100 + 100); the naive pairing scores 400.
        assert!(score <= 200.0 + 1e-9, "barrier-aware claim scored {score}");
    }

    #[test]
    fn rectangular_products_supported() {
        let a = gen::erdos_renyi(64, 40, 300, 95);
        let b = gen::erdos_renyi(40, 32, 200, 96);
        let run =
            row_blocked(&sys(), native(ImplId::Spz), &a, &b, &ParallelConfig::new(3)).unwrap();
        assert!(same_product(&run.csr, &reference(&a, &b), 1e-3));
        let bad = row_blocked(&sys(), native(ImplId::Spz), &b, &a, &ParallelConfig::new(2));
        assert!(bad.is_err());
    }
}
