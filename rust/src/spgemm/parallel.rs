//! Multi-core SpGEMM driver: run any [`SpGemm`] implementation over row
//! blocks of A on real worker threads, one forked [`Machine`] per simulated
//! core (the paper's evaluation distributes rows of A to per-core matrix
//! units the same way; SpArch and the SSR multi-core clusters are the
//! related-work analogues).
//!
//! Row-wise SpGEMM makes this exact: rows `[lo, hi)` of `C = A*B` depend
//! only on rows `[lo, hi)` of A (and all of B), so a block is simulated by
//! multiplying the corresponding row *slab* of A against B and the per-block
//! outputs stitch back into one [`Csr`] in block order — bit-identical in
//! structure to the serial product, independent of core count and scheduler.
//!
//! Two invariants the tests pin:
//!
//! * **Blocks are core-count independent**: both the uniform splitter
//!   ([`block_rows_for`]) and the work-proportional one ([`row_blocks_dyn`])
//!   depend only on the matrices and the matrix-unit group size, so the
//!   per-core event counts of an N-core run always sum exactly to the
//!   1-core run's totals under the same block policy.
//! * **Blocks are aligned to the matrix-unit group size** (16 rows): the spz
//!   variants process rows in lockstep groups of `unit.n` streams, so
//!   group-aligned blocks leave every group's composition — and therefore
//!   every dynamic event count of `spz`, `scl-array`, and `scl-hash` —
//!   exactly equal to the serial run's. (`vec-radix` re-partitions its ESC
//!   batches per block and `spz-rsort` work-sorts within a block, so their
//!   counts match the 1-core *driver* run, not the serial loop.)
//!
//! After the workers join, the driver runs the **shared-memory replay**
//! ([`crate::mem::shared::replay`]): each core recorded its LLC-level access
//! trace during execution, and the deterministic replay prices the shared
//! LLC (queueing + MESI-lite coherence) and the multi-channel DRAM back end,
//! folding per-core stall cycles into the per-phase metrics. Everything
//! stays bit-reproducible across host thread schedules, and at 1 core the
//! replay is an exact no-op on the cycle counts.

use crate::config::SystemConfig;
use crate::matrix::Csr;
use crate::mem::{shared, TraceEvent};
use crate::sim::machine::NUM_PHASES;
use crate::sim::{Machine, MulticoreMetrics};
use crate::spgemm::SpGemm;
use crate::util::round_up;
use anyhow::{ensure, Context, Result};
use std::sync::Mutex;

/// How row blocks are assigned to cores.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheduler {
    /// Contiguous static partition of the block list (each core gets
    /// `nblocks/cores` consecutive blocks up front). Cheap, but exposed to
    /// load imbalance when heavy rows cluster — the effect `spz-rsort`'s
    /// row sorting (and Figure 11's work-variance column) makes measurable.
    Static,
    /// Dynamic self-scheduling off a shared queue: blocks are claimed in
    /// order by whichever core becomes idle first, so one heavy block never
    /// idles the pool. The claim sequence is simulated *deterministically*
    /// from the per-row work estimates (the same Gustavson work counts every
    /// implementation's Preprocess pass computes) rather than from host
    /// thread timing — per-core metrics, critical path, and fig12 are
    /// bit-reproducible run to run.
    WorkStealing,
    /// Work-stealing claims over *work-proportional* blocks: instead of a
    /// uniform row count per block, block boundaries are cut where the
    /// accumulated Gustavson work estimate crosses an equal share (see
    /// [`row_blocks_dyn`]), so heavy hub rows stop producing one outsized
    /// block. Boundaries stay group-aligned and depend only on the matrices
    /// — never the core count — preserving exact count additivity.
    WorkStealingDyn,
}

impl Scheduler {
    pub const fn name(self) -> &'static str {
        match self {
            Scheduler::Static => "static",
            Scheduler::WorkStealing => "work-stealing",
            Scheduler::WorkStealingDyn => "ws-dyn",
        }
    }
}

impl std::str::FromStr for Scheduler {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "static" => Ok(Scheduler::Static),
            "work-stealing" | "ws" => Ok(Scheduler::WorkStealing),
            "ws-dyn" | "work-stealing-dyn" => Ok(Scheduler::WorkStealingDyn),
            other => Err(format!(
                "unknown scheduler '{other}' (expected one of: static, work-stealing, ws-dyn)"
            )),
        }
    }
}

impl std::fmt::Display for Scheduler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(self.name())
    }
}

/// Parallel-execution parameters.
#[derive(Clone, Copy, Debug)]
pub struct ParallelConfig {
    /// Simulated cores (= real worker threads). Clamped to at least 1.
    pub cores: usize,
    pub scheduler: Scheduler,
    /// Rows of A per block (rounded up to the matrix-unit group size);
    /// `None` picks [`block_rows_for`]'s core-count-independent default.
    pub block_rows: Option<usize>,
}

impl ParallelConfig {
    pub fn new(cores: usize) -> Self {
        ParallelConfig {
            cores,
            scheduler: Scheduler::WorkStealing,
            block_rows: None,
        }
    }
}

/// Result of a parallel run: the stitched product, the per-core metrics
/// aggregate, and how many blocks each core executed (the scheduler's
/// footprint, useful for imbalance reporting).
#[derive(Clone, Debug)]
pub struct ParallelRun {
    pub csr: Csr,
    pub metrics: MulticoreMetrics,
    pub blocks_per_core: Vec<usize>,
}

/// Target block count for both the uniform and the work-proportional
/// splitters: ~64 blocks means plenty of steals even at 8 cores.
const TARGET_BLOCKS: usize = 64;

/// Default rows per block: targets ~[`TARGET_BLOCKS`] blocks with a
/// one-group floor, rounded up to the group size. Depends only on the
/// matrix and the unit geometry — never on the core count — so per-core
/// event counts sum identically at every core count.
pub fn block_rows_for(nrows: usize, group: usize) -> usize {
    let group = group.max(1);
    round_up(nrows.max(1).div_ceil(TARGET_BLOCKS).max(group), group)
}

/// The row-block list for an `nrows`-row A (block size from
/// [`ParallelConfig::block_rows`] or [`block_rows_for`]).
pub fn row_blocks(nrows: usize, group: usize, cfg: &ParallelConfig) -> Vec<(usize, usize)> {
    let bs = match cfg.block_rows {
        Some(req) => round_up(req.max(1), group.max(1)),
        None => block_rows_for(nrows, group),
    };
    let mut blocks = Vec::with_capacity(nrows.div_ceil(bs.max(1)));
    let mut lo = 0usize;
    while lo < nrows {
        let hi = (lo + bs).min(nrows);
        blocks.push((lo, hi));
        lo = hi;
    }
    blocks
}

/// Work-proportional row blocks (the `ws-dyn` policy): cut a block boundary
/// whenever the accumulated per-row work estimate (Gustavson multiply
/// counts plus a per-row overhead term, the same estimator the
/// work-stealing claim replay uses) crosses 1/[`TARGET_BLOCKS`] of the
/// total. Two invariants are preserved on purpose:
///
/// * boundaries move only at matrix-unit-group granularity, so the spz/scl
///   group compositions — and therefore their dynamic event counts — stay
///   exactly equal to the serial run's;
/// * the split depends only on `(a, b, group)`, never on the core count, so
///   per-core counts still sum identically at every core count.
///
/// An explicit [`ParallelConfig::block_rows`] request overrides the policy
/// and falls back to the uniform splitter.
pub fn row_blocks_dyn(a: &Csr, b: &Csr, group: usize, cfg: &ParallelConfig) -> Vec<(usize, usize)> {
    if cfg.block_rows.is_some() {
        return row_blocks(a.nrows, group, cfg);
    }
    dyn_blocks_from_work(a.nrows, group, &crate::matrix::stats::row_work(a, b))
}

/// [`row_blocks_dyn`]'s core, over a precomputed work estimate (the driver
/// computes `row_work` once and shares it with the scheduler).
fn dyn_blocks_from_work(nrows: usize, group: usize, row_work: &[u64]) -> Vec<(usize, usize)> {
    let group = group.max(1);
    let total: u64 = row_work.iter().sum::<u64>() + nrows as u64;
    let target = total.div_ceil(TARGET_BLOCKS as u64).max(1);
    let mut blocks = Vec::new();
    let mut lo = 0usize;
    let mut acc = 0u64;
    let mut r = 0usize;
    while r < nrows {
        let hi = (r + group).min(nrows);
        acc += row_work[r..hi].iter().sum::<u64>() + (hi - r) as u64;
        r = hi;
        if acc >= target || r == nrows {
            blocks.push((lo, r));
            lo = r;
            acc = 0;
        }
    }
    blocks
}

/// Per-core block assignment, decided up front so it depends only on the
/// inputs (never on host-thread timing):
///
/// * `Static` — contiguous equal-count chunks of the block list.
/// * `WorkStealing` — the deterministic replay of a dynamic self-scheduling
///   queue: walk blocks in order, handing each to the core whose accumulated
///   estimated work (Gustavson multiply counts, + a per-row term for the
///   fixed row overheads) is smallest — i.e. the core that would have gone
///   idle and stolen next. Ties break toward the lowest core id.
fn assign_blocks(
    row_work: &[u64],
    blocks: &[(usize, usize)],
    cores: usize,
    scheduler: Scheduler,
) -> Vec<Vec<usize>> {
    let nblocks = blocks.len();
    match scheduler {
        Scheduler::Static => (0..cores)
            .map(|c| (c * nblocks / cores..(c + 1) * nblocks / cores).collect())
            .collect(),
        Scheduler::WorkStealing | Scheduler::WorkStealingDyn => {
            let mut plan: Vec<Vec<usize>> = vec![Vec::new(); cores];
            let mut est = vec![0.0f64; cores];
            for (i, &(lo, hi)) in blocks.iter().enumerate() {
                let w: u64 = row_work[lo..hi].iter().sum();
                let mut best = 0usize;
                for c in 1..cores {
                    if est[c] < est[best] {
                        best = c;
                    }
                }
                plan[best].push(i);
                est[best] += (w + (hi - lo) as u64) as f64;
            }
            plan
        }
    }
}

/// Rows `[lo, hi)` of `a` as a standalone CSR (same column space).
fn row_slab(a: &Csr, lo: usize, hi: usize) -> Csr {
    let base = a.indptr[lo];
    Csr {
        nrows: hi - lo,
        ncols: a.ncols,
        indptr: a.indptr[lo..=hi].iter().map(|&p| p - base).collect(),
        indices: a.indices[a.indptr[lo]..a.indptr[hi]].to_vec(),
        data: a.data[a.indptr[lo]..a.indptr[hi]].to_vec(),
    }
}

/// Concatenate per-block products (in block order) into one CSR.
fn stitch(nrows: usize, ncols: usize, parts: Vec<Option<Csr>>) -> Result<Csr> {
    let nnz: usize = parts.iter().map(|p| p.as_ref().map_or(0, |c| c.nnz())).sum();
    let mut indptr = Vec::with_capacity(nrows + 1);
    indptr.push(0usize);
    let mut indices = Vec::with_capacity(nnz);
    let mut data = Vec::with_capacity(nnz);
    for part in parts {
        let c = part.context("internal: a row block produced no result")?;
        let base = indices.len();
        for &p in &c.indptr[1..] {
            indptr.push(base + p);
        }
        indices.extend_from_slice(&c.indices);
        data.extend_from_slice(&c.data);
    }
    ensure!(indptr.len() == nrows + 1, "internal: stitched row count mismatch");
    Ok(Csr { nrows, ncols, indptr, indices, data })
}

/// Run `C = A*B` over row blocks of A on `cfg.cores` worker threads.
///
/// `make_impl` constructs one implementation instance per worker (the spz
/// engines are `&mut`-stateful, so cores cannot share one). Each worker
/// charges a [`Machine::fork_core`] fork whose `SystemConfig.cores` enables
/// the shared-LLC/DRAM contention adjustment. The block-to-core assignment
/// is decided up front by [`Scheduler`] (host-thread timing never leaks into
/// it), so the product, every event count, *and* the per-core cycle
/// breakdown are bit-reproducible run to run.
pub fn row_blocked<F>(
    sys: &SystemConfig,
    make_impl: F,
    a: &Csr,
    b: &Csr,
    cfg: &ParallelConfig,
) -> Result<ParallelRun>
where
    F: Fn() -> Result<Box<dyn SpGemm>> + Sync,
{
    ensure!(
        a.ncols == b.nrows,
        "dimension mismatch: ({}x{}) * ({}x{})",
        a.nrows,
        a.ncols,
        b.nrows,
        b.ncols
    );
    let cores = cfg.cores.max(1);
    ensure!(
        cores <= 64,
        "at most 64 simulated cores are supported (the shared-memory \
         replay's coherence directory uses 64-bit sharer sets), got {cores}"
    );
    let mut sys = *sys;
    sys.cores = cores;
    let mut base = Machine::new(sys);
    // Every fork maps the shared operand (B) at the same canonical
    // addresses, and each core's private allocations live in a disjoint
    // region — so line identity across cores in the replay is exactly
    // "the same bytes of B".
    base.enable_shared_operands();

    // One O(nnz) Gustavson work estimate serves both the ws-dyn block cut
    // and the work-stealing claim replay (Static needs neither).
    let row_work = if cfg.scheduler == Scheduler::Static {
        Vec::new()
    } else {
        crate::matrix::stats::row_work(a, b)
    };
    let blocks = if cfg.scheduler == Scheduler::WorkStealingDyn && cfg.block_rows.is_none() {
        dyn_blocks_from_work(a.nrows, sys.unit.n, &row_work)
    } else {
        row_blocks(a.nrows, sys.unit.n, cfg)
    };
    let plan = assign_blocks(&row_work, &blocks, cores, cfg.scheduler);
    let blocks_per_core: Vec<usize> = plan.iter().map(|p| p.len()).collect();

    let results: Mutex<Vec<Option<Csr>>> = Mutex::new(vec![None; blocks.len()]);
    let mut per_core = Vec::with_capacity(cores);
    let mut traces: Vec<Vec<TraceEvent>> = Vec::with_capacity(cores);
    let mut failures: Vec<String> = Vec::new();

    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(cores);
        for (core, mine) in plan.iter().enumerate() {
            let machine = base.fork_core(core);
            let blocks = &blocks;
            let results = &results;
            let make_impl = &make_impl;
            handles.push(scope.spawn(
                move || -> Result<(crate::sim::RunMetrics, Vec<TraceEvent>)> {
                    let mut machine = machine;
                    machine.enable_trace();
                    let mut im = make_impl()?;
                    for &bi in mine {
                        let (lo, hi) = blocks[bi];
                        let slab = row_slab(a, lo, hi);
                        let c = im
                            .multiply(&mut machine, &slab, b)
                            .with_context(|| format!("rows {lo}..{hi} on core {core}"))?;
                        results.lock().unwrap()[bi] = Some(c);
                    }
                    let trace = machine.take_trace();
                    Ok((machine.metrics(), trace))
                },
            ));
        }
        for (core, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(Ok((m, t))) => {
                    per_core.push(m);
                    traces.push(t);
                }
                Ok(Err(e)) => failures.push(format!("core {core}: {e:#}")),
                Err(_) => failures.push(format!("core {core}: worker panicked")),
            }
        }
    });
    ensure!(failures.is_empty(), "parallel SpGEMM failed: {failures:?}");

    // Phase 2: deterministic shared-memory replay. The merged per-core
    // traces price the shared LLC (queueing + MESI-lite coherence) and the
    // DRAM channels; the resulting per-core stalls fold into the same
    // per-phase buckets the accesses charged in phase 1. At 1 core every
    // replay-derived cost is exactly zero, so this stage is an identity on
    // the seed model's numbers (the differential tests pin that).
    let outcome = shared::replay(&sys.mem, &sys.shared, &traces);
    for (c, m) in per_core.iter_mut().enumerate() {
        m.shared = outcome.per_core[c];
        let stalls = &outcome.per_core_phase_stalls[c];
        for (p, &stall) in stalls.iter().enumerate().take(NUM_PHASES) {
            m.phase_cycles[p] += stall;
            m.cycles += stall;
        }
    }
    let mut metrics = MulticoreMetrics::from_cores(per_core);
    metrics.channel_busy_cycles = outcome.channel_busy_cycles;

    let csr = stitch(a.nrows, b.ncols, results.into_inner().unwrap())?;
    Ok(ParallelRun {
        csr,
        metrics,
        blocks_per_core,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::sim::RunMetrics;
    use crate::spgemm::{reference, same_product, ImplId};

    fn sys() -> SystemConfig {
        SystemConfig::default()
    }

    fn native(id: ImplId) -> impl Fn() -> Result<Box<dyn SpGemm>> + Sync {
        move || id.instantiate(crate::runtime::Engine::Native, std::path::Path::new("."))
    }

    fn serial(id: ImplId, a: &Csr) -> (Csr, RunMetrics) {
        let mut m = Machine::new(sys());
        let mut im = native(id)().unwrap();
        let c = im.multiply(&mut m, a, a).unwrap();
        (c, m.metrics())
    }

    #[test]
    fn scheduler_parses_and_prints() {
        assert_eq!("static".parse::<Scheduler>().unwrap(), Scheduler::Static);
        assert_eq!("ws".parse::<Scheduler>().unwrap(), Scheduler::WorkStealing);
        assert_eq!(
            "work-stealing".parse::<Scheduler>().unwrap().to_string(),
            "work-stealing"
        );
        assert_eq!("ws-dyn".parse::<Scheduler>().unwrap(), Scheduler::WorkStealingDyn);
        assert_eq!(Scheduler::WorkStealingDyn.to_string(), "ws-dyn");
        let e = "greedy".parse::<Scheduler>().unwrap_err();
        assert!(e.contains("static") && e.contains("greedy") && e.contains("ws-dyn"), "{e}");
    }

    #[test]
    fn block_sizing_is_core_independent_and_group_aligned() {
        assert_eq!(block_rows_for(100, 16), 16);
        assert_eq!(block_rows_for(200_000, 16), 3136);
        assert_eq!(block_rows_for(0, 16), 16);
        assert_eq!(block_rows_for(100, 16) % 16, 0);
        let blocks = row_blocks(100, 16, &ParallelConfig::new(4));
        assert_eq!(blocks.len(), 7);
        assert_eq!(blocks[0], (0, 16));
        assert_eq!(blocks[6], (96, 100));
        // An explicit request is rounded up to group alignment.
        let cfg = ParallelConfig { block_rows: Some(10), ..ParallelConfig::new(2) };
        assert!(row_blocks(100, 16, &cfg).iter().all(|&(lo, _)| lo % 16 == 0));
    }

    #[test]
    fn row_slab_extracts_rows() {
        let a = gen::erdos_renyi(40, 30, 200, 5);
        let s = row_slab(&a, 16, 32);
        assert!(s.validate().is_ok());
        assert_eq!(s.nrows, 16);
        assert_eq!(s.ncols, 30);
        for r in 0..16 {
            assert_eq!(s.row(r), a.row(16 + r), "row {r}");
        }
    }

    #[test]
    fn parallel_matches_serial_for_every_impl() {
        let a = gen::rmat(128, 128, 1100, 0.6, 0.18, 0.14, 91);
        let r = reference(&a, &a);
        for id in ImplId::ALL {
            let (cs, _) = serial(id, &a);
            for cores in [1usize, 3] {
                let run = row_blocked(&sys(), native(id), &a, &a, &ParallelConfig::new(cores))
                    .unwrap();
                assert!(run.csr.validate().is_ok());
                assert_eq!(run.csr.indptr, cs.indptr, "{} x{cores}", id.name());
                assert_eq!(run.csr.indices, cs.indices, "{} x{cores}", id.name());
                assert!(same_product(&run.csr, &cs, 1e-5), "{} x{cores}", id.name());
                assert!(same_product(&run.csr, &r, 1e-3), "{} x{cores}", id.name());
                assert_eq!(run.metrics.cores(), cores);
                assert_eq!(
                    run.blocks_per_core.iter().sum::<usize>(),
                    row_blocks(a.nrows, 16, &ParallelConfig::new(cores)).len()
                );
            }
        }
    }

    #[test]
    fn per_core_counts_sum_to_single_core_totals() {
        let a = gen::rmat(128, 128, 1100, 0.6, 0.18, 0.14, 92);
        for id in ImplId::ALL {
            let one = row_blocked(&sys(), native(id), &a, &a, &ParallelConfig::new(1)).unwrap();
            for cores in [2usize, 7] {
                for sched in [Scheduler::Static, Scheduler::WorkStealing] {
                    let cfg = ParallelConfig { scheduler: sched, ..ParallelConfig::new(cores) };
                    let many = row_blocked(&sys(), native(id), &a, &a, &cfg).unwrap();
                    assert_eq!(
                        many.metrics.total.ops, one.metrics.total.ops,
                        "{} x{cores} {sched}", id.name()
                    );
                }
            }
        }
    }

    #[test]
    fn group_aligned_blocks_keep_spz_counts_exactly_serial() {
        let a = gen::rmat(160, 160, 1400, 0.58, 0.2, 0.14, 93);
        for id in [ImplId::SclArray, ImplId::SclHash, ImplId::Spz] {
            let (_, sm) = serial(id, &a);
            let run = row_blocked(&sys(), native(id), &a, &a, &ParallelConfig::new(4)).unwrap();
            assert_eq!(run.metrics.total.ops, sm.ops, "{}", id.name());
        }
    }

    #[test]
    fn work_stealing_schedule_is_deterministic_and_beats_static_on_skew() {
        let a = gen::rmat(256, 256, 2600, 0.62, 0.18, 0.14, 97);
        let run =
            || row_blocked(&sys(), native(ImplId::Spz), &a, &a, &ParallelConfig::new(4)).unwrap();
        let r1 = run();
        let r2 = run();
        let c1: Vec<f64> = r1.metrics.per_core.iter().map(|m| m.cycles).collect();
        let c2: Vec<f64> = r2.metrics.per_core.iter().map(|m| m.cycles).collect();
        assert_eq!(c1, c2, "per-core schedule must not depend on host timing");
        assert_eq!(r1.blocks_per_core, r2.blocks_per_core);
        // R-MAT hubs cluster in the low rows, so contiguous static chunking
        // overloads one core; estimate-driven dynamic claiming spreads them.
        let st_cfg = ParallelConfig { scheduler: Scheduler::Static, ..ParallelConfig::new(4) };
        let st = row_blocked(&sys(), native(ImplId::Spz), &a, &a, &st_cfg).unwrap();
        assert!(
            r1.metrics.critical_path_cycles <= st.metrics.critical_path_cycles * 1.05,
            "work-stealing {} should not lose to static {}",
            r1.metrics.critical_path_cycles,
            st.metrics.critical_path_cycles
        );
    }

    #[test]
    fn critical_path_shrinks_with_cores() {
        let a = gen::erdos_renyi(512, 512, 6000, 94);
        let one =
            row_blocked(&sys(), native(ImplId::Spz), &a, &a, &ParallelConfig::new(1)).unwrap();
        let eight =
            row_blocked(&sys(), native(ImplId::Spz), &a, &a, &ParallelConfig::new(8)).unwrap();
        assert!(
            eight.metrics.critical_path_cycles < one.metrics.critical_path_cycles,
            "{} !< {}",
            eight.metrics.critical_path_cycles,
            one.metrics.critical_path_cycles
        );
        assert!(eight.metrics.parallel_efficiency() > 1.5);
    }

    #[test]
    fn dyn_blocks_are_aligned_core_independent_and_cover_all_rows() {
        let a = gen::rmat(256, 256, 2600, 0.62, 0.18, 0.14, 98);
        let cfg2 = ParallelConfig {
            scheduler: Scheduler::WorkStealingDyn,
            ..ParallelConfig::new(2)
        };
        let cfg8 = ParallelConfig {
            scheduler: Scheduler::WorkStealingDyn,
            ..ParallelConfig::new(8)
        };
        let b2 = row_blocks_dyn(&a, &a, 16, &cfg2);
        let b8 = row_blocks_dyn(&a, &a, 16, &cfg8);
        assert_eq!(b2, b8, "dyn blocks must not depend on the core count");
        assert!(b2.iter().all(|&(lo, _)| lo % 16 == 0), "group alignment");
        assert_eq!(b2.first().unwrap().0, 0);
        assert_eq!(b2.last().unwrap().1, a.nrows);
        for w in b2.windows(2) {
            assert_eq!(w[0].1, w[1].0, "blocks must tile contiguously");
        }
        // An explicit block size overrides the policy (uniform fallback).
        let forced = ParallelConfig { block_rows: Some(32), ..cfg2 };
        let bf = row_blocks_dyn(&a, &a, 16, &forced);
        assert!(bf.iter().take(bf.len() - 1).all(|&(lo, hi)| hi - lo == 32));
    }

    #[test]
    fn ws_dyn_matches_serial_product_and_counts() {
        let a = gen::rmat(160, 160, 1400, 0.58, 0.2, 0.14, 99);
        for id in [ImplId::SclArray, ImplId::SclHash, ImplId::Spz] {
            let (cs, sm) = serial(id, &a);
            let cfg = ParallelConfig {
                scheduler: Scheduler::WorkStealingDyn,
                ..ParallelConfig::new(4)
            };
            let run = row_blocked(&sys(), native(id), &a, &a, &cfg).unwrap();
            assert_eq!(run.csr.indptr, cs.indptr, "{}", id.name());
            assert_eq!(run.csr.indices, cs.indices, "{}", id.name());
            // Group-aligned dyn blocks keep the row/group-local impls'
            // event counts exactly serial.
            assert_eq!(run.metrics.total.ops, sm.ops, "{}", id.name());
        }
    }

    #[test]
    fn ws_dyn_does_not_lose_to_uniform_work_stealing_on_skew() {
        let a = gen::rmat(256, 256, 2600, 0.62, 0.18, 0.14, 100);
        let ws = row_blocked(&sys(), native(ImplId::Spz), &a, &a, &ParallelConfig::new(4)).unwrap();
        let dyn_cfg = ParallelConfig {
            scheduler: Scheduler::WorkStealingDyn,
            ..ParallelConfig::new(4)
        };
        let dy = row_blocked(&sys(), native(ImplId::Spz), &a, &a, &dyn_cfg).unwrap();
        assert!(
            dy.metrics.critical_path_cycles <= ws.metrics.critical_path_cycles * 1.05,
            "ws-dyn {} should not lose to uniform work-stealing {}",
            dy.metrics.critical_path_cycles,
            ws.metrics.critical_path_cycles
        );
    }

    #[test]
    fn one_core_replay_is_an_exact_noop() {
        let a = gen::rmat(128, 128, 1100, 0.6, 0.18, 0.14, 101);
        for id in [ImplId::SclHash, ImplId::Spz] {
            let run = row_blocked(&sys(), native(id), &a, &a, &ParallelConfig::new(1)).unwrap();
            let s = &run.metrics.per_core[0].shared;
            assert!(s.llc_accesses > 0, "{}: trace must have been recorded", id.name());
            assert_eq!(s.stall_cycles(), 0.0, "{}", id.name());
            assert_eq!(s.llc_queue_cycles, 0.0, "{}", id.name());
            assert_eq!(s.dram_queue_cycles, 0.0, "{}", id.name());
            assert_eq!(s.coherence_cycles, 0.0, "{}", id.name());
            assert_eq!(s.shared_fills + s.demotions, 0, "{}: shadow == shared", id.name());
            assert_eq!(s.coherence_events(), 0, "{}", id.name());
            // The shadow and the shared model agree access for access.
            assert_eq!(
                s.llc_accesses + s.writeback_installs,
                run.metrics.per_core[0].mem.llc_accesses,
                "{}",
                id.name()
            );
        }
    }

    #[test]
    fn multicore_replay_reports_contention_and_stays_deterministic() {
        let a = gen::erdos_renyi(512, 512, 6000, 102);
        let run =
            || row_blocked(&sys(), native(ImplId::Spz), &a, &a, &ParallelConfig::new(4)).unwrap();
        let r1 = run();
        let r2 = run();
        // Bit-reproducible across host thread schedules: cycles, stalls,
        // coherence counters, and channel occupancy all match exactly.
        let c1: Vec<f64> = r1.metrics.per_core.iter().map(|m| m.cycles).collect();
        let c2: Vec<f64> = r2.metrics.per_core.iter().map(|m| m.cycles).collect();
        assert_eq!(c1, c2);
        assert_eq!(
            r1.metrics.per_core.iter().map(|m| m.shared).collect::<Vec<_>>(),
            r2.metrics.per_core.iter().map(|m| m.shared).collect::<Vec<_>>()
        );
        assert_eq!(r1.metrics.channel_busy_cycles, r2.metrics.channel_busy_cycles);
        assert_eq!(
            r1.metrics.channel_busy_cycles.len(),
            sys().shared.dram_channels
        );
        // Four cores streaming one B: the shared LLC sees real traffic and
        // the totals add up exactly.
        let tot = &r1.metrics.total.shared;
        assert!(tot.llc_accesses > 0);
        assert_eq!(tot.llc_hits + tot.llc_misses, tot.llc_accesses);
        let sum: u64 = r1.metrics.per_core.iter().map(|m| m.shared.llc_accesses).sum();
        assert_eq!(sum, tot.llc_accesses);
        // Per-phase cycles still sum to the core's total after folding.
        for m in &r1.metrics.per_core {
            let ps: f64 = m.phase_cycles.iter().sum();
            assert!(
                (ps - m.cycles).abs() <= 1e-9 * m.cycles.max(1.0),
                "{ps} vs {}",
                m.cycles
            );
        }
    }

    #[test]
    fn empty_and_tiny_matrices_work() {
        let e = Csr::empty(0, 0);
        let run =
            row_blocked(&sys(), native(ImplId::Spz), &e, &e, &ParallelConfig::new(4)).unwrap();
        assert_eq!(run.csr.nrows, 0);
        assert_eq!(run.csr.nnz(), 0);
        // More cores than blocks: idle cores report zero metrics.
        let tiny = Csr::identity(8);
        let run =
            row_blocked(&sys(), native(ImplId::SclHash), &tiny, &tiny, &ParallelConfig::new(7))
                .unwrap();
        assert_eq!(run.csr, tiny);
        assert_eq!(run.metrics.cores(), 7);
        assert_eq!(run.blocks_per_core.iter().sum::<usize>(), 1);
    }

    #[test]
    fn more_than_64_cores_is_a_clean_error() {
        let a = Csr::identity(8);
        let e = row_blocked(&sys(), native(ImplId::SclHash), &a, &a, &ParallelConfig::new(65));
        assert!(e.is_err(), "65 cores must error, not panic");
        assert!(format!("{:#}", e.unwrap_err()).contains("64"));
        // The boundary itself works.
        assert!(row_blocked(&sys(), native(ImplId::SclHash), &a, &a, &ParallelConfig::new(64))
            .is_ok());
    }

    #[test]
    fn rectangular_products_supported() {
        let a = gen::erdos_renyi(64, 40, 300, 95);
        let b = gen::erdos_renyi(40, 32, 200, 96);
        let run =
            row_blocked(&sys(), native(ImplId::Spz), &a, &b, &ParallelConfig::new(3)).unwrap();
        assert!(same_product(&run.csr, &reference(&a, &b), 1e-3));
        let bad = row_blocked(&sys(), native(ImplId::Spz), &b, &a, &ParallelConfig::new(2));
        assert!(bad.is_err());
    }
}
