//! Multi-core SpGEMM driver: run any [`SpGemm`] implementation over row
//! blocks of A on real worker threads, one forked [`Machine`] per simulated
//! core (the paper's evaluation distributes rows of A to per-core matrix
//! units the same way; SpArch and the SSR multi-core clusters are the
//! related-work analogues).
//!
//! Row-wise SpGEMM makes this exact: rows `[lo, hi)` of `C = A*B` depend
//! only on rows `[lo, hi)` of A (and all of B), so a block is simulated by
//! multiplying the corresponding row *slab* of A against B and the per-block
//! outputs stitch back into one [`Csr`] in block order — bit-identical in
//! structure to the serial product, independent of core count and scheduler.
//!
//! Two invariants the tests pin:
//!
//! * **Blocks are core-count independent** (and scheduler-independent):
//!   [`block_rows_for`] depends only on the matrix and the matrix-unit group
//!   size, so the per-core event counts of an N-core run always sum exactly
//!   to the 1-core run's totals.
//! * **Blocks are aligned to the matrix-unit group size** (16 rows): the spz
//!   variants process rows in lockstep groups of `unit.n` streams, so
//!   group-aligned blocks leave every group's composition — and therefore
//!   every dynamic event count of `spz`, `scl-array`, and `scl-hash` —
//!   exactly equal to the serial run's. (`vec-radix` re-partitions its ESC
//!   batches per block and `spz-rsort` work-sorts within a block, so their
//!   counts match the 1-core *driver* run, not the serial loop.)

use crate::config::SystemConfig;
use crate::matrix::Csr;
use crate::sim::{Machine, MulticoreMetrics};
use crate::spgemm::SpGemm;
use crate::util::round_up;
use anyhow::{ensure, Context, Result};
use std::sync::Mutex;

/// How row blocks are assigned to cores.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheduler {
    /// Contiguous static partition of the block list (each core gets
    /// `nblocks/cores` consecutive blocks up front). Cheap, but exposed to
    /// load imbalance when heavy rows cluster — the effect `spz-rsort`'s
    /// row sorting (and Figure 11's work-variance column) makes measurable.
    Static,
    /// Dynamic self-scheduling off a shared queue: blocks are claimed in
    /// order by whichever core becomes idle first, so one heavy block never
    /// idles the pool. The claim sequence is simulated *deterministically*
    /// from the per-row work estimates (the same Gustavson work counts every
    /// implementation's Preprocess pass computes) rather than from host
    /// thread timing — per-core metrics, critical path, and fig12 are
    /// bit-reproducible run to run.
    WorkStealing,
}

impl Scheduler {
    pub const fn name(self) -> &'static str {
        match self {
            Scheduler::Static => "static",
            Scheduler::WorkStealing => "work-stealing",
        }
    }
}

impl std::str::FromStr for Scheduler {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "static" => Ok(Scheduler::Static),
            "work-stealing" | "ws" => Ok(Scheduler::WorkStealing),
            other => Err(format!(
                "unknown scheduler '{other}' (expected one of: static, work-stealing)"
            )),
        }
    }
}

impl std::fmt::Display for Scheduler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(self.name())
    }
}

/// Parallel-execution parameters.
#[derive(Clone, Copy, Debug)]
pub struct ParallelConfig {
    /// Simulated cores (= real worker threads). Clamped to at least 1.
    pub cores: usize,
    pub scheduler: Scheduler,
    /// Rows of A per block (rounded up to the matrix-unit group size);
    /// `None` picks [`block_rows_for`]'s core-count-independent default.
    pub block_rows: Option<usize>,
}

impl ParallelConfig {
    pub fn new(cores: usize) -> Self {
        ParallelConfig {
            cores,
            scheduler: Scheduler::WorkStealing,
            block_rows: None,
        }
    }
}

/// Result of a parallel run: the stitched product, the per-core metrics
/// aggregate, and how many blocks each core executed (the scheduler's
/// footprint, useful for imbalance reporting).
#[derive(Clone, Debug)]
pub struct ParallelRun {
    pub csr: Csr,
    pub metrics: MulticoreMetrics,
    pub blocks_per_core: Vec<usize>,
}

/// Default rows per block: targets ~64 blocks (plenty of steals even at 8
/// cores) with a one-group floor, rounded up to the group size. Depends only
/// on the matrix and the unit geometry — never on the core count — so
/// per-core event counts sum identically at every core count.
pub fn block_rows_for(nrows: usize, group: usize) -> usize {
    let group = group.max(1);
    round_up(nrows.max(1).div_ceil(64).max(group), group)
}

/// The row-block list for an `nrows`-row A (block size from
/// [`ParallelConfig::block_rows`] or [`block_rows_for`]).
pub fn row_blocks(nrows: usize, group: usize, cfg: &ParallelConfig) -> Vec<(usize, usize)> {
    let bs = match cfg.block_rows {
        Some(req) => round_up(req.max(1), group.max(1)),
        None => block_rows_for(nrows, group),
    };
    let mut blocks = Vec::with_capacity(nrows.div_ceil(bs.max(1)));
    let mut lo = 0usize;
    while lo < nrows {
        let hi = (lo + bs).min(nrows);
        blocks.push((lo, hi));
        lo = hi;
    }
    blocks
}

/// Per-core block assignment, decided up front so it depends only on the
/// inputs (never on host-thread timing):
///
/// * `Static` — contiguous equal-count chunks of the block list.
/// * `WorkStealing` — the deterministic replay of a dynamic self-scheduling
///   queue: walk blocks in order, handing each to the core whose accumulated
///   estimated work (Gustavson multiply counts, + a per-row term for the
///   fixed row overheads) is smallest — i.e. the core that would have gone
///   idle and stolen next. Ties break toward the lowest core id.
fn assign_blocks(
    a: &Csr,
    b: &Csr,
    blocks: &[(usize, usize)],
    cores: usize,
    scheduler: Scheduler,
) -> Vec<Vec<usize>> {
    let nblocks = blocks.len();
    match scheduler {
        Scheduler::Static => (0..cores)
            .map(|c| (c * nblocks / cores..(c + 1) * nblocks / cores).collect())
            .collect(),
        Scheduler::WorkStealing => {
            let row_work = crate::matrix::stats::row_work(a, b);
            let mut plan: Vec<Vec<usize>> = vec![Vec::new(); cores];
            let mut est = vec![0.0f64; cores];
            for (i, &(lo, hi)) in blocks.iter().enumerate() {
                let w: u64 = row_work[lo..hi].iter().sum();
                let mut best = 0usize;
                for c in 1..cores {
                    if est[c] < est[best] {
                        best = c;
                    }
                }
                plan[best].push(i);
                est[best] += (w + (hi - lo) as u64) as f64;
            }
            plan
        }
    }
}

/// Rows `[lo, hi)` of `a` as a standalone CSR (same column space).
fn row_slab(a: &Csr, lo: usize, hi: usize) -> Csr {
    let base = a.indptr[lo];
    Csr {
        nrows: hi - lo,
        ncols: a.ncols,
        indptr: a.indptr[lo..=hi].iter().map(|&p| p - base).collect(),
        indices: a.indices[a.indptr[lo]..a.indptr[hi]].to_vec(),
        data: a.data[a.indptr[lo]..a.indptr[hi]].to_vec(),
    }
}

/// Concatenate per-block products (in block order) into one CSR.
fn stitch(nrows: usize, ncols: usize, parts: Vec<Option<Csr>>) -> Result<Csr> {
    let nnz: usize = parts.iter().map(|p| p.as_ref().map_or(0, |c| c.nnz())).sum();
    let mut indptr = Vec::with_capacity(nrows + 1);
    indptr.push(0usize);
    let mut indices = Vec::with_capacity(nnz);
    let mut data = Vec::with_capacity(nnz);
    for part in parts {
        let c = part.context("internal: a row block produced no result")?;
        let base = indices.len();
        for &p in &c.indptr[1..] {
            indptr.push(base + p);
        }
        indices.extend_from_slice(&c.indices);
        data.extend_from_slice(&c.data);
    }
    ensure!(indptr.len() == nrows + 1, "internal: stitched row count mismatch");
    Ok(Csr { nrows, ncols, indptr, indices, data })
}

/// Run `C = A*B` over row blocks of A on `cfg.cores` worker threads.
///
/// `make_impl` constructs one implementation instance per worker (the spz
/// engines are `&mut`-stateful, so cores cannot share one). Each worker
/// charges a [`Machine::fork_core`] fork whose `SystemConfig.cores` enables
/// the shared-LLC/DRAM contention adjustment. The block-to-core assignment
/// is decided up front by [`Scheduler`] (host-thread timing never leaks into
/// it), so the product, every event count, *and* the per-core cycle
/// breakdown are bit-reproducible run to run.
pub fn row_blocked<F>(
    sys: &SystemConfig,
    make_impl: F,
    a: &Csr,
    b: &Csr,
    cfg: &ParallelConfig,
) -> Result<ParallelRun>
where
    F: Fn() -> Result<Box<dyn SpGemm>> + Sync,
{
    ensure!(
        a.ncols == b.nrows,
        "dimension mismatch: ({}x{}) * ({}x{})",
        a.nrows,
        a.ncols,
        b.nrows,
        b.ncols
    );
    let cores = cfg.cores.max(1);
    let mut sys = *sys;
    sys.cores = cores;
    let base = Machine::new(sys);

    let blocks = row_blocks(a.nrows, sys.unit.n, cfg);
    let plan = assign_blocks(a, b, &blocks, cores, cfg.scheduler);
    let blocks_per_core: Vec<usize> = plan.iter().map(|p| p.len()).collect();

    let results: Mutex<Vec<Option<Csr>>> = Mutex::new(vec![None; blocks.len()]);
    let mut per_core = Vec::with_capacity(cores);
    let mut failures: Vec<String> = Vec::new();

    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(cores);
        for (core, mine) in plan.iter().enumerate() {
            let machine = base.fork_core(core);
            let blocks = &blocks;
            let results = &results;
            let make_impl = &make_impl;
            handles.push(scope.spawn(move || -> Result<crate::sim::RunMetrics> {
                let mut machine = machine;
                let mut im = make_impl()?;
                for &bi in mine {
                    let (lo, hi) = blocks[bi];
                    let slab = row_slab(a, lo, hi);
                    let c = im
                        .multiply(&mut machine, &slab, b)
                        .with_context(|| format!("rows {lo}..{hi} on core {core}"))?;
                    results.lock().unwrap()[bi] = Some(c);
                }
                Ok(machine.metrics())
            }));
        }
        for (core, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(Ok(m)) => per_core.push(m),
                Ok(Err(e)) => failures.push(format!("core {core}: {e:#}")),
                Err(_) => failures.push(format!("core {core}: worker panicked")),
            }
        }
    });
    ensure!(failures.is_empty(), "parallel SpGEMM failed: {failures:?}");

    let csr = stitch(a.nrows, b.ncols, results.into_inner().unwrap())?;
    Ok(ParallelRun {
        csr,
        metrics: MulticoreMetrics::from_cores(per_core),
        blocks_per_core,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::sim::RunMetrics;
    use crate::spgemm::{reference, same_product, ImplId};

    fn sys() -> SystemConfig {
        SystemConfig::default()
    }

    fn native(id: ImplId) -> impl Fn() -> Result<Box<dyn SpGemm>> + Sync {
        move || id.instantiate(crate::runtime::Engine::Native, std::path::Path::new("."))
    }

    fn serial(id: ImplId, a: &Csr) -> (Csr, RunMetrics) {
        let mut m = Machine::new(sys());
        let mut im = native(id)().unwrap();
        let c = im.multiply(&mut m, a, a).unwrap();
        (c, m.metrics())
    }

    #[test]
    fn scheduler_parses_and_prints() {
        assert_eq!("static".parse::<Scheduler>().unwrap(), Scheduler::Static);
        assert_eq!("ws".parse::<Scheduler>().unwrap(), Scheduler::WorkStealing);
        assert_eq!(
            "work-stealing".parse::<Scheduler>().unwrap().to_string(),
            "work-stealing"
        );
        let e = "greedy".parse::<Scheduler>().unwrap_err();
        assert!(e.contains("static") && e.contains("greedy"), "{e}");
    }

    #[test]
    fn block_sizing_is_core_independent_and_group_aligned() {
        assert_eq!(block_rows_for(100, 16), 16);
        assert_eq!(block_rows_for(200_000, 16), 3136);
        assert_eq!(block_rows_for(0, 16), 16);
        assert_eq!(block_rows_for(100, 16) % 16, 0);
        let blocks = row_blocks(100, 16, &ParallelConfig::new(4));
        assert_eq!(blocks.len(), 7);
        assert_eq!(blocks[0], (0, 16));
        assert_eq!(blocks[6], (96, 100));
        // An explicit request is rounded up to group alignment.
        let cfg = ParallelConfig { block_rows: Some(10), ..ParallelConfig::new(2) };
        assert!(row_blocks(100, 16, &cfg).iter().all(|&(lo, _)| lo % 16 == 0));
    }

    #[test]
    fn row_slab_extracts_rows() {
        let a = gen::erdos_renyi(40, 30, 200, 5);
        let s = row_slab(&a, 16, 32);
        assert!(s.validate().is_ok());
        assert_eq!(s.nrows, 16);
        assert_eq!(s.ncols, 30);
        for r in 0..16 {
            assert_eq!(s.row(r), a.row(16 + r), "row {r}");
        }
    }

    #[test]
    fn parallel_matches_serial_for_every_impl() {
        let a = gen::rmat(128, 128, 1100, 0.6, 0.18, 0.14, 91);
        let r = reference(&a, &a);
        for id in ImplId::ALL {
            let (cs, _) = serial(id, &a);
            for cores in [1usize, 3] {
                let run = row_blocked(&sys(), native(id), &a, &a, &ParallelConfig::new(cores))
                    .unwrap();
                assert!(run.csr.validate().is_ok());
                assert_eq!(run.csr.indptr, cs.indptr, "{} x{cores}", id.name());
                assert_eq!(run.csr.indices, cs.indices, "{} x{cores}", id.name());
                assert!(same_product(&run.csr, &cs, 1e-5), "{} x{cores}", id.name());
                assert!(same_product(&run.csr, &r, 1e-3), "{} x{cores}", id.name());
                assert_eq!(run.metrics.cores(), cores);
                assert_eq!(
                    run.blocks_per_core.iter().sum::<usize>(),
                    row_blocks(a.nrows, 16, &ParallelConfig::new(cores)).len()
                );
            }
        }
    }

    #[test]
    fn per_core_counts_sum_to_single_core_totals() {
        let a = gen::rmat(128, 128, 1100, 0.6, 0.18, 0.14, 92);
        for id in ImplId::ALL {
            let one = row_blocked(&sys(), native(id), &a, &a, &ParallelConfig::new(1)).unwrap();
            for cores in [2usize, 7] {
                for sched in [Scheduler::Static, Scheduler::WorkStealing] {
                    let cfg = ParallelConfig { scheduler: sched, ..ParallelConfig::new(cores) };
                    let many = row_blocked(&sys(), native(id), &a, &a, &cfg).unwrap();
                    assert_eq!(
                        many.metrics.total.ops, one.metrics.total.ops,
                        "{} x{cores} {sched}", id.name()
                    );
                }
            }
        }
    }

    #[test]
    fn group_aligned_blocks_keep_spz_counts_exactly_serial() {
        let a = gen::rmat(160, 160, 1400, 0.58, 0.2, 0.14, 93);
        for id in [ImplId::SclArray, ImplId::SclHash, ImplId::Spz] {
            let (_, sm) = serial(id, &a);
            let run = row_blocked(&sys(), native(id), &a, &a, &ParallelConfig::new(4)).unwrap();
            assert_eq!(run.metrics.total.ops, sm.ops, "{}", id.name());
        }
    }

    #[test]
    fn work_stealing_schedule_is_deterministic_and_beats_static_on_skew() {
        let a = gen::rmat(256, 256, 2600, 0.62, 0.18, 0.14, 97);
        let run =
            || row_blocked(&sys(), native(ImplId::Spz), &a, &a, &ParallelConfig::new(4)).unwrap();
        let r1 = run();
        let r2 = run();
        let c1: Vec<f64> = r1.metrics.per_core.iter().map(|m| m.cycles).collect();
        let c2: Vec<f64> = r2.metrics.per_core.iter().map(|m| m.cycles).collect();
        assert_eq!(c1, c2, "per-core schedule must not depend on host timing");
        assert_eq!(r1.blocks_per_core, r2.blocks_per_core);
        // R-MAT hubs cluster in the low rows, so contiguous static chunking
        // overloads one core; estimate-driven dynamic claiming spreads them.
        let st_cfg = ParallelConfig { scheduler: Scheduler::Static, ..ParallelConfig::new(4) };
        let st = row_blocked(&sys(), native(ImplId::Spz), &a, &a, &st_cfg).unwrap();
        assert!(
            r1.metrics.critical_path_cycles <= st.metrics.critical_path_cycles * 1.05,
            "work-stealing {} should not lose to static {}",
            r1.metrics.critical_path_cycles,
            st.metrics.critical_path_cycles
        );
    }

    #[test]
    fn critical_path_shrinks_with_cores() {
        let a = gen::erdos_renyi(512, 512, 6000, 94);
        let one =
            row_blocked(&sys(), native(ImplId::Spz), &a, &a, &ParallelConfig::new(1)).unwrap();
        let eight =
            row_blocked(&sys(), native(ImplId::Spz), &a, &a, &ParallelConfig::new(8)).unwrap();
        assert!(
            eight.metrics.critical_path_cycles < one.metrics.critical_path_cycles,
            "{} !< {}",
            eight.metrics.critical_path_cycles,
            one.metrics.critical_path_cycles
        );
        assert!(eight.metrics.parallel_efficiency() > 1.5);
    }

    #[test]
    fn empty_and_tiny_matrices_work() {
        let e = Csr::empty(0, 0);
        let run =
            row_blocked(&sys(), native(ImplId::Spz), &e, &e, &ParallelConfig::new(4)).unwrap();
        assert_eq!(run.csr.nrows, 0);
        assert_eq!(run.csr.nnz(), 0);
        // More cores than blocks: idle cores report zero metrics.
        let tiny = Csr::identity(8);
        let run =
            row_blocked(&sys(), native(ImplId::SclHash), &tiny, &tiny, &ParallelConfig::new(7))
                .unwrap();
        assert_eq!(run.csr, tiny);
        assert_eq!(run.metrics.cores(), 7);
        assert_eq!(run.blocks_per_core.iter().sum::<usize>(), 1);
    }

    #[test]
    fn rectangular_products_supported() {
        let a = gen::erdos_renyi(64, 40, 300, 95);
        let b = gen::erdos_renyi(40, 32, 200, 96);
        let run =
            row_blocked(&sys(), native(ImplId::Spz), &a, &b, &ParallelConfig::new(3)).unwrap();
        assert!(same_product(&run.csr, &reference(&a, &b), 1e-3));
        let bad = row_blocked(&sys(), native(ImplId::Spz), &b, &a, &ParallelConfig::new(2));
        assert!(bad.is_err());
    }
}
