//! `scl-array`: scalar row-wise SpGEMM with a dense accumulator
//! (Gilbert/MATLAB SpA [19]). For every output row, partial products are
//! scattered into a dense `ncols`-sized array with a stamp array marking
//! valid entries; touched columns are collected, sorted, and emitted.
//!
//! The performance story the paper tells (§VI-A): accesses to the dense
//! accumulator are scattered over a multi-MB array, so L1 hit rates collapse
//! for matrices with large dimension (ndwww, patents, usroads) — our cache
//! simulation reproduces that directly.

use crate::matrix::Csr;
use crate::sim::{Machine, Phase};
use crate::spgemm::{CsrAddrs, SpGemm};
use anyhow::Result;

pub struct SclArray;

impl SpGemm for SclArray {
    fn name(&self) -> &'static str {
        "scl-array"
    }

    fn multiply(&mut self, m: &mut Machine, a: &Csr, b: &Csr) -> Result<Csr> {
        let aa = CsrAddrs::register(m, a);
        let ba = CsrAddrs::register_shared(m, b);

        // --- Preprocess: size the output (upper bound = total work). ------
        let work = crate::spgemm::prep::row_work(m, a, b, &aa, &ba);
        let total_work: u64 = work.iter().sum();
        let out = CsrAddrs::register_output(m, a.nrows, total_work.max(1) as usize);
        let (out_idx_addr, out_val_addr, out_ptr_addr) = (out.indices, out.data, out.indptr);

        // Dense accumulator + stamp + touched list (simulated addresses).
        let acc_addr = m.salloc(b.ncols * 4);
        let stamp_addr = m.salloc(b.ncols * 4);
        let touched_addr = m.salloc(b.ncols * 4);

        // Functional state.
        let mut acc = vec![0f32; b.ncols];
        let mut stamp = vec![u32::MAX; b.ncols];
        let mut touched: Vec<u32> = Vec::new();
        let mut rows_out: Vec<(Vec<u32>, Vec<f32>)> = Vec::with_capacity(a.nrows);
        let mut out_cursor = 0u64;

        for r in 0..a.nrows {
            // --- Expand: scatter partial products into the accumulator. ---
            m.phase(Phase::Expand);
            touched.clear();
            let (ak, av) = a.row(r);
            m.load(aa.indptr_at(r + 1), 8);
            for (ai, (&j, &aval)) in ak.iter().zip(av).enumerate() {
                let a_off = a.indptr[r] + ai;
                m.load(aa.idx_at(a_off), 4);
                m.load(aa.val_at(a_off), 4);
                m.load(ba.indptr_at(j as usize), 8);
                m.load(ba.indptr_at(j as usize + 1), 8);
                let (bk, bv) = b.row(j as usize);
                let b_base = b.indptr[j as usize];
                for (bi, (&k, &bval)) in bk.iter().zip(bv).enumerate() {
                    let b_off = b_base + bi;
                    m.load(ba.idx_at(b_off), 4);
                    m.load(ba.val_at(b_off), 4);
                    // The scattered accumulator accesses — the hot spot.
                    m.load_dep(stamp_addr + (k as u64) * 4, 4);
                    m.scalar_ops(4); // mul, add, cmp, addr arith
                    m.branches(1);
                    if stamp[k as usize] != r as u32 {
                        stamp[k as usize] = r as u32;
                        acc[k as usize] = aval * bval;
                        m.store(stamp_addr + (k as u64) * 4, 4);
                        m.store(acc_addr + (k as u64) * 4, 4);
                        m.store(touched_addr + (touched.len() as u64) * 4, 4);
                        touched.push(k);
                    } else {
                        acc[k as usize] += aval * bval;
                        m.load_dep(acc_addr + (k as u64) * 4, 4);
                        m.store(acc_addr + (k as u64) * 4, 4);
                    }
                }
            }

            // --- Sort touched columns (quicksort; §V-B). -------------------
            m.phase(Phase::Sort);
            let l = touched.len() as u64;
            if l > 1 {
                let cmps = l * (64 - l.leading_zeros() as u64).max(1);
                m.scalar_ops(3 * cmps);
                m.branches_unpredictable(cmps);
                // Partition swaps touch the (small, cached) touched list.
                for i in 0..cmps {
                    m.load(touched_addr + (i % l) * 4, 4);
                }
            }
            touched.sort_unstable();

            // --- Output generation: gather accumulator, emit row. ---------
            m.phase(Phase::Output);
            let mut keys = Vec::with_capacity(touched.len());
            let mut vals = Vec::with_capacity(touched.len());
            for &k in &touched {
                m.load_dep(acc_addr + (k as u64) * 4, 4);
                m.store(out_idx_addr + out_cursor * 4, 4);
                m.store(out_val_addr + out_cursor * 4, 4);
                m.scalar_ops(2);
                out_cursor += 1;
                keys.push(k);
                vals.push(acc[k as usize]);
            }
            m.store(out_ptr_addr + (r as u64 + 1) * 8, 8);
            rows_out.push((keys, vals));
        }

        Ok(Csr::from_rows(a.nrows, b.ncols, rows_out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::matrix::gen;
    use crate::spgemm::{reference, same_product};

    #[test]
    fn correct_on_random() {
        let a = gen::erdos_renyi(80, 80, 400, 31);
        let mut m = Machine::new(SystemConfig::default());
        let c = SclArray.multiply(&mut m, &a, &a).unwrap();
        assert!(same_product(&c, &reference(&a, &a), 1e-3));
    }

    #[test]
    fn correct_on_identity() {
        let i = Csr::identity(10);
        let mut m = Machine::new(SystemConfig::default());
        let c = SclArray.multiply(&mut m, &i, &i).unwrap();
        assert_eq!(c, i);
    }

    #[test]
    fn charges_expand_and_output() {
        let a = gen::erdos_renyi(50, 50, 250, 32);
        let mut m = Machine::new(SystemConfig::default());
        SclArray.multiply(&mut m, &a, &a).unwrap();
        let r = m.metrics();
        assert!(r.phase_cycles[Phase::Expand as usize] > 0.0);
        assert!(r.phase_cycles[Phase::Output as usize] > 0.0);
        assert!(r.ops.scalar_loads > 0);
        assert_eq!(r.ops.mszipk, 0, "scalar impl must not touch the matrix unit");
    }

    #[test]
    fn large_dimension_hurts_l1() {
        // Same nnz, larger dimension => bigger accumulator => worse hit rate.
        let small = gen::erdos_renyi(2_000, 2_000, 20_000, 33);
        let large = gen::erdos_renyi(60_000, 60_000, 20_000, 33);
        let mut m1 = Machine::new(SystemConfig::default());
        SclArray.multiply(&mut m1, &small, &small).unwrap();
        let mut m2 = Machine::new(SystemConfig::default());
        SclArray.multiply(&mut m2, &large, &large).unwrap();
        assert!(
            m2.metrics().mem.l1d_hit_rate() < m1.metrics().mem.l1d_hit_rate(),
            "{} !< {}",
            m2.metrics().mem.l1d_hit_rate(),
            m1.metrics().mem.l1d_hit_rate()
        );
    }
}
