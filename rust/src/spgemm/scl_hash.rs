//! `scl-hash`: scalar row-wise SpGEMM accumulating each output row in a
//! per-row hash table with linear probing [1, 15], sized from the
//! preprocessing work estimate; unique keys are then quicksorted and
//! emitted (§V-B).
//!
//! Paper behaviour reproduced by the cache model: the small per-row tables
//! stay L1-resident (hit rates near 100% for ndwww/patents/usroads), but
//! relatively dense outputs (wiki, soc, bcsstk17, p3d) suffer hash
//! collisions and lose to `scl-array`.

use crate::matrix::Csr;
use crate::sim::{Machine, Phase};
use crate::spgemm::{CsrAddrs, SpGemm};
use crate::util::next_pow2;
use anyhow::Result;

pub struct SclHash;

const HASH_MULT: u64 = 0x9E3779B1;

impl SpGemm for SclHash {
    fn name(&self) -> &'static str {
        "scl-hash"
    }

    fn multiply(&mut self, m: &mut Machine, a: &Csr, b: &Csr) -> Result<Csr> {
        let aa = CsrAddrs::register(m, a);
        let ba = CsrAddrs::register_shared(m, b);

        // --- Preprocess: per-row work -> per-row table size. --------------
        let work = crate::spgemm::prep::row_work(m, a, b, &aa, &ba);
        let max_table = work
            .iter()
            .map(|&w| table_size(w))
            .max()
            .unwrap_or(8);
        let total_work: u64 = work.iter().sum();

        let key_addr = m.salloc(max_table * 4);
        let val_addr = m.salloc(max_table * 4);
        let list_addr = m.salloc(max_table * 4);
        let out = CsrAddrs::register_output(m, a.nrows, total_work.max(1) as usize);
        let (out_idx_addr, out_val_addr, out_ptr_addr) = (out.indices, out.data, out.indptr);

        // Functional table (u32::MAX = empty).
        let mut tkeys = vec![u32::MAX; max_table];
        let mut tvals = vec![0f32; max_table];
        let mut inserted: Vec<u32> = Vec::new(); // occupied slot indices
        let mut rows_out: Vec<(Vec<u32>, Vec<f32>)> = Vec::with_capacity(a.nrows);
        let mut out_cursor = 0u64;

        for r in 0..a.nrows {
            let tsize = table_size(work[r]);
            let mask = (tsize - 1) as u64;

            // --- Expand: multiply and insert into the hash table. ---------
            m.phase(Phase::Expand);
            let (ak, av) = a.row(r);
            m.load(aa.indptr_at(r + 1), 8);
            for (ai, (&j, &aval)) in ak.iter().zip(av).enumerate() {
                let a_off = a.indptr[r] + ai;
                m.load(aa.idx_at(a_off), 4);
                m.load(aa.val_at(a_off), 4);
                m.load(ba.indptr_at(j as usize), 8);
                m.load(ba.indptr_at(j as usize + 1), 8);
                let (bk, bv) = b.row(j as usize);
                let b_base = b.indptr[j as usize];
                for (bi, (&k, &bval)) in bk.iter().zip(bv).enumerate() {
                    let b_off = b_base + bi;
                    m.load(ba.idx_at(b_off), 4);
                    m.load(ba.val_at(b_off), 4);
                    m.scalar_ops(5); // mul, hash, mask, cmp, add
                    // Linear probing (functional + accounted identically).
                    let mut h = ((k as u64).wrapping_mul(HASH_MULT)) & mask;
                    loop {
                        m.load_dep(key_addr + h * 4, 4);
                        m.branches_unpredictable(1);
                        if tkeys[h as usize] == u32::MAX {
                            tkeys[h as usize] = k;
                            tvals[h as usize] = aval * bval;
                            inserted.push(h as u32);
                            m.store(key_addr + h * 4, 4);
                            m.store(val_addr + h * 4, 4);
                            m.store(list_addr + (inserted.len() as u64) * 4, 4);
                            break;
                        } else if tkeys[h as usize] == k {
                            tvals[h as usize] += aval * bval;
                            m.load_dep(val_addr + h * 4, 4);
                            m.store(val_addr + h * 4, 4);
                            break;
                        }
                        m.scalar_ops(2); // probe advance
                        h = (h + 1) & mask;
                    }
                }
            }

            // --- Sort: quicksort the unique keys (§V-B). -------------------
            m.phase(Phase::Sort);
            let l = inserted.len() as u64;
            let mut keys: Vec<u32> = inserted.iter().map(|&s| tkeys[s as usize]).collect();
            if l > 1 {
                let cmps = l * (64 - l.leading_zeros() as u64).max(1);
                m.scalar_ops(3 * cmps);
                m.branches_unpredictable(cmps);
                for i in 0..cmps {
                    m.load(list_addr + (i % l) * 4, 4);
                }
            }
            keys.sort_unstable();

            // --- Output: re-probe for each sorted key, emit, clear table. --
            m.phase(Phase::Output);
            let mut vals = Vec::with_capacity(keys.len());
            for &k in &keys {
                let mut h = ((k as u64).wrapping_mul(HASH_MULT)) & mask;
                loop {
                    m.load_dep(key_addr + h * 4, 4);
                    m.branches_unpredictable(1);
                    if tkeys[h as usize] == k {
                        break;
                    }
                    h = (h + 1) & mask;
                }
                vals.push(tvals[h as usize]);
                m.load_dep(val_addr + h * 4, 4);
                m.store(out_idx_addr + out_cursor * 4, 4);
                m.store(out_val_addr + out_cursor * 4, 4);
                out_cursor += 1;
            }
            for &s in &inserted {
                tkeys[s as usize] = u32::MAX;
                m.store(key_addr + (s as u64) * 4, 4);
            }
            inserted.clear();
            m.store(out_ptr_addr + (r as u64 + 1) * 8, 8);
            rows_out.push((keys, vals));
        }

        Ok(Csr::from_rows(a.nrows, b.ncols, rows_out))
    }
}

/// Table sized to ~1.5x the work estimate, power of two, >= 8.
fn table_size(work: u64) -> usize {
    next_pow2(((work as usize * 3) / 2).max(8))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::matrix::gen;
    use crate::spgemm::{reference, same_product};

    #[test]
    fn correct_on_random() {
        let a = gen::erdos_renyi(80, 80, 400, 41);
        let mut m = Machine::new(SystemConfig::default());
        let c = SclHash.multiply(&mut m, &a, &a).unwrap();
        assert!(same_product(&c, &reference(&a, &a), 1e-3));
    }

    #[test]
    fn correct_on_skewed() {
        let a = gen::rmat(128, 128, 1024, 0.6, 0.18, 0.14, 42);
        let mut m = Machine::new(SystemConfig::default());
        let c = SclHash.multiply(&mut m, &a, &a).unwrap();
        assert!(same_product(&c, &reference(&a, &a), 1e-3));
    }

    #[test]
    fn table_size_pow2() {
        assert_eq!(table_size(0), 8);
        assert_eq!(table_size(10), 16);
        assert_eq!(table_size(100), 256);
    }

    #[test]
    fn sparse_output_hits_l1_better_than_scl_array() {
        let a = gen::erdos_renyi(60_000, 60_000, 20_000, 43);
        let mut mh = Machine::new(SystemConfig::default());
        SclHash.multiply(&mut mh, &a, &a).unwrap();
        let mut ma = Machine::new(SystemConfig::default());
        crate::spgemm::scl_array::SclArray.multiply(&mut ma, &a, &a).unwrap();
        assert!(mh.metrics().mem.l1d_hit_rate() > ma.metrics().mem.l1d_hit_rate());
        assert!(mh.metrics().cycles < ma.metrics().cycles);
    }
}
