//! Sparse-matrix substrate: CSR/COO formats, MatrixMarket I/O, synthetic
//! dataset generators, the calibrated Table III dataset registry, and the
//! statistics the paper characterizes datasets with.

pub mod coo;
pub mod csr;
pub mod gen;
pub mod mm;
pub mod registry;
pub mod stats;

pub use coo::Coo;
pub use csr::Csr;
pub use registry::{Dataset, DATASETS};
pub use stats::MatrixStats;
