//! Synthetic sparse-matrix generators.
//!
//! Each generator targets the degree distribution / structure of one family
//! in Table III (DESIGN.md "Substitutions"): RMAT for social/web graphs,
//! stencils for PDE meshes, banded for FEM stiffness matrices,
//! union-of-permutations for the perfectly regular `m133-b3`, and grid-ish
//! chains for road networks. Values are uniform in [0.5, 1.5) — SpGEMM
//! performance is structure-driven, values only flow through the datapath.

use crate::matrix::{Coo, Csr};
use crate::util::Pcg32;

fn rand_val(rng: &mut Pcg32) -> f32 {
    rng.gen_f32_range(0.5, 1.5)
}

/// Erdős–Rényi-ish: `nnz` entries thrown uniformly (duplicates collapse,
/// so the realized nnz is slightly lower at high densities).
pub fn erdos_renyi(nrows: usize, ncols: usize, nnz: usize, seed: u64) -> Csr {
    let mut rng = Pcg32::new(seed);
    let mut coo = Coo::with_capacity(nrows, ncols, nnz);
    for _ in 0..nnz {
        let r = rng.gen_usize(nrows) as u32;
        let c = rng.gen_usize(ncols) as u32;
        let v = rand_val(&mut rng);
        coo.push(r, c, v);
    }
    dedup_value_fix(coo.to_csr())
}

/// R-MAT / Kronecker-style power-law graph over a 2^scale vertex square,
/// truncated to `nrows` x `ncols`. (a,b,c,d) sum to 1; larger `a` = more
/// skew (hubbier degree distribution, higher work variance).
#[allow(clippy::too_many_arguments)]
pub fn rmat(
    nrows: usize,
    ncols: usize,
    nnz: usize,
    a: f64,
    b: f64,
    c: f64,
    seed: u64,
) -> Csr {
    let scale_r = (nrows as f64).log2().ceil() as u32;
    let scale_c = (ncols as f64).log2().ceil() as u32;
    let scale = scale_r.max(scale_c);
    let mut rng = Pcg32::new(seed);
    let mut coo = Coo::with_capacity(nrows, ncols, nnz);
    let mut placed = 0usize;
    let mut attempts = 0usize;
    let max_attempts = nnz * 8 + 1024;
    while placed < nnz && attempts < max_attempts {
        attempts += 1;
        let (mut r, mut cc) = (0u64, 0u64);
        // Add per-level noise so the quadrant probabilities wobble (standard
        // "smoothed" R-MAT: avoids exactly self-similar artifacts).
        for lvl in 0..scale {
            let u = rng.gen_f64();
            let (qa, qb, qc) = (a, a + b, a + b + c);
            let (dr, dc) = if u < qa {
                (0, 0)
            } else if u < qb {
                (0, 1)
            } else if u < qc {
                (1, 0)
            } else {
                (1, 1)
            };
            r |= (dr as u64) << (scale - 1 - lvl);
            cc |= (dc as u64) << (scale - 1 - lvl);
        }
        if (r as usize) < nrows && (cc as usize) < ncols {
            let v = rand_val(&mut rng);
            coo.push(r as u32, cc as u32, v);
            placed += 1;
        }
    }
    dedup_value_fix(coo.to_csr())
}

/// 5-point 2-D Laplacian stencil on an nx x ny grid.
pub fn grid2d(nx: usize, ny: usize, seed: u64) -> Csr {
    let mut rng = Pcg32::new(seed);
    let n = nx * ny;
    let mut coo = Coo::with_capacity(n, n, n * 5);
    let idx = |x: usize, y: usize| (y * nx + x) as u32;
    for y in 0..ny {
        for x in 0..nx {
            let me = idx(x, y);
            coo.push(me, me, 4.0 + rand_val(&mut rng));
            if x > 0 {
                coo.push(me, idx(x - 1, y), -rand_val(&mut rng));
            }
            if x + 1 < nx {
                coo.push(me, idx(x + 1, y), -rand_val(&mut rng));
            }
            if y > 0 {
                coo.push(me, idx(x, y - 1), -rand_val(&mut rng));
            }
            if y + 1 < ny {
                coo.push(me, idx(x, y + 1), -rand_val(&mut rng));
            }
        }
    }
    coo.to_csr()
}

/// Road-network-like planar graph: 2-D grid where each edge exists with
/// probability `p_edge` — degree ~2.5, low work variance like `usroads`.
/// Vertex ids are randomly permuted: SuiteSparse road networks are not
/// geometrically ordered, so accumulator accesses scatter (the <20% L1 hit
/// rate the paper reports for scl-array on usroads depends on this).
pub fn road(nx: usize, ny: usize, p_edge: f64, seed: u64) -> Csr {
    let mut rng = Pcg32::new(seed);
    let n = nx * ny;
    let perm = rng.permutation(n);
    let mut coo = Coo::with_capacity(n, n, (n as f64 * 4.0 * p_edge) as usize);
    let idx = |x: usize, y: usize| perm[y * nx + x];
    for y in 0..ny {
        for x in 0..nx {
            let me = idx(x, y);
            if x + 1 < nx && rng.gen_bool(p_edge) {
                let v = rand_val(&mut rng);
                coo.push(me, idx(x + 1, y), v);
                coo.push(idx(x + 1, y), me, v);
            }
            if y + 1 < ny && rng.gen_bool(p_edge) {
                let v = rand_val(&mut rng);
                coo.push(me, idx(x, y + 1), v);
                coo.push(idx(x, y + 1), me, v);
            }
        }
    }
    coo.to_csr()
}

/// 27-point 3-D stencil on an n^3 cube (`p3d`-like Poisson problem).
pub fn grid3d_27pt(n: usize, seed: u64) -> Csr {
    let mut rng = Pcg32::new(seed);
    let total = n * n * n;
    let mut coo = Coo::with_capacity(total, total, total * 27);
    let idx = |x: usize, y: usize, z: usize| ((z * n + y) * n + x) as u32;
    for z in 0..n {
        for y in 0..n {
            for x in 0..n {
                let me = idx(x, y, z);
                for dz in -1isize..=1 {
                    for dy in -1isize..=1 {
                        for dx in -1isize..=1 {
                            let (nx_, ny_, nz_) = (
                                x as isize + dx,
                                y as isize + dy,
                                z as isize + dz,
                            );
                            if nx_ < 0
                                || ny_ < 0
                                || nz_ < 0
                                || nx_ >= n as isize
                                || ny_ >= n as isize
                                || nz_ >= n as isize
                            {
                                continue;
                            }
                            let v = if dx == 0 && dy == 0 && dz == 0 {
                                26.0 + rand_val(&mut rng)
                            } else {
                                -rand_val(&mut rng)
                            };
                            coo.push(me, idx(nx_ as usize, ny_ as usize, nz_ as usize), v);
                        }
                    }
                }
            }
        }
    }
    coo.to_csr()
}

/// Power-law graph with *controlled* degree dispersion: every vertex gets a
/// lognormal weight (sigma chosen from the target work/deg^2 ratio of
/// Table III) that drives both its out-degree and its popularity as a
/// destination. Because the same weight controls in- and out-degree,
/// E[work/row] = deg^2 * (1 + cv^2) exactly as in real scale-free graphs —
/// this is the knob the R-MAT recursion lacks (its tails overshoot Table
/// III's work columns by 10-25x).
pub fn powerlaw(n: usize, nnz: usize, sigma: f64, seed: u64) -> Csr {
    powerlaw_clustered(n, nnz, sigma, 0.0, seed)
}

/// `powerlaw` plus triangle closure: with probability `p_tri` an edge is
/// redirected to a random out-neighbour of its original target, so
/// neighbourhoods of related rows overlap. This is the knob for Table III's
/// work : out-nnz compression ratio (real social/web graphs are clustered;
/// independent sampling would give out-nnz ~= work).
pub fn powerlaw_clustered(n: usize, nnz: usize, sigma: f64, p_tri: f64, seed: u64) -> Csr {
    let mut rng = Pcg32::new(seed);
    // Lognormal weights, normalized later via the cumulative table.
    let mut w: Vec<f64> = (0..n)
        .map(|_| (sigma * rng.gen_normal() - 0.5 * sigma * sigma).exp())
        .collect();
    let total: f64 = w.iter().sum();
    // Cumulative table for destination sampling (binary search).
    let mut cum: Vec<f64> = Vec::with_capacity(n);
    let mut acc = 0.0;
    for x in &w {
        acc += x;
        cum.push(acc);
    }
    let cap = (n / 4).max(8) as f64;
    // Base targets, row-major so triangle closure can look up neighbours.
    let mut adj: Vec<Vec<u32>> = Vec::with_capacity(n);
    for r in 0..n {
        // Expected out-degree proportional to the vertex weight.
        let mean_deg = (nnz as f64) * w[r] / total;
        let d = (rng.gen_poisson(mean_deg.min(cap)) as usize).min(n - 1);
        let mut row = Vec::with_capacity(d);
        for _ in 0..d {
            let u = rng.gen_f64() * acc;
            let c = cum.partition_point(|&x| x < u).min(n - 1);
            row.push(c as u32);
        }
        let _ = r;
        adj.push(row);
    }
    // Triangle closure: redirect edges to neighbours-of-neighbours.
    let mut coo = Coo::with_capacity(n, n, nnz + nnz / 8);
    for r in 0..n {
        for i in 0..adj[r].len() {
            let mut c = adj[r][i];
            if p_tri > 0.0 && rng.gen_bool(p_tri) {
                let tgt = &adj[c as usize];
                if !tgt.is_empty() {
                    c = tgt[rng.gen_usize(tgt.len())];
                }
            }
            coo.push(r as u32, c, rand_val(&mut rng));
        }
    }
    w.clear();
    dedup_value_fix(coo.to_csr())
}

/// Block-banded FEM-like matrix (`bcsstk17`, `cage11`): rows come in blocks
/// of `block` consecutive rows sharing the same column clusters (element
/// coupling), so neighbouring rows reference overlapping column sets and
/// the A*A output row is much denser-compressed than the work count
/// (Table III's high work : out-nnz ratio). Per-block degree jitter sets a
/// moderate work variance.
pub fn block_banded(
    n: usize,
    half_band: usize,
    per_row: usize,
    block: usize,
    jitter: f64,
    seed: u64,
) -> Csr {
    let mut rng = Pcg32::new(seed);
    let mut coo = Coo::with_capacity(n, n, n * per_row + n);
    let mut b0 = 0usize;
    while b0 < n {
        let bsize = block.min(n - b0);
        // Per-block degree scale (lognormal-ish jitter).
        let scale = (jitter * rng.gen_normal()).exp();
        let deg = ((per_row as f64 * scale).round() as usize).clamp(2, 4 * per_row);
        // Shared clusters for this block.
        let nclusters = (deg / 6).max(1);
        let clen = deg / nclusters;
        let center = b0 + bsize / 2;
        let lo = center.saturating_sub(half_band);
        let hi = (center + half_band).min(n - 1);
        let width = hi - lo + 1;
        let clusters: Vec<usize> = (0..nclusters).map(|_| lo + rng.gen_usize(width)).collect();
        for r in b0..b0 + bsize {
            coo.push(r as u32, r as u32, 10.0 + rand_val(&mut rng));
            for &cs in &clusters {
                for c in cs..(cs + clen).min(n) {
                    if c != r {
                        coo.push(r as u32, c as u32, -rand_val(&mut rng));
                    }
                }
            }
        }
        b0 += bsize;
    }
    dedup_value_fix(coo.to_csr())
}

/// Banded FEM-like matrix (`bcsstk17`): each row has ~`per_row` entries
/// inside a ±`half_band` band around the diagonal, in contiguous clusters.
pub fn banded(n: usize, half_band: usize, per_row: usize, seed: u64) -> Csr {
    let mut rng = Pcg32::new(seed);
    let mut coo = Coo::with_capacity(n, n, n * per_row);
    for r in 0..n {
        let lo = r.saturating_sub(half_band);
        let hi = (r + half_band).min(n - 1);
        let width = hi - lo + 1;
        coo.push(r as u32, r as u32, 10.0 + rand_val(&mut rng));
        // Contiguous cluster starts (FEM element coupling blocks).
        let clusters = (per_row / 6).max(1);
        for _ in 0..clusters {
            let start = lo + rng.gen_usize(width);
            let len = (per_row / clusters).max(1);
            for c in start..(start + len).min(hi + 1) {
                if c != r {
                    coo.push(r as u32, c as u32, -rand_val(&mut rng));
                }
            }
        }
    }
    dedup_value_fix(coo.to_csr())
}

/// Union of `k` random permutation matrices: every row AND column has
/// exactly `k` nonzeros (up to collisions, retried) — the `m133-b3`
/// simplicial-boundary stand-in with zero work variance.
pub fn kregular(n: usize, k: usize, seed: u64) -> Csr {
    let mut rng = Pcg32::new(seed);
    let mut coo = Coo::with_capacity(n, n, n * k);
    let mut used: Vec<Vec<u32>> = vec![Vec::with_capacity(k); n];
    for _ in 0..k {
        let perm = rng.permutation(n);
        for (r, &c) in perm.iter().enumerate() {
            // Avoid duplicate (r,c) from earlier permutations by linear probing
            // the column space (keeps row/col degree exactly k in expectation;
            // collisions are vanishingly rare for n >> k).
            let mut c = c;
            while used[r].contains(&c) {
                c = (c + 1) % n as u32;
            }
            used[r].push(c);
            coo.push(r as u32, c, if rng.gen_bool(0.5) { 1.0 } else { -1.0 });
        }
    }
    coo.to_csr()
}

/// Near-uniform-degree random matrix (`cage11`-like): row degree uniform in
/// [k_lo, k_hi], columns uniform — tiny work variance.
pub fn uniform_degree(n: usize, k_lo: usize, k_hi: usize, seed: u64) -> Csr {
    let mut rng = Pcg32::new(seed);
    let mut coo = Coo::with_capacity(n, n, n * (k_lo + k_hi) / 2);
    for r in 0..n {
        let k = k_lo + rng.gen_usize(k_hi - k_lo + 1);
        for _ in 0..k {
            coo.push(r as u32, rng.gen_usize(n) as u32, rand_val(&mut rng));
        }
    }
    dedup_value_fix(coo.to_csr())
}

/// Circuit-like matrix (`scircuit`): mostly near-diagonal couplings plus a
/// few long-range "nets"; moderate, low-variance degrees.
pub fn circuit(n: usize, mean_deg: f64, p_longrange: f64, seed: u64) -> Csr {
    let mut rng = Pcg32::new(seed);
    let mut coo = Coo::with_capacity(n, n, (n as f64 * mean_deg) as usize);
    for r in 0..n {
        coo.push(r as u32, r as u32, rand_val(&mut rng));
        let k = rng.gen_poisson(mean_deg - 1.0);
        for _ in 0..k {
            let c = if rng.gen_bool(p_longrange) {
                rng.gen_usize(n) as u32
            } else {
                // local coupling within +-64
                let off = rng.gen_usize(129) as i64 - 64;
                (r as i64 + off).clamp(0, n as i64 - 1) as u32
            };
            coo.push(r as u32, c, rand_val(&mut rng));
        }
    }
    dedup_value_fix(coo.to_csr())
}

/// COO->CSR collapses duplicate coordinates by summing; re-randomize values
/// so sums don't drift outside [0.5, 1.5) (keeps numerics tame for f32
/// accumulation checks).
fn dedup_value_fix(mut m: Csr) -> Csr {
    let mut rng = Pcg32::new(0xC0FFEE);
    for v in &mut m.data {
        if *v < 0.5 || *v >= 1.5 {
            *v = rng.gen_f32_range(0.5, 1.5);
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn er_shape_and_validity() {
        let m = erdos_renyi(100, 80, 500, 1);
        assert!(m.validate().is_ok());
        assert_eq!(m.nrows, 100);
        assert_eq!(m.ncols, 80);
        assert!(m.nnz() > 400 && m.nnz() <= 500);
    }

    #[test]
    fn er_deterministic() {
        let a = erdos_renyi(50, 50, 100, 7);
        let b = erdos_renyi(50, 50, 100, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn rmat_is_skewed() {
        let m = rmat(1024, 1024, 8192, 0.57, 0.19, 0.19, 3);
        assert!(m.validate().is_ok());
        let degs: Vec<f64> = (0..m.nrows).map(|r| m.row_len(r) as f64).collect();
        let cv = crate::util::stats::cv(&degs);
        assert!(cv > 0.8, "rmat should be skewed, cv={cv}");
    }

    #[test]
    fn grid2d_degrees() {
        let m = grid2d(10, 10, 0);
        assert!(m.validate().is_ok());
        assert_eq!(m.nrows, 100);
        // interior rows have 5 entries
        assert_eq!(m.row_len(55), 5);
        // corner has 3
        assert_eq!(m.row_len(0), 3);
    }

    #[test]
    fn grid3d_27pt_interior() {
        let m = grid3d_27pt(5, 0);
        assert!(m.validate().is_ok());
        // interior point (2,2,2) has full 27 neighbours
        let center = (2 * 5 + 2) * 5 + 2;
        assert_eq!(m.row_len(center), 27);
    }

    #[test]
    fn kregular_exact_degree() {
        let m = kregular(200, 4, 9);
        assert!(m.validate().is_ok());
        for r in 0..m.nrows {
            assert_eq!(m.row_len(r), 4, "row {r}");
        }
        assert_eq!(m.nnz(), 800);
    }

    #[test]
    fn uniform_degree_bounds() {
        let m = uniform_degree(500, 12, 17, 11);
        assert!(m.validate().is_ok());
        let avg = m.nnz() as f64 / m.nrows as f64;
        assert!(avg > 11.0 && avg < 17.5, "avg degree {avg}");
    }

    #[test]
    fn banded_stays_in_band() {
        let m = banded(100, 10, 8, 2);
        assert!(m.validate().is_ok());
        for r in 0..m.nrows {
            let (k, _) = m.row(r);
            for &c in k {
                assert!((c as i64 - r as i64).abs() <= 10);
            }
        }
    }

    #[test]
    fn road_sparse_low_degree() {
        let m = road(30, 30, 0.64, 4);
        assert!(m.validate().is_ok());
        let avg = m.nnz() as f64 / m.nrows as f64;
        assert!(avg > 1.5 && avg < 3.5, "avg {avg}");
    }

    #[test]
    fn circuit_validates() {
        let m = circuit(1000, 5.6, 0.1, 5);
        assert!(m.validate().is_ok());
        let avg = m.nnz() as f64 / m.nrows as f64;
        assert!(avg > 4.0 && avg < 7.0, "avg {avg}");
    }

    #[test]
    fn values_in_range() {
        let m = erdos_renyi(100, 100, 400, 13);
        assert!(m.data.iter().all(|&v| (0.5..1.5).contains(&v)));
    }
}
