//! Table III statistics: per-row work (multiplications to compute one output
//! row of A*A), average output nnz per row (symbolic SpGEMM), per-16-row
//! group work, and the within-group work coefficient of variation that
//! drives spz's lockstep-imbalance story (§VI-A).

use crate::matrix::Csr;
use crate::util::stats::{cv, mean};

/// The statistics reported in Table III for one matrix (self-multiply A*A).
#[derive(Clone, Debug)]
pub struct MatrixStats {
    pub nrows: usize,
    pub nnz: usize,
    pub density: f64,
    /// Avg multiplications per output row: mean_r sum_{j in A(r,:)} nnz(A(j,:)).
    pub avg_work_per_row: f64,
    /// Avg nonzeros per output row of A*A (symbolic).
    pub avg_out_nnz_per_row: f64,
    /// Avg work per group of `group` consecutive rows (in thousands in the paper).
    pub avg_work_per_group: f64,
    /// Mean within-group CV of per-row work ("Work Var" column).
    pub work_var: f64,
}

/// Per-row work for C = A*B (number of multiplications, Gustavson).
pub fn row_work(a: &Csr, b: &Csr) -> Vec<u64> {
    (0..a.nrows)
        .map(|r| {
            a.row(r)
                .0
                .iter()
                .map(|&j| b.row_len(j as usize) as u64)
                .sum()
        })
        .collect()
}

/// Symbolic SpGEMM: nnz per output row of A*B (dense-bitmap per row, fast
/// enough for our dataset sizes; used only for characterization).
pub fn symbolic_out_nnz(a: &Csr, b: &Csr) -> Vec<u32> {
    let mut mark = vec![u32::MAX; b.ncols];
    let mut out = Vec::with_capacity(a.nrows);
    for r in 0..a.nrows {
        let stamp = r as u32;
        let mut cnt = 0u32;
        for &j in a.row(r).0 {
            for &k in b.row(j as usize).0 {
                if mark[k as usize] != stamp {
                    mark[k as usize] = stamp;
                    cnt += 1;
                }
            }
        }
        out.push(cnt);
    }
    out
}

/// Compute the full Table III row for `a * a` with 16-row groups.
pub fn characterize(a: &Csr, group: usize) -> MatrixStats {
    let work = row_work(a, a);
    let out_nnz = symbolic_out_nnz(a, a);
    let workf: Vec<f64> = work.iter().map(|&w| w as f64).collect();
    let mut group_works = Vec::new();
    let mut group_cvs = Vec::new();
    for chunk in workf.chunks(group) {
        let s: f64 = chunk.iter().sum();
        group_works.push(s);
        // Paper's "Work Var": CV of per-row work within a 16-row group,
        // averaged over groups with non-trivial work.
        if s > 0.0 {
            group_cvs.push(cv(chunk));
        }
    }
    MatrixStats {
        nrows: a.nrows,
        nnz: a.nnz(),
        density: a.density(),
        avg_work_per_row: mean(&workf),
        avg_out_nnz_per_row: mean(&out_nnz.iter().map(|&x| x as f64).collect::<Vec<_>>()),
        avg_work_per_group: mean(&group_works),
        work_var: mean(&group_cvs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;

    #[test]
    fn row_work_identity() {
        let i = Csr::identity(8);
        assert_eq!(row_work(&i, &i), vec![1; 8]);
    }

    #[test]
    fn symbolic_identity() {
        let i = Csr::identity(8);
        assert_eq!(symbolic_out_nnz(&i, &i), vec![1; 8]);
    }

    #[test]
    fn symbolic_matches_reference_spgemm() {
        let a = gen::erdos_renyi(60, 60, 300, 21);
        let c = crate::spgemm::reference(&a, &a);
        let sym = symbolic_out_nnz(&a, &a);
        for r in 0..a.nrows {
            assert_eq!(sym[r] as usize, c.row_len(r), "row {r}");
        }
    }

    #[test]
    fn kregular_work_var_zero() {
        let m = gen::kregular(256, 4, 1);
        let st = characterize(&m, 16);
        assert!((st.avg_work_per_row - 16.0).abs() < 1e-9);
        assert!(st.work_var < 1e-9, "work var {}", st.work_var);
    }

    #[test]
    fn rmat_work_var_high() {
        let m = gen::rmat(2048, 2048, 16384, 0.57, 0.19, 0.19, 2);
        let st = characterize(&m, 16);
        assert!(st.work_var > 0.7, "work var {}", st.work_var);
    }

    #[test]
    fn density_consistent() {
        let m = gen::erdos_renyi(100, 100, 400, 5);
        let st = characterize(&m, 16);
        assert!((st.density - m.density()).abs() < 1e-12);
    }
}
