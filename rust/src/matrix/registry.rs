//! The 14-dataset evaluation suite of Table III.
//!
//! SuiteSparse downloads are unavailable in this environment, so each matrix
//! is replaced by a calibrated synthetic stand-in whose Table III statistics
//! (rows, nnz, work/row, output density, within-16-row work CV) approximate
//! the original (DESIGN.md "Substitutions"). `spz table3` prints paper vs
//! measured side by side. Real `.mtx` files can be substituted via
//! `spz ... --mtx-dir DIR` (files named `<name>.mtx`).

use crate::matrix::{gen, Csr};

/// Statistics as printed in Table III of the paper.
#[derive(Clone, Copy, Debug)]
pub struct PaperRow {
    pub rows: f64,
    pub nnz: f64,
    pub density: f64,
    pub avg_work: f64,
    pub avg_out_nnz: f64,
    pub group_work: f64,
    pub work_var: f64,
}

/// Generator recipe for the synthetic stand-in.
#[derive(Clone, Copy, Debug)]
pub enum GenSpec {
    /// R-MAT power-law graph (a, b, c quadrant probabilities).
    Rmat { rows: usize, nnz: usize, a: f64, b: f64, c: f64 },
    /// Lognormal-weight power-law graph with controlled degree CV
    /// (sigma derived from Table III's work/deg^2 ratio).
    Powerlaw { rows: usize, nnz: usize, sigma: f64, p_tri: f64 },
    /// Block-banded FEM matrix (shared column clusters per row block).
    BlockBanded { n: usize, half_band: usize, per_row: usize, block: usize, jitter: f64 },
    /// Road-like partial 2-D grid.
    Road { nx: usize, ny: usize, p_edge: f64 },
    /// 27-point 3-D stencil on n^3.
    Grid3d { n: usize },
    /// Banded FEM-like matrix.
    Banded { n: usize, half_band: usize, per_row: usize },
    /// Union of k permutations (exactly k nnz/row and /col).
    KRegular { n: usize, k: usize },
    /// Uniform row degree in [k_lo, k_hi].
    UniformDeg { n: usize, k_lo: usize, k_hi: usize },
    /// Circuit-like local + long-range couplings.
    Circuit { n: usize, mean_deg: f64, p_long: f64 },
}

#[derive(Clone, Copy, Debug)]
pub struct Dataset {
    pub name: &'static str,
    pub family: &'static str,
    pub paper: PaperRow,
    pub spec: GenSpec,
    pub seed: u64,
}

/// The effective scale [`Dataset::build`] uses for any requested scale.
/// Shared with the API's dataset cache keys so that scales which build the
/// same matrix also share one cache entry.
pub fn normalize_scale(scale: f64) -> f64 {
    scale.clamp(1e-3, 1.0)
}

impl Dataset {
    /// Instantiate the synthetic stand-in, optionally scaled down
    /// (`scale` in (0, 1]; rows and nnz shrink together so the densities and
    /// per-row work statistics are approximately preserved).
    pub fn build(&self, scale: f64) -> Csr {
        let s = normalize_scale(scale);
        let sc = |x: usize| ((x as f64 * s).round() as usize).max(64);
        match self.spec {
            GenSpec::Rmat { rows, nnz, a, b, c } => {
                gen::rmat(sc(rows), sc(rows), sc(nnz), a, b, c, self.seed)
            }
            GenSpec::Powerlaw { rows, nnz, sigma, p_tri } => {
                gen::powerlaw_clustered(sc(rows), sc(nnz), sigma, p_tri, self.seed)
            }
            GenSpec::BlockBanded { n, half_band, per_row, block, jitter } => {
                gen::block_banded(sc(n), half_band, per_row, block, jitter, self.seed)
            }
            GenSpec::Road { nx, ny, p_edge } => {
                let f = s.sqrt();
                let scx = |x: usize| ((x as f64 * f).round() as usize).max(8);
                gen::road(scx(nx), scx(ny), p_edge, self.seed)
            }
            GenSpec::Grid3d { n } => {
                let f = s.cbrt();
                gen::grid3d_27pt(((n as f64 * f).round() as usize).max(4), self.seed)
            }
            GenSpec::Banded { n, half_band, per_row } => {
                gen::banded(sc(n), half_band, per_row, self.seed)
            }
            GenSpec::KRegular { n, k } => gen::kregular(sc(n), k, self.seed),
            GenSpec::UniformDeg { n, k_lo, k_hi } => gen::uniform_degree(sc(n), k_lo, k_hi, self.seed),
            GenSpec::Circuit { n, mean_deg, p_long } => gen::circuit(sc(n), mean_deg, p_long, self.seed),
        }
    }
}

/// The evaluation suite, ordered as in Table III (by decreasing work var).
pub const DATASETS: &[Dataset] = &[
    Dataset {
        name: "p2p",
        family: "p2p network",
        paper: PaperRow { rows: 63e3, nnz: 148e3, density: 3.78e-5, avg_work: 8.60, avg_out_nnz: 8.59, group_work: 0.14e3, work_var: 2.26 },
        spec: GenSpec::Powerlaw { rows: 63_000, nnz: 148_000, sigma: 0.67, p_tri: 0.00 },
        seed: 0xA001,
    },
    Dataset {
        name: "wiki",
        family: "social graph",
        paper: PaperRow { rows: 8e3, nnz: 104e3, density: 1.51e-3, avg_work: 547.52, avg_out_nnz: 220.70, group_work: 8.76e3, work_var: 2.06 },
        spec: GenSpec::Powerlaw { rows: 8_300, nnz: 104_000, sigma: 1.12, p_tri: 0.70 },
        seed: 0xA002,
    },
    Dataset {
        name: "soc",
        family: "social graph",
        paper: PaperRow { rows: 76e3, nnz: 509e3, density: 8.84e-5, avg_work: 526.09, avg_out_nnz: 271.20, group_work: 8.48e3, work_var: 1.43 },
        spec: GenSpec::Powerlaw { rows: 76_000, nnz: 509_000, sigma: 1.50, p_tri: 0.60 },
        seed: 0xA003,
    },
    Dataset {
        name: "ca-cm",
        family: "collaboration",
        paper: PaperRow { rows: 23e3, nnz: 187e3, density: 3.49e-4, avg_work: 178.66, avg_out_nnz: 101.82, group_work: 2.86e3, work_var: 1.35 },
        spec: GenSpec::Powerlaw { rows: 23_000, nnz: 187_000, sigma: 1.00, p_tri: 0.55 },
        seed: 0xA004,
    },
    Dataset {
        name: "ndwww",
        family: "web graph",
        paper: PaperRow { rows: 326e3, nnz: 930e3, density: 8.76e-6, avg_work: 29.42, avg_out_nnz: 12.63, group_work: 0.78e3, work_var: 1.30 },
        spec: GenSpec::Powerlaw { rows: 326_000, nnz: 930_000, sigma: 1.13, p_tri: 0.65 },
        seed: 0xA005,
    },
    Dataset {
        name: "patents",
        family: "citation graph",
        paper: PaperRow { rows: 241e3, nnz: 561e3, density: 9.69e-6, avg_work: 10.83, avg_out_nnz: 9.48, group_work: 0.20e3, work_var: 1.29 },
        spec: GenSpec::Powerlaw { rows: 241_000, nnz: 561_000, sigma: 0.83, p_tri: 0.15 },
        seed: 0xA006,
    },
    Dataset {
        name: "ca-cs",
        family: "collaboration",
        paper: PaperRow { rows: 227e3, nnz: 1628e3, density: 3.15e-5, avg_work: 164.38, avg_out_nnz: 72.68, group_work: 2.63e3, work_var: 0.98 },
        spec: GenSpec::Powerlaw { rows: 227_000, nnz: 1_628_000, sigma: 1.08, p_tri: 0.65 },
        seed: 0xA007,
    },
    Dataset {
        name: "email",
        family: "email graph",
        paper: PaperRow { rows: 37e3, nnz: 184e3, density: 1.37e-4, avg_work: 163.04, avg_out_nnz: 89.30, group_work: 2.64e3, work_var: 0.88 },
        spec: GenSpec::Powerlaw { rows: 37_000, nnz: 184_000, sigma: 1.30, p_tri: 0.60 },
        seed: 0xA008,
    },
    Dataset {
        name: "scircuit",
        family: "circuit",
        paper: PaperRow { rows: 171e3, nnz: 959e3, density: 3.28e-5, avg_work: 50.74, avg_out_nnz: 30.54, group_work: 0.81e3, work_var: 0.48 },
        spec: GenSpec::Circuit { n: 171_000, mean_deg: 5.6, p_long: 0.06 },
        seed: 0xA009,
    },
    Dataset {
        name: "bcsstk17",
        family: "FEM stiffness",
        paper: PaperRow { rows: 11e3, nnz: 220e3, density: 1.83e-3, avg_work: 445.71, avg_out_nnz: 56.58, group_work: 7.13e3, work_var: 0.38 },
        spec: GenSpec::BlockBanded { n: 11_000, half_band: 120, per_row: 19, block: 8, jitter: 0.35 },
        seed: 0xA00A,
    },
    Dataset {
        name: "usroads",
        family: "road network",
        paper: PaperRow { rows: 129e3, nnz: 331e3, density: 1.98e-5, avg_work: 7.18, avg_out_nnz: 5.45, group_work: 0.11e3, work_var: 0.31 },
        spec: GenSpec::Road { nx: 360, ny: 360, p_edge: 0.64 },
        seed: 0xA00B,
    },
    Dataset {
        name: "p3d",
        family: "3-D Poisson",
        paper: PaperRow { rows: 14e3, nnz: 353e3, density: 1.93e-3, avg_work: 870.85, avg_out_nnz: 218.85, group_work: 13.93e3, work_var: 0.24 },
        spec: GenSpec::Grid3d { n: 24 },
        seed: 0xA00C,
    },
    Dataset {
        name: "cage11",
        family: "DNA electrophoresis",
        paper: PaperRow { rows: 39e3, nnz: 560e3, density: 3.66e-4, avg_work: 225.13, avg_out_nnz: 97.59, group_work: 3.60e3, work_var: 0.08 },
        spec: GenSpec::BlockBanded { n: 39_000, half_band: 500, per_row: 14, block: 4, jitter: 0.10 },
        seed: 0xA00D,
    },
    Dataset {
        name: "m133-b3",
        family: "simplicial complex",
        paper: PaperRow { rows: 200e3, nnz: 800e3, density: 2.00e-5, avg_work: 16.00, avg_out_nnz: 15.90, group_work: 0.26e3, work_var: 0.00 },
        spec: GenSpec::KRegular { n: 200_000, k: 4 },
        seed: 0xA00E,
    },
];

/// Look a dataset up by name.
pub fn find(name: &str) -> Option<&'static Dataset> {
    DATASETS.iter().find(|d| d.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fourteen_datasets() {
        assert_eq!(DATASETS.len(), 14);
    }

    #[test]
    fn names_unique() {
        let mut names: Vec<_> = DATASETS.iter().map(|d| d.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 14);
    }

    #[test]
    fn find_works() {
        assert!(find("wiki").is_some());
        assert!(find("nope").is_none());
    }

    #[test]
    fn small_scale_builds_validate() {
        for d in DATASETS {
            let m = d.build(0.02);
            assert!(m.validate().is_ok(), "{} invalid", d.name);
            assert!(m.nnz() > 0, "{} empty", d.name);
        }
    }

    #[test]
    fn m133_regular_at_scale() {
        let d = find("m133-b3").unwrap();
        let m = d.build(0.01);
        for r in 0..m.nrows {
            assert_eq!(m.row_len(r), 4);
        }
    }
}
