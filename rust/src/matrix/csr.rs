//! Compressed Sparse Row matrix — the format all SpGEMM implementations
//! consume and produce (the row-wise dataflow needs no CSC conversion,
//! paper §II-B).

/// CSR sparse matrix with u32 column indices and f32 values (ELEN=32).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Csr {
    pub nrows: usize,
    pub ncols: usize,
    /// len nrows+1; row r occupies indices[indptr[r]..indptr[r+1]].
    pub indptr: Vec<usize>,
    /// column indices, sorted ascending within each row, unique.
    pub indices: Vec<u32>,
    pub data: Vec<f32>,
}

impl Csr {
    pub fn empty(nrows: usize, ncols: usize) -> Self {
        Csr {
            nrows,
            ncols,
            indptr: vec![0; nrows + 1],
            indices: Vec::new(),
            data: Vec::new(),
        }
    }

    /// Identity matrix (useful for tests and AMG example).
    pub fn identity(n: usize) -> Self {
        Csr {
            nrows: n,
            ncols: n,
            indptr: (0..=n).collect(),
            indices: (0..n as u32).collect(),
            data: vec![1.0; n],
        }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    pub fn density(&self) -> f64 {
        if self.nrows == 0 || self.ncols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.nrows as f64 * self.ncols as f64)
    }

    #[inline]
    pub fn row_range(&self, r: usize) -> std::ops::Range<usize> {
        self.indptr[r]..self.indptr[r + 1]
    }

    #[inline]
    pub fn row_len(&self, r: usize) -> usize {
        self.indptr[r + 1] - self.indptr[r]
    }

    #[inline]
    pub fn row(&self, r: usize) -> (&[u32], &[f32]) {
        let rng = self.row_range(r);
        (&self.indices[rng.clone()], &self.data[rng])
    }

    /// Build from per-row (already sorted, unique) key/value lists.
    pub fn from_rows(nrows: usize, ncols: usize, rows: Vec<(Vec<u32>, Vec<f32>)>) -> Self {
        assert_eq!(rows.len(), nrows);
        let nnz: usize = rows.iter().map(|(k, _)| k.len()).sum();
        let mut indptr = Vec::with_capacity(nrows + 1);
        let mut indices = Vec::with_capacity(nnz);
        let mut data = Vec::with_capacity(nnz);
        indptr.push(0);
        for (k, v) in rows {
            debug_assert_eq!(k.len(), v.len());
            debug_assert!(k.windows(2).all(|w| w[0] < w[1]), "rows must be sorted unique");
            indices.extend_from_slice(&k);
            data.extend_from_slice(&v);
            indptr.push(indices.len());
        }
        Csr {
            nrows,
            ncols,
            indptr,
            indices,
            data,
        }
    }

    /// Structural + numeric validation (used by property tests).
    pub fn validate(&self) -> Result<(), String> {
        if self.indptr.len() != self.nrows + 1 {
            return Err("indptr length".into());
        }
        if self.indptr[0] != 0 || *self.indptr.last().unwrap() != self.indices.len() {
            return Err("indptr endpoints".into());
        }
        if self.indices.len() != self.data.len() {
            return Err("indices/data length mismatch".into());
        }
        for r in 0..self.nrows {
            if self.indptr[r] > self.indptr[r + 1] {
                return Err(format!("indptr not monotone at row {r}"));
            }
            let (k, _) = self.row(r);
            for w in k.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("row {r} not sorted-unique"));
                }
            }
            if let Some(&max) = k.last() {
                if max as usize >= self.ncols {
                    return Err(format!("row {r} column out of range"));
                }
            }
        }
        Ok(())
    }

    /// Transpose (CSR of A^T). Counting-sort based, O(nnz).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.ncols + 1];
        for &c in &self.indices {
            counts[c as usize + 1] += 1;
        }
        for i in 0..self.ncols {
            counts[i + 1] += counts[i];
        }
        let mut indices = vec![0u32; self.nnz()];
        let mut data = vec![0f32; self.nnz()];
        let mut next = counts.clone();
        for r in 0..self.nrows {
            for i in self.row_range(r) {
                let c = self.indices[i] as usize;
                indices[next[c]] = r as u32;
                data[next[c]] = self.data[i];
                next[c] += 1;
            }
        }
        Csr {
            nrows: self.ncols,
            ncols: self.nrows,
            indptr: counts,
            indices,
            data,
        }
    }

    /// Dense representation (small matrices / oracles only).
    pub fn to_dense(&self) -> Vec<Vec<f32>> {
        let mut d = vec![vec![0f32; self.ncols]; self.nrows];
        for r in 0..self.nrows {
            for i in self.row_range(r) {
                d[r][self.indices[i] as usize] = self.data[i];
            }
        }
        d
    }

    /// Approximate numeric equality with identical structure.
    pub fn approx_eq(&self, other: &Csr, rel: f32) -> bool {
        if self.nrows != other.nrows
            || self.ncols != other.ncols
            || self.indptr != other.indptr
            || self.indices != other.indices
        {
            return false;
        }
        self.data
            .iter()
            .zip(&other.data)
            .all(|(a, b)| (a - b).abs() <= rel * a.abs().max(b.abs()).max(1.0))
    }

    /// Sum of |values| (quick fingerprint for tests).
    pub fn abs_sum(&self) -> f64 {
        self.data.iter().map(|v| v.abs() as f64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [[1 0 2], [0 0 0], [3 4 0]]
        Csr {
            nrows: 3,
            ncols: 3,
            indptr: vec![0, 2, 2, 4],
            indices: vec![0, 2, 0, 1],
            data: vec![1.0, 2.0, 3.0, 4.0],
        }
    }

    #[test]
    fn validate_good() {
        assert!(sample().validate().is_ok());
    }

    #[test]
    fn validate_catches_unsorted() {
        let mut m = sample();
        m.indices.swap(0, 1);
        assert!(m.validate().is_err());
    }

    #[test]
    fn validate_catches_out_of_range() {
        let mut m = sample();
        m.indices[0] = 17;
        assert!(m.validate().is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let m = sample();
        let t = m.transpose().transpose();
        assert_eq!(m, t);
    }

    #[test]
    fn transpose_correct() {
        let t = sample().transpose();
        let d = t.to_dense();
        assert_eq!(d[0], vec![1.0, 0.0, 3.0]);
        assert_eq!(d[1], vec![0.0, 0.0, 4.0]);
        assert_eq!(d[2], vec![2.0, 0.0, 0.0]);
    }

    #[test]
    fn identity_validates() {
        let i = Csr::identity(5);
        assert!(i.validate().is_ok());
        assert_eq!(i.nnz(), 5);
    }

    #[test]
    fn from_rows_matches() {
        let m = Csr::from_rows(
            2,
            3,
            vec![(vec![0, 2], vec![1.0, 2.0]), (vec![1], vec![5.0])],
        );
        assert!(m.validate().is_ok());
        assert_eq!(m.row(1), (&[1u32][..], &[5.0f32][..]));
    }

    #[test]
    fn density() {
        assert!((sample().density() - 4.0 / 9.0).abs() < 1e-12);
    }
}
