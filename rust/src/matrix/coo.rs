//! Coordinate-format sparse matrix (builder format for the generators).

use crate::matrix::csr::Csr;

/// COO triplets; duplicates allowed until conversion (summed in `to_csr`).
#[derive(Clone, Debug, Default)]
pub struct Coo {
    pub nrows: usize,
    pub ncols: usize,
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Coo {
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Coo {
            nrows,
            ncols,
            rows: Vec::new(),
            cols: Vec::new(),
            vals: Vec::new(),
        }
    }

    pub fn with_capacity(nrows: usize, ncols: usize, cap: usize) -> Self {
        Coo {
            nrows,
            ncols,
            rows: Vec::with_capacity(cap),
            cols: Vec::with_capacity(cap),
            vals: Vec::with_capacity(cap),
        }
    }

    #[inline]
    pub fn push(&mut self, r: u32, c: u32, v: f32) {
        debug_assert!((r as usize) < self.nrows && (c as usize) < self.ncols);
        self.rows.push(r);
        self.cols.push(c);
        self.vals.push(v);
    }

    pub fn nnz(&self) -> usize {
        self.rows.len()
    }

    /// Convert to CSR; duplicate (r,c) entries are summed, columns sorted.
    pub fn to_csr(&self) -> Csr {
        // Counting sort by row.
        let mut counts = vec![0usize; self.nrows + 1];
        for &r in &self.rows {
            counts[r as usize + 1] += 1;
        }
        for i in 0..self.nrows {
            counts[i + 1] += counts[i];
        }
        let mut order = vec![0u32; self.nnz()];
        {
            let mut next = counts.clone();
            for (i, &r) in self.rows.iter().enumerate() {
                order[next[r as usize]] = i as u32;
                next[r as usize] += 1;
            }
        }
        let mut indptr = Vec::with_capacity(self.nrows + 1);
        let mut indices: Vec<u32> = Vec::with_capacity(self.nnz());
        let mut data: Vec<f32> = Vec::with_capacity(self.nnz());
        indptr.push(0usize);
        let mut scratch: Vec<(u32, f32)> = Vec::new();
        for r in 0..self.nrows {
            scratch.clear();
            for &oi in &order[counts[r]..counts[r + 1]] {
                scratch.push((self.cols[oi as usize], self.vals[oi as usize]));
            }
            scratch.sort_unstable_by_key(|&(c, _)| c);
            // merge duplicates
            let mut i = 0;
            while i < scratch.len() {
                let (c, mut v) = scratch[i];
                let mut j = i + 1;
                while j < scratch.len() && scratch[j].0 == c {
                    v += scratch[j].1;
                    j += 1;
                }
                indices.push(c);
                data.push(v);
                i = j;
            }
            indptr.push(indices.len());
        }
        Csr {
            nrows: self.nrows,
            ncols: self.ncols,
            indptr,
            indices,
            data,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn to_csr_sorts_and_sums_duplicates() {
        let mut m = Coo::new(2, 4);
        m.push(0, 3, 1.0);
        m.push(0, 1, 2.0);
        m.push(0, 3, 0.5);
        m.push(1, 0, 4.0);
        let c = m.to_csr();
        assert_eq!(c.indptr, vec![0, 2, 3]);
        assert_eq!(c.indices, vec![1, 3, 0]);
        assert_eq!(c.data, vec![2.0, 1.5, 4.0]);
    }

    #[test]
    fn empty_rows_ok() {
        let mut m = Coo::new(3, 3);
        m.push(2, 2, 1.0);
        let c = m.to_csr();
        assert_eq!(c.indptr, vec![0, 0, 0, 1]);
        assert_eq!(c.nnz(), 1);
    }

    #[test]
    fn empty_matrix() {
        let m = Coo::new(2, 2);
        let c = m.to_csr();
        assert_eq!(c.nnz(), 0);
        assert_eq!(c.indptr, vec![0, 0, 0]);
    }
}
