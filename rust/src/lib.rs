//! # SparseZipper — full-system reproduction, as an embeddable service
//!
//! Reproduction of *SparseZipper: Enhancing Matrix Extensions to Accelerate
//! SpGEMM on CPUs* (Ta, Randall, Batten) as a three-layer Rust + JAX/Pallas
//! stack:
//!
//! * **L3 (this crate)** — the cycle-level simulation substrate (instrumented
//!   machine + cache hierarchy + systolic-array model), the SparseZipper ISA,
//!   all five SpGEMM implementations from the paper's evaluation, and the
//!   Table IV area model.
//! * **L2/L1 (python/compile, build-time only)** — the matrix unit's
//!   functional datapath (sort/zip steps) as a JAX graph over Pallas kernels,
//!   AOT-lowered to HLO text and executed from Rust through the PJRT CPU
//!   client ([`runtime`], behind the `xla` cargo feature).
//!
//! ## The [`api`] module is the front door
//!
//! Experiments are typed values run against a long-lived [`Session`], which
//! owns the engine selection, the XLA artifact location, the simulated
//! [`SystemConfig`], and a dataset cache keyed by `(source, scale)` —
//! matrices, their Table III
//! characterization, and reference products are built at most once per
//! session and shared across jobs:
//!
//! ```no_run
//! # fn main() -> anyhow::Result<()> {
//! use sparsezipper::{DatasetSource, ImplId, JobSpec, Session, SuiteSpec};
//!
//! let session = Session::new();
//!
//! // One job: spz on the p2p stand-in, verified against the cached oracle.
//! let job = JobSpec::new(ImplId::Spz, DatasetSource::registry("p2p")?)
//!     .with_scale(0.05)
//!     .with_verify(true);
//! let result = session.run(&job)?;
//! println!("{:.0} cycles, verified={}", result.metrics.cycles, result.verified);
//! println!("{}", result.to_json());
//!
//! // A sweep: the paper's full (datasets x implementations) grid.
//! let suite = session.run_suite(&SuiteSpec { scale: 0.05, ..Default::default() })?;
//! println!("{}", sparsezipper::coordinator::figures::fig8(&suite));
//! # Ok(())
//! # }
//! ```
//!
//! [`Session::spgemm`] runs a general `C = A*B` on caller-owned matrices;
//! [`DatasetSource`] covers registry synthetics, `.mtx` files, and in-memory
//! [`Csr`]s. [`JobSpec::with_cores`] switches a job onto the row-blocked
//! multi-core driver ([`spgemm::parallel`]): row blocks of A on real worker
//! threads, one forked [`Machine`] per simulated core, per-core metrics and
//! critical-path cycles in [`MulticoreMetrics`], and six deterministic
//! block schedulers — static, work-stealing, work-proportional (`ws-dyn`),
//! the pilot-replay-guided bandwidth/NUMA pair (`ws-bw`/`ws-numa`), and the
//! adaptive `ws-adapt`, which picks the kernel *and* the block geometry per
//! block from probe passes and the pilot, falling back bit-identically to
//! the best fixed plan whenever it predicts no win. The memory
//! system behind the cores is modeled end-to-end: private L1/L2 per core
//! and one shared LLC with MESI-lite coherence bookkeeping plus a
//! multi-channel DRAM back end, priced by deterministic trace-and-replay.
//! The trace is a *streaming pipeline*: each core publishes sealed 64KB
//! event chunks into a bounded ring ([`mem::trace`]) while the replay
//! engine ([`mem::shared`]) consumes the streams concurrently in canonical
//! `(time, core, program-order)` interleaving — overflow chunks spill to a
//! temp file and are demand-loaded back, so peak trace memory is bounded
//! (`SharedMemConfig::trace_ring_chunks`) and per-core results stay
//! bit-reproducible across host thread schedules *and* ring sizes. DRAM
//! pages are placed NUMA-honestly: first-touch homes each 4KB page on the
//! first demanding core's socket ([`config::PagePlacement`], identical to
//! the historical blind interleave at one socket), and every multi-core run
//! is certified against a compulsory-DRAM-traffic *oracle*
//! ([`mem::oracle::OracleBound`]) — the achieved-vs-bound ratio rides in
//! [`mem::SharedStats`], the stable JSON, fig12, and `spz mem`, and
//! `achieved >= oracle` is a gating CI invariant on every registry
//! dataset. The `spz` CLI (`src/main.rs`) is a thin argv adapter
//! over this API, and [`coordinator`] renders [`api::SuiteRun`]s into the
//! paper's tables and figures (including the `fig12` multi-core scaling
//! study and the `spz mem` shared-memory report).
//!
//! For multi-tenant hosting, the [`service`] module wraps a shared
//! [`Session`] in a [`service::SimService`]: a bounded admission queue with
//! reject/block backpressure, deficit-round-robin fair scheduling across
//! tenants (weighted by the same Gustavson work estimates the `ws-*`
//! schedulers use), a fixed worker pool that simulated cores are budgeted
//! against, and handles that are both blocking-joinable and `.await`-able
//! with no async runtime. `Session::run_suite` itself runs on this pool —
//! there is one grid scheduler in the crate. See `rust/README.md` for a
//! quick start, or `examples/` (quickstart, paper_pipeline,
//! triangle_counting, amg_galerkin) for the API in use.

pub mod api;
pub mod area;
pub mod config;
pub mod coordinator;
pub mod isa;
pub mod matrix;
pub mod mem;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod spgemm;
pub mod systolic;
pub mod util;

pub use api::{
    DatasetSource, JobResult, JobSpec, Product, Session, SessionConfig, SuiteRun, SuiteSpec,
};
pub use config::{SharedMemConfig, SystemConfig};
pub use matrix::Csr;
pub use runtime::Engine;
pub use sim::{Machine, MulticoreMetrics, RunMetrics};
pub use spgemm::ImplId;
