//! # SparseZipper — full-system reproduction
//!
//! Reproduction of *SparseZipper: Enhancing Matrix Extensions to Accelerate
//! SpGEMM on CPUs* (Ta, Randall, Batten) as a three-layer Rust + JAX/Pallas
//! stack:
//!
//! * **L3 (this crate)** — the cycle-level simulation substrate (instrumented
//!   machine + cache hierarchy + systolic-array model), the SparseZipper ISA,
//!   all five SpGEMM implementations from the paper's evaluation, the
//!   experiment coordinator that regenerates every table and figure, and the
//!   Table IV area model.
//! * **L2/L1 (python/compile, build-time only)** — the matrix unit's
//!   functional datapath (sort/zip steps) as a JAX graph over Pallas kernels,
//!   AOT-lowered to HLO text and executed from Rust through the PJRT CPU
//!   client ([`runtime`]).
//!
//! Quick start: see `examples/quickstart.rs`; figures: `spz all`.

pub mod area;
pub mod config;
pub mod coordinator;
pub mod isa;
pub mod matrix;
pub mod mem;
pub mod runtime;
pub mod sim;
pub mod spgemm;
pub mod systolic;
pub mod util;

pub use config::SystemConfig;
pub use matrix::Csr;
pub use sim::Machine;
