//! Emitters that regenerate each table/figure of the paper from a
//! [`SuiteRun`]: aligned-text rendering (stdout) plus TSV series
//! (reports/ directory) for plotting.

use crate::api::{DatasetSource, JobSpec, Session, SuiteRun};
use crate::matrix::registry;
use crate::sim::machine::{Phase, NUM_PHASES, PHASE_NAMES};
use crate::spgemm::parallel::Scheduler;
use crate::spgemm::ImplId;
use crate::util::stats::geomean;
use anyhow::Result;
use std::fmt::Write as _;

/// Order datasets as Table III (descending work variance), then any
/// non-registry datasets (`.mtx` / in-memory sources) in name order so user
/// data shows up in the figures rather than being silently dropped.
fn ordered_datasets(r: &SuiteRun) -> Vec<String> {
    let mut names: Vec<String> = registry::DATASETS
        .iter()
        .map(|d| d.name)
        .filter(|n| r.dataset_stats.contains_key(*n))
        .map(str::to_string)
        .collect();
    let mut extra: Vec<String> = r
        .dataset_stats
        .keys()
        .filter(|k| registry::find(k).is_none())
        .cloned()
        .collect();
    extra.sort();
    names.extend(extra);
    names
}

/// Table III: dataset characterization — paper value vs measured stand-in.
pub fn table3(r: &SuiteRun) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "Table III. Evaluated datasets (paper -> measured synthetic stand-in)"
    );
    let _ = writeln!(
        s,
        "{:<10} {:>12} {:>12} {:>11} {:>16} {:>16} {:>14} {:>14}",
        "Matrix", "Rows", "NNZ", "Density", "AvgWork/row", "AvgOutNNZ/row", "Work/16rows", "WorkVar"
    );
    for name in ordered_datasets(r) {
        // Non-registry datasets have no paper row to compare against.
        let Some(d) = registry::find(&name) else { continue };
        let st = &r.dataset_stats[&name];
        let p = d.paper;
        let _ = writeln!(
            s,
            "{:<10} {:>5.0}K/{:>5.0}K {:>5.0}K/{:>5.0}K {:>5.0e}/{:>4.0e} {:>7.2}/{:>7.2} {:>7.2}/{:>7.2} {:>6.2}K/{:>5.2}K {:>6.2}/{:>6.2}",
            name,
            p.rows / 1e3,
            st.nrows as f64 / 1e3,
            p.nnz / 1e3,
            st.nnz as f64 / 1e3,
            p.density,
            st.density,
            p.avg_work,
            st.avg_work_per_row,
            p.avg_out_nnz,
            st.avg_out_nnz_per_row,
            p.group_work / 1e3,
            st.avg_work_per_group / 1e3,
            p.work_var,
            st.work_var,
        );
    }
    s
}

/// Figure 8: speedup over scl-hash per dataset, plus the paper's headline
/// geomean ratios.
pub fn fig8(r: &SuiteRun) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "Figure 8. Speedup over scalar baseline using hash table (scl-hash = 1.0)");
    let impls = ImplId::ALL;
    let _ = write!(s, "{:<10}", "Matrix");
    for i in impls {
        let _ = write!(s, " {i:>10}");
    }
    let _ = writeln!(s);
    let mut per_impl: Vec<Vec<f64>> = vec![Vec::new(); impls.len()];
    for name in ordered_datasets(r) {
        let _ = write!(s, "{name:<10}");
        for (k, i) in impls.iter().enumerate() {
            match r.speedup(*i, ImplId::SclHash, &name) {
                Some(x) => {
                    per_impl[k].push(x);
                    let _ = write!(s, " {x:>10.2}");
                }
                None => {
                    let _ = write!(s, " {:>10}", "-");
                }
            }
        }
        let _ = writeln!(s);
    }
    let _ = write!(s, "{:<10}", "geomean");
    for v in &per_impl {
        if v.is_empty() {
            let _ = write!(s, " {:>10}", "-");
        } else {
            let _ = write!(s, " {:>10.2}", geomean(v));
        }
    }
    let _ = writeln!(s);
    // Headline ratios (paper: 12.13x / 5.98x / 2.61x for spz, 2.60x spz/vec-radix).
    let ratio = |num: ImplId, den: ImplId| -> Option<f64> {
        let xs: Vec<f64> = ordered_datasets(r)
            .iter()
            .filter_map(|d| r.speedup(num, den, d))
            .collect();
        if xs.is_empty() {
            None
        } else {
            Some(geomean(&xs))
        }
    };
    for (num, den, paper) in [
        (ImplId::Spz, ImplId::SclArray, 12.13),
        (ImplId::Spz, ImplId::SclHash, 5.98),
        (ImplId::Spz, ImplId::VecRadix, 2.61),
        (ImplId::SclHash, ImplId::SclArray, 2.03),
        (ImplId::VecRadix, ImplId::SclHash, 2.29),
    ] {
        if let Some(x) = ratio(num, den) {
            let _ = writeln!(s, "  {num} vs {den}: {x:.2}x  (paper: {paper:.2}x)");
        }
    }
    s
}

/// Figure 9: execution-time breakdown, normalized to each dataset's
/// scl-hash total (the paper normalizes within each matrix).
pub fn fig9(r: &SuiteRun) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "Figure 9. Execution time breakdown (fraction of each impl's own total)"
    );
    let impls = [ImplId::VecRadix, ImplId::Spz, ImplId::SpzRsort];
    let _ = writeln!(
        s,
        "{:<10} {:<10} {:>9} {:>9} {:>9} {:>9} {:>9} {:>14}",
        "Matrix", "Impl", PHASE_NAMES[0], PHASE_NAMES[1], PHASE_NAMES[2], PHASE_NAMES[3], PHASE_NAMES[4], "cycles"
    );
    for name in ordered_datasets(r) {
        for i in impls {
            if let Some(e) = r.get(i, &name) {
                let tot: f64 = e.metrics.cycles.max(1e-9);
                let _ = write!(s, "{name:<10} {i:<10}");
                for p in 0..NUM_PHASES {
                    let _ = write!(s, " {:>8.1}%", 100.0 * e.metrics.phase_cycles[p] / tot);
                }
                // Simulated wall clock (critical path for multi-core jobs).
                let _ = writeln!(s, " {:>14.0}", e.time_cycles());
            }
        }
    }
    s
}

/// Figure 10: L1 data-cache accesses, vec-radix vs spz (normalized to spz).
pub fn fig10(r: &SuiteRun) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "Figure 10. L1D accesses (relative to spz = 1.0)");
    let _ = writeln!(
        s,
        "{:<10} {:>14} {:>14} {:>10}",
        "Matrix", "vec-radix", "spz", "ratio"
    );
    let mut ratios = Vec::new();
    for name in ordered_datasets(r) {
        if let (Some(v), Some(z)) = (r.get(ImplId::VecRadix, &name), r.get(ImplId::Spz, &name)) {
            let ratio = v.metrics.mem.l1d_accesses as f64 / z.metrics.mem.l1d_accesses.max(1) as f64;
            ratios.push(ratio);
            let _ = writeln!(
                s,
                "{:<10} {:>14} {:>14} {:>9.2}x",
                name, v.metrics.mem.l1d_accesses, z.metrics.mem.l1d_accesses, ratio
            );
        }
    }
    if !ratios.is_empty() {
        let _ = writeln!(s, "geomean vec-radix/spz: {:.2}x (paper: >1 across all matrices)", geomean(&ratios));
    }
    s
}

/// Figure 11: dynamic mssortk+mszipk instruction counts, spz vs spz-rsort.
pub fn fig11(r: &SuiteRun) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "Figure 11. Dynamic mssortk & mszipk instruction counts");
    let _ = writeln!(
        s,
        "{:<10} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "Matrix", "spz sortk", "spz zipk", "rsort sortk", "rsort zipk", "reduction"
    );
    for name in ordered_datasets(r) {
        if let (Some(z), Some(rs)) = (r.get(ImplId::Spz, &name), r.get(ImplId::SpzRsort, &name)) {
            let t1 = z.metrics.total_matrix_kv_pairs();
            let t2 = rs.metrics.total_matrix_kv_pairs();
            let _ = writeln!(
                s,
                "{:<10} {:>12} {:>12} {:>12} {:>12} {:>9.1}%",
                name,
                z.metrics.ops.mssortk,
                z.metrics.ops.mszipk,
                rs.metrics.ops.mssortk,
                rs.metrics.ops.mszipk,
                100.0 * (1.0 - t2 as f64 / t1.max(1) as f64)
            );
        }
    }
    s
}

/// TSV exports for plotting (one file per figure).
pub fn tsv_exports(r: &SuiteRun) -> Vec<(String, String)> {
    let mut out = Vec::new();
    // fig8.tsv
    let mut t = String::from("matrix\timpl\tspeedup_over_sclhash\tcycles\n");
    for name in ordered_datasets(r) {
        for e in r.results.iter().filter(|e| e.dataset == name) {
            let sp = r.speedup(e.impl_id, ImplId::SclHash, &name).unwrap_or(f64::NAN);
            let _ = writeln!(t, "{name}\t{}\t{sp:.6}\t{:.1}", e.impl_id, e.time_cycles());
        }
    }
    out.push(("fig8.tsv".to_string(), t));
    // fig9.tsv
    let mut t = String::from("matrix\timpl\tphase\tcycles\n");
    for name in ordered_datasets(r) {
        for e in r.results.iter().filter(|e| e.dataset == name) {
            for p in 0..NUM_PHASES {
                let _ = writeln!(
                    t,
                    "{name}\t{}\t{}\t{:.1}",
                    e.impl_id, PHASE_NAMES[p], e.metrics.phase_cycles[p]
                );
            }
        }
    }
    out.push(("fig9.tsv".to_string(), t));
    // fig10.tsv
    let mut t = String::from("matrix\timpl\tl1d_accesses\tl1d_hit_rate\n");
    for name in ordered_datasets(r) {
        for e in r.results.iter().filter(|e| e.dataset == name) {
            let _ = writeln!(
                t,
                "{name}\t{}\t{}\t{:.4}",
                e.impl_id,
                e.metrics.mem.l1d_accesses,
                e.metrics.mem.l1d_hit_rate()
            );
        }
    }
    out.push(("fig10.tsv".to_string(), t));
    // fig11.tsv
    let mut t = String::from("matrix\timpl\tmssortk\tmszipk\n");
    for name in ordered_datasets(r) {
        for e in r.results.iter().filter(|e| e.dataset == name) {
            let _ = writeln!(
                t,
                "{name}\t{}\t{}\t{}",
                e.impl_id, e.metrics.ops.mssortk, e.metrics.ops.mszipk
            );
        }
    }
    out.push(("fig11.tsv".to_string(), t));
    out
}

/// Sanity assertion helpers used by tests and the e2e example: does the
/// sweep reproduce the paper's qualitative shape?
pub fn shape_checks(r: &SuiteRun) -> Vec<(String, bool)> {
    let mut checks = Vec::new();
    let ds = ordered_datasets(r);
    let geo = |num: ImplId, den: ImplId| {
        let xs: Vec<f64> = ds.iter().filter_map(|d| r.speedup(num, den, d)).collect();
        geomean(&xs)
    };
    checks.push((
        "spz beats scl-hash (geomean > 2x)".into(),
        geo(ImplId::Spz, ImplId::SclHash) > 2.0,
    ));
    checks.push((
        "spz beats vec-radix (geomean > 1.5x)".into(),
        geo(ImplId::Spz, ImplId::VecRadix) > 1.5,
    ));
    // The scalar crossover is a cache-capacity effect: scl-array's dense
    // accumulator (~8B x ncols) must overflow the LLC for its scattered
    // accesses to hurt. Only assert it over datasets where that holds
    // (at small --scale no dataset qualifies and the check is skipped).
    let big: Vec<&str> = ds
        .iter()
        .filter(|d| {
            r.dataset_stats
                .get(d.as_str())
                .map(|st| st.nrows * 8 > 512 * 1024)
                .unwrap_or(false)
        })
        .map(|s| s.as_str())
        .collect();
    if !big.is_empty() {
        let xs: Vec<f64> = big
            .iter()
            .filter_map(|d| r.speedup(ImplId::SclHash, ImplId::SclArray, d))
            .collect();
        checks.push((
            format!("scl-hash beats scl-array on LLC-overflow matrices ({})", big.join(",")),
            geomean(&xs) > 1.2,
        ));
    }
    checks.push((
        "vec-radix beats scl-hash (geomean > 1.2x)".into(),
        geo(ImplId::VecRadix, ImplId::SclHash) > 1.2,
    ));
    // Fig 10 shape: vec-radix touches L1D more than spz on every matrix.
    let fig10_ok = ds.iter().all(|d| {
        match (r.get(ImplId::VecRadix, d), r.get(ImplId::Spz, d)) {
            (Some(v), Some(z)) => v.metrics.mem.l1d_accesses > z.metrics.mem.l1d_accesses,
            _ => true,
        }
    });
    checks.push(("vec-radix L1D accesses > spz on all matrices".into(), fig10_ok));
    // Fig 11 shape: rsort reduces k/v pairs on the high-variance matrices.
    for d in ["wiki", "soc", "ndwww", "ca-cm"] {
        if let (Some(z), Some(rs)) = (r.get(ImplId::Spz, d), r.get(ImplId::SpzRsort, d)) {
            checks.push((
                format!("rsort cuts kv-pairs on {d}"),
                rs.metrics.total_matrix_kv_pairs() < z.metrics.total_matrix_kv_pairs(),
            ));
        }
    }
    checks
}

/// Execution-phase share of the sort phase (used in tests).
pub fn sort_share(r: &SuiteRun, impl_id: ImplId, dataset: &str) -> Option<f64> {
    let e = r.get(impl_id, dataset)?;
    Some(e.metrics.phase_cycles[Phase::Sort as usize] / e.metrics.cycles.max(1e-9))
}

/// One point of the Figure 12 scaling study: `impl_id` on `dataset` at
/// `cores` under `scheduler` (`None` = the serial 1-core baseline).
#[derive(Clone, Debug)]
pub struct ScalingPoint {
    pub dataset: String,
    pub impl_id: ImplId,
    pub scheduler: Option<Scheduler>,
    pub cores: usize,
    /// Simulated wall-clock cycles (multi-core critical path).
    pub cycles: f64,
    /// Speedup over the same implementation's 1-core run.
    pub speedup: f64,
    /// Busiest core over mean core cycles (1.0 = balanced; the static vs
    /// work-stealing gap this exposes is the spz vs spz-rsort story at the
    /// core level).
    pub imbalance: f64,
    /// Shared-LLC demand hit rate from the replay (private-LLC rate for the
    /// serial baseline, where the shadow is the LLC).
    pub llc_hit_rate: f64,
    /// Coherence events (upgrades + dirty forwards) summed over cores.
    pub coherence_events: u64,
    /// Cross-core DRAM channel queueing cycles summed over cores.
    pub dram_queue_cycles: f64,
    /// Remote-socket fills (NUMA) summed over cores; 0 at one socket.
    pub remote_fills: u64,
    /// Hop-priced NUMA extra cycles summed over cores; 0 at one socket.
    pub remote_extra_cycles: f64,
    /// Blocks `ws-adapt` ran on a kernel other than the job's own
    /// implementation (its mixed-impl decision count); 0 under every fixed
    /// scheduler and on the serial baseline.
    pub mixed_impl_blocks: usize,
    /// Blocks `ws-adapt` split in two for bandwidth/balance; 0 otherwise.
    pub split_blocks: usize,
    /// DRAM lines the run actually moved (shared-LLC demand misses summed
    /// over cores); 0 on the serial baseline (no replay ran).
    pub achieved_dram_lines: u64,
    /// Compulsory-traffic oracle lower bound for the run
    /// ([`crate::mem::oracle::OracleBound`]); 0 on the serial baseline.
    pub oracle_dram_lines: u64,
    /// `achieved / oracle` — the model-honesty ratio, >= 1.0 wherever both
    /// are stamped; 0.0 on the serial baseline.
    pub oracle_ratio: f64,
}

/// Run the Figure 12 scaling study: `impl_id` on every dataset at each core
/// count, once per scheduler in `scheds` (`&Scheduler::ALL` for the full
/// sweep), all through the session's dataset cache.
pub fn scaling_sweep(
    session: &Session,
    datasets: &[DatasetSource],
    impl_id: ImplId,
    scale: f64,
    cores: &[usize],
    scheds: &[Scheduler],
) -> Result<Vec<ScalingPoint>> {
    let mut out = Vec::new();
    for src in datasets {
        let base = session.run(&JobSpec::new(impl_id, src.clone()).with_scale(scale))?;
        let base_cycles = base.time_cycles();
        let private_llc_rate = if base.metrics.mem.llc_accesses == 0 {
            0.0
        } else {
            base.metrics.mem.llc_hits as f64 / base.metrics.mem.llc_accesses as f64
        };
        out.push(ScalingPoint {
            dataset: base.dataset.clone(),
            impl_id,
            scheduler: None,
            cores: 1,
            cycles: base_cycles,
            speedup: 1.0,
            imbalance: 1.0,
            llc_hit_rate: private_llc_rate,
            coherence_events: 0,
            dram_queue_cycles: 0.0,
            remote_fills: 0,
            remote_extra_cycles: 0.0,
            mixed_impl_blocks: 0,
            split_blocks: 0,
            achieved_dram_lines: 0,
            oracle_dram_lines: 0,
            oracle_ratio: 0.0,
        });
        for &c in cores.iter().filter(|&&c| c > 1) {
            for &sched in scheds {
                let r = session.run(
                    &JobSpec::new(impl_id, src.clone())
                        .with_scale(scale)
                        .with_cores(c)
                        .with_scheduler(sched),
                )?;
                let cycles = r.time_cycles();
                let dec = r.sched_decisions;
                let sh = &r.metrics.shared;
                out.push(ScalingPoint {
                    dataset: r.dataset.clone(),
                    impl_id,
                    scheduler: Some(sched),
                    cores: c,
                    cycles,
                    speedup: base_cycles / cycles.max(1e-9),
                    imbalance: r.multicore.as_ref().map(|m| m.imbalance()).unwrap_or(1.0),
                    llc_hit_rate: sh.llc_hit_rate(),
                    coherence_events: sh.coherence_events(),
                    dram_queue_cycles: sh.dram_queue_cycles,
                    remote_fills: sh.remote_fills,
                    remote_extra_cycles: sh.remote_extra_cycles,
                    mixed_impl_blocks: dec.map(|d| d.swapped_blocks).unwrap_or(0),
                    split_blocks: dec.map(|d| d.split_blocks).unwrap_or(0),
                    achieved_dram_lines: sh.achieved_dram_lines,
                    oracle_dram_lines: sh.oracle_dram_lines,
                    oracle_ratio: sh.oracle_ratio(),
                });
            }
        }
    }
    Ok(out)
}

/// Figure 12: multi-core speedup per dataset, static vs (dynamic)
/// work-stealing, with the shared-memory picture at the largest core count
/// (shared-LLC hit rate and coherence events from the replay).
pub fn fig12(points: &[ScalingPoint]) -> String {
    let mut s = String::new();
    let impl_name = points.first().map(|p| p.impl_id.name()).unwrap_or("-");
    let mut cores: Vec<usize> = points.iter().map(|p| p.cores).collect();
    cores.sort_unstable();
    cores.dedup();
    // The scheduler list (and the row ordering below) derives from
    // Scheduler::ALL, the same source as fig12_tsv, so a new scheduler
    // cannot desynchronize the two renderings.
    let sched_list =
        Scheduler::ALL.iter().map(|sc| sc.name()).collect::<Vec<_>>().join(" vs ");
    let _ = writeln!(
        s,
        "Figure 12. Multi-core scaling ({impl_name}): speedup over 1 core \
         (row-blocked driver; {sched_list} block schedule; \
         llc-hit/coh/dram-q/numa-cyc from the shared-memory replay at the \
         largest core count — numa-cyc is 0 unless --sockets >= 2; \
         mixed/split are ws-adapt's kernel swaps and block splits, 0 under \
         every fixed scheduler; dram-lines vs oracle is achieved DRAM \
         traffic against the compulsory-traffic lower bound, ratio >= 1.0 \
         by construction)"
    );
    let _ = write!(s, "{:<10} {:<14}", "Matrix", "sched");
    for c in &cores {
        let col = format!("x{c}");
        let _ = write!(s, " {col:>7}");
    }
    let _ = writeln!(
        s,
        " {:>10} {:>8} {:>8} {:>10} {:>10} {:>6} {:>6} {:>11} {:>11} {:>6}",
        "imbalance", "llc-hit", "coh", "dram-q", "numa-cyc", "mixed", "split",
        "dram-lines", "oracle", "ratio"
    );
    let mut datasets: Vec<&str> = Vec::new();
    for p in points {
        if !datasets.contains(&p.dataset.as_str()) {
            datasets.push(&p.dataset);
        }
    }
    for d in datasets {
        for sched in Scheduler::ALL {
            // Skip schedulers the sweep did not run (older point sets).
            if !points.iter().any(|p| p.dataset == d && p.scheduler == Some(sched)) {
                continue;
            }
            let _ = write!(s, "{d:<10} {:<14}", sched.name());
            let mut worst_imb = 1.0f64;
            let mut biggest: Option<&ScalingPoint> = None;
            for &c in &cores {
                let pt = points.iter().find(|p| {
                    p.dataset == d
                        && p.cores == c
                        && (p.scheduler == Some(sched) || (c == 1 && p.scheduler.is_none()))
                });
                match pt {
                    Some(p) => {
                        worst_imb = worst_imb.max(p.imbalance);
                        if p.cores > 1 {
                            biggest = Some(p);
                        }
                        let _ = write!(s, " {:>7.2}", p.speedup);
                    }
                    None => {
                        let _ = write!(s, " {:>7}", "-");
                    }
                }
            }
            match biggest {
                Some(p) => {
                    let _ = writeln!(
                        s,
                        " {worst_imb:>9.2}x {:>7.1}% {:>8} {:>10.0} {:>10.0} {:>6} {:>6} \
                         {:>11} {:>11} {:>6.2}",
                        100.0 * p.llc_hit_rate,
                        p.coherence_events,
                        p.dram_queue_cycles,
                        p.remote_extra_cycles,
                        p.mixed_impl_blocks,
                        p.split_blocks,
                        p.achieved_dram_lines,
                        p.oracle_dram_lines,
                        p.oracle_ratio
                    );
                }
                None => {
                    let _ = writeln!(
                        s,
                        " {worst_imb:>9.2}x {:>8} {:>8} {:>10} {:>10} {:>6} {:>6} \
                         {:>11} {:>11} {:>6}",
                        "-", "-", "-", "-", "-", "-", "-", "-", "-"
                    );
                }
            }
        }
    }
    s
}

/// TSV series for the scaling study (`fig12.tsv`). Columns only ever get
/// appended (the NUMA pair landed after `dram_queue_cycles`; the ws-adapt
/// decision pair after `remote_extra_cycles`; the oracle triple after
/// `split_blocks`). Row ordering derives from `Scheduler::ALL` — the same
/// source as the text table — so a new scheduler cannot desynchronize the
/// two renderings.
pub fn fig12_tsv(points: &[ScalingPoint]) -> String {
    let mut t = String::from(
        "matrix\timpl\tsched\tcores\tcycles\tspeedup\timbalance\tllc_hit_rate\t\
         coherence_events\tdram_queue_cycles\tremote_fills\tremote_extra_cycles\t\
         mixed_impl_blocks\tsplit_blocks\tachieved_dram_lines\toracle_dram_lines\t\
         oracle_ratio\n",
    );
    let mut datasets: Vec<&str> = Vec::new();
    for p in points {
        if !datasets.contains(&p.dataset.as_str()) {
            datasets.push(&p.dataset);
        }
    }
    for d in datasets {
        let mut emit = |p: &ScalingPoint| {
            let _ = writeln!(
                t,
                "{}\t{}\t{}\t{}\t{:.1}\t{:.6}\t{:.6}\t{:.6}\t{}\t{:.1}\t{}\t{:.1}\t{}\t{}\t{}\t{}\t{:.6}",
                p.dataset,
                p.impl_id,
                p.scheduler.map(|s| s.name()).unwrap_or("serial"),
                p.cores,
                p.cycles,
                p.speedup,
                p.imbalance,
                p.llc_hit_rate,
                p.coherence_events,
                p.dram_queue_cycles,
                p.remote_fills,
                p.remote_extra_cycles,
                p.mixed_impl_blocks,
                p.split_blocks,
                p.achieved_dram_lines,
                p.oracle_dram_lines,
                p.oracle_ratio
            );
        };
        for p in points.iter().filter(|p| p.dataset == d && p.scheduler.is_none()) {
            emit(p);
        }
        for sched in Scheduler::ALL {
            for p in points.iter().filter(|p| p.dataset == d && p.scheduler == Some(sched)) {
                emit(p);
            }
        }
    }
    t
}

/// `spz mem`: the shared-memory picture of one job — per-core shared-LLC
/// traffic, queueing, coherence counters, sharing corrections, and DRAM
/// channel occupancy from the trace replay. Serial jobs report the private
/// hierarchy only (no replay ran).
pub fn mem_report(r: &crate::api::JobResult) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "Shared-memory report: {} on {} ({} core{}{})",
        r.impl_id.name(),
        r.dataset,
        r.cores,
        if r.cores == 1 { "" } else { "s" },
        r.sched
            .map(|sc| format!(", sched {}", sc.name()))
            .unwrap_or_default()
    );
    let m = &r.metrics.mem;
    let _ = writeln!(
        s,
        "private   | L1D {:.1}% of {} | L2 {} | shadow-LLC {} | DRAM {} | writebacks {}",
        100.0 * m.l1d_hit_rate(),
        m.l1d_accesses,
        m.l2_accesses,
        m.llc_accesses,
        m.dram_accesses,
        m.writebacks
    );
    let Some(mc) = &r.multicore else {
        let _ = writeln!(
            s,
            "(serial job: the shadow LLC is the LLC; run with --cores >= 2 \
             for the shared-memory replay)"
        );
        return s;
    };
    let _ = writeln!(
        s,
        "{:<5} {:>12} {:>9} {:>7} {:>6} {:>6} {:>6} {:>6} {:>6} {:>9} {:>9} {:>9} {:>10}",
        "core", "cycles", "llc_acc", "hit%", "fills", "demot", "upgr", "inv_rx", "fwd",
        "q_llc", "q_dram", "coh", "net_stall"
    );
    let mut rows: Vec<(String, &crate::sim::RunMetrics)> = mc
        .per_core
        .iter()
        .enumerate()
        .map(|(c, m)| (c.to_string(), m))
        .collect();
    rows.push(("all".to_string(), &mc.total));
    for (name, m) in rows {
        let sh = &m.shared;
        let _ = writeln!(
            s,
            "{:<5} {:>12.0} {:>9} {:>6.1}% {:>6} {:>6} {:>6} {:>6} {:>6} {:>9.0} {:>9.0} {:>9.0} {:>10.0}",
            name,
            m.cycles,
            sh.llc_accesses,
            100.0 * sh.llc_hit_rate(),
            sh.shared_fills,
            sh.demotions,
            sh.upgrades,
            sh.invalidations_received,
            sh.dirty_forwards,
            sh.llc_queue_cycles,
            sh.dram_queue_cycles,
            sh.coherence_cycles,
            sh.stall_cycles()
        );
    }
    let tot = &mc.total.shared;
    let _ = writeln!(
        s,
        "replay    | {} iteration{} (residual {:.1} cycles) | row-buffer: {} hits, {} misses, \
         {} conflicts ({:+.0} cycles)",
        tot.replay_iters,
        if tot.replay_iters == 1 { "" } else { "s" },
        tot.replay_residual,
        tot.row_hits,
        tot.row_misses,
        tot.row_conflicts,
        tot.row_extra_cycles
    );
    let _ = writeln!(
        s,
        "numa      | remote fills {}, remote forwards {}, remote extra {:+.0} cycles \
         (all zero at 1 socket)",
        tot.remote_fills, tot.remote_forwards, tot.remote_extra_cycles
    );
    let _ = writeln!(
        s,
        "trace     | {:.1} MB recorded, peak resident {} chunk{} ({} KB), {} spilled to disk",
        tot.trace_bytes_total as f64 / (1024.0 * 1024.0),
        tot.trace_peak_resident_chunks,
        if tot.trace_peak_resident_chunks == 1 { "" } else { "s" },
        tot.trace_peak_resident_chunks * 64,
        tot.spilled_chunks
    );
    let _ = writeln!(
        s,
        "oracle    | achieved {} DRAM lines vs compulsory-traffic bound {} \
         (ratio {:.2}x; >= 1.0 certifies the model moves at least the \
         unavoidable traffic)",
        tot.achieved_dram_lines,
        tot.oracle_dram_lines,
        tot.oracle_ratio()
    );
    if let Some(d) = &r.sched_decisions {
        let _ = writeln!(
            s,
            "ws-adapt  | {} blocks (scl-array {}, scl-hash {}, spz {}, other {}), \
             {} swapped, {} split | stalls predicted {:.0} vs achieved {:.0}",
            d.total_blocks,
            d.blocks_scl_array,
            d.blocks_scl_hash,
            d.blocks_spz,
            d.blocks_other,
            d.swapped_blocks,
            d.split_blocks,
            d.predicted_stall_cycles,
            d.achieved_stall_cycles
        );
    }
    let _ = writeln!(
        s,
        "critical path {:.0} cycles, efficiency {:.2}x, imbalance {:.2}x",
        mc.critical_path_cycles,
        mc.parallel_efficiency(),
        mc.imbalance()
    );
    if !mc.channel_busy_cycles.is_empty() {
        let _ = write!(s, "DRAM channels (busy cycles):");
        for (ch, b) in mc.channel_busy_cycles.iter().copied().enumerate() {
            let pct = if mc.critical_path_cycles > 0.0 {
                100.0 * b / mc.critical_path_cycles
            } else {
                0.0
            };
            let _ = write!(s, " ch{ch} {b:.0} ({pct:.1}%)");
        }
        let _ = writeln!(s);
    }
    s
}
