//! Multi-threaded sweep executor: builds each dataset once, computes the
//! reference product once, then fans (implementation x dataset) runs out to
//! a scoped thread pool. Simulations are independent (one `Machine` each),
//! so this parallelism does not perturb the simulated metrics.

use crate::config::SystemConfig;
use crate::coordinator::experiment::{run_one, ExperimentResult};
use crate::matrix::{registry, stats, Csr};
use crate::runtime::Engine;
use crate::spgemm;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;

/// Sweep configuration.
#[derive(Clone, Debug)]
pub struct SuiteConfig {
    /// Dataset names (default: all 14 of Table III).
    pub datasets: Vec<String>,
    /// Implementations (default: the five of Figure 8).
    pub impls: Vec<String>,
    /// Dataset scale in (0, 1].
    pub scale: f64,
    /// Worker threads.
    pub threads: usize,
    /// Verify every product against the reference oracle.
    pub verify: bool,
    pub engine: Engine,
    pub artifact_dir: PathBuf,
    /// Optional directory of real `.mtx` files overriding the synthetics.
    pub mtx_dir: Option<PathBuf>,
    pub sys: SystemConfig,
}

impl Default for SuiteConfig {
    fn default() -> Self {
        SuiteConfig {
            datasets: registry::DATASETS.iter().map(|d| d.name.to_string()).collect(),
            impls: spgemm::IMPL_NAMES.iter().map(|s| s.to_string()).collect(),
            scale: 1.0,
            threads: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4),
            verify: false,
            engine: Engine::Native,
            artifact_dir: crate::runtime::client::artifact_dir(),
            mtx_dir: None,
            sys: SystemConfig::default(),
        }
    }
}

/// All results of a sweep, with the per-dataset Table III characterization.
#[derive(Debug, Default)]
pub struct SuiteResult {
    pub results: Vec<ExperimentResult>,
    pub dataset_stats: HashMap<String, stats::MatrixStats>,
}

impl SuiteResult {
    pub fn get(&self, impl_name: &str, dataset: &str) -> Option<&ExperimentResult> {
        self.results
            .iter()
            .find(|r| r.impl_name == impl_name && r.dataset == dataset)
    }

    /// Speedup of `num` over `den` on `dataset` (cycles ratio).
    pub fn speedup(&self, num: &str, den: &str, dataset: &str) -> Option<f64> {
        let n = self.get(num, dataset)?;
        let d = self.get(den, dataset)?;
        Some(d.metrics.cycles / n.metrics.cycles)
    }
}

/// Build one dataset (synthetic stand-in or user-provided `.mtx`).
pub fn build_dataset(cfg: &SuiteConfig, name: &str) -> Result<Csr> {
    if let Some(dir) = &cfg.mtx_dir {
        let p = dir.join(format!("{name}.mtx"));
        if p.exists() {
            return crate::matrix::mm::read_mtx(&p);
        }
    }
    let d = registry::find(name).with_context(|| format!("unknown dataset '{name}'"))?;
    Ok(d.build(cfg.scale))
}

/// Run the full sweep.
pub fn run_suite(cfg: &SuiteConfig) -> Result<SuiteResult> {
    // Phase 1: build datasets (parallel across datasets).
    let built: Mutex<HashMap<String, (Csr, Option<Csr>)>> = Mutex::new(HashMap::new());
    let stats_map: Mutex<HashMap<String, stats::MatrixStats>> = Mutex::new(HashMap::new());
    let errs: Mutex<Vec<String>> = Mutex::new(Vec::new());

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for name in &cfg.datasets {
            let built = &built;
            let stats_map = &stats_map;
            let errs = &errs;
            handles.push(scope.spawn(move || {
                match build_dataset(cfg, name) {
                    Ok(a) => {
                        let st = stats::characterize(&a, 16);
                        let reference = if cfg.verify {
                            Some(spgemm::reference(&a, &a))
                        } else {
                            None
                        };
                        stats_map.lock().unwrap().insert(name.clone(), st);
                        built.lock().unwrap().insert(name.clone(), (a, reference));
                    }
                    Err(e) => errs.lock().unwrap().push(format!("{name}: {e:#}")),
                }
            }));
            // Bound build parallelism to the thread budget.
            if handles.len() >= cfg.threads {
                handles.drain(..).for_each(|h| h.join().unwrap());
            }
        }
        handles.drain(..).for_each(|h| h.join().unwrap());
    });
    let errv = errs.into_inner().unwrap();
    anyhow::ensure!(errv.is_empty(), "dataset build failures: {errv:?}");
    let built = built.into_inner().unwrap();

    // Phase 2: run the grid.
    let jobs: Vec<(String, String)> = cfg
        .datasets
        .iter()
        .flat_map(|d| cfg.impls.iter().map(move |i| (i.clone(), d.clone())))
        .collect();
    let results: Mutex<Vec<ExperimentResult>> = Mutex::new(Vec::new());
    let job_errs: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let next: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

    std::thread::scope(|scope| {
        for _ in 0..cfg.threads.max(1) {
            let jobs = &jobs;
            let built = &built;
            let results = &results;
            let job_errs = &job_errs;
            let next = &next;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let (impl_name, dataset) = &jobs[i];
                let (a, reference) = &built[dataset];
                match run_one(
                    impl_name,
                    dataset,
                    a,
                    cfg.sys,
                    cfg.engine,
                    &cfg.artifact_dir,
                    reference.as_ref(),
                ) {
                    Ok(r) => results.lock().unwrap().push(r),
                    Err(e) => job_errs
                        .lock()
                        .unwrap()
                        .push(format!("{impl_name}/{dataset}: {e:#}")),
                }
            });
        }
    });
    let errv = job_errs.into_inner().unwrap();
    anyhow::ensure!(errv.is_empty(), "experiment failures: {errv:?}");

    Ok(SuiteResult {
        results: results.into_inner().unwrap(),
        dataset_stats: stats_map.into_inner().unwrap(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_suite_runs_and_verifies() {
        let cfg = SuiteConfig {
            datasets: vec!["p2p".into(), "m133-b3".into()],
            impls: vec!["scl-hash".into(), "spz".into()],
            scale: 0.01,
            threads: 2,
            verify: true,
            ..Default::default()
        };
        let r = run_suite(&cfg).unwrap();
        assert_eq!(r.results.len(), 4);
        assert!(r.results.iter().all(|x| x.verified));
        assert!(r.speedup("spz", "scl-hash", "p2p").unwrap() > 0.0);
        assert!(r.dataset_stats.contains_key("m133-b3"));
    }
}
