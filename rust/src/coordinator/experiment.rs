//! One experiment = one implementation on one dataset (A * A), with
//! verification against the reference product.

use crate::config::SystemConfig;
use crate::matrix::Csr;
use crate::runtime::Engine;
use crate::sim::{Machine, RunMetrics};
use crate::spgemm::{self, SpGemm};
use anyhow::{ensure, Context, Result};
use std::path::Path;
use std::time::Instant;

/// Result of one simulated run.
#[derive(Clone, Debug)]
pub struct ExperimentResult {
    pub impl_name: String,
    pub dataset: String,
    pub metrics: RunMetrics,
    pub out_nnz: usize,
    pub verified: bool,
    /// Host wall-clock seconds for the simulation itself (§Perf data).
    pub wall_secs: f64,
    /// Block size chosen for vec-radix (after the sweep), if applicable.
    pub block_elems: Option<usize>,
}

/// Run `impl_name` on `a * a`, verifying the product against `reference`
/// when `verify` is set (skippable for the big sweeps; the integration
/// suite always verifies).
pub fn run_one(
    impl_name: &str,
    dataset: &str,
    a: &Csr,
    cfg: SystemConfig,
    engine: Engine,
    artifact_dir: &Path,
    verify: Option<&Csr>,
) -> Result<ExperimentResult> {
    let t0 = Instant::now();
    let mut block = None;

    let (metrics, product) = if impl_name == "vec-radix" {
        // The paper sweeps the ESC block size per matrix and reports the
        // best configuration (§V-B).
        let mut best: Option<(RunMetrics, Csr, usize)> = None;
        for be in [4 * 1024usize, 16 * 1024, 64 * 1024] {
            let mut m = Machine::new(cfg);
            let mut im = spgemm::vec_radix::VecRadix { block_elems: be };
            let c = im
                .multiply(&mut m, a, a)
                .with_context(|| format!("vec-radix block={be}"))?;
            let met = m.metrics();
            if best.as_ref().map(|(b, _, _)| met.cycles < b.cycles).unwrap_or(true) {
                best = Some((met, c, be));
            }
        }
        let (met, c, be) = best.unwrap();
        block = Some(be);
        (met, c)
    } else {
        let mut m = Machine::new(cfg);
        let mut im = spgemm::by_name(impl_name, engine, artifact_dir)?;
        let c = im
            .multiply(&mut m, a, a)
            .with_context(|| format!("{impl_name} on {dataset}"))?;
        (m.metrics(), c)
    };

    let verified = match verify {
        Some(r) => {
            ensure!(
                spgemm::same_product(&product, r, 1e-2),
                "{impl_name} on {dataset}: product mismatch ({} vs {} nnz)",
                product.nnz(),
                r.nnz()
            );
            true
        }
        None => false,
    };

    Ok(ExperimentResult {
        impl_name: impl_name.to_string(),
        dataset: dataset.to_string(),
        out_nnz: product.nnz(),
        metrics,
        verified,
        wall_secs: t0.elapsed().as_secs_f64(),
        block_elems: block,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::runtime::client::artifact_dir;

    #[test]
    fn run_one_verifies() {
        let a = gen::erdos_renyi(60, 60, 300, 81);
        let r = spgemm::reference(&a, &a);
        for name in spgemm::IMPL_NAMES {
            let res = run_one(
                name,
                "test",
                &a,
                SystemConfig::default(),
                Engine::Native,
                &artifact_dir(),
                Some(&r),
            )
            .unwrap();
            assert!(res.verified, "{name}");
            assert!(res.metrics.cycles > 0.0, "{name}");
            assert_eq!(res.out_nnz, r.nnz(), "{name}");
        }
    }

    #[test]
    fn vec_radix_reports_block() {
        let a = gen::erdos_renyi(60, 60, 300, 82);
        let res = run_one(
            "vec-radix",
            "test",
            &a,
            SystemConfig::default(),
            Engine::Native,
            &artifact_dir(),
            None,
        )
        .unwrap();
        assert!(res.block_elems.is_some());
    }
}
