//! `spz serve-demo`: exercise the [`crate::service`] subsystem end-to-end —
//! N tenant threads firing M jobs each at one [`SimService`] — and render a
//! fairness/throughput report.
//!
//! The rendered report is **deterministic** (the CI determinism gate
//! byte-diffs it across runs): it carries only admission counters, per-tenant
//! served counts/shares, simulated cycles, and the bit-identity verdict.
//! Wall-clock throughput and the queue/slot high-water marks depend on host
//! scheduling and go to stderr instead.

use crate::api::{JobSpec, Session, SessionConfig};
use crate::service::{Backpressure, QueueFull, SimService, SimServiceConfig};
use anyhow::{ensure, Result};
use std::fmt::Write as _;

/// Knobs of one serve-demo run (argv-parsed by `spz`, defaulted for CI).
pub struct DemoConfig {
    /// Number of tenant submitter threads.
    pub tenants: usize,
    /// Jobs each tenant submits.
    pub jobs: usize,
    /// Worker-pool budget in core-slots.
    pub workers: usize,
    /// Pending-queue bound.
    pub depth: usize,
    /// Admission behaviour when the queue is full.
    pub backpressure: Backpressure,
    /// Per-tenant weights, cycled over tenants (`t0` gets `weights[0]`, ...).
    pub weights: Vec<u32>,
    /// The job every tenant submits (identical on purpose: it makes the
    /// bit-identity contract checkable across every completion).
    pub job: JobSpec,
}

/// Run the demo and render the deterministic report. `session_cfg` seeds
/// both the serving session and the fresh single-job session the
/// bit-identity check runs against.
pub fn serve_demo(session_cfg: SessionConfig, demo: &DemoConfig) -> Result<String> {
    ensure!(demo.tenants >= 1, "serve-demo needs at least 1 tenant (got {})", demo.tenants);
    ensure!(demo.jobs >= 1, "serve-demo needs at least 1 job per tenant (got {})", demo.jobs);
    ensure!(!demo.weights.is_empty(), "serve-demo needs at least one tenant weight");

    // The ground truth: the same spec through a fresh session, no service.
    let expected = Session::with_config(session_cfg.clone())
        .run(&demo.job)?
        .to_json_stable();

    let svc = SimService::start(
        Session::with_config(session_cfg),
        SimServiceConfig {
            workers: demo.workers,
            queue_depth: demo.depth,
            backpressure: demo.backpressure,
            tenant_weights: (0..demo.tenants)
                .map(|i| (format!("t{i}"), demo.weights[i % demo.weights.len()]))
                .collect(),
            ..SimServiceConfig::default()
        },
    )?;

    let t0 = std::time::Instant::now();
    // One submitter thread per tenant, all slamming the queue concurrently.
    // Each returns (ok results' stable JSON matches, served, rejected,
    // sum of simulated cycles).
    let per_tenant: Vec<(u64, u64, u64, f64)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..demo.tenants)
            .map(|i| {
                let svc = &svc;
                let expected = expected.as_str();
                let job = &demo.job;
                scope.spawn(move || {
                    let tenant = format!("t{i}");
                    let mut pending = Vec::with_capacity(demo.jobs);
                    let mut rejected = 0u64;
                    for _ in 0..demo.jobs {
                        match svc.submit(&tenant, job.clone()) {
                            Ok(h) => pending.push(h),
                            Err(e) if e.downcast_ref::<QueueFull>().is_some() => rejected += 1,
                            Err(e) => return Err(e),
                        }
                    }
                    let mut identical = 0u64;
                    let mut served = 0u64;
                    let mut cycles = 0.0f64;
                    for h in pending {
                        let r = h.wait()?;
                        served += 1;
                        cycles += r.time_cycles();
                        if r.to_json_stable() == expected {
                            identical += 1;
                        }
                    }
                    Ok((identical, served, rejected, cycles))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("tenant thread panicked"))
            .collect::<Result<_>>()
    })?;
    let wall = t0.elapsed().as_secs_f64();

    let stats = svc.stats();
    let total: u64 = per_tenant.iter().map(|t| t.1).sum();
    let identical: u64 = per_tenant.iter().map(|t| t.0).sum();

    // Host-dependent numbers stay off the byte-diffed report.
    eprintln!(
        "[spz] serve-demo: {total} jobs in {wall:.2}s ({:.0} jobs/s), queue high-water {}, \
         slots high-water {}/{}",
        total as f64 / wall.max(1e-9),
        stats.queue_depth_high_water,
        stats.slots_high_water,
        stats.workers
    );

    let mut s = String::new();
    let _ = writeln!(
        s,
        "spz serve-demo: {} tenants x {} jobs (workers={} depth={} backpressure={})",
        demo.tenants,
        demo.jobs,
        demo.workers,
        demo.depth,
        match demo.backpressure {
            Backpressure::Reject => "reject",
            Backpressure::Block => "block",
        }
    );
    let _ = writeln!(
        s,
        "job: impl={} dataset={} scale={} cores={}",
        demo.job.impl_id.name(),
        demo.job.dataset.name(),
        demo.job.scale,
        demo.job.cores
    );
    let _ = writeln!(
        s,
        "service: admitted={} rejected={} completed={} failed={}",
        stats.admitted, stats.rejected, stats.completed, stats.failed
    );
    let _ = writeln!(s, "{:<8} {:>6} {:>6} {:>7} {:>14}", "tenant", "weight", "served", "share", "sum_cycles");
    for (i, (_, served, _, cycles)) in per_tenant.iter().enumerate() {
        let row = stats.tenants.iter().find(|t| t.tenant == format!("t{i}"));
        let _ = writeln!(
            s,
            "{:<8} {:>6} {:>6} {:>6.1}% {:>14.0}",
            format!("t{i}"),
            row.map(|t| t.weight).unwrap_or(0),
            served,
            100.0 * *served as f64 / total.max(1) as f64,
            cycles
        );
    }
    let _ = writeln!(
        s,
        "determinism: {identical}/{total} results byte-identical to a direct Session::run"
    );
    ensure!(
        identical == total,
        "service determinism violated: only {identical}/{total} results matched the direct run"
    );
    Ok(s)
}
