//! Rendering layer over the [`crate::api`] experiment pipeline: regenerates
//! every table and figure of the paper's evaluation (Tables I–IV,
//! Figures 8–11) from a [`crate::api::SuiteRun`], plus the ablation sweeps.
//!
//! Experiment *execution* lives in [`crate::api`] ([`crate::api::Session`],
//! [`crate::api::JobSpec`], [`crate::api::SuiteSpec`]); this module only
//! turns results into reports.

pub mod ablate;
pub mod demo;
pub mod figures;
pub mod report;
