//! Experiment coordinator: runs (implementation x dataset) grids on worker
//! threads, collects [`crate::sim::RunMetrics`], and regenerates every table
//! and figure of the paper's evaluation (Tables I–IV, Figures 8–11).

pub mod experiment;
pub mod figures;
pub mod report;
pub mod runner;

pub use experiment::{run_one, ExperimentResult};
pub use runner::{run_suite, SuiteConfig, SuiteResult};
pub mod ablate;
