//! Ablation studies over the design choices DESIGN.md calls out:
//!
//! * systolic array size N (chunk size = stream count per group): the
//!   paper's future-work question of wider matrix registers;
//! * non-speculative issue overhead of sort/zip pairs;
//! * the vec-radix ESC block-size sweep (the paper's own tuning knob).

use crate::config::SystemConfig;
use crate::matrix::Csr;
use crate::runtime::NativeEngine;
use crate::sim::Machine;
use crate::spgemm::{self, SpGemm};
use anyhow::Result;

/// One ablation point.
#[derive(Clone, Debug)]
pub struct AblationPoint {
    pub label: String,
    pub cycles: f64,
    pub kv_pairs: u64,
    pub l1d_accesses: u64,
}

/// Sweep the systolic array size for spz (N = 4..64). Larger arrays merge
/// longer chunks per instruction but waste occupancy on short streams.
pub fn array_size_sweep(a: &Csr, sizes: &[usize]) -> Result<Vec<AblationPoint>> {
    let mut out = Vec::new();
    for &n in sizes {
        let mut cfg = SystemConfig::default();
        cfg.unit.n = n;
        let mut m = Machine::new(cfg);
        let mut im = spgemm::spz::Spz::with_engine(Box::new(NativeEngine::new(n)));
        let c = im.multiply(&mut m, a, a)?;
        let r = m.metrics();
        anyhow::ensure!(c.validate().is_ok());
        out.push(AblationPoint {
            label: format!("N={n}"),
            cycles: r.cycles,
            kv_pairs: r.total_matrix_kv_pairs(),
            l1d_accesses: r.mem.l1d_accesses,
        });
    }
    Ok(out)
}

/// Sweep the non-speculative issue overhead (how much the ROB-head
/// serialization of §V-A costs end to end).
pub fn issue_overhead_sweep(a: &Csr, overheads: &[u32]) -> Result<Vec<AblationPoint>> {
    let mut out = Vec::new();
    for &ov in overheads {
        let mut cfg = SystemConfig::default();
        cfg.unit.issue_overhead = ov;
        let mut m = Machine::new(cfg);
        let mut im = spgemm::spz::Spz::native();
        im.multiply(&mut m, a, a)?;
        let r = m.metrics();
        out.push(AblationPoint {
            label: format!("issue+{ov}"),
            cycles: r.cycles,
            kv_pairs: r.total_matrix_kv_pairs(),
            l1d_accesses: r.mem.l1d_accesses,
        });
    }
    Ok(out)
}

/// Sweep the vec-radix block size explicitly (paper §V-B).
pub fn block_size_sweep(a: &Csr, blocks: &[usize]) -> Result<Vec<AblationPoint>> {
    let mut out = Vec::new();
    for &be in blocks {
        let mut m = Machine::new(SystemConfig::default());
        let mut im = spgemm::vec_radix::VecRadix { block_elems: be };
        im.multiply(&mut m, a, a)?;
        let r = m.metrics();
        out.push(AblationPoint {
            label: format!("block={be}"),
            cycles: r.cycles,
            kv_pairs: 0,
            l1d_accesses: r.mem.l1d_accesses,
        });
    }
    Ok(out)
}

/// Render a sweep as an aligned table.
pub fn render(title: &str, points: &[AblationPoint]) -> String {
    let mut s = format!("{title}\n");
    let best = points
        .iter()
        .map(|p| p.cycles)
        .fold(f64::INFINITY, f64::min);
    for p in points {
        s.push_str(&format!(
            "  {:<12} {:>14.0} cycles ({:>5.2}x best)  {:>10} kv-pairs  {:>12} L1D\n",
            p.label,
            p.cycles,
            p.cycles / best,
            p.kv_pairs,
            p.l1d_accesses
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;

    #[test]
    fn array_size_sweep_runs_and_shrinks_pairs() {
        let a = gen::powerlaw_clustered(300, 2400, 1.0, 0.4, 9);
        let pts = array_size_sweep(&a, &[8, 16, 32]).unwrap();
        assert_eq!(pts.len(), 3);
        // Bigger arrays need fewer k/v pairs (more elements per pair).
        assert!(pts[2].kv_pairs < pts[0].kv_pairs);
    }

    #[test]
    fn issue_overhead_monotone() {
        let a = gen::powerlaw_clustered(200, 1600, 1.0, 0.4, 10);
        let pts = issue_overhead_sweep(&a, &[0, 16, 64]).unwrap();
        assert!(pts[0].cycles < pts[2].cycles);
    }

    #[test]
    fn render_has_rows() {
        let a = gen::erdos_renyi(100, 100, 500, 11);
        let pts = block_size_sweep(&a, &[256, 4096]).unwrap();
        let s = render("t", &pts);
        assert!(s.contains("block=256"));
    }
}
