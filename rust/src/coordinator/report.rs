//! Report writing: persists rendered tables/figures and TSV series under a
//! reports/ directory, and appends run records for EXPERIMENTS.md.

use anyhow::{Context, Result};
use std::path::Path;

/// Write a rendered artifact (and echo it to stdout).
pub fn emit(out_dir: &Path, name: &str, content: &str, quiet: bool) -> Result<()> {
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("create {}", out_dir.display()))?;
    let p = out_dir.join(name);
    std::fs::write(&p, content).with_context(|| format!("write {}", p.display()))?;
    if !quiet {
        println!("{content}");
        println!("[written to {}]", p.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emit_writes_file() {
        let dir = std::env::temp_dir().join(format!("spz_report_{}", std::process::id()));
        emit(&dir, "t.txt", "hello", true).unwrap();
        assert_eq!(std::fs::read_to_string(dir.join("t.txt")).unwrap(), "hello");
        std::fs::remove_dir_all(&dir).ok();
    }
}
