//! Line-granular shared-memory access traces (phase 1 of the two-phase
//! shared-memory model).
//!
//! The shared LLC and DRAM channels are *time-shared* resources: what they
//! cost a core depends on what every other core is doing at the same moment.
//! Simulating them inline would make per-core results depend on host thread
//! interleaving and break the bit-reproducibility invariant the parallel
//! driver pins (see `spgemm::parallel`). Instead the model is two-phase
//! **trace-and-replay**:
//!
//! 1. During parallel execution, each core's [`crate::mem::Hierarchy`]
//!    records a compact trace of every access that leaves its private L1/L2
//!    — demand fills walking down into the LLC and dirty L2 victims written
//!    back into it — stamped with the core's *local logical time* (its own
//!    simulated cycle count) and the Figure 9 phase it charged into.
//!    Private L1/L2 results are final in this phase.
//! 2. After the workers join, a deterministic interleaver merges the
//!    per-core traces in canonical logical-time order and replays them
//!    through the shared LLC + multi-channel DRAM model
//!    ([`crate::mem::shared::ReplayEngine`]), producing per-core
//!    shared-memory stall cycles and coherence counters that are a pure
//!    function of the traces — independent of host scheduling.
//!
//! The trade-off is explicit: phase 1 prices each core's private-hierarchy
//! latency against its own *shadow* copy of the LLC, so cross-core effects
//! on private-cache contents (a line another core invalidated, say) are
//! folded in as replay-derived stall corrections rather than re-executed.
//!
//! ## Storage format
//!
//! Multi-core jobs on large matrices record tens of millions of events per
//! core, so the in-memory format matters. Each [`TraceEvent`] is a packed
//! 16-byte record: the line id and all flag bits (kind, write intent, shadow
//! outcome, bandwidth attribution, phase) share one `u64`, and the local
//! timestamp is a 48-bit *delta* from the previous event of the same core in
//! 1/64-cycle fixed point. [`TraceBuf`] stores events in fixed-size chunks
//! (no doubling reallocation, so peak memory stays within one chunk of the
//! live data) and decodes absolute times by sequential accumulation.
//!
//! ## Streaming (bounded-memory handoff to the replay)
//!
//! Materializing whole traces makes peak memory O(events) and serializes
//! the pipeline behind the slowest kernel core. [`TraceStream`] is the
//! bounded alternative: the producing core's [`TraceWriter`] seals events
//! into the same fixed-size chunks and publishes each sealed chunk
//! immediately, while any number of independent [`TraceReader`]s (the
//! replay's shard and merge walks) consume `(time, event)` pairs in program
//! order, blocking only until the chunk they need is sealed. When a ring
//! budget is set (`SharedMemConfig::trace_ring_chunks`), sealing past the
//! budget transparently evicts the oldest resident chunk to an unlinked
//! temp file as raw 16-byte little-endian records; readers demand-load
//! spilled chunks back through the stream's free list. The producer never
//! blocks, eviction happens only at seal time, and the resident/spill
//! accounting is producer-side only — so `peak_resident`/`spilled` are a
//! pure function of the seal sequence (deterministic, and `peak_resident
//! <= ring` by construction) no matter how consumers are scheduled. Sealed
//! chunks are never mutated and stay addressable (resident or spilled) for
//! the engine's later corrective passes, which re-read the stream from the
//! start through fresh readers.

/// Upper bound on [`TraceEvent::phase`] values ( >= the machine model's
/// `NUM_PHASES`; replay buckets stalls per phase in arrays of this size).
pub const MAX_PHASES: usize = 8;

/// Events per [`TraceBuf`] chunk (64KB of packed events per chunk).
pub const TRACE_CHUNK: usize = 4096;

/// Fixed-point shift for trace time deltas: 1/64-cycle resolution, so the
/// 48-bit delta spans ~4.4 trillion cycles between consecutive LLC-level
/// events of one core. A `u32` delta used to saturate silently here at ~67M
/// cycles — enough for a long service-queue wait between a job's phases to
/// quietly compress, corrupting the canonical merge order with no signal —
/// so gaps beyond the (absurd) 48-bit span are now a hard error, not a
/// clamp.
const TIME_SHIFT: u32 = 6;
const TIME_SCALE: f64 = (1u64 << TIME_SHIFT) as f64;
/// Max representable quantized delta (48 bits: `dt` low word + `dt_hi`).
const MAX_DT: u64 = (1u64 << 48) - 1;

/// What a traced LLC-level access was doing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    /// Demand fill: an access that missed the private L1 and L2 and walked
    /// down into the LLC.
    Demand,
    /// A dirty L2 victim installed into the LLC (write-back path). Latency
    /// is hidden by the write buffer, but the install still updates LLC
    /// state and occupies the shared tag pipeline.
    Writeback,
}

// Bit layout of `TraceEvent::bits`: the low 53 bits hold the line address,
// the next 4 the requesting core's socket id, the top 7 the flags. Line
// addresses are `byte_addr >> 6`; the simulated address space tops out at
// the shared-operand region (2^56 + epsilon), so lines fit in ~51 bits with
// room to spare even after ceding 4 bits to the socket id.
const LINE_BITS: u32 = 53;
const LINE_MASK: u64 = (1u64 << LINE_BITS) - 1;
const SOCKET_SHIFT: u32 = 53;
const SOCKET_MASK: u64 = (crate::config::MAX_SOCKETS as u64) - 1;
const KIND_BIT: u64 = 1 << 57;
const WRITE_BIT: u64 = 1 << 58;
const SHADOW_BIT: u64 = 1 << 59;
const PAID_BIT: u64 = 1 << 60;
const PHASE_SHIFT: u32 = 61;

/// One line-granular access that left a core's private L1/L2, packed into
/// 16 bytes (see the module docs for the layout). Construct with
/// [`TraceEvent::new`]; the local timestamp lives in the owning
/// [`TraceBuf`]'s delta stream, not in the event itself.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    bits: u64,
    /// Low 32 bits of the time delta to the previous event of the same
    /// trace, 1/64-cycle fixed point (filled in by [`TraceBuf::push`]).
    dt: u32,
    /// High 16 bits of the delta (48 bits total; the padding the old
    /// u32-delta layout wasted anyway, put to work).
    dt_hi: u16,
}

// The whole point of the packed layout: one event is 16 bytes, not the ~32
// of the naive struct-of-fields encoding.
const _: () = assert!(std::mem::size_of::<TraceEvent>() == 16);
const _: () = assert!(MAX_PHASES <= (1usize << (64 - PHASE_SHIFT as usize)));
// The socket field must fill its 4 bits exactly and sit flush against the
// kind bit.
const _: () = assert!(crate::config::MAX_SOCKETS == 16);
const _: () = assert!(SOCKET_SHIFT + 4 == 57);

impl TraceEvent {
    /// Pack an event (timestamp is assigned by [`TraceBuf::push`]). The
    /// requesting core's socket id defaults to 0 (single-socket / flat);
    /// stamp it with [`TraceEvent::with_socket`].
    pub fn new(
        line: u64,
        kind: TraceKind,
        write: bool,
        shadow_hit: bool,
        paid_bw: bool,
        phase: u8,
    ) -> TraceEvent {
        debug_assert!(line <= LINE_MASK, "line id overflows the packed layout");
        debug_assert!((phase as usize) < MAX_PHASES);
        let mut bits = line & LINE_MASK;
        if kind == TraceKind::Writeback {
            bits |= KIND_BIT;
        }
        if write {
            bits |= WRITE_BIT;
        }
        if shadow_hit {
            bits |= SHADOW_BIT;
        }
        if paid_bw {
            bits |= PAID_BIT;
        }
        bits |= ((phase as u64) & (MAX_PHASES as u64 - 1)) << PHASE_SHIFT;
        TraceEvent { bits, dt: 0, dt_hi: 0 }
    }

    /// Stamp the requesting core's socket id (`< MAX_SOCKETS`): the replay
    /// prices each event's NUMA distance from this, so traces stay
    /// self-describing (no side-channel core-to-socket table).
    #[inline]
    pub fn with_socket(mut self, socket: u8) -> TraceEvent {
        debug_assert!((socket as usize) < crate::config::MAX_SOCKETS);
        self.bits = (self.bits & !(SOCKET_MASK << SOCKET_SHIFT))
            | (((socket as u64) & SOCKET_MASK) << SOCKET_SHIFT);
        self
    }

    /// Socket of the requesting core (0 for single-socket traces).
    #[inline]
    pub fn socket(self) -> u8 {
        ((self.bits >> SOCKET_SHIFT) & SOCKET_MASK) as u8
    }

    /// Line address (byte address `>> line_shift`).
    #[inline]
    pub fn line(self) -> u64 {
        self.bits & LINE_MASK
    }

    #[inline]
    pub fn kind(self) -> TraceKind {
        if self.bits & KIND_BIT != 0 {
            TraceKind::Writeback
        } else {
            TraceKind::Demand
        }
    }

    /// Demand intent: `true` for stores (drives the MESI-lite upgrade /
    /// invalidation bookkeeping). Always `true` for writeback installs.
    #[inline]
    pub fn write(self) -> bool {
        self.bits & WRITE_BIT != 0
    }

    /// Phase-1 outcome in the core's private *shadow* LLC. The replay
    /// compares this prediction against the real shared-LLC outcome to
    /// price constructive sharing (shadow miss, shared hit) and destructive
    /// interference (shadow hit, shared miss).
    #[inline]
    pub fn shadow_hit(self) -> bool {
        self.bits & SHADOW_BIT != 0
    }

    /// Whether phase 1 actually charged the DRAM bandwidth floor for this
    /// access. False for shadow hits, for stream-prefetched accesses (whose
    /// raw latency was clamped to an L1 hit, so `dram_bw` saw no DRAM
    /// latency), and for writeback installs. The replay refunds the floor on
    /// constructive sharing only when it was really paid.
    #[inline]
    pub fn paid_bw(self) -> bool {
        self.bits & PAID_BIT != 0
    }

    /// Figure 9 breakdown phase the access charged into (`< MAX_PHASES`),
    /// so replay-derived stalls land in the same per-phase buckets.
    #[inline]
    pub fn phase(self) -> u8 {
        (self.bits >> PHASE_SHIFT) as u8
    }

    /// The encoder-filled 48-bit quantized time delta to the previous event
    /// of the same trace (decode support for the replay's cursors).
    #[inline]
    pub(crate) fn dt_q(self) -> u64 {
        self.dt as u64 | ((self.dt_hi as u64) << 32)
    }
}

/// Absolute time from an accumulated quantized timestamp. This is *the*
/// decode expression: every consumer ([`TraceBuf::iter_timed`], the replay
/// engine's buffer and stream cursors) must share it so decoded times — and
/// therefore the canonical merge order and every `f64` accumulation — are
/// bit-identical across trace stores.
#[inline]
pub(crate) fn decode_time(acc_q: u64) -> f64 {
    acc_q as f64 / TIME_SCALE
}

/// Quantize one core-local timestamp and delta-encode it against the
/// encoder state `last_q`, returning the split 48-bit delta. Shared by
/// [`TraceBuf::push`] and [`TraceWriter::push`] so the two stores can never
/// drift apart. Local times are monotone per core; a backwards stamp
/// saturates to the previous time (the clock can stall but never run in
/// reverse). A *forward* gap past the 48-bit span, by contrast, cannot be
/// represented — clamping it would silently reorder this core's events
/// against every other core's in the canonical merge, so it fails loudly
/// instead.
fn encode_delta(last_q: &mut u64, time: f64) -> (u32, u16) {
    let q = (time * TIME_SCALE).max(0.0) as u64;
    let dt = q.saturating_sub(*last_q);
    assert!(
        dt <= MAX_DT,
        "trace time gap of {dt} quantized units overflows the 48-bit \
         delta encoding (~4.4e12 cycles between consecutive events)"
    );
    *last_q += dt;
    (dt as u32, (dt >> 32) as u16)
}

/// A core's recorded trace: packed events in fixed-size chunks plus the
/// delta-encoded local timestamps. Absolute times are recovered by
/// sequential accumulation ([`TraceBuf::iter_timed`]); random access to the
/// packed fields (not times) goes through [`TraceBuf::get`].
#[derive(Clone, Debug, Default)]
pub struct TraceBuf {
    chunks: Vec<Vec<TraceEvent>>,
    len: usize,
    /// Quantized timestamp of the last pushed event (encoder state; kept in
    /// quantized units so encode and decode can never drift apart).
    last_q: u64,
    /// Chunk buffers recycled by [`TraceBuf::clear`]: a cleared-and-refilled
    /// buffer (the pilot replays and iterative passes clear traces between
    /// uses) reuses its old chunks instead of reallocating one 64KB block
    /// per [`TRACE_CHUNK`] events.
    free: Vec<Vec<TraceEvent>>,
}

impl TraceBuf {
    pub fn new() -> TraceBuf {
        TraceBuf::default()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append an event issued at core-local `time` (simulated cycles,
    /// monotone per core; quantized to 1/64-cycle deltas — see
    /// [`encode_delta`] for the saturation/overflow contract).
    pub fn push(&mut self, mut e: TraceEvent, time: f64) {
        let (dt, dt_hi) = encode_delta(&mut self.last_q, time);
        e.dt = dt;
        e.dt_hi = dt_hi;
        if self.chunks.last().map(|c| c.len() >= TRACE_CHUNK).unwrap_or(true) {
            let chunk = self
                .free
                .pop()
                .unwrap_or_else(|| Vec::with_capacity(TRACE_CHUNK));
            self.chunks.push(chunk);
        }
        self.chunks.last_mut().unwrap().push(e);
        self.len += 1;
    }

    /// Random access to the packed event fields (times require the
    /// sequential decoder, [`TraceBuf::iter_timed`]).
    #[inline]
    pub fn get(&self, i: usize) -> TraceEvent {
        self.chunks[i / TRACE_CHUNK][i % TRACE_CHUNK]
    }

    /// Iterate `(absolute_time, event)` pairs, decoding the delta stream.
    pub fn iter_timed(&self) -> impl Iterator<Item = (f64, TraceEvent)> + '_ {
        let mut acc = 0u64;
        self.chunks.iter().flatten().map(move |&e| {
            acc += e.dt_q();
            (decode_time(acc), e)
        })
    }

    /// Iterate the packed events without decoding times.
    pub fn iter(&self) -> impl Iterator<Item = TraceEvent> + '_ {
        self.chunks.iter().flatten().copied()
    }

    /// Drop all recorded events (encoder time state resets too). The chunk
    /// buffers are kept on a free list for reuse by later pushes.
    pub fn clear(&mut self) {
        for mut c in self.chunks.drain(..) {
            c.clear();
            self.free.push(c);
        }
        self.len = 0;
        self.last_q = 0;
    }

    /// Test/builder convenience: a buffer from `(time, event)` pairs.
    pub fn from_events<I: IntoIterator<Item = (f64, TraceEvent)>>(events: I) -> TraceBuf {
        let mut b = TraceBuf::new();
        for (t, e) in events {
            b.push(e, t);
        }
        b
    }
}

// ---------------------------------------------------------------------------
// Streaming: bounded-memory chunk handoff with spill-to-disk
// ---------------------------------------------------------------------------

/// Serialized size of one packed event in the spill file: the in-memory 16
/// bytes made explicit-endian (`u64` bits, `u32` dt, `u16` dt_hi, `u16`
/// zero pad), all little-endian.
const SPILL_EVENT_BYTES: usize = 16;

/// Encode a sealed chunk as raw 16-byte little-endian spill records into
/// `out` (cleared first).
fn encode_chunk(events: &[TraceEvent], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(events.len() * SPILL_EVENT_BYTES);
    for e in events {
        out.extend_from_slice(&e.bits.to_le_bytes());
        out.extend_from_slice(&e.dt.to_le_bytes());
        out.extend_from_slice(&e.dt_hi.to_le_bytes());
        out.extend_from_slice(&[0u8; 2]);
    }
}

/// Decode spill records back into packed events in `out` (cleared first).
/// Exact inverse of [`encode_chunk`]: the delta stream round-trips bit for
/// bit, so a spilled chunk replays identically to a resident one.
fn decode_chunk(bytes: &[u8], out: &mut Vec<TraceEvent>) {
    debug_assert_eq!(bytes.len() % SPILL_EVENT_BYTES, 0);
    out.clear();
    out.reserve(bytes.len() / SPILL_EVENT_BYTES);
    for rec in bytes.chunks_exact(SPILL_EVENT_BYTES) {
        out.push(TraceEvent {
            bits: u64::from_le_bytes(rec[0..8].try_into().unwrap()),
            dt: u32::from_le_bytes(rec[8..12].try_into().unwrap()),
            dt_hi: u16::from_le_bytes(rec[12..14].try_into().unwrap()),
        });
    }
}

/// A fresh spill file in the system temp directory, unlinked as soon as it
/// is created so the storage can never outlive the process (the open handle
/// keeps it alive; the name exists only long enough to create it).
fn open_spill_file() -> std::fs::File {
    use std::sync::atomic::{AtomicU64, Ordering};
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    loop {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir()
            .join(format!("spz-trace-{}-{n}.spill", std::process::id()));
        match std::fs::OpenOptions::new()
            .create_new(true)
            .read(true)
            .write(true)
            .open(&path)
        {
            Ok(file) => {
                let _ = std::fs::remove_file(&path);
                return file;
            }
            // A stale name from a crashed run with a recycled pid: try the
            // next counter value.
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => continue,
            Err(e) => panic!("cannot create trace spill file {}: {e}", path.display()),
        }
    }
}

/// Footprint accounting for one stream (see [`TraceStream::stats`]). The
/// byte total is ring-independent; the peak and spill counts are a pure
/// function of the seal sequence under the configured ring budget.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceStreamStats {
    /// Total packed event bytes this stream carried (16 per event).
    pub bytes_total: u64,
    /// Peak sealed chunks resident in memory at once (`<=` the ring budget
    /// whenever one is set).
    pub peak_resident_chunks: u64,
    /// Sealed chunks evicted to the spill file.
    pub spilled_chunks: u64,
}

/// One sealed chunk's location in the stream's store.
enum ChunkSlot {
    /// Resident in memory; readers share it by `Arc` clone (sealed chunks
    /// are immutable).
    Resident(std::sync::Arc<Vec<TraceEvent>>),
    /// Evicted to the spill file: `len` events starting at byte `off`.
    Spilled { off: u64, len: u32 },
}

/// Mutex-guarded store behind one [`TraceStream`].
struct StreamState {
    chunks: Vec<ChunkSlot>,
    /// Total events sealed so far.
    len: u64,
    /// The producer finished (its partial final chunk, if any, is sealed).
    finished: bool,
    /// Sealed chunks currently resident. Producer-side accounting only:
    /// readers never touch it, so `peak_resident`/`spilled` cannot depend
    /// on consumer scheduling.
    resident: usize,
    peak_resident: usize,
    spilled: u64,
    /// Index of the oldest chunk not yet considered for eviction.
    spill_cursor: usize,
    /// Lazily created, already-unlinked spill file.
    spill: Option<std::fs::File>,
    /// Bytes written to the spill file so far (the next chunk's offset).
    spill_len: u64,
    /// Scratch byte buffer for spill encode/decode (reused under the lock).
    spill_buf: Vec<u8>,
    /// Cleared chunk buffers recycled between the writer's seals, evicted
    /// chunks, and readers' demand-loads.
    free: Vec<Vec<TraceEvent>>,
}

impl StreamState {
    /// Evict the oldest resident sealed chunk to the spill file. Called at
    /// seal time when the ring is full; the 64KB write happens under the
    /// state lock, which is what keeps the eviction and its accounting one
    /// atomic, deterministic step.
    fn spill_oldest(&mut self) {
        while self.spill_cursor < self.chunks.len() {
            let idx = self.spill_cursor;
            self.spill_cursor += 1;
            if !matches!(self.chunks[idx], ChunkSlot::Resident(_)) {
                continue;
            }
            let off = self.spill_len;
            let mut bytes = std::mem::take(&mut self.spill_buf);
            let len;
            {
                use std::io::{Seek, SeekFrom, Write};
                let ChunkSlot::Resident(arc) = &self.chunks[idx] else {
                    unreachable!()
                };
                len = arc.len() as u32;
                encode_chunk(arc, &mut bytes);
                let file = self.spill.get_or_insert_with(open_spill_file);
                file.seek(SeekFrom::Start(off)).expect("trace spill seek failed");
                file.write_all(&bytes).expect("trace spill write failed");
            }
            self.spill_len += bytes.len() as u64;
            bytes.clear();
            self.spill_buf = bytes;
            let old = std::mem::replace(&mut self.chunks[idx], ChunkSlot::Spilled { off, len });
            if let ChunkSlot::Resident(arc) = old {
                // Recycle the buffer unless a reader still holds it.
                if let Ok(mut v) = std::sync::Arc::try_unwrap(arc) {
                    v.clear();
                    self.free.push(v);
                }
            }
            self.resident -= 1;
            self.spilled += 1;
            return;
        }
        unreachable!("spill_oldest called with no resident chunk in the ring");
    }

    /// Read one spilled chunk back into a (recycled) event buffer.
    fn load_spilled(&mut self, off: u64, len: u32) -> Vec<TraceEvent> {
        use std::io::{Read, Seek, SeekFrom};
        let mut bytes = std::mem::take(&mut self.spill_buf);
        bytes.resize(len as usize * SPILL_EVENT_BYTES, 0);
        let file = self.spill.as_mut().expect("spilled chunk without a spill file");
        file.seek(SeekFrom::Start(off)).expect("trace spill seek failed");
        file.read_exact(&mut bytes).expect("trace spill read failed");
        let mut v = self.free.pop().unwrap_or_default();
        decode_chunk(&bytes, &mut v);
        bytes.clear();
        self.spill_buf = bytes;
        v
    }
}

struct StreamShared {
    state: std::sync::Mutex<StreamState>,
    cv: std::sync::Condvar,
    /// Ring budget in sealed chunks (0 = unbounded: nothing ever spills).
    ring: usize,
}

/// The consumer-side handle of one core's streaming trace (see the module
/// docs): a store of sealed immutable chunks that [`TraceReader`]s walk in
/// program order while the producing [`TraceWriter`] is still appending.
/// Cheap to share by reference; re-readable any number of times (the
/// replay's corrective passes re-walk it from the start).
pub struct TraceStream {
    shared: std::sync::Arc<StreamShared>,
}

impl TraceStream {
    /// A producer/consumer pair with the given ring budget in sealed chunks
    /// (`0` = unbounded, nothing ever spills; otherwise `>= 2`, validated
    /// upstream by `SharedMemConfig::validate`).
    pub fn channel(ring_chunks: usize) -> (TraceWriter, TraceStream) {
        let shared = std::sync::Arc::new(StreamShared {
            state: std::sync::Mutex::new(StreamState {
                chunks: Vec::new(),
                len: 0,
                finished: false,
                resident: 0,
                peak_resident: 0,
                spilled: 0,
                spill_cursor: 0,
                spill: None,
                spill_len: 0,
                spill_buf: Vec::new(),
                free: Vec::new(),
            }),
            cv: std::sync::Condvar::new(),
            ring: ring_chunks,
        });
        let writer = TraceWriter {
            shared: shared.clone(),
            open: Vec::with_capacity(TRACE_CHUNK),
            last_q: 0,
            finished: false,
        };
        (writer, TraceStream { shared })
    }

    /// A fresh sequential reader positioned at the first event.
    pub fn reader(&self) -> TraceReader {
        TraceReader {
            shared: self.shared.clone(),
            chunk: 0,
            i: 0,
            current: None,
            acc_q: 0,
        }
    }

    /// Total events sealed so far (final once the producer finished).
    pub fn len(&self) -> u64 {
        self.shared.state.lock().unwrap().len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Footprint accounting. Stable once the producer finished; the replay
    /// engine stamps these into the per-core [`crate::mem::SharedStats`].
    pub fn stats(&self) -> TraceStreamStats {
        let st = self.shared.state.lock().unwrap();
        TraceStreamStats {
            bytes_total: st.len * SPILL_EVENT_BYTES as u64,
            peak_resident_chunks: st.peak_resident as u64,
            spilled_chunks: st.spilled,
        }
    }
}

/// The producer side of a [`TraceStream`]: the same push/encode contract as
/// [`TraceBuf::push`], sealing each filled [`TRACE_CHUNK`]-event chunk into
/// the stream as it completes. Pushing never blocks — a full ring evicts
/// its oldest chunk to disk instead of stalling the simulated core.
pub struct TraceWriter {
    shared: std::sync::Arc<StreamShared>,
    open: Vec<TraceEvent>,
    last_q: u64,
    finished: bool,
}

impl TraceWriter {
    /// Append an event issued at core-local `time` (same encoding and
    /// monotonicity contract as [`TraceBuf::push`]).
    pub fn push(&mut self, mut e: TraceEvent, time: f64) {
        debug_assert!(!self.finished, "push after finish");
        let (dt, dt_hi) = encode_delta(&mut self.last_q, time);
        e.dt = dt;
        e.dt_hi = dt_hi;
        self.open.push(e);
        if self.open.len() >= TRACE_CHUNK {
            self.seal(false);
        }
    }

    /// Seal the partial final chunk and mark the stream finished. Idempotent;
    /// also runs on drop, so a panicking producer still ends its stream and
    /// readers never block forever.
    pub fn finish(&mut self) {
        if self.finished {
            return;
        }
        self.finished = true;
        self.seal(true);
    }

    fn seal(&mut self, finish: bool) {
        let mut st = self.shared.state.lock().unwrap();
        if !self.open.is_empty() {
            if self.shared.ring > 0 && st.resident >= self.shared.ring {
                st.spill_oldest();
            }
            let chunk = std::mem::take(&mut self.open);
            st.len += chunk.len() as u64;
            st.chunks.push(ChunkSlot::Resident(std::sync::Arc::new(chunk)));
            st.resident += 1;
            st.peak_resident = st.peak_resident.max(st.resident);
            if !finish {
                self.open = st
                    .free
                    .pop()
                    .unwrap_or_else(|| Vec::with_capacity(TRACE_CHUNK));
            }
        }
        if finish {
            st.finished = true;
        }
        drop(st);
        self.shared.cv.notify_all();
    }
}

impl Drop for TraceWriter {
    fn drop(&mut self) {
        self.finish();
    }
}

/// A sequential consumer of one [`TraceStream`]: yields `(absolute_time,
/// event)` pairs in program order with exactly [`TraceBuf::iter_timed`]'s
/// decode, blocking until the producer seals the chunk it needs (or
/// finishes). Readers are independent — each shard walk and the serial
/// merge hold their own.
pub struct TraceReader {
    shared: std::sync::Arc<StreamShared>,
    /// Next chunk index to load.
    chunk: usize,
    /// Position within the loaded chunk.
    i: usize,
    current: Option<LoadedChunk>,
    acc_q: u64,
}

enum LoadedChunk {
    /// A resident chunk, shared with the store.
    Shared(std::sync::Arc<Vec<TraceEvent>>),
    /// A spilled chunk demand-loaded for this reader alone.
    Owned(Vec<TraceEvent>),
}

impl LoadedChunk {
    fn events(&self) -> &[TraceEvent] {
        match self {
            LoadedChunk::Shared(a) => a,
            LoadedChunk::Owned(v) => v,
        }
    }
}

impl TraceReader {
    /// Next `(absolute_time, event)` pair, or `None` once the stream has
    /// finished and every sealed event was consumed.
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> Option<(f64, TraceEvent)> {
        loop {
            if let Some(cur) = &self.current {
                if let Some(&e) = cur.events().get(self.i) {
                    self.i += 1;
                    self.acc_q += e.dt_q();
                    return Some((decode_time(self.acc_q), e));
                }
            }
            if !self.advance() {
                return None;
            }
        }
    }

    /// Block until the next sealed chunk exists (or the stream is finished)
    /// and load it — by `Arc` clone if resident, decoded back through the
    /// stream's free list if spilled.
    fn advance(&mut self) -> bool {
        let mut st = self.shared.state.lock().unwrap();
        // Return the previous demand-loaded buffer before taking the next.
        if let Some(LoadedChunk::Owned(mut v)) = self.current.take() {
            v.clear();
            st.free.push(v);
        }
        while self.chunk >= st.chunks.len() {
            if st.finished {
                return false;
            }
            st = self.shared.cv.wait(st).unwrap();
        }
        let spilled_at = match &st.chunks[self.chunk] {
            ChunkSlot::Resident(arc) => {
                self.current = Some(LoadedChunk::Shared(arc.clone()));
                None
            }
            &ChunkSlot::Spilled { off, len } => Some((off, len)),
        };
        if let Some((off, len)) = spilled_at {
            self.current = Some(LoadedChunk::Owned(st.load_spilled(off, len)));
        }
        self.chunk += 1;
        self.i = 0;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_event_is_packed_and_round_trips() {
        assert_eq!(std::mem::size_of::<TraceEvent>(), 16);
        let e = TraceEvent::new(42, TraceKind::Demand, false, true, false, 1);
        assert_eq!(e.line(), 42);
        assert_eq!(e.kind(), TraceKind::Demand);
        assert!(!e.write());
        assert!(e.shadow_hit());
        assert!(!e.paid_bw());
        assert_eq!(e.phase(), 1);
        let w = TraceEvent::new((1 << 50) + 7, TraceKind::Writeback, true, false, true, 7);
        assert_eq!(w.line(), (1 << 50) + 7);
        assert_eq!(w.kind(), TraceKind::Writeback);
        assert!(w.write());
        assert!(!w.shadow_hit());
        assert!(w.paid_bw());
        assert_eq!(w.phase(), 7);
        assert_ne!(e, w);
    }

    #[test]
    fn socket_stamp_round_trips_without_disturbing_other_fields() {
        let e = TraceEvent::new((1 << 50) + 3, TraceKind::Demand, true, true, true, 5);
        assert_eq!(e.socket(), 0, "unstamped events are socket 0 (flat model)");
        let s = e.with_socket(15);
        assert_eq!(s.socket(), 15);
        assert_eq!(s.line(), (1 << 50) + 3);
        assert_eq!(s.kind(), TraceKind::Demand);
        assert!(s.write() && s.shadow_hit() && s.paid_bw());
        assert_eq!(s.phase(), 5);
        // Restamping overwrites rather than ORs.
        assert_eq!(s.with_socket(2).socket(), 2);
        assert_eq!(s.with_socket(0).socket(), 0);
    }

    #[test]
    fn trace_buf_preserves_order_times_and_chunks() {
        let mut b = TraceBuf::new();
        let n = TRACE_CHUNK * 2 + 17; // force multiple chunks
        for i in 0..n {
            b.push(
                TraceEvent::new(i as u64, TraceKind::Demand, i % 2 == 0, false, true, 2),
                i as f64 * 1.5,
            );
        }
        assert_eq!(b.len(), n);
        for (i, (t, e)) in b.iter_timed().enumerate() {
            assert_eq!(e.line(), i as u64);
            assert_eq!(e.write(), i % 2 == 0);
            assert!((t - i as f64 * 1.5).abs() < 1.0 / 64.0 + 1e-9, "event {i}: {t}");
            assert_eq!(b.get(i).line(), i as u64);
        }
        b.clear();
        assert!(b.is_empty());
        // After a clear, the delta encoder restarts at time zero.
        b.push(TraceEvent::new(9, TraceKind::Demand, false, false, false, 0), 10.0);
        let (t0, _) = b.iter_timed().next().unwrap();
        assert!((t0 - 10.0).abs() < 1.0 / 64.0 + 1e-9);
    }

    #[test]
    fn fractional_times_quantize_to_sixty_fourths() {
        let b = TraceBuf::from_events([
            (0.25, TraceEvent::new(1, TraceKind::Demand, false, false, true, 1)),
            (0.75, TraceEvent::new(2, TraceKind::Demand, false, false, true, 1)),
        ]);
        let ts: Vec<f64> = b.iter_timed().map(|(t, _)| t).collect();
        assert_eq!(ts, vec![0.25, 0.75], "quarter cycles are exactly representable");
    }

    #[test]
    fn gaps_past_the_old_u32_delta_no_longer_saturate() {
        // Regression: a >u32::MAX quantized gap (~67M cycles) used to clamp
        // silently, compressing this core's later events backwards in time
        // and corrupting the canonical merge order. The widened delta must
        // round-trip it exactly.
        let gap_cycles = 1e9; // 6.4e10 quantized units, far past u32::MAX
        let b = TraceBuf::from_events([
            (0.0, TraceEvent::new(1, TraceKind::Demand, false, false, true, 1)),
            (gap_cycles, TraceEvent::new(2, TraceKind::Demand, false, false, true, 1)),
            (gap_cycles + 0.5, TraceEvent::new(3, TraceKind::Demand, false, false, true, 1)),
        ]);
        let ts: Vec<f64> = b.iter_timed().map(|(t, _)| t).collect();
        assert_eq!(ts, vec![0.0, gap_cycles, gap_cycles + 0.5]);
    }

    #[test]
    #[should_panic(expected = "overflows the 48-bit")]
    fn gaps_past_the_48_bit_delta_fail_loudly() {
        let _ = TraceBuf::from_events([
            (0.0, TraceEvent::new(1, TraceKind::Demand, false, false, true, 1)),
            // 2^43 cycles = 2^49 quantized units: unrepresentable, and a
            // clamp here would silently reorder the merged replay.
            ((1u64 << 43) as f64, TraceEvent::new(2, TraceKind::Demand, false, false, true, 1)),
        ]);
    }

    #[test]
    fn non_monotone_time_saturates_instead_of_panicking() {
        let b = TraceBuf::from_events([
            (100.0, TraceEvent::new(1, TraceKind::Demand, false, false, true, 1)),
            (50.0, TraceEvent::new(2, TraceKind::Demand, false, false, true, 1)),
        ]);
        let ts: Vec<f64> = b.iter_timed().map(|(t, _)| t).collect();
        assert_eq!(ts[1], ts[0], "clock can stall but never run backwards");
    }

    #[test]
    fn clear_recycles_chunk_buffers_through_the_free_list() {
        let mut b = TraceBuf::new();
        for i in 0..TRACE_CHUNK * 2 + 5 {
            b.push(TraceEvent::new(i as u64, TraceKind::Demand, false, false, true, 1), i as f64);
        }
        assert_eq!(b.chunks.len(), 3);
        b.clear();
        assert_eq!(b.free.len(), 3, "cleared chunks land on the free list");
        assert!(b.free.iter().all(|c| c.is_empty() && c.capacity() >= TRACE_CHUNK));
        for i in 0..TRACE_CHUNK + 1 {
            b.push(TraceEvent::new(i as u64, TraceKind::Demand, false, false, true, 1), i as f64);
        }
        assert_eq!(b.free.len(), 1, "refilling reuses recycled chunks first");
        assert_eq!(b.len(), TRACE_CHUNK + 1);
    }

    #[test]
    fn spill_records_encode_and_decode_exactly() {
        let mut b = TraceBuf::new();
        b.push(TraceEvent::new(3, TraceKind::Demand, true, true, false, 5).with_socket(9), 0.25);
        b.push(TraceEvent::new((1 << 50) + 1, TraceKind::Writeback, true, false, false, 2), 1e9);
        let events: Vec<TraceEvent> = b.iter().collect();
        let mut bytes = Vec::new();
        encode_chunk(&events, &mut bytes);
        assert_eq!(bytes.len(), events.len() * SPILL_EVENT_BYTES);
        let mut back = Vec::new();
        decode_chunk(&bytes, &mut back);
        assert_eq!(back, events, "bits and the split 48-bit delta round-trip");
    }

    /// Events streamed through a writer decode exactly like the same events
    /// pushed into a `TraceBuf` — including with a tiny ring forcing every
    /// early chunk through the spill file.
    #[test]
    fn stream_round_trips_like_a_buf_resident_and_spilled() {
        let n = TRACE_CHUNK * 4 + 123;
        let ev = |i: usize| {
            (
                i as f64 * 0.75,
                TraceEvent::new(i as u64 % 977, TraceKind::Demand, i % 3 == 0, i % 5 == 0, true, 1)
                    .with_socket((i % 2) as u8),
            )
        };
        let buf = TraceBuf::from_events((0..n).map(ev));
        for ring in [0usize, 2] {
            let (mut w, stream) = TraceStream::channel(ring);
            for i in 0..n {
                let (t, e) = ev(i);
                w.push(e, t);
            }
            w.finish();
            assert_eq!(stream.len(), n as u64);
            let stats = stream.stats();
            assert_eq!(stats.bytes_total, 16 * n as u64);
            if ring == 0 {
                assert_eq!(stats.spilled_chunks, 0);
                assert_eq!(stats.peak_resident_chunks, 5, "ceil(n / TRACE_CHUNK) chunks");
            } else {
                assert!(stats.spilled_chunks > 0, "a 2-chunk ring must spill 5 chunks' worth");
                assert!(stats.peak_resident_chunks <= ring as u64);
            }
            // Two passes: streams are re-readable (the replay's corrective
            // passes re-walk them), and reading must not perturb the
            // producer-side footprint accounting.
            for pass in 0..2 {
                let mut r = stream.reader();
                let mut got = 0usize;
                let mut it = buf.iter_timed();
                while let Some((t, e)) = r.next() {
                    let (bt, be) = it.next().expect("stream yielded extra events");
                    assert_eq!(t.to_bits(), bt.to_bits(), "pass {pass}: time decode must be bit-identical");
                    assert_eq!(e, be);
                    got += 1;
                }
                assert_eq!(got, n);
                assert!(it.next().is_none());
            }
            assert_eq!(stream.stats(), stats, "readers never change the accounting");
        }
    }

    /// The satellite pin: a 48-bit (>u32) time delta landing exactly on a
    /// chunk boundary must survive the spill encode/decode round trip.
    #[test]
    fn spilled_chunk_round_trips_a_48_bit_delta_at_a_chunk_boundary() {
        let gap_cycles = 1e9; // 6.4e10 quantized units: needs dt_hi
        let time = |i: usize| {
            if i < TRACE_CHUNK {
                i as f64
            } else {
                gap_cycles + i as f64
            }
        };
        // The boundary delta is the first event of chunk 1; sealing chunks 2
        // and 3 into a 2-chunk ring evicts chunks 0 *and* 1, so the delta is
        // read back through the spill file.
        let n = TRACE_CHUNK * 4;
        let ev = |i: usize| TraceEvent::new(i as u64, TraceKind::Demand, false, false, true, 1);
        let (mut w, stream) = TraceStream::channel(2);
        for i in 0..n {
            w.push(ev(i), time(i));
        }
        w.finish();
        assert!(stream.stats().spilled_chunks >= 2, "the boundary chunk must have spilled");
        let mut r = stream.reader();
        for i in 0..n {
            let (t, e) = r.next().expect("missing event");
            assert_eq!(e.line(), i as u64);
            assert_eq!(
                t.to_bits(),
                time(i).to_bits(),
                "event {i}: the 48-bit boundary delta must decode exactly"
            );
        }
        assert!(r.next().is_none());
    }

    /// A reader started before any data exists blocks until the producer
    /// seals, and a dropped writer finishes its stream (no deadlock when a
    /// producer unwinds mid-run).
    #[test]
    fn reader_blocks_until_seal_and_writer_drop_finishes() {
        let (mut w, stream) = TraceStream::channel(0);
        let consumer = std::thread::spawn({
            let mut r = stream.reader();
            move || {
                let mut n = 0u64;
                while r.next().is_some() {
                    n += 1;
                }
                n
            }
        });
        for i in 0..(TRACE_CHUNK + 7) {
            w.push(TraceEvent::new(i as u64, TraceKind::Demand, false, false, true, 1), i as f64);
        }
        drop(w); // no explicit finish
        assert_eq!(consumer.join().unwrap(), TRACE_CHUNK as u64 + 7);
        assert_eq!(stream.len(), TRACE_CHUNK as u64 + 7);
    }

    #[test]
    fn empty_stream_finishes_clean() {
        let (mut w, stream) = TraceStream::channel(2);
        w.finish();
        assert!(stream.is_empty());
        assert!(stream.reader().next().is_none());
        assert_eq!(stream.stats(), TraceStreamStats::default());
    }
}
