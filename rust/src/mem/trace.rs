//! Line-granular shared-memory access traces (phase 1 of the two-phase
//! shared-memory model).
//!
//! The shared LLC and DRAM channels are *time-shared* resources: what they
//! cost a core depends on what every other core is doing at the same moment.
//! Simulating them inline would make per-core results depend on host thread
//! interleaving and break the bit-reproducibility invariant the parallel
//! driver pins (see `spgemm::parallel`). Instead the model is two-phase
//! **trace-and-replay**:
//!
//! 1. During parallel execution, each core's [`crate::mem::Hierarchy`]
//!    records a compact trace of every access that leaves its private L1/L2
//!    — demand fills walking down into the LLC and dirty L2 victims written
//!    back into it — stamped with the core's *local logical time* (its own
//!    simulated cycle count) and the Figure 9 phase it charged into.
//!    Private L1/L2 results are final in this phase.
//! 2. After the workers join, a deterministic interleaver merges the
//!    per-core traces in canonical logical-time order and replays them
//!    through the shared LLC + multi-channel DRAM model
//!    ([`crate::mem::shared::replay`]), producing per-core shared-memory
//!    stall cycles and coherence counters that are a pure function of the
//!    traces — independent of host scheduling.
//!
//! The trade-off is explicit: phase 1 prices each core's private-hierarchy
//! latency against its own *shadow* copy of the LLC, so cross-core effects
//! on private-cache contents (a line another core invalidated, say) are
//! folded in as replay-derived stall corrections rather than re-executed.

/// Upper bound on [`TraceEvent::phase`] values ( >= the machine model's
/// `NUM_PHASES`; replay buckets stalls per phase in arrays of this size).
pub const MAX_PHASES: usize = 8;

/// What a traced LLC-level access was doing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    /// Demand fill: an access that missed the private L1 and L2 and walked
    /// down into the LLC.
    Demand,
    /// A dirty L2 victim installed into the LLC (write-back path). Latency
    /// is hidden by the write buffer, but the install still updates LLC
    /// state and occupies the shared tag pipeline.
    Writeback,
}

/// One line-granular access that left a core's private L1/L2.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    /// Line address (byte address `>> line_shift`).
    pub line: u64,
    /// Core-local logical time in simulated cycles at which the access
    /// issued (the machine's cycle counter, monotone within a core).
    pub time: f64,
    pub kind: TraceKind,
    /// Demand intent: `true` for stores (drives the MESI-lite upgrade /
    /// invalidation bookkeeping). Always `true` for writeback installs.
    pub write: bool,
    /// Phase-1 outcome in the core's private *shadow* LLC. The replay
    /// compares this prediction against the real shared-LLC outcome to
    /// price constructive sharing (shadow miss, shared hit) and destructive
    /// interference (shadow hit, shared miss).
    pub shadow_hit: bool,
    /// Whether phase 1 actually charged the DRAM bandwidth floor for this
    /// access. False for shadow hits, for stream-prefetched accesses (whose
    /// raw latency was clamped to an L1 hit, so `dram_bw` saw no DRAM
    /// latency), and for writeback installs. The replay refunds the floor on
    /// constructive sharing only when it was really paid.
    pub paid_bw: bool,
    /// Figure 9 breakdown phase the access charged into (`< MAX_PHASES`),
    /// so replay-derived stalls land in the same per-phase buckets.
    pub phase: u8,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_event_is_compact_and_comparable() {
        let e = TraceEvent {
            line: 42,
            time: 7.5,
            kind: TraceKind::Demand,
            write: false,
            shadow_hit: true,
            paid_bw: false,
            phase: 1,
        };
        assert_eq!(e, e);
        assert_ne!(
            e,
            TraceEvent {
                kind: TraceKind::Writeback,
                ..e
            }
        );
    }
}
