//! Line-granular shared-memory access traces (phase 1 of the two-phase
//! shared-memory model).
//!
//! The shared LLC and DRAM channels are *time-shared* resources: what they
//! cost a core depends on what every other core is doing at the same moment.
//! Simulating them inline would make per-core results depend on host thread
//! interleaving and break the bit-reproducibility invariant the parallel
//! driver pins (see `spgemm::parallel`). Instead the model is two-phase
//! **trace-and-replay**:
//!
//! 1. During parallel execution, each core's [`crate::mem::Hierarchy`]
//!    records a compact trace of every access that leaves its private L1/L2
//!    — demand fills walking down into the LLC and dirty L2 victims written
//!    back into it — stamped with the core's *local logical time* (its own
//!    simulated cycle count) and the Figure 9 phase it charged into.
//!    Private L1/L2 results are final in this phase.
//! 2. After the workers join, a deterministic interleaver merges the
//!    per-core traces in canonical logical-time order and replays them
//!    through the shared LLC + multi-channel DRAM model
//!    ([`crate::mem::shared::ReplayEngine`]), producing per-core
//!    shared-memory stall cycles and coherence counters that are a pure
//!    function of the traces — independent of host scheduling.
//!
//! The trade-off is explicit: phase 1 prices each core's private-hierarchy
//! latency against its own *shadow* copy of the LLC, so cross-core effects
//! on private-cache contents (a line another core invalidated, say) are
//! folded in as replay-derived stall corrections rather than re-executed.
//!
//! ## Storage format
//!
//! Multi-core jobs on large matrices record tens of millions of events per
//! core, so the in-memory format matters. Each [`TraceEvent`] is a packed
//! 16-byte record: the line id and all flag bits (kind, write intent, shadow
//! outcome, bandwidth attribution, phase) share one `u64`, and the local
//! timestamp is a 48-bit *delta* from the previous event of the same core in
//! 1/64-cycle fixed point. [`TraceBuf`] stores events in fixed-size chunks
//! (no doubling reallocation, so peak memory stays within one chunk of the
//! live data) and decodes absolute times by sequential accumulation.

/// Upper bound on [`TraceEvent::phase`] values ( >= the machine model's
/// `NUM_PHASES`; replay buckets stalls per phase in arrays of this size).
pub const MAX_PHASES: usize = 8;

/// Events per [`TraceBuf`] chunk (64KB of packed events per chunk).
pub const TRACE_CHUNK: usize = 4096;

/// Fixed-point shift for trace time deltas: 1/64-cycle resolution, so the
/// 48-bit delta spans ~4.4 trillion cycles between consecutive LLC-level
/// events of one core. A `u32` delta used to saturate silently here at ~67M
/// cycles — enough for a long service-queue wait between a job's phases to
/// quietly compress, corrupting the canonical merge order with no signal —
/// so gaps beyond the (absurd) 48-bit span are now a hard error, not a
/// clamp.
const TIME_SHIFT: u32 = 6;
const TIME_SCALE: f64 = (1u64 << TIME_SHIFT) as f64;
/// Max representable quantized delta (48 bits: `dt` low word + `dt_hi`).
const MAX_DT: u64 = (1u64 << 48) - 1;

/// What a traced LLC-level access was doing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    /// Demand fill: an access that missed the private L1 and L2 and walked
    /// down into the LLC.
    Demand,
    /// A dirty L2 victim installed into the LLC (write-back path). Latency
    /// is hidden by the write buffer, but the install still updates LLC
    /// state and occupies the shared tag pipeline.
    Writeback,
}

// Bit layout of `TraceEvent::bits`: the low 53 bits hold the line address,
// the next 4 the requesting core's socket id, the top 7 the flags. Line
// addresses are `byte_addr >> 6`; the simulated address space tops out at
// the shared-operand region (2^56 + epsilon), so lines fit in ~51 bits with
// room to spare even after ceding 4 bits to the socket id.
const LINE_BITS: u32 = 53;
const LINE_MASK: u64 = (1u64 << LINE_BITS) - 1;
const SOCKET_SHIFT: u32 = 53;
const SOCKET_MASK: u64 = (crate::config::MAX_SOCKETS as u64) - 1;
const KIND_BIT: u64 = 1 << 57;
const WRITE_BIT: u64 = 1 << 58;
const SHADOW_BIT: u64 = 1 << 59;
const PAID_BIT: u64 = 1 << 60;
const PHASE_SHIFT: u32 = 61;

/// One line-granular access that left a core's private L1/L2, packed into
/// 16 bytes (see the module docs for the layout). Construct with
/// [`TraceEvent::new`]; the local timestamp lives in the owning
/// [`TraceBuf`]'s delta stream, not in the event itself.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    bits: u64,
    /// Low 32 bits of the time delta to the previous event of the same
    /// trace, 1/64-cycle fixed point (filled in by [`TraceBuf::push`]).
    dt: u32,
    /// High 16 bits of the delta (48 bits total; the padding the old
    /// u32-delta layout wasted anyway, put to work).
    dt_hi: u16,
}

// The whole point of the packed layout: one event is 16 bytes, not the ~32
// of the naive struct-of-fields encoding.
const _: () = assert!(std::mem::size_of::<TraceEvent>() == 16);
const _: () = assert!(MAX_PHASES <= (1usize << (64 - PHASE_SHIFT as usize)));
// The socket field must fill its 4 bits exactly and sit flush against the
// kind bit.
const _: () = assert!(crate::config::MAX_SOCKETS == 16);
const _: () = assert!(SOCKET_SHIFT + 4 == 57);

impl TraceEvent {
    /// Pack an event (timestamp is assigned by [`TraceBuf::push`]). The
    /// requesting core's socket id defaults to 0 (single-socket / flat);
    /// stamp it with [`TraceEvent::with_socket`].
    pub fn new(
        line: u64,
        kind: TraceKind,
        write: bool,
        shadow_hit: bool,
        paid_bw: bool,
        phase: u8,
    ) -> TraceEvent {
        debug_assert!(line <= LINE_MASK, "line id overflows the packed layout");
        debug_assert!((phase as usize) < MAX_PHASES);
        let mut bits = line & LINE_MASK;
        if kind == TraceKind::Writeback {
            bits |= KIND_BIT;
        }
        if write {
            bits |= WRITE_BIT;
        }
        if shadow_hit {
            bits |= SHADOW_BIT;
        }
        if paid_bw {
            bits |= PAID_BIT;
        }
        bits |= ((phase as u64) & (MAX_PHASES as u64 - 1)) << PHASE_SHIFT;
        TraceEvent { bits, dt: 0, dt_hi: 0 }
    }

    /// Stamp the requesting core's socket id (`< MAX_SOCKETS`): the replay
    /// prices each event's NUMA distance from this, so traces stay
    /// self-describing (no side-channel core-to-socket table).
    #[inline]
    pub fn with_socket(mut self, socket: u8) -> TraceEvent {
        debug_assert!((socket as usize) < crate::config::MAX_SOCKETS);
        self.bits = (self.bits & !(SOCKET_MASK << SOCKET_SHIFT))
            | (((socket as u64) & SOCKET_MASK) << SOCKET_SHIFT);
        self
    }

    /// Socket of the requesting core (0 for single-socket traces).
    #[inline]
    pub fn socket(self) -> u8 {
        ((self.bits >> SOCKET_SHIFT) & SOCKET_MASK) as u8
    }

    /// Line address (byte address `>> line_shift`).
    #[inline]
    pub fn line(self) -> u64 {
        self.bits & LINE_MASK
    }

    #[inline]
    pub fn kind(self) -> TraceKind {
        if self.bits & KIND_BIT != 0 {
            TraceKind::Writeback
        } else {
            TraceKind::Demand
        }
    }

    /// Demand intent: `true` for stores (drives the MESI-lite upgrade /
    /// invalidation bookkeeping). Always `true` for writeback installs.
    #[inline]
    pub fn write(self) -> bool {
        self.bits & WRITE_BIT != 0
    }

    /// Phase-1 outcome in the core's private *shadow* LLC. The replay
    /// compares this prediction against the real shared-LLC outcome to
    /// price constructive sharing (shadow miss, shared hit) and destructive
    /// interference (shadow hit, shared miss).
    #[inline]
    pub fn shadow_hit(self) -> bool {
        self.bits & SHADOW_BIT != 0
    }

    /// Whether phase 1 actually charged the DRAM bandwidth floor for this
    /// access. False for shadow hits, for stream-prefetched accesses (whose
    /// raw latency was clamped to an L1 hit, so `dram_bw` saw no DRAM
    /// latency), and for writeback installs. The replay refunds the floor on
    /// constructive sharing only when it was really paid.
    #[inline]
    pub fn paid_bw(self) -> bool {
        self.bits & PAID_BIT != 0
    }

    /// Figure 9 breakdown phase the access charged into (`< MAX_PHASES`),
    /// so replay-derived stalls land in the same per-phase buckets.
    #[inline]
    pub fn phase(self) -> u8 {
        (self.bits >> PHASE_SHIFT) as u8
    }
}

/// A core's recorded trace: packed events in fixed-size chunks plus the
/// delta-encoded local timestamps. Absolute times are recovered by
/// sequential accumulation ([`TraceBuf::iter_timed`]); random access to the
/// packed fields (not times) goes through [`TraceBuf::get`].
#[derive(Clone, Debug, Default)]
pub struct TraceBuf {
    chunks: Vec<Vec<TraceEvent>>,
    len: usize,
    /// Quantized timestamp of the last pushed event (encoder state; kept in
    /// quantized units so encode and decode can never drift apart).
    last_q: u64,
}

impl TraceBuf {
    pub fn new() -> TraceBuf {
        TraceBuf::default()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append an event issued at core-local `time` (simulated cycles,
    /// monotone per core; quantized to 1/64-cycle deltas).
    pub fn push(&mut self, mut e: TraceEvent, time: f64) {
        let q = (time * TIME_SCALE).max(0.0) as u64;
        // Local times are monotone per core; a backwards stamp saturates to
        // the previous time (the clock can stall but never run in reverse).
        // A *forward* gap past the 48-bit span, by contrast, cannot be
        // represented — clamping it would silently reorder this core's
        // events against every other core's in the canonical merge, so it
        // fails loudly instead.
        let dt = q.saturating_sub(self.last_q);
        assert!(
            dt <= MAX_DT,
            "trace time gap of {dt} quantized units overflows the 48-bit \
             delta encoding (~4.4e12 cycles between consecutive events)"
        );
        self.last_q += dt;
        e.dt = dt as u32;
        e.dt_hi = (dt >> 32) as u16;
        if self.chunks.last().map(|c| c.len() >= TRACE_CHUNK).unwrap_or(true) {
            self.chunks.push(Vec::with_capacity(TRACE_CHUNK));
        }
        self.chunks.last_mut().unwrap().push(e);
        self.len += 1;
    }

    /// Random access to the packed event fields (times require the
    /// sequential decoder, [`TraceBuf::iter_timed`]).
    #[inline]
    pub fn get(&self, i: usize) -> TraceEvent {
        self.chunks[i / TRACE_CHUNK][i % TRACE_CHUNK]
    }

    /// Iterate `(absolute_time, event)` pairs, decoding the delta stream.
    pub fn iter_timed(&self) -> impl Iterator<Item = (f64, TraceEvent)> + '_ {
        let mut acc = 0u64;
        self.chunks.iter().flatten().map(move |&e| {
            acc += e.dt as u64 | ((e.dt_hi as u64) << 32);
            (acc as f64 / TIME_SCALE, e)
        })
    }

    /// Iterate the packed events without decoding times.
    pub fn iter(&self) -> impl Iterator<Item = TraceEvent> + '_ {
        self.chunks.iter().flatten().copied()
    }

    /// Drop all recorded events (encoder time state resets too).
    pub fn clear(&mut self) {
        self.chunks.clear();
        self.len = 0;
        self.last_q = 0;
    }

    /// Test/builder convenience: a buffer from `(time, event)` pairs.
    pub fn from_events<I: IntoIterator<Item = (f64, TraceEvent)>>(events: I) -> TraceBuf {
        let mut b = TraceBuf::new();
        for (t, e) in events {
            b.push(e, t);
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_event_is_packed_and_round_trips() {
        assert_eq!(std::mem::size_of::<TraceEvent>(), 16);
        let e = TraceEvent::new(42, TraceKind::Demand, false, true, false, 1);
        assert_eq!(e.line(), 42);
        assert_eq!(e.kind(), TraceKind::Demand);
        assert!(!e.write());
        assert!(e.shadow_hit());
        assert!(!e.paid_bw());
        assert_eq!(e.phase(), 1);
        let w = TraceEvent::new((1 << 50) + 7, TraceKind::Writeback, true, false, true, 7);
        assert_eq!(w.line(), (1 << 50) + 7);
        assert_eq!(w.kind(), TraceKind::Writeback);
        assert!(w.write());
        assert!(!w.shadow_hit());
        assert!(w.paid_bw());
        assert_eq!(w.phase(), 7);
        assert_ne!(e, w);
    }

    #[test]
    fn socket_stamp_round_trips_without_disturbing_other_fields() {
        let e = TraceEvent::new((1 << 50) + 3, TraceKind::Demand, true, true, true, 5);
        assert_eq!(e.socket(), 0, "unstamped events are socket 0 (flat model)");
        let s = e.with_socket(15);
        assert_eq!(s.socket(), 15);
        assert_eq!(s.line(), (1 << 50) + 3);
        assert_eq!(s.kind(), TraceKind::Demand);
        assert!(s.write() && s.shadow_hit() && s.paid_bw());
        assert_eq!(s.phase(), 5);
        // Restamping overwrites rather than ORs.
        assert_eq!(s.with_socket(2).socket(), 2);
        assert_eq!(s.with_socket(0).socket(), 0);
    }

    #[test]
    fn trace_buf_preserves_order_times_and_chunks() {
        let mut b = TraceBuf::new();
        let n = TRACE_CHUNK * 2 + 17; // force multiple chunks
        for i in 0..n {
            b.push(
                TraceEvent::new(i as u64, TraceKind::Demand, i % 2 == 0, false, true, 2),
                i as f64 * 1.5,
            );
        }
        assert_eq!(b.len(), n);
        for (i, (t, e)) in b.iter_timed().enumerate() {
            assert_eq!(e.line(), i as u64);
            assert_eq!(e.write(), i % 2 == 0);
            assert!((t - i as f64 * 1.5).abs() < 1.0 / 64.0 + 1e-9, "event {i}: {t}");
            assert_eq!(b.get(i).line(), i as u64);
        }
        b.clear();
        assert!(b.is_empty());
        // After a clear, the delta encoder restarts at time zero.
        b.push(TraceEvent::new(9, TraceKind::Demand, false, false, false, 0), 10.0);
        let (t0, _) = b.iter_timed().next().unwrap();
        assert!((t0 - 10.0).abs() < 1.0 / 64.0 + 1e-9);
    }

    #[test]
    fn fractional_times_quantize_to_sixty_fourths() {
        let b = TraceBuf::from_events([
            (0.25, TraceEvent::new(1, TraceKind::Demand, false, false, true, 1)),
            (0.75, TraceEvent::new(2, TraceKind::Demand, false, false, true, 1)),
        ]);
        let ts: Vec<f64> = b.iter_timed().map(|(t, _)| t).collect();
        assert_eq!(ts, vec![0.25, 0.75], "quarter cycles are exactly representable");
    }

    #[test]
    fn gaps_past_the_old_u32_delta_no_longer_saturate() {
        // Regression: a >u32::MAX quantized gap (~67M cycles) used to clamp
        // silently, compressing this core's later events backwards in time
        // and corrupting the canonical merge order. The widened delta must
        // round-trip it exactly.
        let gap_cycles = 1e9; // 6.4e10 quantized units, far past u32::MAX
        let b = TraceBuf::from_events([
            (0.0, TraceEvent::new(1, TraceKind::Demand, false, false, true, 1)),
            (gap_cycles, TraceEvent::new(2, TraceKind::Demand, false, false, true, 1)),
            (gap_cycles + 0.5, TraceEvent::new(3, TraceKind::Demand, false, false, true, 1)),
        ]);
        let ts: Vec<f64> = b.iter_timed().map(|(t, _)| t).collect();
        assert_eq!(ts, vec![0.0, gap_cycles, gap_cycles + 0.5]);
    }

    #[test]
    #[should_panic(expected = "overflows the 48-bit")]
    fn gaps_past_the_48_bit_delta_fail_loudly() {
        let _ = TraceBuf::from_events([
            (0.0, TraceEvent::new(1, TraceKind::Demand, false, false, true, 1)),
            // 2^43 cycles = 2^49 quantized units: unrepresentable, and a
            // clamp here would silently reorder the merged replay.
            ((1u64 << 43) as f64, TraceEvent::new(2, TraceKind::Demand, false, false, true, 1)),
        ]);
    }

    #[test]
    fn non_monotone_time_saturates_instead_of_panicking() {
        let b = TraceBuf::from_events([
            (100.0, TraceEvent::new(1, TraceKind::Demand, false, false, true, 1)),
            (50.0, TraceEvent::new(2, TraceKind::Demand, false, false, true, 1)),
        ]);
        let ts: Vec<f64> = b.iter_timed().map(|(t, _)| t).collect();
        assert_eq!(ts[1], ts[0], "clock can stall but never run backwards");
    }
}
