//! Simulated memory subsystem: a bump allocator for the *simulated* address
//! space, set-associative write-back caches, the per-core private hierarchy
//! from Table II, and the shared end of the system — one LLC shared by all
//! cores with MESI-lite coherence bookkeeping and a multi-channel DRAM back
//! end, priced by deterministic trace-and-replay ([`trace`] records,
//! [`shared`] replays). This substrate replaces gem5's Ruby/CHI model with a
//! tag-only timing simulation (DESIGN.md "Substitutions").

pub mod alloc;
pub mod cache;
pub mod hierarchy;
pub mod oracle;
pub mod shared;
pub mod trace;

pub use alloc::SimAlloc;
pub use oracle::OracleBound;
pub use cache::Cache;
pub use hierarchy::{AccessKind, Hierarchy, MemStats};
pub use shared::{replay, ReplayEngine, ReplayOutcome, SharedStats, TraceSource};
pub use trace::{
    TraceBuf, TraceEvent, TraceKind, TraceReader, TraceStream, TraceStreamStats, TraceWriter,
    MAX_PHASES, TRACE_CHUNK,
};
