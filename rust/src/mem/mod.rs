//! Simulated memory subsystem: a bump allocator for the *simulated* address
//! space, set-associative write-back caches, and the three-level hierarchy
//! from Table II. This substrate replaces gem5's Ruby/CHI model with a
//! tag-only timing simulation (DESIGN.md "Substitutions").

pub mod alloc;
pub mod cache;
pub mod hierarchy;

pub use alloc::SimAlloc;
pub use cache::Cache;
pub use hierarchy::{AccessKind, Hierarchy, MemStats};
