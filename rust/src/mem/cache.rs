//! Set-associative, write-back, write-allocate cache with true-LRU
//! replacement. Tag-only: no data is stored, only residency is tracked.
//! Hot-path code — keep allocation-free after construction.

use crate::config::CacheConfig;

/// One cache level. Ways are kept in LRU order within each set
/// (index 0 = MRU) — sets are small (4–8 ways) so rotation is cheap.
#[derive(Debug, Clone)]
pub struct Cache {
    cfg: CacheConfig,
    line_shift: u32,
    set_mask: u64,
    /// tags[set * ways + way]; u64::MAX = invalid.
    tags: Vec<u64>,
    /// dirty bit per way (parallel to `tags`).
    dirty: Vec<bool>,
    pub accesses: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub writebacks: u64,
}

impl Cache {
    pub fn new(cfg: CacheConfig) -> Self {
        let sets = cfg.sets();
        assert!(sets.is_power_of_two(), "sets must be a power of two");
        assert!(cfg.line_bytes.is_power_of_two());
        Cache {
            cfg,
            line_shift: cfg.line_bytes.trailing_zeros(),
            set_mask: (sets - 1) as u64,
            tags: vec![u64::MAX; sets * cfg.ways],
            dirty: vec![false; sets * cfg.ways],
            accesses: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            writebacks: 0,
        }
    }

    pub fn cfg(&self) -> &CacheConfig {
        &self.cfg
    }

    #[inline]
    fn set_of(&self, line: u64) -> usize {
        (line & self.set_mask) as usize
    }

    /// Access one *line address* (addr >> line_shift already applied by the
    /// hierarchy). Returns `(hit, evicted_dirty_line)`.
    #[inline]
    pub fn access_line(&mut self, line: u64, write: bool) -> (bool, Option<u64>) {
        self.accesses += 1;
        let set = self.set_of(line);
        let ways = self.cfg.ways;
        let base = set * ways;
        let tags = &mut self.tags[base..base + ways];
        // Search for hit.
        for w in 0..ways {
            if tags[w] == line {
                self.hits += 1;
                // Move to MRU position.
                let d = self.dirty[base + w] || write;
                tags.copy_within(0..w, 1);
                tags[0] = line;
                self.dirty.copy_within(base..base + w, base + 1);
                self.dirty[base] = d;
                return (true, None);
            }
        }
        // Miss: evict LRU (last way).
        self.misses += 1;
        let victim_tag = tags[ways - 1];
        let victim_dirty = self.dirty[base + ways - 1];
        let evicted = if victim_tag != u64::MAX {
            self.evictions += 1;
            if victim_dirty {
                self.writebacks += 1;
                Some(victim_tag)
            } else {
                None
            }
        } else {
            None
        };
        tags.copy_within(0..ways - 1, 1);
        tags[0] = line;
        self.dirty.copy_within(base..base + ways - 1, base + 1);
        self.dirty[base] = write;
        (false, evicted)
    }

    /// Number of lines currently resident (test/introspection only).
    pub fn resident_lines(&self) -> usize {
        self.tags.iter().filter(|&&t| t != u64::MAX).count()
    }

    pub fn line_shift(&self) -> u32 {
        self.line_shift
    }

    pub fn hit_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses as f64
        }
    }

    pub fn reset_stats(&mut self) {
        self.accesses = 0;
        self.hits = 0;
        self.misses = 0;
        self.evictions = 0;
        self.writebacks = 0;
    }

    /// Return to the just-constructed state (all lines invalid, stats zero)
    /// without reallocating — the replay engine reuses its per-shard LLC
    /// replicas across iteration passes.
    pub fn reset(&mut self) {
        self.tags.iter_mut().for_each(|t| *t = u64::MAX);
        self.dirty.iter_mut().for_each(|d| *d = false);
        self.reset_stats();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CacheConfig;

    fn tiny() -> Cache {
        // 4 sets x 2 ways x 64B lines = 512B cache.
        Cache::new(CacheConfig {
            size_bytes: 512,
            ways: 2,
            line_bytes: 64,
            hit_latency: 2,
        })
    }

    #[test]
    fn cold_miss_then_hit() {
        let mut c = tiny();
        let (hit, _) = c.access_line(5, false);
        assert!(!hit);
        let (hit, _) = c.access_line(5, false);
        assert!(hit);
        assert_eq!(c.accesses, 2);
        assert_eq!(c.hits, 1);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = tiny();
        // Lines 0, 4, 8 all map to set 0 (4 sets).
        c.access_line(0, false);
        c.access_line(4, false);
        c.access_line(0, false); // 0 becomes MRU, 4 is LRU
        let (hit, _) = c.access_line(8, false); // evicts 4
        assert!(!hit);
        let (hit, _) = c.access_line(0, false);
        assert!(hit, "0 must survive (was MRU)");
        let (hit, _) = c.access_line(4, false);
        assert!(!hit, "4 must have been evicted");
    }

    #[test]
    fn dirty_writeback() {
        let mut c = tiny();
        c.access_line(0, true); // dirty
        c.access_line(4, false);
        let (_, wb) = c.access_line(8, false); // evicts 0 (LRU, dirty)
        assert_eq!(wb, Some(0));
        assert_eq!(c.writebacks, 1);
    }

    #[test]
    fn clean_eviction_no_writeback() {
        let mut c = tiny();
        c.access_line(0, false);
        c.access_line(4, false);
        let (_, wb) = c.access_line(8, false);
        assert_eq!(wb, None);
    }

    #[test]
    fn write_hit_marks_dirty() {
        let mut c = tiny();
        c.access_line(0, false);
        c.access_line(0, true); // now dirty via write hit
        c.access_line(4, false);
        let (_, wb) = c.access_line(8, false);
        assert_eq!(wb, Some(0));
    }

    #[test]
    fn different_sets_dont_conflict() {
        let mut c = tiny();
        for line in 0..4 {
            c.access_line(line, false);
        }
        for line in 0..4 {
            let (hit, _) = c.access_line(line, false);
            assert!(hit);
        }
    }

    #[test]
    fn reset_restores_the_constructed_state() {
        let mut c = tiny();
        c.access_line(0, true);
        c.access_line(4, false);
        c.access_line(8, false); // evicts dirty 0
        assert!(c.resident_lines() > 0);
        c.reset();
        assert_eq!(c.resident_lines(), 0);
        assert_eq!(c.accesses + c.hits + c.misses + c.evictions + c.writebacks, 0);
        // No stale dirty bit: refilling and evicting line 0's set must not
        // write back a line the reset already dropped.
        c.access_line(0, false);
        c.access_line(4, false);
        let (_, wb) = c.access_line(8, false);
        assert_eq!(wb, None);
    }

    #[test]
    fn resident_count() {
        let mut c = tiny();
        assert_eq!(c.resident_lines(), 0);
        c.access_line(1, false);
        c.access_line(2, false);
        assert_eq!(c.resident_lines(), 2);
    }
}
