//! Three-level cache hierarchy (L1D -> L2 -> LLC -> DRAM) per Table II.
//!
//! `access` walks an address range line-by-line, probes the levels in order,
//! models write-back propagation of dirty victims, and returns the raw
//! latency of the *slowest* line touched plus the number of L1D line
//! accesses (Figure 10's metric). The cost model in `sim::cost` turns raw
//! latencies into effective (overlap-adjusted) cycles.

use crate::config::MemConfig;
use crate::mem::cache::Cache;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccessKind {
    Read,
    Write,
}

/// Aggregate statistics across the hierarchy.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemStats {
    pub l1d_accesses: u64,
    pub l1d_hits: u64,
    pub l2_accesses: u64,
    pub l2_hits: u64,
    pub llc_accesses: u64,
    pub llc_hits: u64,
    pub dram_accesses: u64,
    pub writebacks: u64,
}

impl MemStats {
    pub fn l1d_hit_rate(&self) -> f64 {
        if self.l1d_accesses == 0 {
            0.0
        } else {
            self.l1d_hits as f64 / self.l1d_accesses as f64
        }
    }

    /// Element-wise accumulate (multi-core aggregation across per-core
    /// private hierarchies).
    pub fn add(&mut self, o: &MemStats) {
        self.l1d_accesses += o.l1d_accesses;
        self.l1d_hits += o.l1d_hits;
        self.l2_accesses += o.l2_accesses;
        self.l2_hits += o.l2_hits;
        self.llc_accesses += o.llc_accesses;
        self.llc_hits += o.llc_hits;
        self.dram_accesses += o.dram_accesses;
        self.writebacks += o.writebacks;
    }
}

#[derive(Debug)]
pub struct Hierarchy {
    pub l1d: Cache,
    pub l2: Cache,
    pub llc: Cache,
    cfg: MemConfig,
    line_shift: u32,
    pub dram_accesses: u64,
    /// Next-line stream-prefetcher model: recent line addresses; an access
    /// adjacent to a recent line is treated as prefetched (latency hidden
    /// down to an L1 hit) while still updating cache state. gem5's CHI
    /// configs run stride prefetchers; without this, streaming phases pay
    /// full miss latency and every vectorized/scalar ratio compresses.
    prefetch_tab: [u64; 8],
    pf_idx: usize,
    pub prefetch_hits: u64,
}

impl Hierarchy {
    pub fn new(cfg: MemConfig) -> Self {
        assert_eq!(cfg.l1d.line_bytes, cfg.l2.line_bytes);
        assert_eq!(cfg.l2.line_bytes, cfg.llc.line_bytes);
        Hierarchy {
            l1d: Cache::new(cfg.l1d),
            l2: Cache::new(cfg.l2),
            llc: Cache::new(cfg.llc),
            line_shift: cfg.l1d.line_bytes.trailing_zeros(),
            cfg,
            dram_accesses: 0,
            prefetch_tab: [u64::MAX; 8],
            pf_idx: 0,
            prefetch_hits: 0,
        }
    }

    pub fn line_bytes(&self) -> usize {
        self.cfg.l1d.line_bytes
    }

    /// Probe a single line address (already shifted). Returns raw latency,
    /// with stream-prefetched misses reported at L1-hit latency.
    #[inline]
    pub fn access_line(&mut self, line: u64, kind: AccessKind) -> u32 {
        // Stream detection *before* the demand access: a line adjacent to a
        // recently touched one would have been prefetched.
        let streamed = self
            .prefetch_tab
            .iter()
            .any(|&p| p != u64::MAX && (line == p + 1 || line == p + 2));
        self.prefetch_tab[self.pf_idx] = line;
        self.pf_idx = (self.pf_idx + 1) % self.prefetch_tab.len();
        let raw = self.demand_line(line, kind);
        if streamed && raw > self.cfg.l1d.hit_latency {
            self.prefetch_hits += 1;
            return self.cfg.l1d.hit_latency;
        }
        raw
    }

    #[inline]
    fn demand_line(&mut self, line: u64, kind: AccessKind) -> u32 {
        let write = kind == AccessKind::Write;
        let (hit1, wb1) = self.l1d.access_line(line, write);
        if let Some(v) = wb1 {
            // Dirty L1 victim written back into L2 (allocate, mark dirty).
            let (_, wb2) = self.l2.access_line(v, true);
            if let Some(v2) = wb2 {
                let (_, _wb3) = self.llc.access_line(v2, true);
                // LLC dirty victims go to DRAM; latency hidden (write buffer).
            }
        }
        if hit1 {
            return self.cfg.l1d.hit_latency;
        }
        // Fill from L2. Fills are reads regardless of the demand kind;
        // the demand write dirties L1 (handled above via write-allocate).
        let (hit2, wb2) = self.l2.access_line(line, false);
        if let Some(v2) = wb2 {
            let (_, _wb3) = self.llc.access_line(v2, true);
        }
        if hit2 {
            return self.cfg.l1d.hit_latency + self.cfg.l2.hit_latency;
        }
        let (hit3, _wb3) = self.llc.access_line(line, false);
        if hit3 {
            return self.cfg.l1d.hit_latency + self.cfg.l2.hit_latency + self.cfg.llc.hit_latency;
        }
        self.dram_accesses += 1;
        self.cfg.l1d.hit_latency
            + self.cfg.l2.hit_latency
            + self.cfg.llc.hit_latency
            + self.cfg.dram_latency
    }

    /// Access `bytes` starting at simulated address `addr`. Returns
    /// `(max_line_latency, lines_touched)`.
    #[inline]
    pub fn access(&mut self, addr: u64, bytes: usize, kind: AccessKind) -> (u32, u32) {
        if bytes == 0 {
            return (0, 0);
        }
        let first = addr >> self.line_shift;
        let last = (addr + bytes as u64 - 1) >> self.line_shift;
        let mut worst = 0u32;
        let mut lines = 0u32;
        let mut l = first;
        loop {
            worst = worst.max(self.access_line(l, kind));
            lines += 1;
            if l == last {
                break;
            }
            l += 1;
        }
        (worst, lines)
    }

    pub fn stats(&self) -> MemStats {
        MemStats {
            l1d_accesses: self.l1d.accesses,
            l1d_hits: self.l1d.hits,
            l2_accesses: self.l2.accesses,
            l2_hits: self.l2.hits,
            llc_accesses: self.llc.accesses,
            llc_hits: self.llc.hits,
            dram_accesses: self.dram_accesses,
            writebacks: self.l1d.writebacks + self.l2.writebacks + self.llc.writebacks,
        }
    }

    pub fn reset_stats(&mut self) {
        self.l1d.reset_stats();
        self.l2.reset_stats();
        self.llc.reset_stats();
        self.dram_accesses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn h() -> Hierarchy {
        Hierarchy::new(SystemConfig::default().mem)
    }

    #[test]
    fn cold_access_hits_dram() {
        let mut m = h();
        let (lat, lines) = m.access(0x10000, 4, AccessKind::Read);
        assert_eq!(lines, 1);
        assert_eq!(lat, 2 + 8 + 8 + 160);
        assert_eq!(m.stats().dram_accesses, 1);
    }

    #[test]
    fn warm_access_is_l1_hit() {
        let mut m = h();
        m.access(0x10000, 4, AccessKind::Read);
        let (lat, _) = m.access(0x10000, 4, AccessKind::Read);
        assert_eq!(lat, 2);
        assert_eq!(m.stats().l1d_hits, 1);
    }

    #[test]
    fn same_line_counts_once() {
        let mut m = h();
        let (_, lines) = m.access(0x10000, 64, AccessKind::Read);
        assert_eq!(lines, 1); // aligned 64B spans exactly one line
        let (_, lines) = m.access(0x10020, 64, AccessKind::Read);
        assert_eq!(lines, 2); // misaligned spans two
    }

    #[test]
    fn l2_hit_after_l1_eviction() {
        let mut m = h();
        // Touch a line, then blow L1 (32KB = 512 lines) with a big sweep.
        m.access(0x100000, 4, AccessKind::Read);
        for i in 0..2048u64 {
            m.access(0x200000 + i * 64, 4, AccessKind::Read);
        }
        // L2 is 256KB = 4096 lines, so our line should still be in L2.
        let (lat, _) = m.access(0x100000, 4, AccessKind::Read);
        assert_eq!(lat, 2 + 8);
    }

    #[test]
    fn streaming_l1_hit_rate_is_per_line() {
        let mut m = h();
        // 16 sequential 4-byte reads in one line: 1 miss + 15 hits.
        for i in 0..16 {
            m.access(0x40000 + i * 4, 4, AccessKind::Read);
        }
        let s = m.stats();
        assert_eq!(s.l1d_accesses, 16);
        assert_eq!(s.l1d_hits, 15);
    }

    #[test]
    fn writeback_path_counts() {
        let mut m = h();
        // Dirty many distinct lines mapping over all of L1, then evict them.
        for i in 0..1024u64 {
            m.access(0x300000 + i * 64, 4, AccessKind::Write);
        }
        for i in 0..4096u64 {
            m.access(0x800000 + i * 64, 4, AccessKind::Read);
        }
        assert!(m.l1d.writebacks > 0);
    }

    #[test]
    fn zero_byte_access_is_free() {
        let mut m = h();
        let (lat, lines) = m.access(0x10000, 0, AccessKind::Read);
        assert_eq!((lat, lines), (0, 0));
        assert_eq!(m.stats().l1d_accesses, 0);
    }
}
