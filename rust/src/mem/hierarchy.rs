//! Per-core cache hierarchy (L1D -> L2 -> LLC-shadow -> DRAM) per Table II.
//!
//! `access` walks an address range line-by-line, probes the levels in order,
//! models write-back propagation of dirty victims, and returns the raw
//! latency of the *slowest* line touched plus the number of L1D line
//! accesses (Figure 10's metric). The cost model in `sim::cost` turns raw
//! latencies into effective (overlap-adjusted) cycles.
//!
//! The split between private and shared levels: `l1d` and `l2` are the
//! core's private caches and their results are final. `llc` is the core's
//! private *shadow* of the shared LLC — at one core it **is** the LLC;
//! under multi-core execution it serves as each core's latency predictor
//! while the real shared LLC (+ coherence + DRAM channels) is priced by
//! deterministic trace-and-replay: with tracing enabled (see
//! [`Hierarchy::enable_trace`]) every access that leaves the private L1/L2
//! is recorded as a [`TraceEvent`] for [`crate::mem::shared::replay`].

use crate::config::MemConfig;
use crate::mem::cache::Cache;
use crate::mem::trace::{TraceBuf, TraceEvent, TraceKind, TraceWriter};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccessKind {
    Read,
    Write,
}

/// Aggregate statistics across the hierarchy.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemStats {
    pub l1d_accesses: u64,
    pub l1d_hits: u64,
    pub l2_accesses: u64,
    pub l2_hits: u64,
    pub llc_accesses: u64,
    pub llc_hits: u64,
    pub dram_accesses: u64,
    pub writebacks: u64,
}

impl MemStats {
    pub fn l1d_hit_rate(&self) -> f64 {
        if self.l1d_accesses == 0 {
            0.0
        } else {
            self.l1d_hits as f64 / self.l1d_accesses as f64
        }
    }

    /// Element-wise accumulate (multi-core aggregation across per-core
    /// private hierarchies).
    pub fn add(&mut self, o: &MemStats) {
        self.l1d_accesses += o.l1d_accesses;
        self.l1d_hits += o.l1d_hits;
        self.l2_accesses += o.l2_accesses;
        self.l2_hits += o.l2_hits;
        self.llc_accesses += o.llc_accesses;
        self.llc_hits += o.llc_hits;
        self.dram_accesses += o.dram_accesses;
        self.writebacks += o.writebacks;
    }
}

#[derive(Debug)]
pub struct Hierarchy {
    pub l1d: Cache,
    pub l2: Cache,
    /// The core's private shadow of the shared LLC (see module docs).
    pub llc: Cache,
    cfg: MemConfig,
    line_shift: u32,
    pub dram_accesses: u64,
    /// Next-line stream-prefetcher model: recent line addresses; an access
    /// adjacent to a recent line is treated as prefetched (latency hidden
    /// down to an L1 hit) while still updating cache state. gem5's CHI
    /// configs run stride prefetchers; without this, streaming phases pay
    /// full miss latency and every vectorized/scalar ratio compresses.
    prefetch_tab: [u64; 8],
    pf_idx: usize,
    pub prefetch_hits: u64,
    /// Shared-memory access trace (`None` = tracing off, the serial
    /// default). Records every LLC-level access for phase-2 replay.
    trace: Option<TraceBuf>,
    /// Streaming trace sink (`None` = materialized/off). Takes precedence
    /// over `trace`: with a writer attached, every LLC-level access is
    /// published straight into the bounded per-core ring the concurrent
    /// replay engine is already draining, instead of materializing.
    trace_writer: Option<TraceWriter>,
    /// Core-local logical time stamped onto trace events (set by the
    /// machine before each access group).
    now: f64,
    /// Figure 9 phase stamped onto trace events.
    phase: u8,
    /// Socket of the owning core, stamped onto trace events (0 for serial
    /// machines and single-socket configs; set by `Machine::fork_core`).
    socket: u8,
    /// Whether the current `access()` call has already attributed the
    /// (once-per-access) DRAM bandwidth floor to one of its lines: the cost
    /// model charges `dram_bw` from the single worst-line latency, so
    /// exactly one traced line per access may carry `paid_bw = true`.
    bw_paid_this_access: bool,
}

impl Hierarchy {
    pub fn new(cfg: MemConfig) -> Self {
        assert_eq!(cfg.l1d.line_bytes, cfg.l2.line_bytes);
        assert_eq!(cfg.l2.line_bytes, cfg.llc.line_bytes);
        Hierarchy {
            l1d: Cache::new(cfg.l1d),
            l2: Cache::new(cfg.l2),
            llc: Cache::new(cfg.llc),
            line_shift: cfg.l1d.line_bytes.trailing_zeros(),
            cfg,
            dram_accesses: 0,
            prefetch_tab: [u64::MAX; 8],
            pf_idx: 0,
            prefetch_hits: 0,
            trace: None,
            trace_writer: None,
            now: 0.0,
            phase: 0,
            socket: 0,
            bw_paid_this_access: false,
        }
    }

    pub fn line_bytes(&self) -> usize {
        self.cfg.l1d.line_bytes
    }

    // ---- shared-memory trace hooks ----------------------------------------

    /// Start recording the shared-memory (LLC-level) access trace. The
    /// parallel driver enables this on every forked core; serial machines
    /// leave it off and pay no overhead.
    pub fn enable_trace(&mut self) {
        self.trace = Some(TraceBuf::new());
    }

    pub fn trace_enabled(&self) -> bool {
        self.trace.is_some() || self.trace_writer.is_some()
    }

    /// Take the recorded trace (empty if tracing was never enabled).
    /// Tracing stays enabled with a fresh buffer.
    pub fn take_trace(&mut self) -> TraceBuf {
        self.trace.as_mut().map(std::mem::take).unwrap_or_default()
    }

    /// Attach a streaming trace sink: subsequent LLC-level accesses are
    /// published into the writer's chunk ring (and consumed concurrently by
    /// the replay engine) instead of a materialized [`TraceBuf`]. Replaces
    /// any previous sink or buffer.
    pub fn attach_trace_writer(&mut self, w: TraceWriter) {
        self.trace = None;
        self.trace_writer = Some(w);
    }

    /// Finish and detach the streaming sink, marking this core's stream
    /// complete so the replay's merge can drain past it. (A panic unwinds
    /// through [`TraceWriter`]'s `Drop` to the same effect.) No-op when no
    /// writer is attached.
    pub fn finish_trace(&mut self) {
        if let Some(mut w) = self.trace_writer.take() {
            w.finish();
        }
    }

    /// Stamp the core-local logical time onto subsequent trace events.
    #[inline]
    pub fn set_now(&mut self, t: f64) {
        self.now = t;
    }

    /// Stamp the Figure 9 phase onto subsequent trace events.
    #[inline]
    pub fn set_phase(&mut self, p: u8) {
        self.phase = p;
    }

    /// Stamp the owning core's socket onto subsequent trace events (the
    /// NUMA replay prices each access by the distance from this socket to
    /// the line's home channel group).
    #[inline]
    pub fn set_socket(&mut self, s: u8) {
        self.socket = s;
    }

    #[inline]
    fn record(&mut self, line: u64, kind: TraceKind, write: bool, shadow_hit: bool, paid_bw: bool) {
        let now = self.now;
        let phase = self.phase;
        let socket = self.socket;
        if let Some(w) = self.trace_writer.as_mut() {
            w.push(
                TraceEvent::new(line, kind, write, shadow_hit, paid_bw, phase)
                    .with_socket(socket),
                now,
            );
        } else if let Some(t) = self.trace.as_mut() {
            t.push(
                TraceEvent::new(line, kind, write, shadow_hit, paid_bw, phase)
                    .with_socket(socket),
                now,
            );
        }
    }

    /// Probe a single line address (already shifted). Returns raw latency,
    /// with stream-prefetched misses reported at L1-hit latency.
    #[inline]
    pub fn access_line(&mut self, line: u64, kind: AccessKind) -> u32 {
        // Stream detection *before* the demand access: a line adjacent to a
        // recently touched one would have been prefetched.
        let streamed = self
            .prefetch_tab
            .iter()
            .any(|&p| p != u64::MAX && (line == p + 1 || line == p + 2));
        self.prefetch_tab[self.pf_idx] = line;
        self.pf_idx = (self.pf_idx + 1) % self.prefetch_tab.len();
        let raw = self.demand_line(line, kind, streamed);
        if streamed && raw > self.cfg.l1d.hit_latency {
            self.prefetch_hits += 1;
            return self.cfg.l1d.hit_latency;
        }
        raw
    }

    #[inline]
    fn demand_line(&mut self, line: u64, kind: AccessKind, streamed: bool) -> u32 {
        let write = kind == AccessKind::Write;
        let (hit1, wb1) = self.l1d.access_line(line, write);
        if let Some(v) = wb1 {
            // Dirty L1 victim written back into L2 (allocate, mark dirty).
            let (_, wb2) = self.l2.access_line(v, true);
            if let Some(v2) = wb2 {
                let (wbhit, _wb3) = self.llc.access_line(v2, true);
                // LLC dirty victims go to DRAM; latency hidden (write buffer).
                self.record(v2, TraceKind::Writeback, true, wbhit, false);
            }
        }
        if hit1 {
            return self.cfg.l1d.hit_latency;
        }
        // Fill from L2. Fills are reads regardless of the demand kind;
        // the demand write dirties L1 (handled above via write-allocate).
        let (hit2, wb2) = self.l2.access_line(line, false);
        if let Some(v2) = wb2 {
            let (wbhit, _wb3) = self.llc.access_line(v2, true);
            self.record(v2, TraceKind::Writeback, true, wbhit, false);
        }
        if hit2 {
            return self.cfg.l1d.hit_latency + self.cfg.l2.hit_latency;
        }
        let (hit3, _wb3) = self.llc.access_line(line, false);
        // The bandwidth floor is charged by the cost model once per access
        // call, from the worst line's reported latency — which reaches DRAM
        // iff some line misses here without being stream-clamped. Attribute
        // the floor to the *first* such line only, so the replay can never
        // refund more than phase 1 charged.
        let paid = !hit3 && !streamed && !self.bw_paid_this_access;
        if paid {
            self.bw_paid_this_access = true;
        }
        self.record(line, TraceKind::Demand, write, hit3, paid);
        if hit3 {
            return self.cfg.l1d.hit_latency + self.cfg.l2.hit_latency + self.cfg.llc.hit_latency;
        }
        self.dram_accesses += 1;
        self.cfg.l1d.hit_latency
            + self.cfg.l2.hit_latency
            + self.cfg.llc.hit_latency
            + self.cfg.dram_latency
    }

    /// Access `bytes` starting at simulated address `addr`. Returns
    /// `(max_line_latency, lines_touched)`. One machine-level access call;
    /// the cost model charges the DRAM bandwidth floor at most once per
    /// call, and the trace marks at most one line as having paid it.
    #[inline]
    pub fn access(&mut self, addr: u64, bytes: usize, kind: AccessKind) -> (u32, u32) {
        if bytes == 0 {
            return (0, 0);
        }
        self.bw_paid_this_access = false;
        let first = addr >> self.line_shift;
        let last = (addr + bytes as u64 - 1) >> self.line_shift;
        let mut worst = 0u32;
        let mut lines = 0u32;
        let mut l = first;
        loop {
            worst = worst.max(self.access_line(l, kind));
            lines += 1;
            if l == last {
                break;
            }
            l += 1;
        }
        (worst, lines)
    }

    pub fn stats(&self) -> MemStats {
        MemStats {
            l1d_accesses: self.l1d.accesses,
            l1d_hits: self.l1d.hits,
            l2_accesses: self.l2.accesses,
            l2_hits: self.l2.hits,
            llc_accesses: self.llc.accesses,
            llc_hits: self.llc.hits,
            dram_accesses: self.dram_accesses,
            writebacks: self.l1d.writebacks + self.l2.writebacks + self.llc.writebacks,
        }
    }

    pub fn reset_stats(&mut self) {
        self.l1d.reset_stats();
        self.l2.reset_stats();
        self.llc.reset_stats();
        self.dram_accesses = 0;
        // Prefetcher stats *and* stream state: without clearing the table,
        // lines touched before the reset kept being detected as streams
        // afterwards, leaking both the counter and the predictor state
        // across reset boundaries.
        self.prefetch_hits = 0;
        self.prefetch_tab = [u64::MAX; 8];
        self.pf_idx = 0;
        if let Some(t) = self.trace.as_mut() {
            t.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn h() -> Hierarchy {
        Hierarchy::new(SystemConfig::default().mem)
    }

    #[test]
    fn cold_access_hits_dram() {
        let mut m = h();
        let (lat, lines) = m.access(0x10000, 4, AccessKind::Read);
        assert_eq!(lines, 1);
        assert_eq!(lat, 2 + 8 + 8 + 160);
        assert_eq!(m.stats().dram_accesses, 1);
    }

    #[test]
    fn warm_access_is_l1_hit() {
        let mut m = h();
        m.access(0x10000, 4, AccessKind::Read);
        let (lat, _) = m.access(0x10000, 4, AccessKind::Read);
        assert_eq!(lat, 2);
        assert_eq!(m.stats().l1d_hits, 1);
    }

    #[test]
    fn same_line_counts_once() {
        let mut m = h();
        let (_, lines) = m.access(0x10000, 64, AccessKind::Read);
        assert_eq!(lines, 1); // aligned 64B spans exactly one line
        let (_, lines) = m.access(0x10020, 64, AccessKind::Read);
        assert_eq!(lines, 2); // misaligned spans two
    }

    #[test]
    fn l2_hit_after_l1_eviction() {
        let mut m = h();
        // Touch a line, then blow L1 (32KB = 512 lines) with a big sweep.
        m.access(0x100000, 4, AccessKind::Read);
        for i in 0..2048u64 {
            m.access(0x200000 + i * 64, 4, AccessKind::Read);
        }
        // L2 is 256KB = 4096 lines, so our line should still be in L2.
        let (lat, _) = m.access(0x100000, 4, AccessKind::Read);
        assert_eq!(lat, 2 + 8);
    }

    #[test]
    fn streaming_l1_hit_rate_is_per_line() {
        let mut m = h();
        // 16 sequential 4-byte reads in one line: 1 miss + 15 hits.
        for i in 0..16 {
            m.access(0x40000 + i * 4, 4, AccessKind::Read);
        }
        let s = m.stats();
        assert_eq!(s.l1d_accesses, 16);
        assert_eq!(s.l1d_hits, 15);
    }

    #[test]
    fn writeback_path_counts() {
        let mut m = h();
        // Dirty many distinct lines mapping over all of L1, then evict them.
        for i in 0..1024u64 {
            m.access(0x300000 + i * 64, 4, AccessKind::Write);
        }
        for i in 0..4096u64 {
            m.access(0x800000 + i * 64, 4, AccessKind::Read);
        }
        assert!(m.l1d.writebacks > 0);
    }

    #[test]
    fn zero_byte_access_is_free() {
        let mut m = h();
        let (lat, lines) = m.access(0x10000, 0, AccessKind::Read);
        assert_eq!((lat, lines), (0, 0));
        assert_eq!(m.stats().l1d_accesses, 0);
    }

    #[test]
    fn reset_stats_clears_prefetch_state_and_counters() {
        let mut m = h();
        // Stream enough adjacent lines to score prefetch hits and leave the
        // stream table populated.
        for i in 0..16u64 {
            m.access(0x50000 + i * 64, 4, AccessKind::Read);
        }
        assert!(m.prefetch_hits > 0, "streaming must hit the prefetcher");
        m.reset_stats();
        assert_eq!(m.prefetch_hits, 0, "prefetch_hits must reset");
        assert_eq!(m.stats().l1d_accesses, 0);
        assert_eq!(m.stats().dram_accesses, 0);
        // Regression: the stream table used to survive the reset, so the
        // never-touched line adjacent to the pre-reset stream was still
        // treated as prefetched (latency clamped to an L1 hit). After a true
        // reset it pays its full cold-miss latency.
        let (lat, _) = m.access(0x50000 + 16 * 64, 4, AccessKind::Read);
        assert!(
            lat > 2,
            "line adjacent to pre-reset stream must not be treated as prefetched (lat {lat})"
        );
        assert_eq!(m.prefetch_hits, 0);
    }

    #[test]
    fn trace_records_llc_level_accesses_only() {
        let mut m = h();
        m.enable_trace();
        assert!(m.trace_enabled());
        m.set_phase(2);
        m.set_now(123.0);
        // Cold access: misses L1/L2, reaches the LLC -> one demand event.
        m.access(0x10000, 4, AccessKind::Write);
        // Warm repeat: L1 hit, no LLC-level traffic.
        m.access(0x10000, 4, AccessKind::Read);
        let t = m.take_trace();
        assert_eq!(t.len(), 1);
        let (time, e) = t.iter_timed().next().unwrap();
        assert_eq!(e.kind(), TraceKind::Demand);
        assert_eq!(e.line(), 0x10000 >> 6);
        assert_eq!(time, 123.0);
        assert_eq!(e.phase(), 2);
        assert!(e.write());
        assert!(!e.shadow_hit(), "cold line cannot hit the shadow LLC");
        assert!(e.paid_bw(), "non-streamed DRAM access pays the bandwidth floor");
        // The buffer was taken; tracing continues fresh.
        assert!(m.take_trace().is_empty());
        m.access(0x90000, 4, AccessKind::Read);
        assert_eq!(m.take_trace().len(), 1);
        // An untraced hierarchy records nothing.
        let mut quiet = h();
        quiet.access(0x10000, 4, AccessKind::Read);
        assert!(quiet.take_trace().is_empty());
        assert!(!quiet.trace_enabled());
    }

    #[test]
    fn trace_events_carry_the_configured_socket() {
        let mut m = h();
        m.enable_trace();
        m.access(0x10000, 4, AccessKind::Read);
        m.set_socket(3);
        m.access(0x20000, 4, AccessKind::Read);
        let t = m.take_trace();
        assert_eq!(t.len(), 2);
        assert_eq!(t.get(0).socket(), 0, "default socket is 0 (flat model)");
        assert_eq!(t.get(1).socket(), 3);
    }

    #[test]
    fn streamed_accesses_record_unpaid_bandwidth_floor() {
        let mut m = h();
        m.enable_trace();
        m.access(0x60000, 4, AccessKind::Read); // cold, not streamed
        m.access(0x60000 + 64, 4, AccessKind::Read); // adjacent -> streamed
        let t = m.take_trace();
        assert_eq!(t.len(), 2);
        assert!(t.get(0).paid_bw());
        assert!(!t.get(1).paid_bw(), "prefetched line pays no bandwidth floor in phase 1");
        assert!(!t.get(1).shadow_hit());
    }

    #[test]
    fn multi_line_access_pays_the_bandwidth_floor_at_most_once() {
        let mut m = h();
        m.enable_trace();
        // A cold 4-line access charges one bandwidth floor (the cost model
        // uses the single worst-line latency), so exactly one traced line
        // may carry paid_bw — the replay can never refund more than was
        // charged.
        m.access(0x70000, 256, AccessKind::Read);
        let t = m.take_trace();
        assert_eq!(t.len(), 4);
        assert_eq!(t.iter().filter(|e| e.paid_bw()).count(), 1);
        assert!(t.get(0).paid_bw(), "the first DRAM-reaching line carries the floor");
    }

    #[test]
    fn trace_sees_every_llc_access_of_the_shadow() {
        let mut m = h();
        m.enable_trace();
        // Write enough distinct lines to force L1 and L2 evictions, so the
        // trace carries both demand fills and writeback installs.
        for i in 0..8192u64 {
            m.access(0x200000 + i * 64, 8, AccessKind::Write);
        }
        let t = m.take_trace();
        let demands = t.iter().filter(|e| e.kind() == TraceKind::Demand).count() as u64;
        let wbs = t.iter().filter(|e| e.kind() == TraceKind::Writeback).count() as u64;
        assert!(wbs > 0, "dirty L2 victims must appear in the trace");
        assert_eq!(
            demands + wbs,
            m.stats().llc_accesses,
            "every LLC-level access must be traced exactly once"
        );
    }
}
