//! Compulsory-DRAM-traffic oracle: a deterministic lower bound on the line
//! traffic any execution of `C = A * B` must move between DRAM and the
//! cache hierarchy, per `(matrix, cache budget)`.
//!
//! The bound is the yardstick the fig12 scaling study and `spz mem` report
//! every scheduler against (`achieved_dram_lines / oracle_dram_lines`), in
//! the spirit of spada-sim's `oracle_storage_traffic_model` and SpArch's
//! traffic-bound analysis: scheduler quality measured against an absolute
//! floor instead of only against other schedulers. "Achieved" is the
//! replay's total shared-LLC demand-miss count — every miss fetches exactly
//! one line from DRAM — so bound and measurement are in the same unit
//! (64B lines) by construction.
//!
//! # Soundness
//!
//! Two elementary arguments, both independent of the replacement policy:
//!
//! 1. **Cold traffic.** Every line the workload touches must be fetched at
//!    least once (the first access misses every level). The simulated
//!    allocator line-aligns every region ([`crate::mem::alloc::SimAlloc`]
//!    aligns to 64B or more), so disjoint byte intervals totalling `T`
//!    bytes within a region occupy at least `ceil(T / 64)` distinct lines,
//!    and distinct regions never share a line. Gustavson's algorithm
//!    streams all of A, reads exactly the B rows named by A's column
//!    indices, and writes every output element once.
//!
//! 2. **Capacity-forced re-reads.** While producing output row `i`, the
//!    kernel touches the `|S_i|` distinct B lines of the rows that row
//!    `i` of A names. If `|S_i|` exceeds the cache budget (shared LLC at
//!    the active slicing plus every core's private L1+L2 — the hierarchy
//!    is non-inclusive), then at least `|S_i| - budget` of those touches
//!    miss during that row no matter what the replacement policy kept:
//!    at most `budget` lines can be resident when the row starts. Rows
//!    on one core occupy disjoint time intervals, so their deficits sum;
//!    across cores a single DRAM fetch can satisfy the deficit of up to
//!    `cores` concurrently-processed rows (the LLC is shared), so the
//!    summed deficit is divided by the core count. The B traffic bound is
//!    then `max(cold_B, reuse_B(budget) / cores)` — both are lower bounds
//!    on the same miss population, so their max is too.
//!
//! Degenerate cases come out in closed form: when the budget covers the
//! largest per-row working set the reuse term vanishes and the bound is
//! exactly the cold footprint (cache >= footprint => compulsory misses
//! only), and a bigger budget can never raise any term, so the bound is
//! monotone non-increasing in the budget (pinned by `tests/oracle.rs`).

use crate::config::{MemConfig, SharedMemConfig, SystemConfig};
use crate::matrix::Csr;

/// Cache-line size the whole simulator is built around (Table II).
const LINE: u64 = 64;

fn lines(bytes: u64) -> u64 {
    bytes.div_ceil(LINE)
}

/// The per-matrix-pair oracle: cold line counts for the A stream, the
/// needed B rows, and the C output, plus the per-output-row B working-set
/// sizes the budget-dependent reuse term is computed from. Construction is
/// `O(nnz(A) + nrows(B))`; evaluating the bound at a budget is
/// `O(nrows(A))`.
#[derive(Clone, Debug)]
pub struct OracleBound {
    /// Compulsory lines for streaming all of A (indptr + indices + data).
    pub cold_a_lines: u64,
    /// Compulsory lines for the B rows A actually names (union of their
    /// index/data byte ranges plus the touched indptr entries).
    pub cold_b_lines: u64,
    /// Compulsory lines for writing C (indptr entries plus `c_nnz`
    /// index/data elements).
    pub cold_c_lines: u64,
    /// Per-output-row distinct-B-line working sets `|S_i|`, the input to
    /// the capacity-forced reuse term.
    row_b_lines: Vec<u64>,
}

impl OracleBound {
    /// Build the oracle for `C = A * B` where the finished product has
    /// `c_nnz` nonzeros. Deterministic: depends only on the two sparsity
    /// patterns and the output size.
    pub fn new(a: &Csr, b: &Csr, c_nnz: u64) -> OracleBound {
        // A is streamed in full: the whole indptr walk plus every
        // index/data element exactly once (4B elements, 8B indptr entries,
        // matching `CsrAddrs::csr_sizes`).
        let a_nnz = a.nnz() as u64;
        let cold_a_lines =
            lines((a.nrows as u64 + 1) * 8) + 2 * lines(a_nnz * 4);

        // Needed B rows: every distinct column index of A.
        let mut needed = vec![false; b.nrows];
        for &k in &a.indices {
            if (k as usize) < b.nrows {
                needed[k as usize] = true;
            }
        }

        // Union of the needed rows' line footprints, swept in ascending
        // row order so overlapping/adjacent line intervals merge exactly.
        // The index and data regions have identical element offsets, so
        // one sweep covers both (x2); the indptr region is swept
        // separately (every needed row reads entries k and k+1).
        let mut elem_lines = 0u64;
        let mut elem_last: Option<u64> = None;
        let mut ptr_lines = 0u64;
        let mut ptr_last: Option<u64> = None;
        for (k, &need) in needed.iter().enumerate() {
            if !need {
                continue;
            }
            let (s, e) = (b.indptr[k] as u64, b.indptr[k + 1] as u64);
            if e > s {
                sweep(&mut elem_lines, &mut elem_last, s * 4, e * 4);
            }
            sweep(&mut ptr_lines, &mut ptr_last, k as u64 * 8, (k as u64 + 2) * 8);
        }
        let cold_b_lines = 2 * elem_lines + ptr_lines;

        // C output: the row-pointer walk plus every produced element
        // written once into the index and data regions.
        let cold_c_lines = lines(a.nrows as u64 * 8) + 2 * lines(c_nnz * 4);

        // Per-output-row B working sets. Rows of one A row are distinct
        // (valid CSR), so their B byte ranges are disjoint and the
        // distinct-line count is at least ceil(total bytes / 64) per
        // region.
        let mut row_b_lines = Vec::with_capacity(a.nrows);
        for i in 0..a.nrows {
            let mut bytes = 0u64;
            for &k in &a.indices[a.indptr[i]..a.indptr[i + 1]] {
                if (k as usize) < b.nrows {
                    bytes += b.row_len(k as usize) as u64 * 4;
                }
            }
            row_b_lines.push(2 * lines(bytes));
        }

        OracleBound {
            cold_a_lines,
            cold_b_lines,
            cold_c_lines,
            row_b_lines,
        }
    }

    /// Total compulsory (cold) lines — the bound at an infinite budget.
    pub fn cold_lines(&self) -> u64 {
        self.cold_a_lines + self.cold_b_lines + self.cold_c_lines
    }

    /// Capacity-forced B re-read lines at `budget_lines` of cache, before
    /// the concurrency division: `sum_i max(0, |S_i| - budget)`.
    pub fn reuse_b_lines(&self, budget_lines: u64) -> u64 {
        self.row_b_lines
            .iter()
            .map(|&s| s.saturating_sub(budget_lines))
            .sum()
    }

    /// The oracle: DRAM lines any `cores`-core execution under
    /// `budget_lines` of total cache must move. Monotone non-increasing in
    /// `budget_lines`; equals [`OracleBound::cold_lines`] whenever the
    /// budget covers the largest per-row working set.
    pub fn dram_lines(&self, budget_lines: u64, cores: usize) -> u64 {
        let reuse = self
            .reuse_b_lines(budget_lines)
            .div_ceil(cores.max(1) as u64);
        self.cold_a_lines + self.cold_c_lines + self.cold_b_lines.max(reuse)
    }
}

/// Interval sweep over ascending, non-overlapping byte ranges `[s, e)`
/// within one line-aligned region: counts each line at most once.
fn sweep(count: &mut u64, last: &mut Option<u64>, s: u64, e: u64) {
    debug_assert!(e > s);
    let s_line = s / LINE;
    let e_line = (e - 1) / LINE;
    let from = match *last {
        Some(l) if s_line <= l => l + 1,
        _ => s_line,
    };
    if e_line >= from {
        *count += e_line - from + 1;
    }
    *last = Some(last.map_or(e_line, |l| l.max(e_line)));
}

/// The cache budget (in 64B lines) a `cores`-core run of `sys` has to hold
/// B rows in: the shared LLC at the active slicing policy
/// ([`crate::mem::shared`] scales sliced LLCs with the core count) plus
/// every core's private L1D and L2 — the hierarchy is non-inclusive, so
/// private capacity shelters lines from LLC pressure too.
pub fn budget_lines(sys: &SystemConfig, cores: usize) -> u64 {
    budget_lines_for(&sys.mem, &sys.shared, cores)
}

/// [`budget_lines`] over the raw config pieces (test fixtures poke these
/// directly).
pub fn budget_lines_for(mem: &MemConfig, shared: &SharedMemConfig, cores: usize) -> u64 {
    let llc = crate::mem::shared::scaled_llc_cfg(mem, shared, cores.max(1));
    let private = (mem.l1d.size_bytes + mem.l2.size_bytes) as u64 / LINE;
    llc.size_bytes as u64 / LINE + cores.max(1) as u64 * private
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;

    fn dense(n: usize) -> Csr {
        let rows: Vec<(Vec<u32>, Vec<f32>)> = (0..n)
            .map(|_| ((0..n as u32).collect(), vec![1.0; n]))
            .collect();
        Csr::from_rows(n, n, rows)
    }

    #[test]
    fn dense_block_closed_form() {
        let n = 64usize;
        let a = dense(n);
        let b = dense(n);
        let c_nnz = (n * n) as u64;
        let o = OracleBound::new(&a, &b, c_nnz);
        let elem = lines((n * n) as u64 * 4);
        assert_eq!(o.cold_a_lines, lines((n as u64 + 1) * 8) + 2 * elem);
        // Every B row is needed: the whole element footprint plus the
        // whole indptr walk.
        assert_eq!(o.cold_b_lines, 2 * elem + lines((n as u64 + 1) * 8));
        assert_eq!(o.cold_c_lines, lines(n as u64 * 8) + 2 * elem);
        // Each output row touches all of B.
        assert_eq!(o.reuse_b_lines(0), n as u64 * 2 * elem);
        // Budget covering one full row's working set => cold only.
        assert_eq!(o.dram_lines(2 * elem, 1), o.cold_lines());
    }

    #[test]
    fn identity_b_has_no_reuse_pressure() {
        let a = gen::erdos_renyi(128, 128, 512, 7);
        let b = Csr::identity(128);
        let o = OracleBound::new(&a, &b, a.nnz() as u64);
        // Every per-row working set is at most a line or two of B.
        let max_ws = o.row_b_lines.iter().copied().max().unwrap_or(0);
        assert!(max_ws <= 2 * lines(128 * 4));
        assert_eq!(o.reuse_b_lines(max_ws), 0);
        assert_eq!(o.dram_lines(max_ws, 1), o.cold_lines());
    }

    #[test]
    fn cache_exceeding_footprint_means_cold_only() {
        let a = gen::erdos_renyi(200, 200, 1600, 3);
        let b = gen::erdos_renyi(200, 200, 1600, 5);
        let o = OracleBound::new(&a, &b, 4096);
        let footprint = o.cold_lines();
        assert_eq!(o.dram_lines(footprint, 4), o.cold_lines());
        assert_eq!(o.dram_lines(u64::MAX, 1), o.cold_lines());
    }

    #[test]
    fn bound_monotone_in_budget_and_cores() {
        let a = gen::rmat(256, 256, 2048, 0.57, 0.19, 0.19, 11);
        let b = gen::rmat(256, 256, 2048, 0.57, 0.19, 0.19, 13);
        let o = OracleBound::new(&a, &b, 9000);
        let mut prev = u64::MAX;
        for budget in [0u64, 16, 64, 256, 1024, 4096, 1 << 20] {
            let v = o.dram_lines(budget, 2);
            assert!(v <= prev, "bound must not increase with budget");
            assert!(v >= o.cold_lines(), "bound never drops below cold traffic");
            prev = v;
        }
        // More cores can only relax (divide) the reuse term.
        assert!(o.dram_lines(64, 8) <= o.dram_lines(64, 1));
    }

    #[test]
    fn budget_counts_private_caches_and_slices() {
        let sys = crate::SystemConfig::default();
        let one = budget_lines(&sys, 1);
        let four = budget_lines(&sys, 4);
        assert!(four > one, "sliced LLC + private caches grow with cores");
        let private = (sys.mem.l1d.size_bytes + sys.mem.l2.size_bytes) as u64 / 64;
        assert_eq!(one, sys.mem.llc.size_bytes as u64 / 64 + private);
    }

    #[test]
    fn empty_matrices_are_safe() {
        let a = Csr::from_rows(2, 2, vec![(vec![], vec![]), (vec![], vec![])]);
        let b = Csr::identity(2);
        let o = OracleBound::new(&a, &b, 0);
        assert_eq!(o.cold_b_lines, 0);
        assert_eq!(o.reuse_b_lines(0), 0);
        assert!(o.dram_lines(0, 1) >= o.cold_a_lines);
    }
}
