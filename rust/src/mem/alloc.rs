//! Bump allocator for the simulated address space.
//!
//! Every data structure a workload touches (CSR arrays, accumulators, hash
//! tables, temporary stream buffers, ...) gets a simulated address so that
//! the cache model sees realistic conflict/locality behaviour. Addresses are
//! never dereferenced; the functional computation uses ordinary Rust memory.

/// Simulated-address bump allocator. Page-aligns large allocations the way a
/// real `malloc`/`mmap` would, so large arrays land on distinct pages.
#[derive(Debug, Clone)]
pub struct SimAlloc {
    next: u64,
    /// Total bytes handed out (for reporting peak footprint).
    allocated: u64,
}

pub const PAGE: u64 = 4096;

impl Default for SimAlloc {
    fn default() -> Self {
        Self::new()
    }
}

/// Default start of the simulated address space (away from address zero,
/// like a real process image).
pub const START: u64 = 0x10000;

// ---- multi-core address-space layout --------------------------------------
//
// The layout below is what makes cross-core line identity in the shared
// memory replay *honest*: two cores touching the same line address are
// touching the same bytes of the same object, never two private objects a
// bump allocator happened to alias.

/// Private address-space stride between simulated cores: large enough that
/// 64 cores' regions never collide, and a power of two far above every
/// cache-index bit, so a core's cache behaviour is identical to a
/// base-region run.
pub const CORE_ADDR_SPAN: u64 = 1 << 40;

/// Base of the canonical shared region (above every core's private span):
/// read-shared operands (the B matrix) and the write-shared stitched output
/// both live here, mapped at addresses common to every fork.
pub const SHARED_ADDR_BASE: u64 = 1 << 56;

impl SimAlloc {
    pub fn new() -> Self {
        Self::with_base(START)
    }

    /// An allocator whose first allocation starts at `base` — used to give
    /// each simulated core a disjoint private region and the shared-operand
    /// table its own canonical region (see `sim::Machine::fork_core`).
    pub fn with_base(base: u64) -> Self {
        SimAlloc {
            next: base,
            allocated: 0,
        }
    }

    /// Allocate `bytes` with the given alignment (power of two).
    pub fn alloc_aligned(&mut self, bytes: usize, align: u64) -> u64 {
        debug_assert!(align.is_power_of_two());
        let base = (self.next + align - 1) & !(align - 1);
        self.next = base + bytes as u64;
        self.allocated += bytes as u64;
        base
    }

    /// Allocate with heuristic alignment: big blocks page-aligned, small
    /// blocks 64B (cache-line) aligned.
    pub fn alloc(&mut self, bytes: usize) -> u64 {
        let align = if bytes as u64 >= PAGE { PAGE } else { 64 };
        self.alloc_aligned(bytes, align)
    }

    /// Total simulated bytes allocated so far.
    pub fn footprint(&self) -> u64 {
        self.allocated
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment() {
        let mut a = SimAlloc::new();
        let p1 = a.alloc(8192);
        assert_eq!(p1 % PAGE, 0);
        let p2 = a.alloc(16);
        assert_eq!(p2 % 64, 0);
        assert!(p2 > p1);
    }

    #[test]
    fn non_overlapping() {
        let mut a = SimAlloc::new();
        let p1 = a.alloc(100);
        let p2 = a.alloc(100);
        assert!(p2 >= p1 + 100);
    }

    #[test]
    fn footprint_accumulates() {
        let mut a = SimAlloc::new();
        a.alloc(100);
        a.alloc(50);
        assert_eq!(a.footprint(), 150);
    }
}
