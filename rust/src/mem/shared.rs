//! Phase 2 of the shared-memory model: deterministic, *iterative* replay of
//! the merged per-core traces through one shared LLC (with MESI-lite
//! coherence bookkeeping) and a multi-channel DRAM back end with per-channel
//! bank/row-buffer state.
//!
//! The [`ReplayEngine`] is a *pure function* of the per-core traces and the
//! configuration: host thread scheduling never enters, so per-core stall
//! cycles and coherence counters are bit-reproducible run to run (the same
//! invariant the parallel driver pins for event counts). Four cost classes
//! come out of it, every one of which is exactly zero when a single core
//! runs alone:
//!
//! * **Queueing** — waiting behind *other* cores' lookups at the shared LLC
//!   tag pipeline, and behind other cores' line transfers on the same DRAM
//!   channel. A core's own back-to-back traffic never queues against itself
//!   here (its own throughput is already priced in phase 1), and each
//!   event's charged wait is bounded by one in-flight service per other
//!   core — finite queues/MSHRs — so saturation degrades gracefully
//!   instead of compounding.
//! * **Coherence** — MESI-lite bookkeeping over a line directory: a write to
//!   a line other cores hold costs the writer an upgrade (invalidation
//!   round-trip — with the stitched product mapped into the shared
//!   destination region, the block-boundary output lines exercise exactly
//!   this path), and a read of a line last written by another core costs a
//!   dirty forward.
//! * **Sharing corrections** — phase 1 priced each access against the
//!   core's private *shadow* LLC. Where the real shared LLC disagrees, the
//!   difference is settled here: a shadow miss that hits shared (another
//!   core already pulled B's row in — constructive sharing) refunds the
//!   bandwidth floor phase 1 charged; a shadow hit that misses shared
//!   (capacity interference from the other cores — destructive) pays the
//!   floor plus extra exposed latency.
//! * **Row-buffer interference** — each DRAM channel has banks with one
//!   open row each. The engine tracks the *shared* bank state (all cores
//!   interleaved) next to each core's private *shadow* bank state (the core
//!   running alone) and charges only the **difference** between the two
//!   service outcomes: a row this core's stream kept open that another
//!   core's traffic closed is a row conflict; a row another core happened to
//!   open for us is a (negative-cost) convenience. Single-stream row
//!   behaviour is phase 1's flat `dram_latency`, so at one core the two
//!   states are identical and the delta is exactly zero.
//! * **NUMA distance** — the DRAM channels split into per-socket *channel
//!   groups* and cores sit on sockets
//!   ([`crate::config::SharedMemConfig::sockets`]); every trace event
//!   carries its requester's socket. A shared-LLC miss whose channel
//!   belongs to another socket pays `hops * remote_transfer_cycles` (and
//!   occupies the channel that much longer); a hit served by a remote
//!   socket's slice, a dirty forward from a core on another socket, and an
//!   upgrade whose invalidations cross the interconnect pay
//!   `hops * remote_coherence_cycles`. Distances come from the ring
//!   distance matrix ([`crate::config::SharedMemConfig::socket_distance`]),
//!   so at one socket every hop count — and therefore every NUMA charge —
//!   is exactly zero and the flat model is reproduced bit for bit.
//!
//! ## Iteration (closing the loop)
//!
//! The one-shot replay priced every *demotion* (shadow hit, shared miss) at
//! full freight — bandwidth floor plus exposed latency — even when the same
//! core had already been demoted on the same line: in reality the first
//! demotion refetches the line and the core's later misses on it are
//! predicted, overlapped misses, not surprise stalls. The engine therefore
//! re-replays: demotions found in iteration k invalidate those shadow-LLC
//! lines for iteration k+1, where subsequent shadow-hit/shared-miss events
//! on an invalidated line pay only the (genuinely uncharged) bandwidth
//! floor. Corrections only ever shrink, so iteration totals are monotone
//! non-increasing; the engine stops once the pending correction falls under
//! [`crate::config::SharedMemConfig::replay_epsilon`] or
//! [`crate::config::SharedMemConfig::max_replay_iters`] passes have run, and
//! reports the iteration count and the residual in [`SharedStats`]. (With
//! the current feedback — invalidations alter pricing, never the shared
//! LLC/bank/queue state — demotion triggers are pass-invariant, so the
//! fixed point arrives in at most two passes; the budget and epsilon bound
//! the loop as richer cross-pass feedback lands.)
//!
//! At one core the shared LLC sees exactly the shadow's access sequence with
//! identical geometry, so predictions never diverge and every cost class
//! vanishes — the differential tests pin that the 1-core model reproduces
//! the seed cycle-for-cycle.
//!
//! ## Sharded execution (`replay_shards`)
//!
//! The replay is the hot path of every multi-core job, and most of its
//! per-event cost is *line-local*: the LLC way scan, the directory lookup,
//! and the demotion-trigger bookkeeping all depend only on earlier events
//! touching the **same line's** state. Each pass therefore splits into two
//! sub-phases:
//!
//! 1. **Shard phase** (parallel across
//!    [`crate::config::SharedMemConfig::replay_shards`] scoped threads):
//!    lines partition by `line % replay_shards`. Because the shard count is
//!    a power of two no larger than the LLC set count, and the set index is
//!    `line & (sets - 1)`, every LLC set — and every directory line and
//!    trigger map entry — belongs to exactly one shard, so each shard's
//!    full-geometry LLC/directory replica evolves exactly as the serial
//!    structures restricted to its lines. Each shard walks its slice of the
//!    canonical order and emits one discrete [`EventOutcome`] per demand
//!    event (hit/miss, invalidated sharers, forward hops, demotion flags).
//!    **No floating-point accumulation happens here.**
//! 2. **Merge phase** (serial, canonical order): walks the full interleaved
//!    order, consumes each event's outcome through a per-shard cursor, and
//!    performs every order-coupled update — occupancy tails, DRAM
//!    bank/row-buffer state, and **all** `f64` accumulation — in exactly
//!    the order the serial engine used.
//!
//! Float addition is not associative, so the split is what makes the result
//! **bit-identical at every shard count** (1 shard runs the same two-phase
//! code inline): shards only ever produce discrete facts, and the merge
//! adds cycles in one canonical sequence. Sharding is purely a wall-clock
//! knob — which is also why `replay_shards` never appears in the JSON
//! exports. The per-run [`Scratch`] arena (shard LLC/directory replicas,
//! bank/occupancy vectors) is allocated once and reused across iteration
//! passes.
//!
//! ## Streaming sources (bounded-memory replay)
//!
//! The engine reads its events through a [`TraceSource`]: either fully
//! materialized per-core [`TraceBuf`]s (pilot replays, tests) or live
//! [`TraceStream`]s the kernel cores are still producing. No materialized
//! canonical-order vector exists anymore — every walk (each shard thread
//! and the serial merge) runs its own incremental k-way merge over fresh
//! per-core cursors, consuming chunks as producers seal them, so the
//! replay *overlaps* kernel execution and peak trace memory is bounded by
//! the per-core ring budget
//! ([`crate::config::SharedMemConfig::trace_ring_chunks`]) instead of
//! growing with the event count. Shards hand the merge their
//! [`EventOutcome`]s through small bounded batch channels; producers never
//! block (a full ring spills to disk), shards block only on producers and
//! on merge backpressure, and the merge blocks only on data that is still
//! being produced — an acyclic dependency chain, so the pipeline cannot
//! deadlock. Every cursor decodes times with the same fixed-point
//! expression and every consumer walks the same canonical `(time, core,
//! program-order)` interleaving, so the streamed result is bit-identical
//! to the materialized one at every shard count and every ring budget —
//! spilling is purely a footprint knob, like sharding is a wall-clock one.

use crate::config::{MemConfig, SharedMemConfig, DRAM_BW_CYCLES};
use crate::mem::cache::Cache;
use crate::mem::trace::{
    decode_time, TraceBuf, TraceEvent, TraceKind, TraceStream, TraceStreamStats, MAX_PHASES,
    TRACE_CHUNK,
};
use std::collections::HashMap;
use std::sync::mpsc;

/// Per-core shared-memory counters and stall cycles from one replay.
/// Counters are exact; stall fields are replay-derived cycles. Everything is
/// zero for serial (non-replayed) runs.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SharedStats {
    /// Demand lookups this core issued at the shared LLC.
    pub llc_accesses: u64,
    pub llc_hits: u64,
    pub llc_misses: u64,
    /// Dirty L2 victims this core installed into the shared LLC.
    /// `llc_accesses + writeback_installs` equals the core's shadow-LLC
    /// access count exactly (the replay sees every LLC-level access).
    pub writeback_installs: u64,
    /// Shadow-miss / shared-hit events: another core had already filled the
    /// line (constructive sharing).
    pub shared_fills: u64,
    /// Shadow-hit / shared-miss events: sharing pressure evicted a line the
    /// private shadow still predicted resident (destructive interference).
    pub demotions: u64,
    /// Writes to lines other cores held (MESI upgrade, invalidations sent).
    pub upgrades: u64,
    /// Remote copies this core's writes invalidated.
    pub invalidations_sent: u64,
    /// This core's copies invalidated by other cores' writes.
    pub invalidations_received: u64,
    /// Reads of lines last written by another core (dirty data forwarded).
    pub dirty_forwards: u64,
    /// DRAM row-buffer hits among this core's shared-LLC misses.
    pub row_hits: u64,
    /// Row-buffer misses turned by this core's own stream.
    pub row_misses: u64,
    /// Row-buffer conflicts: rows this core had open that other cores'
    /// interleaved traffic closed.
    pub row_conflicts: u64,
    /// Lines this core filled from a *remote* socket: shared-LLC misses
    /// served by another socket's channel group plus shared-LLC hits served
    /// by a remote socket's slice. Zero at 1 socket by construction.
    pub remote_fills: u64,
    /// Cross-socket coherence transactions this core initiated: dirty
    /// forwards from a core on another socket and upgrades whose
    /// invalidations crossed the interconnect. Zero at 1 socket.
    pub remote_forwards: u64,
    /// Cycles spent queueing behind other cores at the shared LLC.
    pub llc_queue_cycles: f64,
    /// Cycles spent queueing behind other cores' DRAM channel transfers.
    pub dram_queue_cycles: f64,
    /// Upgrade + dirty-forward stalls.
    pub coherence_cycles: f64,
    /// Bandwidth floor + exposed latency paid for demotions.
    pub demotion_cycles: f64,
    /// Bandwidth-floor refunds earned from constructive sharing.
    pub sharing_saved_cycles: f64,
    /// Net row-buffer interference: shared-state service cost minus the
    /// core-alone shadow-state cost (negative when other cores' traffic
    /// happened to leave this core's rows open).
    pub row_extra_cycles: f64,
    /// NUMA distance charges: hop-priced remote transfer and coherence
    /// cycles over all of this core's remote fills and forwards. Exactly
    /// zero at 1 socket.
    pub remote_extra_cycles: f64,
    /// Replay iterations the engine ran (1 = the one-shot model sufficed;
    /// identical across cores of one run, aggregated with `max`).
    pub replay_iters: u32,
    /// Pending stall correction left when iteration stopped (cycles the
    /// next pass would still have reclassified; 0 at the fixed point).
    pub replay_residual: f64,
    /// Packed trace bytes this core recorded in phase 1 (16 per event) —
    /// the footprint the streaming pipeline bounds. Independent of the
    /// ring budget.
    pub trace_bytes_total: u64,
    /// Peak sealed 64KB trace chunks resident in memory for this core
    /// (`<=` the ring budget whenever one is set; cores sum, so the
    /// aggregate bounds the job's whole resident trace footprint).
    /// Ring-dependent — the stable JSON zeroes it alongside `wall_secs`.
    pub trace_peak_resident_chunks: u64,
    /// Trace chunks this core spilled to disk (0 unless a ring budget
    /// forced eviction). Ring-dependent, zeroed in the stable JSON.
    pub spilled_chunks: u64,
    /// DRAM lines this core actually moved: its shared-LLC demand misses
    /// (every miss fetches exactly one line). Stamped by the parallel
    /// driver so it can be compared against the oracle in the same unit;
    /// zero for serial (non-replayed) runs like every other field here.
    pub achieved_dram_lines: u64,
    /// The compulsory-traffic oracle lower bound for the whole run
    /// ([`crate::mem::oracle::OracleBound`] at the run's cache budget and
    /// core count). A per-run fact stamped identically on every core and
    /// aggregated with `max`, like `replay_iters`.
    pub oracle_dram_lines: u64,
}

impl SharedStats {
    /// Element-wise accumulate (multi-core aggregation). Stall cycles and
    /// counters sum; the run-wide `replay_iters`/`replay_residual` take the
    /// max (they are per-run facts stamped on every core).
    pub fn add(&mut self, o: &SharedStats) {
        self.llc_accesses += o.llc_accesses;
        self.llc_hits += o.llc_hits;
        self.llc_misses += o.llc_misses;
        self.writeback_installs += o.writeback_installs;
        self.shared_fills += o.shared_fills;
        self.demotions += o.demotions;
        self.upgrades += o.upgrades;
        self.invalidations_sent += o.invalidations_sent;
        self.invalidations_received += o.invalidations_received;
        self.dirty_forwards += o.dirty_forwards;
        self.row_hits += o.row_hits;
        self.row_misses += o.row_misses;
        self.row_conflicts += o.row_conflicts;
        self.remote_fills += o.remote_fills;
        self.remote_forwards += o.remote_forwards;
        self.llc_queue_cycles += o.llc_queue_cycles;
        self.dram_queue_cycles += o.dram_queue_cycles;
        self.coherence_cycles += o.coherence_cycles;
        self.demotion_cycles += o.demotion_cycles;
        self.sharing_saved_cycles += o.sharing_saved_cycles;
        self.row_extra_cycles += o.row_extra_cycles;
        self.remote_extra_cycles += o.remote_extra_cycles;
        self.replay_iters = self.replay_iters.max(o.replay_iters);
        self.replay_residual = self.replay_residual.max(o.replay_residual);
        self.trace_bytes_total += o.trace_bytes_total;
        self.trace_peak_resident_chunks += o.trace_peak_resident_chunks;
        self.spilled_chunks += o.spilled_chunks;
        self.achieved_dram_lines += o.achieved_dram_lines;
        self.oracle_dram_lines = self.oracle_dram_lines.max(o.oracle_dram_lines);
    }

    /// Shared-LLC demand hit rate.
    pub fn llc_hit_rate(&self) -> f64 {
        if self.llc_accesses == 0 {
            0.0
        } else {
            self.llc_hits as f64 / self.llc_accesses as f64
        }
    }

    /// Coherence protocol events this core initiated.
    pub fn coherence_events(&self) -> u64 {
        self.upgrades + self.dirty_forwards
    }

    /// Achieved DRAM traffic over the oracle lower bound (>= 1.0 whenever
    /// both are stamped — the model-honesty invariant the CI oracle gate
    /// enforces). 0.0 when no oracle was stamped (serial runs).
    pub fn oracle_ratio(&self) -> f64 {
        if self.oracle_dram_lines == 0 {
            0.0
        } else {
            self.achieved_dram_lines as f64 / self.oracle_dram_lines as f64
        }
    }

    /// Net replay-derived stall cycles (sharing refunds subtract).
    pub fn stall_cycles(&self) -> f64 {
        self.llc_queue_cycles + self.dram_queue_cycles + self.coherence_cycles
            + self.demotion_cycles
            + self.row_extra_cycles
            + self.remote_extra_cycles
            - self.sharing_saved_cycles
    }
}

/// Everything one replay produced.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ReplayOutcome {
    /// Per-core counters and stall totals, indexed by core id.
    pub per_core: Vec<SharedStats>,
    /// Per-core stall cycles bucketed by the phase each traced access
    /// charged into (fold these into the matching `phase_cycles` /
    /// `cycles`; entries past the machine's phase count stay zero).
    pub per_core_phase_stalls: Vec<[f64; MAX_PHASES]>,
    /// Total transfer occupancy per DRAM channel, in cycles.
    pub channel_busy_cycles: Vec<f64>,
}

/// MESI-lite directory state for one line: which cores plausibly hold it in
/// their private caches (set on demand fill, cleared on writeback or remote
/// invalidation) and who wrote it last.
struct LineState {
    sharers: u64,
    /// Last writer (`u8::MAX` = none / written back).
    owner: u8,
    dirty: bool,
}

const NO_OWNER: u8 = u8::MAX;

/// One DRAM bank's row-buffer state: the open row and which core's access
/// opened it.
#[derive(Clone, Copy)]
struct BankState {
    open_row: u64,
    owner: u8,
}

const NO_ROW: u64 = u64::MAX;

/// Per-core shadow-LLC invalidations discovered by one pass: for each
/// demoted `(core, line)`, the merge-order position of the *first* demotion
/// (later shadow-hit misses on that line are predicted misses, not
/// surprises).
type InvalMap = HashMap<u64, usize>;

/// What one replay pass produced beyond the outcome: the demotion-derived
/// invalidation points and the stall cycles the *next* pass would reclassify
/// if it ran with them.
struct Pass {
    outcome: ReplayOutcome,
    triggers: Vec<InvalMap>,
    pending: f64,
}

/// Every discrete fact a shard's line-local replay of one demand event
/// hands the merge pass: the shared-LLC outcome, the coherence transitions,
/// and the demotion classification. Deliberately contains no `f64` — all
/// cycle accumulation happens in the serial merge, in canonical order, so
/// the result cannot depend on the shard count.
#[derive(Clone, Copy, Default)]
struct EventOutcome {
    /// Sharers a write-upgrade invalidated (0 = no upgrade happened).
    inval_mask: u64,
    /// Shared-LLC lookup outcome.
    hit: bool,
    /// Max hop distance to the invalidated sharers (upgrade round-trip).
    coh_hops: u8,
    /// The read hit dirty data last written by another core.
    fwd: bool,
    /// Hop distance to that forwarding owner's socket.
    fwd_hops: u8,
    /// Demotion on a line an earlier pass already invalidated (pays the
    /// bandwidth floor only).
    demote_invalidated: bool,
    /// Repeat demotion within this pass whose exposure penalty the next
    /// pass would drop (feeds the pending correction).
    demote_repeat: bool,
}

/// One shard's private replay state: a full-geometry LLC replica and
/// directory that only ever see this shard's lines (whole sets are
/// shard-private — see the module docs) and the shard's slice of the
/// demotion trigger maps. Reused across iteration passes via
/// [`ShardState::reset`].
struct ShardState {
    llc: Cache,
    directory: HashMap<u64, LineState>,
    /// Per-core demotion trigger points for lines this shard owns.
    triggers: Vec<InvalMap>,
}

impl ShardState {
    fn reset(&mut self) {
        self.llc.reset();
        self.directory.clear();
        for t in &mut self.triggers {
            t.clear();
        }
    }
}

/// The per-run replay arena: everything allocated once in [`ReplayEngine::
/// run`] and reused by every iteration pass — the shard LLC/directory
/// replicas and the merge phase's occupancy/bank scratch vectors.
struct Scratch {
    states: Vec<ShardState>,
    /// Socket of each core (locates the remote party of coherence events).
    core_socket: Vec<usize>,
    // --- merge-phase scratch, reset at the start of every pass ---
    /// Shared-LLC tag-pipeline occupancy tail per core.
    llc_busy: Vec<f64>,
    /// DRAM transfer occupancy tail per channel per core.
    chan_busy: Vec<Vec<f64>>,
    /// Shared bank state (all cores interleaved).
    bank: Vec<BankState>,
    /// Per-core shadow bank state (the core running alone).
    shadow_bank: Vec<Vec<u64>>,
}

impl Scratch {
    fn reset_merge(&mut self) {
        self.llc_busy.iter_mut().for_each(|x| *x = 0.0);
        for cb in &mut self.chan_busy {
            cb.iter_mut().for_each(|x| *x = 0.0);
        }
        self.bank
            .iter_mut()
            .for_each(|b| *b = BankState { open_row: NO_ROW, owner: NO_OWNER });
        for sb in &mut self.shadow_bank {
            sb.iter_mut().for_each(|r| *r = NO_ROW);
        }
    }
}

/// Where the engine's events come from: fully materialized per-core
/// [`TraceBuf`]s (pilot replays, tests, synthetic fixtures) or live
/// bounded-memory [`TraceStream`]s still being produced by the kernel
/// cores. Index = core id in both arms. Every walk re-reads the source
/// through fresh [`EventCursor`]s, so streams must be re-readable — sealed
/// chunks stay addressable (resident or spilled) for the engine's later
/// corrective passes.
pub enum TraceSource<'a> {
    Bufs(&'a [TraceBuf]),
    Streams(&'a [TraceStream]),
}

impl<'a> TraceSource<'a> {
    fn cores(&self) -> usize {
        match self {
            TraceSource::Bufs(b) => b.len(),
            TraceSource::Streams(s) => s.len(),
        }
    }

    /// A fresh sequential cursor over one core's events.
    fn cursor(&self, core: usize, sockets: usize) -> EventCursor<'a> {
        match self {
            TraceSource::Bufs(bufs) => EventCursor::Buf {
                buf: &bufs[core],
                core: core as u32,
                sockets,
                i: 0,
                acc_q: 0,
            },
            TraceSource::Streams(streams) => EventCursor::Stream {
                reader: streams[core].reader(),
                core: core as u32,
                sockets,
            },
        }
    }

    /// Phase-1 footprint accounting for one core, stamped into its
    /// [`SharedStats`] after the run. A materialized buffer is, by
    /// definition, fully resident and never spilled.
    fn trace_stats(&self, core: usize) -> TraceStreamStats {
        match self {
            TraceSource::Bufs(bufs) => {
                let len = bufs[core].len();
                TraceStreamStats {
                    bytes_total: 16 * len as u64,
                    peak_resident_chunks: len.div_ceil(TRACE_CHUNK) as u64,
                    spilled_chunks: 0,
                }
            }
            TraceSource::Streams(streams) => streams[core].stats(),
        }
    }
}

/// A sequential walk of one core's trace with absolute times decoded — a
/// per-core head of the canonical merge. Both arms share the exact decode
/// expression ([`decode_time`] over the accumulated quantized deltas), so
/// merge keys and every downstream `f64` are bit-identical across sources.
///
/// The cursor is also the construction boundary for the self-describing
/// socket stamps (the job the materialized order-building pass used to
/// own): every event's stamp is asserted against the topology. A hard
/// assert (not `debug_assert!`) because an out-of-range stamp would wrap
/// the ring-distance arithmetic in release builds and charge phantom NUMA
/// hops silently.
enum EventCursor<'a> {
    Buf { buf: &'a TraceBuf, core: u32, sockets: usize, i: usize, acc_q: u64 },
    Stream { reader: crate::mem::trace::TraceReader, core: u32, sockets: usize },
}

impl EventCursor<'_> {
    fn next(&mut self) -> Option<(f64, TraceEvent)> {
        let (core, sockets, item) = match self {
            EventCursor::Buf { buf, core, sockets, i, acc_q } => {
                let item = if *i < buf.len() {
                    let e = buf.get(*i);
                    *i += 1;
                    *acc_q += e.dt_q();
                    Some((decode_time(*acc_q), e))
                } else {
                    None
                };
                (*core, *sockets, item)
            }
            EventCursor::Stream { reader, core, sockets } => (*core, *sockets, reader.next()),
        };
        if let Some((_, e)) = item {
            let socket = e.socket();
            assert!(
                (socket as usize) < sockets,
                "core {core}: trace-stamped socket {socket} is out of range for \
                 {sockets} socket(s) — stamp sockets in [0, sockets)"
            );
        }
        item
    }
}

/// The canonical deterministic interleaving as an *incremental* k-way
/// merge: `(time, core, index)` ordered by local time, ties breaking toward
/// the lower core id, then program order — exactly the sequence the old
/// materialized order vector held, but produced lazily so no O(events)
/// index is ever built and streaming sources are consumed as their
/// producers seal chunks. Each core's decoded times are monotone, so the
/// heap walk is O(N log cores) and yields the sequence a full sort under
/// the same comparator would.
struct CanonicalMerge<'a> {
    cursors: Vec<EventCursor<'a>>,
    heap: std::collections::BinaryHeap<std::cmp::Reverse<Head>>,
}

/// Head of one core's timed stream, ordered by the canonical
/// `(time, core, index)` key and carrying the decoded event.
struct Head {
    time: f64,
    core: u32,
    index: u64,
    event: TraceEvent,
}

impl PartialEq for Head {
    fn eq(&self, o: &Head) -> bool {
        self.cmp(o) == std::cmp::Ordering::Equal
    }
}
impl Eq for Head {}
impl PartialOrd for Head {
    fn partial_cmp(&self, o: &Head) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Head {
    fn cmp(&self, o: &Head) -> std::cmp::Ordering {
        self.time
            .total_cmp(&o.time)
            .then(self.core.cmp(&o.core))
            .then(self.index.cmp(&o.index))
    }
}

impl<'a> CanonicalMerge<'a> {
    fn new(source: &TraceSource<'a>, sockets: usize) -> CanonicalMerge<'a> {
        let mut cursors: Vec<EventCursor<'a>> =
            (0..source.cores()).map(|c| source.cursor(c, sockets)).collect();
        let mut heap = std::collections::BinaryHeap::with_capacity(cursors.len());
        for (c, cur) in cursors.iter_mut().enumerate() {
            if let Some((time, event)) = cur.next() {
                heap.push(std::cmp::Reverse(Head { time, core: c as u32, index: 0, event }));
            }
        }
        CanonicalMerge { cursors, heap }
    }

    fn next(&mut self) -> Option<(f64, u32, TraceEvent)> {
        let std::cmp::Reverse(h) = self.heap.pop()?;
        if let Some((time, event)) = self.cursors[h.core as usize].next() {
            self.heap.push(std::cmp::Reverse(Head {
                time,
                core: h.core,
                index: h.index + 1,
                event,
            }));
        }
        Some((h.time, h.core, h.event))
    }
}

/// Events per [`EventOutcome`] batch a shard sends the merge, and the
/// bounded batch-queue depth per shard. Bounding the queue is what keeps
/// the *outcome* side O(1) per shard too: a shard that runs far ahead of
/// the merge parks on `send` instead of buffering the whole run.
const OUTCOME_BATCH: usize = 1024;
const OUTCOME_QUEUE_BATCHES: usize = 64;

/// The merge's view of one shard's outcome stream: batches pulled off the
/// channel, consumed strictly in canonical order.
struct OutcomeCursor {
    rx: mpsc::Receiver<Vec<EventOutcome>>,
    batch: Vec<EventOutcome>,
    i: usize,
}

impl OutcomeCursor {
    fn next(&mut self) -> EventOutcome {
        while self.i >= self.batch.len() {
            self.batch = self
                .rx
                .recv()
                .expect("shard outcome stream ended before its events were consumed");
            self.i = 0;
        }
        let o = self.batch[self.i];
        self.i += 1;
        o
    }
}

/// The shared LLC's geometry for `cores` active cores. In sliced mode every
/// active core brings one Table II slice of capacity, scaled through the
/// *set count* (power-of-two slices keep the sets a power of two and the
/// per-lookup way scan O(base ways)); odd core counts round up to the next
/// power-of-two slicing via a second way bank. At 1 core both modes are
/// exactly the shadow geometry.
pub(crate) fn scaled_llc_cfg(
    mem: &MemConfig,
    cfg: &SharedMemConfig,
    cores: usize,
) -> crate::config::CacheConfig {
    let mut llc_cfg = mem.llc;
    if cfg.llc_sliced {
        let sets_scale = if cores.is_power_of_two() {
            cores
        } else {
            cores.next_power_of_two() / 2
        };
        let ways_scale = cores.div_ceil(sets_scale);
        llc_cfg.size_bytes *= sets_scale * ways_scale;
        llc_cfg.ways *= ways_scale;
    }
    llc_cfg
}

/// The iterative trace-replay engine (see the module docs). Construct with
/// [`ReplayEngine::new`] and call [`ReplayEngine::run`]; the free function
/// [`replay`] is the one-call convenience wrapper.
pub struct ReplayEngine<'a> {
    mem: &'a MemConfig,
    cfg: &'a SharedMemConfig,
    source: TraceSource<'a>,
}

impl<'a> ReplayEngine<'a> {
    /// An engine over the merged per-core materialized traces (index =
    /// core id): the historical constructor, now a thin wrapper over
    /// [`ReplayEngine::from_source`].
    pub fn new(
        mem: &'a MemConfig,
        cfg: &'a SharedMemConfig,
        traces: &'a [TraceBuf],
    ) -> ReplayEngine<'a> {
        ReplayEngine::from_source(mem, cfg, TraceSource::Bufs(traces))
    }

    /// An engine over any per-core [`TraceSource`] (index = core id).
    /// Supports up to 64 cores (directory bitmaps). The configuration must
    /// satisfy [`SharedMemConfig::validate`] — the driver and CLI `ensure!`
    /// it with a clean error; the engine asserts it rather than silently
    /// clamping. With a [`TraceSource::Streams`] source, [`ReplayEngine::
    /// run`] may be called while producers are still writing: it consumes
    /// chunks as they seal and returns only after every stream finished.
    pub fn from_source(
        mem: &'a MemConfig,
        cfg: &'a SharedMemConfig,
        source: TraceSource<'a>,
    ) -> ReplayEngine<'a> {
        let cores = source.cores();
        assert!(
            (1..=64).contains(&cores),
            "replay supports 1..=64 cores, got {cores}"
        );
        if let Err(e) = cfg.validate() {
            panic!("invalid SharedMemConfig handed to the replay engine: {e}");
        }
        ReplayEngine { mem, cfg, source }
    }

    /// Socket of each core, read back from its trace's first event — used
    /// to locate the *remote party* of a coherence transaction (the dirty
    /// line's owner, an upgrade's sharers). The requester's own socket is
    /// read per event (events are self-describing), so a trace whose stamps
    /// vary mid-stream still prices each access correctly. Cores with empty
    /// traces resolve to socket 0; every stamp is validated against the
    /// topology by the merge cursors (no silent clamping). On a streaming
    /// source this blocks until each core seals its first chunk or
    /// finishes — the same data dependency the first pass has anyway.
    fn core_sockets(&self) -> Vec<usize> {
        match &self.source {
            TraceSource::Bufs(bufs) => bufs
                .iter()
                .map(|t| t.iter().next().map(|e| e.socket() as usize).unwrap_or(0))
                .collect(),
            TraceSource::Streams(streams) => streams
                .iter()
                .map(|s| s.reader().next().map(|(_, e)| e.socket() as usize).unwrap_or(0))
                .collect(),
        }
    }

    /// Run passes until the pending correction falls under
    /// `replay_epsilon` or `max_replay_iters` passes have run, and return
    /// the final pass's outcome with `replay_iters`/`replay_residual`
    /// stamped on every core's [`SharedStats`].
    pub fn run(&self) -> ReplayOutcome {
        let cores = self.source.cores();
        // Both guaranteed by `SharedMemConfig::validate` in `new` — used
        // directly, never clamped.
        let max_iters = self.cfg.max_replay_iters;
        let eps = self.cfg.replay_epsilon;

        let mut scratch = self.scratch();
        let mut inval: Vec<InvalMap> = vec![InvalMap::new(); cores];
        let mut pass = self.pass(&mut scratch, &inval);
        let mut iters = 1u32;
        while pass.pending > eps && iters < max_iters {
            // Fold this pass's demotion points into the invalidation set
            // (keeping the earliest position per line) and re-replay.
            for (c, trig) in pass.triggers.iter().enumerate() {
                for (&line, &pos) in trig {
                    let e = inval[c].entry(line).or_insert(pos);
                    *e = (*e).min(pos);
                }
            }
            pass = self.pass(&mut scratch, &inval);
            iters += 1;
        }
        let mut outcome = pass.outcome;
        for (c, s) in outcome.per_core.iter_mut().enumerate() {
            s.replay_iters = iters;
            s.replay_residual = pass.pending;
            // Phase-1 footprint accounting (final here: the first pass
            // drained every stream, so all producers have finished).
            let ts = self.source.trace_stats(c);
            s.trace_bytes_total = ts.bytes_total;
            s.trace_peak_resident_chunks = ts.peak_resident_chunks;
            s.spilled_chunks = ts.spilled_chunks;
        }
        outcome
    }

    /// Build the per-run arena: one LLC/directory replica per shard and the
    /// merge scratch. No event is read here except each core's first (for
    /// the socket table) — the canonical order is merged incrementally by
    /// every pass, never materialized.
    fn scratch(&self) -> Scratch {
        let cores = self.source.cores();
        let cfg = self.cfg;
        let shards = cfg.replay_shards;
        let llc_cfg = scaled_llc_cfg(self.mem, cfg, cores);
        // The partition is only set-consistent while whole LLC sets stay
        // shard-private (see the module docs); a hand-shrunk LLC with fewer
        // sets than shards is a construction error, not something to clamp.
        assert!(
            shards <= llc_cfg.sets(),
            "replay_shards ({shards}) must not exceed the shared LLC's {} sets: \
             the line partition must keep whole sets shard-private",
            llc_cfg.sets()
        );
        let states = (0..shards)
            .map(|_| ShardState {
                llc: Cache::new(llc_cfg),
                directory: HashMap::new(),
                triggers: vec![InvalMap::new(); cores],
            })
            .collect();
        let (channels, banks) = (cfg.dram_channels, cfg.dram_banks);
        Scratch {
            states,
            core_socket: self.core_sockets(),
            llc_busy: vec![0.0; cores],
            chan_busy: vec![vec![0.0; cores]; channels],
            bank: vec![BankState { open_row: NO_ROW, owner: NO_OWNER }; channels * banks],
            shadow_bank: vec![vec![NO_ROW; channels * banks]; cores],
        }
    }

    /// One deterministic pass over the merged traces: the parallel shard
    /// walks pipelined into the serial canonical-order merge (see the
    /// module docs). Every consumer runs its own incremental k-way merge
    /// over the source; shards emit discrete outcomes through bounded batch
    /// channels the merge drains concurrently. `inval` carries the
    /// demotion-derived shadow invalidations of earlier passes; the pass
    /// reports its own demotion points and the pending correction a further
    /// pass would apply.
    fn pass(&self, sc: &mut Scratch, inval: &[InvalMap]) -> Pass {
        let cfg = self.cfg;
        let cores = self.source.cores();
        let sockets = cfg.sockets;
        let shards = sc.states.len();
        let shard_mask = (shards - 1) as u64;

        sc.reset_merge();
        let Scratch { states, core_socket, llc_busy, chan_busy, bank, shadow_bank } = sc;

        // ---- Shard walk: the line-local heavy lifting (LLC way scans,
        // directory hashing, trigger maps). Walks the *full* canonical
        // order (it needs the global positions anyway) and processes only
        // its own lines, emitting one discrete outcome per demand event.
        let shard_walk =
            |state: &mut ShardState, shard_ix: usize, emit: &mut dyn FnMut(EventOutcome)| {
                state.reset();
                let mut merge = CanonicalMerge::new(&self.source, sockets);
                let mut next_pos = 0usize;
                while let Some((_, ci, e)) = merge.next() {
                    // Global canonical position (counts every core's
                    // writebacks and demands — identical in every walk).
                    let pos = next_pos;
                    next_pos += 1;
                    let line = e.line();
                    if (line & shard_mask) as usize != shard_ix {
                        continue;
                    }
                    let c = ci as usize;
                    match e.kind() {
                        TraceKind::Writeback => {
                            // The install updates the shared LLC exactly as
                            // it did the shadow and means the line has left
                            // this core's private caches; the occupancy and
                            // counter side live in the merge.
                            let _ = state.llc.access_line(line, true);
                            if let Some(st) = state.directory.get_mut(&line) {
                                st.sharers &= !(1u64 << c);
                                if st.owner == c as u8 {
                                    st.owner = NO_OWNER;
                                }
                            }
                        }
                        TraceKind::Demand => {
                            // The event's own stamp (validated by the merge
                            // cursors — never clamped).
                            let my_sock = e.socket() as usize;
                            // The lookup itself — the same fill the shadow
                            // performed.
                            let (hit, _victim) = state.llc.access_line(line, false);
                            let mut o = EventOutcome { hit, ..EventOutcome::default() };

                            // MESI-lite coherence bookkeeping.
                            let st = state.directory.entry(line).or_insert(LineState {
                                sharers: 0,
                                owner: NO_OWNER,
                                dirty: false,
                            });
                            if e.write() {
                                let others = st.sharers & !(1u64 << c);
                                if others != 0 {
                                    o.inval_mask = others;
                                    // The upgrade round-trip is bounded by
                                    // the furthest sharer it must
                                    // invalidate.
                                    let mut hops = 0usize;
                                    for (k, &sock) in core_socket.iter().enumerate() {
                                        if (others >> k) & 1 == 1 {
                                            hops = hops.max(cfg.socket_distance(my_sock, sock));
                                        }
                                    }
                                    o.coh_hops = hops as u8;
                                }
                                st.sharers = 1u64 << c;
                                st.owner = c as u8;
                                st.dirty = true;
                            } else {
                                if st.dirty && st.owner != NO_OWNER && st.owner != c as u8 {
                                    // A forward from a core on another
                                    // socket crosses the interconnect.
                                    o.fwd = true;
                                    o.fwd_hops = cfg
                                        .socket_distance(my_sock, core_socket[st.owner as usize])
                                        as u8;
                                    // Forwarded and downgraded to shared.
                                    st.dirty = false;
                                }
                                st.sharers |= 1u64 << c;
                            }

                            if !hit && e.shadow_hit() {
                                // Demotion classification against the
                                // earlier passes' invalidation points and
                                // this pass's own trigger map (both keyed
                                // by the *global* canonical position).
                                o.demote_invalidated =
                                    inval[c].get(&line).map(|&q| q < pos).unwrap_or(false);
                                match state.triggers[c].get(&line).copied() {
                                    Some(q) if q < pos => {
                                        if !o.demote_invalidated {
                                            o.demote_repeat = true;
                                        }
                                    }
                                    _ => {
                                        state.triggers[c].entry(line).or_insert(pos);
                                    }
                                }
                            }
                            emit(o);
                        }
                    }
                }
            };

        // ---- Merge walk: its own pass over the full canonical order,
        // consuming each demand event's outcome from its shard. Every f64
        // accumulation and every order-coupled structure (queue tails,
        // shared/shadow banks) lives here, in exactly the sequence the
        // serial engine used — bit-identical at any shard count and ring
        // budget.
        let channels = cfg.dram_channels;
        let banks = cfg.dram_banks;
        let row_lines = cfg.row_buffer_lines as u64;
        // First-touch page placement: lines of a 4KB page interleave over
        // the *home* socket's channel group, the home being whichever
        // socket demanded the page first in canonical merge order. The map
        // is rebuilt per pass, which is deterministic (the demand order is
        // pass-invariant) and exactly reproduces the blind interleave at
        // one socket (home is always 0 and the group is every channel).
        let group = (channels / cfg.sockets.max(1)).max(1);
        let first_touch = cfg.page_placement == crate::config::PagePlacement::FirstTouch;
        let merge_walk = |next_outcome: &mut dyn FnMut(usize) -> EventOutcome| -> (
            Vec<SharedStats>,
            Vec<[f64; MAX_PHASES]>,
            Vec<f64>,
            f64,
        ) {
            let mut channel_busy_cycles = vec![0.0f64; channels];
            let mut stats = vec![SharedStats::default(); cores];
            let mut phase_stalls = vec![[0.0f64; MAX_PHASES]; cores];
            let mut page_home: HashMap<u64, u8> = HashMap::new();
            let mut pending = 0.0f64;
            let mut merge = CanonicalMerge::new(&self.source, sockets);
            while let Some((t, ci, e)) = merge.next() {
                let c = ci as usize;
                let line = e.line();
                match e.kind() {
                    TraceKind::Writeback => {
                        // State + occupancy only: the write buffer hides the
                        // latency, but the install occupies the tag pipeline.
                        stats[c].writeback_installs += 1;
                        llc_busy[c] = t.max(llc_busy[c]) + cfg.llc_service_cycles;
                    }
                    TraceKind::Demand => {
                        let o = next_outcome((line & shard_mask) as usize);
                        stats[c].llc_accesses += 1;
                        let my_sock = e.socket() as usize;
                        let mut extra = 0.0f64;

                        // (1) Queue behind other cores' outstanding LLC
                        // lookups. The charged wait is capped at one service
                        // slot per other core: phase-1 issue times feel no
                        // backpressure, so under sustained overload the raw
                        // tail-minus-arrival gap would compound without
                        // bound, while a real core waits at most for the
                        // bounded queue (MSHRs) ahead of it.
                        let mut other = 0.0f64;
                        for (k, &b) in llc_busy.iter().enumerate() {
                            if k != c && b > other {
                                other = b;
                            }
                        }
                        let wait = (other - t)
                            .max(0.0)
                            .min((cores - 1) as f64 * cfg.llc_service_cycles);
                        stats[c].llc_queue_cycles += wait;
                        extra += wait;
                        llc_busy[c] = t.max(llc_busy[c]).max(other) + cfg.llc_service_cycles;

                        // (2)+(3) The lookup and the MESI-lite transitions
                        // ran in the shard walk; settle their costs here.
                        if e.write() {
                            if o.inval_mask != 0 {
                                stats[c].upgrades += 1;
                                stats[c].invalidations_sent += o.inval_mask.count_ones() as u64;
                                stats[c].coherence_cycles += cfg.upgrade_cycles;
                                extra += cfg.upgrade_cycles;
                                for (k, s) in stats.iter_mut().enumerate() {
                                    if k != c && (o.inval_mask >> k) & 1 == 1 {
                                        s.invalidations_received += 1;
                                    }
                                }
                                if o.coh_hops > 0 {
                                    stats[c].remote_forwards += 1;
                                    let x = o.coh_hops as f64 * cfg.remote_coherence_cycles;
                                    stats[c].remote_extra_cycles += x;
                                    extra += x;
                                }
                            }
                        } else if o.fwd {
                            stats[c].dirty_forwards += 1;
                            stats[c].coherence_cycles += cfg.dirty_forward_cycles;
                            extra += cfg.dirty_forward_cycles;
                            if o.fwd_hops > 0 {
                                stats[c].remote_forwards += 1;
                                let x = o.fwd_hops as f64 * cfg.remote_coherence_cycles;
                                stats[c].remote_extra_cycles += x;
                                extra += x;
                            }
                        }

                        // DRAM bank/row-buffer geometry (used by both
                        // branches below): within a channel, consecutive
                        // lines fill one bank's row for `row_buffer_lines`
                        // lines before rotating banks.
                        let (ch, home_sock) = if first_touch {
                            // 64 lines of 64B = one 4KB page.
                            let page = line >> 6;
                            let home =
                                *page_home.entry(page).or_insert(my_sock as u8) as usize;
                            (home * group + (line % group as u64) as usize, home)
                        } else {
                            let ch = (line % channels as u64) as usize;
                            (ch, cfg.socket_of_channel(ch))
                        };
                        let in_chan = line / channels as u64;
                        let bk = ch * banks + ((in_chan / row_lines) % banks as u64) as usize;
                        let row = in_chan / (row_lines * banks as u64);
                        // NUMA: hop distance from the requesting core's
                        // socket to the line's home channel group. 0
                        // everywhere at one socket, so every charge below
                        // vanishes and the flat model is reproduced bit for
                        // bit.
                        let home_hops = cfg.socket_distance(my_sock, home_sock);

                        // (4) Settle the shadow prediction against the
                        // shared truth.
                        if o.hit {
                            stats[c].llc_hits += 1;
                            if home_hops > 0 {
                                // The hit is served by a remote socket's LLC
                                // slice: the line crosses the interconnect.
                                stats[c].remote_fills += 1;
                                let x = home_hops as f64 * cfg.remote_coherence_cycles;
                                stats[c].remote_extra_cycles += x;
                                extra += x;
                            }
                            if !e.shadow_hit() {
                                // Constructive sharing: another core already
                                // pulled the line in. Refund the bandwidth
                                // floor — but only where phase 1 really
                                // charged it (stream-prefetched accesses
                                // were clamped to an L1 hit and never paid).
                                // The core-alone baseline *would* have taken
                                // this access to DRAM, so its shadow bank
                                // state advances even though the shared
                                // system never did.
                                stats[c].shared_fills += 1;
                                shadow_bank[c][bk] = row;
                                if e.paid_bw() {
                                    stats[c].sharing_saved_cycles += DRAM_BW_CYCLES;
                                    extra -= DRAM_BW_CYCLES;
                                }
                            }
                        } else {
                            stats[c].llc_misses += 1;
                            let mut otherb = 0.0f64;
                            for (k, &b) in chan_busy[ch].iter().enumerate() {
                                if k != c && b > otherb {
                                    otherb = b;
                                }
                            }
                            // Same bounded-queue cap as the LLC: at most one
                            // in-flight transfer per other core ahead of us.
                            let dwait = (otherb - t)
                                .max(0.0)
                                .min((cores - 1) as f64 * cfg.dram_transfer_cycles);
                            stats[c].dram_queue_cycles += dwait;
                            extra += dwait;
                            chan_busy[ch][c] =
                                t.max(chan_busy[ch][c]).max(otherb) + cfg.dram_transfer_cycles;
                            channel_busy_cycles[ch] += cfg.dram_transfer_cycles;
                            if home_hops > 0 {
                                // Remote memory access: the transfer pays
                                // the interconnect traversal and occupies
                                // the channel end-to-end for that much
                                // longer.
                                stats[c].remote_fills += 1;
                                let x = home_hops as f64 * cfg.remote_transfer_cycles;
                                stats[c].remote_extra_cycles += x;
                                extra += x;
                                chan_busy[ch][c] += x;
                                channel_busy_cycles[ch] += x;
                            }

                            // (5) Bank/row-buffer state. The *shared* bank
                            // always advances — this is a real DRAM access —
                            // while the core-alone *shadow* bank advances
                            // only on accesses the core would have issued
                            // running alone (shadow-LLC misses). The service
                            // delta is charged only where both models agree
                            // the access reaches DRAM: a demotion's whole
                            // extra trip is already priced by the sharing
                            // corrections below, and charging its row
                            // service too would double-count.
                            let b = &mut bank[bk];
                            let shared_cost = if b.open_row == row {
                                stats[c].row_hits += 1;
                                cfg.row_hit_cycles
                            } else if b.open_row != NO_ROW && b.owner != c as u8 {
                                stats[c].row_conflicts += 1;
                                cfg.row_conflict_cycles
                            } else {
                                stats[c].row_misses += 1;
                                cfg.row_miss_cycles
                            };
                            b.open_row = row;
                            b.owner = c as u8;
                            if !e.shadow_hit() {
                                let shadow_cost = if shadow_bank[c][bk] == row {
                                    cfg.row_hit_cycles
                                } else {
                                    cfg.row_miss_cycles
                                };
                                shadow_bank[c][bk] = row;
                                let delta = shared_cost - shadow_cost;
                                stats[c].row_extra_cycles += delta;
                                extra += delta;
                            }

                            if e.shadow_hit() {
                                // Destructive interference: phase 1 charged
                                // no bandwidth floor for this access — pay
                                // it now. The exposed-latency penalty
                                // applies only to the *first* demotion on a
                                // line: once demoted, later misses on it are
                                // predicted misses the core overlaps like
                                // any other (the shadow invalidation the
                                // iterative engine applies).
                                stats[c].demotions += 1;
                                let pay = if o.demote_invalidated {
                                    DRAM_BW_CYCLES
                                } else {
                                    DRAM_BW_CYCLES + cfg.demotion_cycles
                                };
                                stats[c].demotion_cycles += pay;
                                extra += pay;
                                // A repeat demotion this pass (on a line
                                // prior passes had not yet invalidated) is
                                // exactly what the next pass would drop the
                                // exposure penalty for — the pending
                                // correction.
                                if o.demote_repeat {
                                    pending += cfg.demotion_cycles;
                                }
                            }
                        }

                        let p = (e.phase() as usize).min(MAX_PHASES - 1);
                        phase_stalls[c][p] += extra;
                    }
                }
            }
            (stats, phase_stalls, channel_busy_cycles, pending)
        };

        // ---- Execution. A materialized single-shard replay (pilots, most
        // tests) stays thread- and channel-free: run the one shard to
        // completion, then merge over the buffered outcomes. (This also
        // keeps a socket-stamp construction error surfacing on the caller's
        // own thread with its precise message.) Everything else pipelines:
        // shard threads and the merge run concurrently in one scope,
        // outcomes flowing through the bounded batch channels.
        let inline = shards == 1 && matches!(self.source, TraceSource::Bufs(_));
        let (stats, phase_stalls, channel_busy_cycles, pending) = if inline {
            let mut outcomes = Vec::new();
            shard_walk(&mut states[0], 0, &mut |o| outcomes.push(o));
            let mut i = 0usize;
            merge_walk(&mut |_| {
                let o = outcomes[i];
                i += 1;
                o
            })
        } else {
            let mut txs = Vec::with_capacity(shards);
            let mut cursors = Vec::with_capacity(shards);
            for _ in 0..shards {
                let (tx, rx) = mpsc::sync_channel::<Vec<EventOutcome>>(OUTCOME_QUEUE_BATCHES);
                txs.push(tx);
                cursors.push(OutcomeCursor { rx, batch: Vec::new(), i: 0 });
            }
            let shard_walk = &shard_walk;
            std::thread::scope(|scope| {
                for (shard_ix, (state, tx)) in states.iter_mut().zip(txs).enumerate() {
                    scope.spawn(move || {
                        let mut batch = Vec::with_capacity(OUTCOME_BATCH);
                        shard_walk(state, shard_ix, &mut |o| {
                            batch.push(o);
                            if batch.len() >= OUTCOME_BATCH {
                                let full = std::mem::replace(
                                    &mut batch,
                                    Vec::with_capacity(OUTCOME_BATCH),
                                );
                                // A failed send means the merge is already
                                // unwinding; keep draining quietly.
                                let _ = tx.send(full);
                            }
                        });
                        if !batch.is_empty() {
                            let _ = tx.send(batch);
                        }
                    });
                }
                // The serial merge runs concurrently on this thread,
                // consuming outcome batches as the shards produce them.
                merge_walk(&mut |s| cursors[s].next())
            })
        };

        // The shard trigger maps are line-disjoint by construction: union
        // them into the per-core maps the iteration loop folds from.
        let mut triggers: Vec<InvalMap> = vec![InvalMap::new(); cores];
        for st in states.iter_mut() {
            for (c, trig) in st.triggers.iter_mut().enumerate() {
                triggers[c].extend(trig.drain());
            }
        }

        Pass {
            outcome: ReplayOutcome {
                per_core: stats,
                per_core_phase_stalls: phase_stalls,
                channel_busy_cycles,
            },
            triggers,
            pending,
        }
    }
}

/// Replay the merged per-core traces (index = core id) through the shared
/// LLC + DRAM-channel model: the one-call wrapper over [`ReplayEngine`].
pub fn replay(mem: &MemConfig, cfg: &SharedMemConfig, traces: &[TraceBuf]) -> ReplayOutcome {
    ReplayEngine::new(mem, cfg, traces).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CacheConfig, SystemConfig};
    use crate::mem::trace::TraceEvent;
    use crate::mem::{AccessKind, Hierarchy};

    fn sys() -> SystemConfig {
        SystemConfig::default()
    }

    fn demand(line: u64, write: bool, shadow_hit: bool) -> TraceEvent {
        // Hand-built events model plain (non-prefetched) accesses: the
        // floor was paid exactly when the shadow missed.
        TraceEvent::new(line, TraceKind::Demand, write, shadow_hit, !shadow_hit, 1)
    }

    fn buf(events: impl IntoIterator<Item = (f64, TraceEvent)>) -> TraceBuf {
        TraceBuf::from_events(events)
    }

    fn with_shards(cfg: &SharedMemConfig, shards: usize) -> SharedMemConfig {
        SharedMemConfig { replay_shards: shards, ..*cfg }
    }

    #[test]
    fn single_core_replay_charges_exactly_zero() {
        // Record a real trace through a hierarchy, then replay it alone:
        // every stall class must be *exactly* 0.0 (the 1-core == seed pin).
        let c = sys();
        let mut h = Hierarchy::new(c.mem);
        h.enable_trace();
        for i in 0..4096u64 {
            h.access(0x100000 + i * 64, 4, AccessKind::Write);
        }
        for i in 0..4096u64 {
            h.access(0x100000 + i * 64, 4, AccessKind::Read);
        }
        let trace = h.take_trace();
        assert!(!trace.is_empty());
        let out = replay(&c.mem, &c.shared, std::slice::from_ref(&trace));
        let s = &out.per_core[0];
        assert_eq!(s.llc_queue_cycles, 0.0);
        assert_eq!(s.dram_queue_cycles, 0.0);
        assert_eq!(s.coherence_cycles, 0.0);
        assert_eq!(s.demotion_cycles, 0.0);
        assert_eq!(s.sharing_saved_cycles, 0.0);
        assert_eq!(s.row_extra_cycles, 0.0, "alone, shadow and shared banks agree");
        assert_eq!(s.remote_fills + s.remote_forwards, 0, "one socket has no remote traffic");
        assert_eq!(s.remote_extra_cycles, 0.0);
        assert_eq!(s.stall_cycles(), 0.0);
        assert_eq!(s.upgrades + s.dirty_forwards + s.invalidations_received, 0);
        // The shared LLC agreed with the shadow on every single access.
        assert_eq!(s.shared_fills + s.demotions, 0);
        let hits = trace
            .iter()
            .filter(|e| e.kind() == TraceKind::Demand && e.shadow_hit())
            .count() as u64;
        assert_eq!(s.llc_hits, hits);
        assert!(out.per_core_phase_stalls[0].iter().all(|&x| x == 0.0));
        // Every LLC-level access of the shadow was replayed.
        assert_eq!(
            s.llc_accesses + s.writeback_installs,
            h.stats().llc_accesses
        );
        // The one-shot pass sufficed and reached the fixed point.
        assert_eq!(s.replay_iters, 1);
        assert_eq!(s.replay_residual, 0.0);
        // Row-buffer counters still describe the stream (hits on the open
        // row), they just cost nothing extra.
        assert_eq!(s.row_hits + s.row_misses + s.row_conflicts, s.llc_misses);
        assert_eq!(s.row_conflicts, 0);

        // The same real trace (demands *and* writebacks) sharded 8 ways is
        // the same replay, bit for bit.
        let sharded = replay(&c.mem, &with_shards(&c.shared, 8), std::slice::from_ref(&trace));
        assert_eq!(sharded, out);
    }

    #[test]
    fn replay_is_deterministic() {
        let c = sys();
        let t0 = buf((0..64).map(|i| (i as f64, demand(i * 3, i % 2 == 0, false))));
        let t1 = buf((0..64).map(|i| (i as f64, demand(i * 3 + 1, false, false))));
        let a = replay(&c.mem, &c.shared, &[t0.clone(), t1.clone()]);
        let b = replay(&c.mem, &c.shared, &[t0, t1]);
        assert_eq!(a, b);
    }

    #[test]
    fn sharded_replay_is_bit_identical_to_serial_at_every_shard_count() {
        // Coherence-heavy traffic — overlapping line sets, mixed reads and
        // writes, three cores with interleaved times — replayed at every
        // supported shard count must produce *exactly* the serial outcome:
        // the merge pass performs all float accumulation in canonical
        // order, so there is no tolerance here, only `assert_eq!`.
        let c = sys();
        let t0 = buf((0..512u64).map(|i| (i as f64, demand(i % 64, i % 3 == 0, false))));
        let t1 = buf(
            (0..512u64)
                .map(|i| (0.5 + i as f64, demand(i % 64 + (i % 5) * 31, i % 4 == 0, false))),
        );
        let t2 = buf((0..512u64).map(|i| (0.25 + i as f64, demand((i * 7) % 256, false, false))));
        let traces = [t0, t1, t2];
        let serial = replay(&c.mem, &c.shared, &traces);
        // The traffic must actually exercise the coherence/queueing paths,
        // or the invariance proves nothing.
        let tot: u64 = serial.per_core.iter().map(|s| s.coherence_events()).sum();
        assert!(tot > 0, "the fixture must generate coherence traffic");
        for shards in [2usize, 4, 8, 16, 32, 64] {
            let out = replay(&c.mem, &with_shards(&c.shared, shards), &traces);
            assert_eq!(out, serial, "shard count {shards} must be bit-identical");
        }
    }

    #[test]
    fn sharded_replay_matches_serial_through_the_iterative_fixed_point() {
        // The repeat-demotion fixture needs a second corrective pass: the
        // shard partition, trigger maps, and invalidation points must all
        // survive the iteration loop unchanged.
        let c = sys();
        let llc_lines = (c.mem.llc.size_bytes / c.mem.l1d.line_bytes) as u64;
        let t1 = buf([
            (0.0, demand(7, false, true)),
            (1_000_000.0, demand(7, false, true)),
        ]);
        let t0 = buf(
            (0..llc_lines * 8)
                .map(|i| (10.0 + i as f64 * 0.05, demand(1_000_000 + i, false, false))),
        );
        let traces = [t0, t1];
        let serial = replay(&c.mem, &c.shared, &traces);
        assert_eq!(
            serial.per_core[1].replay_iters, 2,
            "the fixture must exercise the corrective pass"
        );
        for shards in [2usize, 8] {
            let out = replay(&c.mem, &with_shards(&c.shared, shards), &traces);
            assert_eq!(out, serial, "x{shards}");
        }
    }

    #[test]
    fn sharded_replay_matches_serial_with_numa_stamps() {
        // 2-socket, socket-stamped traces with remote fills and forwards:
        // the hop pricing flows shard -> outcome -> merge without drift.
        let c = sys();
        let cfg = two_socket_cfg();
        let mk = |base: u64, sock: u8, t0: f64| {
            TraceBuf::from_events((0..96u64).map(move |i| {
                (
                    t0 + i as f64,
                    demand(base + i % 24, i % 6 == 0, false).with_socket(sock),
                )
            }))
        };
        let traces = [mk(0, 0, 0.0), mk(2, 1, 0.5), mk(0, 1, 0.25)];
        let serial = replay(&c.mem, &cfg, &traces);
        let remote: u64 = serial.per_core.iter().map(|s| s.remote_fills + s.remote_forwards).sum();
        assert!(remote > 0, "the fixture must generate remote traffic");
        for shards in [2usize, 4, 8] {
            let out = replay(&c.mem, &with_shards(&cfg, shards), &traces);
            assert_eq!(out, serial, "x{shards}");
        }
    }

    #[test]
    fn disjoint_addresses_have_zero_coherence() {
        let c = sys();
        let t0 = buf((0..128).map(|i| (i as f64, demand(i * 2, true, false))));
        let t1 = buf((0..128).map(|i| (i as f64, demand(i * 2 + 1, true, false))));
        let out = replay(&c.mem, &c.shared, &[t0, t1]);
        for s in &out.per_core {
            assert_eq!(s.upgrades, 0);
            assert_eq!(s.invalidations_sent, 0);
            assert_eq!(s.invalidations_received, 0);
            assert_eq!(s.dirty_forwards, 0);
            assert_eq!(s.coherence_cycles, 0.0);
            assert_eq!(s.shared_fills, 0, "disjoint lines cannot share fills");
        }
    }

    #[test]
    fn write_shared_line_counts_upgrade_and_invalidation() {
        let c = sys();
        // Core 1 reads line 5, then core 0 writes it.
        let t0 = buf([(100.0, demand(5, true, false))]);
        let t1 = buf([(0.0, demand(5, false, false))]);
        let out = replay(&c.mem, &c.shared, &[t0, t1]);
        assert_eq!(out.per_core[0].upgrades, 1);
        assert_eq!(out.per_core[0].invalidations_sent, 1);
        assert_eq!(out.per_core[1].invalidations_received, 1);
        assert!(out.per_core[0].coherence_cycles > 0.0);
        assert_eq!(out.per_core[1].coherence_cycles, 0.0);
    }

    #[test]
    fn read_after_remote_write_is_a_dirty_forward() {
        let c = sys();
        let t0 = buf([(0.0, demand(9, true, false))]);
        let t1 = buf([(100.0, demand(9, false, false))]);
        let out = replay(&c.mem, &c.shared, &[t0, t1]);
        assert_eq!(out.per_core[1].dirty_forwards, 1);
        assert!(out.per_core[1].coherence_cycles > 0.0);
        // Core 0's fill made it a shared-LLC hit for core 1: constructive.
        assert_eq!(out.per_core[1].shared_fills, 1);
        assert!(out.per_core[1].sharing_saved_cycles > 0.0);
    }

    #[test]
    fn equal_times_tie_break_toward_lower_core_id() {
        let c = sys();
        // Both cores write line 7 at t=0: core 0 replays first, so core 1
        // pays the upgrade. Canonical, host-independent.
        let t0 = buf([(0.0, demand(7, true, false))]);
        let t1 = buf([(0.0, demand(7, true, false))]);
        let out = replay(&c.mem, &c.shared, &[t0, t1]);
        assert_eq!(out.per_core[0].upgrades, 0);
        assert_eq!(out.per_core[1].upgrades, 1);
        assert_eq!(out.per_core[0].invalidations_received, 1);
    }

    #[test]
    fn fewer_channels_mean_more_dram_queueing() {
        let c = sys();
        // Two cores streaming distinct cold lines at overlapping times.
        let t0 = buf((0..256).map(|i| ((i / 4) as f64, demand(i * 2, false, false))));
        let t1 = buf((0..256).map(|i| ((i / 4) as f64, demand(i * 2 + 1, false, false))));
        let narrow_cfg = SharedMemConfig { dram_channels: 1, ..c.shared };
        let wide_cfg = SharedMemConfig { dram_channels: 8, ..c.shared };
        let narrow = replay(&c.mem, &narrow_cfg, &[t0.clone(), t1.clone()]);
        let wide = replay(&c.mem, &wide_cfg, &[t0, t1]);
        let q = |o: &ReplayOutcome| {
            o.per_core.iter().map(|s| s.dram_queue_cycles).sum::<f64>()
        };
        assert!(
            q(&narrow) > q(&wide),
            "1 channel {} !> 8 channels {}",
            q(&narrow),
            q(&wide)
        );
        assert_eq!(narrow.channel_busy_cycles.len(), 1);
        assert_eq!(wide.channel_busy_cycles.len(), 8);
        // Same total transfer occupancy, spread over more channels.
        let tot = |o: &ReplayOutcome| o.channel_busy_cycles.iter().sum::<f64>();
        assert_eq!(tot(&narrow), tot(&wide));
    }

    #[test]
    fn constructive_sharing_refunds_the_bandwidth_floor() {
        let c = sys();
        // Both cores stream the same lines (B's rows): the second core's
        // shadow predicted misses, but the shared LLC has them.
        let t0 = buf((0..64).map(|i| (i as f64, demand(i, false, false))));
        let t1 = buf((0..64).map(|i| (1000.0 + i as f64, demand(i, false, false))));
        let out = replay(&c.mem, &c.shared, &[t0, t1]);
        assert_eq!(out.per_core[1].shared_fills, 64);
        assert_eq!(out.per_core[1].sharing_saved_cycles, 64.0 * DRAM_BW_CYCLES);
        assert!(out.per_core[1].stall_cycles() < 0.0);
        assert_eq!(out.per_core[0].shared_fills, 0);
    }

    #[test]
    fn unpaid_bandwidth_floor_is_never_refunded() {
        let c = sys();
        // Core 1's access is a shadow miss that hits shared, but it was
        // stream-prefetched in phase 1 (paid_bw = false): it still counts as
        // a constructive fill, yet no refund may be issued for a floor that
        // was never charged.
        let t0 = buf([(0.0, demand(11, false, false))]);
        let streamed = TraceEvent::new(11, TraceKind::Demand, false, false, false, 1);
        let t1 = buf([(1000.0, streamed)]);
        let out = replay(&c.mem, &c.shared, &[t0, t1]);
        assert_eq!(out.per_core[1].shared_fills, 1);
        assert_eq!(out.per_core[1].sharing_saved_cycles, 0.0);
        assert_eq!(out.per_core[1].stall_cycles(), 0.0);
    }

    #[test]
    fn phase_stalls_land_in_the_traced_phase() {
        let c = sys();
        let e0 = TraceEvent::new(3, TraceKind::Demand, false, false, true, 2);
        let e1 = TraceEvent::new(3, TraceKind::Demand, true, false, true, 3); // queues + upgrades
        let out = replay(&c.mem, &c.shared, &[buf([(0.0, e0)]), buf([(0.5, e1)])]);
        assert_eq!(out.per_core_phase_stalls[0][2], 0.0, "core 0 went first");
        assert!(out.per_core_phase_stalls[1][3] != 0.0);
        assert_eq!(out.per_core_phase_stalls[1][2], 0.0);
    }

    #[test]
    fn interleaved_streams_pay_row_conflicts_where_a_lone_stream_would_not() {
        let c = sys();
        // One channel, one bank: core 0 and core 1 alternate accesses to
        // widely separated rows, so every shared-bank access turns a row the
        // other core had open — conflicts everywhere. Each core's shadow
        // bank sees its own (single-row) stream and predicts hits.
        let cfg = SharedMemConfig {
            dram_channels: 1,
            dram_banks: 1,
            ..c.shared
        };
        let rl = cfg.row_buffer_lines as u64;
        let t0 = buf((0..32).map(|i| (100.0 * i as f64, demand(i % 8, false, false))));
        let t1 = buf((0..32).map(|i| {
            (100.0 * i as f64 + 50.0, demand(1000 * rl + i % 8, false, false))
        }));
        let out = replay(&c.mem, &cfg, &[t0, t1]);
        let s0 = &out.per_core[0];
        let s1 = &out.per_core[1];
        assert!(s0.row_conflicts > 0, "{s0:?}");
        assert!(s1.row_conflicts > 0, "{s1:?}");
        assert!(s0.row_extra_cycles > 0.0);
        assert!(s1.row_extra_cycles > 0.0);
        // Alone, either stream would mostly keep its row open.
        let alone = replay(
            &c.mem,
            &cfg,
            &[buf((0..32).map(|i| (100.0 * i as f64, demand(i % 8, false, false))))],
        );
        assert_eq!(alone.per_core[0].row_conflicts, 0);
        assert_eq!(alone.per_core[0].row_extra_cycles, 0.0);
    }

    #[test]
    fn repeat_demotions_converge_to_floor_only_charges() {
        // Core 1 is demoted twice on the same line (core 0's sweeps evict it
        // from the shared LLC in between). Pass 1 charges both demotions
        // full freight and reports the pending correction; the engine's
        // second pass drops the repeat's exposure penalty and reaches the
        // fixed point.
        let c = sys();
        let llc_lines = (c.mem.llc.size_bytes / c.mem.l1d.line_bytes) as u64;
        let mut events1 = vec![(0.0, demand(7, false, true))];
        events1.push((1_000_000.0, demand(7, false, true)));
        let t1 = buf(events1);
        // Core 0 sweeps 4x the (2-core sliced) LLC capacity between core 1's
        // two accesses, evicting line 7 both times.
        let t0 = buf(
            (0..llc_lines * 8)
                .map(|i| (10.0 + i as f64 * 0.05, demand(1_000_000 + i, false, false))),
        );
        let one_shot_cfg = SharedMemConfig { max_replay_iters: 1, ..c.shared };
        let one = replay(&c.mem, &one_shot_cfg, &[t0.clone(), t1.clone()]);
        let s1 = &one.per_core[1];
        assert_eq!(s1.demotions, 2, "both accesses demote in the one-shot model");
        assert_eq!(
            s1.demotion_cycles,
            2.0 * (DRAM_BW_CYCLES + c.shared.demotion_cycles)
        );
        assert_eq!(s1.replay_iters, 1);
        assert_eq!(
            s1.replay_residual, c.shared.demotion_cycles,
            "the repeat's exposure penalty is the pending correction"
        );

        let full = replay(&c.mem, &c.shared, &[t0, t1]);
        let f1 = &full.per_core[1];
        assert_eq!(f1.replay_iters, 2, "one corrective pass reaches the fixed point");
        assert_eq!(f1.replay_residual, 0.0);
        assert_eq!(f1.demotions, 2);
        assert_eq!(
            f1.demotion_cycles,
            2.0 * DRAM_BW_CYCLES + c.shared.demotion_cycles,
            "the repeat pays the floor only"
        );
        // Iteration never increases total corrected stalls.
        assert!(f1.stall_cycles() < s1.stall_cycles());
        assert!(full.per_core[0].stall_cycles() <= one.per_core[0].stall_cycles() + 1e-9);
    }

    #[test]
    fn max_replay_iters_caps_the_engine() {
        // Same repeat-demotion trace, but the engine is capped at one pass:
        // the residual is reported instead of resolved.
        let c = sys();
        let llc_lines = (c.mem.llc.size_bytes / c.mem.l1d.line_bytes) as u64;
        let t1 = buf([
            (0.0, demand(7, false, true)),
            (1_000_000.0, demand(7, false, true)),
        ]);
        let t0 = buf(
            (0..llc_lines * 8)
                .map(|i| (10.0 + i as f64 * 0.05, demand(1_000_000 + i, false, false))),
        );
        let capped = SharedMemConfig { max_replay_iters: 1, ..c.shared };
        let out = replay(&c.mem, &capped, &[t0, t1]);
        assert_eq!(out.per_core[1].replay_iters, 1);
        assert!(out.per_core[1].replay_residual > 0.0);
    }

    #[test]
    fn shared_stats_add_sums_and_maxes() {
        let mut a = SharedStats {
            llc_accesses: 3,
            row_hits: 2,
            row_extra_cycles: 1.5,
            remote_fills: 1,
            remote_extra_cycles: 4.0,
            replay_iters: 1,
            replay_residual: 0.0,
            trace_bytes_total: 160,
            trace_peak_resident_chunks: 2,
            spilled_chunks: 1,
            ..SharedStats::default()
        };
        let b = SharedStats {
            llc_accesses: 4,
            row_conflicts: 5,
            row_extra_cycles: -0.5,
            remote_fills: 2,
            remote_forwards: 3,
            remote_extra_cycles: 6.0,
            replay_iters: 2,
            replay_residual: 7.0,
            trace_bytes_total: 320,
            trace_peak_resident_chunks: 3,
            spilled_chunks: 4,
            ..SharedStats::default()
        };
        a.add(&b);
        assert_eq!(a.llc_accesses, 7);
        assert_eq!(a.row_hits, 2);
        assert_eq!(a.row_conflicts, 5);
        assert_eq!(a.row_extra_cycles, 1.0);
        assert_eq!(a.remote_fills, 3);
        assert_eq!(a.remote_forwards, 3);
        assert_eq!(a.remote_extra_cycles, 10.0);
        assert_eq!(a.replay_iters, 2, "iters aggregate with max, not sum");
        assert_eq!(a.replay_residual, 7.0);
        assert_eq!(a.trace_bytes_total, 480, "footprint counters sum");
        assert_eq!(a.trace_peak_resident_chunks, 5);
        assert_eq!(a.spilled_chunks, 5);
    }

    /// Replay the given materialized traces again through live
    /// [`TraceStream`]s (pushed from a producer thread, with the given ring
    /// budget) and return the streamed outcome.
    fn replay_streamed(
        c: &SystemConfig,
        cfg: &SharedMemConfig,
        traces: &[TraceBuf],
        ring: usize,
    ) -> ReplayOutcome {
        let mut writers = Vec::new();
        let mut streams = Vec::new();
        for _ in traces {
            let (w, s) = TraceStream::channel(ring);
            writers.push(w);
            streams.push(s);
        }
        std::thread::scope(|scope| {
            scope.spawn(move || {
                for (t, mut w) in traces.iter().zip(writers) {
                    for (time, e) in t.iter_timed() {
                        w.push(e, time);
                    }
                    w.finish();
                }
            });
            ReplayEngine::from_source(&c.mem, cfg, TraceSource::Streams(&streams)).run()
        })
    }

    #[test]
    fn streamed_replay_is_bit_identical_to_materialized() {
        // The same coherence-heavy fixture as the shard-count sweep, fed
        // once as materialized bufs and once through live streams while the
        // engine is already running — at several shard counts and ring
        // budgets. The two ring-dependent footprint counters are the *only*
        // tolerated difference (the stable JSON zeroes them); with an
        // unbounded ring even those agree, so the whole outcome is
        // `assert_eq!`-identical.
        let c = sys();
        // Three chunks per core, so a ring of 2 genuinely spills.
        let n = (TRACE_CHUNK * 2 + 100) as u64;
        let t0 = buf((0..n).map(|i| (i as f64, demand(i % 64, i % 3 == 0, false))));
        let t1 = buf(
            (0..n).map(|i| (0.5 + i as f64, demand(i % 64 + (i % 5) * 31, i % 4 == 0, false))),
        );
        let t2 = buf((0..n).map(|i| (0.25 + i as f64, demand((i * 7) % 256, false, false))));
        let traces = [t0, t1, t2];
        for shards in [1usize, 4, 8] {
            let cfg = with_shards(&c.shared, shards);
            let materialized = replay(&c.mem, &cfg, &traces);
            // Unbounded ring: nothing spills and the peak equals the
            // buf-derived chunk count, so everything matches bit for bit.
            let streamed = replay_streamed(&c, &cfg, &traces, 0);
            assert_eq!(streamed, materialized, "x{shards} unbounded ring");
            // Tiny ring: identical modulo the zeroed footprint counters.
            let mut spilled = replay_streamed(&c, &cfg, &traces, 2);
            for s in &spilled.per_core {
                assert!(s.spilled_chunks > 0, "3 chunks through a ring of 2 must spill");
                assert!(s.trace_peak_resident_chunks <= 2, "the ring budget is a hard cap");
            }
            for (s, m) in spilled.per_core.iter_mut().zip(&materialized.per_core) {
                s.trace_peak_resident_chunks = m.trace_peak_resident_chunks;
                s.spilled_chunks = m.spilled_chunks;
            }
            assert_eq!(spilled, materialized, "x{shards} ring=2");
        }
    }

    /// Two one-event traces on distinct sockets of a 2-socket, 4-channel
    /// config: lines are chosen so each core's line is either local or
    /// remote to its socket's channel group. Pinned to the blind interleave
    /// — these tests reason about the static `line % channels` homes;
    /// first-touch has its own tests below.
    fn two_socket_cfg() -> SharedMemConfig {
        SharedMemConfig {
            sockets: 2,
            page_placement: crate::config::PagePlacement::Interleave,
            ..SystemConfig::default().shared
        }
    }

    #[test]
    fn remote_dram_transfer_pays_the_hop_price_and_local_does_not() {
        let c = sys();
        let cfg = two_socket_cfg();
        // Channels 0,1 belong to socket 0; channels 2,3 to socket 1.
        // Core 0 (socket 0) touches line 0 (ch 0, local) and line 2 (ch 2,
        // remote); core 1 (socket 1, far in time so no queueing) touches
        // line 3 (ch 3, local).
        let t0 = TraceBuf::from_events([
            (0.0, demand(0, false, false).with_socket(0)),
            (1.0, demand(2, false, false).with_socket(0)),
        ]);
        let t1 = TraceBuf::from_events([(1_000_000.0, demand(3, false, false).with_socket(1))]);
        let out = replay(&c.mem, &cfg, &[t0, t1]);
        let s0 = &out.per_core[0];
        let s1 = &out.per_core[1];
        assert_eq!(s0.remote_fills, 1, "exactly the cross-socket line is remote");
        assert_eq!(s0.remote_extra_cycles, cfg.remote_transfer_cycles);
        assert_eq!(s1.remote_fills, 0, "socket-local access pays nothing");
        assert_eq!(s1.remote_extra_cycles, 0.0);
        // The remote transfer also occupies its channel longer.
        assert_eq!(
            out.channel_busy_cycles[2],
            cfg.dram_transfer_cycles + cfg.remote_transfer_cycles
        );
        assert_eq!(out.channel_busy_cycles[3], cfg.dram_transfer_cycles);
    }

    #[test]
    fn cross_socket_dirty_forward_and_upgrade_are_remote_forwards() {
        let c = sys();
        let cfg = two_socket_cfg();
        // Core 0 (socket 0) writes line 9 (ch 1, local to socket 0); core 1
        // (socket 1) reads it later -> dirty forward across the
        // interconnect; core 0 then rewrites it -> upgrade whose
        // invalidation crosses back.
        let t0 = TraceBuf::from_events([
            (0.0, demand(9, true, false).with_socket(0)),
            (2_000_000.0, demand(9, true, true).with_socket(0)),
        ]);
        let t1 = TraceBuf::from_events([(1_000_000.0, demand(9, false, false).with_socket(1))]);
        let out = replay(&c.mem, &cfg, &[t0, t1]);
        let s1 = &out.per_core[1];
        assert_eq!(s1.dirty_forwards, 1);
        assert_eq!(s1.remote_forwards, 1, "the forward crossed sockets");
        // Core 1's read also filled from a remote channel group (line 9 is
        // ch 1 = socket 0): it hits the shared LLC core 0 filled.
        assert_eq!(s1.remote_fills, 1);
        assert!(s1.remote_extra_cycles > 0.0);
        let s0 = &out.per_core[0];
        assert_eq!(s0.upgrades, 1);
        assert_eq!(
            s0.remote_forwards, 1,
            "the upgrade invalidated a sharer on the other socket"
        );
    }

    #[test]
    fn local_placement_beats_all_remote_placement() {
        // The same access streams, once with each core stamped on the
        // socket owning its lines' channel group and once with the stamps
        // swapped (every access remote): the all-remote run must cost
        // strictly more and the local one must carry zero NUMA charges.
        let c = sys();
        let cfg = two_socket_cfg();
        let lines0: Vec<u64> = (0..64u64).map(|i| 4 * i).collect(); // ch 0: socket 0
        let lines1: Vec<u64> = (0..64u64).map(|i| 4 * i + 2).collect(); // ch 2: socket 1
        let mk = |lines: &[u64], sock: u8| {
            TraceBuf::from_events(
                lines
                    .iter()
                    .enumerate()
                    .map(|(i, &l)| (i as f64, demand(l, false, false).with_socket(sock))),
            )
        };
        let local = replay(&c.mem, &cfg, &[mk(&lines0, 0), mk(&lines1, 1)]);
        let remote = replay(&c.mem, &cfg, &[mk(&lines0, 1), mk(&lines1, 0)]);
        let stalls = |o: &ReplayOutcome| -> f64 {
            o.per_core.iter().map(|s| s.stall_cycles()).sum()
        };
        for s in &local.per_core {
            assert_eq!(s.remote_fills, 0, "affine placement is NUMA-free");
            assert_eq!(s.remote_extra_cycles, 0.0);
        }
        for s in &remote.per_core {
            assert_eq!(s.remote_fills, 64, "anti-affine placement is all-remote");
        }
        assert!(
            stalls(&remote) > stalls(&local),
            "all-remote {} must cost more than local {}",
            stalls(&remote),
            stalls(&local)
        );
        assert_eq!(
            stalls(&remote) - stalls(&local),
            128.0 * cfg.remote_transfer_cycles,
            "the gap is exactly the hop-priced transfers"
        );
    }

    #[test]
    fn local_socket_stamps_carry_no_numa_charges() {
        // A NUMA topology with every access stamped on — and homed to —
        // socket 0: all distances are zero, so no remote charge may appear
        // even though the topology itself is multi-socket. (Out-of-range
        // stamps are a loud construction error now, not a clamp: see
        // `replay_rejects_out_of_range_socket_stamps`.)
        let c = sys();
        let cfg = two_socket_cfg();
        // Lines 4i and 4i+1 live on channels 0 and 1 — socket 0's group.
        let t0 = TraceBuf::from_events(
            (0..32u64).map(|i| (i as f64, demand(4 * i, false, false).with_socket(0))),
        );
        let t1 = TraceBuf::from_events(
            (0..32u64).map(|i| (i as f64, demand(4 * i + 1, false, false).with_socket(0))),
        );
        let out = replay(&c.mem, &cfg, &[t0, t1]);
        for s in &out.per_core {
            assert_eq!(s.remote_fills + s.remote_forwards, 0);
            assert_eq!(s.remote_extra_cycles, 0.0);
        }
    }

    #[test]
    fn first_touch_homes_the_page_on_the_first_toucher() {
        // Core 1 (socket 1) demands line 2 first — a line the blind
        // interleave would home on socket 1's channel group anyway, but the
        // *page* (lines 0..64) becomes socket 1's under first-touch. Core 0
        // (socket 0) then reads line 0 of the same page: local under the
        // interleave, remote under first-touch. The policies must disagree
        // in exactly that way.
        let c = sys();
        let ft = SharedMemConfig {
            sockets: 2,
            ..SystemConfig::default().shared
        };
        assert_eq!(ft.page_placement, crate::config::PagePlacement::FirstTouch);
        let il = SharedMemConfig {
            page_placement: crate::config::PagePlacement::Interleave,
            ..ft
        };
        let mk = || {
            [
                TraceBuf::from_events([(1_000_000.0, demand(0, false, false).with_socket(0))]),
                TraceBuf::from_events([(0.0, demand(2, false, false).with_socket(1))]),
            ]
        };
        let out_ft = replay(&c.mem, &ft, &mk());
        assert_eq!(out_ft.per_core[1].remote_fills, 0, "first toucher is home");
        assert_eq!(
            out_ft.per_core[0].remote_fills, 1,
            "the page was homed by the other socket's first touch"
        );
        let out_il = replay(&c.mem, &il, &mk());
        assert_eq!(out_il.per_core[0].remote_fills, 0, "line 0 is ch 0, socket 0");
        assert_eq!(out_il.per_core[1].remote_fills, 0, "line 2 is ch 2, socket 1");
    }

    #[test]
    fn first_touch_is_the_interleave_bit_for_bit_at_one_socket() {
        // One socket: the home is always socket 0 and the channel group is
        // every channel, so the two policies must produce identical stats
        // and identical per-channel occupancy on a mixed stream.
        let c = sys();
        let ft = SystemConfig::default().shared;
        let il = SharedMemConfig {
            page_placement: crate::config::PagePlacement::Interleave,
            ..ft
        };
        let mk = || {
            [
                TraceBuf::from_events(
                    (0..96u64).map(|i| (i as f64, demand(3 * i, i % 7 == 0, false))),
                ),
                TraceBuf::from_events(
                    (0..96u64).map(|i| (i as f64 + 0.5, demand(5 * i, false, i % 11 == 0))),
                ),
            ]
        };
        let a = replay(&c.mem, &ft, &mk());
        let b = replay(&c.mem, &il, &mk());
        assert_eq!(a.per_core, b.per_core);
        assert_eq!(a.channel_busy_cycles, b.channel_busy_cycles);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn replay_rejects_out_of_range_socket_stamps() {
        // A socket-7 stamp in a 1-socket topology used to clamp silently to
        // socket 0; in release builds an unclamped stamp would underflow
        // the ring distance and charge phantom NUMA hops. It is a
        // construction error and must fail loudly.
        let c = sys();
        let t = TraceBuf::from_events([(0.0, demand(1, false, false).with_socket(7))]);
        let _ = replay(&c.mem, &c.shared, std::slice::from_ref(&t));
    }

    #[test]
    #[should_panic(expected = "replay_shards")]
    fn replay_rejects_more_shards_than_llc_sets() {
        // The line partition is only set-consistent while whole LLC sets
        // stay shard-private; a hand-shrunk LLC with fewer sets than shards
        // must fail loudly.
        let mut c = sys();
        c.mem.llc = CacheConfig {
            size_bytes: 512,
            ways: 2,
            line_bytes: 64,
            hit_latency: 10,
        }; // 4 sets
        c.shared.llc_sliced = false;
        c.shared.replay_shards = 8;
        let t = buf([(0.0, demand(1, false, false))]);
        let _ = replay(&c.mem, &c.shared, std::slice::from_ref(&t));
    }

    #[test]
    #[should_panic(expected = "invalid SharedMemConfig")]
    fn replay_engine_rejects_invalid_configs() {
        let c = sys();
        let bad = SharedMemConfig { dram_channels: 0, ..c.shared };
        let t = buf([(0.0, demand(1, false, false))]);
        let _ = ReplayEngine::new(&c.mem, &bad, std::slice::from_ref(&t));
    }
}
