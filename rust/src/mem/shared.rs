//! Phase 2 of the shared-memory model: deterministic replay of the merged
//! per-core traces through one shared LLC (with MESI-lite coherence
//! bookkeeping) and a multi-channel DRAM back end.
//!
//! [`replay`] is a *pure function* of the per-core traces and the
//! configuration: host thread scheduling never enters, so per-core stall
//! cycles and coherence counters are bit-reproducible run to run (the same
//! invariant the parallel driver pins for event counts). Three cost classes
//! come out of it, every one of which is exactly zero when a single core
//! runs alone:
//!
//! * **Queueing** — waiting behind *other* cores' lookups at the shared LLC
//!   tag pipeline, and behind other cores' line transfers on the same DRAM
//!   channel. A core's own back-to-back traffic never queues against itself
//!   here (its own throughput is already priced in phase 1), and each
//!   event's charged wait is bounded by one in-flight service per other
//!   core — finite queues/MSHRs — so saturation degrades gracefully
//!   instead of compounding.
//! * **Coherence** — MESI-lite bookkeeping over a line directory: a write to
//!   a line other cores hold costs the writer an upgrade (invalidation
//!   round-trip, e.g. the stitched output row-pointer arrays' boundary
//!   lines), and a read of a line last written by another core costs a
//!   dirty forward.
//! * **Sharing corrections** — phase 1 priced each access against the
//!   core's private *shadow* LLC. Where the real shared LLC disagrees, the
//!   difference is settled here: a shadow miss that hits shared (another
//!   core already pulled B's row in — constructive sharing) refunds the
//!   bandwidth floor phase 1 charged; a shadow hit that misses shared
//!   (capacity interference from the other cores — destructive) pays the
//!   floor plus extra exposed latency.
//!
//! At one core the shared LLC sees exactly the shadow's access sequence with
//! identical geometry, so predictions never diverge and all three classes
//! vanish — the differential tests pin that the 1-core model reproduces the
//! seed cycle-for-cycle.

use crate::config::{MemConfig, SharedMemConfig, DRAM_BW_CYCLES};
use crate::mem::cache::Cache;
use crate::mem::trace::{TraceEvent, TraceKind, MAX_PHASES};
use std::collections::HashMap;

/// Per-core shared-memory counters and stall cycles from one replay.
/// Counters are exact; stall fields are replay-derived cycles. Everything is
/// zero for serial (non-replayed) runs.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SharedStats {
    /// Demand lookups this core issued at the shared LLC.
    pub llc_accesses: u64,
    pub llc_hits: u64,
    pub llc_misses: u64,
    /// Dirty L2 victims this core installed into the shared LLC.
    /// `llc_accesses + writeback_installs` equals the core's shadow-LLC
    /// access count exactly (the replay sees every LLC-level access).
    pub writeback_installs: u64,
    /// Shadow-miss / shared-hit events: another core had already filled the
    /// line (constructive sharing).
    pub shared_fills: u64,
    /// Shadow-hit / shared-miss events: sharing pressure evicted a line the
    /// private shadow still predicted resident (destructive interference).
    pub demotions: u64,
    /// Writes to lines other cores held (MESI upgrade, invalidations sent).
    pub upgrades: u64,
    /// Remote copies this core's writes invalidated.
    pub invalidations_sent: u64,
    /// This core's copies invalidated by other cores' writes.
    pub invalidations_received: u64,
    /// Reads of lines last written by another core (dirty data forwarded).
    pub dirty_forwards: u64,
    /// Cycles spent queueing behind other cores at the shared LLC.
    pub llc_queue_cycles: f64,
    /// Cycles spent queueing behind other cores' DRAM channel transfers.
    pub dram_queue_cycles: f64,
    /// Upgrade + dirty-forward stalls.
    pub coherence_cycles: f64,
    /// Bandwidth floor + exposed latency paid for demotions.
    pub demotion_cycles: f64,
    /// Bandwidth-floor refunds earned from constructive sharing.
    pub sharing_saved_cycles: f64,
}

impl SharedStats {
    /// Element-wise accumulate (multi-core aggregation).
    pub fn add(&mut self, o: &SharedStats) {
        self.llc_accesses += o.llc_accesses;
        self.llc_hits += o.llc_hits;
        self.llc_misses += o.llc_misses;
        self.writeback_installs += o.writeback_installs;
        self.shared_fills += o.shared_fills;
        self.demotions += o.demotions;
        self.upgrades += o.upgrades;
        self.invalidations_sent += o.invalidations_sent;
        self.invalidations_received += o.invalidations_received;
        self.dirty_forwards += o.dirty_forwards;
        self.llc_queue_cycles += o.llc_queue_cycles;
        self.dram_queue_cycles += o.dram_queue_cycles;
        self.coherence_cycles += o.coherence_cycles;
        self.demotion_cycles += o.demotion_cycles;
        self.sharing_saved_cycles += o.sharing_saved_cycles;
    }

    /// Shared-LLC demand hit rate.
    pub fn llc_hit_rate(&self) -> f64 {
        if self.llc_accesses == 0 {
            0.0
        } else {
            self.llc_hits as f64 / self.llc_accesses as f64
        }
    }

    /// Coherence protocol events this core initiated.
    pub fn coherence_events(&self) -> u64 {
        self.upgrades + self.dirty_forwards
    }

    /// Net replay-derived stall cycles (sharing refunds subtract).
    pub fn stall_cycles(&self) -> f64 {
        self.llc_queue_cycles + self.dram_queue_cycles + self.coherence_cycles
            + self.demotion_cycles
            - self.sharing_saved_cycles
    }
}

/// Everything one replay produced.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ReplayOutcome {
    /// Per-core counters and stall totals, indexed by core id.
    pub per_core: Vec<SharedStats>,
    /// Per-core stall cycles bucketed by the phase each traced access
    /// charged into (fold these into the matching `phase_cycles` /
    /// `cycles`; entries past the machine's phase count stay zero).
    pub per_core_phase_stalls: Vec<[f64; MAX_PHASES]>,
    /// Total transfer occupancy per DRAM channel, in cycles.
    pub channel_busy_cycles: Vec<f64>,
}

/// MESI-lite directory state for one line: which cores plausibly hold it in
/// their private caches (set on demand fill, cleared on writeback or remote
/// invalidation) and who wrote it last.
struct LineState {
    sharers: u64,
    /// Last writer (`u8::MAX` = none / written back).
    owner: u8,
    dirty: bool,
}

const NO_OWNER: u8 = u8::MAX;

/// Replay the merged per-core traces (index = core id) through the shared
/// LLC + DRAM-channel model. Deterministic: events merge in canonical
/// `(local time, core id, program order)` order, so the outcome is a pure
/// function of the traces. Supports up to 64 cores (directory bitmaps).
pub fn replay(
    mem: &MemConfig,
    cfg: &SharedMemConfig,
    traces: &[Vec<TraceEvent>],
) -> ReplayOutcome {
    let cores = traces.len();
    assert!(
        (1..=64).contains(&cores),
        "replay supports 1..=64 cores, got {cores}"
    );

    // Canonical deterministic interleaving. Per-core traces are already in
    // program order with monotone local times; ties across cores break
    // toward the lower core id, then program order.
    let total: usize = traces.iter().map(|t| t.len()).sum();
    let mut order: Vec<(u32, u32)> = Vec::with_capacity(total);
    for (c, t) in traces.iter().enumerate() {
        for i in 0..t.len() {
            order.push((c as u32, i as u32));
        }
    }
    order.sort_unstable_by(|&(ca, ia), &(cb, ib)| {
        let ta = traces[ca as usize][ia as usize].time;
        let tb = traces[cb as usize][ib as usize].time;
        ta.total_cmp(&tb).then(ca.cmp(&cb)).then(ia.cmp(&ib))
    });

    // The shared LLC. Same geometry as each core's Table II shadow slice;
    // in sliced mode every active core brings one slice of capacity.
    // Capacity scales through the *set count* (power-of-two slices keep the
    // sets a power of two and the per-lookup way scan O(base ways)); odd
    // core counts round up to the next power-of-two slicing via a second
    // way bank. At 1 core both modes are exactly the shadow geometry.
    let mut llc_cfg = mem.llc;
    if cfg.llc_sliced {
        let sets_scale = if cores.is_power_of_two() {
            cores
        } else {
            cores.next_power_of_two() / 2
        };
        let ways_scale = cores.div_ceil(sets_scale);
        llc_cfg.size_bytes *= sets_scale * ways_scale;
        llc_cfg.ways *= ways_scale;
    }
    let mut llc = Cache::new(llc_cfg);

    let channels = cfg.dram_channels.max(1);
    let mut directory: HashMap<u64, LineState> = HashMap::new();
    // Occupancy tails, split per core so a core only ever queues behind
    // *other* cores (self-throughput is phase 1's business).
    let mut llc_busy = vec![0.0f64; cores];
    let mut chan_busy = vec![vec![0.0f64; cores]; channels];
    let mut channel_busy_cycles = vec![0.0f64; channels];
    let mut stats = vec![SharedStats::default(); cores];
    let mut phase_stalls = vec![[0.0f64; MAX_PHASES]; cores];

    for &(ci, ei) in &order {
        let c = ci as usize;
        let e = traces[c][ei as usize];
        let t = e.time;
        match e.kind {
            TraceKind::Writeback => {
                // State + occupancy only: the write buffer hides latency,
                // but the install updates the shared LLC exactly as it did
                // the shadow, occupies the tag pipeline, and means the line
                // has left this core's private caches.
                stats[c].writeback_installs += 1;
                let (_, _victim) = llc.access_line(e.line, true);
                llc_busy[c] = t.max(llc_busy[c]) + cfg.llc_service_cycles;
                if let Some(st) = directory.get_mut(&e.line) {
                    st.sharers &= !(1u64 << c);
                    if st.owner == c as u8 {
                        st.owner = NO_OWNER;
                    }
                }
            }
            TraceKind::Demand => {
                stats[c].llc_accesses += 1;
                let mut extra = 0.0f64;

                // (1) Queue behind other cores' outstanding LLC lookups.
                // The charged wait is capped at one service slot per other
                // core: phase-1 issue times feel no backpressure, so under
                // sustained overload the raw tail-minus-arrival gap would
                // compound without bound, while a real core waits at most
                // for the bounded queue (MSHRs) ahead of it.
                let mut other = 0.0f64;
                for (k, &b) in llc_busy.iter().enumerate() {
                    if k != c && b > other {
                        other = b;
                    }
                }
                let wait = (other - t)
                    .max(0.0)
                    .min((cores - 1) as f64 * cfg.llc_service_cycles);
                stats[c].llc_queue_cycles += wait;
                extra += wait;
                llc_busy[c] = t.max(llc_busy[c]).max(other) + cfg.llc_service_cycles;

                // (2) The lookup itself — the same fill the shadow performed.
                let (hit, _victim) = llc.access_line(e.line, false);

                // (3) MESI-lite coherence bookkeeping.
                let st = directory.entry(e.line).or_insert(LineState {
                    sharers: 0,
                    owner: NO_OWNER,
                    dirty: false,
                });
                if e.write {
                    let others = st.sharers & !(1u64 << c);
                    if others != 0 {
                        stats[c].upgrades += 1;
                        stats[c].invalidations_sent += others.count_ones() as u64;
                        stats[c].coherence_cycles += cfg.upgrade_cycles;
                        extra += cfg.upgrade_cycles;
                        for (k, s) in stats.iter_mut().enumerate() {
                            if k != c && (others >> k) & 1 == 1 {
                                s.invalidations_received += 1;
                            }
                        }
                    }
                    st.sharers = 1u64 << c;
                    st.owner = c as u8;
                    st.dirty = true;
                } else {
                    if st.dirty && st.owner != NO_OWNER && st.owner != c as u8 {
                        stats[c].dirty_forwards += 1;
                        stats[c].coherence_cycles += cfg.dirty_forward_cycles;
                        extra += cfg.dirty_forward_cycles;
                        // Forwarded and downgraded to shared.
                        st.dirty = false;
                    }
                    st.sharers |= 1u64 << c;
                }

                // (4) Settle the shadow prediction against the shared truth.
                if hit {
                    stats[c].llc_hits += 1;
                    if !e.shadow_hit {
                        // Constructive sharing: another core already pulled
                        // the line in. Refund the bandwidth floor — but only
                        // where phase 1 really charged it (stream-prefetched
                        // accesses were clamped to an L1 hit and never paid).
                        stats[c].shared_fills += 1;
                        if e.paid_bw {
                            stats[c].sharing_saved_cycles += DRAM_BW_CYCLES;
                            extra -= DRAM_BW_CYCLES;
                        }
                    }
                } else {
                    stats[c].llc_misses += 1;
                    let ch = (e.line % channels as u64) as usize;
                    let mut otherb = 0.0f64;
                    for (k, &b) in chan_busy[ch].iter().enumerate() {
                        if k != c && b > otherb {
                            otherb = b;
                        }
                    }
                    // Same bounded-queue cap as the LLC: at most one
                    // in-flight transfer per other core ahead of us.
                    let dwait = (otherb - t)
                        .max(0.0)
                        .min((cores - 1) as f64 * cfg.dram_transfer_cycles);
                    stats[c].dram_queue_cycles += dwait;
                    extra += dwait;
                    chan_busy[ch][c] =
                        t.max(chan_busy[ch][c]).max(otherb) + cfg.dram_transfer_cycles;
                    channel_busy_cycles[ch] += cfg.dram_transfer_cycles;
                    if e.shadow_hit {
                        // Destructive interference: phase 1 charged no
                        // bandwidth floor for this access — pay it now plus
                        // the exposed-latency penalty.
                        stats[c].demotions += 1;
                        let pay = DRAM_BW_CYCLES + cfg.demotion_cycles;
                        stats[c].demotion_cycles += pay;
                        extra += pay;
                    }
                }

                let p = (e.phase as usize).min(MAX_PHASES - 1);
                phase_stalls[c][p] += extra;
            }
        }
    }

    ReplayOutcome {
        per_core: stats,
        per_core_phase_stalls: phase_stalls,
        channel_busy_cycles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::mem::{AccessKind, Hierarchy};

    fn sys() -> SystemConfig {
        SystemConfig::default()
    }

    fn demand(line: u64, time: f64, write: bool, shadow_hit: bool) -> TraceEvent {
        TraceEvent {
            line,
            time,
            kind: TraceKind::Demand,
            write,
            shadow_hit,
            // Hand-built events model plain (non-prefetched) accesses: the
            // floor was paid exactly when the shadow missed.
            paid_bw: !shadow_hit,
            phase: 1,
        }
    }

    #[test]
    fn single_core_replay_charges_exactly_zero() {
        // Record a real trace through a hierarchy, then replay it alone:
        // every stall class must be *exactly* 0.0 (the 1-core == seed pin).
        let c = sys();
        let mut h = Hierarchy::new(c.mem);
        h.enable_trace();
        for i in 0..4096u64 {
            h.access(0x100000 + i * 64, 4, AccessKind::Write);
        }
        for i in 0..4096u64 {
            h.access(0x100000 + i * 64, 4, AccessKind::Read);
        }
        let trace = h.take_trace();
        assert!(!trace.is_empty());
        let out = replay(&c.mem, &c.shared, &[trace.clone()]);
        let s = &out.per_core[0];
        assert_eq!(s.llc_queue_cycles, 0.0);
        assert_eq!(s.dram_queue_cycles, 0.0);
        assert_eq!(s.coherence_cycles, 0.0);
        assert_eq!(s.demotion_cycles, 0.0);
        assert_eq!(s.sharing_saved_cycles, 0.0);
        assert_eq!(s.stall_cycles(), 0.0);
        assert_eq!(s.upgrades + s.dirty_forwards + s.invalidations_received, 0);
        // The shared LLC agreed with the shadow on every single access.
        assert_eq!(s.shared_fills + s.demotions, 0);
        let hits = trace
            .iter()
            .filter(|e| e.kind == TraceKind::Demand && e.shadow_hit)
            .count() as u64;
        assert_eq!(s.llc_hits, hits);
        assert!(out.per_core_phase_stalls[0].iter().all(|&x| x == 0.0));
        // Every LLC-level access of the shadow was replayed.
        assert_eq!(
            s.llc_accesses + s.writeback_installs,
            h.stats().llc_accesses
        );
    }

    #[test]
    fn replay_is_deterministic() {
        let c = sys();
        let t0: Vec<TraceEvent> =
            (0..64).map(|i| demand(i * 3, i as f64, i % 2 == 0, false)).collect();
        let t1: Vec<TraceEvent> =
            (0..64).map(|i| demand(i * 3 + 1, i as f64, false, false)).collect();
        let a = replay(&c.mem, &c.shared, &[t0.clone(), t1.clone()]);
        let b = replay(&c.mem, &c.shared, &[t0, t1]);
        assert_eq!(a, b);
    }

    #[test]
    fn disjoint_addresses_have_zero_coherence() {
        let c = sys();
        let t0: Vec<TraceEvent> =
            (0..128).map(|i| demand(i * 2, i as f64, true, false)).collect();
        let t1: Vec<TraceEvent> =
            (0..128).map(|i| demand(i * 2 + 1, i as f64, true, false)).collect();
        let out = replay(&c.mem, &c.shared, &[t0, t1]);
        for s in &out.per_core {
            assert_eq!(s.upgrades, 0);
            assert_eq!(s.invalidations_sent, 0);
            assert_eq!(s.invalidations_received, 0);
            assert_eq!(s.dirty_forwards, 0);
            assert_eq!(s.coherence_cycles, 0.0);
            assert_eq!(s.shared_fills, 0, "disjoint lines cannot share fills");
        }
    }

    #[test]
    fn write_shared_line_counts_upgrade_and_invalidation() {
        let c = sys();
        // Core 1 reads line 5, then core 0 writes it.
        let t0 = vec![demand(5, 100.0, true, false)];
        let t1 = vec![demand(5, 0.0, false, false)];
        let out = replay(&c.mem, &c.shared, &[t0, t1]);
        assert_eq!(out.per_core[0].upgrades, 1);
        assert_eq!(out.per_core[0].invalidations_sent, 1);
        assert_eq!(out.per_core[1].invalidations_received, 1);
        assert!(out.per_core[0].coherence_cycles > 0.0);
        assert_eq!(out.per_core[1].coherence_cycles, 0.0);
    }

    #[test]
    fn read_after_remote_write_is_a_dirty_forward() {
        let c = sys();
        let t0 = vec![demand(9, 0.0, true, false)];
        let t1 = vec![demand(9, 100.0, false, false)];
        let out = replay(&c.mem, &c.shared, &[t0, t1]);
        assert_eq!(out.per_core[1].dirty_forwards, 1);
        assert!(out.per_core[1].coherence_cycles > 0.0);
        // Core 0's fill made it a shared-LLC hit for core 1: constructive.
        assert_eq!(out.per_core[1].shared_fills, 1);
        assert!(out.per_core[1].sharing_saved_cycles > 0.0);
    }

    #[test]
    fn equal_times_tie_break_toward_lower_core_id() {
        let c = sys();
        // Both cores write line 7 at t=0: core 0 replays first, so core 1
        // pays the upgrade. Canonical, host-independent.
        let t0 = vec![demand(7, 0.0, true, false)];
        let t1 = vec![demand(7, 0.0, true, false)];
        let out = replay(&c.mem, &c.shared, &[t0, t1]);
        assert_eq!(out.per_core[0].upgrades, 0);
        assert_eq!(out.per_core[1].upgrades, 1);
        assert_eq!(out.per_core[0].invalidations_received, 1);
    }

    #[test]
    fn fewer_channels_mean_more_dram_queueing() {
        let c = sys();
        // Two cores streaming distinct cold lines at overlapping times.
        let t0: Vec<TraceEvent> =
            (0..256).map(|i| demand(i * 2, (i / 4) as f64, false, false)).collect();
        let t1: Vec<TraceEvent> =
            (0..256).map(|i| demand(i * 2 + 1, (i / 4) as f64, false, false)).collect();
        let narrow_cfg = SharedMemConfig { dram_channels: 1, ..c.shared };
        let wide_cfg = SharedMemConfig { dram_channels: 8, ..c.shared };
        let narrow = replay(&c.mem, &narrow_cfg, &[t0.clone(), t1.clone()]);
        let wide = replay(&c.mem, &wide_cfg, &[t0, t1]);
        let q = |o: &ReplayOutcome| {
            o.per_core.iter().map(|s| s.dram_queue_cycles).sum::<f64>()
        };
        assert!(
            q(&narrow) > q(&wide),
            "1 channel {} !> 8 channels {}",
            q(&narrow),
            q(&wide)
        );
        assert_eq!(narrow.channel_busy_cycles.len(), 1);
        assert_eq!(wide.channel_busy_cycles.len(), 8);
        // Same total transfer occupancy, spread over more channels.
        let tot = |o: &ReplayOutcome| o.channel_busy_cycles.iter().sum::<f64>();
        assert_eq!(tot(&narrow), tot(&wide));
    }

    #[test]
    fn constructive_sharing_refunds_the_bandwidth_floor() {
        let c = sys();
        // Both cores stream the same lines (B's rows): the second core's
        // shadow predicted misses, but the shared LLC has them.
        let t0: Vec<TraceEvent> = (0..64).map(|i| demand(i, i as f64, false, false)).collect();
        let t1: Vec<TraceEvent> =
            (0..64).map(|i| demand(i, 1000.0 + i as f64, false, false)).collect();
        let out = replay(&c.mem, &c.shared, &[t0, t1]);
        assert_eq!(out.per_core[1].shared_fills, 64);
        assert_eq!(out.per_core[1].sharing_saved_cycles, 64.0 * DRAM_BW_CYCLES);
        assert!(out.per_core[1].stall_cycles() < 0.0);
        assert_eq!(out.per_core[0].shared_fills, 0);
    }

    #[test]
    fn unpaid_bandwidth_floor_is_never_refunded() {
        let c = sys();
        // Core 1's access is a shadow miss that hits shared, but it was
        // stream-prefetched in phase 1 (paid_bw = false): it still counts as
        // a constructive fill, yet no refund may be issued for a floor that
        // was never charged.
        let t0 = vec![demand(11, 0.0, false, false)];
        let mut streamed = demand(11, 1000.0, false, false);
        streamed.paid_bw = false;
        let out = replay(&c.mem, &c.shared, &[t0, vec![streamed]]);
        assert_eq!(out.per_core[1].shared_fills, 1);
        assert_eq!(out.per_core[1].sharing_saved_cycles, 0.0);
        assert_eq!(out.per_core[1].stall_cycles(), 0.0);
    }

    #[test]
    fn phase_stalls_land_in_the_traced_phase() {
        let c = sys();
        let mut e0 = demand(3, 0.0, false, false);
        e0.phase = 2;
        let mut e1 = demand(3, 0.5, true, false); // queues + upgrades
        e1.phase = 3;
        let out = replay(&c.mem, &c.shared, &[vec![e0], vec![e1]]);
        assert_eq!(out.per_core_phase_stalls[0][2], 0.0, "core 0 went first");
        assert!(out.per_core_phase_stalls[1][3] != 0.0);
        assert_eq!(out.per_core_phase_stalls[1][2], 0.0);
    }
}
