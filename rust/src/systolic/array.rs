//! Cycle-by-cycle simulation of the N x N SparseZipper systolic array
//! executing one sorting (`mssortk`) or merging (`mszipk`) micro-operation
//! on a single stream (paper Figures 5a/5b), including the compressing pass
//! and the four counters (W_IC, N_IC, E_OC, S_OC).
//!
//! Values ride along with their keys through the comparator decisions, so
//! the same simulation yields the paired v-instruction result. The array
//! outputs are checked against `systolic::functional` (the normative
//! semantics) by unit and property tests — the cross-model agreement is the
//! evidence that the micro-architecture implements the ISA.
//!
//! ## The compressing pass and the abstract merge state
//!
//! The paper deliberately leaves the key-reordering/merge architectural
//! state abstract (§III-C). Our concretization: the first pass through the
//! array does the comparator work (route larger east / smaller south,
//! combine equal keys, set merge bits on direct cross-chunk meetings); the
//! compressing pass — which sweeps every surviving datum anyway — packs
//! valid outputs, combines stragglers that crossed without meeting, and
//! *finalizes* the merge bits with a running seen-other-chunk flag. The
//! final bits are exactly the ISA-level rule ("x is mergeable iff the other
//! chunk contains a key >= x"), which the software merge loop depends on
//! for its pointer arithmetic (Fig. 4b): a direct-meeting-only bit would
//! under-merge and break the prefix-consumption invariant.

use crate::systolic::functional::{self, SortChunkOut, ZipChunkOut};
use crate::systolic::pe::{compare_route, hard_switch, Datum, SRC_NORTH, SRC_WEST};

/// What kind of micro-op the array executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Diagonal PEs hard-switch; the two chunks sort independently.
    Sort,
    /// All PEs compare; the two sorted chunks merge.
    Zip,
}

/// Raw result of one micro-op through the array.
#[derive(Clone, Debug)]
pub struct ArrayOut {
    /// Valid (key, value) pairs on the east side after compressing.
    pub east: Vec<(u32, f32)>,
    /// Valid pairs on the south side.
    pub south: Vec<(u32, f32)>,
    /// Excluded (merge-bit == false) keys per side: west-chunk, north-chunk.
    pub excluded_west: usize,
    pub excluded_north: usize,
    /// Total cycles for the two passes (sorting/merging + compressing).
    pub cycles: u32,
}

/// One pass through the array: `west[i]` enters row i staggered (cycle i),
/// `north[j]` enters column j staggered. Runs until drained. Returns every
/// non-bubble datum that left through the east and south edges, in arrival
/// order, plus the architectural pass latency.
fn run_pass(n: usize, op: Op, west: &[Datum], north: &[Datum]) -> (Vec<Datum>, Vec<Datum>, u32) {
    assert!(west.len() <= n && north.len() <= n);
    // h[i][j] = datum on the wire entering PE(i,j) from the west
    // (column n = the east edge); v[i][j] = entering from the north
    // (row n = the south edge). Double-buffered: next-state computed from
    // current-state, so every wire has exactly one writer per cycle.
    let mut h = vec![vec![Datum::BUBBLE; n + 1]; n];
    let mut v = vec![vec![Datum::BUBBLE; n]; n + 1];
    let mut east: Vec<Datum> = Vec::new();
    let mut south: Vec<Datum> = Vec::new();
    // Generous drain bound; the architectural latency reported to the
    // timing model is the paper's 2N+1 per pass regardless.
    let max_cycles = 4 * n + 8;
    for cycle in 0..max_cycles {
        // Inject staggered inputs: west[i] enters row i at cycle i,
        // north[j] enters column j at cycle j.
        if cycle < west.len() {
            h[cycle][0] = west[cycle];
        }
        if cycle < north.len() {
            v[0][cycle] = north[cycle];
        }
        let mut nh = vec![vec![Datum::BUBBLE; n + 1]; n];
        let mut nv = vec![vec![Datum::BUBBLE; n]; n + 1];
        let mut any_data = false;
        for i in 0..n {
            for j in 0..n {
                let w_in = h[i][j];
                let n_in = v[i][j];
                if !w_in.valid && !n_in.valid && !w_in.dup && !n_in.dup {
                    continue;
                }
                any_data = true;
                let (e, s, _route) = if op == Op::Sort && i == j {
                    hard_switch(w_in, n_in)
                } else {
                    compare_route(w_in, n_in)
                };
                nh[i][j + 1] = e;
                nv[i + 1][j] = s;
            }
        }
        for i in 0..n {
            let d = nh[i][n];
            if d.valid || d.dup {
                east.push(d);
            }
        }
        for j in 0..n {
            let d = nv[n][j];
            if d.valid || d.dup {
                south.push(d);
            }
        }
        h = nh;
        v = nv;
        if !any_data && cycle >= west.len().max(north.len()) {
            break; // fully drained
        }
    }
    debug_assert!(
        h.iter().flatten().chain(v.iter().flatten()).all(|d| !d.valid),
        "systolic array failed to drain"
    );
    (east, south, (2 * n + 1) as u32)
}

/// Compressing pass over the surviving data of one side or of the merged
/// stream: stable pack by key (the hardware pushes valid data through the
/// array again; functionally a sort-by-key with duplicate combining).
fn compress(mut data: Vec<Datum>) -> Vec<Datum> {
    data.retain(|d| d.valid);
    data.sort_by_key(|d| d.key);
    let mut out: Vec<Datum> = Vec::with_capacity(data.len());
    for d in data {
        if let Some(last) = out.last_mut() {
            if last.key == d.key {
                // Stragglers that crossed without meeting combine here.
                last.val += d.val;
                let cross = (last.src | d.src) != last.src || (last.src | d.src) != d.src;
                last.src |= d.src;
                last.merge = last.merge || d.merge || cross;
                continue;
            }
        }
        out.push(d);
    }
    out
}

/// Execute a full sorting micro-op (`mssortk`+`mssortv` for one stream):
/// sorting pass + compressing pass.
pub fn run_sort(n: usize, west_chunk: &[(u32, f32)], north_chunk: &[(u32, f32)]) -> ArrayOut {
    let west: Vec<Datum> = west_chunk
        .iter()
        .map(|&(k, v)| Datum::new(k, v, SRC_WEST))
        .collect();
    let north: Vec<Datum> = north_chunk
        .iter()
        .map(|&(k, v)| Datum::new(k, v, SRC_NORTH))
        .collect();
    let (east_raw, south_raw, c1) = run_pass(n, Op::Sort, &west, &north);
    // Partition check: the diagonal hard-switch confines each chunk.
    debug_assert!(east_raw.iter().all(|d| !d.valid || d.src == SRC_NORTH));
    debug_assert!(south_raw.iter().all(|d| !d.valid || d.src == SRC_WEST));
    let east = compress(east_raw)
        .into_iter()
        .map(|d| (d.key, d.val))
        .collect();
    let south = compress(south_raw)
        .into_iter()
        .map(|d| (d.key, d.val))
        .collect();
    ArrayOut {
        east,
        south,
        excluded_west: 0,
        excluded_north: 0,
        cycles: c1 + 1 + c1, // pass + turn-around + compress pass
    }
}

/// Execute a full merging micro-op (`mszipk`+`mszipv` for one stream).
/// Both chunks must be sorted ascending (unique within each chunk).
pub fn run_zip(n: usize, west_chunk: &[(u32, f32)], north_chunk: &[(u32, f32)]) -> ArrayOut {
    // West keys ordered bottom-to-top ascending (paper Fig. 5b): the largest
    // west key enters row 0 first, meeting north keys in opposing order.
    let mut west: Vec<Datum> = west_chunk
        .iter()
        .map(|&(k, v)| Datum::new(k, v, SRC_WEST))
        .collect();
    west.reverse();
    let north: Vec<Datum> = north_chunk
        .iter()
        .map(|&(k, v)| Datum::new(k, v, SRC_NORTH))
        .collect();
    let (east_raw, south_raw, c1) = run_pass(n, Op::Zip, &west, &north);

    // Compressing pass: pack + combine + finalize merge bits with the
    // running seen-other-chunk sweep (right-to-left over the sorted stream).
    let mut all: Vec<Datum> = east_raw;
    all.extend(south_raw);
    let mut merged = compress(all);
    let mut seen: u8 = 0;
    for d in merged.iter_mut().rev() {
        if seen & !d.src != 0 {
            d.merge = true; // a key >= d.key exists in the other chunk
        }
        seen |= d.src;
    }

    let mut excluded_west = 0usize;
    let mut excluded_north = 0usize;
    let mut out: Vec<(u32, f32)> = Vec::with_capacity(merged.len());
    for d in &merged {
        if d.merge {
            out.push((d.key, d.val));
        } else if d.src == SRC_WEST {
            excluded_west += 1;
        } else {
            excluded_north += 1;
        }
    }
    let split = out.len().min(n);
    let south = out.split_off(split);
    ArrayOut {
        east: out,
        south,
        excluded_west,
        excluded_north,
        cycles: c1 + 1 + c1,
    }
}

/// Convenience: run the array sort and package as the functional type.
pub fn sort_as_functional(n: usize, a: &[(u32, f32)], b: &[(u32, f32)]) -> SortChunkOut {
    let out = run_sort(n, a, b);
    // West chunk exits south, north chunk exits east (diagonal bounce).
    SortChunkOut {
        a_keys: out.south.iter().map(|p| p.0).collect(),
        a_vals: out.south.iter().map(|p| p.1).collect(),
        b_keys: out.east.iter().map(|p| p.0).collect(),
        b_vals: out.east.iter().map(|p| p.1).collect(),
    }
}

/// Convenience: run the array zip and package as the functional type.
pub fn zip_as_functional(n: usize, a: &[(u32, f32)], b: &[(u32, f32)]) -> ZipChunkOut {
    let out = run_zip(n, a, b);
    ZipChunkOut {
        east_keys: out.east.iter().map(|p| p.0).collect(),
        east_vals: out.east.iter().map(|p| p.1).collect(),
        south_keys: out.south.iter().map(|p| p.0).collect(),
        south_vals: out.south.iter().map(|p| p.1).collect(),
        consumed_a: a.len() - out.excluded_west,
        consumed_b: b.len() - out.excluded_north,
    }
}

/// Check the array simulation against the normative functional model for a
/// single (a, b) chunk pair. Returns Err with a description on divergence.
pub fn crosscheck_zip(n: usize, a: &[(u32, f32)], b: &[(u32, f32)]) -> Result<(), String> {
    let arr = zip_as_functional(n, a, b);
    let ak: Vec<u32> = a.iter().map(|p| p.0).collect();
    let av: Vec<f32> = a.iter().map(|p| p.1).collect();
    let bk: Vec<u32> = b.iter().map(|p| p.0).collect();
    let bv: Vec<f32> = b.iter().map(|p| p.1).collect();
    let f = functional::zip_step(n, &ak, &av, &bk, &bv);
    if arr.east_keys != f.east_keys || arr.south_keys != f.south_keys {
        return Err(format!(
            "keys diverge: array east={:?} south={:?}, functional east={:?} south={:?}",
            arr.east_keys, arr.south_keys, f.east_keys, f.south_keys
        ));
    }
    if arr.consumed_a != f.consumed_a || arr.consumed_b != f.consumed_b {
        return Err(format!(
            "counters diverge: array ({}, {}), functional ({}, {})",
            arr.consumed_a, arr.consumed_b, f.consumed_a, f.consumed_b
        ));
    }
    let close = |x: &[f32], y: &[f32]| {
        x.len() == y.len() && x.iter().zip(y).all(|(p, q)| (p - q).abs() < 1e-4)
    };
    if !close(&arr.east_vals, &f.east_vals) || !close(&arr.south_vals, &f.south_vals) {
        return Err("values diverge".into());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    /// Figure 5(a): north inputs {5, 8, 5} sort to {5, 8} with the duplicate
    /// combined; west inputs sort independently.
    #[test]
    fn fig5a_sort_example() {
        let west = [(4u32, 1.0f32), (1, 2.0), (6, 3.0)];
        let north = [(5u32, 1.0f32), (8, 2.0), (5, 4.0)];
        let out = sort_as_functional(3, &west, &north);
        assert_eq!(out.a_keys, vec![1, 4, 6]);
        assert_eq!(out.b_keys, vec![5, 8]);
        assert_eq!(out.b_vals, vec![5.0, 2.0]); // 1.0 + 4.0 combined
    }

    /// Figure 5(b): west {2,5,9}, north {3,8}: east {2,3,5}, south {8},
    /// 9 excluded (unmergeable), W_IC=2, N_IC=2.
    #[test]
    fn fig5b_zip_example() {
        let west = [(2u32, 1.0f32), (5, 2.0), (9, 3.0)];
        let north = [(3u32, 4.0f32), (8, 5.0)];
        let out = run_zip(3, &west, &north);
        assert_eq!(out.east.iter().map(|p| p.0).collect::<Vec<_>>(), vec![2, 3, 5]);
        assert_eq!(out.south.iter().map(|p| p.0).collect::<Vec<_>>(), vec![8]);
        assert_eq!(out.excluded_west, 1);
        assert_eq!(out.excluded_north, 0);
    }

    #[test]
    fn pass_latency_is_2n_plus_1_per_pass() {
        let out = run_sort(3, &[(1, 1.0)], &[(2, 1.0)]);
        assert_eq!(out.cycles, 7 + 1 + 7);
        let out16 = run_sort(16, &[(1, 1.0)], &[(2, 1.0)]);
        assert_eq!(out16.cycles, 33 + 1 + 33);
    }

    #[test]
    fn sort_matches_functional_random() {
        let mut rng = Pcg32::new(4242);
        for trial in 0..200 {
            let n = [3usize, 4, 8][trial % 3];
            let la = rng.gen_usize(n + 1);
            let lb = rng.gen_usize(n + 1);
            let a: Vec<(u32, f32)> = (0..la)
                .map(|_| (rng.gen_range(20), rng.gen_f32_range(0.5, 1.5)))
                .collect();
            let b: Vec<(u32, f32)> = (0..lb)
                .map(|_| (rng.gen_range(20), rng.gen_f32_range(0.5, 1.5)))
                .collect();
            let arr = sort_as_functional(n, &a, &b);
            let f = functional::sort_step(
                &a.iter().map(|p| p.0).collect::<Vec<_>>(),
                &a.iter().map(|p| p.1).collect::<Vec<_>>(),
                &b.iter().map(|p| p.0).collect::<Vec<_>>(),
                &b.iter().map(|p| p.1).collect::<Vec<_>>(),
            );
            assert_eq!(arr.a_keys, f.a_keys, "trial {trial} a={a:?} b={b:?}");
            assert_eq!(arr.b_keys, f.b_keys, "trial {trial}");
            for (x, y) in arr.a_vals.iter().zip(&f.a_vals) {
                assert!((x - y).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn zip_matches_functional_random() {
        let mut rng = Pcg32::new(777);
        for trial in 0..300 {
            let n = [3usize, 4, 8, 16][trial % 4];
            let mk_sorted = |rng: &mut Pcg32, len: usize| {
                let mut ks: Vec<u32> = (0..len).map(|_| rng.gen_range(30)).collect();
                ks.sort_unstable();
                ks.dedup();
                ks.iter()
                    .map(|&k| (k, rng.gen_f32_range(0.5, 1.5)))
                    .collect::<Vec<_>>()
            };
            let la = rng.gen_usize(n + 1);
            let a = mk_sorted(&mut rng, la);
            let lb = rng.gen_usize(n + 1);
            let b = mk_sorted(&mut rng, lb);
            crosscheck_zip(n, &a, &b).unwrap_or_else(|e| panic!("trial {trial}: {e}\na={a:?}\nb={b:?}"));
        }
    }

    /// No datum may be lost or duplicated by the network: total input value
    /// mass equals total output value mass (valid outputs only).
    #[test]
    fn zip_conserves_value_mass() {
        let mut rng = Pcg32::new(31337);
        for _ in 0..100 {
            let n = 8;
            let mk = |rng: &mut Pcg32, len: usize| {
                let mut ks: Vec<u32> = (0..len).map(|_| rng.gen_range(25)).collect();
                ks.sort_unstable();
                ks.dedup();
                ks.iter().map(|&k| (k, 1.0f32)).collect::<Vec<_>>()
            };
            let la = rng.gen_usize(n + 1);
            let a = mk(&mut rng, la);
            let lb = rng.gen_usize(n + 1);
            let b = mk(&mut rng, lb);
            let out = run_zip(n, &a, &b);
            let mass: f32 = out.east.iter().chain(&out.south).map(|p| p.1).sum();
            let expect = (a.len() + b.len() - out.excluded_west - out.excluded_north) as f32;
            assert!((mass - expect).abs() < 1e-3, "mass {mass} expect {expect}");
        }
    }
}
