//! SparseZipper systolic-array micro-architecture (paper §IV).
//!
//! Three models of the same hardware, used at different fidelities:
//!
//! * [`functional`] — the normative instruction semantics (fast; drives the
//!   SpGEMM implementations and the XLA-engine cross-check).
//! * [`array`] — PE-level cycle-by-cycle simulation of the sorting/merging
//!   and compressing passes (validates Figure 5 traces and the functional
//!   model on random inputs).
//! * [`timing`] — the occupancy model (§IV-C) that converts instruction
//!   issue into cycles for the big simulations.

pub mod array;
pub mod dense;
pub mod functional;
pub mod pe;
pub mod timing;

pub use functional::{sort_step, zip_step, SortChunkOut, ZipChunkOut};
pub use timing::SystolicTiming;
