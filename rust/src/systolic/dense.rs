//! Baseline dense-dense GEMM on the same systolic array (paper §II-A).
//!
//! SparseZipper's pitch is that it *minimally extends* a dense-GEMM matrix
//! unit — the dense path must keep working, unchanged. This module models
//! the baseline: weight-stationary N x N tile MACs with the Table II
//! latency, plus a tiled GEMM driver accounted on the `Machine`. The area
//! model (Table IV) and the timing regression test pin "unchanged".

use crate::matrix::Csr;
use crate::sim::{Machine, Phase};

/// Functional N x N tile multiply-accumulate: acc += a * b.
pub fn tile_mac(n: usize, a: &[f32], b: &[f32], acc: &mut [f32]) {
    debug_assert_eq!(a.len(), n * n);
    debug_assert_eq!(b.len(), n * n);
    debug_assert_eq!(acc.len(), n * n);
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            if aik == 0.0 {
                continue;
            }
            for j in 0..n {
                acc[i * n + j] += aik * b[k * n + j];
            }
        }
    }
}

/// Dense GEMM C = A * B over the simulated matrix unit: tiles of N x N,
/// one `mmul` per (i, k, j) tile triple; A/B tiles loaded with row-wise
/// unit-stride micro-ops, C tiles kept accumulator-stationary.
pub fn dense_gemm(m: &mut Machine, a: &[f32], b: &[f32], rows: usize, inner: usize, cols: usize) -> Vec<f32> {
    let n = m.cfg.unit.n;
    m.phase(Phase::Expand);
    let a_addr = m.salloc(rows * inner * 4);
    let b_addr = m.salloc(inner * cols * 4);
    let c_addr = m.salloc(rows * cols * 4);
    let mut c = vec![0f32; rows * cols];
    let tiles_i = rows.div_ceil(n);
    let tiles_k = inner.div_ceil(n);
    let tiles_j = cols.div_ceil(n);
    // Gather a zero-padded n x n tile.
    let tile_of = |src: &[f32], r0: usize, c0: usize, h: usize, w: usize, ld: usize| {
        let mut t = vec![0f32; n * n];
        for i in 0..h.min(n) {
            for j in 0..w.min(n) {
                t[i * n + j] = src[(r0 + i) * ld + c0 + j];
            }
        }
        t
    };
    for ti in 0..tiles_i {
        for tj in 0..tiles_j {
            let mut acc = vec![0f32; n * n];
            for tk in 0..tiles_k {
                let (r0, k0, c0) = (ti * n, tk * n, tj * n);
                let at = tile_of(a, r0, k0, rows - r0, inner - k0, inner);
                let bt = tile_of(b, k0, c0, inner - k0, cols - c0, cols);
                // Tile loads: n unit-stride rows each.
                let a_rows: Vec<(u64, usize)> = (0..n.min(rows - r0))
                    .map(|i| (a_addr + (((r0 + i) * inner + k0) * 4) as u64, n.min(inner - k0)))
                    .collect();
                let b_rows: Vec<(u64, usize)> = (0..n.min(inner - k0))
                    .map(|i| (b_addr + (((k0 + i) * cols + c0) * 4) as u64, n.min(cols - c0)))
                    .collect();
                m.mlxe(a_rows.iter());
                m.mlxe(b_rows.iter());
                m.mmul_tile();
                tile_mac(n, &at, &bt, &mut acc);
            }
            // Write back the C tile.
            let (r0, c0) = (ti * n, tj * n);
            let c_rows: Vec<(u64, usize)> = (0..n.min(rows - r0))
                .map(|i| (c_addr + (((r0 + i) * cols + c0) * 4) as u64, n.min(cols - c0)))
                .collect();
            m.msxe(c_rows.iter());
            for i in 0..n.min(rows - r0) {
                for j in 0..n.min(cols - c0) {
                    c[(r0 + i) * cols + c0 + j] = acc[i * n + j];
                }
            }
        }
    }
    c
}

/// Dense GEMM of two sparse operands (densified) — the "what if you ran
/// SpGEMM on the dense unit" strawman of §I: correct but wasteful.
pub fn dense_gemm_of_sparse(m: &mut Machine, a: &Csr, b: &Csr) -> Vec<f32> {
    let ad: Vec<f32> = a.to_dense().into_iter().flatten().collect();
    let bd: Vec<f32> = b.to_dense().into_iter().flatten().collect();
    dense_gemm(m, &ad, &bd, a.nrows, a.ncols, b.ncols)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::matrix::gen;

    fn naive(a: &[f32], b: &[f32], rows: usize, inner: usize, cols: usize) -> Vec<f32> {
        let mut c = vec![0f32; rows * cols];
        for i in 0..rows {
            for k in 0..inner {
                for j in 0..cols {
                    c[i * cols + j] += a[i * inner + k] * b[k * cols + j];
                }
            }
        }
        c
    }

    #[test]
    fn tile_mac_matches_naive() {
        let n = 4;
        let a: Vec<f32> = (0..16).map(|x| x as f32 * 0.5).collect();
        let b: Vec<f32> = (0..16).map(|x| (x % 5) as f32).collect();
        let mut acc = vec![0f32; 16];
        tile_mac(n, &a, &b, &mut acc);
        assert_eq!(acc, naive(&a, &b, 4, 4, 4));
    }

    #[test]
    fn dense_gemm_non_square_matches_naive() {
        let (rows, inner, cols) = (37, 22, 45);
        let a: Vec<f32> = (0..rows * inner).map(|x| ((x * 7) % 11) as f32 * 0.25).collect();
        let b: Vec<f32> = (0..inner * cols).map(|x| ((x * 3) % 13) as f32 * 0.5).collect();
        let mut m = Machine::new(SystemConfig::default());
        let c = dense_gemm(&mut m, &a, &b, rows, inner, cols);
        let want = naive(&a, &b, rows, inner, cols);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-2, "{x} vs {y}");
        }
        assert!(m.metrics().ops.mmul > 0);
    }

    #[test]
    fn dense_path_timing_is_unchanged_by_extension() {
        // The dense tile latency depends only on baseline parameters —
        // SparseZipper's additions (issue overhead, pass stalls) must not
        // leak into the dense path.
        let mut cfg1 = SystemConfig::default();
        cfg1.unit.issue_overhead = 0;
        cfg1.unit.pass_stalls = 0;
        let t1 = crate::systolic::SystolicTiming::new(cfg1.unit).dense_gemm_cycles();
        let t2 = crate::systolic::SystolicTiming::new(SystemConfig::default().unit).dense_gemm_cycles();
        assert_eq!(t1, t2);
    }

    #[test]
    fn spgemm_beats_dense_strawman_on_sparse_input() {
        // §I motivation: highly sparse inputs on the dense unit waste
        // almost every MAC. Even our small case shows a large gap.
        use crate::spgemm::{spz::Spz, SpGemm};
        let a = gen::powerlaw_clustered(256, 1280, 0.9, 0.3, 12);
        let mut md = Machine::new(SystemConfig::default());
        dense_gemm_of_sparse(&mut md, &a, &a);
        let mut ms = Machine::new(SystemConfig::default());
        Spz::native().multiply(&mut ms, &a, &a).unwrap();
        assert!(
            md.metrics().cycles > 3.0 * ms.metrics().cycles,
            "dense {} !>> spz {}",
            md.metrics().cycles,
            ms.metrics().cycles
        );
    }
}
