//! Normative semantics of `mssortk/mssortv` and `mszipk/mszipv`
//! (DESIGN.md §2). This is the model the SpGEMM implementations execute,
//! the oracle the PE-level array simulation is checked against, and the
//! semantics the L1 Pallas kernel reproduces (python/compile/kernels).

/// Result of sorting one pair of chunks (one stream) — `mssortk`+`mssortv`.
#[derive(Clone, Debug, PartialEq)]
pub struct SortChunkOut {
    /// Sorted-unique chunk A (duplicates combined, values accumulated).
    pub a_keys: Vec<u32>,
    pub a_vals: Vec<f32>,
    /// Sorted-unique chunk B.
    pub b_keys: Vec<u32>,
    pub b_vals: Vec<f32>,
}

/// Result of merging one pair of sorted chunks (one stream) — `mszipk`+`mszipv`.
#[derive(Clone, Debug, PartialEq)]
pub struct ZipChunkOut {
    /// "East" part: the first min(|m|, N) merged keys (smaller keys).
    pub east_keys: Vec<u32>,
    pub east_vals: Vec<f32>,
    /// "South" part: the remainder (larger keys).
    pub south_keys: Vec<u32>,
    pub south_vals: Vec<f32>,
    /// Elements consumed from chunk A (IC0) / chunk B (IC1).
    pub consumed_a: usize,
    pub consumed_b: usize,
}

/// Sort one chunk ascending and combine duplicate keys (values summed).
/// This is what one stream's micro-op does in the sorting + compressing
/// passes of `mssortk`/`mssortv`.
pub fn sort_chunk(keys: &[u32], vals: &[f32]) -> (Vec<u32>, Vec<f32>) {
    debug_assert_eq!(keys.len(), vals.len());
    let mut idx: Vec<usize> = (0..keys.len()).collect();
    idx.sort_by_key(|&i| keys[i]);
    let mut out_k: Vec<u32> = Vec::with_capacity(keys.len());
    let mut out_v: Vec<f32> = Vec::with_capacity(keys.len());
    for &i in &idx {
        if let Some(&last) = out_k.last() {
            if last == keys[i] {
                *out_v.last_mut().unwrap() += vals[i];
                continue;
            }
        }
        out_k.push(keys[i]);
        out_v.push(vals[i]);
    }
    (out_k, out_v)
}

/// `mssortk`+`mssortv` on one stream: chunks A and B sorted independently
/// (diagonal PEs hard-switch, so they never mix).
pub fn sort_step(
    a_keys: &[u32],
    a_vals: &[f32],
    b_keys: &[u32],
    b_vals: &[f32],
) -> SortChunkOut {
    let (ak, av) = sort_chunk(a_keys, a_vals);
    let (bk, bv) = sort_chunk(b_keys, b_vals);
    SortChunkOut {
        a_keys: ak,
        a_vals: av,
        b_keys: bk,
        b_vals: bv,
    }
}

/// `mszipk`+`mszipv` on one stream (DESIGN.md §2):
///
/// * element `x` of A is mergeable iff `x <= max(B)` (merge-bit rule);
///   symmetric for B; nothing is mergeable against an empty chunk;
/// * mergeable elements are merged ascending, equal keys combined
///   (A's value + B's value);
/// * the merged sequence `m` is split into east = `m[0..min(|m|,n)]` and
///   south = the rest, with `n` the hardware chunk size.
///
/// Inputs must be sorted; duplicate keys *within* a chunk are not expected
/// from well-formed software (they are pre-combined by `mssort`), but the
/// hardware would combine them too, so we combine them here for totality.
pub fn zip_step(
    n: usize,
    a_keys: &[u32],
    a_vals: &[f32],
    b_keys: &[u32],
    b_vals: &[f32],
) -> ZipChunkOut {
    debug_assert_eq!(a_keys.len(), a_vals.len());
    debug_assert_eq!(b_keys.len(), b_vals.len());
    debug_assert!(a_keys.windows(2).all(|w| w[0] <= w[1]), "A not sorted");
    debug_assert!(b_keys.windows(2).all(|w| w[0] <= w[1]), "B not sorted");

    let max_a = a_keys.last().copied();
    let max_b = b_keys.last().copied();

    // Mergeable prefixes (sorted inputs => mergeable set is a prefix).
    let la = match max_b {
        None => 0,
        Some(mb) => a_keys.partition_point(|&k| k <= mb),
    };
    let lb = match max_a {
        None => 0,
        Some(ma) => b_keys.partition_point(|&k| k <= ma),
    };

    // Two-pointer merge with cross-chunk (and defensive in-chunk) combining.
    let mut mk: Vec<u32> = Vec::with_capacity(la + lb);
    let mut mv: Vec<f32> = Vec::with_capacity(la + lb);
    let (mut i, mut j) = (0usize, 0usize);
    let push = |mk: &mut Vec<u32>, mv: &mut Vec<f32>, k: u32, v: f32| {
        if let Some(&last) = mk.last() {
            if last == k {
                *mv.last_mut().unwrap() += v;
                return;
            }
        }
        mk.push(k);
        mv.push(v);
    };
    while i < la && j < lb {
        if a_keys[i] <= b_keys[j] {
            push(&mut mk, &mut mv, a_keys[i], a_vals[i]);
            i += 1;
        } else {
            push(&mut mk, &mut mv, b_keys[j], b_vals[j]);
            j += 1;
        }
    }
    while i < la {
        push(&mut mk, &mut mv, a_keys[i], a_vals[i]);
        i += 1;
    }
    while j < lb {
        push(&mut mk, &mut mv, b_keys[j], b_vals[j]);
        j += 1;
    }

    let east_len = mk.len().min(n);
    let south_k = mk.split_off(east_len);
    let south_v = mv.split_off(east_len);
    ZipChunkOut {
        east_keys: mk,
        east_vals: mv,
        south_keys: south_k,
        south_vals: south_v,
        consumed_a: la,
        consumed_b: lb,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sort_chunk_basic() {
        let (k, v) = sort_chunk(&[5, 8, 5], &[1.0, 3.0, 7.0]);
        assert_eq!(k, vec![5, 8]);
        assert_eq!(v, vec![8.0, 3.0]); // duplicates combined per Fig. 5(a)
    }

    #[test]
    fn sort_chunk_empty() {
        let (k, v) = sort_chunk(&[], &[]);
        assert!(k.is_empty() && v.is_empty());
    }

    #[test]
    fn sort_chunk_all_duplicates() {
        let (k, v) = sort_chunk(&[3, 3, 3, 3], &[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(k, vec![3]);
        assert_eq!(v, vec![4.0]);
    }

    #[test]
    fn sort_step_keeps_chunks_separate() {
        let out = sort_step(&[9, 1], &[1.0, 2.0], &[5, 5], &[3.0, 4.0]);
        assert_eq!(out.a_keys, vec![1, 9]);
        assert_eq!(out.b_keys, vec![5]);
        assert_eq!(out.b_vals, vec![7.0]);
    }

    // --- zip_step: the Figure 5(b) example ---------------------------------
    // West chunk {2,5,9}, north chunk {3,8} (sorted). 9 > max(north)=8 is
    // unmergeable; output east {2,3,5}, south {8}.
    #[test]
    fn zip_fig5b_example() {
        let out = zip_step(
            3,
            &[2, 5, 9],
            &[1.0, 2.0, 3.0],
            &[3, 8],
            &[4.0, 5.0],
        );
        assert_eq!(out.east_keys, vec![2, 3, 5]);
        assert_eq!(out.south_keys, vec![8]);
        assert_eq!(out.consumed_a, 2); // {2,5}; 9 excluded
        assert_eq!(out.consumed_b, 2); // {3,8}
    }

    #[test]
    fn zip_combines_cross_duplicates() {
        let out = zip_step(4, &[1, 4, 7], &[1.0, 2.0, 3.0], &[4, 9], &[10.0, 20.0]);
        // max_a=7 => 9 not mergeable from B; max_b=9 => all of A mergeable.
        assert_eq!(out.east_keys, vec![1, 4, 7]);
        assert_eq!(out.east_vals, vec![1.0, 12.0, 3.0]);
        assert_eq!(out.consumed_a, 3);
        assert_eq!(out.consumed_b, 1);
    }

    #[test]
    fn zip_empty_b_merges_nothing() {
        let out = zip_step(4, &[1, 2], &[1.0, 1.0], &[], &[]);
        assert_eq!(out.consumed_a, 0);
        assert_eq!(out.consumed_b, 0);
        assert!(out.east_keys.is_empty());
    }

    #[test]
    fn zip_equal_maxes_consume_everything() {
        let out = zip_step(4, &[1, 5], &[1.0, 2.0], &[3, 5], &[3.0, 4.0]);
        assert_eq!(out.consumed_a, 2);
        assert_eq!(out.consumed_b, 2);
        assert_eq!(out.east_keys, vec![1, 3, 5]);
        assert_eq!(out.east_vals, vec![1.0, 3.0, 6.0]);
    }

    #[test]
    fn zip_overflow_to_south() {
        // maxA = 5, so B's 6 is unmergeable this step; everything else merges.
        let out = zip_step(
            3,
            &[1, 3, 5],
            &[1.0; 3],
            &[2, 4, 6],
            &[1.0; 3],
        );
        assert_eq!(out.east_keys, vec![1, 2, 3]);
        assert_eq!(out.south_keys, vec![4, 5]);
        assert_eq!(out.consumed_a, 3);
        assert_eq!(out.consumed_b, 2);
    }

    #[test]
    fn zip_full_two_chunks_interleaved() {
        // Equal maxes: everything merges; 2N-1 outputs split N east, rest south.
        let out = zip_step(
            3,
            &[1, 3, 6],
            &[1.0; 3],
            &[2, 4, 6],
            &[1.0; 3],
        );
        assert_eq!(out.east_keys, vec![1, 2, 3]);
        assert_eq!(out.south_keys, vec![4, 6]);
        assert_eq!(out.east_vals, vec![1.0, 1.0, 1.0]);
        assert_eq!(out.south_vals, vec![1.0, 2.0]);
        assert_eq!(out.consumed_a, 3);
        assert_eq!(out.consumed_b, 3);
    }

    #[test]
    fn zip_identical_chunks_fully_combine() {
        let out = zip_step(4, &[2, 4], &[1.0, 1.0], &[2, 4], &[2.0, 2.0]);
        assert_eq!(out.east_keys, vec![2, 4]);
        assert_eq!(out.east_vals, vec![3.0, 3.0]);
        assert_eq!(out.consumed_a, 2);
        assert_eq!(out.consumed_b, 2);
    }

    /// Invariant used by the software merge loop: every emitted key is
    /// strictly less than every unconsumed key (so east/south can be stored
    /// to the output stream immediately).
    #[test]
    fn zip_emitted_less_than_unconsumed() {
        let mut rng = crate::util::Pcg32::new(99);
        for _ in 0..500 {
            let n = 8;
            let mut a: Vec<u32> = (0..rng.gen_usize(n + 1)).map(|_| rng.gen_range(40)).collect();
            let mut b: Vec<u32> = (0..rng.gen_usize(n + 1)).map(|_| rng.gen_range(40)).collect();
            a.sort_unstable();
            a.dedup();
            b.sort_unstable();
            b.dedup();
            let av = vec![1.0f32; a.len()];
            let bv = vec![1.0f32; b.len()];
            let out = zip_step(n, &a, &av, &b, &bv);
            let emitted_max = out
                .south_keys
                .last()
                .or(out.east_keys.last())
                .copied();
            if let Some(em) = emitted_max {
                for &k in &a[out.consumed_a..] {
                    assert!(k > em, "unconsumed A key {k} <= emitted max {em}");
                }
                for &k in &b[out.consumed_b..] {
                    assert!(k > em, "unconsumed B key {k} <= emitted max {em}");
                }
            }
        }
    }
}
