//! Occupancy model of the matrix unit executing sort/zip instruction pairs
//! (paper §IV-C, Figure 6).
//!
//! A k-instruction processes R row micro-ops, each flowing through the array
//! in two passes (sorting/merging + compressing) of 2N+1 cycles each, with
//! row micro-ops issued back-to-back (one per cycle) and a 1-cycle stall at
//! each pass turn-around. The paired v-instruction overlaps: it may start as
//! soon as the top-left PE finishes its last compressing operation. Pairs do
//! *not* overlap each other (the output counters must be drained first), and
//! the instructions issue non-speculatively from the head of the ROB.

use crate::config::MatrixUnitConfig;

/// Occupancy/latency calculator for the SparseZipper systolic array.
#[derive(Clone, Copy, Debug)]
pub struct SystolicTiming {
    pub cfg: MatrixUnitConfig,
}

impl SystolicTiming {
    pub fn new(cfg: MatrixUnitConfig) -> Self {
        SystolicTiming { cfg }
    }

    /// Latency of a single micro-op through the array (one pass).
    pub fn pass_latency(&self) -> u64 {
        (2 * self.cfg.n + 1) as u64
    }

    /// Cycles one k-instruction occupies the array for `rows` micro-ops:
    /// fill/drain of the two passes + back-to-back row issue + turn-around
    /// stalls (Figure 6 shows the 1-cycle stalls at each pass boundary).
    pub fn k_instr_cycles(&self, rows: usize) -> u64 {
        if rows == 0 {
            return 0;
        }
        2 * self.pass_latency() + rows as u64 - 1 + self.cfg.pass_stalls as u64
    }

    /// Cycles for a full k/v pair over `rows` active streams. The
    /// v-instruction starts once the k-instruction's last compress op clears
    /// the top-left PE, hiding all but its tail (~one pass + the row drain).
    pub fn pair_cycles(&self, rows: usize) -> u64 {
        if rows == 0 {
            return self.cfg.issue_overhead as u64;
        }
        let k = self.k_instr_cycles(rows);
        let v_tail = self.pass_latency() + rows as u64 - 1 + self.cfg.pass_stalls as u64;
        k + v_tail + self.cfg.issue_overhead as u64
    }

    /// Dense-GEMM occupancy for an R x R x R tile (baseline matrix unit,
    /// used by the dense-path regression test): weights preloaded, R cycles
    /// of streaming + 2N fill/drain + MAC latency.
    pub fn dense_gemm_cycles(&self) -> u64 {
        let n = self.cfg.n as u64;
        2 * n + n + self.cfg.mac_latency as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn t() -> SystolicTiming {
        SystolicTiming::new(SystemConfig::default().unit)
    }

    #[test]
    fn pass_latency_16() {
        assert_eq!(t().pass_latency(), 33);
    }

    #[test]
    fn k_instr_full_group() {
        // 2*33 + 15 + 2 = 83
        assert_eq!(t().k_instr_cycles(16), 83);
    }

    #[test]
    fn pair_adds_v_tail_and_issue() {
        let tm = t();
        // 83 + (33 + 15 + 2) + 4 = 137
        assert_eq!(tm.pair_cycles(16), 137);
        assert!(tm.pair_cycles(1) < tm.pair_cycles(16));
    }

    #[test]
    fn zero_rows_costs_only_issue() {
        assert_eq!(t().pair_cycles(0), 4);
    }

    /// Figure 6 sanity: a 3x3 array sorting 3 streams. Pass latency 7,
    /// k-instr = 14 + 2 + 2 = 18 cycles — matches the figure's scale
    /// (first output appears around cycle 8, last around cycle 18).
    #[test]
    fn fig6_scale_3x3() {
        let tm = SystolicTiming::new(MatrixUnitConfig {
            n: 3,
            num_regs: 16,
            mac_latency: 4,
            issue_overhead: 0,
            pass_stalls: 2,
        });
        assert_eq!(tm.pass_latency(), 7);
        assert_eq!(tm.k_instr_cycles(3), 18);
    }

    #[test]
    fn monotone_in_rows() {
        let tm = t();
        for r in 1..16 {
            assert!(tm.pair_cycles(r) < tm.pair_cycles(r + 1));
        }
    }
}
