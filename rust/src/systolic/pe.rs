//! A single processing element of the SparseZipper systolic array
//! (paper §IV-A/§IV-B/§IV-D).
//!
//! Each PE repurposes the dense-GEMM MAC datapath: the adder compares keys,
//! a small control unit routes the inputs (forward / switch / combine), and
//! three control bits (source, duplicate, merge) travel with every datum.

/// One datum flowing through the array: a key (or value bits) plus the
/// control bits of §IV-B. `valid=false` is a pipeline bubble or an excluded
/// duplicate ("d" in Figure 5).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Datum {
    pub key: u32,
    /// f32 bits of the paired value (carried so the v-pass can be simulated
    /// with the same comparator decisions).
    pub val: f32,
    pub valid: bool,
    /// Source mask: bit 0 = west chunk, bit 1 = north chunk. Combined
    /// duplicates carry both bits.
    pub src: u8,
    /// Set when this key has met a larger-or-equal key from the other chunk
    /// *inside the array*. The compressing pass completes the rule (see
    /// `array::run_zip`): the paper leaves this state abstract (§III-C);
    /// direct meetings alone cannot realize the ISA-level merge rule, so the
    /// compress sweep finalizes it.
    pub merge: bool,
    /// Marks an invalidated duplicate slot ("d").
    pub dup: bool,
}

pub const SRC_WEST: u8 = 0b01;
pub const SRC_NORTH: u8 = 0b10;

impl Datum {
    pub const BUBBLE: Datum = Datum {
        key: 0,
        val: 0.0,
        valid: false,
        src: 0,
        merge: false,
        dup: false,
    };

    pub fn new(key: u32, val: f32, src: u8) -> Self {
        Datum {
            key,
            val,
            valid: true,
            src,
            merge: false,
            dup: false,
        }
    }
}

/// Routing decision a PE makes in one cycle (stored in the repurposed
/// weight register so the v-instruction can replay it).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// west->east, north->south
    Forward,
    /// west->south, north->east
    Switch,
    /// duplicate keys combined; combined datum goes south, east gets "d"
    Combine,
}

/// Compare-and-route: the core PE operation for `mssortk`/`mszipk`.
///
/// * both invalid: forward bubbles;
/// * one invalid: the invalid datum is "larger than any valid key" and is
///   routed east, the valid one south;
/// * equal keys: combine (values accumulate; east output is an invalid dup);
/// * otherwise: larger key east, smaller key south. When the larger datum
///   carries a source bit the smaller one lacks, the smaller key has now
///   met a >= key from the other chunk: its merge bit is set.
///
/// Returns (east, south, route).
pub fn compare_route(w: Datum, n: Datum) -> (Datum, Datum, Route) {
    match (w.valid, n.valid) {
        (false, false) => (w, n, Route::Forward),
        (false, true) => (w, n, Route::Forward),  // invalid west -> east
        (true, false) => (n, w, Route::Switch),   // invalid north -> east (via switch)
        (true, true) => {
            if w.key == n.key {
                let cross = (w.src | n.src) != w.src || (w.src | n.src) != n.src;
                let s = Datum {
                    key: w.key,
                    val: w.val + n.val,
                    valid: true,
                    src: w.src | n.src,
                    // A cross combine satisfies the merge rule for both
                    // constituents; a same-chunk combine inherits.
                    merge: cross || w.merge || n.merge,
                    dup: false,
                };
                let e = Datum {
                    key: w.key,
                    val: 0.0,
                    valid: false,
                    src: w.src,
                    merge: false,
                    dup: true,
                };
                (e, s, Route::Combine)
            } else if w.key > n.key {
                let mut n2 = n;
                if w.src & !n.src != 0 {
                    n2.merge = true; // n met a larger key from the other side
                }
                (w, n2, Route::Forward)
            } else {
                let mut w2 = w;
                if n.src & !w.src != 0 {
                    w2.merge = true;
                }
                (n, w2, Route::Switch)
            }
        }
    }
}

/// Hard-switch for diagonal PEs during `mssortk` (keeps the two chunks from
/// intermixing, paper §IV-A).
pub fn hard_switch(w: Datum, n: Datum) -> (Datum, Datum, Route) {
    (n, w, Route::Switch)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn larger_goes_east() {
        let (e, s, r) = compare_route(Datum::new(7, 1.0, SRC_WEST), Datum::new(3, 2.0, SRC_NORTH));
        assert_eq!(e.key, 7);
        assert_eq!(s.key, 3);
        assert_eq!(r, Route::Forward);
        assert!(s.merge, "smaller key met >= key from other side");
    }

    #[test]
    fn smaller_west_switches() {
        let (e, s, r) = compare_route(Datum::new(2, 1.0, SRC_WEST), Datum::new(9, 2.0, SRC_NORTH));
        assert_eq!(e.key, 9);
        assert_eq!(s.key, 2);
        assert_eq!(r, Route::Switch);
        assert!(s.merge);
        assert!(!e.merge);
    }

    #[test]
    fn equal_keys_combine_values() {
        let (e, s, r) = compare_route(Datum::new(5, 1.5, SRC_WEST), Datum::new(5, 2.5, SRC_NORTH));
        assert_eq!(r, Route::Combine);
        assert!(!e.valid && e.dup);
        assert!(s.valid);
        assert_eq!(s.val, 4.0);
        assert!(s.merge);
        assert_eq!(s.src, SRC_WEST | SRC_NORTH);
    }

    #[test]
    fn same_chunk_equal_combines_without_merge_bit() {
        let (_, s, r) = compare_route(Datum::new(5, 1.0, SRC_NORTH), Datum::new(5, 1.0, SRC_NORTH));
        assert_eq!(r, Route::Combine);
        assert!(!s.merge);
        assert_eq!(s.src, SRC_NORTH);
    }

    #[test]
    fn combined_datum_sets_cross_bit_of_smaller() {
        // Smaller pure-west key meeting a combined (west|north) larger key
        // counts as meeting the other chunk.
        let mut big = Datum::new(9, 1.0, SRC_WEST | SRC_NORTH);
        big.merge = true;
        let (_, s, _) = compare_route(big, Datum::new(3, 1.0, SRC_WEST));
        assert!(s.merge);
    }

    #[test]
    fn invalid_is_larger_than_valid() {
        let inv = Datum { valid: false, dup: true, ..Datum::BUBBLE };
        let (e, s, _) = compare_route(inv, Datum::new(1, 1.0, SRC_NORTH));
        assert!(!e.valid);
        assert_eq!(s.key, 1);
        let (e, s, _) = compare_route(Datum::new(1, 1.0, SRC_WEST), inv);
        assert!(!e.valid);
        assert_eq!(s.key, 1);
    }

    #[test]
    fn bubbles_pass_through() {
        let (e, s, _) = compare_route(Datum::BUBBLE, Datum::BUBBLE);
        assert!(!e.valid && !s.valid);
    }

    #[test]
    fn merge_bit_not_set_within_chunk() {
        let (_, s, _) = compare_route(Datum::new(7, 1.0, SRC_WEST), Datum::new(3, 2.0, SRC_WEST));
        assert!(!s.merge, "same-chunk comparison must not set merge bit");
    }
}
