//! Post-synthesis component-level area model (Table IV).
//!
//! Seeded with the paper's 12nm per-component numbers and composed exactly
//! the way the paper composes them: per-PE area x N^2, skew/deskew shift
//! register buffers, SRAM matrix registers, and the popcount/counter logic
//! SparseZipper adds. Parameterized over array size and register count so
//! `spz table4 --sweep` can explore the design space.

/// One synthesizable component with its 12nm area estimate.
#[derive(Clone, Copy, Debug)]
pub struct Component {
    pub name: &'static str,
    /// Area of one instance, in k-um^2.
    pub area_kum2: f64,
    /// Instances in the baseline dense-GEMM design.
    pub count_baseline: usize,
    /// Instances in the SparseZipper design.
    pub count_spz: usize,
}

/// Area model for an N x N systolic array with `num_regs` matrix registers.
#[derive(Clone, Copy, Debug)]
pub struct AreaModel {
    pub n: usize,
    pub num_regs: usize,
}

/// Paper Table IV per-component areas for the 16x16 / 512-bit design point.
const PE_BASE: f64 = 0.45;
const PE_SPZ: f64 = 0.51;
const SKEW_16: f64 = 3.16;
const MATREG_16X512: f64 = 0.96;
const POPCOUNT_16: f64 = 0.45;

impl AreaModel {
    pub fn paper() -> Self {
        AreaModel { n: 16, num_regs: 16 }
    }

    /// Scale a 16-lane buffer-ish component to n lanes. Skew/deskew buffers
    /// are arrays of n shift registers of average depth n/2 -> quadratic.
    fn skew_area(&self) -> f64 {
        let s = self.n as f64 / 16.0;
        SKEW_16 * s * s
    }

    /// SRAM matrix register: n rows x (n * 32) bits -> quadratic in n.
    fn matreg_area(&self) -> f64 {
        let s = self.n as f64 / 16.0;
        MATREG_16X512 * s * s
    }

    /// Popcount logic: n counters of (log2 n + 1) bits plus counter vectors.
    fn popcount_area(&self) -> f64 {
        let bits16 = 16.0 * 5.0;
        let bits = self.n as f64 * ((self.n as f64).log2() + 1.0);
        POPCOUNT_16 * bits / bits16
    }

    /// Component table for this design point.
    pub fn components(&self) -> Vec<Component> {
        let pes = self.n * self.n;
        vec![
            Component {
                name: "Baseline PE (32-bit MAC)",
                area_kum2: PE_BASE,
                count_baseline: pes,
                count_spz: 0,
            },
            Component {
                name: "SparseZipper PE (MAC + compare/route ctl)",
                area_kum2: PE_SPZ,
                count_baseline: 0,
                count_spz: pes,
            },
            Component {
                name: "Skew buffer",
                area_kum2: self.skew_area(),
                count_baseline: 2,
                count_spz: 2,
            },
            Component {
                name: "Deskew buffer",
                area_kum2: self.skew_area(),
                count_baseline: 1,
                count_spz: 2, // second write port needs a second deskew (§IV-D)
            },
            Component {
                name: "Matrix register (SRAM)",
                area_kum2: self.matreg_area(),
                count_baseline: self.num_regs,
                count_spz: self.num_regs,
            },
            Component {
                name: "Popcount + counter vectors",
                area_kum2: self.popcount_area(),
                count_baseline: 0,
                count_spz: 1,
            },
        ]
    }

    pub fn baseline_total(&self) -> f64 {
        self.components()
            .iter()
            .map(|c| c.area_kum2 * c.count_baseline as f64)
            .sum()
    }

    pub fn spz_total(&self) -> f64 {
        self.components()
            .iter()
            .map(|c| c.area_kum2 * c.count_spz as f64)
            .sum()
    }

    /// SparseZipper area overhead over the baseline array (paper: 12.72%).
    pub fn overhead_pct(&self) -> f64 {
        100.0 * (self.spz_total() - self.baseline_total()) / self.baseline_total()
    }

    /// Render Table IV.
    pub fn table4(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "Table IV. Post-synthesis area estimates, {0}x{0} systolic array ({1} matrix regs)\n",
            self.n, self.num_regs
        ));
        s.push_str(&format!(
            "{:<46} {:>9} {:>10} {:>12}\n",
            "Component", "k um^2", "Baseline", "SparseZipper"
        ));
        for c in self.components() {
            let fmt_count = |k: usize| {
                if k == 0 {
                    String::new()
                } else {
                    format!("x {k}")
                }
            };
            s.push_str(&format!(
                "{:<46} {:>9.2} {:>10} {:>12}\n",
                c.name,
                c.area_kum2,
                fmt_count(c.count_baseline),
                fmt_count(c.count_spz)
            ));
        }
        s.push_str(&format!(
            "{:<46} {:>9} {:>10.2} {:>12.2}\n",
            "Total", "", self.baseline_total(), self.spz_total()
        ));
        s.push_str(&format!(
            "SparseZipper vs. baseline overhead: {:.2}%  (paper: 12.72%)\n",
            self.overhead_pct()
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_design_point_matches_table4() {
        let m = AreaModel::paper();
        // Paper totals: 140.16 baseline, 158.00 spz, 12.72% overhead
        // (component values are rounded in print; allow ~1%).
        assert!((m.baseline_total() - 140.16).abs() < 1.5, "{}", m.baseline_total());
        assert!((m.spz_total() - 158.00).abs() < 1.5, "{}", m.spz_total());
        assert!((m.overhead_pct() - 12.72).abs() < 1.0, "{}", m.overhead_pct());
    }

    #[test]
    fn overhead_shrinks_relative_for_smaller_popcount_share() {
        // At larger N the PE delta dominates; overhead approaches
        // (0.51-0.45)/0.45 of the PE share and stays in a sane band.
        for n in [8usize, 16, 32] {
            let m = AreaModel { n, num_regs: 16 };
            let o = m.overhead_pct();
            assert!(o > 5.0 && o < 25.0, "n={n} overhead {o}");
        }
    }

    #[test]
    fn table_renders() {
        let t = AreaModel::paper().table4();
        assert!(t.contains("SparseZipper"));
        assert!(t.contains("Skew buffer"));
    }
}
