//! First-order component-level area model (paper §VI-B, Table IV).

pub mod model;

pub use model::{AreaModel, Component};
