//! Architectural state: matrix (tile) registers and the four special-purpose
//! counter vector registers (paper §III-B).

/// One R x R matrix register of 32-bit elements. Row `s` holds the current
/// chunk of key-value stream `s`. Stored as raw u32 bits; value registers
/// reinterpret them as f32.
#[derive(Clone, Debug, PartialEq)]
pub struct MatReg {
    pub n: usize,
    pub data: Vec<u32>, // row-major n*n
}

impl MatReg {
    pub fn new(n: usize) -> Self {
        MatReg { n, data: vec![0; n * n] }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[u32] {
        &self.data[r * self.n..(r + 1) * self.n]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [u32] {
        &mut self.data[r * self.n..(r + 1) * self.n]
    }

    pub fn row_f32(&self, r: usize) -> Vec<f32> {
        self.row(r).iter().map(|&b| f32::from_bits(b)).collect()
    }

    pub fn set_row_u32(&mut self, r: usize, xs: &[u32]) {
        let n = self.n;
        let row = self.row_mut(r);
        row[..xs.len().min(n)].copy_from_slice(&xs[..xs.len().min(n)]);
        for x in row[xs.len().min(n)..].iter_mut() {
            *x = 0;
        }
    }

    pub fn set_row_f32(&mut self, r: usize, xs: &[f32]) {
        let bits: Vec<u32> = xs.iter().map(|v| v.to_bits()).collect();
        self.set_row_u32(r, &bits);
    }
}

/// A counter vector register: R counters of ceil(log2(R))+1 bits
/// (stored widened; the bit-width matters only for the area model).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CounterVec(pub Vec<u8>);

impl CounterVec {
    pub fn new(n: usize) -> Self {
        CounterVec(vec![0; n])
    }
}

/// The full SparseZipper register file: `num_regs` matrix registers plus
/// IC0/IC1/OC0/OC1 counter vectors.
#[derive(Clone, Debug)]
pub struct RegFile {
    pub n: usize,
    pub tr: Vec<MatReg>,
    pub ic0: CounterVec,
    pub ic1: CounterVec,
    pub oc0: CounterVec,
    pub oc1: CounterVec,
}

impl RegFile {
    pub fn new(n: usize, num_regs: usize) -> Self {
        RegFile {
            n,
            tr: (0..num_regs).map(|_| MatReg::new(n)).collect(),
            ic0: CounterVec::new(n),
            ic1: CounterVec::new(n),
            oc0: CounterVec::new(n),
            oc1: CounterVec::new(n),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_roundtrip() {
        let mut m = MatReg::new(4);
        m.set_row_u32(1, &[9, 8, 7]);
        assert_eq!(m.row(1), &[9, 8, 7, 0]);
    }

    #[test]
    fn f32_bits_roundtrip() {
        let mut m = MatReg::new(4);
        m.set_row_f32(0, &[1.5, -2.25]);
        let back = m.row_f32(0);
        assert_eq!(back[0], 1.5);
        assert_eq!(back[1], -2.25);
    }

    #[test]
    fn regfile_shape() {
        let rf = RegFile::new(16, 16);
        assert_eq!(rf.tr.len(), 16);
        assert_eq!(rf.tr[0].data.len(), 256);
        assert_eq!(rf.oc0.0.len(), 16);
    }
}
