//! Figure 4 reproduction: the RISC-V assembly kernels for sorting and
//! merging key-value chunks across VLEN streams, emitted from the same
//! `Instr` structures the simulator accounts — the listing stays consistent
//! with the ISA definition by construction.

use crate::isa::instr::{CounterSel, Instr};

fn line(out: &mut String, n: usize, asm: &str, comment: &str) {
    out.push_str(&format!("{n:>2}  {asm:<42} # {comment}\n"));
}

/// Figure 4(a): sorting key-value chunks from VLEN streams.
pub fn fig4a_sort_kernel() -> String {
    let mut s = String::from(
        "Figure 4(a). Sorting key-value chunks across VLEN streams\n\
         #  a0=key base  a1=val base  v0/v1=chunk offsets  v2/v3=chunk lengths\n",
    );
    line(&mut s, 8, &Instr::MlxeT { td1: 0, rs1: 10, vs2: 0, vs3: 2 }.to_string(), "load keys, chunk set 0");
    line(&mut s, 9, &Instr::MlxeT { td1: 1, rs1: 11, vs2: 0, vs3: 2 }.to_string(), "load values, chunk set 0");
    line(&mut s, 10, &Instr::MlxeT { td1: 2, rs1: 10, vs2: 1, vs3: 3 }.to_string(), "load keys, chunk set 1");
    line(&mut s, 11, &Instr::MlxeT { td1: 3, rs1: 11, vs2: 1, vs3: 3 }.to_string(), "load values, chunk set 1");
    line(&mut s, 13, &Instr::MssortK { td1: 0, td2: 2, vs1: 2, vs2: 3 }.to_string(), "sort keys (both chunk sets)");
    line(&mut s, 14, &Instr::MssortV { td1: 1, td2: 3, vs1: 2, vs2: 3 }.to_string(), "shuffle+accumulate values");
    line(&mut s, 16, &Instr::MmvVo { vd: 4, which: CounterSel::Oc0 }.to_string(), "output chunk lengths (set 0)");
    line(&mut s, 17, &Instr::MmvVo { vd: 5, which: CounterSel::Oc1 }.to_string(), "output chunk lengths (set 1)");
    line(&mut s, 19, &Instr::MsxeT { ts1: 0, rs1: 10, vs2: 0, vs3: 4 }.to_string(), "store sorted keys, set 0");
    line(&mut s, 20, &Instr::MsxeT { ts1: 1, rs1: 11, vs2: 0, vs3: 4 }.to_string(), "store values, set 0");
    line(&mut s, 21, &Instr::MsxeT { ts1: 2, rs1: 10, vs2: 1, vs3: 5 }.to_string(), "store sorted keys, set 1");
    line(&mut s, 22, &Instr::MsxeT { ts1: 3, rs1: 11, vs2: 1, vs3: 5 }.to_string(), "store values, set 1");
    s
}

/// Figure 4(b): merging key-value chunks from adjacent partitions.
pub fn fig4b_merge_kernel() -> String {
    let mut s = String::from(
        "Figure 4(b). Merging key-value chunks from adjacent partitions\n\
         #  a0=key base  a1=val base  v0/v1=partition offsets  v2/v3=remaining lengths\n",
    );
    line(&mut s, 8, &Instr::MlxeT { td1: 0, rs1: 10, vs2: 0, vs3: 2 }.to_string(), "load keys, partition A");
    line(&mut s, 9, &Instr::MlxeT { td1: 1, rs1: 11, vs2: 0, vs3: 2 }.to_string(), "load values, partition A");
    line(&mut s, 10, &Instr::MlxeT { td1: 2, rs1: 10, vs2: 1, vs3: 3 }.to_string(), "load keys, partition B");
    line(&mut s, 11, &Instr::MlxeT { td1: 3, rs1: 11, vs2: 1, vs3: 3 }.to_string(), "load values, partition B");
    line(&mut s, 13, &Instr::MszipK { td1: 0, td2: 2, vs1: 2, vs2: 3 }.to_string(), "merge sorted keys");
    line(&mut s, 14, &Instr::MszipV { td1: 1, td2: 3, vs1: 2, vs2: 3 }.to_string(), "shuffle+accumulate values");
    line(&mut s, 16, &Instr::MmvVi { vd: 6, which: CounterSel::Ic0 }.to_string(), "merged counts, partition A");
    line(&mut s, 17, &Instr::MmvVi { vd: 7, which: CounterSel::Ic1 }.to_string(), "merged counts, partition B");
    line(&mut s, 19, &Instr::MmvVo { vd: 8, which: CounterSel::Oc0 }.to_string(), "east output lengths");
    line(&mut s, 20, &Instr::MmvVo { vd: 9, which: CounterSel::Oc1 }.to_string(), "south output lengths");
    line(&mut s, 22, &Instr::MsxeT { ts1: 0, rs1: 10, vs2: 4, vs3: 8 }.to_string(), "store east keys");
    line(&mut s, 23, &Instr::MsxeT { ts1: 1, rs1: 11, vs2: 4, vs3: 8 }.to_string(), "store east values");
    line(&mut s, 24, &Instr::MsxeT { ts1: 2, rs1: 10, vs2: 5, vs3: 9 }.to_string(), "store south keys");
    line(&mut s, 25, &Instr::MsxeT { ts1: 3, rs1: 11, vs2: 5, vs3: 9 }.to_string(), "store south values");
    line(&mut s, 27, "vadd.vv v0, v0, v6 / v1, v1, v7", "advance partition pointers by IC");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4a_structure() {
        let s = fig4a_sort_kernel();
        assert_eq!(s.matches("mlxe.t").count(), 4);
        assert_eq!(s.matches("msxe.t").count(), 4);
        assert!(s.contains("mssortk.tt tr0, tr2"));
        assert!(s.contains("mssortv.tt tr1, tr3"));
        assert_eq!(s.matches("mmv.vo").count(), 2);
    }

    #[test]
    fn fig4b_structure() {
        let s = fig4b_merge_kernel();
        assert!(s.contains("mszipk.tt tr0, tr2"));
        assert!(s.contains("mszipv.tt tr1, tr3"));
        assert_eq!(s.matches("mmv.vi").count(), 2);
        assert_eq!(s.matches("mmv.vo").count(), 2);
        assert_eq!(s.matches("msxe.t").count(), 4);
    }
}
