//! SparseZipper instructions (Table I) plus the base scalar/vector operation
//! classes the simulator accounts. The `Display` impl reproduces Table I's
//! assembly syntax for `spz isa`.

use std::fmt;

/// Which special-purpose counter vector an `mmv` reads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CounterSel {
    Ic0,
    Ic1,
    Oc0,
    Oc1,
}

/// SparseZipper ISA extension instructions (register indices are
/// architectural numbers: td/ts = matrix regs, vs/vd = vector regs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Instr {
    /// mlxe.t td1, 0(rs1), vs2, vs3 — indexed matrix load (row-wise
    /// unit-stride micro-ops; vs2 = byte offsets, vs3 = stream lengths).
    MlxeT { td1: u8, rs1: u8, vs2: u8, vs3: u8 },
    /// msxe.t ts1, 0(rs1), vs2, vs3 — indexed matrix store.
    MsxeT { ts1: u8, rs1: u8, vs2: u8, vs3: u8 },
    /// mssortk.tt td1, td2, vs1, vs2 — sort keys in both registers.
    MssortK { td1: u8, td2: u8, vs1: u8, vs2: u8 },
    /// mssortv.tt — shuffle & accumulate values per last key sort.
    MssortV { td1: u8, td2: u8, vs1: u8, vs2: u8 },
    /// mszipk.tt — merge sorted keys across the two registers.
    MszipK { td1: u8, td2: u8, vs1: u8, vs2: u8 },
    /// mszipv.tt — shuffle & accumulate values per last key merge.
    MszipV { td1: u8, td2: u8, vs1: u8, vs2: u8 },
    /// mmv.vi vd, cimm — move input counter vector into vd.
    MmvVi { vd: u8, which: CounterSel },
    /// mmv.vo vd, cimm — move output counter vector into vd.
    MmvVo { vd: u8, which: CounterSel },
}

impl Instr {
    /// Table I description string.
    pub fn describe(&self) -> &'static str {
        match self {
            Instr::MlxeT { .. } => "Load data into td1 using indices in vs2; rs1 is the base address; vs3 are stream lengths.",
            Instr::MsxeT { .. } => "Store data from ts1 using indices in vs2; rs1 is the base address; vs3 are stream lengths.",
            Instr::MssortK { .. } => "Sort keys in td1 and td2; vs1 and vs2 are input lengths.",
            Instr::MssortV { .. } => "Shuffle & accumulate values in td1 and td2 based on last key sorting results.",
            Instr::MszipK { .. } => "Merge keys in td1 and td2; vs1 and vs2 are input lengths.",
            Instr::MszipV { .. } => "Shuffle & accumulate values in td1 and td2 based on last key merging results.",
            Instr::MmvVi { .. } => "Move values from an input counter vector IC[cimm] to vd.",
            Instr::MmvVo { .. } => "Move values from an output counter vector OC[cimm] to vd.",
        }
    }

    /// Does this instruction execute on the systolic array?
    pub fn uses_matrix_unit(&self) -> bool {
        matches!(
            self,
            Instr::MssortK { .. } | Instr::MssortV { .. } | Instr::MszipK { .. } | Instr::MszipV { .. }
        )
    }
}

impl fmt::Display for Instr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Instr::MlxeT { td1, rs1, vs2, vs3 } => {
                write!(f, "mlxe.t tr{td1}, 0(x{rs1}), v{vs2}, v{vs3}")
            }
            Instr::MsxeT { ts1, rs1, vs2, vs3 } => {
                write!(f, "msxe.t tr{ts1}, 0(x{rs1}), v{vs2}, v{vs3}")
            }
            Instr::MssortK { td1, td2, vs1, vs2 } => {
                write!(f, "mssortk.tt tr{td1}, tr{td2}, v{vs1}, v{vs2}")
            }
            Instr::MssortV { td1, td2, vs1, vs2 } => {
                write!(f, "mssortv.tt tr{td1}, tr{td2}, v{vs1}, v{vs2}")
            }
            Instr::MszipK { td1, td2, vs1, vs2 } => {
                write!(f, "mszipk.tt tr{td1}, tr{td2}, v{vs1}, v{vs2}")
            }
            Instr::MszipV { td1, td2, vs1, vs2 } => {
                write!(f, "mszipv.tt tr{td1}, tr{td2}, v{vs1}, v{vs2}")
            }
            Instr::MmvVi { vd, which } => write!(f, "mmv.vi v{vd}, {}", sel_imm(*which)),
            Instr::MmvVo { vd, which } => write!(f, "mmv.vo v{vd}, {}", sel_imm(*which)),
        }
    }
}

fn sel_imm(s: CounterSel) -> u8 {
    match s {
        CounterSel::Ic0 | CounterSel::Oc0 => 0,
        CounterSel::Ic1 | CounterSel::Oc1 => 1,
    }
}

/// Render the full Table I listing.
pub fn table1() -> String {
    let rows: Vec<Instr> = vec![
        Instr::MlxeT { td1: 1, rs1: 1, vs2: 2, vs3: 3 },
        Instr::MsxeT { ts1: 1, rs1: 1, vs2: 2, vs3: 3 },
        Instr::MssortK { td1: 1, td2: 2, vs1: 1, vs2: 2 },
        Instr::MssortV { td1: 1, td2: 2, vs1: 1, vs2: 2 },
        Instr::MszipK { td1: 1, td2: 2, vs1: 1, vs2: 2 },
        Instr::MszipV { td1: 1, td2: 2, vs1: 1, vs2: 2 },
        Instr::MmvVi { vd: 1, which: CounterSel::Ic0 },
        Instr::MmvVo { vd: 1, which: CounterSel::Oc0 },
    ];
    let mut s = String::from("Table I. SparseZipper instructions\n");
    for r in rows {
        s.push_str(&format!("  {:<38} {}\n", r.to_string(), r.describe()));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_table1_syntax() {
        let i = Instr::MssortK { td1: 0, td2: 2, vs1: 4, vs2: 5 };
        assert_eq!(i.to_string(), "mssortk.tt tr0, tr2, v4, v5");
    }

    #[test]
    fn matrix_unit_classification() {
        assert!(Instr::MszipK { td1: 0, td2: 1, vs1: 0, vs2: 1 }.uses_matrix_unit());
        assert!(!Instr::MlxeT { td1: 0, rs1: 1, vs2: 2, vs3: 3 }.uses_matrix_unit());
        assert!(!Instr::MmvVi { vd: 0, which: CounterSel::Ic0 }.uses_matrix_unit());
    }

    #[test]
    fn table1_has_eight_instructions() {
        let t = table1();
        assert_eq!(t.lines().count(), 9); // header + 8
        assert!(t.contains("mszipv.tt"));
        assert!(t.contains("mmv.vo"));
    }
}
