//! The SparseZipper instruction-set extension (paper §III): instruction
//! definitions (Table I) and the architectural state they operate on
//! (matrix registers, counter vector registers).

pub mod codegen;
pub mod instr;
pub mod regfile;

pub use instr::{CounterSel, Instr};
pub use regfile::{CounterVec, MatReg, RegFile};
